//! Stochastic trace / logdet through the streaming engine (ISSUE 9):
//! wall-clock of a full SLQ drain — every probe lane on one shared
//! panel — at 1 and 4 sweep workers.
//!
//! Before timing anything, the harness asserts the stochastic contract
//! end to end: the report is bit-identical across worker counts and
//! both sweep modes (probes are seeded at submission, so scheduling
//! must not leak into the answer), and on the smaller instance the
//! dense-Cholesky oracle value lies inside the reported combined
//! interval (4× guard band over the 95% t-interval).
//!
//! Run: `cargo bench --bench bench_slq`

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::linalg::Cholesky;
use gauss_bif::quadrature::engine::{Engine, EngineConfig, SweepMode};
use gauss_bif::quadrature::query::{Answer, Query};
use gauss_bif::quadrature::stochastic::{SlqConfig, SpectralFn, StochasticReport};
use gauss_bif::quadrature::GqlOptions;
use gauss_bif::sparse::{Csr, SymOp};
use gauss_bif::util::bench::{Bencher, Stats, Table};
use gauss_bif::util::rng::Rng;
use std::sync::Arc;

const PROBES: usize = 16;
const TOL: f64 = 1e-2;

struct Instance {
    a: Arc<Csr>,
    opts: GqlOptions,
    slq: SlqConfig,
}

fn build(n: usize, seed: u64) -> Instance {
    let mut rng = Rng::new(seed);
    let density = 5e-3_f64.max(8.0 / (n as f64 * n as f64));
    let (a, w) = random_sparse_spd(&mut rng, n, density, 0.5);
    Instance {
        a: Arc::new(a),
        opts: GqlOptions::new(w.lo, w.hi),
        slq: SlqConfig::new(PROBES, seed ^ 0x51D, TOL),
    }
}

fn query(inst: &Instance, kind: &str) -> Query {
    match kind {
        "trace_inv" => Query::Trace { f: SpectralFn::Inverse, cfg: inst.slq },
        "logdet" => Query::LogDet { cfg: inst.slq },
        other => panic!("unknown kind {other}"),
    }
}

fn drain(inst: &Instance, q: &Query, workers: usize, mode: SweepMode) -> StochasticReport {
    let cfg = EngineConfig::default().with_workers(workers).with_sweep_mode(mode);
    let mut eng = Engine::new(cfg).expect("bench engine config is valid");
    let t = eng.submit(1, Arc::clone(&inst.a) as Arc<dyn SymOp>, inst.opts, q.clone());
    eng.drain();
    eng.answer(t)
        .and_then(Answer::stochastic)
        .expect("stochastic queries answer stochastically")
        .clone()
}

fn same(a: &StochasticReport, b: &StochasticReport) -> bool {
    a.estimate.to_bits() == b.estimate.to_bits()
        && a.combined.lo.to_bits() == b.combined.lo.to_bits()
        && a.combined.hi.to_bits() == b.combined.hi.to_bits()
}

fn main() {
    let mut b = Bencher::quick();
    println!("stochastic trace/logdet drains: {PROBES} probes, tol {TOL:.0e}, 1 vs 4 workers\n");

    // oracle check on an instance small enough to densify
    let small = build(200, 0xB51);
    let ch = Cholesky::factor(&small.a.to_dense()).expect("generator output is PD");
    let exact_tr: f64 = (0..small.a.n)
        .map(|i| {
            let mut e = vec![0.0; small.a.n];
            e[i] = 1.0;
            ch.bif(&e)
        })
        .sum();
    for (kind, exact) in [("trace_inv", exact_tr), ("logdet", ch.logdet())] {
        let r = drain(&small, &query(&small, kind), 1, SweepMode::Stealing);
        let guard = 4.0 * (r.combined.width() / 2.0) + 1e-9 * (1.0 + exact.abs());
        assert!(
            (exact - r.combined.mid()).abs() <= guard,
            "{kind}: exact {exact} outside guarded interval [{}, {}]",
            r.combined.lo,
            r.combined.hi
        );
    }

    let mut table = Table::new(&["n", "kind", "w=1", "w=4"]);
    for &n in &[400usize, 800] {
        let inst = build(n, 0xB51 ^ n as u64);
        for kind in ["trace_inv", "logdet"] {
            let q = query(&inst, kind);
            // scheduling must not leak into a pinned-seed answer
            let want = drain(&inst, &q, 1, SweepMode::Stealing);
            for workers in [2usize, 4] {
                for mode in [SweepMode::Stealing, SweepMode::Static] {
                    assert!(
                        same(&want, &drain(&inst, &q, workers, mode)),
                        "n={n} {kind}: answer changed at {workers} workers ({mode:?})"
                    );
                }
            }
            let w1 = b.bench(&format!("n={n} {kind} w=1"), || {
                drain(&inst, &q, 1, SweepMode::Stealing)
            });
            let w4 = b.bench(&format!("n={n} {kind} w=4"), || {
                drain(&inst, &q, 4, SweepMode::Stealing)
            });
            table.row(vec![
                n.to_string(),
                kind.into(),
                Stats::fmt_time(w1.median_ns),
                Stats::fmt_time(w4.median_ns),
            ]);
        }
    }
    println!("\n{}", table.render());

    match b.write_json("slq") {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_slq.json not written: {e}"),
    }
}
