//! Multi-operator streaming engine vs per-operator sequential sessions
//! (ISSUE 5): the same estimate workload — several operators, several
//! queries each — drained one session at a time vs jointly by the engine
//! at 1/2/4 sweep workers.
//!
//! Answers are asserted bit-identical across every configuration before
//! timing (the engine is a scheduler, not a numeric path); wall-clock is
//! the headline here because worker fan-out is the one axis panel-sweep
//! counts cannot show.
//!
//! Run: `cargo bench --bench bench_engine`

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::quadrature::block::StopRule;
use gauss_bif::quadrature::engine::{Engine, EngineConfig, OpKey, SweepMode};
use gauss_bif::quadrature::query::{Answer, Query, Session};
use gauss_bif::quadrature::race::RacePolicy;
use gauss_bif::quadrature::GqlOptions;
use gauss_bif::sparse::Csr;
use gauss_bif::util::bench::{Bencher, Stats, Table};
use gauss_bif::util::rng::Rng;
use std::sync::Arc;

struct Workload {
    ops: Vec<(Arc<Csr>, GqlOptions)>,
    /// per-operator query vectors
    queries: Vec<Vec<Vec<f64>>>,
}

const STOP: StopRule = StopRule::GapRel(1e-8);
const WIDTH: usize = 8;

fn build(n: usize, ops: usize, per_op: usize, seed: u64) -> Workload {
    build_sizes(&vec![n; ops], per_op, seed)
}

/// Mixed operator sizes (the skewed-workload builder): one entry per
/// operator, so a single oversized entry models the straggler that makes
/// static chunked fan-out idle at the tail.
fn build_sizes(sizes: &[usize], per_op: usize, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    let mut kernels = Vec::new();
    let mut queries = Vec::new();
    for &n in sizes {
        let density = 5e-3_f64.max(8.0 / (n as f64 * n as f64));
        let (a, w) = random_sparse_spd(&mut rng, n, density, 0.05);
        let qs: Vec<Vec<f64>> = (0..per_op)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        kernels.push((Arc::new(a), GqlOptions::new(w.lo, w.hi)));
        queries.push(qs);
    }
    Workload { ops: kernels, queries }
}

/// Per-operator sequential serving: drain each operator's session to
/// completion before the next starts. Returns the answers' Gauss bits.
fn run_sequential(w: &Workload) -> Vec<u64> {
    let mut bits = Vec::new();
    for ((a, opts), qs) in w.ops.iter().zip(&w.queries) {
        let mut s = Session::new(&**a, *opts, WIDTH, RacePolicy::Prune);
        let qids: Vec<usize> = qs
            .iter()
            .map(|u| s.submit(Query::Estimate { u: u.clone(), stop: STOP }))
            .collect();
        let answers = s.run(&**a);
        for qid in qids {
            match &answers[qid] {
                Answer::Estimate { bounds, .. } => bits.push(bounds.gauss.to_bits()),
                other => panic!("wrong answer kind {other:?}"),
            }
        }
    }
    bits
}

/// Joint serving: every operator's session advances each engine round,
/// swept by `workers` threads under the default (work-stealing) fan-out.
fn run_engine(w: &Workload, workers: usize) -> Vec<u64> {
    run_engine_mode(w, workers, SweepMode::Stealing)
}

fn run_engine_mode(w: &Workload, workers: usize, sweep: SweepMode) -> Vec<u64> {
    run_engine_cfg(
        w,
        EngineConfig::default()
            .with_width(WIDTH)
            .with_lanes(WIDTH * w.ops.len())
            .with_workers(workers)
            .with_sweep_mode(sweep),
    )
}

fn run_engine_cfg(w: &Workload, cfg: EngineConfig) -> Vec<u64> {
    let mut eng = Engine::new(cfg).expect("static engine config is valid");
    let mut tickets = Vec::new();
    for (k, ((a, opts), qs)) in w.ops.iter().zip(&w.queries).enumerate() {
        for u in qs {
            tickets.push(eng.submit(
                k as OpKey,
                Arc::clone(a),
                *opts,
                Query::Estimate { u: u.clone(), stop: STOP },
            ));
        }
    }
    eng.drain();
    tickets
        .iter()
        .map(|&t| match eng.answer(t).expect("drained") {
            Answer::Estimate { bounds, .. } => bounds.gauss.to_bits(),
            other => panic!("wrong answer kind {other:?}"),
        })
        .collect()
}

/// Drain the workload once with round profiling on; returns the measured
/// sweep tail idleness and how many slot claims crossed chunk boundaries.
fn profile_drain(w: &Workload, workers: usize, sweep: SweepMode) -> (f64, usize) {
    let mut eng = Engine::new(
        EngineConfig::default()
            .with_width(WIDTH)
            .with_lanes(WIDTH * w.ops.len())
            .with_workers(workers)
            .with_sweep_mode(sweep)
            .with_profile(true),
    )
    .expect("static engine config is valid");
    for (k, ((a, opts), qs)) in w.ops.iter().zip(&w.queries).enumerate() {
        for u in qs {
            eng.submit(
                k as OpKey,
                Arc::clone(a),
                *opts,
                Query::Estimate { u: u.clone(), stop: STOP },
            );
        }
    }
    eng.drain();
    let idle = eng.profile().map(|p| p.idle_frac()).unwrap_or(0.0);
    (idle, eng.stats().steals)
}

fn main() {
    let mut b = Bencher::quick();
    println!("multi-operator estimate workload: engine (1/2/4 workers) vs sequential sessions\n");
    let mut table = Table::new(&[
        "n", "ops", "q/op", "sequential", "engine w=1", "engine w=2", "engine w=4",
    ]);
    for &(n, ops, per_op) in &[(400usize, 4usize, 8usize), (900, 6, 8)] {
        let w = build(n, ops, per_op, 0xE6B ^ n as u64);
        // identity across every configuration before timing anything
        let want = run_sequential(&w);
        for workers in [1usize, 2, 4] {
            assert_eq!(
                want,
                run_engine(&w, workers),
                "engine answers diverged at {workers} workers"
            );
        }
        let seq = b.bench(&format!("n={n} ops={ops} sequential"), || run_sequential(&w));
        let e1 = b.bench(&format!("n={n} ops={ops} engine w=1"), || run_engine(&w, 1));
        let e2 = b.bench(&format!("n={n} ops={ops} engine w=2"), || run_engine(&w, 2));
        let e4 = b.bench(&format!("n={n} ops={ops} engine w=4"), || run_engine(&w, 4));
        table.row(vec![
            n.to_string(),
            ops.to_string(),
            per_op.to_string(),
            Stats::fmt_time(seq.median_ns),
            Stats::fmt_time(e1.median_ns),
            Stats::fmt_time(e2.median_ns),
            Stats::fmt_time(e4.median_ns),
        ]);
    }
    println!("\n{}", table.render());

    // Skewed workload: one operator 8x the dimension of the rest, so a
    // static chunked fan-out parks three workers behind the straggler.
    // Bit-identity across both sweep modes is asserted before timing; the
    // profiled drains report the measured tail idleness each mode leaves.
    println!("== skewed workload: one operator 8x larger, 4 sweep workers ==");
    let w = build_sizes(&[300, 300, 300, 2400], 8, 0x5E1F);
    let want = run_sequential(&w);
    for mode in [SweepMode::Static, SweepMode::Stealing] {
        assert_eq!(want, run_engine_mode(&w, 4, mode), "skewed answers diverged ({mode:?})");
    }
    let st = b.bench("skew static w=4", || run_engine_mode(&w, 4, SweepMode::Static));
    let sw = b.bench("skew stealing w=4", || run_engine_mode(&w, 4, SweepMode::Stealing));
    let (idle_static, _) = profile_drain(&w, 4, SweepMode::Static);
    let (idle_steal, steals) = profile_drain(&w, 4, SweepMode::Stealing);
    let mut table = Table::new(&["sweep", "median", "worker_idle_frac", "steals"]);
    table.row(vec![
        "static".into(),
        Stats::fmt_time(st.median_ns),
        format!("{idle_static:.3}"),
        "0".into(),
    ]);
    table.row(vec![
        "stealing".into(),
        Stats::fmt_time(sw.median_ns),
        format!("{idle_steal:.3}"),
        steals.to_string(),
    ]);
    println!("\n{}", table.render());

    // Flight-recorder overhead (ISSUE 10): the same workload drained with
    // the query-lifecycle recorder armed vs dropped. Bit-identity is
    // asserted first — events hook only the scheduling phases — and the
    // CI gate holds the recorder-on median to within 5% of recorder-off
    // (validate_bench.py --overhead).
    println!("== flight recorder overhead: same workload, recorder on vs off ==");
    let w = build(400, 4, 8, 0xF119);
    let base = EngineConfig::default()
        .with_width(WIDTH)
        .with_lanes(WIDTH * w.ops.len())
        .with_workers(2);
    assert_eq!(
        run_engine_cfg(&w, base.with_flight(true)),
        run_engine_cfg(&w, base.with_flight(false)),
        "flight recorder changed an answer bit"
    );
    let on = b.bench("flight on w=2", || run_engine_cfg(&w, base.with_flight(true)));
    let off = b.bench("flight off w=2", || run_engine_cfg(&w, base.with_flight(false)));
    let ratio = on.median_ns / off.median_ns.max(1.0);
    let mut table = Table::new(&["recorder", "median", "vs off"]);
    table.row(vec!["on".into(), Stats::fmt_time(on.median_ns), format!("{ratio:.3}x")]);
    table.row(vec!["off".into(), Stats::fmt_time(off.median_ns), "1.000x".into()]);
    println!("\n{}", table.render());

    match b.write_json("engine") {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_engine.json not written: {e}"),
    }
}
