//! Figure 2 regeneration: (k-)DPP and double-greedy running time +
//! speedup vs matrix density on synthetic sparse matrices.
//!
//! Default runs at 1/4 of the paper's sizes so the bench suite fits the
//! session budget; `GAUSS_BIF_SCALE=1 cargo bench --bench bench_fig2`
//! reproduces the paper's 5000²/2000² sizes.  The *shape* — speedup
//! growing as density falls, all three algorithms ahead of their exact
//! baselines — is scale-invariant (see EXPERIMENTS.md).

use gauss_bif::config::RunConfig;
use gauss_bif::experiments::fig2::{self, Fig2Budget};
use gauss_bif::util::bench::{fmt_sci, write_stats_json, Stats, Table};

fn main() {
    let scale: usize = std::env::var("GAUSS_BIF_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let cfg = RunConfig { seed: 0xF162, dataset_scale: scale, ..Default::default() };
    let budget = Fig2Budget {
        baseline_steps: 4,
        gauss_steps: 150,
        dg_baseline_elems: 4,
    };
    println!(
        "Fig. 2 sweep at scale 1/{scale} (DPP/kDPP n={}, DG n={})",
        5000 / scale,
        2000 / scale
    );
    let rows = fig2::run(&cfg, budget, &fig2::DENSITIES);

    let mut table = Table::new(&[
        "algo", "density", "baseline s/step", "gauss s/step", "speedup", "avg judge iters",
    ]);
    for r in &rows {
        table.row(vec![
            r.algo.into(),
            format!("{:.0e}", r.density),
            fmt_sci(r.baseline_s),
            fmt_sci(r.gauss_s),
            format!("{:.1}x", r.speedup),
            format!("{:.1}", r.gauss_avg_judge_iters),
        ]);
    }
    println!("{}", table.render());

    // paper-shape checks (soft: printed, not asserted, so the bench never
    // aborts a suite run)
    for algo in ["dpp", "kdpp", "dg"] {
        let algo_rows: Vec<_> = rows.iter().filter(|r| r.algo == algo).collect();
        let all_win = algo_rows.iter().all(|r| r.speedup > 1.0);
        let sparse_vs_dense = algo_rows.first().map(|r| r.speedup).unwrap_or(0.0)
            / algo_rows.last().map(|r| r.speedup.max(1e-9)).unwrap_or(1.0);
        println!(
            "shape[{algo}]: quadrature wins at every density: {all_win}; speedup(sparsest)/speedup(densest) = {sparse_vs_dense:.1} (paper: > 1)"
        );
    }

    // one end-to-end timing per (algo, density) cell — single-sample
    // stats, but enough to chart the perf trajectory across commits
    let stats: Vec<Stats> = rows
        .iter()
        .map(|r| {
            Stats::single(&format!("fig2 {} d={:.0e} gauss s/step", r.algo, r.density), r.gauss_s * 1e9)
        })
        .collect();
    match write_stats_json("fig2", &stats) {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_fig2.json not written: {e}"),
    }
}
