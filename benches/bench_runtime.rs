//! PJRT runtime + coordinator benchmarks: artifact execute latency per
//! bucket, single vs batched dispatch, and judge-service throughput.
//! Requires `make artifacts` (skips gracefully without them).
//!
//! Run: `cargo bench --bench bench_runtime`

use gauss_bif::coordinator::{BatchPolicy, JudgeService, ThresholdRequest};
use gauss_bif::datasets::random_spd_exact;
use gauss_bif::runtime::GqlRuntime;
use gauss_bif::util::bench::{write_stats_json, Bencher, Stats, Table};
use gauss_bif::util::rng::Rng;
use std::path::Path;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/ missing — run `make artifacts` first; skipping bench_runtime");
        return;
    }
    let rt = GqlRuntime::load(dir).expect("load artifacts");
    println!("platform: {}\n", rt.platform());
    let mut b = Bencher::quick();

    // --- execute latency per bucket ---
    println!("== PJRT execute latency per bucket ==");
    let mut table = Table::new(&["bucket", "batch", "iters", "latency", "µs/lane-iter"]);
    let mut rng = Rng::new(0xBE1);
    for art in rt.artifacts() {
        let n = art.meta.n;
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.8, 0.3);
        let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
        let uf: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let lo = (l1 * 0.99) as f32;
        let hi = (ln * 1.01) as f32;
        let stats = if art.meta.batch == 1 {
            b.bench(&format!("exec {}", art.meta.name), || {
                art.execute(&af, &uf, lo, hi).unwrap()
            })
        } else {
            let bsz = art.meta.batch;
            let mut a_all = Vec::new();
            let mut u_all = Vec::new();
            for _ in 0..bsz {
                a_all.extend_from_slice(&af);
                u_all.extend_from_slice(&uf);
            }
            let lo_all = vec![lo; bsz];
            let hi_all = vec![hi; bsz];
            b.bench(&format!("exec {}", art.meta.name), || {
                art.execute_batch(&a_all, &u_all, &lo_all, &hi_all).unwrap()
            })
        };
        let lane_iters = (art.meta.batch * art.meta.iters) as f64;
        table.row(vec![
            art.meta.n.to_string(),
            art.meta.batch.to_string(),
            art.meta.iters.to_string(),
            Stats::fmt_time(stats.mean_ns),
            format!("{:.1}", stats.mean_ns / 1e3 / lane_iters),
        ]);
    }
    println!("\n{}", table.render());

    // --- service throughput across batch policies ---
    println!("== judge service throughput (200 mixed-size requests) ==");
    let mut table = Table::new(&["max_batch", "max_wait_µs", "req/s", "pjrt %"]);
    let mut extra: Vec<Stats> = Vec::new();
    for (max_batch, wait_us) in [(1usize, 0u64), (4, 100), (8, 200), (8, 1000)] {
        let policy = BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_micros(wait_us),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(Some(dir.to_path_buf()), policy, 2).expect("valid policy");
        let mut rng = Rng::new(0xBE2);
        let n_requests = 200;
        let t0 = std::time::Instant::now();
        let mut rxs = Vec::new();
        for i in 0..n_requests {
            let n = [12usize, 16, 24, 32][i % 4];
            let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.8, 0.3);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            rxs.push(svc.submit(ThresholdRequest {
                a: (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (l1 * 0.99) as f32,
                lam_max: (ln * 1.01) as f32,
                t: 1.0,
                op_key: None,
                reorth: false,
            }));
        }
        let mut pjrt = 0usize;
        for rx in rxs {
            if matches!(
                rx.recv().unwrap().path,
                gauss_bif::coordinator::RoutePath::Pjrt { .. }
            ) {
                pjrt += 1;
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        extra.push(Stats::single(
            &format!("service mb={max_batch} wait={wait_us}µs ns/req"),
            dt * 1e9 / n_requests as f64,
        ));
        table.row(vec![
            max_batch.to_string(),
            wait_us.to_string(),
            format!("{:.0}", n_requests as f64 / dt),
            format!("{:.0}", 100.0 * pjrt as f64 / n_requests as f64),
        ]);
        svc.shutdown();
    }
    println!("{}", table.render());

    let mut all = b.results().to_vec();
    all.extend(extra);
    match write_stats_json("runtime", &all) {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_runtime.json not written: {e}"),
    }
}
