//! Racing-vs-exhaustive greedy MAP: the same selection computed with
//! every candidate refined to tolerance (`RacePolicy::Exhaustive`) and
//! with interval-dominance pruning (`RacePolicy::Prune`), on a gapped
//! kernel where a few candidates clearly dominate each round.
//!
//! The headline number is **panel sweeps** (counted, deterministic), with
//! wall-clock alongside; selections are asserted identical — pruning only
//! discards dominated candidates.
//!
//! Run: `cargo bench --bench bench_race`

use gauss_bif::apps::dpp::{greedy_map_stats, GreedyConfig};
use gauss_bif::experiments::race::gapped_kernel;
use gauss_bif::quadrature::RacePolicy;
use gauss_bif::util::bench::{Bencher, Table};
use gauss_bif::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let n = 1200usize;
    let density = 5e-3;
    let mut rng = Rng::new(0x9ACE);
    println!("gapped kernel: n={n} density={density:.0e}, boosted diagonal block\n");

    let mut table = Table::new(&[
        "k", "width", "exhaustive sweeps", "prune sweeps", "saved", "exhaustive ms", "prune ms",
    ]);
    for &(k, width) in &[(4usize, 8usize), (8, 16), (16, 32)] {
        let (l, w) = gapped_kernel(&mut rng, n, density, 2 * k, 50.0);
        let l = std::sync::Arc::new(l);
        let base = GreedyConfig::new(w, k).with_block_width(width);
        let mut sweeps = [0usize; 2];
        let mut sel: [Vec<usize>; 2] = [Vec::new(), Vec::new()];
        let timings: Vec<f64> = [RacePolicy::Exhaustive, RacePolicy::Prune]
            .iter()
            .enumerate()
            .map(|(i, &policy)| {
                let stats = b.bench(&format!("k={k} w={width} {policy:?}"), || {
                    let (s, st) = greedy_map_stats(&l, &base.with_race(policy));
                    sweeps[i] = st.sweeps;
                    sel[i] = s;
                    st.sweeps
                });
                stats.mean_ns / 1e6
            })
            .collect();
        assert_eq!(sel[0], sel[1], "pruning changed the selection at k={k}");
        assert!(
            sweeps[1] <= sweeps[0],
            "pruning added sweeps at k={k} ({} vs {})",
            sweeps[1],
            sweeps[0]
        );
        let saved = sweeps[0].saturating_sub(sweeps[1]) as f64 / sweeps[0].max(1) as f64;
        table.row(vec![
            k.to_string(),
            width.to_string(),
            sweeps[0].to_string(),
            sweeps[1].to_string(),
            format!("{:.0}%", 100.0 * saved),
            format!("{:.1}", timings[0]),
            format!("{:.1}", timings[1]),
        ]);
    }
    println!("\n{}", table.render());

    match b.write_json("race") {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_race.json not written: {e}"),
    }
}
