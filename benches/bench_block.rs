//! Block-vs-scalar GQL: k independent scalar runs against one `BlockGql`
//! run over the same shared sparse operator, at k ∈ {4, 16, 64} (the
//! acceptance sweep) plus a panel-width sweep at fixed k.
//!
//! Run: `cargo bench --bench bench_block`

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::quadrature::{block_solve, run_scalar, GqlOptions, StopRule};
use gauss_bif::sparse::SymOp;
use gauss_bif::util::bench::{Bencher, Table};
use gauss_bif::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let n = 2000usize;
    let density = 1e-2;
    let iters = 16usize;
    let mut rng = Rng::new(0xB10C);
    let (a, w) = random_sparse_spd(&mut rng, n, density, 1e-2);
    let opts = GqlOptions::new(w.lo, w.hi);
    let stop = StopRule::Iters(iters);
    println!(
        "shared operator: n={n} nnz={} density={density:.0e}, {iters} iters/query\n",
        a.nnz()
    );

    println!("== k scalar GQL runs vs one BlockGql run (width = k) ==");
    let mut table = Table::new(&["k", "scalar ns/query-iter", "block ns/query-iter", "speedup"]);
    for &k in &[4usize, 16, 64] {
        let queries: Vec<Vec<f64>> = (0..k)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let scalar = b.bench(&format!("scalar k={k}"), || {
            queries
                .iter()
                .map(|u| run_scalar(&a, u, opts, stop, false).bounds.gauss)
                .sum::<f64>()
        });
        let block = b.bench(&format!("block  k={k}"), || {
            block_solve(&a, opts, k, queries.iter().map(|u| (u.as_slice(), stop)))
                .iter()
                .map(|r| r.bounds.gauss)
                .sum::<f64>()
        });
        let per = (k * iters) as f64;
        table.row(vec![
            k.to_string(),
            format!("{:.0}", scalar.mean_ns / per),
            format!("{:.0}", block.mean_ns / per),
            format!("{:.2}x", scalar.mean_ns / block.mean_ns),
        ]);
    }
    println!("\n{}", table.render());

    println!("== panel-width sweep at k = 64 ==");
    let k = 64usize;
    let queries: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();
    let scalar = b.bench("scalar k=64 (ref)", || {
        queries
            .iter()
            .map(|u| run_scalar(&a, u, opts, stop, false).bounds.gauss)
            .sum::<f64>()
    });
    let mut table = Table::new(&["width", "ns/query-iter", "speedup vs scalar"]);
    for &width in &[2usize, 4, 8, 16, 32, 64] {
        let block = b.bench(&format!("width={width}"), || {
            block_solve(&a, opts, width, queries.iter().map(|u| (u.as_slice(), stop)))
                .iter()
                .map(|r| r.bounds.gauss)
                .sum::<f64>()
        });
        table.row(vec![
            width.to_string(),
            format!("{:.0}", block.mean_ns / (k * iters) as f64),
            format!("{:.2}x", scalar.mean_ns / block.mean_ns),
        ]);
    }
    println!("\n{}", table.render());

    // Raw spmm kernel: the register-tiled 8-wide panel traversal against
    // the fixed-4 kernel it replaced, bit-identity asserted before any
    // timing. b = 64 pushes the interleaved panel past the cache-blocking
    // threshold, so that row also covers the column-windowed traversal.
    println!("== spmm kernel: register-tiled 8-wide panel vs fixed-4 reference ==");
    let mut table = Table::new(&["b", "ref4 ns/nnz-lane", "tiled ns/nnz-lane", "speedup"]);
    for &width in &[4usize, 8, 16, 64] {
        let x: Vec<f64> = (0..n * width).map(|_| rng.normal()).collect();
        let mut y = vec![0.0; n * width];
        let mut y4 = vec![0.0; n * width];
        a.matvec_multi(&x, &mut y, width);
        a.matvec_multi_ref4(&x, &mut y4, width);
        assert!(
            y.iter().zip(&y4).all(|(p, q)| p.to_bits() == q.to_bits()),
            "kernels diverged at b={width}"
        );
        let tiled = b.bench(&format!("spmm tiled b={width}"), || {
            a.matvec_multi(&x, &mut y, width);
            y[0]
        });
        let ref4 = b.bench(&format!("spmm ref4  b={width}"), || {
            a.matvec_multi_ref4(&x, &mut y4, width);
            y4[0]
        });
        let per = (a.nnz() * width) as f64;
        table.row(vec![
            width.to_string(),
            format!("{:.2}", ref4.mean_ns / per),
            format!("{:.2}", tiled.mean_ns / per),
            format!("{:.2}x", ref4.mean_ns / tiled.mean_ns),
        ]);
    }
    println!("\n{}", table.render());

    match b.write_json("block") {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_block.json not written: {e}"),
    }
}
