//! Ablations of the design choices DESIGN.md §6 calls out:
//!
//! 1. judge bound source: Gauss-Radau vs Gauss/Lobatto (Thm. 4/6 predict
//!    Radau decides in ≤ iterations),
//! 2. two-sided refinement: adaptive (§5.1) vs strict alternation,
//! 3. Jacobi preconditioning (§5.4) on a badly-scaled kernel,
//! 4. reorthogonalization cost (scalar, and batched through the block
//!    engine's per-lane bases — ISSUE 2),
//! 5. DPP baseline strength: exact-Cholesky vs maintained-inverse vs
//!    quadrature.
//!
//! Run: `cargo bench --bench bench_ablation`

use gauss_bif::apps::{BifStrategy, DppConfig, DppSampler};
use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::linalg::{sym_eigenvalues, Cholesky, DMat};
use gauss_bif::quadrature::{
    block_solve, judge_ratio_policy, judge_threshold_src, run_scalar, BoundSource, Gql,
    GqlOptions, JacobiPrecond, RefinePolicy, Reorth, StopRule,
};
use gauss_bif::util::bench::{write_stats_json, Bencher, Stats, Table};
use gauss_bif::util::rng::Rng;

fn main() {
    let mut b = Bencher::quick();

    // --- 1. bound source: Radau vs Gauss/Lobatto ---
    println!("== ablation 1: judge bound source (iterations to decide) ==");
    let mut rng = Rng::new(0xAB1);
    let n = 600;
    let (a, w) = random_sparse_spd(&mut rng, n, 5e-3, 1e-2);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = gauss_bif::quadrature::cg::cg_bif_estimate(&a, &u, 1e-12, 10 * n);
    let opts = GqlOptions::new(w.lo, w.hi);
    let mut table = Table::new(&["threshold/exact", "radau iters", "gauss/lobatto iters"]);
    let mut radau_total = 0usize;
    let mut gl_total = 0usize;
    for f in [0.5, 0.9, 0.99, 1.01, 1.1, 2.0] {
        let t = exact * f;
        let (_, jr) = judge_threshold_src(&a, &u, t, opts, BoundSource::Radau);
        let (_, jg) = judge_threshold_src(&a, &u, t, opts, BoundSource::GaussLobatto);
        radau_total += jr.iters;
        gl_total += jg.iters;
        table.row(vec![f.to_string(), jr.iters.to_string(), jg.iters.to_string()]);
    }
    println!("{}", table.render());
    println!("totals: radau {radau_total} vs gauss/lobatto {gl_total} (Thm. 4/6 ⇒ radau ≤)\n");

    // --- 2. refinement policy on ratio judgements ---
    println!("== ablation 2: adaptive (§5.1) vs alternate refinement ==");
    let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact_v = gauss_bif::quadrature::cg::cg_bif_estimate(&a, &v, 1e-12, 10 * n);
    let mut adaptive_total = 0usize;
    let mut alternate_total = 0usize;
    for p in [0.1, 0.3, 0.7, 0.9] {
        let truth = p * exact_v - exact;
        for off in [-0.3, -0.05, 0.05, 0.3] {
            let t = truth + off * exact.abs();
            let (da, ja) =
                judge_ratio_policy(&a, &u, &v, t, p, opts, RefinePolicy::Adaptive);
            let (dn, jn) =
                judge_ratio_policy(&a, &u, &v, t, p, opts, RefinePolicy::Alternate);
            assert_eq!(da, dn, "policies must agree on the decision");
            adaptive_total += ja.iters;
            alternate_total += jn.iters;
        }
    }
    println!(
        "total iterations over 16 judgements: adaptive {adaptive_total} vs alternate {alternate_total}\n"
    );

    // --- 3. Jacobi preconditioning on a badly-scaled kernel ---
    println!("== ablation 3: Jacobi preconditioning (badly scaled matrix) ==");
    let n2 = 120;
    let mut rng2 = Rng::new(0xAB3);
    let (mut d, _) = {
        let (a, w) = random_sparse_spd(&mut rng2, n2, 0.3, 1e-1);
        (a.to_dense(), w)
    };
    for i in 0..n2 {
        let s = 10f64.powi((i % 4) as i32);
        for j in 0..n2 {
            let v = d.get(i, j) * s.sqrt() * (10f64.powi((j % 4) as i32)).sqrt();
            d.set(i, j, v);
        }
    }
    let ev = sym_eigenvalues(&d);
    let u2: Vec<f64> = (0..n2).map(|_| rng2.normal()).collect();
    let exact2 = Cholesky::factor(&d).unwrap().bif(&u2);
    let plain_opts = GqlOptions::new(ev[0] * 0.99, ev[n2 - 1] * 1.01);
    let iters_plain = {
        let mut q = Gql::new(&d, &u2, plain_opts);
        q.run_to_gap(1e-3 * exact2.abs()).iter
    };
    let pc = JacobiPrecond::new(&d).unwrap();
    let su = pc.scaled_query(&u2);
    let mut m = DMat::zeros(n2, n2);
    for j in 0..n2 {
        let mut e = vec![0.0; n2];
        e[j] = 1.0;
        let mut col = vec![0.0; n2];
        gauss_bif::sparse::SymOp::matvec(&pc, &e, &mut col);
        for i in 0..n2 {
            m.set(i, j, col[i]);
        }
    }
    let ev_pc = sym_eigenvalues(&m);
    let pc_opts = GqlOptions::new(ev_pc[0] * 0.99, ev_pc[n2 - 1] * 1.01);
    let iters_pc = {
        let mut q = Gql::new(&pc, &su, pc_opts);
        q.run_to_gap(1e-3 * exact2.abs()).iter
    };
    println!(
        "iterations to 0.1% bracket: plain {iters_plain} (κ={:.1e}) vs jacobi {iters_pc} (κ={:.1e})\n",
        ev[n2 - 1] / ev[0],
        ev_pc[n2 - 1] / ev_pc[0]
    );

    // --- 4. reorthogonalization cost ---
    println!("== ablation 4: reorthogonalization cost (n=600, 48 iters) ==");
    let s_none = b.bench("gql_no_reorth", || {
        let mut q = Gql::new(&a, &u, opts);
        q.run(48).last().unwrap().gauss
    });
    let s_full = b.bench("gql_full_reorth", || {
        let mut q = Gql::new(&a, &u, opts.with_reorth(Reorth::Full));
        q.run(48).last().unwrap().gauss
    });
    println!(
        "overhead: {:.1}x\n",
        s_full.mean_ns / s_none.mean_ns
    );

    // --- 4b. block reorthogonalization: batched §5.4 lanes ---
    println!("== ablation 4b: block reorthogonalization (8 queries, n=600, 48 iters) ==");
    let k = 8usize;
    let queries: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();
    let reorth_opts = opts.with_reorth(Reorth::Full);
    let stop = StopRule::Iters(48);
    let s_scalar = b.bench("scalar_reorth_x8", || {
        queries
            .iter()
            .map(|u| run_scalar(&a, u, reorth_opts, stop, false).bounds.gauss)
            .sum::<f64>()
    });
    let s_block = b.bench("block_reorth_w8", || {
        block_solve(&a, reorth_opts, k, queries.iter().map(|u| (u.as_slice(), stop)))
            .iter()
            .map(|r| r.bounds.gauss)
            .sum::<f64>()
    });
    // the exactness contract extends to reorthogonalized lanes: the two
    // paths must agree bit-for-bit, not just to rounding
    let scalar_bits: Vec<u64> = queries
        .iter()
        .map(|u| run_scalar(&a, u, reorth_opts, stop, false).bounds.gauss.to_bits())
        .collect();
    let block_bits: Vec<u64> =
        block_solve(&a, reorth_opts, k, queries.iter().map(|u| (u.as_slice(), stop)))
            .iter()
            .map(|r| r.bounds.gauss.to_bits())
            .collect();
    assert_eq!(scalar_bits, block_bits, "block reorth deviated from scalar");
    println!(
        "batched speedup: {:.2}x (scalar {:.0} ns vs block {:.0} ns)\n",
        s_scalar.mean_ns / s_block.mean_ns,
        s_scalar.mean_ns,
        s_block.mean_ns
    );

    // --- 5. DPP baseline strength ---
    println!("== ablation 5: DPP step cost — exact vs incremental vs gauss ==");
    let mut rng3 = Rng::new(0xAB5);
    let (l, w3) = random_sparse_spd(&mut rng3, 700, 5e-3, 1e-2);
    let l = std::sync::Arc::new(l);
    let mut table = Table::new(&["strategy", "ms/step"]);
    let mut extra: Vec<Stats> = Vec::new();
    for (name, strategy, steps) in [
        ("exact (paper baseline)", BifStrategy::Exact, 4usize),
        ("incremental inverse", BifStrategy::Incremental, 40),
        ("gauss (ours)", BifStrategy::Gauss, 200),
    ] {
        let mut r = Rng::new(77);
        let mut s = DppSampler::new(
            &l,
            DppConfig::new(strategy, w3).with_init_size(700 / 3),
            &mut r,
        );
        let t0 = std::time::Instant::now();
        s.run(steps, &mut r);
        let per = t0.elapsed().as_secs_f64() / steps as f64;
        extra.push(Stats::single(&format!("dpp_step {name}"), per * 1e9));
        table.row(vec![name.into(), format!("{:.3}", per * 1e3)]);
    }
    println!("{}", table.render());

    let mut all = b.results().to_vec();
    all.extend(extra);
    match write_stats_json("ablation", &all) {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_ablation.json not written: {e}"),
    }
}
