//! GQL core microbenchmarks + Figure-1 regeneration timing.
//!
//! Rows reported:
//! * per-iteration cost of `Gql::step` across matrix size × density
//!   (sparse CSR — the paper's O(nnz) claim),
//! * judge iterations/latency as the threshold hardness varies,
//! * full Fig. 1 panel regeneration time,
//! * the dense-Cholesky exact-BIF cost for contrast.
//!
//! Run: `cargo bench --bench bench_quadrature`

use gauss_bif::config::RunConfig;
use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::experiments::fig1;
use gauss_bif::linalg::Cholesky;
use gauss_bif::quadrature::cg::cg_bif_estimate;
use gauss_bif::quadrature::{judge_threshold, Gql, GqlOptions};
use gauss_bif::util::bench::{Bencher, Table};
use gauss_bif::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    println!("== GQL per-iteration cost (one sparse matvec + O(1)) ==");
    let mut table = Table::new(&["n", "density", "nnz", "ns/iter"]);
    for &n in &[500usize, 2000, 8000] {
        for &density in &[1e-3, 1e-2] {
            let mut rng = Rng::new(0xB101);
            let (a, w) = random_sparse_spd(&mut rng, n, density, 1e-2);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let opts = GqlOptions::new(w.lo, w.hi);
            // measure k steps per sample to amortize setup
            let k = 16usize;
            let stats = b.bench(&format!("gql_step n={n} d={density:.0e}"), || {
                let mut q = Gql::new(&a, &u, opts);
                let mut acc = 0.0;
                for _ in 0..k {
                    acc += q.step().gauss;
                }
                acc
            });
            table.row(vec![
                n.to_string(),
                format!("{density:.0e}"),
                a.nnz().to_string(),
                format!("{:.0}", stats.mean_ns / k as f64),
            ]);
        }
    }
    println!("\n{}", table.render());

    println!("== judge latency vs threshold hardness (n=2000, d=1e-2) ==");
    let mut rng = Rng::new(0xB102);
    let n = 2000;
    let (a, w) = random_sparse_spd(&mut rng, n, 1e-2, 1e-2);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = cg_bif_estimate(&a, &u, 1e-12, 10 * n);
    let opts = GqlOptions::new(w.lo, w.hi);
    let mut table = Table::new(&["threshold/exact", "iters", "µs/judgement"]);
    for f in [0.2, 0.8, 0.95, 0.999] {
        let t = exact * f;
        let (_, js) = judge_threshold(&a, &u, t, opts);
        let stats = b.bench(&format!("judge f={f}"), || judge_threshold(&a, &u, t, opts));
        table.row(vec![
            format!("{f}"),
            js.iters.to_string(),
            format!("{:.1}", stats.mean_ns / 1e3),
        ]);
    }
    println!("\n{}", table.render());

    println!("== Fig. 1 regeneration (3 panels x 60 iterations, n=100) ==");
    let cfg = RunConfig::default();
    b.bench("fig1_all_panels", || fig1::run(&cfg, 60));

    println!("\n== exact-BIF baseline for contrast (dense Cholesky) ==");
    let mut table = Table::new(&["n", "ms/solve"]);
    for &n in &[200usize, 500, 1000] {
        let mut rng = Rng::new(0xB103);
        let (a, _) = random_sparse_spd(&mut rng, n, 0.05, 1e-2);
        let d = a.to_dense();
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let stats = b.bench(&format!("cholesky_bif n={n}"), || {
            Cholesky::factor(&d).unwrap().bif(&u)
        });
        table.row(vec![n.to_string(), format!("{:.2}", stats.mean_ns / 1e6)]);
    }
    println!("\n{}", table.render());

    match b.write_json("quadrature") {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_quadrature.json not written: {e}"),
    }
}
