//! Mixed-session panel sharing: the same heterogeneous query stream —
//! thresholds, comparisons, estimates, and an argmax race against one
//! shared operator — served sequentially (one planner session per query,
//! the pre-ISSUE-4 shape) vs compiled onto one shared `Session` panel.
//!
//! The headline number is **panel sweeps** (counted, deterministic), with
//! wall-clock alongside; answers are asserted identical — co-scheduling
//! must never change a decision.
//!
//! Run: `cargo bench --bench bench_session`

use gauss_bif::experiments::session::run_one;
use gauss_bif::util::bench::{Bencher, Table};
use gauss_bif::util::rng::Rng;

fn main() {
    let mut b = Bencher::new();
    let density = 5e-3;
    println!("gapped kernels, mixed query stream (4 thresholds + 2 compares + 2 estimates + k-arm argmax)\n");

    let mut table = Table::new(&[
        "n",
        "k",
        "queries",
        "lanes",
        "sequential sweeps",
        "session sweeps",
        "saved",
        "sequential ms",
        "session ms",
    ]);
    for &(n, k) in &[(400usize, 8usize), (800, 16), (1200, 24)] {
        b.bench(&format!("n={n} k={k} mixed"), || {
            let mut r = Rng::new(0x5E55 ^ n as u64);
            run_one(&mut r, n, density, k).session_sweeps
        });
        let mut rng = Rng::new(0x5E55 ^ n as u64);
        let rep = run_one(&mut rng, n, density, k);
        assert!(rep.identical, "mixed answers diverged at n={n}");
        assert!(
            rep.session_sweeps <= rep.sequential_sweeps,
            "co-scheduling added sweeps at n={n} ({} vs {})",
            rep.session_sweeps,
            rep.sequential_sweeps
        );
        table.row(vec![
            n.to_string(),
            k.to_string(),
            rep.queries.to_string(),
            rep.lanes.to_string(),
            rep.sequential_sweeps.to_string(),
            rep.session_sweeps.to_string(),
            format!("{:.0}%", 100.0 * rep.saved_frac),
            format!("{:.1}", rep.sequential_s * 1e3),
            format!("{:.1}", rep.session_s * 1e3),
        ]);
    }
    println!("\n{}", table.render());

    match b.write_json("session") {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_session.json not written: {e}"),
    }
}
