//! Table 2 regeneration: running time + speedup for (k-)DPP and double
//! greedy on the six Table-1 dataset substitutes.
//!
//! Defaults to scale 1/8 and a short chain so the whole bench suite runs
//! in minutes; the recorded full-scale numbers live in EXPERIMENTS.md.
//! Env overrides: GAUSS_BIF_SCALE, GAUSS_BIF_DATASETS, GAUSS_BIF_STEPS.
//!
//! Run: `cargo bench --bench bench_table2`

use gauss_bif::config::RunConfig;
use gauss_bif::experiments::table2::{self, Table2Budget};
use gauss_bif::util::bench::{fmt_sci, write_stats_json, Stats, Table};

fn main() {
    let scale: usize = std::env::var("GAUSS_BIF_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let n_datasets: usize = std::env::var("GAUSS_BIF_DATASETS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let gauss_steps: usize = std::env::var("GAUSS_BIF_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(100);

    let cfg = RunConfig { seed: 0x7AB2, dataset_scale: scale, ..Default::default() };
    let budget = Table2Budget {
        gauss_steps,
        baseline_steps: 3,
        baseline_timeout_s: 120.0,
        dg_limit: Some(4000 / scale.max(1)),
    };
    println!("Table 2 at scale 1/{scale}, first {n_datasets} datasets\n");
    let rows = table2::run(&cfg, budget, n_datasets);

    let mut table = Table::new(&[
        "dataset", "algo", "n", "nnz", "baseline s", "gauss s", "speedup",
    ]);
    for r in &rows {
        table.row(vec![
            r.dataset.into(),
            r.algo.into(),
            r.n.to_string(),
            r.nnz.to_string(),
            r.baseline_s.map_or("*".into(), fmt_sci),
            fmt_sci(r.gauss_s),
            r.speedup.map_or("*".into(), |s| format!("{s:.1}x")),
        ]);
    }
    println!("{}", table.render());
    println!("(DPP/kDPP rows: seconds per chain step; DG rows: full-run seconds; '*' = baseline infeasible, as in the paper)");

    let stats: Vec<Stats> = rows
        .iter()
        .map(|r| {
            Stats::single(&format!("table2 {}/{} gauss s", r.dataset, r.algo), r.gauss_s * 1e9)
        })
        .collect();
    match write_stats_json("table2", &stats) {
        Ok(p) => println!("perf trajectory: {}", p.display()),
        Err(e) => eprintln!("BENCH_table2.json not written: {e}"),
    }
}
