"""AOT pipeline: lowering must produce parseable HLO text + a manifest the
rust runtime can consume, and the lowered computation must be numerically
faithful (executed back via jax from the stablehlo module).
"""

import json
import os

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


class TestLowering:
    def test_hlo_text_shape_signature(self):
        text = aot.lower_bucket(16, 1, 4, use_pallas=False)
        assert "HloModule" in text
        assert "f32[16,16]" in text          # A parameter
        assert "f32[4]" in text              # per-rule output [iters]
        # entry signature: (a, u, lam_min, lam_max) -> 4-tuple of [iters]
        assert "(f32[16,16]{1,0}, f32[16]{0}, f32[], f32[])" in text

    def test_hlo_text_batched_signature(self):
        text = aot.lower_bucket(8, 4, 3, use_pallas=False)
        assert "f32[4,8,8]" in text
        assert "f32[4,3]" in text

    def test_pallas_bucket_lowers(self):
        # interpret-mode pallas must lower to plain HLO (no custom-call)
        text = aot.lower_bucket(8, 1, 3, use_pallas=True)
        assert "HloModule" in text
        assert "custom-call" not in text.lower() or "mosaic" not in text.lower()


class TestBuild:
    def test_build_writes_artifacts_and_manifest(self, tmp_path):
        out = str(tmp_path / "artifacts")
        manifest = aot.build(out, buckets=[(8, 1, 4, False), (8, 2, 4, False)])
        with open(os.path.join(out, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk == manifest
        assert on_disk["version"] == 1
        assert len(on_disk["entries"]) == 2
        for e in on_disk["entries"]:
            p = os.path.join(out, e["path"])
            assert os.path.exists(p)
            with open(p) as f:
                assert "HloModule" in f.read(200)
            assert set(e) >= {"name", "path", "n", "batch", "iters", "dtype"}
            assert e["dtype"] == "f32"

    def test_manifest_names_unique(self, tmp_path):
        manifest = aot.build(str(tmp_path), buckets=[(8, 1, 4, False),
                                                     (16, 1, 4, False)])
        names = [e["name"] for e in manifest["entries"]]
        assert len(names) == len(set(names))


class TestNumericalFaithfulness:
    def test_lowered_fn_equals_model(self):
        """jit(fn)(x) must equal the eager model — the artifact computes what
        the library claims it computes."""
        import jax

        n, iters = 12, 6
        a64, lmin, lmax = ref.random_spd(n, density=0.8, lam1=0.5, seed=2)
        a = a64.astype(np.float32)
        u = np.random.default_rng(3).standard_normal(n).astype(np.float32)
        lam_min = np.float32(lmin * 0.99)
        lam_max = np.float32(lmax * 1.01)

        def fn(a, u, lo, hi):
            return model.gql_bounds(a, u, lo, hi, iters, use_pallas=False)

        jitted = jax.jit(fn)(a, u, lam_min, lam_max)
        eager = fn(a, u, lam_min, lam_max)
        for j, e in zip(jitted, eager):
            np.testing.assert_allclose(np.asarray(j), np.asarray(e),
                                       rtol=1e-5, atol=1e-6)
        # and the truth is inside [g_rr, g_lr]
        exact = ref.bif_exact(a64, u)
        g, g_rr, g_lr, g_lo = (np.asarray(x) for x in jitted)
        assert g_rr[-1] <= exact * (1 + 1e-3)
        assert g_lr[-1] >= exact * (1 - 1e-3)
