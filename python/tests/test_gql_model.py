"""L2 model correctness: the scan-based jax GQL (with and without the Pallas
kernel on the hot path) against the float64 oracle, plus the identity-padding
shape bridge and batching semantics the rust coordinator relies on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def make_problem(n, seed, lam1=0.5, density=0.7):
    # float32 on the model path wants moderate conditioning
    a, lmin, lmax = ref.random_spd(n, density=density, lam1=lam1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    u = rng.standard_normal(n)
    return (a.astype(np.float32), u.astype(np.float32),
            np.float32(lmin * 0.99), np.float32(lmax * 1.01))


class TestModelVsOracle:
    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([8, 16, 32]), seed=SEEDS)
    def test_jnp_path_matches_f64_oracle(self, n, seed):
        a, u, lmin, lmax = make_problem(n, seed)
        iters = n // 2
        got = model.gql_bounds(a, u, lmin, lmax, iters, use_pallas=False)
        want = ref.gql_bounds_ref(np.asarray(a, np.float64), u, float(lmin),
                                  float(lmax), iters)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(np.asarray(gg), ww, rtol=5e-3, atol=1e-4)

    @settings(max_examples=8, deadline=None)
    @given(n=st.sampled_from([8, 16, 32]), seed=SEEDS)
    def test_pallas_path_matches_jnp_path(self, n, seed):
        a, u, lmin, lmax = make_problem(n, seed)
        iters = n // 2
        got = model.gql_bounds(a, u, lmin, lmax, iters, use_pallas=True)
        want = model.gql_bounds(a, u, lmin, lmax, iters, use_pallas=False)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                       rtol=1e-3, atol=1e-4)

    def test_bounds_sandwich_truth_f32(self):
        a, u, lmin, lmax = make_problem(24, 3)
        exact = ref.bif_exact(a, u)
        g, g_rr, g_lr, g_lo = model.gql_bounds(a, u, lmin, lmax, 12,
                                               use_pallas=True)
        tol = 1e-3 * abs(exact)
        assert np.all(np.asarray(g) <= exact + tol)
        assert np.all(np.asarray(g_rr) <= exact + tol)
        assert np.all(np.asarray(g_lr) >= exact - tol)
        assert np.all(np.asarray(g_lo) >= exact - tol)

    def test_breakdown_freezes_at_exact(self):
        """iters > n: after Krylov exhaustion all rules equal the exact BIF
        and contain no NaN/inf."""
        n = 6
        a, u, lmin, lmax = make_problem(n, 9, density=1.0)
        exact = ref.bif_exact(a, u)
        outs = model.gql_bounds(a, u, lmin, lmax, n + 5, use_pallas=False)
        for o in outs:
            o = np.asarray(o)
            assert np.all(np.isfinite(o))
            assert abs(o[-1] - exact) / abs(exact) < 5e-3

    def test_single_iteration_shape(self):
        a, u, lmin, lmax = make_problem(8, 1)
        outs = model.gql_bounds(a, u, lmin, lmax, 1, use_pallas=False)
        for o in outs:
            assert o.shape == (1,)


class TestPaddingBridge:
    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([5, 8, 13]), n_pad=st.sampled_from([16, 32]),
           seed=SEEDS)
    def test_identity_padding_is_exact_invariance(self, n, n_pad, seed):
        """blkdiag(A, I) + zero-padded u leaves every GQL iterate unchanged —
        this is what lets the coordinator bucket dense queries."""
        a, u, lmin, lmax = make_problem(n, seed)
        a_p, u_p = model.pad_query(jnp.asarray(a), jnp.asarray(u), n_pad)
        assert a_p.shape == (n_pad, n_pad) and u_p.shape == (n_pad,)
        iters = max(2, n // 2)
        got = model.gql_bounds(np.asarray(a_p), np.asarray(u_p), lmin, lmax,
                               iters, use_pallas=False)
        want = model.gql_bounds(a, u, lmin, lmax, iters, use_pallas=False)
        for gg, ww in zip(got, want):
            np.testing.assert_allclose(np.asarray(gg), np.asarray(ww),
                                       rtol=1e-5, atol=1e-6)

    def test_pad_noop_when_equal(self):
        a, u, *_ = make_problem(16, 0)
        a_p, u_p = model.pad_query(jnp.asarray(a), jnp.asarray(u), 16)
        np.testing.assert_array_equal(np.asarray(a_p), a)


class TestBatched:
    def test_batched_matches_loop(self):
        b, n, iters = 4, 16, 8
        As, Us, lmins, lmaxs = [], [], [], []
        for s in range(b):
            a, u, lmin, lmax = make_problem(n, s)
            As.append(a); Us.append(u); lmins.append(lmin); lmaxs.append(lmax)
        A = np.stack(As); U = np.stack(Us)
        LMIN = np.array(lmins, np.float32); LMAX = np.array(lmaxs, np.float32)
        got = model.gql_bounds_batched(A, U, LMIN, LMAX, iters)
        for i in range(b):
            want = model.gql_bounds(As[i], Us[i], lmins[i], lmaxs[i], iters,
                                    use_pallas=False)
            for gg, ww in zip(got, want):
                np.testing.assert_allclose(np.asarray(gg)[i], np.asarray(ww),
                                           rtol=1e-5, atol=1e-6)

    def test_batched_shapes(self):
        b, n, iters = 2, 8, 5
        a = np.stack([np.eye(n, dtype=np.float32) * 2] * b)
        u = np.ones((b, n), np.float32)
        lm = np.full((b,), 1.0, np.float32)
        lx = np.full((b,), 3.0, np.float32)
        outs = model.gql_bounds_batched(a, u, lm, lx, iters)
        for o in outs:
            assert o.shape == (b, iters)
        # A = 2I ⇒ u'A⁻¹u = n/2 exactly at iteration 1
        np.testing.assert_allclose(np.asarray(outs[0])[:, 0], n / 2, rtol=1e-6)
