"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes; every case asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec as K
from compile.kernels import ref

SIZES = st.sampled_from([1, 2, 3, 8, 16, 33, 64, 128])
DTYPES = st.sampled_from([np.float32])
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(rng, shape, dtype):
    return rng.standard_normal(shape).astype(dtype)


class TestMatvecTiled:
    @settings(max_examples=40, deadline=None)
    @given(n=SIZES, dtype=DTYPES, seed=SEEDS)
    def test_matches_ref(self, n, dtype, seed):
        rng = np.random.default_rng(seed)
        a = _rand(rng, (n, n), dtype)
        u = _rand(rng, (n,), dtype)
        got = K.matvec_tiled(a, u)
        want = ref.matvec_ref(jnp.asarray(a), jnp.asarray(u))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([128, 256]), block=st.sampled_from([32, 64, 128]),
           seed=SEEDS)
    def test_block_rows_invariance(self, n, block, seed):
        """Tiling must not change the numbers (schedule-only knob)."""
        rng = np.random.default_rng(seed)
        a = _rand(rng, (n, n), np.float32)
        u = _rand(rng, (n,), np.float32)
        got = K.matvec_tiled(a, u, block_rows=block)
        want = K.matvec_tiled(a, u, block_rows=n)
        # different panel shapes pick different XLA dot blockings ⇒ f32
        # summation-order noise, nothing more
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_ragged_size_falls_back_to_single_panel(self):
        rng = np.random.default_rng(0)
        a = _rand(rng, (37, 37), np.float32)
        u = _rand(rng, (37,), np.float32)
        got = K.matvec_tiled(a, u, block_rows=16)
        np.testing.assert_allclose(got, a @ u, rtol=1e-5, atol=1e-5)


class TestMatvecBatched:
    @settings(max_examples=25, deadline=None)
    @given(b=st.sampled_from([1, 2, 5, 8]), n=st.sampled_from([8, 16, 64]),
           seed=SEEDS)
    def test_matches_ref(self, b, n, seed):
        rng = np.random.default_rng(seed)
        a = _rand(rng, (b, n, n), np.float32)
        u = _rand(rng, (b, n), np.float32)
        got = K.matvec_tiled_batched(a, u)
        want = ref.matvec_ref(jnp.asarray(a), jnp.asarray(u))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_batch_independence(self):
        """Each lane must see only its own (A, u)."""
        rng = np.random.default_rng(7)
        a = _rand(rng, (3, 16, 16), np.float32)
        u = _rand(rng, (3, 16), np.float32)
        full = np.asarray(K.matvec_tiled_batched(a, u))
        for i in range(3):
            solo = np.asarray(K.matvec_tiled(a[i], u[i]))
            np.testing.assert_allclose(full[i], solo, rtol=1e-6, atol=1e-6)


class TestLanczosStepFused:
    @settings(max_examples=40, deadline=None)
    @given(n=SIZES, seed=SEEDS, with_prev=st.booleans())
    def test_matches_ref(self, n, seed, with_prev):
        rng = np.random.default_rng(seed)
        a = _rand(rng, (n, n), np.float32)
        a = (a + a.T) / 2
        v_curr = _rand(rng, (n,), np.float32)
        v_curr /= np.linalg.norm(v_curr)
        if with_prev:
            v_prev = _rand(rng, (n,), np.float32)
            v_prev /= np.linalg.norm(v_prev)
            beta_prev = np.float32(abs(rng.standard_normal()))
        else:
            v_prev = np.zeros((n,), np.float32)
            beta_prev = np.float32(0.0)
        alpha, beta, v_next = K.lanczos_step_fused(a, v_prev, v_curr, beta_prev)
        alpha_r, beta_r, v_next_r = ref.lanczos_step_ref(
            jnp.asarray(a), jnp.asarray(v_prev), jnp.asarray(v_curr),
            jnp.asarray(beta_prev))
        np.testing.assert_allclose(alpha, alpha_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(beta, beta_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(v_next, v_next_r, rtol=1e-3, atol=1e-4)

    def test_breakdown_returns_zero_vector(self):
        """A = I: w = v - 1*v = 0 ⇒ beta = 0 and v_next = 0, no NaNs."""
        n = 8
        a = np.eye(n, dtype=np.float32)
        v = np.zeros((n,), np.float32)
        v[0] = 1.0
        alpha, beta, v_next = K.lanczos_step_fused(
            a, np.zeros_like(v), v, np.float32(0.0))
        assert np.isclose(float(alpha), 1.0)
        assert np.isclose(float(beta), 0.0)
        assert np.all(np.isfinite(np.asarray(v_next)))
        np.testing.assert_allclose(v_next, 0.0)

    def test_orthogonality_one_step(self):
        """v_next ⟂ v_curr after an exact step."""
        rng = np.random.default_rng(3)
        n = 32
        a = _rand(rng, (n, n), np.float32)
        a = (a + a.T) / 2
        v = _rand(rng, (n,), np.float32)
        v /= np.linalg.norm(v)
        _, beta, v_next = K.lanczos_step_fused(a, np.zeros_like(v), v,
                                               np.float32(0.0))
        assert abs(float(np.asarray(v_next) @ v)) < 1e-4
        assert abs(float(np.linalg.norm(np.asarray(v_next))) - 1.0) < 1e-4


class TestVmemBudget:
    @pytest.mark.parametrize("n", [16, 32, 64, 128, 256, 512])
    def test_buckets_fit_vmem(self, n):
        assert K.vmem_bytes(n) <= 16 * 2**20

    def test_tiling_kicks_in_beyond_vmem(self):
        # At n=8192 a whole-A panel would blow VMEM; tiling caps the panel.
        whole = K.vmem_bytes(8192, block_rows=8192)
        tiled = K.vmem_bytes(8192, block_rows=128)
        assert whole > 16 * 2**20 > tiled
