"""Validate the oracle itself: the Sherman–Morrison GQL recurrences in
ref.gql_bounds_ref against (a) direct modified-Jacobi-matrix evaluation and
(b) the exact BIF, plus the paper's theorems as executable properties.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref

SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def make_problem(n, density, lam1, seed):
    a, lmin, lmax = ref.random_spd(n, density=density, lam1=lam1, seed=seed)
    rng = np.random.default_rng(seed + 1)
    u = rng.standard_normal(n)
    return a, u, lmin, lmax


class TestRecurrencesVsDirect:
    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([4, 8, 16, 32]), seed=SEEDS)
    def test_sherman_morrison_matches_direct_solve(self, n, seed):
        a, u, lmin, lmax = make_problem(n, 0.5, 1e-1, seed)
        lam_min, lam_max = lmin * 0.999, lmax * 1.001
        iters = n - 1  # strictly before exhaustion: recurrences well-defined
        got = ref.gql_bounds_ref(a, u, lam_min, lam_max, iters)
        want = ref.gql_bounds_eig_ref(a, u, lam_min, lam_max, iters)
        for g1, g2 in zip(got, want):
            np.testing.assert_allclose(g1, g2, rtol=1e-6, atol=1e-8)

    def test_exact_at_n_iterations(self):
        a, u, lmin, lmax = make_problem(24, 0.6, 1e-1, 5)
        exact = ref.bif_exact(a, u)
        g, g_rr, g_lr, g_lo = ref.gql_bounds_ref(
            a, u, lmin * 0.999, lmax * 1.001, 24)
        assert abs(g[-1] - exact) / exact < 1e-8
        assert abs(g_rr[-1] - exact) / exact < 1e-6
        assert abs(g_lr[-1] - exact) / exact < 1e-6


class TestPaperTheorems:
    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([16, 32, 64]), seed=SEEDS,
           density=st.sampled_from([0.2, 0.5, 1.0]))
    def test_bounds_sandwich_truth(self, n, seed, density):
        """Thm. 2: g, g_rr ≤ u'A⁻¹u ≤ g_lr, g_lo at every iteration."""
        a, u, lmin, lmax = make_problem(n, density, 1e-1, seed)
        exact = ref.bif_exact(a, u)
        g, g_rr, g_lr, g_lo = ref.gql_bounds_ref(
            a, u, lmin * 0.99, lmax * 1.01, n - 1)
        tol = 1e-7 * abs(exact)
        assert np.all(g <= exact + tol)
        assert np.all(g_rr <= exact + tol)
        assert np.all(g_lr >= exact - tol)
        assert np.all(g_lo >= exact - tol)

    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([16, 32, 64]), seed=SEEDS)
    def test_monotonicity_corr7(self, n, seed):
        a, u, lmin, lmax = make_problem(n, 0.4, 1e-1, seed)
        g, g_rr, g_lr, g_lo = ref.gql_bounds_ref(
            a, u, lmin * 0.99, lmax * 1.01, n - 1)
        tol = 1e-9 * max(1.0, abs(g[-1]))
        assert np.all(np.diff(g) >= -tol)
        assert np.all(np.diff(g_rr) >= -tol)
        assert np.all(np.diff(g_lr) <= tol)
        assert np.all(np.diff(g_lo) <= tol)

    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([16, 32]), seed=SEEDS)
    def test_ordering_thm4_thm6(self, n, seed):
        """g_i ≤ g_i^rr ≤ g_{i+1} and g_{i+1}^lo ≤ g_i^lr ≤ g_i^lo."""
        a, u, lmin, lmax = make_problem(n, 0.5, 1e-1, seed)
        g, g_rr, g_lr, g_lo = ref.gql_bounds_ref(
            a, u, lmin * 0.99, lmax * 1.01, n - 1)
        tol = 1e-8 * max(1.0, abs(g[-1]))
        assert np.all(g <= g_rr + tol)
        assert np.all(g_rr[:-1] <= g[1:] + tol)
        assert np.all(g_lr <= g_lo + tol)
        assert np.all(g_lo[1:] <= g_lr[:-1] + tol)

    def test_linear_rate_thm3(self):
        """Relative error of Gauss ≤ 2((√κ−1)/(√κ+1))^i."""
        a, u, lmin, lmax = make_problem(48, 1.0, 1.0, 11)
        exact = ref.bif_exact(a, u)
        kappa = lmax / lmin
        rho = (np.sqrt(kappa) - 1) / (np.sqrt(kappa) + 1)
        g, g_rr, _, _ = ref.gql_bounds_ref(a, u, lmin * 0.999, lmax * 1.001, 40)
        for i, gi in enumerate(g, start=1):
            assert (exact - gi) / exact <= 2 * rho**i + 1e-9
        # Thm. 5: same rate for right Gauss-Radau
        for i, gi in enumerate(g_rr, start=1):
            assert (exact - gi) / exact <= 2 * rho**i + 1e-9

    def test_radau_tighter_than_gauss_and_lobatto(self):
        """Thm 4/6: at equal i, Radau dominates Gauss (lower) / Lobatto
        (upper)."""
        a, u, lmin, lmax = make_problem(32, 0.5, 1e-1, 13)
        g, g_rr, g_lr, g_lo = ref.gql_bounds_ref(
            a, u, lmin * 0.99, lmax * 1.01, 31)
        assert np.all(g_rr >= g - 1e-12)
        assert np.all(g_lr <= g_lo + 1e-12)


class TestLobattoCoeffs:
    @settings(max_examples=25, deadline=None)
    @given(seed=SEEDS, n=st.sampled_from([2, 3, 5, 8]))
    def test_extended_matrix_has_prescribed_eigenvalues(self, n, seed):
        """The (a_lo, b_lo²) solution must place lam_min and lam_max in the
        spectrum of the extended Jacobi matrix."""
        a, u, lmin, lmax = make_problem(n + 4, 1.0, 1e-1, seed)
        lam_min, lam_max = lmin * 0.9, lmax * 1.1
        # run n Lanczos steps to get J_n, then extend
        unorm = np.linalg.norm(u)
        v = u / unorm
        V = [v]
        alphas, betas = [], []
        v_prev, beta_prev = np.zeros_like(v), 0.0
        for _ in range(n):
            w = a @ v - beta_prev * v_prev
            al = float(v @ w)
            w = w - al * v
            for q in V:
                w -= (q @ w) * q
            be = float(np.linalg.norm(w))
            alphas.append(al)
            betas.append(be)
            v_prev, v, beta_prev = v, w / be, be
            V.append(v)
        d_lr, d_rr = alphas[0] - lam_min, alphas[0] - lam_max
        for j in range(1, n):
            d_lr = alphas[j] - lam_min - betas[j - 1] ** 2 / d_lr
            d_rr = alphas[j] - lam_max - betas[j - 1] ** 2 / d_rr
        a_lo, b_lo2 = ref.lobatto_coeffs(d_lr, d_rr, lam_min, lam_max)
        assert b_lo2 > 0
        J = np.diag(alphas) + np.diag(betas[:-1], 1) + np.diag(betas[:-1], -1)
        Je = np.zeros((n + 1, n + 1))
        Je[:n, :n] = J
        Je[n, n] = a_lo
        Je[n - 1, n] = Je[n, n - 1] = np.sqrt(b_lo2)
        ev = np.linalg.eigvalsh(Je)
        assert min(abs(ev - lam_min)) < 1e-6 * max(1, abs(lam_min))
        assert min(abs(ev - lam_max)) < 1e-6 * abs(lam_max)


class TestGenerator:
    def test_random_spd_spectrum(self):
        a, lmin, lmax = ref.random_spd(64, density=0.1, lam1=1e-2, seed=0)
        ev = np.linalg.eigvalsh(a)
        assert abs(ev[0] - 1e-2) < 1e-8
        assert abs(ev[0] - lmin) < 1e-10
        assert abs(ev[-1] - lmax) < 1e-8
        np.testing.assert_allclose(a, a.T)
