"""L1 Pallas kernels: VMEM-tiled symmetric matvec and the fused Lanczos step.

These are the per-iteration hot-spot of GQL.  The TPU mapping (see
DESIGN.md §Hardware-Adaptation):

* ``matvec_tiled`` — A is tiled into ``(TM, N)`` row panels; each grid step
  holds one panel plus the full ``u`` in VMEM and emits a ``(TM,)`` slice of
  ``y``.  ``dot(panel, u)`` maps to an (TM x N)·(N x 1) MXU op.  The
  BlockSpec index maps express the HBM↔VMEM schedule the paper's CPU code
  left to the BLAS.
* ``lanczos_step_fused`` — for bucket sizes where whole-A fits in VMEM
  (all serving buckets: N ≤ 512 → ≤ 1 MiB f32), the matvec and both BLAS-1
  reductions (alpha, beta) plus the vector update are fused into a single
  pass: one HBM read of A per Lanczos iteration instead of three vector
  sweeps.

All kernels are lowered with ``interpret=True``: the image's CPU PJRT cannot
run Mosaic custom-calls, so interpret mode is both the validation path and
the artifact path; real-TPU perf is estimated structurally in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT requirement; see module docstring.


def _matvec_kernel(a_ref, u_ref, o_ref):
    # One (TM, N) row panel of A against the full u vector.
    o_ref[...] = a_ref[...] @ u_ref[...]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def matvec_tiled(a, u, *, block_rows=128):
    """y = A @ u with A:[n,n] tiled into (block_rows, n) VMEM panels."""
    n = a.shape[0]
    tm = min(block_rows, n)
    if n % tm != 0:
        # fall back to a single whole-matrix panel for ragged sizes
        tm = n
    grid = (n // tm,)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), a.dtype),
        interpret=INTERPRET,
    )(a, u)


def _matvec_batched_kernel(a_ref, u_ref, o_ref):
    # a_ref: (1, TM, N); u_ref: (1, N); o_ref: (1, TM)
    o_ref[...] = (a_ref[0] @ u_ref[0])[None]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def matvec_tiled_batched(a, u, *, block_rows=128):
    """y[b] = A[b] @ u[b] with grid (B, n/TM): the batcher's bucket maps to
    the leading grid axis so one dispatch serves a whole bucket."""
    b, n, _ = a.shape
    tm = min(block_rows, n)
    if n % tm != 0:
        tm = n
    grid = (b, n // tm)
    return pl.pallas_call(
        _matvec_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tm, n), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, n), lambda bi, i: (bi, 0)),
        ],
        out_specs=pl.BlockSpec((1, tm), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), a.dtype),
        interpret=INTERPRET,
    )(a, u)


def _lanczos_step_kernel(a_ref, vp_ref, vc_ref, bp_ref, alpha_ref, beta_ref, vn_ref):
    """Fused Lanczos step; see lanczos_step_ref in ref.py for the math."""
    vc = vc_ref[...]
    av = a_ref[...] @ vc
    alpha = jnp.sum(av * vc)
    w = av - alpha * vc - bp_ref[0] * vp_ref[...]
    beta = jnp.sqrt(jnp.sum(w * w))
    alpha_ref[0] = alpha
    beta_ref[0] = beta
    safe = jnp.where(beta > 0, beta, jnp.ones_like(beta))
    vn_ref[...] = jnp.where(beta > 0, w / safe, jnp.zeros_like(w))


@jax.jit
def lanczos_step_fused(a, v_prev, v_curr, beta_prev):
    """(alpha, beta, v_next) in one fused pass; whole-A-in-VMEM variant.

    ``beta_prev`` is a scalar or shape-(1,) array.
    """
    n = a.shape[0]
    bp = jnp.asarray(beta_prev, dtype=a.dtype).reshape((1,))
    alpha, beta, v_next = pl.pallas_call(
        _lanczos_step_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((1,), a.dtype),
            jax.ShapeDtypeStruct((1,), a.dtype),
            jax.ShapeDtypeStruct((n,), a.dtype),
        ),
        interpret=INTERPRET,
    )(a, v_prev, v_curr, bp)
    return alpha[0], beta[0], v_next


def vmem_bytes(n, block_rows=128, dtype_bytes=4, batched=1):
    """Structural VMEM footprint of one grid step of the tiled matvec:
    one (TM, N) panel + u + y-slice.  Used by DESIGN.md's roofline estimate
    and asserted < 16 MiB in tests for every serving bucket."""
    tm = min(block_rows, n)
    return batched * (tm * n + n + tm) * dtype_bytes
