"""Pure-jnp / numpy oracles for the L1 Pallas kernels and the L2 GQL model.

Everything in this file is the *correctness reference*: no Pallas, no
cleverness — just the textbook math.  pytest compares the Pallas kernels and
the scan-based GQL model against these; the rust native implementation is
cross-checked (via golden files) against this oracle too.

Notation follows Alg. 5 of the paper (Gauss Quadrature Lanczos, GQL):
``g`` = Gauss, ``g_rr`` = right Gauss-Radau, ``g_lr`` = left Gauss-Radau,
``g_lo`` = Gauss-Lobatto.  ``g``/``g_rr`` lower-bound u^T A^{-1} u while
``g_lr``/``g_lo`` upper-bound it (Thm. 2).

Two deliberate deviations from the paper's typeset pseudocode, both verified
against direct eigen-decomposition quadrature in tests:

* The ``||u||`` prefactor in the g-updates is ``||u||^2`` (the integral mass
  is sum(u_tilde^2) = ||u||^2; cf. Golub & Meurant 2009, ch. 7).
* The Gauss-Lobatto coefficients in the paper's Alg. 5 are OCR-mangled; we
  use the characteristic-polynomial solution of the 2x2 system
      a_lo - b_lo^2 / d_lr = lam_min,     a_lo - b_lo^2 / d_rr = lam_max
  i.e.  b_lo^2 = (lam_max - lam_min) * d_lr * d_rr / (d_rr - d_lr)  and
        a_lo   = (lam_max * d_rr - lam_min * d_lr) / (d_rr - d_lr),
  which reproduces trace/det exactly for the n=1 case and yields the
  prescribed extremal eigenvalues in tests.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def matvec_ref(a, u):
    """y = A @ u for A:[n,n], u:[n] (or batched [b,n,n] x [b,n])."""
    if a.ndim == 3:
        return jnp.einsum("bij,bj->bi", a, u)
    return a @ u


def lanczos_step_ref(a, v_prev, v_curr, beta_prev):
    """One Lanczos step (no reorthogonalization).

    Given symmetric ``a``, the two most recent orthonormal Lanczos vectors and
    the previous off-diagonal ``beta_prev``, returns ``(alpha, beta, v_next)``
    per the three-term recurrence (paper Alg. 5: alpha_i = v^T A v):

        av     = A v_curr
        alpha  = <v_curr, av>
        w      = av - alpha * v_curr - beta_prev * v_prev
        beta   = ||w||
        v_next = w / beta      (zero vector if beta == 0)
    """
    beta_prev = jnp.asarray(beta_prev)
    av = matvec_ref(a, v_curr)
    alpha = jnp.sum(av * v_curr, axis=-1)
    w = av - alpha[..., None] * v_curr - beta_prev[..., None] * v_prev
    beta = jnp.sqrt(jnp.sum(w * w, axis=-1))
    safe = jnp.where(beta > 0, beta, 1.0)
    v_next = jnp.where(beta[..., None] > 0, w / safe[..., None], jnp.zeros_like(w))
    return alpha, beta, v_next


def lobatto_coeffs(d_lr, d_rr, lam_min, lam_max):
    """(a_lo, b_lo^2) such that the extended Jacobi matrix has eigenvalues
    lam_min and lam_max (see module docstring)."""
    denom = d_rr - d_lr
    b_lo2 = (lam_max - lam_min) * d_lr * d_rr / denom
    a_lo = (lam_max * d_rr - lam_min * d_lr) / denom
    return a_lo, b_lo2


def gql_bounds_ref(a, u, lam_min, lam_max, iters):
    """Reference GQL (Alg. 5) in scalar float64 python.

    Returns four np.float64 arrays of shape [iters]: per-iteration Gauss,
    right Gauss-Radau, left Gauss-Radau and Gauss-Lobatto estimates of
    u^T A^{-1} u.  Once the Krylov space is exhausted (beta == 0) all four
    sequences are held at the (now exact) Gauss value.
    """
    a = np.asarray(a, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    unorm2 = float(u @ u)
    u0 = u / np.sqrt(unorm2)

    g_h, grr_h, glr_h, glo_h = [], [], [], []

    # --- iteration 1 ---
    av = a @ u0
    alpha = float(u0 @ av)
    w = av - alpha * u0
    beta = float(np.linalg.norm(w))
    g = unorm2 / alpha
    c = 1.0
    delta = alpha
    d_lr = alpha - lam_min
    d_rr = alpha - lam_max

    def radau_lobatto(g, beta, c, delta, d_lr, d_rr):
        a_lr = lam_min + beta**2 / d_lr
        a_rr = lam_max + beta**2 / d_rr
        a_lo, b_lo2 = lobatto_coeffs(d_lr, d_rr, lam_min, lam_max)
        g_rr = g + unorm2 * beta**2 * c**2 / (delta * (a_rr * delta - beta**2))
        g_lr = g + unorm2 * beta**2 * c**2 / (delta * (a_lr * delta - beta**2))
        g_lo = g + unorm2 * b_lo2 * c**2 / (delta * (a_lo * delta - b_lo2))
        return g_rr, g_lr, g_lo

    g_rr, g_lr, g_lo = radau_lobatto(g, beta, c, delta, d_lr, d_rr)
    g_h.append(g); grr_h.append(g_rr); glr_h.append(g_lr); glo_h.append(g_lo)

    v_prev = u0
    v_curr = w / beta if beta > 0 else np.zeros_like(w)
    beta_prev = beta
    for _ in range(1, iters):
        if beta_prev <= 1e-300:  # Krylov space exhausted: g is exact
            g_h.append(g); grr_h.append(g); glr_h.append(g); glo_h.append(g)
            continue
        av = a @ v_curr
        alpha = float(v_curr @ av)
        w = av - alpha * v_curr - beta_prev * v_prev
        beta = float(np.linalg.norm(w))
        # Sherman–Morrison update of unorm2 * [J_i^{-1}]_{1,1}
        g = g + unorm2 * beta_prev**2 * c**2 / (delta * (alpha * delta - beta_prev**2))
        c = c * beta_prev / delta
        delta_new = alpha - beta_prev**2 / delta
        d_lr = alpha - lam_min - beta_prev**2 / d_lr
        d_rr = alpha - lam_max - beta_prev**2 / d_rr
        delta = delta_new
        g_rr, g_lr, g_lo = radau_lobatto(g, beta, c, delta, d_lr, d_rr)
        g_h.append(g); grr_h.append(g_rr); glr_h.append(g_lr); glo_h.append(g_lo)
        v_prev = v_curr
        v_curr = w / beta if beta > 0 else np.zeros_like(w)
        beta_prev = beta

    return (np.array(g_h), np.array(grr_h), np.array(glr_h), np.array(glo_h))


def gql_bounds_eig_ref(a, u, lam_min, lam_max, iters):
    """Slow oracle-of-the-oracle: build J_i by explicit Lanczos with full
    reorthogonalization, form the modified Jacobi matrices *as matrices*,
    and evaluate unorm2 * e1^T J'^{-1} e1 directly.  Used to validate the
    Sherman–Morrison recurrences of :func:`gql_bounds_ref`."""
    a = np.asarray(a, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    n = u.shape[0]
    unorm2 = float(u @ u)
    iters = min(iters, n)

    V = np.zeros((n, iters))
    alphas, betas = [], []
    v = u / np.sqrt(unorm2)
    V[:, 0] = v
    beta_prev, v_prev = 0.0, np.zeros_like(v)
    g_h, grr_h, glr_h, glo_h = [], [], [], []
    for i in range(iters):
        w = a @ v - beta_prev * v_prev
        alpha = float(v @ w)
        w = w - alpha * v
        # full reorthogonalization (twice for stability)
        for _ in range(2):
            w = w - V[:, : i + 1] @ (V[:, : i + 1].T @ w)
        beta = float(np.linalg.norm(w))
        alphas.append(alpha)
        betas.append(beta)

        k = i + 1
        J = np.diag(alphas) + np.diag(betas[:-1], 1) + np.diag(betas[:-1], -1)
        e1 = np.zeros(k); e1[0] = 1.0
        g_h.append(unorm2 * float(np.linalg.solve(J, e1)[0]))

        # modified matrices: prescribed eigenvalue(s) via delta recurrences
        d_lr, d_rr = alphas[0] - lam_min, alphas[0] - lam_max
        for j in range(1, k):
            d_lr = alphas[j] - lam_min - betas[j - 1] ** 2 / d_lr
            d_rr = alphas[j] - lam_max - betas[j - 1] ** 2 / d_rr
        a_lr = lam_min + beta**2 / d_lr
        a_rr = lam_max + beta**2 / d_rr
        a_lo, b_lo2 = lobatto_coeffs(d_lr, d_rr, lam_min, lam_max)

        def ext(alpha_last, beta_last2):
            Je = np.zeros((k + 1, k + 1))
            Je[:k, :k] = J
            Je[k, k] = alpha_last
            b = np.sqrt(max(beta_last2, 0.0))
            Je[k - 1, k] = Je[k, k - 1] = b
            e = np.zeros(k + 1); e[0] = 1.0
            return unorm2 * float(np.linalg.solve(Je, e)[0])

        glr_h.append(ext(a_lr, beta**2))
        grr_h.append(ext(a_rr, beta**2))
        glo_h.append(ext(a_lo, b_lo2))

        if beta <= 1e-14:
            # pad remaining iterations with the exact value
            while len(g_h) < iters:
                g_h.append(g_h[-1]); grr_h.append(g_h[-1])
                glr_h.append(g_h[-1]); glo_h.append(g_h[-1])
            break
        v_prev, v = v, w / beta
        if i + 1 < iters:
            V[:, i + 1] = v
        beta_prev = beta

    return (np.array(g_h), np.array(grr_h), np.array(glr_h), np.array(glo_h))


def bif_exact(a, u):
    """u^T A^{-1} u by direct solve — the ground truth for tests."""
    a = np.asarray(a, dtype=np.float64)
    u = np.asarray(u, dtype=np.float64)
    return float(u @ np.linalg.solve(a, u))


def random_spd(n, density=0.1, lam1=1e-2, seed=0):
    """The paper's §4.4 synthetic generator: random symmetric matrix with
    the given density of standard-normal entries, diagonal-shifted so the
    smallest eigenvalue equals ``lam1``.  Returns (A, lam1, lamN)."""
    rng = np.random.default_rng(seed)
    mask = rng.random((n, n)) < density
    vals = rng.standard_normal((n, n)) * mask
    a = (vals + vals.T) / 2.0
    evals = np.linalg.eigvalsh(a)
    a += (lam1 - evals[0]) * np.eye(n)
    evals = evals - evals[0] + lam1
    return a, float(evals[0]), float(evals[-1])
