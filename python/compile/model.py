"""L2: the GQL compute graph in JAX (paper Alg. 5), calling the L1 kernels.

``gql_bounds`` runs a fixed number of Gauss-Quadrature-Lanczos iterations as
a ``lax.scan`` whose body is the fused Pallas Lanczos step plus the
Sherman–Morrison bound recurrences, returning the full per-iteration history
of the four Gauss-type bounds.  The rust coordinator then scans that history
for the first iteration at which a retrospective judge becomes decidable —
that keeps PJRT artifacts fixed-shape while preserving the paper's
"iterate-until-decidable" semantics.

``gql_bounds_batched`` vmaps over a bucket of queries; one PJRT dispatch
serves a whole dynamic-batcher bucket.

Shapes are bridged by identity padding (see ``pad_query``): blkdiag(A, I)
with zero-padded u leaves every Lanczos iterate — hence every bound —
unchanged, which tests assert exactly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from compile.kernels import matvec as kernels


def _radau_lobatto(g, unorm2, beta, c, delta, d_lr, d_rr, lam_min, lam_max):
    """Bound corrections from the modified Jacobi matrices (see ref.py for
    the Lobatto coefficient derivation)."""
    beta2 = beta * beta
    a_lr = lam_min + beta2 / d_lr
    a_rr = lam_max + beta2 / d_rr
    denom = d_rr - d_lr
    b_lo2 = (lam_max - lam_min) * d_lr * d_rr / denom
    a_lo = (lam_max * d_rr - lam_min * d_lr) / denom
    c2 = c * c
    g_rr = g + unorm2 * beta2 * c2 / (delta * (a_rr * delta - beta2))
    g_lr = g + unorm2 * beta2 * c2 / (delta * (a_lr * delta - beta2))
    g_lo = g + unorm2 * b_lo2 * c2 / (delta * (a_lo * delta - b_lo2))
    return g_rr, g_lr, g_lo


def gql_bounds(a, u, lam_min, lam_max, iters, *, use_pallas=True):
    """Per-iteration GQL bounds on u^T A^{-1} u.

    Args:
      a: [n, n] symmetric positive definite (f32).
      u: [n] query vector (nonzero).
      lam_min, lam_max: scalars straddling the spectrum (0 < lam_min ≤ λ_1,
        lam_max ≥ λ_n).
      iters: static number of quadrature iterations.
      use_pallas: route the Lanczos step through the L1 kernel (default) or
        the pure-jnp reference (used by tests to isolate kernel bugs).

    Returns:
      (g, g_rr, g_lr, g_lo): four [iters] arrays; g/g_rr are lower bounds,
      g_lr/g_lo upper bounds, monotone per Corr. 7.  After Krylov breakdown
      all four freeze at the (exact) Gauss value.
    """
    n = a.shape[0]
    dtype = a.dtype
    unorm2 = jnp.sum(u * u)
    u0 = u / jnp.sqrt(unorm2)
    lam_min = jnp.asarray(lam_min, dtype)
    lam_max = jnp.asarray(lam_max, dtype)

    def step_kernel(v_prev, v_curr, beta_prev):
        if use_pallas:
            return kernels.lanczos_step_fused(a, v_prev, v_curr, beta_prev)
        av = a @ v_curr
        alpha = jnp.sum(av * v_curr)
        w = av - alpha * v_curr - beta_prev * v_prev
        beta = jnp.sqrt(jnp.sum(w * w))
        safe = jnp.where(beta > 0, beta, jnp.ones_like(beta))
        v_next = jnp.where(beta > 0, w / safe, jnp.zeros_like(w))
        return alpha, beta, v_next

    # --- iteration 1 (initializes every recurrence) ---
    alpha1, beta1, v1 = step_kernel(jnp.zeros_like(u0), u0, jnp.zeros((), dtype))
    g1 = unorm2 / alpha1
    c1 = jnp.ones((), dtype)
    delta1 = alpha1
    d_lr1 = alpha1 - lam_min
    d_rr1 = alpha1 - lam_max
    grr1, glr1, glo1 = _radau_lobatto(
        g1, unorm2, beta1, c1, delta1, d_lr1, d_rr1, lam_min, lam_max
    )

    def body(carry, _):
        v_prev, v_curr, beta_prev, g, c, delta, d_lr, d_rr = carry
        alive = beta_prev > 0

        alpha, beta, v_next = step_kernel(v_prev, v_curr, beta_prev)

        bp2 = beta_prev * beta_prev
        g_new = g + unorm2 * bp2 * c * c / (delta * (alpha * delta - bp2))
        c_new = c * beta_prev / delta
        delta_new = alpha - bp2 / delta
        d_lr_new = alpha - lam_min - bp2 / d_lr
        d_rr_new = alpha - lam_max - bp2 / d_rr
        g_rr, g_lr, g_lo = _radau_lobatto(
            g_new, unorm2, beta, c_new, delta_new, d_lr_new, d_rr_new,
            lam_min, lam_max,
        )

        # Krylov breakdown: freeze everything at the exact Gauss value.
        g_out = jnp.where(alive, g_new, g)
        outs = (
            g_out,
            jnp.where(alive, g_rr, g),
            jnp.where(alive, g_lr, g),
            jnp.where(alive, g_lo, g),
        )
        carry = (
            jnp.where(alive, v_curr, v_prev),
            jnp.where(alive, v_next, v_curr),
            jnp.where(alive, beta, beta_prev * 0),
            g_out,
            jnp.where(alive, c_new, c),
            jnp.where(alive, delta_new, delta),
            jnp.where(alive, d_lr_new, d_lr),
            jnp.where(alive, d_rr_new, d_rr),
        )
        return carry, outs

    carry0 = (u0, v1, beta1, g1, c1, delta1, d_lr1, d_rr1)
    if iters > 1:
        _, (gs, grrs, glrs, glos) = lax.scan(body, carry0, None, length=iters - 1)
        g = jnp.concatenate([g1[None], gs])
        g_rr = jnp.concatenate([grr1[None], grrs])
        g_lr = jnp.concatenate([glr1[None], glrs])
        g_lo = jnp.concatenate([glo1[None], glos])
    else:
        g, g_rr, g_lr, g_lo = g1[None], grr1[None], glr1[None], glo1[None]
    return g, g_rr, g_lr, g_lo


def gql_bounds_batched(a, u, lam_min, lam_max, iters, *, use_pallas=False):
    """vmapped GQL over a bucket: a:[B,n,n], u:[B,n], lam_*:[B].

    The batched artifact uses the jnp step (vmap of a pallas_call in
    interpret mode lowers to per-example loops anyway; the single-query
    artifact exercises the kernel).
    """
    fn = functools.partial(gql_bounds, iters=iters, use_pallas=use_pallas)
    return jax.vmap(fn, in_axes=(0, 0, 0, 0))(a, u, lam_min, lam_max)


def pad_query(a, u, n_pad):
    """Identity-pad a query to bucket size ``n_pad``: A ← blkdiag(A, I),
    u ← [u; 0].  Leaves u^T A^{-1} u and every GQL iterate unchanged."""
    n = a.shape[0]
    if n == n_pad:
        return a, u
    a_p = jnp.eye(n_pad, dtype=a.dtype).at[:n, :n].set(a)
    u_p = jnp.zeros((n_pad,), dtype=u.dtype).at[:n].set(u)
    return a_p, u_p
