"""AOT pipeline: lower the L2 GQL model to HLO *text* artifacts + manifest.

Python runs once at build time (``make artifacts``); the rust runtime loads
``artifacts/*.hlo.txt`` via ``HloModuleProto::from_text_file`` and never
calls back into python.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the image's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/load_hlo/.

Artifact signature (all f32):
  inputs : a [n,n] (or [b,n,n]), u [n] (or [b,n]), lam_min [] (or [b]),
           lam_max [] (or [b])
  outputs: 4-tuple (g, g_rr, g_lr, g_lo), each [iters] (or [b,iters])

The manifest is plain JSON parsed by the in-repo parser in
rust/src/config/json.rs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model

# (n, batch, iters, use_pallas): serving buckets.  The single-query buckets
# route through the fused Pallas Lanczos-step kernel; batched buckets use the
# vmapped jnp step (see model.gql_bounds_batched docstring).
DEFAULT_BUCKETS = [
    (16, 1, 16, True),
    (32, 1, 32, True),
    (64, 1, 48, True),
    (128, 1, 64, True),
    (256, 1, 64, True),
    (32, 8, 32, False),
    (64, 8, 48, False),
    (128, 8, 64, False),
]


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_bucket(n: int, batch: int, iters: int, use_pallas: bool) -> str:
    import jax.numpy as jnp

    f32 = jnp.float32
    if batch == 1:
        spec_a = jax.ShapeDtypeStruct((n, n), f32)
        spec_u = jax.ShapeDtypeStruct((n,), f32)
        spec_s = jax.ShapeDtypeStruct((), f32)

        def fn(a, u, lam_min, lam_max):
            return model.gql_bounds(a, u, lam_min, lam_max, iters,
                                    use_pallas=use_pallas)
    else:
        spec_a = jax.ShapeDtypeStruct((batch, n, n), f32)
        spec_u = jax.ShapeDtypeStruct((batch, n), f32)
        spec_s = jax.ShapeDtypeStruct((batch,), f32)

        def fn(a, u, lam_min, lam_max):
            return model.gql_bounds_batched(a, u, lam_min, lam_max, iters,
                                            use_pallas=use_pallas)

    lowered = jax.jit(fn).lower(spec_a, spec_u, spec_s, spec_s)
    return to_hlo_text(lowered)


def build(out_dir: str, buckets=None) -> dict:
    buckets = buckets or DEFAULT_BUCKETS
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for n, batch, iters, use_pallas in buckets:
        name = f"gql_n{n}_b{batch}_i{iters}"
        path = f"{name}.hlo.txt"
        text = lower_bucket(n, batch, iters, use_pallas)
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "path": path,
            "n": n,
            "batch": batch,
            "iters": iters,
            "dtype": "f32",
            "pallas": use_pallas,
        })
        print(f"  wrote {path} ({len(text)} chars)")
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(entries)} entries)")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the two smallest buckets (for tests)")
    args = ap.parse_args()
    buckets = DEFAULT_BUCKETS[:2] if args.quick else DEFAULT_BUCKETS
    build(args.out_dir, buckets)


if __name__ == "__main__":
    main()
