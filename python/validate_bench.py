#!/usr/bin/env python3
"""Validate BENCH_*.json perf-trajectory artifacts and gate regressions.

Every artifact in the given directory is checked against the version-1
stats schema emitted by ``rust/src/util/bench.rs::write_stats_json``:

    {"bench": str, "version": 1, "results":
      [{"name": str, "mean": ns, "median": ns, "p95": ns, "n": samples}]}

If a baseline directory is given, each artifact with a same-named
committed baseline is additionally compared row by row: a row whose
median exceeds ``baseline_median * tolerance`` fails the gate. Rows
missing from the baseline are skipped (new benches never fail the gate),
as are artifacts without a committed baseline — so the baseline set is
opt-in per bench and can stay deliberately loose.

Usage:
    python3 python/validate_bench.py <artifact-dir> \
        [--baseline benches/baselines] [--tolerance 1.25]

Exit status is nonzero on any schema violation or regression.
"""

import argparse
import json
import sys
from pathlib import Path

ROW_KEYS = ("name", "mean", "median", "p95", "n")


def validate_schema(path):
    """Return the parsed artifact, raising ValueError on schema breaks."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc.get("bench"), str) or not doc["bench"]:
        raise ValueError(f"{path.name}: 'bench' must be a non-empty string")
    if doc.get("version") != 1:
        raise ValueError(f"{path.name}: unsupported version {doc.get('version')!r}")
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"{path.name}: 'results' must be a non-empty list")
    seen = set()
    for row in rows:
        for key in ROW_KEYS:
            if key not in row:
                raise ValueError(f"{path.name}: row {row!r} missing '{key}'")
        name = row["name"]
        if not isinstance(name, str) or not name:
            raise ValueError(f"{path.name}: row name must be a non-empty string")
        if name in seen:
            raise ValueError(f"{path.name}: duplicate row name {name!r}")
        seen.add(name)
        for key in ("mean", "median", "p95"):
            v = row[key]
            if not isinstance(v, (int, float)) or v <= 0:
                raise ValueError(f"{path.name}: {name!r} {key}={v!r} not > 0")
        if not isinstance(row["n"], (int, float)) or row["n"] < 1:
            raise ValueError(f"{path.name}: {name!r} n={row['n']!r} not >= 1")
    return doc


def compare_to_baseline(path, doc, base_doc, tolerance):
    """Return (checked, skipped, failures) for one artifact/baseline pair."""
    base = {r["name"]: r for r in base_doc["results"]}
    checked, skipped, failures = 0, [], []
    for row in doc["results"]:
        ref = base.get(row["name"])
        if ref is None:
            skipped.append(row["name"])
            continue
        checked += 1
        limit = ref["median"] * tolerance
        if row["median"] > limit:
            failures.append(
                f"{path.name}: {row['name']!r} median {row['median']:.0f} ns "
                f"exceeds baseline {ref['median']:.0f} ns * {tolerance:g} "
                f"= {limit:.0f} ns"
            )
    return checked, skipped, failures


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact_dir", type=Path, help="directory holding BENCH_*.json")
    ap.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="directory of committed baseline BENCH_*.json files",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=1.25,
        help="fail a row whose median exceeds baseline * tolerance (default 1.25)",
    )
    args = ap.parse_args()

    artifacts = sorted(args.artifact_dir.glob("BENCH_*.json"))
    if not artifacts:
        print(f"no BENCH_*.json artifacts under {args.artifact_dir}", file=sys.stderr)
        return 1

    failures = []
    for path in artifacts:
        try:
            doc = validate_schema(path)
        except (ValueError, json.JSONDecodeError, OSError) as e:
            failures.append(f"{path.name}: {e}")
            continue
        print(f"{path.name}: schema OK ({len(doc['results'])} rows)")
        if args.baseline is None:
            continue
        base_path = args.baseline / path.name
        if not base_path.exists():
            print(f"{path.name}: no committed baseline, gate skipped")
            continue
        try:
            base_doc = validate_schema(base_path)
        except (ValueError, json.JSONDecodeError, OSError) as e:
            failures.append(f"baseline {base_path}: {e}")
            continue
        checked, skipped, row_failures = compare_to_baseline(
            path, doc, base_doc, args.tolerance
        )
        failures.extend(row_failures)
        note = f", {len(skipped)} new rows skipped" if skipped else ""
        print(
            f"{path.name}: {checked} rows within {args.tolerance:g}x "
            f"of baseline{note}"
            if not row_failures
            else f"{path.name}: {len(row_failures)} regressions"
        )

    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
