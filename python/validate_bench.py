#!/usr/bin/env python3
"""Validate BENCH_*.json perf-trajectory artifacts and gate regressions.

Every artifact in the given directory is checked against the version-1
stats schema emitted by ``rust/src/util/bench.rs::write_stats_json``:

    {"bench": str, "version": 1, "results":
      [{"name": str, "mean": ns, "median": ns, "p95": ns, "n": samples}]}

If a baseline directory is given, each artifact with a same-named
committed baseline is additionally compared row by row: a row whose
median exceeds ``baseline_median * tolerance`` fails the gate. Rows
missing from the baseline are skipped (new benches never fail the gate),
as are artifacts without a committed baseline — so the baseline set is
opt-in per bench and can stay deliberately loose.

``--require NAME`` (repeatable) hardens that opt-in for the artifacts the
gate is expected to cover: a required artifact that is missing from the
artifact directory, or whose committed baseline is missing from the
baseline directory, fails the run instead of being silently skipped — a
renamed bench or a dropped baseline file can no longer turn the gate into
a no-op.

``--overhead ARTIFACT:NUM_ROW:DEN_ROW:LIMIT`` (repeatable) checks a
within-artifact ratio: the NUM_ROW median must stay within LIMIT times
the DEN_ROW median (e.g. the flight-recorder-on row vs the recorder-off
row at 1.05). Missing artifact or rows fail the gate.

Usage:
    python3 python/validate_bench.py <artifact-dir> \
        [--baseline benches/baselines] [--tolerance 1.25] \
        [--require BENCH_engine.json] \
        [--overhead "BENCH_engine.json:flight on w=2:flight off w=2:1.05"]

Exit status is nonzero on any schema violation, regression, missing
required artifact/baseline, or overhead-ceiling breach.
"""

import argparse
import json
import sys
from pathlib import Path

ROW_KEYS = ("name", "mean", "median", "p95", "n")


def validate_schema(path):
    """Return the parsed artifact, raising ValueError on schema breaks."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc.get("bench"), str) or not doc["bench"]:
        raise ValueError(f"{path.name}: 'bench' must be a non-empty string")
    if doc.get("version") != 1:
        raise ValueError(f"{path.name}: unsupported version {doc.get('version')!r}")
    rows = doc.get("results")
    if not isinstance(rows, list) or not rows:
        raise ValueError(f"{path.name}: 'results' must be a non-empty list")
    seen = set()
    for row in rows:
        for key in ROW_KEYS:
            if key not in row:
                raise ValueError(f"{path.name}: row {row!r} missing '{key}'")
        name = row["name"]
        if not isinstance(name, str) or not name:
            raise ValueError(f"{path.name}: row name must be a non-empty string")
        if name in seen:
            raise ValueError(f"{path.name}: duplicate row name {name!r}")
        seen.add(name)
        for key in ("mean", "median", "p95"):
            v = row[key]
            if not isinstance(v, (int, float)) or v <= 0:
                raise ValueError(f"{path.name}: {name!r} {key}={v!r} not > 0")
        if not isinstance(row["n"], (int, float)) or row["n"] < 1:
            raise ValueError(f"{path.name}: {name!r} n={row['n']!r} not >= 1")
    return doc


def compare_to_baseline(path, doc, base_doc, tolerance):
    """Return (checked, skipped, failures) for one artifact/baseline pair."""
    base = {r["name"]: r for r in base_doc["results"]}
    checked, skipped, failures = 0, [], []
    for row in doc["results"]:
        ref = base.get(row["name"])
        if ref is None:
            skipped.append(row["name"])
            continue
        checked += 1
        limit = ref["median"] * tolerance
        if row["median"] > limit:
            failures.append(
                f"{path.name}: {row['name']!r} median {row['median']:.0f} ns "
                f"exceeds baseline {ref['median']:.0f} ns * {tolerance:g} "
                f"= {limit:.0f} ns"
            )
    return checked, skipped, failures


def parse_overhead_spec(spec):
    """Split 'ARTIFACT:NUM_ROW:DEN_ROW:LIMIT' into its typed parts."""
    parts = spec.split(":")
    if len(parts) != 4:
        raise ValueError(
            f"--overhead {spec!r}: expected ARTIFACT:NUM_ROW:DEN_ROW:LIMIT"
        )
    artifact, num_row, den_row, limit = parts
    try:
        limit = float(limit)
    except ValueError:
        raise ValueError(f"--overhead {spec!r}: limit {limit!r} is not a number")
    if limit <= 0:
        raise ValueError(f"--overhead {spec!r}: limit must be > 0")
    return artifact, num_row, den_row, limit


def check_overhead(docs, spec):
    """Return a failure string for one overhead spec, or None if it holds."""
    artifact, num_row, den_row, limit = parse_overhead_spec(spec)
    doc = docs.get(artifact)
    if doc is None:
        return f"--overhead: artifact {artifact!r} missing or failed validation"
    rows = {r["name"]: r for r in doc["results"]}
    for name in (num_row, den_row):
        if name not in rows:
            return f"--overhead: {artifact}: row {name!r} not found"
    num, den = rows[num_row]["median"], rows[den_row]["median"]
    ceiling = den * limit
    if num > ceiling:
        return (
            f"--overhead: {artifact}: {num_row!r} median {num:.0f} ns exceeds "
            f"{den_row!r} median {den:.0f} ns * {limit:g} = {ceiling:.0f} ns"
        )
    print(
        f"{artifact}: overhead OK — {num_row!r} {num:.0f} ns <= "
        f"{den_row!r} {den:.0f} ns * {limit:g}"
    )
    return None


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("artifact_dir", type=Path, help="directory holding BENCH_*.json")
    ap.add_argument(
        "--baseline",
        type=Path,
        default=None,
        help="directory of committed baseline BENCH_*.json files",
    )
    ap.add_argument(
        "--tolerance",
        type=float,
        default=1.25,
        help="fail a row whose median exceeds baseline * tolerance (default 1.25)",
    )
    ap.add_argument(
        "--require",
        action="append",
        default=[],
        metavar="NAME",
        help="artifact that must exist (and, with --baseline, must have a "
        "committed baseline); repeatable",
    )
    ap.add_argument(
        "--overhead",
        action="append",
        default=[],
        metavar="ARTIFACT:NUM_ROW:DEN_ROW:LIMIT",
        help="within-artifact median ratio ceiling; repeatable",
    )
    args = ap.parse_args()

    artifacts = sorted(args.artifact_dir.glob("BENCH_*.json"))
    if not artifacts:
        print(f"no BENCH_*.json artifacts under {args.artifact_dir}", file=sys.stderr)
        return 1

    failures = []
    docs = {}
    present = {p.name for p in artifacts}
    for name in args.require:
        if name not in present:
            failures.append(
                f"--require: artifact {name!r} missing from {args.artifact_dir}"
            )
        elif args.baseline is not None and not (args.baseline / name).exists():
            failures.append(
                f"--require: {name!r} has no committed baseline under "
                f"{args.baseline} — the regression gate would silently skip it"
            )
    for path in artifacts:
        try:
            doc = validate_schema(path)
        except (ValueError, json.JSONDecodeError, OSError) as e:
            failures.append(f"{path.name}: {e}")
            continue
        docs[path.name] = doc
        print(f"{path.name}: schema OK ({len(doc['results'])} rows)")
        if args.baseline is None:
            continue
        base_path = args.baseline / path.name
        if not base_path.exists():
            print(f"{path.name}: no committed baseline, gate skipped")
            continue
        try:
            base_doc = validate_schema(base_path)
        except (ValueError, json.JSONDecodeError, OSError) as e:
            failures.append(f"baseline {base_path}: {e}")
            continue
        checked, skipped, row_failures = compare_to_baseline(
            path, doc, base_doc, args.tolerance
        )
        failures.extend(row_failures)
        note = f", {len(skipped)} new rows skipped" if skipped else ""
        print(
            f"{path.name}: {checked} rows within {args.tolerance:g}x "
            f"of baseline{note}"
            if not row_failures
            else f"{path.name}: {len(row_failures)} regressions"
        )

    for spec in args.overhead:
        try:
            fail = check_overhead(docs, spec)
        except ValueError as e:
            fail = str(e)
        if fail:
            failures.append(fail)

    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
