// perf probe: DPP gauss chain per-step cost (EXPERIMENTS.md §Perf)
use gauss_bif::apps::{BifStrategy, DppConfig, DppSampler};
use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::util::rng::Rng;
fn main() {
    for &n in &[5000usize, 20000, 50000] {
        let mut rng = Rng::new(0xFEED);
        let (l, w) = random_sparse_spd(&mut rng, n, 2e-4, 1e-2);
        let l = std::sync::Arc::new(l);
        let mut r = Rng::new(1);
        let mut s = DppSampler::new(&l, DppConfig::new(BifStrategy::Gauss, w).with_init_size(n/3), &mut r);
        let steps = 300;
        let t0 = std::time::Instant::now();
        s.run(steps, &mut r);
        let per = t0.elapsed().as_secs_f64()/steps as f64;
        println!("n={n:6} nnz={:8} per-step={:.1}us avg-judge-iters={:.1}",
            l.nnz(), per*1e6, s.stats.judge_iters_total as f64/s.stats.decisions as f64);
    }
}
