//! Quickstart: bound a bilinear inverse form `u^T A^{-1} u` with iteratively
//! tightening Gauss-type quadrature, and use the retrospective judge to
//! decide a comparison in a handful of iterations.
//!
//! Run: `cargo run --release --example quickstart`

use gauss_bif::datasets::random_sparse_spd;
use gauss_bif::linalg::Cholesky;
use gauss_bif::quadrature::{judge_threshold, Gql, GqlOptions};
use gauss_bif::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(42);

    // A 500×500 sparse SPD matrix (1% density) and a random query vector.
    let n = 500;
    let (a, window) = random_sparse_spd(&mut rng, n, 0.01, 1e-2);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    println!(
        "A: {}x{} CSR, nnz = {} (density {:.2e}), spectrum window [{:.3e}, {:.3e}]",
        n,
        n,
        a.nnz(),
        a.density(),
        window.lo,
        window.hi
    );

    // Ground truth (dense Cholesky — the thing quadrature avoids).
    let exact = Cholesky::factor(&a.to_dense()).unwrap().bif(&u);
    println!("exact  u^T A^-1 u = {exact:.6}");

    // Iteratively tightening bounds (paper Alg. 5). Each step is one
    // sparse matvec.
    let opts = GqlOptions::new(window.lo, window.hi);
    let mut gql = Gql::new(&a, &u, opts);
    println!("\niter |    gauss (lower) | radau lower | radau upper | lobatto (upper)");
    for _ in 0..25 {
        let b = gql.step();
        if b.iter % 5 == 0 || b.iter <= 3 {
            println!(
                "{:4} | {:16.6} | {:11.6} | {:11.6} | {:15.6}",
                b.iter, b.gauss, b.radau_lower, b.radau_upper, b.lobatto
            );
        }
        if b.exact {
            break;
        }
    }
    let b = gql.last_bounds().unwrap();
    // fully converged bounds agree with the Cholesky value to rounding
    let tol = 1e-9 * exact.abs();
    assert!(b.lower() <= exact + tol && exact <= b.upper() + tol);
    println!(
        "\nafter {} iterations: bracket [{:.6}, {:.6}] (width {:.2e}) contains the truth",
        gql.iterations(),
        b.lower(),
        b.upper(),
        b.gap()
    );

    // The retrospective judge: decide "is 0.9·exact < BIF?" — typically in
    // far fewer iterations than convergence requires.
    let (ans, stats) = judge_threshold(&a, &u, 0.9 * exact, opts);
    println!(
        "judge(0.9·exact < BIF) = {ans} after only {} iterations ({:?})",
        stats.iters, stats.outcome
    );
    assert!(ans);
    let (ans, stats) = judge_threshold(&a, &u, 1.1 * exact, opts);
    println!(
        "judge(1.1·exact < BIF) = {ans} after only {} iterations ({:?})",
        stats.iters, stats.outcome
    );
    assert!(!ans);
    println!("\nquickstart OK");
}
