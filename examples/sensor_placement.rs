//! Sensor placement / information maximization via double greedy on
//! `F(S) = log det(L_S)` (paper §2 "Submodular optimization, Sensing" and
//! §5.2): select a near-optimal subset of spatial locations modeled by a
//! Gaussian-process RBF kernel.
//!
//! Demonstrates that the retrospective variant selects the *same set* as
//! the exact algorithm (Alg. 2's correctness guarantee) while being much
//! faster, and reports the achieved log-det objective.
//!
//! Run: `cargo run --release --example sensor_placement`

use gauss_bif::apps::{double_greedy, BifStrategy, DgConfig};
use gauss_bif::datasets::{rbf_kernel_csr, PointCloud, RIDGE};
use gauss_bif::sparse::gershgorin_bounds;
use gauss_bif::util::bench::{fmt_sci, fmt_speedup};
use gauss_bif::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(11);

    // A synthetic 2-d sensor field: 600 candidate locations, RBF kernel
    // with hard locality (as in GP-based spatial monitoring).
    let n = 600;
    let cloud = PointCloud::synthetic(&mut rng, n, 2);
    let l = Arc::new(rbf_kernel_csr(&cloud, 0.12, 0.36, 0.02).with_diag_shift(RIDGE));
    let window = gershgorin_bounds(&l).clamp_lo(RIDGE * 0.5);
    println!(
        "sensor field: {} candidate locations, kernel nnz = {} (density {:.2e})",
        n,
        l.nnz(),
        l.density()
    );

    // Exact double greedy (per-decision dense Cholesky on the shrinking
    // Y-side — the expensive baseline; restrict to a prefix so the demo
    // stays interactive).
    let demo_elems = 150;
    let mut r = Rng::new(33);
    let t0 = Instant::now();
    let exact = double_greedy(
        &l,
        DgConfig::new(BifStrategy::Exact, window).with_limit(demo_elems),
        &mut r,
    );
    let t_exact = t0.elapsed().as_secs_f64();

    // Retrospective quadrature, same seed ⇒ must choose the same set.
    let mut r = Rng::new(33);
    let t0 = Instant::now();
    let gauss = double_greedy(
        &l,
        DgConfig::new(BifStrategy::Gauss, window).with_limit(demo_elems),
        &mut r,
    );
    let t_gauss = t0.elapsed().as_secs_f64();

    assert_eq!(
        exact.chosen, gauss.chosen,
        "retrospective judging must not change the algorithm's choices"
    );
    println!("\ndouble greedy over the first {demo_elems} candidates:");
    println!(
        "  selected {} locations, log det(L_S) = {:.4}",
        gauss.chosen.len(),
        gauss.objective
    );
    println!("  exact baseline : {}", fmt_sci(t_exact));
    println!("  gauss (ours)   : {}", fmt_sci(t_gauss));
    println!("  speedup        : {}", fmt_speedup(t_exact, t_gauss));
    println!(
        "  identical selections: YES (guaranteed by exact judging)"
    );

    // Full ground set with quadrature only (baseline would take minutes).
    let mut r = Rng::new(34);
    let t0 = Instant::now();
    let full = double_greedy(&l, DgConfig::new(BifStrategy::Gauss, window), &mut r);
    let t_full = t0.elapsed().as_secs_f64();
    println!(
        "\nfull ground set ({} elements) with quadrature: {} — picked {} locations, log det = {:.4}",
        n,
        fmt_sci(t_full),
        full.chosen.len(),
        full.objective
    );
    println!("\nsensor_placement OK");
}
