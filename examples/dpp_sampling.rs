//! DPP and k-DPP sampling with the retrospective quadrature framework
//! (paper §5.1), on an RBF-kernel dataset substitute — mirrors the
//! workload behind Table 2's Dpp/k-Dpp rows and prints the same
//! time + speedup columns.
//!
//! Run: `cargo run --release --example dpp_sampling`

use gauss_bif::apps::{BifStrategy, DppConfig, DppSampler, KdppConfig, KdppSampler};
use gauss_bif::datasets::{table1_specs, RIDGE};
use gauss_bif::sparse::gershgorin_bounds;
use gauss_bif::util::bench::{fmt_sci, fmt_speedup};
use gauss_bif::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(7);
    // Abalone substitute at 1/8 scale so the exact baseline stays feasible
    // for a live demo (Table 2's full-scale run lives in EXPERIMENTS.md).
    let spec = &table1_specs()[0];
    let scale = 8;
    let l = Arc::new(spec.build(&mut rng, scale));
    let window = gershgorin_bounds(&l).clamp_lo(RIDGE * 0.5);
    let n = l.n;
    let k = n / 3;
    println!(
        "{} substitute (scale 1/{}): n={} nnz={} density={:.2e}",
        spec.name,
        scale,
        n,
        l.nnz(),
        l.density()
    );

    // --- DPP: exact baseline vs retrospective quadrature ---
    let steps_exact = 20;
    let steps_gauss = 400;

    let mut r = Rng::new(1001);
    let mut exact = DppSampler::new(
        &l,
        DppConfig::new(BifStrategy::Exact, window).with_init_size(k),
        &mut r,
    );
    let t0 = Instant::now();
    exact.run(steps_exact, &mut r);
    let exact_per_step = t0.elapsed().as_secs_f64() / steps_exact as f64;

    let mut r = Rng::new(1001);
    let mut gauss = DppSampler::new(
        &l,
        DppConfig::new(BifStrategy::Gauss, window).with_init_size(k),
        &mut r,
    );
    let t0 = Instant::now();
    gauss.run(steps_gauss, &mut r);
    let gauss_per_step = t0.elapsed().as_secs_f64() / steps_gauss as f64;

    println!("\nDPP  (per chain step):");
    println!("  exact baseline : {}", fmt_sci(exact_per_step));
    println!("  gauss (ours)   : {}", fmt_sci(gauss_per_step));
    println!("  speedup        : {}", fmt_speedup(exact_per_step, gauss_per_step));
    println!(
        "  avg judge iterations: {:.1} (set size ~{})",
        gauss.stats.judge_iters_total as f64 / gauss.stats.decisions.max(1) as f64,
        gauss.current_set().len()
    );

    // --- k-DPP swap chain ---
    let mut r = Rng::new(2002);
    let mut exact = KdppSampler::new(&l, KdppConfig::new(BifStrategy::Exact, window, k), &mut r);
    let t0 = Instant::now();
    exact.run(steps_exact, &mut r);
    let exact_per_step = t0.elapsed().as_secs_f64() / steps_exact as f64;

    let mut r = Rng::new(2002);
    let mut gauss = KdppSampler::new(&l, KdppConfig::new(BifStrategy::Gauss, window, k), &mut r);
    let t0 = Instant::now();
    gauss.run(steps_gauss, &mut r);
    let gauss_per_step = t0.elapsed().as_secs_f64() / steps_gauss as f64;

    println!("\nk-DPP (k = {k}, per swap proposal):");
    println!("  exact baseline : {}", fmt_sci(exact_per_step));
    println!("  gauss (ours)   : {}", fmt_sci(gauss_per_step));
    println!("  speedup        : {}", fmt_speedup(exact_per_step, gauss_per_step));
    println!(
        "  acceptance rate: {:.2}",
        gauss.stats.accepted as f64 / gauss.stats.steps as f64
    );

    println!("\ndpp_sampling OK");
}
