//! Network centrality via BIF bounds (paper §2 "Network Analysis"):
//! find the top-k Bonacich-central nodes of a power-law graph by refining
//! per-node centrality *intervals* only until the ranking separates —
//! no full linear solve per node.
//!
//! Run: `cargo run --release --example centrality_ranking`

use gauss_bif::apps::rank_top_k_centrality;
use gauss_bif::datasets::power_law_graph;
use gauss_bif::quadrature::cg_solve;
use gauss_bif::sparse::{gershgorin_bounds, CsrBuilder};
use gauss_bif::util::rng::Rng;
use std::time::Instant;

fn main() {
    let mut rng = Rng::new(23);
    let n = 2000;
    let edges = power_law_graph(&mut rng, n, 6.0);
    let mut b = CsrBuilder::new(n);
    for &(i, j) in &edges {
        b.push_sym(i, j, 1.0);
    }
    let a = b.build();
    println!("graph: {} nodes, {} edges", n, edges.len());

    let alpha = 0.5 / gershgorin_bounds(&a).hi;
    println!("Bonacich α = {alpha:.5} (½/λmax bound)");

    // Retrospective interval ranking over a candidate pool.
    let candidates: Vec<usize> = (0..n).step_by(4).collect();
    let k = 10;
    let t0 = Instant::now();
    let res = rank_top_k_centrality(&a, alpha, k, Some(&candidates));
    let t_ours = t0.elapsed().as_secs_f64();
    println!(
        "\ntop-{k} via interval refinement: {:?}  ({} quadrature iterations, {:.3}s)",
        res.top, res.iters, t_ours
    );

    // Exact baseline: solve (I − αA) x = 1 once with CG and rank.
    let m = gauss_bif::apps::centrality::bonacich_matrix(&a, alpha);
    let t0 = Instant::now();
    let x = cg_solve(&m, &vec![1.0; n], 1e-10, 10 * n).x;
    let t_exact = t0.elapsed().as_secs_f64();
    let mut order = candidates.clone();
    order.sort_by(|&i, &j| x[j].partial_cmp(&x[i]).unwrap());
    let want: Vec<usize> = order[..k].to_vec();
    println!("top-{k} via full CG solve:      {:?}  ({t_exact:.3}s)", want);

    let mut got = res.top.clone();
    let mut expect = want.clone();
    got.sort_unstable();
    expect.sort_unstable();
    assert_eq!(got, expect, "rankings must agree");
    println!("\nrankings agree; centrality_ranking OK");
}
