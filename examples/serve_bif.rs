//! END-TO-END DRIVER: proves all three layers compose.
//!
//!   L1 (Pallas kernel) + L2 (JAX GQL scan)  →  AOT HLO artifacts
//!   → rust runtime (PJRT CPU client)        →  coordinator (router +
//!     dynamic batcher + judge service)       →  a real serving workload.
//!
//! The workload: a stream of DPP-style transition judgements (dense BIF
//! threshold queries at mixed sizes, exactly what Alg. 3 issues per chain
//! step) is submitted concurrently to the judge service. Every decision is
//! checked against a dense Cholesky oracle; we report throughput, latency
//! percentiles, batch-size distribution and the PJRT/native routing split.
//!
//! Requires `make artifacts` first (the Makefile dependency does this).
//!
//! Run: `cargo run --release --example serve_bif [-- <requests>]`

use gauss_bif::coordinator::{BatchPolicy, JudgeService, RoutePath, ThresholdRequest};
use gauss_bif::datasets::random_spd_exact;
use gauss_bif::linalg::Cholesky;
use gauss_bif::metrics::{MetricValue, MetricsRegistry};
use gauss_bif::runtime::GqlRuntime;
use gauss_bif::util::rng::Rng;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let n_requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(400);
    let artifacts = PathBuf::from("artifacts");

    // --- Layer check: artifacts present and loadable ---
    match GqlRuntime::load(&artifacts) {
        Ok(rt) => {
            println!(
                "runtime: platform={}, {} compiled buckets:",
                rt.platform(),
                rt.artifacts().len()
            );
            for a in rt.artifacts() {
                println!(
                    "  {:<20} n={:<4} batch={:<2} iters={:<3} pallas={}",
                    a.meta.name, a.meta.n, a.meta.batch, a.meta.iters, a.meta.pallas
                );
            }
        }
        Err(e) => {
            eprintln!("cannot load artifacts ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    }

    // --- Start the service (dedicated PJRT executor + 2 router workers) ---
    let svc =
        JudgeService::start(Some(artifacts), BatchPolicy::default(), 2).expect("valid policy");

    // --- Periodic registry reporter: every 250 ms a background thread
    // re-exports the live service counters into a MetricsRegistry and
    // prints a one-line summary — the serving-loop shape of the
    // `--telemetry` snapshot the CLI writes at exit ---
    let stop = Arc::new(AtomicBool::new(false));
    let reporter = {
        let metrics = Arc::clone(&svc.metrics);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let reg = MetricsRegistry::new();
            let mut tick = 0u64;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(250));
                tick += 1;
                metrics.export_into(&reg);
                let snap = reg.snapshot();
                if let Some(MetricValue::Counter(reqs)) = snap.get("service.requests") {
                    println!(
                        "[telemetry t+{:>4}ms] {} requests served, {} metrics in registry",
                        tick * 250,
                        reqs,
                        snap.len()
                    );
                }
            }
        })
    };

    // --- Workload: mixed-size BIF threshold judgements with oracle ---
    let mut rng = Rng::new(0xE2E);
    println!("\nsubmitting {n_requests} judgement requests (sizes 8..64)...");
    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for i in 0..n_requests {
        let n = [8, 12, 16, 24, 32, 48, 64][i % 7];
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.7, 0.3);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        // thresholds at varying hardness (some decide in 1 iteration, some
        // need many)
        let t = exact * (0.6 + 0.8 * rng.f64());
        let req = ThresholdRequest {
            a: (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect(),
            u: u.iter().map(|&x| x as f32).collect(),
            n,
            lam_min: (l1 * 0.99) as f32,
            lam_max: (ln * 1.01) as f32,
            t,
            op_key: None, // fresh operator per request: nothing to coalesce
            reorth: false,
        };
        let want = t < exact;
        pending.push((svc.submit(req), want));
    }

    let mut correct = 0usize;
    let mut pjrt_served = 0usize;
    let mut batched = 0usize;
    let mut iters_total = 0usize;
    for (rx, want) in pending {
        let resp = rx.recv().expect("response");
        if resp.decision == want {
            correct += 1;
        }
        iters_total += resp.iters;
        match resp.path {
            RoutePath::Pjrt { batch, .. } => {
                pjrt_served += 1;
                if batch > 1 {
                    batched += 1;
                }
            }
            RoutePath::Native
            | RoutePath::NativeSession { .. }
            | RoutePath::NativeEngine { .. }
            | RoutePath::NativeRace { .. } => {}
        }
    }
    let dt = t0.elapsed().as_secs_f64();

    println!("\n=== end-to-end results ===");
    println!(
        "throughput : {:.0} judgements/s ({n_requests} in {dt:.3}s)",
        n_requests as f64 / dt
    );
    println!(
        "correctness: {correct}/{n_requests} decisions match the dense-Cholesky oracle"
    );
    println!(
        "routing    : {pjrt_served} via PJRT artifacts ({batched} co-batched), {} native",
        n_requests - pjrt_served
    );
    println!(
        "efficiency : {:.1} quadrature iterations per decision on average",
        iters_total as f64 / n_requests as f64
    );
    println!("metrics    : {}", svc.metrics.summary());

    // final registry snapshot after the reporter loop winds down
    stop.store(true, Ordering::Relaxed);
    reporter.join().expect("reporter thread panicked");
    let reg = MetricsRegistry::new();
    svc.metrics.export_into(&reg);
    println!(
        "registry   : {} metrics exported under service.*",
        reg.snapshot().len()
    );
    svc.shutdown();

    assert_eq!(correct, n_requests, "all decisions must be oracle-correct");
    assert!(pjrt_served > 0, "PJRT path must have served requests");
    println!("\nserve_bif OK — Pallas kernel → JAX scan → HLO → PJRT → coordinator all compose");
}
