//! Figure 2 — running time + speedup on synthetic sparse matrices
//! (paper §5.3.1): (k-)DPP on 5000×5000 and double greedy on 2000×2000,
//! density swept 1e-3 … 1e-1; DPP initialized at |Y| = N/3, times averaged
//! over chain iterations.
//!
//! Methodology note (documented in EXPERIMENTS.md): the baseline's dense
//! Cholesky costs O((N/3)³) *per step*, so we measure it over
//! `baseline_steps ≪ chain_iters` steps and report per-step time; the
//! quadrature variant is measured over `gauss_steps` steps. Both report
//! seconds/step exactly as the paper's Fig. 2 y-axis does. With
//! `RunConfig::dataset_scale > 1` the matrix sizes shrink by that factor
//! (shape-preserving; recorded alongside the numbers).

use crate::apps::{BifStrategy, DgConfig, DppConfig, DppSampler, KdppConfig, KdppSampler};
use crate::config::RunConfig;
use crate::datasets::random_sparse_spd;
use crate::experiments::time_secs;
use crate::util::rng::Rng;

/// One (algorithm, density) measurement.
#[derive(Clone, Debug)]
pub struct Fig2Row {
    pub algo: &'static str,
    pub n: usize,
    pub density: f64,
    /// seconds per chain step (DPP/kDPP) or per element (DG)
    pub baseline_s: f64,
    pub gauss_s: f64,
    pub speedup: f64,
    pub gauss_avg_judge_iters: f64,
}

/// Densities the paper sweeps.
pub const DENSITIES: [f64; 5] = [1e-3, 3e-3, 1e-2, 3e-2, 1e-1];

/// Steps used to time each variant (per-step times are what's reported).
#[derive(Clone, Copy, Debug)]
pub struct Fig2Budget {
    pub baseline_steps: usize,
    pub gauss_steps: usize,
    pub dg_baseline_elems: usize,
}

impl Default for Fig2Budget {
    fn default() -> Self {
        Fig2Budget { baseline_steps: 5, gauss_steps: 300, dg_baseline_elems: 5 }
    }
}

pub fn run(cfg: &RunConfig, budget: Fig2Budget, densities: &[f64]) -> Vec<Fig2Row> {
    let scale = cfg.dataset_scale.max(1);
    let n_dpp = 5000 / scale;
    let n_dg = 2000 / scale;
    let mut rows = Vec::new();
    let mut rng = Rng::new(cfg.seed ^ 0xF162);

    for &density in densities {
        // --- DPP / kDPP ---
        let (l, w) = random_sparse_spd(&mut rng, n_dpp, density, 1e-2);
        let l = std::sync::Arc::new(l);
        let k = n_dpp / 3;

        // DPP baseline (exact Cholesky per decision)
        let mut r = rng.fork();
        let cfg_b = DppConfig::new(BifStrategy::Exact, w).with_init_size(k);
        let mut s_b = DppSampler::new(&l, cfg_b, &mut r);
        let (_, t_b) = time_secs(|| s_b.run(budget.baseline_steps, &mut r));
        let base_per_step = t_b / budget.baseline_steps as f64;

        // DPP quadrature
        let mut r = rng.fork();
        let cfg_g = DppConfig::new(BifStrategy::Gauss, w).with_init_size(k);
        let mut s_g = DppSampler::new(&l, cfg_g, &mut r);
        let (_, t_g) = time_secs(|| s_g.run(budget.gauss_steps, &mut r));
        let gauss_per_step = t_g / budget.gauss_steps as f64;
        rows.push(Fig2Row {
            algo: "dpp",
            n: n_dpp,
            density,
            baseline_s: base_per_step,
            gauss_s: gauss_per_step,
            speedup: base_per_step / gauss_per_step,
            gauss_avg_judge_iters: s_g.stats.judge_iters_total as f64
                / s_g.stats.decisions.max(1) as f64,
        });

        // kDPP baseline
        let mut r = rng.fork();
        let mut s_b = KdppSampler::new(&l, KdppConfig::new(BifStrategy::Exact, w, k), &mut r);
        let (_, t_b) = time_secs(|| s_b.run(budget.baseline_steps, &mut r));
        let base_per_step = t_b / budget.baseline_steps as f64;

        // kDPP quadrature
        let mut r = rng.fork();
        let mut s_g = KdppSampler::new(&l, KdppConfig::new(BifStrategy::Gauss, w, k), &mut r);
        let (_, t_g) = time_secs(|| s_g.run(budget.gauss_steps, &mut r));
        let gauss_per_step = t_g / budget.gauss_steps as f64;
        rows.push(Fig2Row {
            algo: "kdpp",
            n: n_dpp,
            density,
            baseline_s: base_per_step,
            gauss_s: gauss_per_step,
            speedup: base_per_step / gauss_per_step,
            gauss_avg_judge_iters: s_g.stats.judge_iters_total as f64
                / s_g.stats.steps.max(1) as f64,
        });

        // --- double greedy (2000², per-element times) ---
        let (l, w) = random_sparse_spd(&mut rng, n_dg, density, 1e-2);
        let l = std::sync::Arc::new(l);
        let mut r = rng.fork();
        // full ground set in Y, but only the first few elements processed:
        // the Y-side Cholesky at |Y| ≈ n dominates every step of the real
        // baseline, so the per-element extrapolation is representative
        // (if anything it *under*-states the baseline by the X-side cost).
        let cfg_b =
            DgConfig::new(BifStrategy::Exact, w).with_stop_after(budget.dg_baseline_elems);
        let (_, t_b) = time_secs(|| crate::apps::double_greedy(&l, cfg_b, &mut r));
        let base_per_elem = t_b / budget.dg_baseline_elems as f64;

        let mut r = rng.fork();
        let cfg_g = DgConfig::new(BifStrategy::Gauss, w);
        let (res_g, t_g) = time_secs(|| crate::apps::double_greedy(&l, cfg_g, &mut r));
        let gauss_per_elem = t_g / n_dg as f64;
        rows.push(Fig2Row {
            algo: "dg",
            n: n_dg,
            density,
            baseline_s: base_per_elem,
            gauss_s: gauss_per_elem,
            speedup: base_per_elem / gauss_per_elem,
            gauss_avg_judge_iters: res_g.judge_iters_total as f64 / n_dg as f64,
        });
    }
    rows
}

pub const CSV_HEADER: [&str; 7] = [
    "algo", "n", "density", "baseline_s_per_step", "gauss_s_per_step", "speedup",
    "gauss_avg_judge_iters",
];

pub fn csv_rows(rows: &[Fig2Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                r.algo.to_string(),
                r.n.to_string(),
                format!("{:e}", r.density),
                format!("{:.6e}", r.baseline_s),
                format!("{:.6e}", r.gauss_s),
                format!("{:.2}", r.speedup),
                format!("{:.2}", r.gauss_avg_judge_iters),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_produces_speedups() {
        // session-scale smoke: 1/20th size, 2 densities
        let cfg = RunConfig { seed: 3, dataset_scale: 20, ..Default::default() };
        let budget = Fig2Budget { baseline_steps: 3, gauss_steps: 30, dg_baseline_elems: 3 };
        let rows = run(&cfg, budget, &[1e-2, 1e-1]);
        assert_eq!(rows.len(), 6);
        for r in &rows {
            assert!(r.baseline_s > 0.0 && r.gauss_s > 0.0);
            assert!(r.speedup.is_finite());
        }
        // the paper's headline: quadrature wins clearly on sparse DPP at
        // this size class
        let dpp_sparse = rows.iter().find(|r| r.algo == "dpp").unwrap();
        assert!(
            dpp_sparse.speedup > 1.0,
            "expected speedup, got {}",
            dpp_sparse.speedup
        );
    }
}
