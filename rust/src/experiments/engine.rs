//! Multi-operator streaming-engine sweep (ISSUE 5): the cross-operator
//! workloads the engine was built for — double greedy's Δ⁺/Δ⁻ sides, a
//! pool of k-DPP chains with several live submatrix operators, and joint
//! greedy MAP over several kernels — served two ways:
//!
//! * **per-side / per-operator sequential** — the pre-engine shape: one
//!   operator advances per scheduling step (`race_dg`'s §5.2 alternation
//!   refines one side per step; each chain or kernel drains its own
//!   session to completion before the next starts);
//! * **joint** — every live operator's panel advances each engine round.
//!
//! The headline number is **panel rounds**: scheduling steps in which
//! work that could run concurrently actually does. The sequential
//! baseline spends one operator traversal per round by construction; the
//! engine spends one round per joint sweep of *all* live operators —
//! `max` over operators instead of their sum. Answers must be identical
//! (decisions, trajectories, selections), which doubles as an end-to-end
//! check of the engine's "scheduler, not a numeric path" invariant.

use crate::apps::dpp::{greedy_map, greedy_map_multi, greedy_map_stats, GreedyConfig};
use crate::apps::kdpp::{step_chains, KdppConfig, KdppSampler};
use crate::apps::BifStrategy;
use crate::config::RunConfig;
use crate::experiments::race::gapped_kernel;
use crate::quadrature::engine::{race_dg_joint, DgSideSpec, Engine, EngineConfig};
use crate::quadrature::race::{race_dg, RacePolicy};
use crate::quadrature::{is_zero, GqlOptions};
use crate::sparse::{Csr, SpectrumBounds};
use crate::util::rng::Rng;
use std::sync::Arc;

/// One sweep row: the three cross-operator workloads at one problem size
/// and chain count.
#[derive(Clone, Debug)]
pub struct EngineReport {
    pub n: usize,
    /// double-greedy inclusion tests raced
    pub dg_elements: usize,
    /// operator traversals of the §5.2 per-side alternation (one side
    /// advances per step — the sequential baseline)
    pub dg_sequential_rounds: usize,
    /// joint engine rounds (both sides advance per round)
    pub dg_joint_rounds: usize,
    pub dg_saved_frac: f64,
    /// chains in the k-DPP pool (each owns its own kernel/operator)
    pub kdpp_chains: usize,
    /// proposals per chain
    pub kdpp_steps: usize,
    /// Σ over chains of solo engine rounds (one chain at a time)
    pub kdpp_sequential_rounds: usize,
    /// joint pool engine rounds (every chain's compare advances per round)
    pub kdpp_joint_rounds: usize,
    pub kdpp_saved_frac: f64,
    /// kernels in the joint greedy MAP workload
    pub greedy_kernels: usize,
    /// Σ over kernels of solo greedy panel sweeps
    pub greedy_sequential_rounds: usize,
    /// joint engine rounds across all kernels' greedy races
    pub greedy_joint_rounds: usize,
    /// every decision/trajectory/selection identical to sequential (must
    /// be true)
    pub identical: bool,
}

fn saved(seq: usize, joint: usize) -> f64 {
    if seq > 0 {
        seq.saturating_sub(joint) as f64 / seq as f64
    } else {
        0.0
    }
}

/// Workload A — double greedy's Δ⁺/Δ⁻ comparison race: random
/// (X, Y', i) splits of one kernel, each judged by the §5.2 alternation
/// (`race_dg`) and by per-round bracket exchange on a shared engine
/// (`race_dg_joint`). Returns (sequential rounds, joint rounds, identical).
fn dg_workload(
    rng: &mut Rng,
    l: &Csr,
    w: SpectrumBounds,
    elements: usize,
) -> (usize, usize, bool) {
    let n = l.n;
    let opts = GqlOptions::new(w.lo * 0.5, w.hi * 1.5);
    let mut seq_rounds = 0usize;
    let mut joint_rounds = 0usize;
    let mut identical = true;
    for _ in 0..elements {
        let k = 2 + rng.below(n / 2);
        let all = rng.sample_indices(n, n);
        let (xs, rest) = all.split_at(k);
        let (ys, _) = rest.split_at(1 + rng.below(rest.len() - 1));
        let i = *all.last().unwrap();
        let mut xs = xs.to_vec();
        let mut ys = ys.to_vec();
        xs.sort_unstable();
        ys.sort_unstable();
        let ax = l.principal_submatrix(&xs);
        let ay = l.principal_submatrix(&ys);
        let ux: Vec<f64> = xs.iter().map(|&m| l.get(m, i)).collect();
        let uy: Vec<f64> = ys.iter().map(|&m| l.get(m, i)).collect();
        let l_ii = l.get(i, i);
        let p = rng.f64();

        let (seq, js) = race_dg(
            Some((&ax, &ux)),
            Some((&ay, &uy)),
            l_ii,
            p,
            opts,
            opts,
            RacePolicy::Prune,
        );
        // the alternation's traversal count: its counted refinement steps
        // plus the uncounted initial step of each live side
        let live = [ux.as_slice(), uy.as_slice()]
            .iter()
            .filter(|u| !is_zero(u))
            .count();
        seq_rounds += js.iters + live;

        let mut eng = Engine::new(EngineConfig::default().with_width(1))
            .expect("static engine config is valid");
        let (joint, _) = race_dg_joint(
            &mut eng,
            Some(DgSideSpec { op: Arc::new(ax), u: ux, opts }),
            Some(DgSideSpec { op: Arc::new(ay), u: uy, opts }),
            l_ii,
            p,
            RacePolicy::Prune,
        );
        joint_rounds += eng.stats().rounds;
        identical &= seq == joint;
    }
    (seq_rounds, joint_rounds, identical)
}

/// Workload B — a pool of k-DPP chains, each on its own kernel: solo
/// stepping (reference trajectories via `KdppSampler::step`, solo engine
/// rounds via single-chain `step_chains`) vs the joint pool. Returns
/// (sequential rounds, joint rounds, identical).
fn kdpp_workload(
    rng: &mut Rng,
    n: usize,
    density: f64,
    chains: usize,
    steps: usize,
    ecfg: EngineConfig,
) -> (usize, usize, bool) {
    let mut kernels: Vec<(Arc<Csr>, SpectrumBounds)> = Vec::new();
    for _ in 0..chains {
        let (l, w) = crate::datasets::random_sparse_spd(rng, n, density, 0.05);
        kernels.push((Arc::new(l), w));
    }
    let k = (n / 4).clamp(2, 12);
    let seeds: Vec<u64> = (0..chains).map(|_| rng.next_u64()).collect();
    let cfg_of = |w: &SpectrumBounds| KdppConfig::new(BifStrategy::Gauss, *w, k);

    // reference trajectories: plain solo stepping (no engine at all)
    let reference: Vec<Vec<usize>> = kernels
        .iter()
        .zip(&seeds)
        .map(|((l, w), &s)| {
            let mut r = Rng::new(s);
            let mut smp = KdppSampler::new(l, cfg_of(w), &mut r);
            smp.run(steps, &mut r);
            smp.current_set().to_vec()
        })
        .collect();

    // sequential engine baseline: one chain at a time
    let mut seq_rounds = 0usize;
    let mut identical = true;
    for (ci, ((l, w), &s)) in kernels.iter().zip(&seeds).enumerate() {
        let mut r = vec![Rng::new(s)];
        let mut pool = vec![KdppSampler::new(l, cfg_of(w), &mut r[0])];
        for _ in 0..steps {
            seq_rounds += step_chains(&mut pool, &mut r, ecfg).expect("validated knobs");
        }
        identical &= pool[0].current_set() == reference[ci].as_slice();
    }

    // joint pool: every chain's swap test advances per engine round
    let mut rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
    let mut pool: Vec<KdppSampler> = kernels
        .iter()
        .zip(rngs.iter_mut())
        .map(|((l, w), r)| KdppSampler::new(l, cfg_of(w), r))
        .collect();
    let mut joint_rounds = 0usize;
    for _ in 0..steps {
        joint_rounds += step_chains(&mut pool, &mut rngs, ecfg).expect("validated knobs");
    }
    for (c, want) in pool.iter().zip(&reference) {
        identical &= c.current_set() == want.as_slice();
    }
    (seq_rounds, joint_rounds, identical)
}

/// Workload C — joint greedy MAP over several gapped kernels vs each
/// kernel's solo `greedy_map`. Returns (sequential panel sweeps, joint
/// rounds, identical).
fn greedy_workload(
    rng: &mut Rng,
    n: usize,
    density: f64,
    kernels: usize,
    k: usize,
    width: usize,
    ecfg: EngineConfig,
) -> (usize, usize, bool) {
    let mut ops: Vec<(Arc<Csr>, SpectrumBounds)> = Vec::new();
    for _ in 0..kernels {
        let (l, w) = gapped_kernel(rng, n, density, (2 * k).min(n), 50.0);
        ops.push((Arc::new(l), w));
    }
    let window = ops.iter().fold(
        SpectrumBounds { lo: f64::INFINITY, hi: 0.0 },
        |acc, (_, w)| SpectrumBounds { lo: acc.lo.min(w.lo), hi: acc.hi.max(w.hi) },
    );
    let cfg = GreedyConfig::new(window, k).with_block_width(width);
    let mut seq_rounds = 0usize;
    let mut solo: Vec<Vec<usize>> = Vec::new();
    for (l, _) in &ops {
        let (sel, stats) = greedy_map_stats(l, &cfg);
        seq_rounds += stats.sweeps;
        solo.push(sel);
    }
    let refs: Vec<Arc<Csr>> = ops.iter().map(|(l, _)| Arc::clone(l)).collect();
    let (joint, joint_rounds) =
        greedy_map_multi(&refs, &cfg, ecfg).expect("engine knobs validated at admission");
    let mut identical = joint == solo;
    // sanity: greedy_map and greedy_map_stats agree (same entry point)
    identical &= refs
        .iter()
        .zip(&solo)
        .all(|(l, sel)| greedy_map(l, &cfg) == *sel);
    (seq_rounds, joint_rounds, identical)
}

pub fn run_one(
    rng: &mut Rng,
    n: usize,
    density: f64,
    chains: usize,
    ecfg: EngineConfig,
) -> EngineReport {
    let (l, w) = crate::datasets::random_sparse_spd(rng, n, density, 0.05);
    let dg_elements = 12usize.min(n / 2);
    let (dg_seq, dg_joint, dg_ok) = dg_workload(rng, &l, w, dg_elements);

    let kdpp_steps = 15usize;
    let (kd_seq, kd_joint, kd_ok) =
        kdpp_workload(rng, (n / 2).max(16), density * 2.0, chains.max(2), kdpp_steps, ecfg);

    let gk = 3usize;
    let (gr_seq, gr_joint, gr_ok) = greedy_workload(
        rng,
        (n / 2).max(24),
        (density * 2.0).min(0.3),
        gk,
        6.min(n / 4).max(2),
        ecfg.width,
        ecfg,
    );

    EngineReport {
        n,
        dg_elements,
        dg_sequential_rounds: dg_seq,
        dg_joint_rounds: dg_joint,
        dg_saved_frac: saved(dg_seq, dg_joint),
        kdpp_chains: chains.max(2),
        kdpp_steps,
        kdpp_sequential_rounds: kd_seq,
        kdpp_joint_rounds: kd_joint,
        kdpp_saved_frac: saved(kd_seq, kd_joint),
        greedy_kernels: gk,
        greedy_sequential_rounds: gr_seq,
        greedy_joint_rounds: gr_joint,
        identical: dg_ok && kd_ok && gr_ok,
    }
}

/// Sweep chain-pool sizes `chain_counts` on one problem size; the size
/// shrinks with `dataset_scale` for session-budget (and CI smoke) runs.
pub fn run(cfg: &RunConfig, chain_counts: &[usize]) -> Vec<EngineReport> {
    let mut rng = Rng::new(cfg.seed ^ 0xE61);
    let n = (800 / cfg.dataset_scale.max(1)).max(32);
    let density = 0.08_f64.max(8.0 / (n as f64 * n as f64));
    let ecfg = cfg.engine_config();
    chain_counts
        .iter()
        .map(|&c| run_one(&mut rng, n, density, c.clamp(2, 16), ecfg))
        .collect()
}

pub const CSV_HEADER: [&str; 13] = [
    "n",
    "dg_elements",
    "dg_sequential_rounds",
    "dg_joint_rounds",
    "dg_saved_frac",
    "kdpp_chains",
    "kdpp_steps",
    "kdpp_sequential_rounds",
    "kdpp_joint_rounds",
    "kdpp_saved_frac",
    "greedy_sequential_rounds",
    "greedy_joint_rounds",
    "identical",
];

pub fn csv_rows(reports: &[EngineReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                r.dg_elements.to_string(),
                r.dg_sequential_rounds.to_string(),
                r.dg_joint_rounds.to_string(),
                format!("{:.3}", r.dg_saved_frac),
                r.kdpp_chains.to_string(),
                r.kdpp_steps.to_string(),
                r.kdpp_sequential_rounds.to_string(),
                r.kdpp_joint_rounds.to_string(),
                format!("{:.3}", r.kdpp_saved_frac),
                r.greedy_sequential_rounds.to_string(),
                r.greedy_joint_rounds.to_string(),
                r.identical.to_string(),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_workloads_are_identical_and_save_rounds() {
        let mut rng = Rng::new(0xE611);
        let rep = run_one(&mut rng, 48, 0.1, 3, EngineConfig::default());
        assert!(rep.identical, "a joint workload diverged from sequential");
        assert!(
            rep.dg_joint_rounds < rep.dg_sequential_rounds,
            "joint DG race must finish in fewer rounds ({} vs {})",
            rep.dg_joint_rounds,
            rep.dg_sequential_rounds
        );
        assert!(
            rep.kdpp_joint_rounds < rep.kdpp_sequential_rounds,
            "joint k-DPP pool must finish in fewer rounds ({} vs {})",
            rep.kdpp_joint_rounds,
            rep.kdpp_sequential_rounds
        );
    }

    #[test]
    fn scaled_run_produces_a_row_per_chain_count() {
        let cfg = RunConfig { dataset_scale: 20, ..Default::default() };
        let rows = run(&cfg, &[2, 3]);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.identical));
    }
}
