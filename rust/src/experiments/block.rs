//! Scalar-vs-block wall-clock sweep: `k` independent GQL runs against one
//! shared sparse operator versus a single `BlockGql` run at the same
//! fixed iteration count (rates-style driver: structured rows + CSV).
//!
//! Because the block engine's per-lane arithmetic is bit-identical to the
//! scalar engine, `max_dev` must be exactly zero — the sweep doubles as an
//! end-to-end equivalence check while it measures the panel speedup.

use crate::config::RunConfig;
use crate::datasets::random_sparse_spd;
use crate::experiments::time_secs;
use crate::quadrature::{block_solve, run_scalar, GqlOptions, Reorth, StopRule};
use crate::util::rng::Rng;

/// One sweep row: `k` queries of `iters` iterations each, scalar vs a
/// width-`width` block run.
#[derive(Clone, Debug)]
pub struct BlockReport {
    pub n: usize,
    pub density: f64,
    pub nnz: usize,
    pub k: usize,
    pub width: usize,
    pub iters: usize,
    pub scalar_s: f64,
    pub block_s: f64,
    pub speedup: f64,
    /// max |gauss_block − gauss_scalar| over all queries (must be 0.0)
    pub max_dev: f64,
}

pub fn run_one(
    rng: &mut Rng,
    n: usize,
    density: f64,
    k: usize,
    width: usize,
    iters: usize,
    reorth: Reorth,
) -> BlockReport {
    let (a, w) = random_sparse_spd(rng, n, density, 1e-2);
    let opts = GqlOptions::new(w.lo, w.hi).with_reorth(reorth);
    let stop = StopRule::Iters(iters);
    let queries: Vec<Vec<f64>> = (0..k)
        .map(|_| (0..n).map(|_| rng.normal()).collect())
        .collect();

    let (scalar_res, scalar_s) = time_secs(|| {
        queries
            .iter()
            .map(|u| run_scalar(&a, u, opts, stop, false))
            .collect::<Vec<_>>()
    });
    let (block_res, block_s) = time_secs(|| {
        block_solve(&a, opts, width, queries.iter().map(|u| (u.as_slice(), stop)))
    });

    let max_dev = scalar_res
        .iter()
        .zip(&block_res)
        .map(|(s, b)| (s.bounds.gauss - b.bounds.gauss).abs())
        .fold(0.0f64, f64::max);
    BlockReport {
        n,
        density,
        nnz: a.nnz(),
        k,
        width,
        iters,
        scalar_s,
        block_s,
        speedup: scalar_s / block_s.max(1e-12),
        max_dev,
    }
}

/// Sweep query counts `ks` at the configured `block_width` (and
/// `cfg.reorth` mode — the bit-identity check covers §5.4 runs too);
/// problem size shrinks with `dataset_scale` for session-budget runs.
pub fn run(cfg: &RunConfig, ks: &[usize]) -> Vec<BlockReport> {
    let mut rng = Rng::new(cfg.seed ^ 0xB10C);
    let n = (4000 / cfg.dataset_scale.max(1)).max(64);
    let density = 2e-3;
    let iters = 16;
    let reorth = if cfg.reorth { Reorth::Full } else { Reorth::None };
    ks.iter()
        .map(|&k| run_one(&mut rng, n, density, k, cfg.block_width.max(1), iters, reorth))
        .collect()
}

pub const CSV_HEADER: [&str; 10] = [
    "n", "density", "nnz", "k", "width", "iters", "scalar_s", "block_s", "speedup", "max_dev",
];

pub fn csv_rows(reports: &[BlockReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                format!("{:.1e}", r.density),
                r.nnz.to_string(),
                r.k.to_string(),
                r.width.to_string(),
                r.iters.to_string(),
                format!("{:.4e}", r.scalar_s),
                format!("{:.4e}", r.block_s),
                format!("{:.2}", r.speedup),
                format!("{:.1e}", r.max_dev),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_rows_are_exact_and_well_formed() {
        let mut rng = Rng::new(0xB10D);
        let rep = run_one(&mut rng, 128, 0.05, 8, 4, 6, Reorth::None);
        assert_eq!(rep.k, 8);
        assert_eq!(rep.width, 4);
        assert!(rep.scalar_s > 0.0 && rep.block_s > 0.0);
        // bit-identical lanes: the deviation is exactly zero, not just small
        assert_eq!(rep.max_dev, 0.0);
    }

    #[test]
    fn reorth_rows_stay_bit_exact() {
        // the §5.4 mode preserves the scalar/block exactness contract
        let mut rng = Rng::new(0xB10E);
        let rep = run_one(&mut rng, 96, 0.05, 6, 3, 6, Reorth::Full);
        assert_eq!(rep.max_dev, 0.0);
    }

    #[test]
    fn scaled_run_produces_a_row_per_k() {
        let cfg = RunConfig { dataset_scale: 40, block_width: 4, ..Default::default() };
        let rows = run(&cfg, &[2, 4]);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.max_dev == 0.0));
    }
}
