//! Stochastic Lanczos quadrature validation sweep: on sparse SPD
//! reference instances small enough to densify, drive
//! [`Query::Trace`]`{f: Inverse}` and [`Query::LogDet`] through the
//! streaming engine and compare the reported **combined interval**
//! (deterministic quadrature envelope ⊕ Monte-Carlo t-interval) against
//! the exact value from a dense Cholesky oracle.
//!
//! Two contracts gate the run:
//! * **containment** — the exact trace/logdet lies inside the combined
//!   interval (checked with a 4× guard band about its midpoint, so the
//!   95% confidence interval gates at an effective ≫99.99% level and a
//!   pinned-seed CI run cannot flake);
//! * **determinism** — under a pinned [`SlqConfig`] seed the whole
//!   report is bit-identical across worker counts {1, 2, 4} and both
//!   [`SweepMode`]s. Probes are seeded per-index at submission, so
//!   scheduling must not leak into the estimate; this sweep is the
//!   end-to-end proof.

use crate::config::RunConfig;
use crate::datasets::random_sparse_spd;
use crate::linalg::Cholesky;
use crate::quadrature::engine::{Engine, EngineConfig, SweepMode};
use crate::quadrature::query::{Answer, Query};
use crate::quadrature::stochastic::{SlqConfig, SpectralFn, StochasticReport};
use crate::quadrature::GqlOptions;
use crate::sparse::{Csr, SymOp};
use crate::util::rng::Rng;
use std::sync::Arc;

/// One validated query: an `n`-dim instance, one spectral sum, one
/// stochastic answer checked against the dense oracle.
#[derive(Clone, Debug)]
pub struct SlqReport {
    pub n: usize,
    pub nnz: usize,
    /// which spectral sum: `"trace_inv"` or `"logdet"`
    pub kind: &'static str,
    pub probes: usize,
    pub tol: f64,
    /// stochastic point estimate (mean of bracket midpoints)
    pub estimate: f64,
    /// combined interval endpoints
    pub lo: f64,
    pub hi: f64,
    /// dense-Cholesky oracle value
    pub exact: f64,
    /// |estimate − exact| / max(|exact|, 1)
    pub rel_err: f64,
    /// exact inside the 4×-guarded combined interval (must be true)
    pub contained: bool,
    pub tol_met: bool,
    pub retired_early: usize,
    /// total Lanczos iterations across every probe lane
    pub iters: usize,
    /// report bit-identical across workers {1,2,4} × both sweep modes
    pub deterministic: bool,
}

/// Drive one stochastic query through a fresh engine with the given
/// scheduling shape.
fn run_query(
    a: &Arc<Csr>,
    opts: GqlOptions,
    q: &Query,
    workers: usize,
    mode: SweepMode,
) -> StochasticReport {
    let cfg = EngineConfig::default().with_workers(workers).with_sweep_mode(mode);
    let mut eng = Engine::new(cfg).expect("slq engine config is valid");
    let t = eng.submit(1, Arc::clone(a) as Arc<dyn SymOp>, opts, q.clone());
    eng.drain();
    eng.answer(t)
        .and_then(Answer::stochastic)
        .expect("stochastic queries answer stochastically")
        .clone()
}

/// Same estimate, same interval, bit for bit.
fn same_report(a: &StochasticReport, b: &StochasticReport) -> bool {
    a.estimate.to_bits() == b.estimate.to_bits()
        && a.combined.lo.to_bits() == b.combined.lo.to_bits()
        && a.combined.hi.to_bits() == b.combined.hi.to_bits()
        && a.probes_contributing == b.probes_contributing
        && a.iters == b.iters
}

fn report_for(
    a: &Arc<Csr>,
    opts: GqlOptions,
    q: &Query,
    kind: &'static str,
    slq: SlqConfig,
    exact: f64,
) -> SlqReport {
    // reference run: the engine's default shape
    let r = run_query(a, opts, q, EngineConfig::default().workers, SweepMode::Stealing);
    // scheduling must not leak into a pinned-seed answer
    let mut deterministic = true;
    for workers in [1usize, 2, 4] {
        for mode in [SweepMode::Stealing, SweepMode::Static] {
            deterministic &= same_report(&r, &run_query(a, opts, q, workers, mode));
        }
    }
    let half = r.combined.width() / 2.0;
    let slack = 1e-9 * (1.0 + exact.abs());
    let contained = (exact - r.combined.mid()).abs() <= 4.0 * half + slack;
    SlqReport {
        n: a.n,
        nnz: a.nnz(),
        kind,
        probes: slq.probes,
        tol: slq.tol,
        estimate: r.estimate,
        lo: r.combined.lo,
        hi: r.combined.hi,
        exact,
        rel_err: (r.estimate - exact).abs() / exact.abs().max(1.0),
        contained,
        tol_met: r.tol_met,
        retired_early: r.probes_retired_early,
        iters: r.iters,
        deterministic,
    }
}

/// Validate both spectral sums on one sparse SPD instance: two rows,
/// `trace_inv` then `logdet`.
pub fn run_one(rng: &mut Rng, n: usize, density: f64, slq: SlqConfig) -> Vec<SlqReport> {
    let (a, w) = random_sparse_spd(rng, n, density, 0.5);
    let opts = GqlOptions::new(w.lo, w.hi);
    let a = Arc::new(a);
    // dense oracle: tr(A⁻¹) = Σᵢ eᵢᵀA⁻¹eᵢ, logdet = 2·Σ log Lᵢᵢ
    let ch = Cholesky::factor(&a.to_dense()).expect("generator output is PD");
    let exact_tr: f64 = (0..n)
        .map(|i| {
            let mut e = vec![0.0; n];
            e[i] = 1.0;
            ch.bif(&e)
        })
        .sum();
    let exact_ld = ch.logdet();
    vec![
        report_for(
            &a,
            opts,
            &Query::Trace { f: SpectralFn::Inverse, cfg: slq },
            "trace_inv",
            slq,
            exact_tr,
        ),
        report_for(&a, opts, &Query::LogDet { cfg: slq }, "logdet", slq, exact_ld),
    ]
}

/// Sweep instance sizes; the stochastic knobs come from the run config
/// (`slq_probes` / `slq_seed` / `slq_tol`, overridable via `--slq-*`).
pub fn run(cfg: &RunConfig, sizes: &[usize]) -> Vec<SlqReport> {
    let mut rng = Rng::new(cfg.seed ^ 0x510);
    let slq = cfg.slq_config();
    sizes
        .iter()
        .flat_map(|&n| {
            let n = n.max(8);
            let density = 0.05_f64.max(8.0 / (n as f64 * n as f64));
            run_one(&mut rng, n, density, slq)
        })
        .collect()
}

pub const CSV_HEADER: [&str; 15] = [
    "n",
    "nnz",
    "kind",
    "probes",
    "tol",
    "estimate",
    "lo",
    "hi",
    "exact",
    "rel_err",
    "contained",
    "tol_met",
    "retired_early",
    "iters",
    "deterministic",
];

pub fn csv_rows(reports: &[SlqReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                r.nnz.to_string(),
                r.kind.to_string(),
                r.probes.to_string(),
                format!("{:.1e}", r.tol),
                format!("{:.9e}", r.estimate),
                format!("{:.9e}", r.lo),
                format!("{:.9e}", r.hi),
                format!("{:.9e}", r.exact),
                format!("{:.3e}", r.rel_err),
                r.contained.to_string(),
                r.tol_met.to_string(),
                r.retired_early.to_string(),
                r.iters.to_string(),
                r.deterministic.to_string(),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn both_spectral_sums_are_contained_and_deterministic() {
        let mut rng = Rng::new(0x510_0001);
        let rows = run_one(&mut rng, 40, 0.08, SlqConfig::new(12, 0x510_0002, 5e-2));
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].kind, "trace_inv");
        assert_eq!(rows[1].kind, "logdet");
        for r in &rows {
            assert!(r.contained, "{}: exact {} outside [{}, {}]", r.kind, r.exact, r.lo, r.hi);
            assert!(r.deterministic, "{}: scheduling leaked into the answer", r.kind);
            assert!(r.lo <= r.estimate && r.estimate <= r.hi);
            assert!(r.iters > 0);
        }
    }

    #[test]
    fn config_driven_run_produces_two_rows_per_size() {
        let cfg = RunConfig { slq_probes: 8, slq_tol: 5e-2, ..Default::default() };
        let rows = run(&cfg, &[24, 32]);
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.contained && r.deterministic));
    }
}
