//! Experiment drivers: one per paper table/figure (see DESIGN.md §4).
//! Each driver is a library function returning structured rows, so the
//! CLI (`gauss-bif <exp>`), the examples and the benches all regenerate
//! the same artifact; results are also written as CSV under
//! `results/`.

pub mod block;
pub mod engine;
pub mod fig1;
pub mod fig2;
pub mod race;
pub mod rates;
pub mod session;
pub mod slq;
pub mod table2;

use std::io::Write;
use std::path::Path;

/// Write rows as CSV (header + records) under `dir/name`.
pub fn write_csv(dir: &Path, name: &str, header: &[&str], rows: &[Vec<String>]) -> std::io::Result<std::path::PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    let mut f = std::fs::File::create(&path)?;
    writeln!(f, "{}", header.join(","))?;
    for r in rows {
        writeln!(f, "{}", r.join(","))?;
    }
    Ok(path)
}

/// Measure wall-clock seconds of `f` (single shot — experiment drivers
/// measure real workloads, not micro-ops).
pub fn time_secs<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("gauss_bif_csv_test");
        let p = write_csv(
            &dir,
            "t.csv",
            &["a", "b"],
            &[vec!["1".into(), "2".into()]],
        )
        .unwrap();
        let s = std::fs::read_to_string(p).unwrap();
        assert_eq!(s, "a,b\n1,2\n");
    }

    #[test]
    fn time_secs_returns_value() {
        let (v, s) = time_secs(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
