//! Mixed-session sweep: the same workload — threshold judgements, ratio
//! comparisons, estimates, and an argmax race, all against one shared
//! operator — served two ways: **sequentially** (each query in its own
//! planner session, the pre-ISSUE-4 shape where every entry point drove
//! its own loop) and **mixed** (every query compiled onto one
//! [`Session`] panel). The headline number is **panel sweeps** —
//! `matvec_multi` traversals of the shared operator, the paper-faithful
//! cost model — saved by co-scheduling; answers must be identical, which
//! doubles as an end-to-end check of the planner's answer-identity
//! guarantee.
//!
//! The kernel is *gapped* (a boosted diagonal block) so the argmax
//! decides early and the mixed panel's refill machinery is exercised.

use crate::config::RunConfig;
use crate::experiments::race::gapped_kernel;
use crate::experiments::time_secs;
use crate::quadrature::block::{run_scalar, StopRule};
use crate::quadrature::query::{Answer, Query, QueryArm, Session};
use crate::quadrature::race::RacePolicy;
use crate::quadrature::GqlOptions;
use crate::util::rng::Rng;

/// One sweep row: a mixed workload over an `n`-dim gapped kernel, served
/// sequentially vs through one shared session.
#[derive(Clone, Debug)]
pub struct SessionReport {
    pub n: usize,
    pub nnz: usize,
    /// queries in the workload
    pub queries: usize,
    /// panel lanes those queries compile to
    pub lanes: usize,
    /// panel sweeps spent serving each query in its own session
    pub sequential_sweeps: usize,
    /// panel sweeps spent by the one mixed session
    pub session_sweeps: usize,
    /// fraction of sweeps saved by co-scheduling
    pub saved_frac: f64,
    /// argmax arms evicted by dominance inside the mixed session
    pub pruned: usize,
    /// every answer identical between the two paths (must be true)
    pub identical: bool,
    pub sequential_s: f64,
    pub session_s: f64,
}

/// Panel lanes a query compiles to.
fn lane_demand(q: &Query) -> usize {
    match q {
        Query::Estimate { .. } | Query::Threshold { .. } => 1,
        Query::Compare { .. } => 2,
        Query::Argmax { arms, .. } => arms.len(),
        Query::Trace { cfg, .. } | Query::LogDet { cfg } => cfg.probes,
    }
}

/// Answer equality as the acceptance criterion defines it: decisions and
/// winners bit-equal, estimates bit-equal on their Gauss values.
fn same_answer(a: &Answer, b: &Answer) -> bool {
    match (a, b) {
        (Answer::Threshold { decision: x, .. }, Answer::Threshold { decision: y, .. }) => x == y,
        (Answer::Compare { decision: x, .. }, Answer::Compare { decision: y, .. }) => x == y,
        (Answer::Argmax { winner: x, .. }, Answer::Argmax { winner: y, .. }) => x == y,
        (Answer::Estimate { bounds: x, .. }, Answer::Estimate { bounds: y, .. }) => {
            x.gauss.to_bits() == y.gauss.to_bits()
        }
        _ => false,
    }
}

/// Build the mixed workload: 4 thresholds, 2 comparisons, 2 estimates,
/// and one `k`-arm argmax, all against the same operator.
fn build_queries(rng: &mut Rng, l: &crate::sparse::Csr, opts: GqlOptions, k: usize) -> Vec<Query> {
    let n = l.n;
    let randvec = |rng: &mut Rng| -> Vec<f64> { (0..n).map(|_| rng.normal()).collect() };
    // a cheap 2-iteration bracket midpoint puts thresholds in the right
    // decade without an exact solve
    let rough = |u: &[f64]| run_scalar(l, u, opts, StopRule::Iters(2), false).bounds.mid();
    let mut queries = Vec::new();
    for i in 0..4 {
        let u = randvec(rng);
        let t = rough(&u) * (0.6 + 0.2 * i as f64);
        queries.push(Query::Threshold { u, t });
    }
    for _ in 0..2 {
        let (u, v) = (randvec(rng), randvec(rng));
        let t = 0.5 * rough(&v) - rough(&u) + if rng.bool(0.5) { 0.2 } else { -0.2 };
        queries.push(Query::Compare { u, v, t, p: 0.5 });
    }
    for _ in 0..2 {
        queries.push(Query::Estimate { u: randvec(rng), stop: StopRule::GapRel(1e-8) });
    }
    let arms = (0..k)
        .map(|i| QueryArm {
            u: randvec(rng),
            stop: StopRule::GapRel(1e-10),
            // one clearly-boosted arm, so dominance pruning has a gap
            offset: if i == 0 { 50.0 } else { 1.0 + rng.f64() },
            scale: -1.0,
        })
        .collect();
    queries.push(Query::Argmax { arms, floor: None });
    queries
}

pub fn run_one(rng: &mut Rng, n: usize, density: f64, k: usize) -> SessionReport {
    let (l, w) = gapped_kernel(rng, n, density, (2 * k).min(n), 50.0);
    let opts = GqlOptions::new(w.lo, w.hi);
    let queries = build_queries(rng, &l, opts, k);
    let lanes: usize = queries.iter().map(lane_demand).sum();

    // sequential: each query runs in its own right-sized session — the
    // pre-redesign shape, one driver loop per entry point
    let mut sequential_sweeps = 0usize;
    let (seq_answers, sequential_s) = time_secs(|| {
        queries
            .iter()
            .map(|q| {
                let mut s = Session::new(&l, opts, lane_demand(q).max(1), RacePolicy::Prune);
                let qid = s.submit(q.clone());
                let mut answers = s.run(&l);
                sequential_sweeps += s.sweeps();
                answers.swap_remove(qid)
            })
            .collect::<Vec<_>>()
    });

    // mixed: one session, one dense panel over every lane
    let mut pruned = 0usize;
    let mut session_sweeps = 0usize;
    let (mix_answers, session_s) = time_secs(|| {
        let mut s = Session::new(&l, opts, lanes.max(1), RacePolicy::Prune);
        for q in &queries {
            s.submit(q.clone());
        }
        let answers = s.run(&l);
        let st = s.stats();
        session_sweeps = st.sweeps;
        pruned = st.pruned;
        answers
    });

    let identical = seq_answers.len() == mix_answers.len()
        && seq_answers
            .iter()
            .zip(&mix_answers)
            .all(|(a, b)| same_answer(a, b));
    let saved_frac = if sequential_sweeps > 0 {
        sequential_sweeps.saturating_sub(session_sweeps) as f64 / sequential_sweeps as f64
    } else {
        0.0
    };
    SessionReport {
        n,
        nnz: l.nnz(),
        queries: queries.len(),
        lanes,
        sequential_sweeps,
        session_sweeps,
        saved_frac,
        pruned,
        identical,
        sequential_s,
        session_s,
    }
}

/// Sweep argmax arm counts `ks` on a gapped kernel; problem size shrinks
/// with `dataset_scale` for session-budget (and CI smoke) runs.
pub fn run(cfg: &RunConfig, ks: &[usize]) -> Vec<SessionReport> {
    let mut rng = Rng::new(cfg.seed ^ 0x5E55);
    let n = (2000 / cfg.dataset_scale.max(1)).max(48);
    let density = 5e-3_f64.max(8.0 / (n as f64 * n as f64));
    ks.iter()
        .map(|&k| run_one(&mut rng, n, density, k.clamp(2, n / 2)))
        .collect()
}

pub const CSV_HEADER: [&str; 10] = [
    "n",
    "nnz",
    "queries",
    "lanes",
    "sequential_sweeps",
    "session_sweeps",
    "saved_frac",
    "pruned",
    "identical",
    "speedup",
];

pub fn csv_rows(reports: &[SessionReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                r.nnz.to_string(),
                r.queries.to_string(),
                r.lanes.to_string(),
                r.sequential_sweeps.to_string(),
                r.session_sweeps.to_string(),
                format!("{:.3}", r.saved_frac),
                r.pruned.to_string(),
                r.identical.to_string(),
                format!("{:.2}", r.sequential_s / r.session_s.max(1e-12)),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixed_session_is_identical_and_saves_sweeps() {
        let mut rng = Rng::new(0x5E551);
        let rep = run_one(&mut rng, 96, 0.03, 6);
        assert!(rep.identical, "mixed answers diverged from sequential");
        assert!(
            rep.session_sweeps < rep.sequential_sweeps,
            "co-scheduling must save sweeps (session {} vs sequential {})",
            rep.session_sweeps,
            rep.sequential_sweeps
        );
        assert!(rep.saved_frac > 0.0);
        assert_eq!(rep.queries, 9);
        assert_eq!(rep.lanes, 4 + 4 + 2 + 6);
    }

    #[test]
    fn scaled_run_produces_a_row_per_k() {
        let cfg = RunConfig { dataset_scale: 40, ..Default::default() };
        let rows = run(&cfg, &[2, 4]);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.identical));
    }
}
