//! Table 2 — running time and speedup for (k-)DPP and double greedy on the
//! six Table-1 dataset substitutes (see DESIGN.md §3 for the
//! substitutions).
//!
//! Conventions matching the paper:
//! * DPP / k-DPP rows report seconds **per chain iteration** (the paper
//!   averages over 1000 iterations); k-DPP uses k = N/3 like Fig. 2.
//! * DG rows report the **full-run** time over the ground set.
//! * `*` marks baseline runs that are infeasible (the paper's 24-hour
//!   timeouts on Epinions/Slashdot); we mark a baseline infeasible when a
//!   single measured step extrapolates beyond `baseline_timeout_s`.

use crate::apps::{BifStrategy, DgConfig, DppConfig, DppSampler, KdppConfig, KdppSampler};
use crate::config::RunConfig;
use crate::datasets::{table1_specs, DatasetSpec, RIDGE};
use crate::experiments::time_secs;
use crate::sparse::{gershgorin_bounds, Csr, SpectrumBounds};
use crate::util::rng::Rng;
use std::sync::Arc;

/// One (dataset, algorithm) cell pair of Table 2.
#[derive(Clone, Debug)]
pub struct Table2Row {
    pub dataset: &'static str,
    pub algo: &'static str,
    pub n: usize,
    pub nnz: usize,
    /// None = infeasible (the paper's "*")
    pub baseline_s: Option<f64>,
    pub gauss_s: f64,
    pub speedup: Option<f64>,
    /// §5.2 alternation judge iterations (the `dg_joint` row only —
    /// ROADMAP item 6 batches the engine experiment's double-greedy
    /// workload into this table)
    pub seq_iters: Option<usize>,
    /// joint-engine judge iterations at the decision rounds (ditto)
    pub joint_iters: Option<usize>,
}

/// Execution budget for the drivers.
#[derive(Clone, Copy, Debug)]
pub struct Table2Budget {
    /// chain steps measured for the quadrature variant
    pub gauss_steps: usize,
    /// chain steps measured for the baseline (per-step extrapolation)
    pub baseline_steps: usize,
    /// skip a baseline whose extrapolated full cost exceeds this
    pub baseline_timeout_s: f64,
    /// cap on DG ground-set size (None = full; the two large graphs use
    /// the full set only in the final recorded run)
    pub dg_limit: Option<usize>,
}

impl Default for Table2Budget {
    fn default() -> Self {
        Table2Budget {
            gauss_steps: 200,
            baseline_steps: 3,
            baseline_timeout_s: 600.0,
            dg_limit: None,
        }
    }
}

fn window_for(m: &Csr) -> SpectrumBounds {
    // all Table-1 matrices are PSD + ridge ⇒ λ_min ≥ RIDGE; Gershgorin
    // gives the right end cheaply.
    gershgorin_bounds(m).clamp_lo(RIDGE * 0.5)
}

/// Run one dataset through DPP / k-DPP / DG. `scale` divides sizes.
pub fn run_dataset(
    spec: &DatasetSpec,
    cfg: &RunConfig,
    budget: Table2Budget,
) -> Vec<Table2Row> {
    let mut rng = Rng::new(cfg.seed ^ spec.n as u64);
    let l = Arc::new(spec.build(&mut rng, cfg.dataset_scale));
    let n = l.n;
    let w = window_for(&l);
    let k = (n / 3).max(1);
    let mut rows = Vec::new();

    // --- DPP (per-step seconds) ---
    let mut r = rng.fork();
    let mut s_g = DppSampler::new(
        &l,
        DppConfig::new(BifStrategy::Gauss, w).with_init_size(k),
        &mut r,
    );
    let (_, t_g) = time_secs(|| s_g.run(budget.gauss_steps, &mut r));
    let gauss_dpp = t_g / budget.gauss_steps as f64;

    let baseline_dpp = {
        // feasibility probe: one exact decision costs O(k³)
        let flops = (k as f64).powi(3) / 3.0;
        if flops / 2e9 > budget.baseline_timeout_s {
            None
        } else {
            let mut r = rng.fork();
            let mut s_b = DppSampler::new(
                &l,
                DppConfig::new(BifStrategy::Exact, w).with_init_size(k),
                &mut r,
            );
            let (_, t_b) = time_secs(|| s_b.run(budget.baseline_steps, &mut r));
            Some(t_b / budget.baseline_steps as f64)
        }
    };
    rows.push(Table2Row {
        dataset: spec.name,
        algo: "dpp",
        n,
        nnz: l.nnz(),
        baseline_s: baseline_dpp,
        gauss_s: gauss_dpp,
        speedup: baseline_dpp.map(|b| b / gauss_dpp),
        seq_iters: None,
        joint_iters: None,
    });

    // --- kDPP (per-step seconds) ---
    let mut r = rng.fork();
    let mut s_g = KdppSampler::new(&l, KdppConfig::new(BifStrategy::Gauss, w, k), &mut r);
    let (_, t_g) = time_secs(|| s_g.run(budget.gauss_steps, &mut r));
    let gauss_kdpp = t_g / budget.gauss_steps as f64;
    let baseline_kdpp = {
        let flops = (k as f64).powi(3) / 3.0;
        if flops / 2e9 > budget.baseline_timeout_s {
            None
        } else {
            let mut r = rng.fork();
            let mut s_b =
                KdppSampler::new(&l, KdppConfig::new(BifStrategy::Exact, w, k), &mut r);
            let (_, t_b) = time_secs(|| s_b.run(budget.baseline_steps, &mut r));
            Some(t_b / budget.baseline_steps as f64)
        }
    };
    rows.push(Table2Row {
        dataset: spec.name,
        algo: "kdpp",
        n,
        nnz: l.nnz(),
        baseline_s: baseline_kdpp,
        gauss_s: gauss_kdpp,
        speedup: baseline_kdpp.map(|b| b / gauss_kdpp),
        seq_iters: None,
        joint_iters: None,
    });

    // --- DG (full-run seconds) ---
    let dg_n = budget.dg_limit.map_or(n, |lim| lim.min(n));
    let r_dg = rng.fork();
    let mut r = r_dg.clone();
    let mut cfg_g = DgConfig::new(BifStrategy::Gauss, w);
    if dg_n < n {
        cfg_g = cfg_g.with_limit(dg_n);
    }
    let (res_seq, t_g) = time_secs(|| crate::apps::double_greedy(&l, cfg_g, &mut r));
    let gauss_dg = t_g;

    let baseline_dg = {
        // Y-side Cholesky is O(n³) per element → n⁴ total
        let flops = (dg_n as f64).powi(3) / 3.0 * budget.baseline_steps as f64;
        if flops / 2e9 > budget.baseline_timeout_s {
            None
        } else {
            let mut r = rng.fork();
            // full Y, first few elements only (see fig2.rs methodology note)
            let cfg_b = DgConfig::new(BifStrategy::Exact, w)
                .with_stop_after(budget.baseline_steps.min(dg_n));
            let (_, t_b) = time_secs(|| crate::apps::double_greedy(&l, cfg_b, &mut r));
            // extrapolate per-element cost to the full ground set
            Some(t_b / budget.baseline_steps as f64 * dg_n as f64)
        }
    };
    rows.push(Table2Row {
        dataset: spec.name,
        algo: "dg",
        n: dg_n,
        nnz: l.nnz(),
        baseline_s: baseline_dg,
        gauss_s: gauss_dg,
        speedup: baseline_dg.map(|b| b / gauss_dg),
        seq_iters: None,
        joint_iters: None,
    });

    // --- DG, joint engine scheduling (ROADMAP item 6): the engine
    // experiment's joint-vs-alternation comparison on the paper's
    // datasets. Same seed as the alternation run, so the two walks make
    // identical decisions and the iteration counts compare like for like
    // (baseline column = the §5.2 alternation's wall time). ---
    let mut r = r_dg.clone();
    let (res_joint, t_j) =
        time_secs(|| crate::apps::double_greedy(&l, cfg_g.with_joint(true), &mut r));
    debug_assert_eq!(res_seq.chosen, res_joint.chosen, "joint DG diverged");
    rows.push(Table2Row {
        dataset: spec.name,
        algo: "dg_joint",
        n: dg_n,
        nnz: l.nnz(),
        baseline_s: Some(t_g),
        gauss_s: t_j,
        speedup: Some(t_g / t_j),
        seq_iters: Some(res_seq.judge_iters_total),
        joint_iters: Some(res_joint.judge_iters_total),
    });
    rows
}

/// Run all six substitutes (or a `skip..skip+limit` window — the two
/// large graphs use a different budget, so the launcher runs them as a
/// second pass).
pub fn run(cfg: &RunConfig, budget: Table2Budget, limit: usize) -> Vec<Table2Row> {
    run_window(cfg, budget, 0, limit)
}

/// Run datasets `skip .. skip+limit`.
pub fn run_window(
    cfg: &RunConfig,
    budget: Table2Budget,
    skip: usize,
    limit: usize,
) -> Vec<Table2Row> {
    table1_specs()
        .iter()
        .skip(skip)
        .take(limit)
        .flat_map(|spec| run_dataset(spec, cfg, budget))
        .collect()
}

pub const CSV_HEADER: [&str; 9] = [
    "dataset", "algo", "n", "nnz", "baseline_s", "gauss_s", "speedup", "seq_iters",
    "joint_iters",
];

pub fn csv_rows(rows: &[Table2Row]) -> Vec<Vec<String>> {
    rows.iter()
        .map(|r| {
            vec![
                r.dataset.to_string(),
                r.algo.to_string(),
                r.n.to_string(),
                r.nnz.to_string(),
                r.baseline_s.map_or("*".into(), |b| format!("{b:.6e}")),
                format!("{:.6e}", r.gauss_s),
                r.speedup.map_or("*".into(), |s| format!("{s:.1}")),
                r.seq_iters.map_or("*".into(), |i| i.to_string()),
                r.joint_iters.map_or("*".into(), |i| i.to_string()),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scale_abalone_runs_and_wins() {
        let cfg = RunConfig { seed: 5, dataset_scale: 16, ..Default::default() };
        let budget = Table2Budget {
            gauss_steps: 30,
            baseline_steps: 3,
            baseline_timeout_s: 30.0,
            dg_limit: Some(60),
        };
        let rows = run_dataset(&table1_specs()[0], &cfg, budget);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.gauss_s > 0.0);
            assert_eq!(r.dataset, "Abalone");
        }
        // at 1/16 scale the dense baseline is feasible and slower
        let dpp = &rows[0];
        assert!(dpp.baseline_s.is_some());
        // the ROADMAP-6 joint row compares like for like: same seed, and
        // both iteration counters populated
        let joint = rows.iter().find(|r| r.algo == "dg_joint").expect("dg_joint row");
        assert!(joint.seq_iters.is_some() && joint.joint_iters.is_some());
        assert!(joint.baseline_s.is_some());
    }

    #[test]
    fn infeasible_baseline_marked_star() {
        // k³ probe: a huge synthetic spec with a tiny timeout
        let cfg = RunConfig { seed: 6, dataset_scale: 16, ..Default::default() };
        let budget = Table2Budget {
            gauss_steps: 10,
            baseline_steps: 2,
            baseline_timeout_s: 1e-9, // force "*"
            dg_limit: Some(30),
        };
        let rows = run_dataset(&table1_specs()[2], &cfg, budget);
        // the dg_joint row's "baseline" is the alternation run itself, so
        // it is always feasible; every exact baseline must be starred
        assert!(rows
            .iter()
            .filter(|r| r.algo != "dg_joint")
            .all(|r| r.baseline_s.is_none()));
        let csv = csv_rows(&rows);
        assert!(csv
            .iter()
            .filter(|r| r[1] != "dg_joint")
            .all(|r| r[4] == "*" && r[6] == "*"));
    }
}
