//! Racing-vs-exhaustive greedy MAP sweep: the same selection computed
//! with [`RacePolicy::Exhaustive`] (every candidate refined to `tol_rel`
//! each round — the pre-racing behavior) and [`RacePolicy::Prune`]
//! (dominated candidates evicted, rounds ending at first decision),
//! reporting **panel sweeps** for both. Sweeps — `matvec_multi`
//! traversals of the shared operator — are the paper-faithful cost model:
//! they count quadrature work directly instead of wall-clock noise.
//!
//! The kernel is *gapped*: a handful of diagonal entries are boosted so a
//! few candidates clearly dominate each round, which is where racing
//! shines (Thm. 3.3–3.4: the brackets separate long before `tol_rel`).
//! Selections must be identical across policies — the sweep doubles as an
//! end-to-end check of the race's selection-identity guarantee.

use crate::apps::dpp::{greedy_map_stats, GreedyConfig};
use crate::config::RunConfig;
use crate::experiments::time_secs;
use crate::quadrature::race::RacePolicy;
use crate::quadrature::Reorth;
use crate::sparse::{gershgorin_bounds, Csr, CsrBuilder, SpectrumBounds};
use crate::util::rng::Rng;

/// One sweep row: greedy selection of `k` elements over an `n`-dim gapped
/// kernel, exhaustive vs pruned racing at panel width `width`.
#[derive(Clone, Debug)]
pub struct RaceReport {
    pub n: usize,
    pub nnz: usize,
    pub k: usize,
    pub width: usize,
    /// panel sweeps spent by the exhaustive policy
    pub exhaustive_sweeps: usize,
    /// panel sweeps spent by the pruning policy
    pub prune_sweeps: usize,
    /// fraction of sweeps saved by pruning
    pub saved_frac: f64,
    /// candidates evicted by interval dominance (all rounds)
    pub pruned: usize,
    /// rounds decided before every surviving candidate hit `tol_rel`
    pub decided_early: usize,
    /// the two policies selected the same subset (must be true)
    pub identical: bool,
    pub exhaustive_s: f64,
    pub prune_s: f64,
}

/// Random sparse SPD kernel with the first `boosted` diagonal entries
/// multiplied by `boost`, so those candidates carry clearly-separated
/// greedy gains. Boosting a diagonal adds a PSD rank-one term, so the
/// kernel stays SPD and the refreshed Gershgorin window stays valid.
pub fn gapped_kernel(
    rng: &mut Rng,
    n: usize,
    density: f64,
    boosted: usize,
    boost: f64,
) -> (Csr, SpectrumBounds) {
    let (base, _) = crate::datasets::random_sparse_spd(rng, n, density, 1e-2);
    let mut b = CsrBuilder::new(n);
    for i in 0..n {
        for (j, v) in base.row(i) {
            if i == j && i < boosted {
                b.push(i, j, v * boost);
            } else {
                b.push(i, j, v);
            }
        }
    }
    let a = b.build();
    let w = gershgorin_bounds(&a).clamp_lo(5e-3);
    (a, w)
}

pub fn run_one(rng: &mut Rng, n: usize, density: f64, k: usize, width: usize) -> RaceReport {
    let (l, w) = gapped_kernel(rng, n, density, (2 * k).min(n), 50.0);
    let l = std::sync::Arc::new(l);
    let base = GreedyConfig::new(w, k)
        .with_block_width(width)
        .with_reorth(Reorth::None);
    let ((ex_sel, ex_stats), exhaustive_s) =
        time_secs(|| greedy_map_stats(&l, &base.with_race(RacePolicy::Exhaustive)));
    let ((pr_sel, pr_stats), prune_s) =
        time_secs(|| greedy_map_stats(&l, &base.with_race(RacePolicy::Prune)));
    let saved_frac = if ex_stats.sweeps > 0 {
        (ex_stats.sweeps.saturating_sub(pr_stats.sweeps)) as f64 / ex_stats.sweeps as f64
    } else {
        0.0
    };
    RaceReport {
        n,
        nnz: l.nnz(),
        k,
        width,
        exhaustive_sweeps: ex_stats.sweeps,
        prune_sweeps: pr_stats.sweeps,
        saved_frac,
        pruned: pr_stats.pruned,
        decided_early: pr_stats.decided_early,
        identical: ex_sel == pr_sel,
        exhaustive_s,
        prune_s,
    }
}

/// Sweep selection sizes `ks` at the configured panel width; problem size
/// shrinks with `dataset_scale` for session-budget (and CI smoke) runs.
pub fn run(cfg: &RunConfig, ks: &[usize]) -> Vec<RaceReport> {
    let mut rng = Rng::new(cfg.seed ^ 0x9ACE);
    let n = (2000 / cfg.dataset_scale.max(1)).max(48);
    let density = 5e-3_f64.max(8.0 / (n as f64 * n as f64));
    ks.iter()
        .map(|&k| run_one(&mut rng, n, density, k.min(n / 2), cfg.block_width.max(1)))
        .collect()
}

pub const CSV_HEADER: [&str; 11] = [
    "n",
    "nnz",
    "k",
    "width",
    "exhaustive_sweeps",
    "prune_sweeps",
    "saved_frac",
    "pruned",
    "decided_early",
    "identical",
    "speedup",
];

pub fn csv_rows(reports: &[RaceReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                r.nnz.to_string(),
                r.k.to_string(),
                r.width.to_string(),
                r.exhaustive_sweeps.to_string(),
                r.prune_sweeps.to_string(),
                format!("{:.3}", r.saved_frac),
                r.pruned.to_string(),
                r.decided_early.to_string(),
                r.identical.to_string(),
                format!("{:.2}", r.exhaustive_s / r.prune_s.max(1e-12)),
            ]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gapped_rows_prune_and_stay_identical() {
        let mut rng = Rng::new(0x9ACE1);
        let rep = run_one(&mut rng, 96, 0.03, 6, 8);
        assert!(rep.identical, "policies must select the same subset");
        assert!(
            rep.prune_sweeps < rep.exhaustive_sweeps,
            "gapped kernel must save sweeps (prune {} vs exhaustive {})",
            rep.prune_sweeps,
            rep.exhaustive_sweeps
        );
        assert!(rep.pruned > 0);
        assert!(rep.saved_frac > 0.0);
    }

    #[test]
    fn scaled_run_produces_a_row_per_k() {
        let cfg = RunConfig { dataset_scale: 40, block_width: 8, ..Default::default() };
        let rows = run(&cfg, &[2, 4]);
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.identical));
    }
}
