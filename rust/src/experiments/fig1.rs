//! Figure 1 — bound evolution on `u^T A^{-1} u`, `A ∈ R^{100×100}` random
//! symmetric with 10% density, λ₁ = 1e-2 (paper §4.4).
//!
//! Three window settings:
//! * (a) tight:  λ_min = λ₁ − 1e-5,   λ_max = λ_N + 1e-5
//! * (b) loose lower: λ_min ← 0.1·(λ₁ − 1e-5)   (hurts left Radau/Lobatto)
//! * (c) loose upper: λ_max ← 10·(λ_N + 1e-5)   (hurts right Radau/Lobatto)

use crate::config::RunConfig;
use crate::datasets::random_spd_exact;
use crate::linalg::Cholesky;
use crate::quadrature::{Bounds, Gql, GqlOptions};
use crate::util::rng::Rng;

/// One panel of Figure 1.
#[derive(Clone, Debug)]
pub struct Fig1Panel {
    pub name: &'static str,
    pub lam_min: f64,
    pub lam_max: f64,
    pub history: Vec<Bounds>,
    pub exact: f64,
}

impl Fig1Panel {
    /// Iterations until the Radau bracket is within `rel` of the truth.
    pub fn iters_to_rel_gap(&self, rel: f64) -> Option<usize> {
        self.history
            .iter()
            .find(|b| b.gap() <= rel * self.exact.abs())
            .map(|b| b.iter)
    }
}

/// Run all three panels; `iters` per panel (paper plots ~N).
pub fn run(cfg: &RunConfig, iters: usize) -> Vec<Fig1Panel> {
    let mut rng = Rng::new(cfg.seed);
    let n = 100;
    let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.10, 1e-2);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = Cholesky::factor(&a).unwrap().bif(&u);

    let l1m = l1 - 1e-5;
    let lnp = ln + 1e-5;
    let panels: [(&'static str, f64, f64); 3] = [
        ("a_tight", l1m, lnp),
        ("b_loose_lmin", 0.1 * l1m, lnp),
        ("c_loose_lmax", l1m, 10.0 * lnp),
    ];
    panels
        .into_iter()
        .map(|(name, lam_min, lam_max)| {
            let mut q = Gql::new(&a, &u, GqlOptions::new(lam_min, lam_max));
            let history = q.run(iters);
            Fig1Panel { name, lam_min, lam_max, history, exact }
        })
        .collect()
}

/// CSV rows: panel, iter, gauss, radau_lower, radau_upper, lobatto, exact.
pub fn csv_rows(panels: &[Fig1Panel]) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for p in panels {
        for b in &p.history {
            rows.push(vec![
                p.name.to_string(),
                b.iter.to_string(),
                format!("{:.10e}", b.gauss),
                format!("{:.10e}", b.radau_lower),
                format!("{:.10e}", b.radau_upper),
                format!("{:.10e}", b.lobatto),
                format!("{:.10e}", p.exact),
            ]);
        }
    }
    rows
}

pub const CSV_HEADER: [&str; 7] =
    ["panel", "iter", "gauss", "radau_lower", "radau_upper", "lobatto", "exact"];

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> RunConfig {
        RunConfig { seed: 0xF161, ..Default::default() }
    }

    #[test]
    fn panels_reproduce_paper_shape() {
        let panels = run(&quick_cfg(), 60);
        assert_eq!(panels.len(), 3);
        let [a, b, c] = [&panels[0], &panels[1], &panels[2]];

        // all bounds sandwich the truth in every panel
        for p in [a, b, c] {
            for bd in &p.history {
                assert!(bd.radau_lower <= p.exact * (1.0 + 1e-6), "{}", p.name);
                assert!(bd.radau_upper >= p.exact * (1.0 - 1e-6), "{}", p.name);
            }
        }
        // paper: "within 25 iterations reasonably tight bounds" (tight
        // windows); allow some slack for generator differences
        let it_a = a.iters_to_rel_gap(0.05).expect("panel a should converge");
        assert!(it_a <= 40, "panel a took {it_a} iterations");

        // (b): worse λ_min slows the *upper* bounds (left Radau) — gap at
        // a mid iteration is wider than in (a)
        let mid = 15.min(a.history.len() - 1);
        assert!(
            b.history[mid].radau_upper >= a.history[mid].radau_upper - 1e-12,
            "loose λ_min should not tighten the upper bound"
        );
        // (c): worse λ_max slows the right-Radau lower bound
        assert!(
            c.history[mid].radau_lower <= a.history[mid].radau_lower + 1e-12,
            "loose λ_max should not tighten the Radau lower bound"
        );
        // Gauss is unaffected by the window (identical in all panels)
        for i in 0..a.history.len() {
            let g = a.history[i].gauss;
            assert!((b.history[i].gauss - g).abs() <= 1e-9 * g.abs().max(1.0));
            assert!((c.history[i].gauss - g).abs() <= 1e-9 * g.abs().max(1.0));
        }
    }

    #[test]
    fn csv_shape() {
        let panels = run(&quick_cfg(), 10);
        let rows = csv_rows(&panels);
        assert_eq!(rows.len(), 30);
        assert_eq!(rows[0].len(), CSV_HEADER.len());
    }
}
