//! §4 theory validation ("THM" in DESIGN.md §4): measure the observed
//! relative errors of all four quadrature rules against the theoretical
//! envelopes of Thm. 3 (Gauss), Thm. 5 (right Radau), Thm. 8 (left Radau)
//! and Corr. 9 (Lobatto), plus the Thm. 12 CG↔GQL identity.

use crate::config::RunConfig;
use crate::datasets::random_spd_exact;
use crate::linalg::Cholesky;
use crate::metrics::{theoretical_rate, GapTrace, MetricsRegistry};
use crate::quadrature::{
    cg_solve, Answer, Engine, EngineConfig, Gql, GqlOptions, OpKey, Query, StopRule,
};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Worst observed ratio (error / theoretical bound) per rule; ≤ 1 means
/// the theorem holds on this instance.
#[derive(Clone, Debug)]
pub struct RateReport {
    pub n: usize,
    pub kappa: f64,
    pub kappa_plus: f64,
    /// Theoretical per-iteration contraction `(√κ−1)/(√κ+1)` (Thm. 3).
    pub rho: f64,
    /// Least-squares geometric rate fitted to the measured bracket-gap
    /// trajectory ([`GapTrace::fitted_rate`]); `NaN` when the run
    /// converged too fast to fit (< 3 usable points).
    pub fitted_rate: f64,
    pub worst_gauss: f64,
    pub worst_radau_lower: f64,
    pub worst_radau_upper: f64,
    pub worst_lobatto: f64,
    /// max |(g_N − g_k) − ||ε_k||²_A| / g_N over k (Thm. 12 residual)
    pub thm12_residual: f64,
}

pub fn run_one(rng: &mut Rng, n: usize) -> RateReport {
    let (a, l1, ln) = random_spd_exact(rng, n, 0.3, 0.1);
    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
    let exact = Cholesky::factor(&a).unwrap().bif(&u);
    let lam_min = l1 * 0.99;
    let lam_max = ln * 1.01;
    let kappa = ln / l1;
    let kappa_plus = ln / lam_min;
    let rho = (kappa.sqrt() - 1.0) / (kappa.sqrt() + 1.0);

    let mut q = Gql::new(&a, &u, GqlOptions::new(lam_min, lam_max));
    let hist = q.run(n - 1);

    let mut worst = [0.0f64; 4];
    for b in &hist {
        if b.exact {
            break;
        }
        let i = b.iter as i32;
        let env_lower = 2.0 * rho.powi(i);
        let env_upper = 2.0 * kappa_plus * rho.powi(i);
        let env_lobatto = 2.0 * kappa_plus * rho.powi(i - 1);
        worst[0] = worst[0].max(((exact - b.gauss) / exact) / env_lower);
        worst[1] = worst[1].max(((exact - b.radau_lower) / exact) / env_lower);
        worst[2] = worst[2].max(((b.radau_upper - exact) / exact) / env_upper);
        worst[3] = worst[3].max(((b.lobatto - exact) / exact) / env_lobatto);
    }

    // Thm. 12: ||ε_k||²_A = ||u||²([J_N^{-1}]₁₁ − [J_k^{-1}]₁₁) = g_N − g_k
    // with CG started at x₀ = 0, b = u.
    let mut thm12_residual = 0.0f64;
    let ch = Cholesky::factor(&a).unwrap();
    let xstar = ch.solve(&u);
    for k in [1usize, 2, 4, 8].into_iter().filter(|&k| k < n) {
        let cg = cg_solve(&a, &u, 0.0, k);
        // ||ε_k||²_A = ε^T A ε
        let eps: Vec<f64> = xstar.iter().zip(&cg.x).map(|(s, x)| s - x).collect();
        let mut aeps = vec![0.0; n];
        crate::sparse::SymOp::matvec(&a, &eps, &mut aeps);
        let err_a2: f64 = eps.iter().zip(&aeps).map(|(a, b)| a * b).sum();
        let gk = hist[k - 1].gauss;
        thm12_residual = thm12_residual.max(((exact - gk) - err_a2).abs() / exact);
    }

    let fitted_rate =
        GapTrace::from_history(&hist).fitted_rate().unwrap_or(f64::NAN);

    RateReport {
        n,
        kappa,
        kappa_plus,
        rho,
        fitted_rate,
        worst_gauss: worst[0],
        worst_radau_lower: worst[1],
        worst_radau_upper: worst[2],
        worst_lobatto: worst[3],
        thm12_residual,
    }
}

pub fn run(cfg: &RunConfig, sizes: &[usize]) -> Vec<RateReport> {
    let mut rng = Rng::new(cfg.seed ^ 0x7A7E5);
    sizes.iter().map(|&n| run_one(&mut rng, n)).collect()
}

pub const CSV_HEADER: [&str; 10] = [
    "n", "kappa", "kappa_plus", "rho", "fitted_rate", "worst_gauss",
    "worst_radau_lower", "worst_radau_upper", "worst_lobatto", "thm12_residual",
];

pub fn csv_rows(reports: &[RateReport]) -> Vec<Vec<String>> {
    reports
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                format!("{:.3e}", r.kappa),
                format!("{:.3e}", r.kappa_plus),
                format!("{:.4}", r.rho),
                format!("{:.4}", r.fitted_rate),
                format!("{:.4}", r.worst_gauss),
                format!("{:.4}", r.worst_radau_lower),
                format!("{:.4}", r.worst_radau_upper),
                format!("{:.4}", r.worst_lobatto),
                format!("{:.3e}", r.thm12_residual),
            ]
        })
        .collect()
}

/// Publish each report's contraction-rate comparison into `reg` as
/// `rates.n<N>.*` gauges (one group per problem size).
pub fn export_registry(reports: &[RateReport], reg: &MetricsRegistry) {
    reg.set_counter("rates.reports", reports.len() as u64);
    for r in reports {
        let p = format!("rates.n{}", r.n);
        reg.set_gauge(&format!("{p}.kappa"), r.kappa);
        reg.set_gauge(&format!("{p}.rho"), r.rho);
        reg.set_gauge(&format!("{p}.fitted_rate"), r.fitted_rate);
        reg.set_gauge(&format!("{p}.worst_gauss"), r.worst_gauss);
        reg.set_gauge(&format!("{p}.worst_lobatto"), r.worst_lobatto);
        reg.set_gauge(&format!("{p}.thm12_residual"), r.thm12_residual);
    }
}

/// Re-run the rate instances through a profiled, trace-recording
/// [`Engine`] (2 workers) so the telemetry snapshot also carries round
/// phase timings, worker busy/idle fractions, and the engine-path fitted
/// contraction rate per size — the observability half of the `rates`
/// experiment.
pub fn profile_engine(cfg: &RunConfig, sizes: &[usize], reg: &MetricsRegistry) {
    let mut rng = Rng::new(cfg.seed ^ 0x9E7E1);
    let probs: Vec<_> = sizes
        .iter()
        .map(|&n| {
            let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.3, 0.1);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            (n, Arc::new(a), l1, ln, u)
        })
        .collect();

    let ecfg = EngineConfig::default()
        .with_workers(2)
        .with_profile(true)
        .with_record_traces(true);
    let mut eng = Engine::new(ecfg).expect("default engine knobs are valid");
    let mut tickets = Vec::new();
    for (i, (n, a, l1, ln, u)) in probs.iter().enumerate() {
        let opts = GqlOptions::new(l1 * 0.99, ln * 1.01);
        let q = Query::Estimate { u: u.clone(), stop: StopRule::GapRel(1e-8) };
        tickets.push((eng.submit(i as OpKey, Arc::clone(a), opts, q), *n, ln / l1));
    }
    eng.drain();
    for (t, n, kappa) in tickets {
        let fitted = eng
            .answer(t)
            .and_then(Answer::trace)
            .and_then(GapTrace::fitted_rate);
        if let Some(rate) = fitted {
            reg.set_gauge(&format!("rates.engine.n{n}.fitted_rate"), rate);
            reg.set_gauge(&format!("rates.engine.n{n}.rho"), theoretical_rate(kappa));
        }
    }
    eng.export_into(reg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_theorem_envelopes_hold() {
        let cfg = RunConfig { seed: 0xAA, ..Default::default() };
        for rep in run(&cfg, &[24, 48, 96]) {
            assert!(rep.worst_gauss <= 1.0 + 1e-9, "Thm3 violated: {rep:?}");
            assert!(rep.worst_radau_lower <= 1.0 + 1e-9, "Thm5 violated: {rep:?}");
            assert!(rep.worst_radau_upper <= 1.0 + 1e-9, "Thm8 violated: {rep:?}");
            assert!(rep.worst_lobatto <= 1.0 + 1e-9, "Corr9 violated: {rep:?}");
            assert!(rep.thm12_residual < 1e-5, "Thm12 violated: {rep:?}");
        }
    }

    #[test]
    fn fitted_rate_stays_within_the_theoretical_contraction() {
        let cfg = RunConfig { seed: 0xAB, ..Default::default() };
        let reports = run(&cfg, &[48, 96]);
        for rep in &reports {
            assert!(rep.rho > 0.0 && rep.rho < 1.0, "bad rho: {rep:?}");
            if rep.fitted_rate.is_finite() {
                // superlinear adaptation can only beat the envelope, so the
                // fitted slope sits at or below ρ (small fit-noise slack)
                assert!(
                    rep.fitted_rate <= rep.rho * 1.05 + 0.05,
                    "measured contraction above theory: {rep:?}"
                );
                assert!(rep.fitted_rate > 0.0, "degenerate fit: {rep:?}");
            }
        }
        let reg = MetricsRegistry::new();
        export_registry(&reports, &reg);
        let snap = reg.snapshot();
        assert!(snap.get("rates.reports").is_some());
        assert!(snap.get("rates.n48.rho").is_some());
        assert!(snap.get("rates.n48.fitted_rate").is_some());
    }

    #[test]
    fn profile_engine_publishes_round_phase_and_rate_telemetry() {
        let cfg = RunConfig { seed: 0xAC, ..Default::default() };
        let reg = MetricsRegistry::new();
        profile_engine(&cfg, &[24, 32], &reg);
        let snap = reg.snapshot();
        for key in [
            "engine.rounds",
            "engine.profile.rounds",
            "engine.profile.worker_busy_frac",
            "engine.profile.worker_idle_frac",
            "engine.profile.step_ns",
            "rates.engine.n24.fitted_rate",
            "rates.engine.n24.rho",
        ] {
            assert!(snap.get(key).is_some(), "missing {key}");
        }
    }
}
