//! The judge service: request queue → router → (PJRT executor | native GQL).
//!
//! The `xla` crate's PJRT handles are not `Send`, so — exactly like a
//! single physical accelerator — one dedicated **executor thread** owns the
//! compiled artifacts; router/worker threads form batches and forward them
//! over a channel, falling back to the native GQL path when the executor
//! is absent (no artifacts) or reports an error.
//!
//! Lifecycle: [`JudgeService::start`] spawns workers (+ executor); clients
//! call [`JudgeService::submit`] (returns a receiver) or
//! [`JudgeService::judge_blocking`]. Drop/`shutdown` drains and joins.

use super::batcher::{BatchPolicy, Bucketizer};
use crate::config::run::parse_manifest;
use crate::linalg::DMat;
use crate::metrics::ServiceMetrics;
use crate::quadrature::block::{BlockGql, StopRule};
use crate::quadrature::{judge_threshold, GqlOptions, Reorth};
use crate::runtime::{BoundsHistory, GqlRuntime};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// A dense threshold-judgement query: decide `t < u^T A^{-1} u`.
#[derive(Clone, Debug)]
pub struct JudgeRequest {
    /// row-major dense symmetric matrix, `n*n`
    pub a: Vec<f32>,
    pub u: Vec<f32>,
    pub n: usize,
    pub lam_min: f32,
    pub lam_max: f32,
    pub t: f64,
    /// Same-operator coalescing key. Clients issuing many queries against
    /// one `a` (a DPP chain, a centrality sweep) tag them with a shared
    /// key; co-keyed native-path requests with equal `n` and spectrum
    /// window are drained into a single `BlockGql` run. **Contract:**
    /// requests sharing a key must carry byte-identical `a`. `None`
    /// disables coalescing for this request.
    pub op_key: Option<u64>,
    /// Fully reorthogonalize the Lanczos basis (§5.4): set for
    /// ill-conditioned operators where plain Lanczos loses bound validity.
    /// Reorth requests always take the native path (the fixed-iteration
    /// PJRT artifacts do not reorthogonalize) and only coalesce with other
    /// reorth requests (part of the coalesce key).
    pub reorth: bool,
}

/// Which path served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePath {
    /// PJRT dispatch into bucket `n` with this many co-batched requests
    Pjrt { bucket: usize, batch: usize },
    /// native rust GQL (big queries, no artifacts, or PJRT failure)
    Native,
    /// native block GQL: `batch` co-keyed requests coalesced into one
    /// shared-operator `BlockGql` run
    NativeBlock { batch: usize },
}

/// Service answer.
#[derive(Clone, Debug)]
pub struct JudgeResponse {
    pub decision: bool,
    /// quadrature iterations the decision consumed (first decisive
    /// iteration for PJRT histories)
    pub iters: usize,
    pub path: RoutePath,
}

struct Queued {
    req: JudgeRequest,
    enqueued: Instant,
    reply: Sender<JudgeResponse>,
}

/// Batch job sent to the executor thread.
struct ExecJob {
    bucket: usize,
    items: Vec<Queued>,
    /// per-item histories (None on execution failure)
    reply: Sender<(Vec<Queued>, Option<Vec<BoundsHistory>>)>,
}

struct Shared {
    queue: Mutex<Vec<Queued>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// The running service.
pub struct JudgeService {
    shared: Arc<Shared>,
    pub metrics: Arc<ServiceMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    executor: Option<std::thread::JoinHandle<()>>,
}

impl JudgeService {
    /// Start with `n_workers` routing threads. `artifacts_dir = None`
    /// forces the native path for everything.
    ///
    /// Rejects policies the drainer cannot make progress under
    /// ([`BatchPolicy::validate`]): `max_batch == 0` or
    /// `native_threshold == 0`.
    pub fn start(
        artifacts_dir: Option<PathBuf>,
        policy: BatchPolicy,
        n_workers: usize,
    ) -> Result<Self, String> {
        policy.validate()?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let metrics = Arc::new(ServiceMetrics::new());

        // Parse the manifest on this thread (cheap) so the workers know
        // the buckets; compile inside the executor thread (owns PJRT).
        let (bucketizer, exec_tx, executor) = match artifacts_dir {
            Some(dir) => {
                let manifest = std::fs::read_to_string(dir.join("manifest.json"))
                    .ok()
                    .and_then(|s| parse_manifest(&s).ok());
                match manifest {
                    Some(entries) => {
                        let sizes: Vec<usize> = entries
                            .iter()
                            .filter(|e| e.batch == 1)
                            .map(|e| e.n)
                            .collect();
                        let (tx, rx) = channel::<ExecJob>();
                        let handle = std::thread::spawn(move || executor_loop(dir, rx));
                        (Bucketizer::new(sizes), Some(tx), Some(handle))
                    }
                    None => (Bucketizer::new(vec![]), None, None),
                }
            }
            None => (Bucketizer::new(vec![]), None, None),
        };

        let exec_tx = Arc::new(Mutex::new(exec_tx));
        let bucketizer = Arc::new(bucketizer);
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                let metrics = metrics.clone();
                let bucketizer = bucketizer.clone();
                let exec_tx = exec_tx.clone();
                std::thread::spawn(move || {
                    worker_loop(shared, metrics, bucketizer, exec_tx, policy)
                })
            })
            .collect();
        Ok(JudgeService { shared, metrics, workers, executor })
    }

    /// Enqueue a request; the receiver yields exactly one response.
    pub fn submit(&self, req: JudgeRequest) -> Receiver<JudgeResponse> {
        self.metrics.requests.inc();
        let (tx, rx) = channel();
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(Queued { req, enqueued: Instant::now(), reply: tx });
        }
        // notify_all, not notify_one: besides idle workers, batch-forming
        // and coalescing drains also sleep on this condvar waiting for
        // stragglers; a single wakeup could land on a drainer the new item
        // doesn't match while an idle worker keeps sleeping.
        self.shared.cv.notify_all();
        rx
    }

    /// Submit and wait.
    pub fn judge_blocking(&self, req: JudgeRequest) -> JudgeResponse {
        self.submit(req).recv().expect("service dropped the reply")
    }

    /// Stop workers and join them.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(e) = self.executor.take() {
            // dropping all worker-held senders closes the channel; we only
            // reach here after workers joined
            let _ = e.join();
        }
    }
}

impl Drop for JudgeService {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

/// The PJRT-owning thread: compiles artifacts once, serves batch jobs.
fn executor_loop(dir: PathBuf, rx: Receiver<ExecJob>) {
    let runtime = GqlRuntime::load(&dir).ok();
    while let Ok(job) = rx.recv() {
        let result = runtime.as_ref().and_then(|rt| run_job(rt, &job));
        let _ = job.reply.send((job.items, result));
    }
}

fn run_job(rt: &GqlRuntime, job: &ExecJob) -> Option<Vec<BoundsHistory>> {
    let bucket = job.bucket;
    let items = &job.items;
    // prefer a batched artifact when >1 request shares the bucket
    let batched = if items.len() > 1 {
        rt.artifacts()
            .iter()
            .find(|a| a.meta.batch >= items.len() && a.meta.n == bucket)
    } else {
        None
    };
    match batched {
        Some(art) => {
            let (n, b) = (art.meta.n, art.meta.batch);
            let mut a = Vec::with_capacity(b * n * n);
            let mut u = Vec::with_capacity(b * n);
            let mut lo = Vec::with_capacity(b);
            let mut hi = Vec::with_capacity(b);
            for item in items {
                let (ap, up) = GqlRuntime::pad_query(&item.req.a, &item.req.u, item.req.n, n);
                a.extend_from_slice(&ap);
                u.extend_from_slice(&up);
                lo.push(item.req.lam_min);
                hi.push(item.req.lam_max);
            }
            for _ in items.len()..b {
                // identity filler lanes
                let mut ap = vec![0.0f32; n * n];
                for i in 0..n {
                    ap[i * n + i] = 1.0;
                }
                a.extend_from_slice(&ap);
                let mut up = vec![0.0f32; n];
                up[0] = 1.0;
                u.extend_from_slice(&up);
                lo.push(0.5);
                hi.push(2.0);
            }
            art.execute_batch(&a, &u, &lo, &hi)
                .ok()
                .map(|h| h.into_iter().take(items.len()).collect())
        }
        None => items
            .iter()
            .map(|item| {
                rt.gql_bounds(
                    &item.req.a,
                    &item.req.u,
                    item.req.n,
                    item.req.lam_min,
                    item.req.lam_max,
                )
                .ok()
            })
            .collect(),
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    metrics: Arc<ServiceMetrics>,
    bucketizer: Arc<Bucketizer>,
    exec_tx: Arc<Mutex<Option<Sender<ExecJob>>>>,
    policy: BatchPolicy,
) {
    loop {
        let first = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                    return;
                }
                if let Some(item) = pop_oldest(&mut q) {
                    break item;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, policy.max_wait.max(std::time::Duration::from_millis(5)))
                    .unwrap();
                q = guard;
            }
        };

        let dim = first.req.n;
        // reorth requests always run native: the fixed-iteration PJRT
        // artifacts do not reorthogonalize, so routing them to an
        // accelerator bucket would silently drop the stability guarantee
        let bucket = bucketizer
            .bucket(dim)
            .filter(|_| dim <= policy.native_threshold && !first.req.reorth);
        let sender = { exec_tx.lock().unwrap().clone() };
        let (bucket, sender) = match (bucket, sender) {
            (Some(b), Some(s)) => (b, s),
            _ => {
                if policy.coalesce && first.req.op_key.is_some() && policy.max_batch > 1 {
                    let group = drain_coalesced(&shared, &first, &policy);
                    serve_native_block(&metrics, first, group);
                } else {
                    serve_native(&metrics, first);
                }
                continue;
            }
        };

        // form a batch from same-bucket requests, sleeping on the condvar
        // between arrivals instead of spinning (a lone request used to
        // burn a core for the full `max_wait` — ROADMAP latency bug)
        let mut batch = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        {
            let mut q = shared.queue.lock().unwrap();
            while batch.len() < policy.max_batch {
                // never absorb a reorth request into an accelerator batch:
                // it must keep the native-path guarantee (see the bucket
                // filter above)
                if let Some(pos) = q.iter().position(|item| {
                    !item.req.reorth && bucketizer.bucket(item.req.n) == Some(bucket)
                }) {
                    batch.push(q.remove(pos));
                    continue;
                }
                let now = Instant::now();
                if now >= deadline || shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let (guard, _) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                q = guard;
            }
        }

        metrics.batches.inc();
        metrics.batch_size.lock().unwrap().record(batch.len() as f64);
        let (reply_tx, reply_rx) = channel();
        let n_items = batch.len();
        if sender
            .send(ExecJob { bucket, items: batch, reply: reply_tx })
            .is_err()
        {
            // executor gone — nothing to do; items are lost with it. This
            // only happens at shutdown.
            continue;
        }
        let (items, histories) = match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => continue,
        };
        match histories {
            Some(hists) => {
                for (item, h) in items.into_iter().zip(hists) {
                    if h.is_empty() {
                        // a runtime that records zero iterations has
                        // nothing to decide on; `h.at(h.len() - 1)` below
                        // would panic and unwind the dispatcher thread —
                        // fall back to the native scalar path instead
                        serve_native(&metrics, item);
                        continue;
                    }
                    let (iters, decision) = match h.first_decision(item.req.t) {
                        Some((i, d)) => (i + 1, d),
                        None => {
                            let last = h.at(h.len() - 1);
                            (h.len(), item.req.t < last.mid())
                        }
                    };
                    metrics.judge_iters.lock().unwrap().record(iters as f64);
                    metrics
                        .latency_ns
                        .lock()
                        .unwrap()
                        .record(item.enqueued.elapsed().as_nanos() as f64);
                    let _ = item.reply.send(JudgeResponse {
                        decision,
                        iters,
                        path: RoutePath::Pjrt { bucket, batch: n_items },
                    });
                }
            }
            None => {
                for item in items {
                    serve_native(&metrics, item);
                }
            }
        }
    }
}

fn pop_oldest(q: &mut Vec<Queued>) -> Option<Queued> {
    if q.is_empty() {
        return None;
    }
    let idx = q
        .iter()
        .enumerate()
        .min_by_key(|(_, item)| item.enqueued)
        .map(|(i, _)| i)?;
    Some(q.remove(idx))
}

/// Coalesce key: requests may share a `BlockGql` panel only when the
/// operator id, dimension, spectrum window, and reorthogonalization mode
/// all agree (the engine's `GqlOptions` are panel-wide).
fn coalesce_key(req: &JudgeRequest) -> Option<(u64, usize, u32, u32, bool)> {
    req.op_key
        .map(|k| (k, req.n, req.lam_min.to_bits(), req.lam_max.to_bits(), req.reorth))
}

/// The Bucketizer's same-operator coalescing mode: drain queued requests
/// co-keyed with `first`, sleeping on the shared condvar (woken by
/// `submit`) up to `max_wait` for stragglers — the client tagged them
/// batchable, so a bounded wait is the right trade, but a lone keyed
/// request now parks instead of burning a core for the full 200µs
/// default (the ROADMAP's named latency bug).
fn drain_coalesced(shared: &Shared, first: &Queued, policy: &BatchPolicy) -> Vec<Queued> {
    let key = coalesce_key(&first.req).expect("caller checked op_key");
    let mut group: Vec<Queued> = Vec::new();
    let deadline = Instant::now() + policy.max_wait;
    let mut q = shared.queue.lock().unwrap();
    loop {
        let keys: Vec<_> = q.iter().map(|item| coalesce_key(&item.req)).collect();
        let want = policy.max_batch - 1 - group.len();
        let pos = Bucketizer::coalesce_positions(&key, &keys, want);
        for p in pos.into_iter().rev() {
            group.push(q.remove(p));
        }
        let now = Instant::now();
        if group.len() + 1 >= policy.max_batch
            || now >= deadline
            || shared.shutdown.load(Ordering::SeqCst)
        {
            return group;
        }
        let (guard, _) = shared.cv.wait_timeout(q, deadline - now).unwrap();
        q = guard;
    }
}

/// Serve a coalesced group through one shared-operator [`BlockGql`] run:
/// the matrix is converted to f64 once and one panel sweep advances every
/// lane. Per-lane decisions are identical to the scalar native path (the
/// block engine's exactness contract).
fn serve_native_block(metrics: &ServiceMetrics, first: Queued, others: Vec<Queued>) {
    if others.is_empty() {
        return serve_native(metrics, first);
    }
    let mut items = Vec::with_capacity(1 + others.len());
    items.push(first);
    items.extend(others);
    let batch = items.len();
    metrics.native_fallbacks.add(batch as u64);
    metrics.coalesced_blocks.inc();
    metrics.batch_size.lock().unwrap().record(batch as f64);
    let n = items[0].req.n;
    // the op_key contract says co-keyed requests carry byte-identical
    // matrices; cheap to actually check in debug builds
    debug_assert!(
        items.iter().all(|it| it.req.a == items[0].req.a),
        "co-keyed requests must share an identical operator matrix"
    );
    let a = DMat::from_fn(n, n, |i, j| items[0].req.a[i * n + j] as f64);
    let opts = GqlOptions::new(items[0].req.lam_min as f64, items[0].req.lam_max as f64)
        .with_reorth(reorth_mode(&items[0].req));
    let mut eng = BlockGql::new(&a, opts, batch);
    for item in &items {
        let u: Vec<f64> = item.req.u.iter().map(|&x| x as f64).collect();
        eng.push(&u, StopRule::Threshold(item.req.t));
    }
    let results = eng.run_all(); // sorted by id == items order
    for (item, r) in items.into_iter().zip(results) {
        metrics.judge_iters.lock().unwrap().record(r.iters as f64);
        metrics
            .latency_ns
            .lock()
            .unwrap()
            .record(item.enqueued.elapsed().as_nanos() as f64);
        let decision = r.decision.unwrap_or_else(|| item.req.t < r.bounds.mid());
        let _ = item.reply.send(JudgeResponse {
            decision,
            iters: r.iters,
            path: RoutePath::NativeBlock { batch },
        });
    }
}

/// The reorthogonalization mode a request asked for.
fn reorth_mode(req: &JudgeRequest) -> Reorth {
    if req.reorth {
        Reorth::Full
    } else {
        Reorth::None
    }
}

fn serve_native(metrics: &ServiceMetrics, item: Queued) {
    metrics.native_fallbacks.inc();
    let n = item.req.n;
    let a = DMat::from_fn(n, n, |i, j| item.req.a[i * n + j] as f64);
    let u: Vec<f64> = item.req.u.iter().map(|&x| x as f64).collect();
    let opts = GqlOptions::new(item.req.lam_min as f64, item.req.lam_max as f64)
        .with_reorth(reorth_mode(&item.req));
    let (decision, stats) = judge_threshold(&a, &u, item.req.t, opts);
    metrics.judge_iters.lock().unwrap().record(stats.iters as f64);
    metrics
        .latency_ns
        .lock()
        .unwrap()
        .record(item.enqueued.elapsed().as_nanos() as f64);
    let _ = item.reply.send(JudgeResponse {
        decision,
        iters: stats.iters,
        path: RoutePath::Native,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_spd_exact;
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    pub fn make_request(rng: &mut Rng, n: usize, t_factor: f64) -> (JudgeRequest, bool) {
        let (a, l1, ln) = random_spd_exact(rng, n, 0.6, 0.2);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let t = exact * t_factor;
        let req = JudgeRequest {
            a: (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect(),
            u: u.iter().map(|&x| x as f32).collect(),
            n,
            lam_min: (l1 * 0.99) as f32,
            lam_max: (ln * 1.01) as f32,
            t,
            op_key: None,
            reorth: false,
        };
        (req, t < exact)
    }

    #[test]
    fn native_only_service_answers_correctly() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 2).unwrap();
        let mut rng = Rng::new(0x5E1);
        for factor in [0.5, 0.9, 1.1, 2.0] {
            let (req, want) = make_request(&mut rng, 20, factor);
            let resp = svc.judge_blocking(req);
            assert_eq!(resp.decision, want, "factor {factor}");
            assert_eq!(resp.path, RoutePath::Native);
        }
        assert_eq!(svc.metrics.requests.get(), 4);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let svc = Arc::new(JudgeService::start(None, BatchPolicy::default(), 3).unwrap());
        let mut rng = Rng::new(0x5E2);
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..24 {
            // factors straddle 1.0 but avoid the exact tie t == BIF
            let (req, want) =
                make_request(&mut rng, 12 + (i % 5), 0.5 + 0.1 * (i % 10) as f64 + 0.03);
            expected.push(want);
            rxs.push(svc.submit(req));
        }
        for (rx, want) in rxs.into_iter().zip(expected) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.decision, want);
        }
        assert_eq!(svc.metrics.requests.get(), 24);
    }

    #[test]
    fn shutdown_drains_queue() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 1).unwrap();
        let mut rng = Rng::new(0x5E3);
        let (req, want) = make_request(&mut rng, 10, 0.5);
        let rx = svc.submit(req);
        svc.shutdown();
        assert_eq!(rx.recv().unwrap().decision, want);
    }

    #[test]
    fn missing_artifacts_dir_degrades_to_native() {
        let svc = JudgeService::start(
            Some(PathBuf::from("/definitely/not/a/real/dir")),
            BatchPolicy::default(),
            1,
        )
        .unwrap();
        let mut rng = Rng::new(0x5E4);
        let (req, want) = make_request(&mut rng, 14, 0.7);
        let resp = svc.judge_blocking(req);
        assert_eq!(resp.decision, want);
        assert_eq!(resp.path, RoutePath::Native);
    }

    #[test]
    fn degenerate_policies_are_rejected_at_start() {
        let mut p = BatchPolicy::default();
        p.max_batch = 0;
        let err = JudgeService::start(None, p, 1).err().expect("must reject");
        assert!(err.contains("max_batch"), "{err}");
        let mut p = BatchPolicy::default();
        p.native_threshold = 0;
        let err = JudgeService::start(None, p, 1).err().expect("must reject");
        assert!(err.contains("native_threshold"), "{err}");
    }

    #[test]
    fn co_keyed_requests_coalesce_into_one_block_run() {
        // one shared operator, eight queries tagged with the same op_key;
        // a generous max_wait makes the drain deterministic
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5E5);
        let n = 18;
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
        let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..8 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = ch.bif(&u);
            let t = exact * (0.55 + 0.1 * i as f64);
            wants.push(t < exact);
            rxs.push(svc.submit(JudgeRequest {
                a: af.clone(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (l1 * 0.99) as f32,
                lam_max: (ln * 1.01) as f32,
                t,
                op_key: Some(0xC0A1),
                reorth: false,
            }));
        }
        let mut block_served = 0usize;
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.decision, want);
            if let RoutePath::NativeBlock { batch } = resp.path {
                assert!(batch >= 2);
                block_served += 1;
            }
        }
        assert!(
            block_served >= 2,
            "expected at least one coalesced block run (got {block_served})"
        );
        assert!(svc.metrics.coalesced_blocks.get() >= 1);
        svc.shutdown();
    }

    #[test]
    fn reorth_requests_are_served_natively_and_correctly() {
        // ill-conditioned-friendly knob: decisions must stay oracle-exact
        // with full reorthogonalization, through both the scalar native
        // path and a coalesced block run
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5E7);
        // scalar path
        let (mut req, want) = make_request(&mut rng, 16, 0.8);
        req.reorth = true;
        let resp = svc.judge_blocking(req);
        assert_eq!(resp.decision, want);
        assert_eq!(resp.path, RoutePath::Native);
        // coalesced block path
        let n = 14;
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
        let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..4 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = ch.bif(&u);
            let t = exact * (0.6 + 0.1 * i as f64);
            wants.push(t < exact);
            rxs.push(svc.submit(JudgeRequest {
                a: af.clone(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (l1 * 0.99) as f32,
                lam_max: (ln * 1.01) as f32,
                t,
                op_key: Some(0xC0A2),
                reorth: true,
            }));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            assert_eq!(rx.recv().unwrap().decision, want);
        }
        svc.shutdown();
    }

    #[test]
    fn coalescing_disabled_keeps_scalar_native_path() {
        let policy = BatchPolicy { coalesce: false, ..BatchPolicy::default() };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5E6);
        let (mut req, want) = make_request(&mut rng, 16, 0.8);
        req.op_key = Some(1);
        let resp = svc.judge_blocking(req);
        assert_eq!(resp.decision, want);
        assert_eq!(resp.path, RoutePath::Native);
        assert_eq!(svc.metrics.coalesced_blocks.get(), 0);
    }
}
