//! The judge service: request queue → router → (PJRT executor | native GQL).
//!
//! The `xla` crate's PJRT handles are not `Send`, so — exactly like a
//! single physical accelerator — one dedicated **executor thread** owns the
//! compiled artifacts; router/worker threads form batches and forward them
//! over a channel, falling back to the native GQL path when the executor
//! is absent (no artifacts) or reports an error.
//!
//! Requests come in two kinds ([`JudgeRequest`]): classic threshold
//! judgements (`t < u^T A^{-1} u`?) and **argmax batches**
//! ([`JudgeRequest::Argmax`]) — N candidate queries against one operator,
//! raced through the native planner
//! ([`crate::quadrature::query::Session`]) so remote callers get best-arm
//! early termination without shipping the kernel N times.
//!
//! Routing: threshold requests small enough for a PJRT bucket dispatch
//! there, unless same-operator coalescing applies *and* the router's
//! latency EWMAs ([`ServiceMetrics::prefer_native_block`]) say the native
//! block path has recently been faster — the ROADMAP "prefer the faster
//! path" heuristic. Argmax requests always run native (the
//! fixed-iteration artifacts cannot early-terminate). Since ISSUE 5 the
//! native drain is a thin client of the **multi-operator streaming
//! engine** ([`crate::quadrature::engine::Engine`]): one drain pulls
//! every queued keyed request — any operator, either kind — and the
//! engine runs one session per distinct coalesce key from a single round
//! loop, one `matvec_multi` panel per operator per round. Single-key
//! groups report [`RoutePath::NativeSession`] exactly as before;
//! cross-operator groups report [`RoutePath::NativeEngine`]. Lone
//! (unkeyed) argmax batches run as width-limited engine sessions
//! ([`RoutePath::NativeRace`]).
//!
//! Since ISSUE 7 that engine is **resident** ([`ResidentEngine`]): one
//! instance per service, shared by every worker, never constructed per
//! drain. Operators it has seen stay pinned while sessions live and then
//! demote to a byte-budgeted LRU warm store, so repeat tenants skip the
//! f32→f64 operator conversion; answers are harvested with
//! [`Engine::take_answer`] so the resident ticket log compacts instead
//! of growing with service uptime.
//!
//! Lifecycle: [`JudgeService::start`] spawns workers (+ executor); clients
//! call [`JudgeService::submit`] / [`JudgeService::submit_argmax`] (each
//! returns a receiver) or the blocking wrappers. Drop/`shutdown` drains
//! and joins.

use super::batcher::{BatchPolicy, Bucketizer};
use crate::config::run::parse_manifest;
use crate::linalg::DMat;
use crate::metrics::ServiceMetrics;
use crate::quadrature::block::StopRule;
use crate::quadrature::engine::{Engine, EngineConfig, OpKey, Ticket, MAX_ENGINE_LANES};
use crate::quadrature::query::{Answer, Query, QueryArm};
use crate::quadrature::race::RacePolicy;
use crate::quadrature::{judge_threshold, GqlOptions, Reorth};
use crate::runtime::{BoundsHistory, GqlRuntime};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// A dense threshold-judgement query: decide `t < u^T A^{-1} u`.
#[derive(Clone, Debug)]
pub struct ThresholdRequest {
    /// row-major dense symmetric matrix, `n*n`
    pub a: Vec<f32>,
    pub u: Vec<f32>,
    pub n: usize,
    pub lam_min: f32,
    pub lam_max: f32,
    pub t: f64,
    /// Same-operator coalescing key. Clients issuing many queries against
    /// one `a` (a DPP chain, a centrality sweep) tag them with a shared
    /// key; co-keyed native-path requests with equal `n` and spectrum
    /// window — threshold *and* argmax, the key excludes the kind — are
    /// drained into a single shared-operator
    /// [`Session`](crate::quadrature::query::Session) run.
    /// **Contract:** requests sharing a key must carry byte-identical
    /// `a`. `None` disables coalescing for this request.
    pub op_key: Option<u64>,
    /// Fully reorthogonalize the Lanczos basis (§5.4): set for
    /// ill-conditioned operators where plain Lanczos loses bound validity.
    /// Reorth requests always take the native path (the fixed-iteration
    /// PJRT artifacts do not reorthogonalize) and only coalesce with other
    /// reorth requests (part of the coalesce key).
    pub reorth: bool,
}

/// An argmax batch: find the candidate with the largest
/// `offset_i ± u_i^T A^{-1} u_i` over one shared operator, racing all
/// candidates through the native scheduler (dominated candidates stop
/// refining early; the winner is identical to exhaustive scoring).
#[derive(Clone, Debug)]
pub struct ArgmaxRequest {
    /// row-major dense symmetric matrix, `n*n` — shared by every arm
    pub a: Vec<f32>,
    pub n: usize,
    pub lam_min: f32,
    pub lam_max: f32,
    /// candidate query vectors, each of length `n`
    pub us: Vec<Vec<f32>>,
    /// per-arm affine offsets (missing entries default to 0)
    pub offsets: Vec<f64>,
    /// arm value orientation: `false` ⇒ `offset + BIF` (plain largest
    /// BIF), `true` ⇒ `offset − BIF` (DPP marginal-gain semantics)
    pub negate: bool,
    /// relative bracket tolerance an arm refines to when not pruned first
    pub tol_rel: f64,
    /// `true` (the point of the kind): prune dominated arms; `false`
    /// scores every arm exhaustively — same winner, more sweeps
    pub prune: bool,
    /// §5.4 full reorthogonalization for every arm
    pub reorth: bool,
    /// Same-operator coalescing key, sharing the namespace of
    /// [`ThresholdRequest::op_key`]. The coalesce key deliberately
    /// excludes the request *kind*: a co-keyed argmax batch drains into
    /// the same native [`Session`](crate::quadrature::query::Session) as
    /// co-keyed threshold traffic, so all
    /// their lanes advance from shared panel sweeps. Same contract:
    /// requests sharing a key must carry byte-identical `a`. `None`
    /// races this batch alone.
    pub op_key: Option<u64>,
}

/// The coordinator's request kinds.
#[derive(Clone, Debug)]
pub enum JudgeRequest {
    Threshold(ThresholdRequest),
    Argmax(ArgmaxRequest),
}

/// Which path served a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePath {
    /// PJRT dispatch into bucket `n` with this many co-batched requests
    Pjrt { bucket: usize, batch: usize },
    /// native rust GQL (big queries, no artifacts, or PJRT failure)
    Native,
    /// native unified planner: `batch` co-keyed requests (threshold
    /// and/or argmax, the key excludes the kind) compiled onto one
    /// shared-operator `Session` — since ISSUE 5 this is the single-
    /// operator case of the engine drain below
    NativeSession { batch: usize },
    /// native multi-operator engine (ISSUE 5): `batch` keyed requests
    /// across `ops` **distinct** operators drained into one
    /// [`Engine`], one `matvec_multi` panel per operator per round
    NativeEngine { ops: usize, batch: usize },
    /// native racing scheduler: one argmax batch of `arms` candidates
    /// served alone (unkeyed, or coalescing disabled) — a width-limited
    /// single-operator engine session since ISSUE 5
    NativeRace { arms: usize },
}

/// Service answer to a threshold request.
#[derive(Clone, Debug)]
pub struct JudgeResponse {
    pub decision: bool,
    /// quadrature iterations the decision consumed (first decisive
    /// iteration for PJRT histories)
    pub iters: usize,
    pub path: RoutePath,
}

/// Service answer to an argmax request.
#[derive(Clone, Debug)]
pub struct ArgmaxResponse {
    /// winning arm index (push order); `None` for empty or malformed
    /// batches (arm/operator dimension mismatch)
    pub winner: Option<usize>,
    /// panel sweeps the race spent
    pub sweeps: usize,
    /// arms pruned by interval dominance
    pub pruned: usize,
    pub path: RoutePath,
}

/// Receiver for a kind-dispatched [`JudgeService::submit_request`].
pub enum JudgePending {
    Threshold(Receiver<JudgeResponse>),
    Argmax(Receiver<ArgmaxResponse>),
}

struct ThreshQueued {
    req: ThresholdRequest,
    enqueued: Instant,
    reply: Sender<JudgeResponse>,
}

struct ArgmaxQueued {
    req: ArgmaxRequest,
    enqueued: Instant,
    reply: Sender<ArgmaxResponse>,
}

enum Queued {
    Threshold(ThreshQueued),
    Argmax(ArgmaxQueued),
}

impl Queued {
    fn enqueued(&self) -> Instant {
        match self {
            Queued::Threshold(t) => t.enqueued,
            Queued::Argmax(a) => a.enqueued,
        }
    }
}

/// Batch job sent to the executor thread.
struct ExecJob {
    bucket: usize,
    items: Vec<ThreshQueued>,
    /// per-item histories (None on execution failure)
    reply: Sender<(Vec<ThreshQueued>, Option<Vec<BoundsHistory>>)>,
}

struct Shared {
    queue: Mutex<Vec<Queued>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// The service's one resident engine (ISSUE 7): see
    /// [`ResidentEngine`]. Workers lock it for the duration of a native
    /// drain — the engine *is* the single scheduler, so serializing
    /// serves through it is the point, not a compromise.
    resident: Mutex<ResidentEngine>,
}

/// Byte budget for resident operators: enough to keep a working set of
/// drain-scale dense operators warm across drains without letting a
/// many-tenant workload grow without bound — past it the store LRU-evicts
/// idle, unpinned entries ([`crate::quadrature::engine::OpStore`]).
const RESIDENT_STORE_BYTES: usize = 64 << 20;

/// Idle rounds before a resident session is torn down. Deliberately
/// small: a session only needs to survive the drain that spun it up, and
/// eviction demotes the operator to the *warm store* (still resident,
/// re-admitted by key with no f32→f64 re-conversion) rather than
/// discarding it.
const RESIDENT_TTL_ROUNDS: usize = 2;

/// The coordinator's one resident multi-tenant engine (ISSUE 7). It
/// outlives every drain: the worker threads share it behind a mutex and
/// each native drain is a thin client — spin up (or find warm) the
/// sessions its groups need, stream the queries in, run the joint round
/// loop, harvest with [`Engine::take_answer`] so the ticket log compacts.
///
/// Repeat tenants are the payoff: a coalesce key seen in an earlier
/// drain maps to the same [`OpKey`], and if the operator is still
/// resident (live session *or* warm store entry) the drain skips the
/// f32→f64 operator conversion entirely. The [`ThresholdRequest::op_key`]
/// contract extends across drains: requests reusing a key (with equal
/// dimension, spectrum window, and reorth mode) must carry the same
/// operator bytes, or the store serves the original — the resident
/// engine cannot re-check a type-erased stored operator against a new
/// upload.
///
/// A warm hit also keeps the live session's original panel width and
/// race policy; per the engine's exactness contract both change sweep
/// counts only, never decisions.
struct ResidentEngine {
    eng: Engine,
    /// Stable coalesce-key → operator-store key mapping, grown only.
    /// Anonymous one-shot serves bypass it via [`Engine::fresh_key`].
    keys: HashMap<CoalesceKey, OpKey>,
}

impl ResidentEngine {
    fn new() -> Self {
        let ecfg = EngineConfig::default()
            .with_lanes(MAX_ENGINE_LANES)
            .with_ttl_rounds(RESIDENT_TTL_ROUNDS)
            .with_store_bytes(RESIDENT_STORE_BYTES);
        ResidentEngine {
            eng: Engine::new(ecfg).expect("static resident engine config is valid"),
            keys: HashMap::new(),
        }
    }

    /// The operator-store key for `ck`, allocating the next dense key on
    /// first sight. Dense keys stay below
    /// [`crate::quadrature::engine::ANON_KEY_BASE`], so they never
    /// collide with the anonymous keys lone serves draw.
    fn key_for(&mut self, ck: CoalesceKey) -> OpKey {
        let next = self.keys.len() as OpKey;
        *self.keys.entry(ck).or_insert(next)
    }
}

/// The running service.
pub struct JudgeService {
    shared: Arc<Shared>,
    pub metrics: Arc<ServiceMetrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
    executor: Option<std::thread::JoinHandle<()>>,
}

impl JudgeService {
    /// Start with `n_workers` routing threads. `artifacts_dir = None`
    /// forces the native path for everything.
    ///
    /// Rejects policies the drainer cannot make progress under
    /// ([`BatchPolicy::validate`]): `max_batch == 0` or
    /// `native_threshold == 0`.
    pub fn start(
        artifacts_dir: Option<PathBuf>,
        policy: BatchPolicy,
        n_workers: usize,
    ) -> Result<Self, String> {
        policy.validate()?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            resident: Mutex::new(ResidentEngine::new()),
        });
        let metrics = Arc::new(ServiceMetrics::new());

        // Parse the manifest on this thread (cheap) so the workers know
        // the buckets; compile inside the executor thread (owns PJRT).
        let (bucketizer, exec_tx, executor) = match artifacts_dir {
            Some(dir) => {
                let manifest = std::fs::read_to_string(dir.join("manifest.json"))
                    .ok()
                    .and_then(|s| parse_manifest(&s).ok());
                match manifest {
                    Some(entries) => {
                        let sizes: Vec<usize> = entries
                            .iter()
                            .filter(|e| e.batch == 1)
                            .map(|e| e.n)
                            .collect();
                        let (tx, rx) = channel::<ExecJob>();
                        let handle = std::thread::spawn(move || executor_loop(dir, rx));
                        (Bucketizer::new(sizes), Some(tx), Some(handle))
                    }
                    None => (Bucketizer::new(vec![]), None, None),
                }
            }
            None => (Bucketizer::new(vec![]), None, None),
        };

        let exec_tx = Arc::new(Mutex::new(exec_tx));
        let bucketizer = Arc::new(bucketizer);
        let workers = (0..n_workers.max(1))
            .map(|_| {
                let shared = shared.clone();
                let metrics = metrics.clone();
                let bucketizer = bucketizer.clone();
                let exec_tx = exec_tx.clone();
                std::thread::spawn(move || {
                    worker_loop(shared, metrics, bucketizer, exec_tx, policy)
                })
            })
            .collect();
        Ok(JudgeService { shared, metrics, workers, executor })
    }

    fn enqueue(&self, item: Queued) {
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push(item);
        }
        // notify_all, not notify_one: besides idle workers, batch-forming
        // and coalescing drains also sleep on this condvar waiting for
        // stragglers; a single wakeup could land on a drainer the new item
        // doesn't match while an idle worker keeps sleeping.
        self.shared.cv.notify_all();
    }

    /// Enqueue a threshold request; the receiver yields exactly one
    /// response.
    pub fn submit(&self, req: ThresholdRequest) -> Receiver<JudgeResponse> {
        self.metrics.requests.inc();
        let (tx, rx) = channel();
        self.enqueue(Queued::Threshold(ThreshQueued {
            req,
            enqueued: Instant::now(),
            reply: tx,
        }));
        rx
    }

    /// Enqueue an argmax batch; the receiver yields exactly one response.
    pub fn submit_argmax(&self, req: ArgmaxRequest) -> Receiver<ArgmaxResponse> {
        self.metrics.requests.inc();
        let (tx, rx) = channel();
        self.enqueue(Queued::Argmax(ArgmaxQueued {
            req,
            enqueued: Instant::now(),
            reply: tx,
        }));
        rx
    }

    /// Kind-dispatching entry for callers holding a [`JudgeRequest`].
    pub fn submit_request(&self, req: JudgeRequest) -> JudgePending {
        match req {
            JudgeRequest::Threshold(r) => JudgePending::Threshold(self.submit(r)),
            JudgeRequest::Argmax(r) => JudgePending::Argmax(self.submit_argmax(r)),
        }
    }

    /// Submit a threshold request and wait.
    pub fn judge_blocking(&self, req: ThresholdRequest) -> JudgeResponse {
        self.submit(req).recv().expect("service dropped the reply")
    }

    /// Submit an argmax batch and wait.
    pub fn argmax_blocking(&self, req: ArgmaxRequest) -> ArgmaxResponse {
        self.submit_argmax(req)
            .recv()
            .expect("service dropped the reply")
    }

    /// Stop workers and join them.
    pub fn shutdown(mut self) {
        self.do_shutdown();
    }

    fn do_shutdown(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(e) = self.executor.take() {
            // dropping all worker-held senders closes the channel; we only
            // reach here after workers joined
            let _ = e.join();
        }
    }
}

impl Drop for JudgeService {
    fn drop(&mut self) {
        self.do_shutdown();
    }
}

/// The PJRT-owning thread: compiles artifacts once, serves batch jobs.
fn executor_loop(dir: PathBuf, rx: Receiver<ExecJob>) {
    let runtime = GqlRuntime::load(&dir).ok();
    while let Ok(job) = rx.recv() {
        let result = runtime.as_ref().and_then(|rt| run_job(rt, &job));
        let _ = job.reply.send((job.items, result));
    }
}

fn run_job(rt: &GqlRuntime, job: &ExecJob) -> Option<Vec<BoundsHistory>> {
    let bucket = job.bucket;
    let items = &job.items;
    // prefer a batched artifact when >1 request shares the bucket
    let batched = if items.len() > 1 {
        rt.artifacts()
            .iter()
            .find(|a| a.meta.batch >= items.len() && a.meta.n == bucket)
    } else {
        None
    };
    match batched {
        Some(art) => {
            let (n, b) = (art.meta.n, art.meta.batch);
            let mut a = Vec::with_capacity(b * n * n);
            let mut u = Vec::with_capacity(b * n);
            let mut lo = Vec::with_capacity(b);
            let mut hi = Vec::with_capacity(b);
            for item in items {
                let (ap, up) = GqlRuntime::pad_query(&item.req.a, &item.req.u, item.req.n, n);
                a.extend_from_slice(&ap);
                u.extend_from_slice(&up);
                lo.push(item.req.lam_min);
                hi.push(item.req.lam_max);
            }
            for _ in items.len()..b {
                // identity filler lanes
                let mut ap = vec![0.0f32; n * n];
                for i in 0..n {
                    ap[i * n + i] = 1.0;
                }
                a.extend_from_slice(&ap);
                let mut up = vec![0.0f32; n];
                up[0] = 1.0;
                u.extend_from_slice(&up);
                lo.push(0.5);
                hi.push(2.0);
            }
            art.execute_batch(&a, &u, &lo, &hi)
                .ok()
                .map(|h| h.into_iter().take(items.len()).collect())
        }
        None => items
            .iter()
            .map(|item| {
                rt.gql_bounds(
                    &item.req.a,
                    &item.req.u,
                    item.req.n,
                    item.req.lam_min,
                    item.req.lam_max,
                )
                .ok()
            })
            .collect(),
    }
}

fn worker_loop(
    shared: Arc<Shared>,
    metrics: Arc<ServiceMetrics>,
    bucketizer: Arc<Bucketizer>,
    exec_tx: Arc<Mutex<Option<Sender<ExecJob>>>>,
    policy: BatchPolicy,
) {
    loop {
        let first = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if shared.shutdown.load(Ordering::SeqCst) && q.is_empty() {
                    return;
                }
                if let Some(item) = pop_oldest(&mut q) {
                    break item;
                }
                let (guard, _) = shared
                    .cv
                    .wait_timeout(q, policy.max_wait.max(std::time::Duration::from_millis(5)))
                    .unwrap();
                q = guard;
            }
        };

        // Any keyed request may lead a native drain: since ISSUE 5 the
        // drain pulls every queued *keyed* request — any operator, either
        // kind — and hands the group to one multi-operator engine (one
        // session per distinct key). The coalesce key still partitions
        // sessions; it no longer partitions the drain.
        let coalescible = policy.coalesce && policy.max_batch > 1 && coalesce_key(&first).is_some();

        // argmax batches always run native: the fixed-iteration PJRT
        // artifacts cannot prune dominated arms mid-flight
        let first = match first {
            Queued::Argmax(item) => {
                if coalescible {
                    let mut group = vec![Queued::Argmax(item)];
                    group.extend(drain_keyed(&shared, &policy));
                    serve_native_engine(&metrics, group, &policy, &shared.resident);
                } else {
                    serve_argmax(&metrics, item, &policy, &shared.resident);
                }
                continue;
            }
            Queued::Threshold(item) => item,
        };

        let dim = first.req.n;
        // reorth requests always run native: the fixed-iteration PJRT
        // artifacts do not reorthogonalize, so routing them to an
        // accelerator bucket would silently drop the stability guarantee
        let bucket = bucketizer
            .bucket(dim)
            .filter(|_| dim <= policy.native_threshold && !first.req.reorth);
        let sender = { exec_tx.lock().unwrap().clone() };
        // EWMA routing (ROADMAP): a coalescible request with a viable
        // PJRT bucket goes native anyway when the native block path has
        // recently been faster per request — or is still unmeasured, in
        // which case it claims this one request as its exploration sample
        let use_pjrt = matches!((&bucket, &sender), (Some(_), Some(_)))
            && !(coalescible && metrics.prefer_native_block());
        let (bucket, sender) = if use_pjrt {
            (bucket.expect("checked above"), sender.expect("checked above"))
        } else {
            if coalescible {
                let mut group = vec![Queued::Threshold(first)];
                group.extend(drain_keyed(&shared, &policy));
                serve_native_engine(&metrics, group, &policy, &shared.resident);
            } else {
                serve_native(&metrics, first);
            }
            continue;
        };

        // form a batch from same-bucket requests, sleeping on the condvar
        // between arrivals instead of spinning (a lone request used to
        // burn a core for the full `max_wait` — ROADMAP latency bug)
        let mut batch = vec![first];
        let deadline = Instant::now() + policy.max_wait;
        {
            let mut q = shared.queue.lock().unwrap();
            while batch.len() < policy.max_batch {
                // never absorb a reorth request (native-path guarantee,
                // see the bucket filter above) or an argmax batch into an
                // accelerator batch
                if let Some(pos) = q.iter().position(|item| {
                    matches!(item, Queued::Threshold(t)
                        if !t.req.reorth && bucketizer.bucket(t.req.n) == Some(bucket))
                }) {
                    match q.remove(pos) {
                        Queued::Threshold(t) => batch.push(t),
                        Queued::Argmax(_) => unreachable!("position matched Threshold"),
                    }
                    continue;
                }
                let now = Instant::now();
                if now >= deadline || shared.shutdown.load(Ordering::SeqCst) {
                    break;
                }
                let (guard, _) = shared.cv.wait_timeout(q, deadline - now).unwrap();
                q = guard;
            }
        }

        metrics.batches.inc();
        metrics.batch_size.lock().unwrap().record(batch.len() as f64);
        let (reply_tx, reply_rx) = channel();
        let n_items = batch.len();
        let dispatched = Instant::now();
        if sender
            .send(ExecJob { bucket, items: batch, reply: reply_tx })
            .is_err()
        {
            // executor gone — nothing to do; items are lost with it. This
            // only happens at shutdown.
            continue;
        }
        let (items, histories) = match reply_rx.recv() {
            Ok(r) => r,
            Err(_) => continue,
        };
        match histories {
            Some(hists) => {
                // feed the router's path-preference EWMA with the per-
                // request service latency of this successful dispatch
                metrics
                    .pjrt_batch_ns
                    .record(dispatched.elapsed().as_nanos() as f64 / n_items as f64);
                for (item, h) in items.into_iter().zip(hists) {
                    if h.is_empty() {
                        // a runtime that records zero iterations has
                        // nothing to decide on; `h.at(h.len() - 1)` below
                        // would panic and unwind the dispatcher thread —
                        // fall back to the native scalar path instead
                        serve_native(&metrics, item);
                        continue;
                    }
                    let (iters, decision) = match h.first_decision(item.req.t) {
                        Some((i, d)) => (i + 1, d),
                        None => {
                            let last = h.at(h.len() - 1);
                            (h.len(), item.req.t < last.mid())
                        }
                    };
                    metrics.judge_iters.lock().unwrap().record(iters as f64);
                    metrics
                        .latency_ns
                        .lock()
                        .unwrap()
                        .record(item.enqueued.elapsed().as_nanos() as f64);
                    let _ = item.reply.send(JudgeResponse {
                        decision,
                        iters,
                        path: RoutePath::Pjrt { bucket, batch: n_items },
                    });
                }
            }
            None => {
                for item in items {
                    serve_native(&metrics, item);
                }
            }
        }
    }
}

fn pop_oldest(q: &mut Vec<Queued>) -> Option<Queued> {
    if q.is_empty() {
        return None;
    }
    let idx = q
        .iter()
        .enumerate()
        .min_by_key(|(_, item)| item.enqueued())
        .map(|(i, _)| i)?;
    Some(q.remove(idx))
}

/// What partitions a session batch: operator id, dimension, spectrum
/// window, and reorthogonalization mode — the metadata that changes the
/// numerics (the planner's `GqlOptions` are panel-wide).
type CoalesceKey = (u64, usize, u32, u32, bool);

/// Coalesce key: requests may share a session panel only when the
/// operator id, dimension, spectrum window, and reorthogonalization mode
/// all agree. The request *kind* is deliberately **not** part of the key
/// (ISSUE 4 satellite): co-keyed argmax and threshold traffic lands in
/// one native session instead of racing alone.
fn coalesce_key(item: &Queued) -> Option<CoalesceKey> {
    match item {
        Queued::Threshold(t) => thresh_key(&t.req),
        Queued::Argmax(a) => argmax_key(&a.req),
    }
}

fn thresh_key(req: &ThresholdRequest) -> Option<CoalesceKey> {
    req.op_key
        .map(|k| (k, req.n, req.lam_min.to_bits(), req.lam_max.to_bits(), req.reorth))
}

fn argmax_key(req: &ArgmaxRequest) -> Option<CoalesceKey> {
    req.op_key
        .map(|k| (k, req.n, req.lam_min.to_bits(), req.lam_max.to_bits(), req.reorth))
}

/// The native engine drain (ISSUE 5): pull **every** queued keyed request
/// — any operator, either kind — sleeping on the shared condvar (woken by
/// `submit`) up to `max_wait` for stragglers. The old per-key coalescing
/// drain waited the same bounded time but could only fold one operator's
/// traffic; the engine client groups by key afterwards, so one drain
/// feeds all live operators' sessions and the cross-operator round loop
/// does the rest. A lone keyed request still parks on the condvar instead
/// of burning a core for the full 200µs default (the ROADMAP's named
/// latency bug).
fn drain_keyed(shared: &Shared, policy: &BatchPolicy) -> Vec<Queued> {
    let mut group: Vec<Queued> = Vec::new();
    let deadline = Instant::now() + policy.max_wait;
    let mut q = shared.queue.lock().unwrap();
    loop {
        let want = policy.max_batch - 1 - group.len();
        let pos: Vec<usize> = q
            .iter()
            .enumerate()
            .filter(|(_, item)| coalesce_key(item).is_some())
            .map(|(i, _)| i)
            .take(want)
            .collect();
        for p in pos.into_iter().rev() {
            group.push(q.remove(p));
        }
        let now = Instant::now();
        if group.len() + 1 >= policy.max_batch
            || now >= deadline
            || shared.shutdown.load(Ordering::SeqCst)
        {
            return group;
        }
        let (guard, _) = shared.cv.wait_timeout(q, deadline - now).unwrap();
        q = guard;
    }
}

/// A request routed into the engine, remembering the ticket that answers
/// it (`None`: malformed argmax, answered without a query).
enum EngineSlot {
    Thresh(ThreshQueued, Ticket),
    Argmax(ArgmaxQueued, Option<Ticket>),
}

/// Lanes a request compiles to (0 for malformed argmax batches).
fn lane_demand(item: &Queued) -> usize {
    match item {
        Queued::Threshold(_) => 1,
        Queued::Argmax(q) => {
            if argmax_malformed(&q.req) {
                0
            } else {
                q.req.us.len()
            }
        }
    }
}

/// Serve a drained group of keyed requests — any mix of operators and
/// kinds — through the service's **resident** multi-operator [`Engine`]
/// (ISSUE 5, resident since ISSUE 7): the group is partitioned by
/// coalesce key, each distinct key gets one session over its operator —
/// found warm in the resident store for repeat tenants, f64-converted
/// once for cold ones — and a single round loop advances one
/// `matvec_multi` panel per operator per round. This *is* the old
/// shared-operator session serve — the single-key case reports
/// [`RoutePath::NativeSession`] exactly as before — generalized so
/// cross-operator traffic stops being served one key at a time
/// ([`RoutePath::NativeEngine`]). Per-request decisions are identical to
/// the dedicated paths (the block engine's exactness contract plus the
/// planner's shared decision ladders; the engine never changes numerics).
fn serve_native_engine(
    metrics: &ServiceMetrics,
    items: Vec<Queued>,
    policy: &BatchPolicy,
    resident: &Mutex<ResidentEngine>,
) {
    let served = Instant::now();
    if items.len() == 1 {
        // degenerate group (no keyed stragglers arrived): keep the
        // specialized paths, but still record the native-path EWMA so the
        // router's exploration sample lands even without real coalescing
        match items.into_iter().next().expect("one item") {
            Queued::Threshold(t) => {
                serve_native(metrics, t);
                metrics
                    .native_block_ns
                    .record(served.elapsed().as_nanos() as f64);
            }
            Queued::Argmax(a) => serve_argmax(metrics, a, policy, resident),
        }
        return;
    }
    // partition by coalesce key, preserving arrival order inside a group
    let mut groups: Vec<(CoalesceKey, Vec<Queued>)> = Vec::new();
    for item in items {
        let key = coalesce_key(&item).expect("the engine drain only pulls keyed requests");
        match groups.iter_mut().find(|(k, _)| *k == key) {
            Some((_, g)) => g.push(item),
            None => groups.push((key, vec![item])),
        }
    }

    // plan each group: an unusable leader operator falls the whole group
    // back to the dedicated per-request paths (which answer malformed
    // batches gracefully). No operator is converted here — the resident
    // engine's store decides per key below whether a conversion is even
    // needed (warm tenants skip it).
    struct GroupPlan {
        ck: CoalesceKey,
        opts: GqlOptions,
        width: usize,
        policy: RacePolicy,
    }
    let mut plans: Vec<GroupPlan> = Vec::new();
    let mut group_items: Vec<Vec<Queued>> = Vec::new();
    let mut fallback: Vec<Queued> = Vec::new();
    for (ck, group) in groups {
        let (n, lam_min, lam_max, reorth) = match &group[0] {
            Queued::Threshold(t) => (t.req.n, t.req.lam_min, t.req.lam_max, t.req.reorth),
            Queued::Argmax(a) => (a.req.n, a.req.lam_min, a.req.lam_max, a.req.reorth),
        };
        let a_bytes: &[f32] = match &group[0] {
            Queued::Threshold(t) => &t.req.a,
            Queued::Argmax(a) => &a.req.a,
        };
        if n == 0 || a_bytes.len() != n * n || !(lam_min > 0.0 && lam_max > lam_min) {
            fallback.extend(group);
            continue;
        }
        // the op_key contract says co-keyed requests carry byte-identical
        // matrices; cheap to actually check in debug builds
        debug_assert!(
            group.iter().all(|it| match it {
                Queued::Threshold(t) => t.req.a == a_bytes,
                Queued::Argmax(q) => q.req.a == a_bytes,
            }),
            "co-keyed requests must share an identical operator matrix"
        );
        let opts =
            GqlOptions::new(lam_min as f64, lam_max as f64).with_reorth(reorth_mode(reorth));
        // width-limited panels (ISSUE 5 satellite): lane demand capped by
        // the drain batch cap instead of the unbounded arms-sized panels
        // the old paths allocated; excess lanes queue and refill, which
        // changes sweep counts but never decisions. An exhaustive-scoring
        // argmax member downgrades its group's policy (prune/exhaustive
        // select identically — only sweeps differ).
        let demand: usize = group.iter().map(lane_demand).sum();
        let width = demand.clamp(1, policy.max_batch.max(1));
        let gpolicy = if group.iter().all(|it| match it {
            Queued::Argmax(q) => q.req.prune,
            Queued::Threshold(_) => true,
        }) {
            RacePolicy::Prune
        } else {
            RacePolicy::Exhaustive
        };
        plans.push(GroupPlan { ck, opts, width, policy: gpolicy });
        group_items.push(group);
    }
    // fallback requests answer through the dedicated paths (which keep
    // their own metrics — serve_native counts its fallback itself), so
    // the engine accounting below covers engine-served requests only
    for item in fallback {
        match item {
            Queued::Threshold(t) => serve_native(metrics, t),
            Queued::Argmax(a) => serve_argmax(metrics, a, policy, resident),
        }
    }
    if plans.is_empty() {
        return;
    }

    let batch: usize = group_items.iter().map(Vec::len).sum();
    let thresholds = group_items
        .iter()
        .flatten()
        .filter(|it| matches!(it, Queued::Threshold(_)))
        .count();
    // only threshold requests have a PJRT path to fall back from; argmax
    // members must not inflate the fallback counter
    metrics.native_fallbacks.add(thresholds as u64);
    metrics.coalesced_blocks.inc();
    metrics.batch_size.lock().unwrap().record(batch as f64);

    let ops_count = plans.len();
    // ISSUE 7: the drain is a thin client of the service's one resident
    // engine — held for the serve, never constructed per drain. Warm
    // tenants (live session, or operator still in the store) skip the
    // f32→f64 conversion; cold tenants convert once and hand the engine
    // the owned operator.
    let resident = &mut *resident.lock().unwrap();
    let mut slots: Vec<EngineSlot> = Vec::with_capacity(batch);
    let mut served_lanes = 0usize;
    for (g, group) in group_items.into_iter().enumerate() {
        let plan = &plans[g];
        let key = resident.key_for(plan.ck);
        let slot = match resident
            .eng
            .spin_up_keyed(key, plan.opts, plan.width, plan.policy)
        {
            Some(slot) => slot,
            None => {
                let (n, a_bytes): (usize, &[f32]) = match &group[0] {
                    Queued::Threshold(t) => (t.req.n, &t.req.a),
                    Queued::Argmax(a) => (a.req.n, &a.req.a),
                };
                let a = DMat::from_fn(n, n, |i, j| a_bytes[i * n + j] as f64);
                resident
                    .eng
                    .spin_up(key, Arc::new(a), plan.opts, plan.width, plan.policy)
            }
        };
        for item in group {
            match item {
                Queued::Threshold(t) => {
                    let u: Vec<f64> = t.req.u.iter().map(|&x| x as f64).collect();
                    let ticket = resident.eng.submit_to(slot, Query::Threshold { u, t: t.req.t });
                    slots.push(EngineSlot::Thresh(t, ticket));
                    served_lanes += 1;
                }
                Queued::Argmax(q) => {
                    if argmax_malformed(&q.req) {
                        slots.push(EngineSlot::Argmax(q, None));
                        continue;
                    }
                    let scale = if q.req.negate { -1.0 } else { 1.0 };
                    let arms: Vec<QueryArm> = q
                        .req
                        .us
                        .iter()
                        .enumerate()
                        .map(|(i, u)| QueryArm {
                            u: u.iter().map(|&x| x as f64).collect(),
                            stop: StopRule::GapRel(q.req.tol_rel.max(0.0)),
                            offset: q.req.offsets.get(i).copied().unwrap_or(0.0),
                            scale,
                        })
                        .collect();
                    served_lanes += q.req.us.len();
                    let ticket = resident.eng.submit_to(slot, Query::Argmax { arms, floor: None });
                    slots.push(EngineSlot::Argmax(q, Some(ticket)));
                }
            }
        }
    }
    resident.eng.drain();
    if ops_count >= 2 {
        metrics.engine_drains.inc();
    }
    // feed the router's path-preference EWMA. The EWMA arbitrates
    // *threshold* routing against PJRT, so the sample is the per-lane
    // engine time (a threshold is one lane): for threshold-only groups
    // this matches the old elapsed/batch figure, and mixed groups still
    // seed the EWMA — required by prefer_native_block's self-seeding
    // contract — without letting a wide argmax batch inflate the
    // apparent per-threshold cost by an order of magnitude
    if thresholds > 0 {
        metrics
            .native_block_ns
            .record(served.elapsed().as_nanos() as f64 / served_lanes.max(1) as f64);
    }
    let path = if ops_count == 1 {
        RoutePath::NativeSession { batch }
    } else {
        RoutePath::NativeEngine { ops: ops_count, batch }
    };
    // harvest with take_answer so the resident ticket log compacts (a
    // drain leaves no tombstone build-up behind; see Engine::take_answer)
    for slot in slots {
        match slot {
            EngineSlot::Thresh(item, ticket) => match resident.eng.take_answer(ticket) {
                Ok(Answer::Threshold { decision, stats }) => {
                    metrics.judge_iters.lock().unwrap().record(stats.iters as f64);
                    metrics
                        .latency_ns
                        .lock()
                        .unwrap()
                        .record(item.enqueued.elapsed().as_nanos() as f64);
                    let _ = item.reply.send(JudgeResponse {
                        decision,
                        iters: stats.iters,
                        path,
                    });
                }
                _ => unreachable!("threshold queries answer with threshold answers"),
            },
            EngineSlot::Argmax(item, None) => {
                metrics.races.inc();
                let _ = item
                    .reply
                    .send(ArgmaxResponse { winner: None, sweeps: 0, pruned: 0, path });
            }
            EngineSlot::Argmax(item, Some(ticket)) => match resident.eng.take_answer(ticket) {
                Ok(Answer::Argmax { winner, stats, .. }) => {
                    metrics.races.inc();
                    metrics
                        .latency_ns
                        .lock()
                        .unwrap()
                        .record(item.enqueued.elapsed().as_nanos() as f64);
                    let _ = item.reply.send(ArgmaxResponse {
                        winner,
                        sweeps: stats.sweeps,
                        pruned: stats.pruned(),
                        path,
                    });
                }
                _ => unreachable!("argmax queries answer with argmax answers"),
            },
        }
    }
}

/// A batch the racing scheduler cannot serve: empty, inconsistent
/// dimensions, or an unusable spectrum window.
fn argmax_malformed(req: &ArgmaxRequest) -> bool {
    req.us.is_empty()
        || req.n == 0
        || req.a.len() != req.n * req.n
        || req.us.iter().any(|u| u.len() != req.n)
        || !(req.lam_min > 0.0 && req.lam_max > req.lam_min)
}

/// Serve a lone argmax batch through a **width-limited engine session**
/// (ISSUE 5 satellite — the standalone `Race` serve arm this replaces
/// allocated an arms-sized panel, so a 100-arm request panelized 100
/// lanes at once): the panel width is capped by the drain batch cap and
/// excess arms queue/refill, which changes sweep counts but never the
/// winner. Dominated arms are pruned (when requested) and the race ends
/// the moment the winner is determined. Since ISSUE 7 the session runs
/// on the service's resident engine under an **anonymous** key
/// ([`Engine::fresh_key`]): the one-shot operator is dropped from the
/// store on eviction instead of competing with keyed tenants for the
/// resident byte budget.
fn serve_argmax(
    metrics: &ServiceMetrics,
    item: ArgmaxQueued,
    policy: &BatchPolicy,
    resident: &Mutex<ResidentEngine>,
) {
    let req = item.req;
    let arms = req.us.len();
    metrics.races.inc();
    let path = RoutePath::NativeRace { arms };
    if argmax_malformed(&req) {
        let _ = item
            .reply
            .send(ArgmaxResponse { winner: None, sweeps: 0, pruned: 0, path });
        return;
    }
    let n = req.n;
    let a = DMat::from_fn(n, n, |i, j| req.a[i * n + j] as f64);
    let opts = GqlOptions::new(req.lam_min as f64, req.lam_max as f64)
        .with_reorth(reorth_mode(req.reorth));
    let rpolicy = if req.prune { RacePolicy::Prune } else { RacePolicy::Exhaustive };
    let scale = if req.negate { -1.0 } else { 1.0 };
    let width = arms.clamp(1, policy.max_batch.max(1));
    let query_arms: Vec<QueryArm> = req
        .us
        .iter()
        .enumerate()
        .map(|(i, u)| QueryArm {
            u: u.iter().map(|&x| x as f64).collect(),
            stop: StopRule::GapRel(req.tol_rel.max(0.0)),
            offset: req.offsets.get(i).copied().unwrap_or(0.0),
            scale,
        })
        .collect();
    let resident = &mut *resident.lock().unwrap();
    let key = resident.eng.fresh_key();
    let slot = resident.eng.spin_up(key, Arc::new(a), opts, width, rpolicy);
    let ticket = resident
        .eng
        .submit_to(slot, Query::Argmax { arms: query_arms, floor: None });
    resident.eng.drain();
    let (winner, sweeps, pruned) = match resident.eng.take_answer(ticket) {
        Ok(Answer::Argmax { winner, stats, .. }) => (winner, stats.sweeps, stats.pruned()),
        _ => unreachable!("argmax queries answer with argmax answers"),
    };
    metrics
        .latency_ns
        .lock()
        .unwrap()
        .record(item.enqueued.elapsed().as_nanos() as f64);
    let _ = item
        .reply
        .send(ArgmaxResponse { winner, sweeps, pruned, path });
}

/// The reorthogonalization mode a request asked for.
fn reorth_mode(reorth: bool) -> Reorth {
    if reorth {
        Reorth::Full
    } else {
        Reorth::None
    }
}

fn serve_native(metrics: &ServiceMetrics, item: ThreshQueued) {
    metrics.native_fallbacks.inc();
    let n = item.req.n;
    let a = DMat::from_fn(n, n, |i, j| item.req.a[i * n + j] as f64);
    let u: Vec<f64> = item.req.u.iter().map(|&x| x as f64).collect();
    let opts = GqlOptions::new(item.req.lam_min as f64, item.req.lam_max as f64)
        .with_reorth(reorth_mode(item.req.reorth));
    let (decision, stats) = judge_threshold(&a, &u, item.req.t, opts);
    metrics.judge_iters.lock().unwrap().record(stats.iters as f64);
    metrics
        .latency_ns
        .lock()
        .unwrap()
        .record(item.enqueued.elapsed().as_nanos() as f64);
    let _ = item.reply.send(JudgeResponse {
        decision,
        iters: stats.iters,
        path: RoutePath::Native,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_spd_exact;
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    pub fn make_request(rng: &mut Rng, n: usize, t_factor: f64) -> (ThresholdRequest, bool) {
        let (a, l1, ln) = random_spd_exact(rng, n, 0.6, 0.2);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let exact = Cholesky::factor(&a).unwrap().bif(&u);
        let t = exact * t_factor;
        let req = ThresholdRequest {
            a: (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect(),
            u: u.iter().map(|&x| x as f32).collect(),
            n,
            lam_min: (l1 * 0.99) as f32,
            lam_max: (ln * 1.01) as f32,
            t,
            op_key: None,
            reorth: false,
        };
        (req, t < exact)
    }

    #[test]
    fn native_only_service_answers_correctly() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 2).unwrap();
        let mut rng = Rng::new(0x5E1);
        for factor in [0.5, 0.9, 1.1, 2.0] {
            let (req, want) = make_request(&mut rng, 20, factor);
            let resp = svc.judge_blocking(req);
            assert_eq!(resp.decision, want, "factor {factor}");
            assert_eq!(resp.path, RoutePath::Native);
        }
        assert_eq!(svc.metrics.requests.get(), 4);
        svc.shutdown();
    }

    #[test]
    fn concurrent_submissions_all_answered() {
        let svc = Arc::new(JudgeService::start(None, BatchPolicy::default(), 3).unwrap());
        let mut rng = Rng::new(0x5E2);
        let mut expected = Vec::new();
        let mut rxs = Vec::new();
        for i in 0..24 {
            // factors straddle 1.0 but avoid the exact tie t == BIF
            let (req, want) =
                make_request(&mut rng, 12 + (i % 5), 0.5 + 0.1 * (i % 10) as f64 + 0.03);
            expected.push(want);
            rxs.push(svc.submit(req));
        }
        for (rx, want) in rxs.into_iter().zip(expected) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.decision, want);
        }
        assert_eq!(svc.metrics.requests.get(), 24);
    }

    #[test]
    fn shutdown_drains_queue() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 1).unwrap();
        let mut rng = Rng::new(0x5E3);
        let (req, want) = make_request(&mut rng, 10, 0.5);
        let rx = svc.submit(req);
        svc.shutdown();
        assert_eq!(rx.recv().unwrap().decision, want);
    }

    #[test]
    fn missing_artifacts_dir_degrades_to_native() {
        let svc = JudgeService::start(
            Some(PathBuf::from("/definitely/not/a/real/dir")),
            BatchPolicy::default(),
            1,
        )
        .unwrap();
        let mut rng = Rng::new(0x5E4);
        let (req, want) = make_request(&mut rng, 14, 0.7);
        let resp = svc.judge_blocking(req);
        assert_eq!(resp.decision, want);
        assert_eq!(resp.path, RoutePath::Native);
    }

    #[test]
    fn degenerate_policies_are_rejected_at_start() {
        let mut p = BatchPolicy::default();
        p.max_batch = 0;
        let err = JudgeService::start(None, p, 1).err().expect("must reject");
        assert!(err.contains("max_batch"), "{err}");
        let mut p = BatchPolicy::default();
        p.native_threshold = 0;
        let err = JudgeService::start(None, p, 1).err().expect("must reject");
        assert!(err.contains("native_threshold"), "{err}");
    }

    #[test]
    fn co_keyed_requests_coalesce_into_one_session_run() {
        // one shared operator, eight queries tagged with the same op_key;
        // a generous max_wait makes the drain deterministic
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5E5);
        let n = 18;
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
        let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..8 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = ch.bif(&u);
            let t = exact * (0.55 + 0.1 * i as f64);
            wants.push(t < exact);
            rxs.push(svc.submit(ThresholdRequest {
                a: af.clone(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (l1 * 0.99) as f32,
                lam_max: (ln * 1.01) as f32,
                t,
                op_key: Some(0xC0A1),
                reorth: false,
            }));
        }
        let mut session_served = 0usize;
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.decision, want);
            if let RoutePath::NativeSession { batch } = resp.path {
                assert!(batch >= 2);
                session_served += 1;
            }
        }
        assert!(
            session_served >= 2,
            "expected at least one coalesced session run (got {session_served})"
        );
        assert!(svc.metrics.coalesced_blocks.get() >= 1);
        assert!(
            svc.metrics.native_block_ns.get().is_some(),
            "block runs must feed the router EWMA"
        );
        svc.shutdown();
    }

    #[test]
    fn reorth_requests_are_served_natively_and_correctly() {
        // ill-conditioned-friendly knob: decisions must stay oracle-exact
        // with full reorthogonalization, through both the scalar native
        // path and a coalesced block run
        let policy = BatchPolicy {
            max_batch: 4,
            max_wait: std::time::Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5E7);
        // scalar path
        let (mut req, want) = make_request(&mut rng, 16, 0.8);
        req.reorth = true;
        let resp = svc.judge_blocking(req);
        assert_eq!(resp.decision, want);
        assert_eq!(resp.path, RoutePath::Native);
        // coalesced block path
        let n = 14;
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
        let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..4 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = ch.bif(&u);
            let t = exact * (0.6 + 0.1 * i as f64);
            wants.push(t < exact);
            rxs.push(svc.submit(ThresholdRequest {
                a: af.clone(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (l1 * 0.99) as f32,
                lam_max: (ln * 1.01) as f32,
                t,
                op_key: Some(0xC0A2),
                reorth: true,
            }));
        }
        for (rx, want) in rxs.into_iter().zip(wants) {
            assert_eq!(rx.recv().unwrap().decision, want);
        }
        svc.shutdown();
    }

    #[test]
    fn coalescing_disabled_keeps_scalar_native_path() {
        let policy = BatchPolicy { coalesce: false, ..BatchPolicy::default() };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5E6);
        let (mut req, want) = make_request(&mut rng, 16, 0.8);
        req.op_key = Some(1);
        let resp = svc.judge_blocking(req);
        assert_eq!(resp.decision, want);
        assert_eq!(resp.path, RoutePath::Native);
        assert_eq!(svc.metrics.coalesced_blocks.get(), 0);
    }

    /// Build an argmax batch over one random SPD operator; returns the
    /// request plus the oracle winner (largest `offset − BIF`).
    fn make_argmax(rng: &mut Rng, n: usize, arms: usize) -> (ArgmaxRequest, Option<usize>) {
        let (a, l1, ln) = random_spd_exact(rng, n, 0.6, 0.2);
        let ch = Cholesky::factor(&a).unwrap();
        let mut us = Vec::new();
        let mut offsets = Vec::new();
        let mut best: Option<(usize, f64)> = None;
        for i in 0..arms {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let off = 2.0 + rng.f64() * 3.0;
            let val = off - ch.bif(&u);
            if best.map_or(true, |(_, g)| val > g) {
                best = Some((i, val));
            }
            us.push(u.iter().map(|&x| x as f32).collect());
            offsets.push(off);
        }
        let req = ArgmaxRequest {
            a: (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect(),
            n,
            lam_min: (l1 * 0.99) as f32,
            lam_max: (ln * 1.01) as f32,
            us,
            offsets,
            negate: true,
            tol_rel: 1e-10,
            prune: true,
            reorth: false,
            op_key: None,
        };
        (req, best.map(|(i, _)| i))
    }

    #[test]
    fn co_keyed_argmax_and_threshold_traffic_share_one_session() {
        // the ISSUE 4 satellite: the coalesce key excludes the request
        // kind, so an argmax batch lands in the same native session as
        // co-keyed threshold requests
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5EB);
        let n = 16;
        let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
        let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
        let ch = Cholesky::factor(&a).unwrap();
        let key = Some(0xC0A3);
        let mut t_rxs = Vec::new();
        let mut t_wants = Vec::new();
        for i in 0..4 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = ch.bif(&u);
            let t = exact * (0.55 + 0.1 * i as f64);
            t_wants.push(t < exact);
            t_rxs.push(svc.submit(ThresholdRequest {
                a: af.clone(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (l1 * 0.99) as f32,
                lam_max: (ln * 1.01) as f32,
                t,
                op_key: key,
                reorth: false,
            }));
        }
        let arms: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let mut best: Option<(usize, f64)> = None;
        for (i, u) in arms.iter().enumerate() {
            let v = ch.bif(u);
            if best.map_or(true, |(_, g)| v > g) {
                best = Some((i, v));
            }
        }
        let a_rx = svc.submit_argmax(ArgmaxRequest {
            a: af.clone(),
            n,
            lam_min: (l1 * 0.99) as f32,
            lam_max: (ln * 1.01) as f32,
            us: arms
                .iter()
                .map(|u| u.iter().map(|&x| x as f32).collect())
                .collect(),
            offsets: vec![0.0; 3],
            negate: false,
            tol_rel: 1e-10,
            prune: true,
            reorth: false,
            op_key: key,
        });
        let mut session_served = 0usize;
        for (rx, want) in t_rxs.into_iter().zip(t_wants) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.decision, want);
            if matches!(resp.path, RoutePath::NativeSession { .. }) {
                session_served += 1;
            }
        }
        let aresp = a_rx.recv().unwrap();
        assert_eq!(aresp.winner, best.map(|(i, _)| i), "session argmax wrong");
        if let RoutePath::NativeSession { batch } = aresp.path {
            assert!(batch >= 2, "argmax coalesced with co-keyed thresholds");
            assert!(
                session_served >= 1,
                "at least one threshold shared the argmax's session"
            );
        } else {
            // scheduling can race the queue drain; the argmax must then
            // have been served alone but still natively
            assert_eq!(aresp.path, RoutePath::NativeRace { arms: 3 });
        }
        assert!(svc.metrics.races.get() >= 1);
        svc.shutdown();
    }

    #[test]
    fn cross_keyed_traffic_drains_into_one_engine() {
        // ISSUE 5: two distinct operators' keyed traffic, submitted
        // together, is served by one multi-operator engine drain instead
        // of one coalesce key at a time
        let policy = BatchPolicy {
            max_batch: 8,
            max_wait: std::time::Duration::from_millis(50),
            ..BatchPolicy::default()
        };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5EC);
        let mut ops = Vec::new();
        for n in [14usize, 18] {
            let (a, l1, ln) = random_spd_exact(&mut rng, n, 0.6, 0.2);
            let af: Vec<f32> = (0..n * n).map(|k| a.get(k / n, k % n) as f32).collect();
            let ch = Cholesky::factor(&a).unwrap();
            ops.push((n, af, l1, ln, ch));
        }
        let mut rxs = Vec::new();
        let mut wants = Vec::new();
        for i in 0..8 {
            let (n, af, l1, ln, ch) = &ops[i % 2];
            let n = *n;
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = ch.bif(&u);
            let t = exact * (0.55 + 0.1 * (i / 2) as f64);
            wants.push(t < exact);
            rxs.push(svc.submit(ThresholdRequest {
                a: af.clone(),
                u: u.iter().map(|&x| x as f32).collect(),
                n,
                lam_min: (*l1 * 0.99) as f32,
                lam_max: (*ln * 1.01) as f32,
                t,
                op_key: Some(100 + (i % 2) as u64),
                reorth: false,
            }));
        }
        let mut engine_served = 0usize;
        for (rx, want) in rxs.into_iter().zip(wants) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.decision, want);
            if let RoutePath::NativeEngine { ops, batch } = resp.path {
                assert!(ops >= 2, "cross-operator drain must span both keys");
                assert!(batch >= 2);
                engine_served += 1;
            }
        }
        assert!(
            engine_served >= 2,
            "expected a cross-operator engine drain (got {engine_served})"
        );
        assert!(svc.metrics.engine_drains.get() >= 1);
        svc.shutdown();
    }

    #[test]
    fn lone_argmax_panels_are_width_limited_but_oracle_correct() {
        // ISSUE 5 satellite: the standalone Race serve arm is gone; a
        // lone argmax with more arms than the drain batch cap runs as a
        // width-limited engine session — same winner, bounded panel
        let policy = BatchPolicy { max_batch: 4, ..BatchPolicy::default() };
        let svc = JudgeService::start(None, policy, 1).unwrap();
        let mut rng = Rng::new(0x5ED);
        let (req, want) = make_argmax(&mut rng, 16, 10);
        let resp = svc.argmax_blocking(req);
        assert_eq!(resp.winner, want, "width cap changed the winner");
        assert_eq!(resp.path, RoutePath::NativeRace { arms: 10 });
        assert!(resp.sweeps > 0);
        svc.shutdown();
    }

    #[test]
    fn argmax_batches_race_to_the_oracle_winner() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 2).unwrap();
        let mut rng = Rng::new(0x5E8);
        for arms in [1usize, 3, 6] {
            let (req, want) = make_argmax(&mut rng, 16, arms);
            // pruned and exhaustive must crown the same winner
            let mut exhaustive = req.clone();
            exhaustive.prune = false;
            let pr = svc.argmax_blocking(req);
            let ex = svc.argmax_blocking(exhaustive);
            assert_eq!(pr.winner, want, "{arms} arms (prune)");
            assert_eq!(ex.winner, want, "{arms} arms (exhaustive)");
            assert_eq!(pr.path, RoutePath::NativeRace { arms });
            assert!(pr.sweeps <= ex.sweeps, "pruning must not add sweeps");
        }
        assert!(svc.metrics.races.get() >= 6);
        svc.shutdown();
    }

    #[test]
    fn malformed_argmax_batches_answer_none() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 1).unwrap();
        let mut rng = Rng::new(0x5E9);
        let (mut req, _) = make_argmax(&mut rng, 12, 3);
        req.us[1].pop(); // dimension mismatch
        let resp = svc.argmax_blocking(req);
        assert_eq!(resp.winner, None);
        assert_eq!(resp.sweeps, 0);
        // empty batch
        let (mut req, _) = make_argmax(&mut rng, 12, 2);
        req.us.clear();
        req.offsets.clear();
        let resp = svc.argmax_blocking(req);
        assert_eq!(resp.winner, None);
        svc.shutdown();
    }

    #[test]
    fn submit_request_dispatches_both_kinds() {
        let svc = JudgeService::start(None, BatchPolicy::default(), 1).unwrap();
        let mut rng = Rng::new(0x5EA);
        let (treq, twant) = make_request(&mut rng, 12, 0.7);
        let (areq, awant) = make_argmax(&mut rng, 12, 4);
        let tp = svc.submit_request(JudgeRequest::Threshold(treq));
        let ap = svc.submit_request(JudgeRequest::Argmax(areq));
        match tp {
            JudgePending::Threshold(rx) => assert_eq!(rx.recv().unwrap().decision, twant),
            JudgePending::Argmax(_) => panic!("wrong reply kind"),
        }
        match ap {
            JudgePending::Argmax(rx) => assert_eq!(rx.recv().unwrap().winner, awant),
            JudgePending::Threshold(_) => panic!("wrong reply kind"),
        }
        svc.shutdown();
    }
}
