//! Size-bucketing and batch formation policy.
//!
//! Dense queries are identity-padded to the smallest artifact bucket that
//! fits (padding is exact — see model.pad_query); queued requests sharing
//! a bucket are drained together up to the bucket's batch width, waiting
//! at most `max_wait` for stragglers.

use std::time::Duration;

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// max requests drained into one dispatch (bounded by the artifact's
    /// batch width at dispatch time); also the panel width for coalesced
    /// native block runs
    pub max_batch: usize,
    /// how long the drainer waits for the batch to fill
    pub max_wait: Duration,
    /// queries with dim above this always take the native path
    pub native_threshold: usize,
    /// drain co-keyed native-path requests (same `op_key`, dim, and
    /// spectrum window) into one `quadrature::block::BlockGql` run
    /// instead of N scalar runs
    pub coalesce: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            native_threshold: 256,
            coalesce: true,
        }
    }
}

impl BatchPolicy {
    /// Reject configurations the drainer cannot make progress under:
    /// `max_batch == 0` would form empty batches forever and
    /// `native_threshold == 0` would starve every query of both paths.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("BatchPolicy.max_batch must be >= 1 (0 would spin the drainer)".into());
        }
        if self.native_threshold == 0 {
            return Err(
                "BatchPolicy.native_threshold must be >= 1 (0 starves every query)".into(),
            );
        }
        Ok(())
    }
}

/// Maps query dimensions to artifact bucket sizes.
#[derive(Clone, Debug)]
pub struct Bucketizer {
    /// sorted ascending bucket sizes available as artifacts
    sizes: Vec<usize>,
}

impl Bucketizer {
    pub fn new(mut sizes: Vec<usize>) -> Self {
        sizes.sort_unstable();
        sizes.dedup();
        Bucketizer { sizes }
    }

    /// Smallest bucket ≥ dim (None: dim exceeds all buckets → native path).
    pub fn bucket(&self, dim: usize) -> Option<usize> {
        self.sizes.iter().copied().find(|&s| s >= dim)
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Padding waste ratio for a query at this dim (diagnostics): padded
    /// area / true area.
    pub fn waste(&self, dim: usize) -> Option<f64> {
        self.bucket(dim)
            .map(|b| (b * b) as f64 / (dim * dim).max(1) as f64)
    }

    /// Same-operator coalescing mode: positions in `queued` whose
    /// coalesce key equals `first`'s, oldest-first up to `limit` — the
    /// requests the drainer folds into one native block run. `None` keys
    /// (no `op_key`) never coalesce.
    pub fn coalesce_positions<K: PartialEq>(
        first: &K,
        queued: &[Option<K>],
        limit: usize,
    ) -> Vec<usize> {
        queued
            .iter()
            .enumerate()
            .filter(|(_, k)| k.as_ref() == Some(first))
            .map(|(i, _)| i)
            .take(limit)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = Bucketizer::new(vec![64, 16, 32, 64]);
        assert_eq!(b.sizes(), &[16, 32, 64]);
        assert_eq!(b.bucket(1), Some(16));
        assert_eq!(b.bucket(16), Some(16));
        assert_eq!(b.bucket(17), Some(32));
        assert_eq!(b.bucket(65), None);
    }

    #[test]
    fn waste_ratio() {
        let b = Bucketizer::new(vec![16]);
        assert_eq!(b.waste(16), Some(1.0));
        assert_eq!(b.waste(8), Some(4.0));
        assert_eq!(b.waste(17), None);
    }

    #[test]
    fn default_policy_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.native_threshold >= 64);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_rejects_zero_knobs() {
        let mut p = BatchPolicy::default();
        p.max_batch = 0;
        assert!(p.validate().unwrap_err().contains("max_batch"));
        let mut p = BatchPolicy::default();
        p.native_threshold = 0;
        assert!(p.validate().unwrap_err().contains("native_threshold"));
    }

    #[test]
    fn coalesce_positions_matches_keys_oldest_first() {
        let key = (7u64, 16usize);
        let queued = vec![
            Some((7u64, 16usize)), // match
            Some((7, 32)),         // same op, different dim: no
            None,                  // unkeyed: no
            Some((8, 16)),         // different op: no
            Some((7, 16)),         // match
            Some((7, 16)),         // match (cut by limit)
        ];
        assert_eq!(Bucketizer::coalesce_positions(&key, &queued, 2), vec![0, 4]);
        assert_eq!(
            Bucketizer::coalesce_positions(&key, &queued, 8),
            vec![0, 4, 5]
        );
        assert!(Bucketizer::coalesce_positions(&key, &[], 4).is_empty());
    }
}
