//! Size-bucketing and batch formation policy.
//!
//! Dense queries are identity-padded to the smallest artifact bucket that
//! fits (padding is exact — see model.pad_query); queued requests sharing
//! a bucket are drained together up to the bucket's batch width, waiting
//! at most `max_wait` for stragglers.

use std::time::Duration;

/// Batch formation policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// max requests drained into one dispatch (bounded by the artifact's
    /// batch width at dispatch time); also the panel width for coalesced
    /// native block runs
    pub max_batch: usize,
    /// how long the drainer waits for the batch to fill
    pub max_wait: Duration,
    /// queries with dim above this always take the native path
    pub native_threshold: usize,
    /// drain queued keyed native-path requests — any operator, either
    /// kind — into one multi-operator `quadrature::engine::Engine` run
    /// (one session per coalesce key) instead of N scalar runs
    pub coalesce: bool,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_batch: 8,
            max_wait: Duration::from_micros(200),
            native_threshold: 256,
            coalesce: true,
        }
    }
}

impl BatchPolicy {
    /// Reject configurations the drainer cannot make progress under:
    /// `max_batch == 0` would form empty batches forever and
    /// `native_threshold == 0` would starve every query of both paths.
    pub fn validate(&self) -> Result<(), String> {
        if self.max_batch == 0 {
            return Err("BatchPolicy.max_batch must be >= 1 (0 would spin the drainer)".into());
        }
        if self.native_threshold == 0 {
            return Err(
                "BatchPolicy.native_threshold must be >= 1 (0 starves every query)".into(),
            );
        }
        Ok(())
    }
}

/// Maps query dimensions to artifact bucket sizes.
#[derive(Clone, Debug)]
pub struct Bucketizer {
    /// sorted ascending bucket sizes available as artifacts
    sizes: Vec<usize>,
}

impl Bucketizer {
    pub fn new(mut sizes: Vec<usize>) -> Self {
        sizes.sort_unstable();
        sizes.dedup();
        Bucketizer { sizes }
    }

    /// Smallest bucket ≥ dim (None: dim exceeds all buckets → native path).
    pub fn bucket(&self, dim: usize) -> Option<usize> {
        self.sizes.iter().copied().find(|&s| s >= dim)
    }

    pub fn sizes(&self) -> &[usize] {
        &self.sizes
    }

    /// Padding waste ratio for a query at this dim (diagnostics): padded
    /// area / true area.
    pub fn waste(&self, dim: usize) -> Option<f64> {
        self.bucket(dim)
            .map(|b| (b * b) as f64 / (dim * dim).max(1) as f64)
    }

    // `coalesce_positions` lived here while the native drain selected
    // requests one coalesce key at a time; ISSUE 5 replaced that drain
    // with the multi-operator engine client (`drain_keyed` pulls every
    // keyed request and the engine partitions by key), so the helper is
    // gone rather than left as misleading dead machinery.
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = Bucketizer::new(vec![64, 16, 32, 64]);
        assert_eq!(b.sizes(), &[16, 32, 64]);
        assert_eq!(b.bucket(1), Some(16));
        assert_eq!(b.bucket(16), Some(16));
        assert_eq!(b.bucket(17), Some(32));
        assert_eq!(b.bucket(65), None);
    }

    #[test]
    fn waste_ratio() {
        let b = Bucketizer::new(vec![16]);
        assert_eq!(b.waste(16), Some(1.0));
        assert_eq!(b.waste(8), Some(4.0));
        assert_eq!(b.waste(17), None);
    }

    #[test]
    fn default_policy_sane() {
        let p = BatchPolicy::default();
        assert!(p.max_batch >= 1);
        assert!(p.native_threshold >= 64);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validate_rejects_zero_knobs() {
        let mut p = BatchPolicy::default();
        p.max_batch = 0;
        assert!(p.validate().unwrap_err().contains("max_batch"));
        let mut p = BatchPolicy::default();
        p.native_threshold = 0;
        assert!(p.validate().unwrap_err().contains("native_threshold"));
    }

}
