//! The serving layer: a BIF **judge service** in the style of an
//! inference router — clients submit "is `t < u^T A^{-1} u`?" queries; a
//! router sends small dense queries to the PJRT artifacts (bucketed +
//! dynamically batched, vLLM-router style) and everything else to the
//! native sparse GQL path. Python is never on this path.
//!
//! Threading: a worker pool over a condvar'd queue (tokio is not in the
//! offline crate cache; the pool is ~the same shape an async runtime would
//! give this CPU-bound workload anyway).

pub mod batcher;
pub mod service;

pub use batcher::{BatchPolicy, Bucketizer};
pub use service::{
    ArgmaxRequest, ArgmaxResponse, JudgePending, JudgeRequest, JudgeResponse, JudgeService,
    RoutePath, ThresholdRequest,
};
