//! Column-major dense matrix. Deliberately small API: exactly what the
//! baselines, generators and tests need — no general BLAS pretensions.

use crate::sparse::SymOp;

/// Column-major `n x m` dense matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct DMat {
    pub nrows: usize,
    pub ncols: usize,
    /// data[i + j * nrows] = A[i, j]
    pub data: Vec<f64>,
}

impl DMat {
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        DMat { nrows, ncols, data: vec![0.0; nrows * ncols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    pub fn from_fn(nrows: usize, ncols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(nrows, ncols);
        for j in 0..ncols {
            for i in 0..nrows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    /// Build from row-major slice (handy in tests).
    pub fn from_rows(nrows: usize, ncols: usize, rows: &[f64]) -> Self {
        assert_eq!(rows.len(), nrows * ncols);
        Self::from_fn(nrows, ncols, |i, j| rows[i * ncols + j])
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[i + j * self.nrows]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.data[i + j * self.nrows] = v;
    }

    #[inline]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    #[inline]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.data[j * self.nrows..(j + 1) * self.nrows]
    }

    /// y = A x (column-major: accumulate columns — stride-1 inner loop).
    pub fn matvec(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.fill(0.0);
        for j in 0..self.ncols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            let col = self.col(j);
            for (yi, &aij) in y.iter_mut().zip(col) {
                *yi += aij * xj;
            }
        }
    }

    /// (A + A^T) / 2 in place (square only).
    pub fn symmetrize(&mut self) {
        assert_eq!(self.nrows, self.ncols);
        for j in 0..self.ncols {
            for i in 0..j {
                let m = 0.5 * (self.get(i, j) + self.get(j, i));
                self.set(i, j, m);
                self.set(j, i, m);
            }
        }
    }

    /// A += s * I.
    pub fn shift_diag(&mut self, s: f64) {
        assert_eq!(self.nrows, self.ncols);
        for i in 0..self.nrows {
            let v = self.get(i, i) + s;
            self.set(i, i, v);
        }
    }

    /// Principal submatrix A[idx, idx].
    pub fn principal_submatrix(&self, idx: &[usize]) -> DMat {
        DMat::from_fn(idx.len(), idx.len(), |i, j| self.get(idx[i], idx[j]))
    }

    /// Max |A[i,j] - A[j,i]| (symmetry check in tests).
    pub fn asymmetry(&self) -> f64 {
        let mut m: f64 = 0.0;
        for j in 0..self.ncols {
            for i in 0..j {
                m = m.max((self.get(i, j) - self.get(j, i)).abs());
            }
        }
        m
    }
}

impl SymOp for DMat {
    fn dim(&self) -> usize {
        debug_assert_eq!(self.nrows, self.ncols);
        self.nrows
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        DMat::matvec(self, x, y)
    }

    fn diagonal(&self) -> Vec<f64> {
        (0..self.nrows).map(|i| self.get(i, i)).collect()
    }

    fn nbytes(&self) -> usize {
        std::mem::size_of::<DMat>() + self.data.capacity() * std::mem::size_of::<f64>()
    }
}

/// x · y
#[inline]
pub fn dot(x: &[f64], y: &[f64]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// ||x||_2
#[inline]
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// y += a * x
#[inline]
pub fn axpy(a: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// x *= a
#[inline]
pub fn scale(a: f64, x: &mut [f64]) {
    for xi in x {
        *xi *= a;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_set_col_major_layout() {
        let mut m = DMat::zeros(2, 3);
        m.set(1, 2, 7.0);
        assert_eq!(m.get(1, 2), 7.0);
        assert_eq!(m.data[1 + 2 * 2], 7.0);
    }

    #[test]
    fn from_rows_matches_row_major_reading() {
        let m = DMat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.get(0, 1), 2.0);
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn matvec_known_values() {
        let m = DMat::from_rows(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let mut y = vec![0.0; 2];
        m.matvec(&[1.0, 1.0], &mut y);
        assert_eq!(y, vec![3.0, 7.0]);
    }

    #[test]
    fn symmetrize_and_asymmetry() {
        let mut m = DMat::from_rows(2, 2, &[0.0, 2.0, 4.0, 0.0]);
        assert_eq!(m.asymmetry(), 2.0);
        m.symmetrize();
        assert_eq!(m.asymmetry(), 0.0);
        assert_eq!(m.get(0, 1), 3.0);
    }

    #[test]
    fn principal_submatrix_selects() {
        let m = DMat::from_rows(3, 3, &[1., 2., 3., 4., 5., 6., 7., 8., 9.]);
        let s = m.principal_submatrix(&[0, 2]);
        assert_eq!(s.get(0, 1), 3.0);
        assert_eq!(s.get(1, 0), 7.0);
        assert_eq!(s.get(1, 1), 9.0);
    }

    #[test]
    fn blas1_helpers() {
        let x = vec![1.0, 2.0];
        let mut y = vec![10.0, 20.0];
        assert_eq!(dot(&x, &y), 50.0);
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![12.0, 24.0]);
        scale(0.5, &mut y);
        assert_eq!(y, vec![6.0, 12.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    fn symop_impl_consistent() {
        let m = DMat::from_rows(2, 2, &[2.0, 1.0, 1.0, 3.0]);
        let op: &dyn SymOp = &m;
        assert_eq!(op.dim(), 2);
        assert_eq!(op.diagonal(), vec![2.0, 3.0]);
        let mut y = vec![0.0; 2];
        op.matvec(&[1.0, 0.0], &mut y);
        assert_eq!(y, vec![2.0, 1.0]);
    }
}
