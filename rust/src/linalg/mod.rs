//! Dense linear-algebra substrate: column-major matrices, Cholesky
//! (the exact-BIF baseline the paper's "original algorithms" use),
//! incremental inverse maintenance, and a symmetric eigensolver
//! (Householder tridiagonalization + implicit-shift QL) for generators
//! and spectrum ground truth.

pub mod chol;
pub mod dense;
pub mod eig;
pub mod inverse;

pub use chol::Cholesky;
pub use dense::DMat;
pub use eig::{sym_eigenvalues, tridiag_eig_weights, tridiag_eigenvalues};
pub use inverse::MaintainedInverse;
