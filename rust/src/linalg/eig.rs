//! Symmetric eigensolver: Householder tridiagonalization + implicit-shift
//! QL on the tridiagonal (eigenvalues only).
//!
//! Used by (a) the synthetic generators, which — like the paper's §4.4
//! setup — shift the diagonal so the smallest eigenvalue hits a prescribed
//! λ₁, and (b) tests that need spectrum ground truth (condition numbers for
//! the rate theorems, Jacobi-matrix spectra, Lobatto prescribed-eigenvalue
//! checks).  O(n³); fine up to the few-thousand sizes the generators use.

use super::dense::DMat;

/// Eigenvalues (ascending) of a symmetric matrix. Reads both triangles
/// (averages them), so slight asymmetry from rounding is harmless.
pub fn sym_eigenvalues(a: &DMat) -> Vec<f64> {
    assert_eq!(a.nrows, a.ncols);
    let (d, e) = householder_tridiag(a);
    tridiag_eigenvalues(&d, &e)
}

/// Householder reduction of a symmetric matrix to tridiagonal form.
/// Returns (diagonal, off-diagonal) with `off[i]` linking i and i+1.
/// (Eigenvalue-only variant of Numerical Recipes `tred2`.)
fn householder_tridiag(a_in: &DMat) -> (Vec<f64>, Vec<f64>) {
    let n = a_in.nrows;
    // Work on a symmetrized copy, row-major style via DMat accessor.
    let mut a = a_in.clone();
    a.symmetrize();
    let mut d = vec![0.0; n];
    let mut e = vec![0.0; n]; // e[i] couples (i-1, i) during the reduction

    for i in (1..n).rev() {
        let l = i; // elements 0..l of row i are being annihilated
        let mut h = 0.0;
        if l > 1 {
            let scale: f64 = (0..l).map(|k| a.get(i, k).abs()).sum();
            if scale == 0.0 {
                e[i] = a.get(i, l - 1);
            } else {
                for k in 0..l {
                    let v = a.get(i, k) / scale;
                    a.set(i, k, v);
                    h += v * v;
                }
                let mut f = a.get(i, l - 1);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a.set(i, l - 1, f - g);
                f = 0.0;
                for j in 0..l {
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a.get(j, k) * a.get(i, k);
                    }
                    for k in (j + 1)..l {
                        g += a.get(k, j) * a.get(i, k);
                    }
                    e[j] = g / h;
                    f += e[j] * a.get(i, j);
                }
                let hh = f / (h + h);
                for j in 0..l {
                    let fj = a.get(i, j);
                    let gj = e[j] - hh * fj;
                    e[j] = gj;
                    for k in 0..=j {
                        let v = a.get(j, k) - fj * e[k] - gj * a.get(i, k);
                        a.set(j, k, v);
                    }
                }
            }
        } else {
            e[i] = a.get(i, l - 1);
        }
        d[i] = h;
    }
    for i in 0..n {
        d[i] = a.get(i, i);
    }
    // Shift e left so e[i] couples (i, i+1), matching tridiag_eigenvalues.
    let mut off = vec![0.0; n.saturating_sub(1)];
    for i in 1..n {
        off[i - 1] = e[i];
    }
    (d, off)
}

/// Eigenvalues (ascending) of a symmetric tridiagonal matrix plus the
/// *squared first components* of the corresponding orthonormal
/// eigenvectors — exactly the Gauss-quadrature weights of the Jacobi
/// matrix (Golub–Welsch). Same implicit-shift QL as
/// [`tridiag_eigenvalues`], but each plane rotation is also applied to a
/// single carried row (initialized to `e1`), so the cost stays O(k²)
/// instead of the O(k³) of a full eigenvector accumulation. Used by the
/// stochastic Lanczos quadrature layer, which turns recorded lane
/// recurrence coefficients into `Σ wⱼ f(λⱼ)` for arbitrary spectral `f`.
pub fn tridiag_eig_weights(d_in: &[f64], e_in: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let n = d_in.len();
    assert_eq!(e_in.len(), n.saturating_sub(1));
    if n == 0 {
        return (vec![], vec![]);
    }
    let mut d = d_in.to_vec();
    let mut e = e_in.to_vec();
    e.push(0.0);
    // first row of the accumulated rotation product: z[j] converges to
    // the first component of eigenvector j
    let mut z = vec![0.0; n];
    z[0] = 1.0;

    for l in 0..n {
        let mut iter = 0;
        loop {
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 64, "QL failed to converge");
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            let mut underflow = false;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    underflow = true;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                f = z[i + 1];
                z[i + 1] = s * z[i] + c * f;
                z[i] = c * z[i] - s * f;
            }
            if underflow {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| d[a].partial_cmp(&d[b]).unwrap());
    let lam: Vec<f64> = idx.iter().map(|&i| d[i]).collect();
    let w: Vec<f64> = idx.iter().map(|&i| z[i] * z[i]).collect();
    (lam, w)
}

/// Eigenvalues (ascending) of a symmetric tridiagonal matrix with diagonal
/// `d` and off-diagonal `e` (`e[i]` couples i and i+1). Implicit-shift QL
/// with Wilkinson shift; eigenvalue-only variant of `tqli`.
pub fn tridiag_eigenvalues(d_in: &[f64], e_in: &[f64]) -> Vec<f64> {
    let n = d_in.len();
    assert_eq!(e_in.len(), n.saturating_sub(1));
    if n == 0 {
        return vec![];
    }
    let mut d = d_in.to_vec();
    let mut e = e_in.to_vec();
    e.push(0.0);

    for l in 0..n {
        let mut iter = 0;
        loop {
            // find a small off-diagonal element to split at
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            assert!(iter < 64, "QL failed to converge");
            // Wilkinson shift
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = g.hypot(1.0);
            g = d[m] - d[l] + e[l] / (g + r.copysign(g));
            let (mut s, mut c) = (1.0, 1.0);
            let mut p = 0.0;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = f.hypot(g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                f = 0.0;
                let _ = f;
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    d.sort_by(|a, b| a.partial_cmp(b).unwrap());
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;

    fn random_sym(rng: &mut Rng, n: usize) -> DMat {
        let mut a = DMat::from_fn(n, n, |_, _| rng.normal());
        a.symmetrize();
        a
    }

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = DMat::eye(4);
        a.set(0, 0, 3.0);
        a.set(1, 1, -1.0);
        a.set(2, 2, 0.5);
        let ev = sym_eigenvalues(&a);
        let want = [-1.0, 0.5, 1.0, 3.0];
        for (g, w) in ev.iter().zip(want) {
            assert_close(*g, w, 1e-12, 1e-12);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] -> 1, 3
        let a = DMat::from_rows(2, 2, &[2.0, 1.0, 1.0, 2.0]);
        let ev = sym_eigenvalues(&a);
        assert_close(ev[0], 1.0, 1e-12, 1e-12);
        assert_close(ev[1], 3.0, 1e-12, 1e-12);
    }

    #[test]
    fn tridiag_toeplitz_has_closed_form() {
        // diag 2, off -1, size n: eigenvalues 2 - 2cos(kπ/(n+1))
        let n = 12;
        let d = vec![2.0; n];
        let e = vec![-1.0; n - 1];
        let ev = tridiag_eigenvalues(&d, &e);
        for (k, g) in ev.iter().enumerate() {
            let w = 2.0 - 2.0 * (std::f64::consts::PI * (k + 1) as f64 / (n as f64 + 1.0)).cos();
            assert_close(*g, w, 1e-10, 1e-10);
        }
    }

    #[test]
    fn trace_and_frobenius_preserved() {
        forall(20, 0x51D, |rng| {
            let n = 2 + rng.below(14);
            let a = random_sym(rng, n);
            let ev = sym_eigenvalues(&a);
            let tr: f64 = (0..n).map(|i| a.get(i, i)).sum();
            assert_close(ev.iter().sum::<f64>(), tr, 1e-9, 1e-9);
            let fro2: f64 = (0..n)
                .flat_map(|i| (0..n).map(move |j| (i, j)))
                .map(|(i, j)| a.get(i, j) * a.get(i, j))
                .sum();
            assert_close(ev.iter().map(|l| l * l).sum::<f64>(), fro2, 1e-9, 1e-9);
        });
    }

    #[test]
    fn eigenvalues_match_characteristic_poly_roots_3x3() {
        forall(20, 0x3A3, |rng| {
            let a = random_sym(rng, 3);
            let ev = sym_eigenvalues(&a);
            // det(A - λI) ≈ 0 for each reported eigenvalue
            for &l in &ev {
                let m = |i: usize, j: usize| a.get(i, j) - if i == j { l } else { 0.0 };
                let det = m(0, 0) * (m(1, 1) * m(2, 2) - m(1, 2) * m(2, 1))
                    - m(0, 1) * (m(1, 0) * m(2, 2) - m(1, 2) * m(2, 0))
                    + m(0, 2) * (m(1, 0) * m(2, 1) - m(1, 1) * m(2, 0));
                // scale by norm^3 for a relative check
                let scale: f64 = ev.iter().map(|x| x.abs()).fold(1.0, f64::max);
                assert!(det.abs() < 1e-8 * scale.powi(3) + 1e-8, "det={det}");
            }
        });
    }

    #[test]
    fn weights_match_eigenvalues_and_sum_to_one() {
        forall(20, 0x71D, |rng| {
            let n = 1 + rng.below(14);
            let d: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let e: Vec<f64> = (0..n.saturating_sub(1)).map(|_| rng.normal()).collect();
            let (lam, w) = tridiag_eig_weights(&d, &e);
            let plain = tridiag_eigenvalues(&d, &e);
            assert_eq!(lam.len(), n);
            for (a, b) in lam.iter().zip(&plain) {
                assert_close(*a, *b, 1e-10, 1e-10);
            }
            // the carried row is a unit vector under orthogonal rotations
            assert_close(w.iter().sum::<f64>(), 1.0, 1e-10, 1e-10);
            assert!(w.iter().all(|&x| x >= 0.0));
            // moment check: Σ wⱼ λⱼ = e₁ᵀ T e₁ = d[0]
            assert_close(
                lam.iter().zip(&w).map(|(l, wi)| l * wi).sum::<f64>(),
                d[0],
                1e-9,
                1e-9,
            );
        });
    }

    #[test]
    fn spd_eigenvalues_positive() {
        forall(10, 0x5bd, |rng| {
            let n = 2 + rng.below(10);
            let b = random_sym(rng, n);
            // b^2 + I is SPD
            let mut a = DMat::zeros(n, n);
            for i in 0..n {
                for j in 0..n {
                    let mut s = if i == j { 1.0 } else { 0.0 };
                    for k in 0..n {
                        s += b.get(i, k) * b.get(k, j);
                    }
                    a.set(i, j, s);
                }
            }
            let ev = sym_eigenvalues(&a);
            assert!(ev[0] >= 1.0 - 1e-9, "λmin={}", ev[0]);
        });
    }
}
