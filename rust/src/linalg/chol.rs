//! Cholesky factorization `A = L L^T` — the exact-BIF workhorse.
//!
//! The paper's baselines ("original algorithm" columns of Fig. 2 / Table 2)
//! evaluate `u^T A^{-1} u` by a direct solve; this module provides that,
//! plus `log det` (for the double-greedy objective) and an *appending*
//! update (`extend`) used by the smarter incremental baseline in
//! [`crate::linalg::inverse`]-adjacent ablations.

use super::dense::DMat;

/// Lower-triangular Cholesky factor of an SPD matrix.
#[derive(Clone, Debug)]
pub struct Cholesky {
    /// Lower factor, column-major, dimension n.
    l: DMat,
}

#[derive(Debug, Clone, PartialEq)]
pub enum CholError {
    /// Leading minor at this index is not positive definite.
    NotPositiveDefinite(usize),
}

impl std::fmt::Display for CholError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CholError::NotPositiveDefinite(k) => {
                write!(f, "matrix not positive definite (pivot {k})")
            }
        }
    }
}

impl std::error::Error for CholError {}

impl Cholesky {
    /// Factor an SPD matrix (reads the lower triangle).
    pub fn factor(a: &DMat) -> Result<Self, CholError> {
        assert_eq!(a.nrows, a.ncols);
        let n = a.nrows;
        let mut l = DMat::zeros(n, n);
        for j in 0..n {
            // diagonal
            let mut d = a.get(j, j);
            for k in 0..j {
                let ljk = l.get(j, k);
                d -= ljk * ljk;
            }
            if d <= 0.0 || !d.is_finite() {
                return Err(CholError::NotPositiveDefinite(j));
            }
            let dj = d.sqrt();
            l.set(j, j, dj);
            // column below diagonal
            for i in (j + 1)..n {
                let mut s = a.get(i, j);
                for k in 0..j {
                    s -= l.get(i, k) * l.get(j, k);
                }
                l.set(i, j, s / dj);
            }
        }
        Ok(Cholesky { l })
    }

    pub fn dim(&self) -> usize {
        self.l.nrows
    }

    pub fn factor_matrix(&self) -> &DMat {
        &self.l
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.dim();
        assert_eq!(x.len(), n);
        // forward: L y = b (column-oriented, stride-1 updates)
        for j in 0..n {
            x[j] /= self.l.get(j, j);
            let xj = x[j];
            let col = self.l.col(j);
            for i in (j + 1)..n {
                x[i] -= col[i] * xj;
            }
        }
        // backward: L^T x = y
        for j in (0..n).rev() {
            let col = self.l.col(j);
            let mut s = x[j];
            for i in (j + 1)..n {
                s -= col[i] * x[i];
            }
            x[j] = s / col[j];
        }
    }

    /// The bilinear inverse form `u^T A^{-1} u` — exact ground truth.
    pub fn bif(&self, u: &[f64]) -> f64 {
        // u^T A^{-1} u = ||L^{-1} u||^2: forward solve only.
        let n = self.dim();
        assert_eq!(u.len(), n);
        let mut y = u.to_vec();
        for j in 0..n {
            y[j] /= self.l.get(j, j);
            let yj = y[j];
            let col = self.l.col(j);
            for i in (j + 1)..n {
                y[i] -= col[i] * yj;
            }
        }
        y.iter().map(|v| v * v).sum()
    }

    /// General bilinear form `u^T A^{-1} v`.
    pub fn bif2(&self, u: &[f64], v: &[f64]) -> f64 {
        let x = self.solve(v);
        u.iter().zip(&x).map(|(a, b)| a * b).sum()
    }

    /// log det A = 2 Σ log L_jj.
    pub fn logdet(&self) -> f64 {
        (0..self.dim()).map(|j| 2.0 * self.l.get(j, j).ln()).sum()
    }

    /// Append one row/column (the SPD matrix grows by one): given the new
    /// column `a_new = A[0..n, n]` and diagonal entry `a_nn`, extend the
    /// factor in O(n^2). Used by the incremental double-greedy baseline.
    pub fn extend(&mut self, a_new: &[f64], a_nn: f64) -> Result<(), CholError> {
        let n = self.dim();
        assert_eq!(a_new.len(), n);
        // Solve L w = a_new
        let mut w = a_new.to_vec();
        for j in 0..n {
            w[j] /= self.l.get(j, j);
            let wj = w[j];
            let col = self.l.col(j);
            for i in (j + 1)..n {
                w[i] -= col[i] * wj;
            }
        }
        let d = a_nn - w.iter().map(|x| x * x).sum::<f64>();
        if d <= 0.0 || !d.is_finite() {
            return Err(CholError::NotPositiveDefinite(n));
        }
        // Grow the factor
        let mut l = DMat::zeros(n + 1, n + 1);
        for j in 0..n {
            for i in j..n {
                l.set(i, j, self.l.get(i, j));
            }
            l.set(n, j, w[j]);
        }
        l.set(n, n, d.sqrt());
        self.l = l;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;

    pub fn random_spd(rng: &mut Rng, n: usize) -> DMat {
        // A = B B^T + n * I: well-conditioned SPD
        let b = DMat::from_fn(n, n, |_, _| rng.normal());
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b.get(i, k) * b.get(j, k);
                }
                a.set(i, j, s);
            }
        }
        a.shift_diag(n as f64);
        a
    }

    #[test]
    fn factor_reconstructs_matrix() {
        forall(20, 0xC0DE, |rng| {
            let n = 1 + rng.below(12);
            let a = random_spd(rng, n);
            let ch = Cholesky::factor(&a).unwrap();
            let l = ch.factor_matrix();
            for i in 0..n {
                for j in 0..n {
                    let mut s = 0.0;
                    for k in 0..n {
                        s += l.get(i, k) * l.get(j, k);
                    }
                    assert_close(s, a.get(i, j), 1e-10, 1e-10);
                }
            }
        });
    }

    #[test]
    fn solve_satisfies_system() {
        forall(20, 0xBEEF, |rng| {
            let n = 1 + rng.below(16);
            let a = random_spd(rng, n);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ch = Cholesky::factor(&a).unwrap();
            let x = ch.solve(&b);
            let mut ax = vec![0.0; n];
            a.matvec(&x, &mut ax);
            for (axi, bi) in ax.iter().zip(&b) {
                assert_close(*axi, *bi, 1e-9, 1e-9);
            }
        });
    }

    #[test]
    fn bif_matches_solve_route() {
        forall(20, 0xF00D, |rng| {
            let n = 1 + rng.below(16);
            let a = random_spd(rng, n);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ch = Cholesky::factor(&a).unwrap();
            let direct = ch.bif(&u);
            let via_solve: f64 = u.iter().zip(ch.solve(&u)).map(|(a, b)| a * b).sum();
            assert_close(direct, via_solve, 1e-10, 1e-12);
            assert!(direct >= 0.0);
        });
    }

    #[test]
    fn bif2_symmetry() {
        forall(10, 0xAB, |rng| {
            let n = 2 + rng.below(10);
            let a = random_spd(rng, n);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ch = Cholesky::factor(&a).unwrap();
            assert_close(ch.bif2(&u, &v), ch.bif2(&v, &u), 1e-9, 1e-10);
        });
    }

    #[test]
    fn logdet_known_value() {
        let mut a = DMat::eye(3);
        a.set(0, 0, 4.0);
        a.set(1, 1, 9.0);
        let ch = Cholesky::factor(&a).unwrap();
        assert_close(ch.logdet(), (36.0f64).ln(), 1e-12, 0.0);
    }

    #[test]
    fn not_pd_detected() {
        let mut a = DMat::eye(2);
        a.set(1, 1, -1.0);
        assert_eq!(
            Cholesky::factor(&a).unwrap_err(),
            CholError::NotPositiveDefinite(1)
        );
    }

    #[test]
    fn extend_matches_full_factorization() {
        forall(20, 0xE11, |rng| {
            let n = 2 + rng.below(10);
            let a = random_spd(rng, n);
            // factor the leading (n-1) block, then extend with last col
            let idx: Vec<usize> = (0..n - 1).collect();
            let a0 = a.principal_submatrix(&idx);
            let mut ch = Cholesky::factor(&a0).unwrap();
            let new_col: Vec<f64> = (0..n - 1).map(|i| a.get(i, n - 1)).collect();
            ch.extend(&new_col, a.get(n - 1, n - 1)).unwrap();
            let full = Cholesky::factor(&a).unwrap();
            for i in 0..n {
                for j in 0..=i {
                    assert_close(
                        ch.factor_matrix().get(i, j),
                        full.factor_matrix().get(i, j),
                        1e-9,
                        1e-10,
                    );
                }
            }
        });
    }
}
