//! Incrementally maintained inverse of a growing/shrinking principal
//! submatrix — the *smarter-than-the-paper* baseline used in ablations.
//!
//! The paper's "original algorithm" baselines do a fresh O(k³) solve per
//! transition.  A stronger classical baseline maintains `M = (L_Y)^{-1}`
//! under single-element insertions (block-inverse formula) and deletions
//! (Schur complement extraction), each O(k²).  `bench_ablation` compares
//! quadrature against BOTH, so the reported speedups aren't an artifact of
//! a weak baseline.

use super::dense::DMat;

/// Dense inverse of `L_Y` for a dynamic index set `Y`, with O(k²) updates.
#[derive(Clone, Debug)]
pub struct MaintainedInverse {
    /// current index set (global indices), in insertion order
    members: Vec<usize>,
    /// inv[(i, j)] = (L_Y)^{-1}[i, j] in `members` order
    inv: DMat,
}

impl MaintainedInverse {
    pub fn empty() -> Self {
        MaintainedInverse { members: vec![], inv: DMat::zeros(0, 0) }
    }

    pub fn members(&self) -> &[usize] {
        &self.members
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn inverse(&self) -> &DMat {
        &self.inv
    }

    /// Schur complement of the candidate `v`: `L_vv - L_vY M L_Yv`.
    /// This *is* the DPP transition quantity; also the pivot the insert
    /// uses. `col[i] = L[members[i], v]`, `diag = L[v, v]`.
    pub fn schur(&self, col: &[f64], diag: f64) -> f64 {
        let k = self.len();
        assert_eq!(col.len(), k);
        if k == 0 {
            return diag;
        }
        let mut m_col = vec![0.0; k];
        self.inv.matvec(col, &mut m_col);
        diag - col.iter().zip(&m_col).map(|(a, b)| a * b).sum::<f64>()
    }

    /// Insert global index `v` with kernel column `col` (vs current members)
    /// and diagonal `diag`. O(k²) via the block-inverse formula. Fails
    /// (returns false, no change) if the Schur pivot is not positive.
    pub fn insert(&mut self, v: usize, col: &[f64], diag: f64) -> bool {
        let k = self.len();
        let s = self.schur(col, diag);
        if s <= 0.0 || !s.is_finite() {
            return false;
        }
        let mut m_col = vec![0.0; k];
        self.inv.matvec(col, &mut m_col);
        let inv_s = 1.0 / s;
        let mut new_inv = DMat::zeros(k + 1, k + 1);
        for j in 0..k {
            for i in 0..k {
                new_inv.set(i, j, self.inv.get(i, j) + m_col[i] * m_col[j] * inv_s);
            }
        }
        for i in 0..k {
            new_inv.set(i, k, -m_col[i] * inv_s);
            new_inv.set(k, i, -m_col[i] * inv_s);
        }
        new_inv.set(k, k, inv_s);
        self.inv = new_inv;
        self.members.push(v);
        true
    }

    /// Remove global index `v` (must be present). O(k²) Schur extraction:
    /// M' = M[rest,rest] - M[rest,p] M[p,rest] / M[p,p].
    pub fn remove(&mut self, v: usize) {
        let p = self
            .members
            .iter()
            .position(|&m| m == v)
            .expect("remove: index not in set");
        let k = self.len();
        let mpp = self.inv.get(p, p);
        let mut new_inv = DMat::zeros(k - 1, k - 1);
        let map = |i: usize| if i < p { i } else { i + 1 };
        for j in 0..k - 1 {
            let gj = map(j);
            for i in 0..k - 1 {
                let gi = map(i);
                let val = self.inv.get(gi, gj)
                    - self.inv.get(gi, p) * self.inv.get(p, gj) / mpp;
                new_inv.set(i, j, val);
            }
        }
        self.inv = new_inv;
        self.members.remove(p);
    }

    /// BIF of an arbitrary vector in members order: `x^T M x`.
    pub fn bif(&self, x: &[f64]) -> f64 {
        let k = self.len();
        assert_eq!(x.len(), k);
        let mut mx = vec![0.0; k];
        self.inv.matvec(x, &mut mx);
        x.iter().zip(&mx).map(|(a, b)| a * b).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;

    fn random_kernel(rng: &mut Rng, n: usize) -> DMat {
        let b = DMat::from_fn(n, n, |_, _| rng.normal());
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let mut s = 0.0;
                for k in 0..n {
                    s += b.get(i, k) * b.get(j, k);
                }
                a.set(i, j, s);
            }
        }
        a.shift_diag(0.5 + n as f64 * 0.1);
        a
    }

    fn check_inverse(mi: &MaintainedInverse, l: &DMat) {
        let k = mi.len();
        let sub = l.principal_submatrix(mi.members());
        // M * sub = I
        for i in 0..k {
            for j in 0..k {
                let mut s = 0.0;
                for t in 0..k {
                    s += mi.inverse().get(i, t) * sub.get(t, j);
                }
                assert_close(s, if i == j { 1.0 } else { 0.0 }, 1e-7, 1e-7);
            }
        }
    }

    #[test]
    fn grows_to_full_inverse() {
        forall(15, 0x111, |rng| {
            let n = 2 + rng.below(10);
            let l = random_kernel(rng, n);
            let mut mi = MaintainedInverse::empty();
            for v in 0..n {
                let col: Vec<f64> = mi.members().iter().map(|&m| l.get(m, v)).collect();
                assert!(mi.insert(v, &col, l.get(v, v)));
            }
            check_inverse(&mi, &l);
        });
    }

    #[test]
    fn random_insert_remove_stays_consistent() {
        forall(15, 0x222, |rng| {
            let n = 4 + rng.below(10);
            let l = random_kernel(rng, n);
            let mut mi = MaintainedInverse::empty();
            for _ in 0..3 * n {
                let v = rng.below(n);
                if mi.members().contains(&v) {
                    mi.remove(v);
                } else {
                    let col: Vec<f64> =
                        mi.members().iter().map(|&m| l.get(m, v)).collect();
                    assert!(mi.insert(v, &col, l.get(v, v)));
                }
            }
            if !mi.is_empty() {
                check_inverse(&mi, &l);
            }
        });
    }

    #[test]
    fn schur_matches_cholesky_bif() {
        forall(15, 0x333, |rng| {
            let n = 3 + rng.below(8);
            let l = random_kernel(rng, n);
            let mut mi = MaintainedInverse::empty();
            for v in 0..n - 1 {
                let col: Vec<f64> = mi.members().iter().map(|&m| l.get(m, v)).collect();
                mi.insert(v, &col, l.get(v, v));
            }
            let v = n - 1;
            let col: Vec<f64> = mi.members().iter().map(|&m| l.get(m, v)).collect();
            let schur = mi.schur(&col, l.get(v, v));
            // vs L_vv - L_vY (L_Y)^{-1} L_Yv via Cholesky
            let idx: Vec<usize> = (0..n - 1).collect();
            let ch = Cholesky::factor(&l.principal_submatrix(&idx)).unwrap();
            let want = l.get(v, v) - ch.bif(&col);
            assert_close(schur, want, 1e-8, 1e-9);
        });
    }

    #[test]
    #[should_panic(expected = "remove: index not in set")]
    fn remove_missing_panics() {
        let mut mi = MaintainedInverse::empty();
        mi.remove(3);
    }
}
