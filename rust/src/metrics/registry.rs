//! Named metrics registry: the aggregation point of the telemetry layer.
//!
//! Every instrumented subsystem ([`ServiceMetrics`](super::ServiceMetrics),
//! [`Engine`](crate::quadrature::engine::Engine),
//! [`Session`](crate::quadrature::query::Session)) publishes its counters,
//! gauges, and histograms into one [`MetricsRegistry`] under dotted names
//! (`engine.rounds`, `service.latency_ns`, ...). A [`Snapshot`] freezes the
//! registry into plain values that the exporters in
//! [`export`](super::export) serialize as JSON or Prometheus exposition
//! text — the `--telemetry <path>` CLI flag is a thin wrapper around
//! `snapshot()` + `write_json`.
//!
//! The registry itself sits **off** the hot paths: subsystems keep their
//! own lock-free/thread-local instruments (atomic counters, per-worker
//! histograms) and export into the registry at harvest points, so
//! registering costs one coarse mutex acquisition per export — never per
//! sample. The mutex is poison-tolerant ([`super::lock_tolerant`]): a
//! panicking exporter cannot take the whole telemetry layer down with it.

use super::{lock_tolerant, Histogram};
use std::collections::BTreeMap;
use std::sync::Mutex;

/// One registered instrument.
#[derive(Clone, Debug)]
enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist(Histogram),
}

/// Frozen value of one instrument at snapshot time.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    /// Monotonic count.
    Counter(u64),
    /// Point-in-time value.
    Gauge(f64),
    /// Summary of a histogram's distribution.
    Hist(HistSummary),
}

/// Percentile summary of a histogram (what the exporters serialize;
/// the full bucket vector never leaves the registry).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl HistSummary {
    fn of(h: &Histogram) -> Self {
        HistSummary {
            count: h.count(),
            mean: h.mean(),
            min: h.min(),
            max: h.max(),
            p50: h.percentile(0.50),
            p90: h.percentile(0.90),
            p99: h.percentile(0.99),
        }
    }
}

/// Frozen registry contents, sorted by metric name.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub entries: Vec<(String, MetricValue)>,
}

impl Snapshot {
    /// Look up one metric by exact name.
    pub fn get(&self, name: &str) -> Option<&MetricValue> {
        self.entries
            .binary_search_by(|(n, _)| n.as_str().cmp(name))
            .ok()
            .map(|i| &self.entries[i].1)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Registry of named counters / gauges / histograms. Shareable across
/// threads (`&self` everywhere); see the module docs for the intended
/// export-at-harvest usage pattern.
///
/// A name's kind is fixed by its first use — writing a gauge value to an
/// existing counter name (or vice versa) replaces the instrument, last
/// writer wins, so exporters that re-publish cumulative stats under the
/// same names stay idempotent.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<BTreeMap<String, Metric>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `delta` to the counter `name` (created at zero on first use).
    pub fn inc_counter(&self, name: &str, delta: u64) {
        let mut m = lock_tolerant(&self.inner);
        match m.get_mut(name) {
            Some(Metric::Counter(c)) => *c += delta,
            _ => {
                m.insert(name.to_string(), Metric::Counter(delta));
            }
        }
    }

    /// Set the counter `name` to an absolute cumulative value (the
    /// idempotent form used when re-exporting subsystem stats).
    pub fn set_counter(&self, name: &str, value: u64) {
        lock_tolerant(&self.inner).insert(name.to_string(), Metric::Counter(value));
    }

    /// Set the gauge `name`.
    pub fn set_gauge(&self, name: &str, value: f64) {
        lock_tolerant(&self.inner).insert(name.to_string(), Metric::Gauge(value));
    }

    /// Record one sample into the histogram `name`.
    pub fn record(&self, name: &str, value: f64) {
        let mut m = lock_tolerant(&self.inner);
        match m.get_mut(name) {
            Some(Metric::Hist(h)) => h.record(value),
            _ => {
                let mut h = Histogram::new();
                h.record(value);
                m.insert(name.to_string(), Metric::Hist(h));
            }
        }
    }

    /// Merge `other` into the histogram `name` (additive).
    pub fn merge_histogram(&self, name: &str, other: &Histogram) {
        let mut m = lock_tolerant(&self.inner);
        match m.get_mut(name) {
            Some(Metric::Hist(h)) => h.merge(other),
            _ => {
                m.insert(name.to_string(), Metric::Hist(other.clone()));
            }
        }
    }

    /// Replace the histogram `name` wholesale (the idempotent form: a
    /// periodic exporter re-publishing a cumulative histogram must not
    /// double-count earlier exports).
    pub fn set_histogram(&self, name: &str, h: Histogram) {
        lock_tolerant(&self.inner).insert(name.to_string(), Metric::Hist(h));
    }

    /// Registered metric count.
    pub fn len(&self) -> usize {
        lock_tolerant(&self.inner).len()
    }

    pub fn is_empty(&self) -> bool {
        lock_tolerant(&self.inner).is_empty()
    }

    /// Freeze the current contents (sorted by name — `BTreeMap` order).
    pub fn snapshot(&self) -> Snapshot {
        let m = lock_tolerant(&self.inner);
        Snapshot {
            entries: m
                .iter()
                .map(|(name, metric)| {
                    let v = match metric {
                        Metric::Counter(c) => MetricValue::Counter(*c),
                        Metric::Gauge(g) => MetricValue::Gauge(*g),
                        Metric::Hist(h) => MetricValue::Hist(HistSummary::of(h)),
                    };
                    (name.clone(), v)
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_and_histograms_round_trip() {
        let reg = MetricsRegistry::new();
        reg.inc_counter("engine.rounds", 3);
        reg.inc_counter("engine.rounds", 2);
        reg.set_gauge("engine.busy_frac", 0.75);
        for v in [10.0, 100.0, 1000.0] {
            reg.record("engine.step_ns", v);
        }
        assert_eq!(reg.len(), 3);
        let snap = reg.snapshot();
        assert_eq!(snap.get("engine.rounds"), Some(&MetricValue::Counter(5)));
        assert_eq!(snap.get("engine.busy_frac"), Some(&MetricValue::Gauge(0.75)));
        match snap.get("engine.step_ns") {
            Some(MetricValue::Hist(h)) => {
                assert_eq!(h.count, 3);
                assert_eq!(h.min, 10.0);
                assert_eq!(h.max, 1000.0);
            }
            other => panic!("wrong kind {other:?}"),
        }
        assert_eq!(snap.get("missing"), None);
    }

    #[test]
    fn snapshot_is_sorted_by_name() {
        let reg = MetricsRegistry::new();
        reg.set_gauge("zz", 1.0);
        reg.set_counter("aa", 1);
        reg.set_counter("mm", 1);
        let names: Vec<&str> =
            reg.snapshot().entries.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["aa", "mm", "zz"]);
    }

    #[test]
    fn set_forms_are_idempotent() {
        let reg = MetricsRegistry::new();
        let mut h = Histogram::new();
        h.record(5.0);
        for _ in 0..3 {
            reg.set_counter("c", 7);
            reg.set_gauge("g", 2.5);
            reg.set_histogram("h", h.clone());
        }
        let snap = reg.snapshot();
        assert_eq!(snap.get("c"), Some(&MetricValue::Counter(7)));
        assert_eq!(snap.get("g"), Some(&MetricValue::Gauge(2.5)));
        match snap.get("h") {
            Some(MetricValue::Hist(s)) => assert_eq!(s.count, 1, "no double counting"),
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn merge_histogram_accumulates() {
        let reg = MetricsRegistry::new();
        let mut a = Histogram::new();
        a.record(10.0);
        let mut b = Histogram::new();
        b.record(1000.0);
        reg.merge_histogram("h", &a);
        reg.merge_histogram("h", &b);
        match reg.snapshot().get("h") {
            Some(MetricValue::Hist(s)) => {
                assert_eq!(s.count, 2);
                assert_eq!(s.max, 1000.0);
            }
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn kind_conflicts_take_the_last_writer() {
        let reg = MetricsRegistry::new();
        reg.inc_counter("x", 4);
        reg.set_gauge("x", 1.5);
        assert_eq!(reg.snapshot().get("x"), Some(&MetricValue::Gauge(1.5)));
        // and an inc on a gauge restarts it as a counter
        reg.inc_counter("x", 2);
        assert_eq!(reg.snapshot().get("x"), Some(&MetricValue::Counter(2)));
    }

    #[test]
    fn registry_is_shareable_across_threads() {
        let reg = MetricsRegistry::new();
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..100 {
                        reg.inc_counter("hits", 1);
                        reg.record("lat", 50.0);
                    }
                });
            }
        });
        let snap = reg.snapshot();
        assert_eq!(snap.get("hits"), Some(&MetricValue::Counter(400)));
        match snap.get("lat") {
            Some(MetricValue::Hist(h)) => assert_eq!(h.count, 400),
            other => panic!("wrong kind {other:?}"),
        }
    }
}
