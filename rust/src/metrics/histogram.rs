//! Log-bucketed histogram: ~1% relative resolution over 1 ns .. 10⁴ s
//! (or iteration counts 1..10⁹), constant memory, O(1) record.

/// Log-scale histogram over positive values.
#[derive(Clone, Debug)]
pub struct Histogram {
    /// buckets[i] counts values in [base^i, base^(i+1))
    buckets: Vec<u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

const BASE: f64 = 1.02;
const N_BUCKETS: usize = 1600; // 1.02^1600 ≈ 5.8e13: covers ns..hours

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    #[inline]
    fn bucket_of(v: f64) -> usize {
        if v <= 1.0 {
            return 0;
        }
        let b = v.ln() / BASE.ln();
        (b as usize).min(N_BUCKETS - 1)
    }

    pub fn record(&mut self, v: f64) {
        debug_assert!(v >= 0.0);
        self.buckets[Self::bucket_of(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 { 0.0 } else { self.max }
    }

    /// Approximate p-quantile (bucket upper edge), p ∈ [0, 1].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((p.clamp(0.0, 1.0)) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return BASE.powi(i as i32 + 1).min(self.max).max(self.min);
            }
        }
        self.max
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroes() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
    }

    #[test]
    fn percentiles_approximate_known_distribution() {
        let mut h = Histogram::new();
        for i in 1..=10_000u64 {
            h.record(i as f64);
        }
        let p50 = h.percentile(0.50);
        let p95 = h.percentile(0.95);
        assert!((p50 / 5000.0 - 1.0).abs() < 0.05, "p50={p50}");
        assert!((p95 / 9500.0 - 1.0).abs() < 0.05, "p95={p95}");
        assert_eq!(h.count(), 10_000);
        assert!((h.mean() - 5000.5).abs() < 1.0);
        assert_eq!(h.min(), 1.0);
        assert_eq!(h.max(), 10_000.0);
    }

    #[test]
    fn percentile_bounded_by_min_max() {
        let mut h = Histogram::new();
        h.record(1234.5);
        assert_eq!(h.percentile(0.0), 1234.5);
        assert_eq!(h.percentile(1.0), 1234.5);
    }

    #[test]
    fn merge_combines() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        a.record(10.0);
        b.record(1000.0);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert_eq!(a.max(), 1000.0);
        assert_eq!(a.min(), 10.0);
    }

    #[test]
    fn huge_values_clamp_to_last_bucket() {
        let mut h = Histogram::new();
        h.record(1e300);
        assert_eq!(h.count(), 1);
        assert!(h.percentile(0.5) > 0.0);
    }

    #[test]
    fn sub_unit_values_share_the_first_bucket() {
        let mut h = Histogram::new();
        for v in [0.0, 0.25, 0.5, 0.999] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.999);
        // all land in bucket 0, so every percentile is clamped into
        // [min, max] rather than reporting the bucket edge (BASE^1 > 1)
        for p in [0.0, 0.5, 1.0] {
            let q = h.percentile(p);
            assert!((0.0..=0.999).contains(&q), "p{p} = {q} escaped [min, max]");
        }
    }

    #[test]
    fn percentiles_are_monotone_in_p() {
        let mut h = Histogram::new();
        // spread over several decades plus duplicates and sub-1.0 samples
        for v in [0.5, 2.0, 2.0, 17.0, 300.0, 300.0, 4_000.0, 90_000.0] {
            h.record(v);
        }
        let mut prev = f64::NEG_INFINITY;
        for i in 0..=100 {
            let q = h.percentile(i as f64 / 100.0);
            assert!(q >= prev, "p{} = {q} < p{} = {prev}", i, i - 1);
            prev = q;
        }
        assert!(h.percentile(0.0) >= h.min());
        assert!(h.percentile(1.0) <= h.max());
    }

    #[test]
    fn merge_is_associative_on_derived_stats() {
        // float `sum` is not bit-associative, so compare the stats that the
        // exporters actually report: count, min, max, and the percentile
        // ladder (bucket counts are integers — those merge associatively)
        let mk = |vals: &[f64]| {
            let mut h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = mk(&[1.0, 5.0, 2_000.0]);
        let b = mk(&[0.3, 77.0]);
        let c = mk(&[9.0, 9.0, 1e9]);

        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);

        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);

        assert_eq!(ab_c.count(), a_bc.count());
        assert_eq!(ab_c.count(), 8);
        assert_eq!(ab_c.min(), a_bc.min());
        assert_eq!(ab_c.max(), a_bc.max());
        for p in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            assert_eq!(
                ab_c.percentile(p),
                a_bc.percentile(p),
                "percentile p={p} differs between merge orders"
            );
        }
    }

    #[test]
    fn merge_with_empty_is_identity_on_derived_stats() {
        let mut h = Histogram::new();
        h.record(10.0);
        h.record(250.0);
        let before = (h.count(), h.min(), h.max(), h.percentile(0.5));
        h.merge(&Histogram::new());
        assert_eq!((h.count(), h.min(), h.max(), h.percentile(0.5)), before);
    }
}
