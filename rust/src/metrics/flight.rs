//! Query-lifecycle flight recorder: a bounded, lock-striped ring buffer
//! of typed per-span events (ISSUE 10).
//!
//! Aggregate counters (the [`MetricsRegistry`]) answer *how much*; the
//! flight recorder answers *what happened to this query* — every
//! submission gets a [`SpanId`] at admission and the engine appends typed
//! lifecycle events ([`FlightEventKind`]) with nanosecond timestamps as
//! the query moves through admission, planning, rounds, parking,
//! shedding, retirement, and answering. The buffer is bounded (old
//! events are overwritten, never reallocated) and striped across several
//! mutexes keyed by span, so concurrent recorders — the engine's driving
//! thread, a serving binary's audit path — contend only when two spans
//! hash to the same stripe.
//!
//! **Hot-path discipline.** Recording allocates nothing: every event is
//! a `Copy` struct written into a slot preallocated at construction, and
//! a global ordering sequence comes from one relaxed `fetch_add`. The
//! recorder is driven entirely from the engine's *scheduling* phases
//! (admission, lane-budget pass, harvest) — never from inside
//! `Session::step` — so panel math runs exactly the same instructions
//! with the recorder on or off and answers stay bit-identical
//! (property-tested in `rust/tests/prop_engine.rs`).
//!
//! Post-mortem dumps serialize the surviving window as JSON
//! ([`FlightRecorder::to_json`], schema version [`FLIGHT_DUMP_VERSION`])
//! ordered by the global sequence — wraparound cannot reorder events,
//! only truncate the oldest ([`FlightRecorder::dropped`] counts what the
//! window lost).

use super::registry::MetricsRegistry;
use super::{export, lock_tolerant};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Identifies one query across its lifecycle events: the engine's global
/// submission sequence number, unique for the engine's lifetime.
pub type SpanId = u64;

/// Span id attached to events that describe no particular query (e.g. a
/// violation audit that could not resolve its ticket).
pub const NO_SPAN: SpanId = u64::MAX;

/// Schema version of [`FlightRecorder::to_json`] dumps.
pub const FLIGHT_DUMP_VERSION: u64 = 1;

/// Default total event capacity of an engine's recorder.
pub const FLIGHT_DEFAULT_CAPACITY: usize = 4096;

/// Default stripe count (capacity is split evenly across stripes).
pub const FLIGHT_DEFAULT_STRIPES: usize = 8;

/// One typed lifecycle event. All payloads are `Copy` scalars — recording
/// never allocates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FlightEventKind {
    /// The query entered the engine (a session accepted it).
    Submitted,
    /// Admission accounting: estimated lane cost and the caller's round
    /// deadline (`u64::MAX` for deadline-free submissions).
    Admitted { cost: u64, deadline: u64 },
    /// The query's lanes were planned onto its operator's panel.
    PlannedOntoPanel { op_key: u64, lanes: u32 },
    /// The query survived a joint round still unresolved; `gap` is its
    /// current four-bound bracket width (NaN for multi-lane kinds whose
    /// bracket is not a single interval).
    SweptRound { round: u64, gap: f64 },
    /// Parked whole by the global lane budget.
    Parked,
    /// Resumed from a park, bit-identically.
    Resumed,
    /// Shed by backpressure; the answer is the bracket `[lo, hi]` the
    /// query had tightened to (NaN for stochastic sheds, whose combined
    /// interval lives in the answer).
    Shed { lo: f64, hi: f64 },
    /// A lane retired by interval dominance.
    RetiredDominated,
    /// A lane retired because the surrounding decision resolved first.
    RetiredDecided,
    /// A stochastic probe lane retired early (its own bracket met the
    /// tolerance before exhaustion).
    ProbeRetired { probe: u32 },
    /// The query resolved: rounds spent in the engine and wall time from
    /// submission to harvest.
    Answered { rounds: u64, wall_ns: u64 },
    /// An auditor observed an invalid answer bracket for this span — the
    /// post-mortem trigger `serve` dumps on.
    BracketViolation,
}

impl FlightEventKind {
    /// Stable snake_case name used by the JSON dump.
    pub fn name(&self) -> &'static str {
        match self {
            FlightEventKind::Submitted => "submitted",
            FlightEventKind::Admitted { .. } => "admitted",
            FlightEventKind::PlannedOntoPanel { .. } => "planned_onto_panel",
            FlightEventKind::SweptRound { .. } => "swept_round",
            FlightEventKind::Parked => "parked",
            FlightEventKind::Resumed => "resumed",
            FlightEventKind::Shed { .. } => "shed",
            FlightEventKind::RetiredDominated => "retired_dominated",
            FlightEventKind::RetiredDecided => "retired_decided",
            FlightEventKind::ProbeRetired { .. } => "probe_retired",
            FlightEventKind::Answered { .. } => "answered",
            FlightEventKind::BracketViolation => "bracket_violation",
        }
    }
}

/// One recorded event: global order, timestamp (ns since the recorder
/// was built), owning span, and the typed payload.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    /// Global recording order (monotone across stripes) — the dump sort
    /// key, immune to ring wraparound.
    pub seq: u64,
    /// Nanoseconds since the recorder's construction.
    pub ts_ns: u64,
    pub span: SpanId,
    pub kind: FlightEventKind,
}

/// One stripe's bounded window: a preallocated slot vector written as a
/// ring once full.
struct Ring {
    buf: Vec<FlightEvent>,
    cap: usize,
    /// Events ever written to this stripe; `written > cap` means the
    /// oldest `written - cap` were overwritten.
    written: u64,
}

impl Ring {
    fn new(cap: usize) -> Self {
        Ring { buf: Vec::with_capacity(cap), cap, written: 0 }
    }

    /// Append, overwriting the stripe's oldest slot once full. Returns
    /// `true` when an old event was dropped to make room.
    fn push(&mut self, ev: FlightEvent) -> bool {
        if self.buf.len() < self.cap {
            self.buf.push(ev); // within the preallocated capacity
            self.written += 1;
            false
        } else {
            let slot = (self.written % self.cap as u64) as usize;
            self.buf[slot] = ev;
            self.written += 1;
            true
        }
    }
}

/// The bounded, lock-striped event ring. Shareable (`&self` recording,
/// typically behind an `Arc`): the engine records from its driving
/// thread while a serving binary's scrape/audit threads snapshot or dump
/// concurrently.
pub struct FlightRecorder {
    stripes: Vec<Mutex<Ring>>,
    seq: AtomicU64,
    dropped: AtomicU64,
    epoch: Instant,
}

/// Float serializer for event payloads: unlike the registry exporter
/// (which clamps to 0 so gauges always chart), a post-mortem must not
/// disguise an undefined gap as a converged one — non-finite becomes
/// `null`.
fn flight_num(v: f64) -> String {
    if v.is_finite() {
        export::json_num(v)
    } else {
        "null".to_string()
    }
}

impl FlightRecorder {
    /// A recorder with the default window ([`FLIGHT_DEFAULT_CAPACITY`]
    /// events over [`FLIGHT_DEFAULT_STRIPES`] stripes).
    pub fn new() -> Self {
        Self::with_capacity(FLIGHT_DEFAULT_CAPACITY, FLIGHT_DEFAULT_STRIPES)
    }

    /// A recorder holding (up to) `capacity` events split evenly over
    /// `stripes` mutexes. Both are floored to 1.
    pub fn with_capacity(capacity: usize, stripes: usize) -> Self {
        let stripes = stripes.max(1);
        let per = (capacity.max(1)).div_ceil(stripes);
        FlightRecorder {
            stripes: (0..stripes).map(|_| Mutex::new(Ring::new(per))).collect(),
            seq: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            epoch: Instant::now(),
        }
    }

    /// Nanoseconds since construction — the timestamp base every event
    /// uses, exposed so callers can stamp correlated data (submission
    /// times) on the same clock.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record one event for `span`. Allocation-free: one relaxed
    /// `fetch_add` for the order, one stripe mutex, one slot write.
    #[inline]
    pub fn record(&self, span: SpanId, kind: FlightEventKind) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let ev = FlightEvent { seq, ts_ns: self.now_ns(), span, kind };
        let stripe = (span % self.stripes.len() as u64) as usize;
        if lock_tolerant(&self.stripes[stripe]).push(ev) {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Events ever recorded (including any since overwritten).
    pub fn recorded(&self) -> u64 {
        self.seq.load(Ordering::Relaxed)
    }

    /// Events overwritten by the bounded window.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Total event capacity across every stripe.
    pub fn capacity(&self) -> usize {
        self.stripes.len() * lock_tolerant(&self.stripes[0]).cap
    }

    /// Snapshot the surviving window in recording order (ascending
    /// `seq`). Wraparound drops the oldest events per stripe but never
    /// reorders survivors.
    pub fn events(&self) -> Vec<FlightEvent> {
        let mut out = Vec::new();
        for s in &self.stripes {
            out.extend(lock_tolerant(s).buf.iter().copied());
        }
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Every surviving event for one span, in recording order — the
    /// post-mortem view of a single query's lifecycle.
    pub fn span_events(&self, span: SpanId) -> Vec<FlightEvent> {
        let stripe = (span % self.stripes.len() as u64) as usize;
        let mut out: Vec<FlightEvent> = lock_tolerant(&self.stripes[stripe])
            .buf
            .iter()
            .filter(|e| e.span == span)
            .copied()
            .collect();
        out.sort_by_key(|e| e.seq);
        out
    }

    /// Publish recorder accounting into `reg` under `flight.*` names
    /// (idempotent set-style writes, like every other exporter).
    pub fn export_into(&self, reg: &MetricsRegistry) {
        reg.set_counter("flight.recorded", self.recorded());
        reg.set_counter("flight.dropped", self.dropped());
        reg.set_gauge("flight.capacity", self.capacity() as f64);
        reg.set_gauge("flight.window", self.events().len() as f64);
    }

    /// Serialize the surviving window as the version-1 post-mortem dump:
    ///
    /// ```json
    /// {"version": 1, "recorded": N, "dropped": D, "events":
    ///   [{"seq": 0, "ts_ns": 123, "span": 7, "event": "submitted"}, ...]}
    /// ```
    ///
    /// Event payload fields are flattened next to `"event"`; floats use
    /// the same serializer as the registry exporter (NaN/inf degrade to
    /// `null`).
    pub fn to_json(&self) -> String {
        let events = self.events();
        let mut out = String::with_capacity(64 + events.len() * 96);
        out.push_str(&format!(
            "{{\"version\": {FLIGHT_DUMP_VERSION}, \"recorded\": {}, \"dropped\": {}, \"events\": [",
            self.recorded(),
            self.dropped()
        ));
        for (i, e) in events.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!(
                "{{\"seq\": {}, \"ts_ns\": {}, \"span\": {}, \"event\": \"{}\"",
                e.seq,
                e.ts_ns,
                e.span,
                export::json_escape(e.kind.name())
            ));
            match e.kind {
                FlightEventKind::Admitted { cost, deadline } => {
                    out.push_str(&format!(", \"cost\": {cost}, \"deadline\": {deadline}"));
                }
                FlightEventKind::PlannedOntoPanel { op_key, lanes } => {
                    out.push_str(&format!(", \"op_key\": {op_key}, \"lanes\": {lanes}"));
                }
                FlightEventKind::SweptRound { round, gap } => {
                    out.push_str(&format!(", \"round\": {round}, \"gap\": {}", flight_num(gap)));
                }
                FlightEventKind::Shed { lo, hi } => {
                    out.push_str(&format!(
                        ", \"lo\": {}, \"hi\": {}",
                        flight_num(lo),
                        flight_num(hi)
                    ));
                }
                FlightEventKind::ProbeRetired { probe } => {
                    out.push_str(&format!(", \"probe\": {probe}"));
                }
                FlightEventKind::Answered { rounds, wall_ns } => {
                    out.push_str(&format!(", \"rounds\": {rounds}, \"wall_ns\": {wall_ns}"));
                }
                FlightEventKind::Submitted
                | FlightEventKind::Parked
                | FlightEventKind::Resumed
                | FlightEventKind::RetiredDominated
                | FlightEventKind::RetiredDecided
                | FlightEventKind::BracketViolation => {}
            }
            out.push('}');
        }
        out.push_str("]}");
        out
    }
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::{parse, Json};

    #[test]
    fn records_and_orders_events_across_stripes() {
        let rec = FlightRecorder::with_capacity(64, 4);
        for span in 0..8u64 {
            rec.record(span, FlightEventKind::Submitted);
            rec.record(span, FlightEventKind::Admitted { cost: 1, deadline: u64::MAX });
        }
        let evs = rec.events();
        assert_eq!(evs.len(), 16);
        assert_eq!(rec.recorded(), 16);
        assert_eq!(rec.dropped(), 0);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64, "global order survives striping");
        }
        let span3 = rec.span_events(3);
        assert_eq!(span3.len(), 2);
        assert_eq!(span3[0].kind, FlightEventKind::Submitted);
        assert!(matches!(span3[1].kind, FlightEventKind::Admitted { cost: 1, .. }));
    }

    #[test]
    fn ring_wraparound_keeps_the_newest_events_in_order() {
        // one stripe, capacity 8: write 20 single-span events so the ring
        // wraps more than once — the window must hold exactly the last 8,
        // ascending by seq with no reordering across the wrap point
        let rec = FlightRecorder::with_capacity(8, 1);
        for i in 0..20u64 {
            rec.record(0, FlightEventKind::SweptRound { round: i, gap: 0.5 });
        }
        let evs = rec.events();
        assert_eq!(evs.len(), 8, "window bounded at capacity");
        assert_eq!(rec.dropped(), 12);
        assert_eq!(rec.recorded(), 20);
        let seqs: Vec<u64> = evs.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, (12..20).collect::<Vec<_>>(), "oldest dropped, order kept");
        for (e, want) in evs.iter().zip(12u64..) {
            match e.kind {
                FlightEventKind::SweptRound { round, .. } => assert_eq!(round, want),
                other => panic!("wrong kind {other:?}"),
            }
        }
    }

    #[test]
    fn wraparound_order_holds_with_many_stripes_and_spans() {
        let rec = FlightRecorder::with_capacity(16, 4);
        for i in 0..100u64 {
            rec.record(i % 5, FlightEventKind::SweptRound { round: i, gap: 1.0 });
        }
        let evs = rec.events();
        assert!(evs.len() <= rec.capacity());
        assert!(!evs.is_empty());
        for w in evs.windows(2) {
            assert!(w[0].seq < w[1].seq, "strictly ascending across stripes");
            assert!(w[0].ts_ns <= w[1].ts_ns, "timestamps monotone with seq");
        }
    }

    #[test]
    fn timestamps_are_monotone() {
        let rec = FlightRecorder::new();
        let a = rec.now_ns();
        rec.record(1, FlightEventKind::Submitted);
        rec.record(1, FlightEventKind::Answered { rounds: 3, wall_ns: 10 });
        let evs = rec.span_events(1);
        assert!(evs[0].ts_ns >= a);
        assert!(evs[1].ts_ns >= evs[0].ts_ns);
    }

    #[test]
    fn dump_round_trips_through_the_crate_json_parser() {
        let rec = FlightRecorder::with_capacity(32, 2);
        rec.record(7, FlightEventKind::Submitted);
        rec.record(7, FlightEventKind::Admitted { cost: 2, deadline: 40 });
        rec.record(7, FlightEventKind::PlannedOntoPanel { op_key: 9, lanes: 2 });
        rec.record(7, FlightEventKind::SweptRound { round: 1, gap: 0.25 });
        rec.record(7, FlightEventKind::Shed { lo: 1.0, hi: 2.0 });
        rec.record(7, FlightEventKind::ProbeRetired { probe: 3 });
        rec.record(7, FlightEventKind::Answered { rounds: 5, wall_ns: 1234 });
        rec.record(7, FlightEventKind::BracketViolation);
        let doc = parse(&rec.to_json()).expect("dump parses");
        assert_eq!(doc.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.get("recorded").and_then(Json::as_usize), Some(8));
        let evs = doc.get("events").and_then(Json::as_arr).expect("events array");
        assert_eq!(evs.len(), 8);
        let names: Vec<&str> =
            evs.iter().map(|e| e.get("event").and_then(Json::as_str).unwrap()).collect();
        assert_eq!(
            names,
            vec![
                "submitted",
                "admitted",
                "planned_onto_panel",
                "swept_round",
                "shed",
                "probe_retired",
                "answered",
                "bracket_violation"
            ]
        );
        assert_eq!(evs[1].get("cost").and_then(Json::as_usize), Some(2));
        assert_eq!(evs[2].get("op_key").and_then(Json::as_usize), Some(9));
        assert_eq!(evs[3].get("gap").and_then(Json::as_f64), Some(0.25));
        assert_eq!(evs[6].get("wall_ns").and_then(Json::as_usize), Some(1234));
        for e in evs {
            assert_eq!(e.get("span").and_then(Json::as_usize), Some(7));
        }
    }

    #[test]
    fn nan_gap_degrades_to_null_in_the_dump() {
        let rec = FlightRecorder::with_capacity(4, 1);
        rec.record(0, FlightEventKind::SweptRound { round: 1, gap: f64::NAN });
        let doc = parse(&rec.to_json()).expect("dump with NaN still parses");
        let evs = doc.get("events").and_then(Json::as_arr).unwrap();
        assert!(matches!(evs[0].get("gap"), Some(Json::Null)));
    }

    #[test]
    fn exports_accounting_into_the_registry() {
        let rec = FlightRecorder::with_capacity(4, 1);
        for _ in 0..6 {
            rec.record(0, FlightEventKind::Submitted);
        }
        let reg = MetricsRegistry::new();
        rec.export_into(&reg);
        let snap = reg.snapshot();
        assert_eq!(snap.get("flight.recorded"), Some(&crate::metrics::MetricValue::Counter(6)));
        assert_eq!(snap.get("flight.dropped"), Some(&crate::metrics::MetricValue::Counter(2)));
        assert_eq!(snap.get("flight.capacity"), Some(&crate::metrics::MetricValue::Gauge(4.0)));
    }

    #[test]
    fn concurrent_recording_is_safe_and_complete() {
        let rec = std::sync::Arc::new(FlightRecorder::with_capacity(4096, 8));
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let rec = std::sync::Arc::clone(&rec);
                s.spawn(move || {
                    for i in 0..100 {
                        rec.record(t * 100 + i, FlightEventKind::Submitted);
                    }
                });
            }
        });
        assert_eq!(rec.recorded(), 400);
        assert_eq!(rec.dropped(), 0);
        let evs = rec.events();
        assert_eq!(evs.len(), 400);
        for w in evs.windows(2) {
            assert!(w[0].seq < w[1].seq);
        }
    }
}
