//! Convergence tracing: recording the four-bound gap trajectory of a GQL
//! query and fitting its geometric contraction rate.
//!
//! Theorem 1 of the paper predicts the Gauss/Radau/Lobatto brackets tighten
//! like `ρ^i` with `ρ = (√κ − 1)/(√κ + 1)` (see
//! [`theoretical_rate`]). A [`GapTrace`] captures the measured relative gap
//! `(upper − lower)/|upper|` per iteration from a `Vec<Bounds>` history and
//! [`GapTrace::fitted_rate`] least-squares-fits `ln(gap)` against the
//! iteration index, so experiments (the `rates` command) and `Answer`
//! metadata can report *measured vs. predicted* contraction directly.
//!
//! Tracing is opt-in (`Session::record_traces`, `BlockGql`'s
//! `record_history`) and happens outside the recurrence arithmetic, so it
//! cannot perturb the bit-identity contracts.

use crate::quadrature::gql::Bounds;

/// Relative gaps below this are treated as the floating-point noise floor
/// and excluded from the rate fit (a converged plateau would otherwise
/// flatten the fitted slope).
const NOISE_FLOOR: f64 = 1e-13;

/// Measured bracket-gap trajectory of one query.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GapTrace {
    /// 1-based Lanczos iteration indices (as reported by `Bounds::iter`).
    pub iters: Vec<usize>,
    /// Relative gap `(upper − lower)/|upper|` at each recorded iteration.
    pub gaps: Vec<f64>,
}

impl GapTrace {
    /// Build a trace from a bounds history, stopping at the first exact
    /// bound or once the relative gap falls under the noise floor.
    pub fn from_history(history: &[Bounds]) -> Self {
        let mut iters = Vec::new();
        let mut gaps = Vec::new();
        for b in history {
            if b.exact {
                break;
            }
            let denom = b.upper().abs();
            if denom <= 0.0 || !denom.is_finite() {
                break;
            }
            let rel = b.gap() / denom;
            if !rel.is_finite() || rel <= NOISE_FLOOR {
                break;
            }
            iters.push(b.iter);
            gaps.push(rel);
        }
        GapTrace { iters, gaps }
    }

    /// Number of recorded points.
    pub fn len(&self) -> usize {
        self.iters.len()
    }

    pub fn is_empty(&self) -> bool {
        self.iters.is_empty()
    }

    /// Final recorded relative gap, if any.
    pub fn final_gap(&self) -> Option<f64> {
        self.gaps.last().copied()
    }

    /// Fitted per-iteration geometric contraction rate: the least-squares
    /// slope of `ln(gap)` against the iteration index, exponentiated.
    ///
    /// Only *usable* points enter the fit — finite gaps above the noise
    /// floor. [`from_history`](Self::from_history) already truncates at
    /// the floor, but the fields are public and hand-built traces (or
    /// histories spliced from several sources) can carry converged or
    /// degenerate entries whose `ln` would poison the regression. Needs
    /// at least 3 usable points; returns `None` otherwise (too short to
    /// distinguish a trend from startup transients).
    pub fn fitted_rate(&self) -> Option<f64> {
        let usable: Vec<(f64, f64)> = self
            .iters
            .iter()
            .zip(&self.gaps)
            .filter(|(_, &g)| g.is_finite() && g > NOISE_FLOOR)
            .map(|(&i, &g)| (i as f64, g.ln()))
            .collect();
        if usable.len() < 3 {
            return None;
        }
        let n = usable.len() as f64;
        let sx: f64 = usable.iter().map(|&(x, _)| x).sum();
        let sy: f64 = usable.iter().map(|&(_, y)| y).sum();
        let sxx: f64 = usable.iter().map(|&(x, _)| x * x).sum();
        let sxy: f64 = usable.iter().map(|&(x, y)| x * y).sum();
        let denom = n * sxx - sx * sx;
        if denom.abs() < f64::EPSILON {
            return None;
        }
        let slope = (n * sxy - sx * sy) / denom;
        let rate = slope.exp();
        rate.is_finite().then_some(rate)
    }

    /// Ratio of the measured rate to the paper's prediction for condition
    /// number `kappa` — ≤ 1 means converging at least as fast as Theorem 1
    /// promises. `None` when either rate is unavailable.
    pub fn rate_vs_theory(&self, kappa: f64) -> Option<f64> {
        let theory = theoretical_rate(kappa);
        if theory.is_nan() || theory <= 0.0 {
            return None;
        }
        Some(self.fitted_rate()? / theory)
    }
}

/// The paper's predicted per-iteration contraction factor
/// `ρ = (√κ − 1)/(√κ + 1)` for condition number `κ ≥ 1`.
pub fn theoretical_rate(kappa: f64) -> f64 {
    if kappa < 1.0 || !kappa.is_finite() {
        return f64::NAN;
    }
    let s = kappa.sqrt();
    (s - 1.0) / (s + 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_bounds(iter: usize, gap: f64) -> Bounds {
        // mid-value 1.0; the four bounds bracket it with the given gap
        Bounds {
            iter,
            gauss: 1.0 - gap / 2.0,
            radau_lower: 1.0 - gap / 2.0,
            radau_upper: 1.0 + gap / 2.0,
            lobatto: 1.0 + gap / 2.0,
            exact: false,
        }
    }

    #[test]
    fn recovers_a_pure_geometric_rate() {
        let rho = 0.6;
        let hist: Vec<Bounds> = (1..=20)
            .map(|i| synthetic_bounds(i, 0.4 * rho.powi(i as i32)))
            .collect();
        let t = GapTrace::from_history(&hist);
        assert_eq!(t.len(), 20);
        let fitted = t.fitted_rate().expect("enough points");
        // relative gap = gap/upper ≈ gap/(1+gap/2); slope still → ln ρ
        assert!((fitted - rho).abs() < 0.02, "fitted {fitted} vs {rho}");
    }

    #[test]
    fn truncates_at_exact_and_noise_floor() {
        let mut hist: Vec<Bounds> =
            (1..=5).map(|i| synthetic_bounds(i, 0.1 / i as f64)).collect();
        let mut exact = synthetic_bounds(6, 0.0);
        exact.exact = true;
        hist.push(exact);
        hist.push(synthetic_bounds(7, 0.05));
        let t = GapTrace::from_history(&hist);
        assert_eq!(t.len(), 5, "stops at the exact entry");

        let hist2: Vec<Bounds> = vec![
            synthetic_bounds(1, 1e-2),
            synthetic_bounds(2, 1e-14), // below noise floor
            synthetic_bounds(3, 1e-3),
        ];
        let t2 = GapTrace::from_history(&hist2);
        assert_eq!(t2.len(), 1, "stops at the noise floor");
    }

    #[test]
    fn short_traces_have_no_rate() {
        let hist: Vec<Bounds> = (1..=2).map(|i| synthetic_bounds(i, 0.1)).collect();
        let t = GapTrace::from_history(&hist);
        assert_eq!(t.fitted_rate(), None);
        assert!(GapTrace::default().is_empty());
        assert_eq!(GapTrace::default().final_gap(), None);
    }

    #[test]
    fn degenerate_points_do_not_count_toward_the_fit() {
        // hand-built trace (the fields are public): 5 recorded points but
        // only 2 survive the usability filter — no fit
        let t = GapTrace {
            iters: vec![1, 2, 3, 4, 5],
            gaps: vec![1e-1, 1e-2, 0.0, 1e-15, f64::NAN],
        };
        assert_eq!(t.len(), 5);
        assert_eq!(t.fitted_rate(), None, "2 usable points is not a trend");

        // with a third usable point the fit returns, and the degenerate
        // tail does not drag the slope: the rate matches the clean prefix
        let t3 = GapTrace {
            iters: vec![1, 2, 3, 4, 5],
            gaps: vec![4e-1, 2e-1, 1e-1, 0.0, f64::NEG_INFINITY],
        };
        let fitted = t3.fitted_rate().expect("3 usable points");
        assert!((fitted - 0.5).abs() < 1e-9, "fitted {fitted}");
    }

    #[test]
    fn theoretical_rate_matches_formula() {
        assert_eq!(theoretical_rate(1.0), 0.0);
        let r = theoretical_rate(9.0); // √κ = 3 → (3−1)/(3+1) = 0.5
        assert!((r - 0.5).abs() < 1e-15);
        assert!(theoretical_rate(0.5).is_nan());
        assert!(theoretical_rate(f64::INFINITY).is_nan());
    }

    #[test]
    fn rate_vs_theory_flags_fast_convergence() {
        let rho = 0.3;
        let hist: Vec<Bounds> = (1..=15)
            .map(|i| synthetic_bounds(i, 0.2 * rho.powi(i as i32)))
            .collect();
        let t = GapTrace::from_history(&hist);
        // κ chosen so theory predicts ~0.5: measured 0.3 → ratio < 1
        let ratio = t.rate_vs_theory(9.0).expect("rates available");
        assert!(ratio < 1.0, "ratio {ratio}");
    }
}
