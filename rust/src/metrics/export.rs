//! Snapshot serializers: JSON (for `--telemetry <path>` dumps and CI
//! validation) and Prometheus exposition text (for scrape endpoints).
//!
//! Both formats are hand-rolled — the crate carries no serde — and the JSON
//! form is round-trip tested against the crate's own parser
//! ([`crate::config::json::parse`]), so a snapshot written by
//! [`write_json`] is guaranteed loadable by any tool that reads the
//! `config` JSON dialect.

use super::registry::{MetricValue, Snapshot};
use std::io::Write;
use std::path::Path;

/// Schema version stamped into every JSON snapshot.
const SNAPSHOT_VERSION: u64 = 1;

fn fin(v: f64) -> f64 {
    if v.is_finite() {
        v
    } else {
        0.0
    }
}

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

pub(crate) fn json_num(v: f64) -> String {
    let v = fin(v);
    if v == v.trunc() && v.abs() < 1e15 {
        format!("{:.1}", v)
    } else {
        format!("{v}")
    }
}

/// Serialize a snapshot as a JSON object:
/// `{"version":1,"metrics":{"<name>":{"type":"counter","value":N}|…}}`.
/// Non-finite values are clamped to 0 so the output always parses.
pub fn to_json(snap: &Snapshot) -> String {
    let mut out = String::from("{\n  \"version\": ");
    out.push_str(&SNAPSHOT_VERSION.to_string());
    out.push_str(",\n  \"metrics\": {");
    for (i, (name, value)) in snap.entries.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str("\n    \"");
        out.push_str(&json_escape(name));
        out.push_str("\": ");
        match value {
            MetricValue::Counter(c) => {
                out.push_str(&format!("{{\"type\": \"counter\", \"value\": {c}}}"));
            }
            MetricValue::Gauge(g) => {
                out.push_str(&format!(
                    "{{\"type\": \"gauge\", \"value\": {}}}",
                    json_num(*g)
                ));
            }
            MetricValue::Hist(h) => {
                out.push_str(&format!(
                    "{{\"type\": \"histogram\", \"count\": {}, \"mean\": {}, \
                     \"min\": {}, \"max\": {}, \"p50\": {}, \"p90\": {}, \"p99\": {}}}",
                    h.count,
                    json_num(h.mean),
                    json_num(h.min),
                    json_num(h.max),
                    json_num(h.p50),
                    json_num(h.p90),
                    json_num(h.p99),
                ));
            }
        }
    }
    out.push_str("\n  }\n}\n");
    out
}

/// Sanitize a dotted metric name into a legal Prometheus metric name
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`).
fn prom_name(name: &str) -> String {
    let mut out: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' || c == ':' { c } else { '_' })
        .collect();
    match out.chars().next() {
        Some(c) if c.is_ascii_digit() => out.insert(0, '_'),
        None => out.push('_'),
        _ => {}
    }
    out
}

/// Escape a Prometheus label *value* per the exposition format: inside
/// the double quotes, backslash, double-quote, and line-feed must be
/// written `\\`, `\"`, and `\n`.
pub(crate) fn prom_label_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Serialize a snapshot in Prometheus exposition text format. Histograms
/// are rendered as summaries (`quantile` labels plus `_sum`/`_count`).
pub fn to_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    for (name, value) in &snap.entries {
        let p = prom_name(name);
        match value {
            MetricValue::Counter(c) => {
                out.push_str(&format!("# TYPE {p} counter\n{p} {c}\n"));
            }
            MetricValue::Gauge(g) => {
                out.push_str(&format!("# TYPE {p} gauge\n{p} {}\n", fin(*g)));
            }
            MetricValue::Hist(h) => {
                out.push_str(&format!("# TYPE {p} summary\n"));
                for (q, v) in
                    [("0.5", h.p50), ("0.9", h.p90), ("0.99", h.p99)]
                {
                    out.push_str(&format!(
                        "{p}{{quantile=\"{}\"}} {}\n",
                        prom_label_escape(q),
                        fin(v)
                    ));
                }
                out.push_str(&format!(
                    "{p}_sum {}\n{p}_count {}\n",
                    fin(h.mean) * h.count as f64,
                    h.count
                ));
            }
        }
    }
    out
}

/// Write the JSON form of `snap` to `path`, creating parent directories.
pub fn write_json(path: &Path, snap: &Snapshot) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(to_json(snap).as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::{parse, Json};
    use crate::metrics::MetricsRegistry;

    fn sample_registry() -> MetricsRegistry {
        let reg = MetricsRegistry::new();
        reg.inc_counter("engine.rounds", 12);
        reg.set_gauge("engine.busy_frac", 0.875);
        reg.set_gauge("weird name-with/chars", f64::NAN);
        for v in [5.0, 50.0, 500.0] {
            reg.record("sweep.step_ns", v);
        }
        reg
    }

    #[test]
    fn json_round_trips_through_own_parser() {
        let text = to_json(&sample_registry().snapshot());
        let doc = parse(&text).expect("snapshot JSON must parse");
        assert_eq!(doc.get("version").and_then(Json::as_f64), Some(1.0));
        let metrics = doc.get("metrics").expect("metrics object");
        let rounds = metrics.get("engine.rounds").expect("counter present");
        assert_eq!(rounds.get("type").and_then(Json::as_str), Some("counter"));
        assert_eq!(rounds.get("value").and_then(Json::as_f64), Some(12.0));
        let busy = metrics.get("engine.busy_frac").expect("gauge present");
        assert_eq!(busy.get("value").and_then(Json::as_f64), Some(0.875));
        let hist = metrics.get("sweep.step_ns").expect("histogram present");
        assert_eq!(hist.get("type").and_then(Json::as_str), Some("histogram"));
        assert_eq!(hist.get("count").and_then(Json::as_f64), Some(3.0));
        for key in ["mean", "min", "max", "p50", "p90", "p99"] {
            assert!(
                hist.get(key).and_then(Json::as_f64).is_some(),
                "missing histogram key {key}"
            );
        }
        // NaN gauge clamps to a parseable 0
        let weird = metrics.get("weird name-with/chars").expect("gauge");
        assert_eq!(weird.get("value").and_then(Json::as_f64), Some(0.0));
    }

    #[test]
    fn prometheus_output_has_legal_names_and_type_lines() {
        let text = to_prometheus(&sample_registry().snapshot());
        assert!(text.contains("# TYPE engine_rounds counter"));
        assert!(text.contains("engine_rounds 12"));
        assert!(text.contains("# TYPE engine_busy_frac gauge"));
        assert!(text.contains("# TYPE sweep_step_ns summary"));
        assert!(text.contains("sweep_step_ns{quantile=\"0.5\"}"));
        assert!(text.contains("sweep_step_ns_count 3"));
        assert!(text.contains("weird_name_with_chars 0"));
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let name = line.split([' ', '{']).next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "illegal prometheus name {name:?}"
            );
        }
    }

    #[test]
    fn prom_name_rewrites_dots_and_guards_leading_digits() {
        assert_eq!(super::prom_name("engine.admission.shed"), "engine_admission_shed");
        assert_eq!(super::prom_name("rates.n24.rho"), "rates_n24_rho");
        assert_eq!(super::prom_name("weird name-with/chars"), "weird_name_with_chars");
        assert_eq!(super::prom_name("0starts.with.digit"), "_0starts_with_digit");
        assert_eq!(super::prom_name(""), "_");
        assert_eq!(super::prom_name("already_legal:name"), "already_legal:name");
    }

    #[test]
    fn prom_label_escape_handles_quotes_backslashes_and_newlines() {
        assert_eq!(prom_label_escape("plain"), "plain");
        assert_eq!(prom_label_escape("say \"hi\""), "say \\\"hi\\\"");
        assert_eq!(prom_label_escape("a\\b"), "a\\\\b");
        assert_eq!(prom_label_escape("line1\nline2"), "line1\\nline2");
        assert_eq!(
            prom_label_escape("\"\\\n"),
            "\\\"\\\\\\n",
            "all three specials in sequence"
        );
        // escaped values embed in an exposition line without breaking the
        // quoting: the rendered label stays on one physical line and the
        // only raw quotes are the delimiters
        let line = format!("m{{k=\"{}\"}} 1", prom_label_escape("v\"w\nx\\y"));
        assert_eq!(line.lines().count(), 1, "newline must not split the sample line");
        let unescaped_quotes =
            line.match_indices('"').filter(|(i, _)| *i == 0 || line.as_bytes()[i - 1] != b'\\');
        assert_eq!(unescaped_quotes.count(), 2, "only the delimiting quotes survive");
    }

    #[test]
    fn write_json_creates_parent_dirs() {
        let dir = std::env::temp_dir().join("gauss_bif_export_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested").join("telemetry.json");
        write_json(&path, &sample_registry().snapshot()).expect("write");
        let text = std::fs::read_to_string(&path).expect("read back");
        assert!(parse(&text).is_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
