//! Observability: counters, latency histograms with percentile queries,
//! and iteration-count histograms (how many quadrature iterations each
//! retrospective judgement actually needed — the paper's speedups live or
//! die on this distribution staying tiny).

pub mod histogram;

pub use histogram::Histogram;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic counter, shareable across threads.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1)
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Increment and return the pre-increment value (an atomic ticket).
    #[inline]
    pub fn tick(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

/// Lock-free exponentially-weighted moving average over an atomic f64
/// (bit-packed). `None` until the first sample. Used by the coordinator
/// router to track recent per-request latency of the PJRT and
/// native-block paths and prefer the faster one (ROADMAP open item).
#[derive(Debug)]
pub struct Ewma {
    bits: AtomicU64,
    alpha: f64,
}

impl Ewma {
    /// `alpha` is the new-sample weight: `ewma ← ewma + α·(x − ewma)`.
    pub fn new(alpha: f64) -> Self {
        Ewma { bits: AtomicU64::new(f64::NAN.to_bits()), alpha }
    }

    pub fn record(&self, x: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let old = f64::from_bits(cur);
            let new = if old.is_nan() { x } else { old + self.alpha * (x - old) };
            match self.bits.compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current average; `None` before any sample.
    pub fn get(&self) -> Option<f64> {
        let v = f64::from_bits(self.bits.load(Ordering::Relaxed));
        if v.is_nan() {
            None
        } else {
            Some(v)
        }
    }
}

impl Default for Ewma {
    fn default() -> Self {
        // a 0.2 weight forgets a stale latency regime in ~10 batches
        Ewma::new(0.2)
    }
}

/// Scope timer: `let _t = Timer::start(&hist);` records on drop (ns).
pub struct Timer<'a> {
    hist: &'a std::sync::Mutex<Histogram>,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a std::sync::Mutex<Histogram>) -> Self {
        Timer { hist, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as f64;
        self.hist.lock().unwrap().record(ns);
    }
}

/// Service-level metrics bundle for the coordinator.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub requests: Counter,
    pub batches: Counter,
    pub native_fallbacks: Counter,
    /// coalesced shared-operator session runs on the native path (mixed
    /// threshold/argmax groups compiled onto one panel)
    pub coalesced_blocks: Counter,
    /// cross-operator engine drains: native groups spanning ≥ 2 distinct
    /// operators, served jointly by the multi-operator streaming engine
    pub engine_drains: Counter,
    /// argmax batches served natively (lone races and session members)
    pub races: Counter,
    pub latency_ns: std::sync::Mutex<Histogram>,
    pub batch_size: std::sync::Mutex<Histogram>,
    pub judge_iters: std::sync::Mutex<Histogram>,
    /// recent per-request service latency of dispatched PJRT batches
    pub pjrt_batch_ns: Ewma,
    /// recent per-request service latency of coalesced native session runs
    pub native_block_ns: Ewma,
    /// router decisions taken once both path EWMAs are seeded (drives the
    /// periodic re-exploration ticket)
    pub route_decisions: Counter,
}

impl ServiceMetrics {
    /// One in this many fully-seeded routing decisions re-explores the
    /// slower path (ε-greedy refresh of its latency EWMA).
    pub const EXPLORE_EVERY: u64 = 64;

    pub fn new() -> Self {
        Self::default()
    }

    /// Router heuristic (ROADMAP open item): prefer the native block path
    /// over a PJRT dispatch for coalescible requests when its recent
    /// per-request latency EWMA is lower. Self-seeding: an unmeasured
    /// native path claims the next coalescible request (one exploration
    /// sample — the coalesced serve path records its EWMA even for a
    /// degenerate single-request group), while an unmeasured PJRT path is
    /// left preferred so any bucketed dispatch seeds it. Once both are
    /// seeded the comparison takes over, except that every
    /// [`Self::EXPLORE_EVERY`]-th decision deliberately takes the
    /// currently-unpreferred path — the losing path's EWMA would
    /// otherwise freeze at its last (possibly cold-start) sample and a
    /// later regime change could never flip the preference back.
    pub fn prefer_native_block(&self) -> bool {
        match (self.native_block_ns.get(), self.pjrt_batch_ns.get()) {
            (None, _) => true,
            (Some(_), None) => false,
            (Some(native), Some(pjrt)) => {
                let prefer = native < pjrt;
                if (self.route_decisions.tick() + 1) % Self::EXPLORE_EVERY == 0 {
                    !prefer
                } else {
                    prefer
                }
            }
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let lat = self.latency_ns.lock().unwrap();
        let bs = self.batch_size.lock().unwrap();
        let it = self.judge_iters.lock().unwrap();
        format!(
            "requests={} batches={} native={} coalesced={} engine={} races={} | latency p50={} p95={} p99={} | batch p50={:.1} | iters p50={:.0} p95={:.0}",
            self.requests.get(),
            self.batches.get(),
            self.native_fallbacks.get(),
            self.coalesced_blocks.get(),
            self.engine_drains.get(),
            self.races.get(),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.50)),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.95)),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.99)),
            bs.percentile(0.50),
            it.percentile(0.50),
            it.percentile(0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_records_on_drop() {
        let hist = std::sync::Mutex::new(Histogram::new());
        {
            let _t = Timer::start(&hist);
            std::hint::black_box((0..1000).sum::<u64>());
        }
        assert_eq!(hist.lock().unwrap().count(), 1);
        assert!(hist.lock().unwrap().percentile(0.5) > 0.0);
    }

    #[test]
    fn service_metrics_summary_renders() {
        let m = ServiceMetrics::new();
        m.requests.add(3);
        m.latency_ns.lock().unwrap().record(1000.0);
        let s = m.summary();
        assert!(s.contains("requests=3"), "{s}");
    }

    #[test]
    fn ewma_tracks_and_starts_empty() {
        let e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.record(100.0);
        assert_eq!(e.get(), Some(100.0), "first sample seeds the average");
        e.record(200.0);
        assert_eq!(e.get(), Some(150.0));
        e.record(200.0);
        assert_eq!(e.get(), Some(175.0));
    }

    #[test]
    fn router_explores_then_prefers_the_faster_path() {
        let m = ServiceMetrics::new();
        assert!(
            m.prefer_native_block(),
            "unmeasured native path claims one exploratory request"
        );
        m.native_block_ns.record(1_000.0);
        assert!(!m.prefer_native_block(), "PJRT unmeasured: let dispatches seed it");
        m.pjrt_batch_ns.record(5_000.0);
        assert!(m.prefer_native_block(), "native measured faster");
        // a long streak of slow native runs flips the preference back
        for _ in 0..40 {
            m.native_block_ns.record(50_000.0);
        }
        assert!(!m.prefer_native_block());
    }

    #[test]
    fn router_periodically_re_explores_the_slower_path() {
        let m = ServiceMetrics::new();
        m.native_block_ns.record(1_000.0);
        m.pjrt_batch_ns.record(500.0); // PJRT faster: native unpreferred
        let explorations = (0..2 * ServiceMetrics::EXPLORE_EVERY)
            .filter(|_| m.prefer_native_block())
            .count();
        assert_eq!(
            explorations, 2,
            "exactly one exploratory native run per {} decisions",
            ServiceMetrics::EXPLORE_EVERY
        );
    }
}
