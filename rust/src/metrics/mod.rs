//! Observability: counters, latency histograms with percentile queries,
//! and iteration-count histograms (how many quadrature iterations each
//! retrospective judgement actually needed — the paper's speedups live or
//! die on this distribution staying tiny).
//!
//! The telemetry layer on top of these primitives:
//! - [`registry`] — a named [`MetricsRegistry`] of counters / gauges /
//!   histograms that every subsystem exports into at harvest points;
//! - [`export`] — JSON and Prometheus-exposition serializers for registry
//!   snapshots (behind the `--telemetry <path>` CLI flag);
//! - [`trace`] — opt-in convergence tracing: per-query four-bound gap
//!   trajectories and fitted geometric contraction rates, compared
//!   against the paper's `(√κ−1)/(√κ+1)` prediction;
//! - [`flight`] — the query-lifecycle flight recorder: typed per-span
//!   events (admission → planning → rounds → answer) in a bounded
//!   lock-striped ring, dumped as JSON for post-mortems and scraped live
//!   by the `serve` binary's introspection endpoints.

pub mod export;
pub mod flight;
pub mod histogram;
pub mod registry;
pub mod trace;

pub use flight::{FlightEvent, FlightEventKind, FlightRecorder, SpanId};
pub use histogram::Histogram;
pub use registry::{HistSummary, MetricValue, MetricsRegistry, Snapshot};
pub use trace::{theoretical_rate, GapTrace};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

/// Poison-tolerant lock: a thread that panicked while holding a metrics
/// mutex poisons it, but metrics are advisory — recording into or reading
/// a possibly-inconsistent histogram is strictly better than cascading the
/// panic into every other thread that touches telemetry.
pub(crate) fn lock_tolerant<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Monotonic counter, shareable across threads.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1)
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    /// Increment and return the pre-increment value (an atomic ticket).
    #[inline]
    pub fn tick(&self) -> u64 {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
}

/// Lock-free exponentially-weighted moving average over an atomic f64
/// (bit-packed). `None` until the first sample. Used by the coordinator
/// router to track recent per-request latency of the PJRT and
/// native-block paths and prefer the faster one (ROADMAP open item).
#[derive(Debug)]
pub struct Ewma {
    bits: AtomicU64,
    alpha: f64,
}

impl Ewma {
    /// `alpha` is the new-sample weight: `ewma ← ewma + α·(x − ewma)`.
    pub fn new(alpha: f64) -> Self {
        Ewma { bits: AtomicU64::new(f64::NAN.to_bits()), alpha }
    }

    pub fn record(&self, x: f64) {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            let old = f64::from_bits(cur);
            let new = if old.is_nan() { x } else { old + self.alpha * (x - old) };
            match self.bits.compare_exchange_weak(
                cur,
                new.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Current average; `None` before any sample.
    pub fn get(&self) -> Option<f64> {
        let v = f64::from_bits(self.bits.load(Ordering::Relaxed));
        if v.is_nan() {
            None
        } else {
            Some(v)
        }
    }
}

impl Default for Ewma {
    fn default() -> Self {
        // a 0.2 weight forgets a stale latency regime in ~10 batches
        Ewma::new(0.2)
    }
}

/// Scope timer: `let _t = Timer::start(&hist);` records on drop (ns).
pub struct Timer<'a> {
    hist: &'a std::sync::Mutex<Histogram>,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a std::sync::Mutex<Histogram>) -> Self {
        Timer { hist, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as f64;
        // Poison-tolerant: Timer drops during unwinding too, and a second
        // panic inside a Drop aborts the process.
        lock_tolerant(self.hist).record(ns);
    }
}

/// Service-level metrics bundle for the coordinator.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub requests: Counter,
    pub batches: Counter,
    pub native_fallbacks: Counter,
    /// coalesced shared-operator session runs on the native path (mixed
    /// threshold/argmax groups compiled onto one panel)
    pub coalesced_blocks: Counter,
    /// cross-operator engine drains: native groups spanning ≥ 2 distinct
    /// operators, served jointly by the multi-operator streaming engine
    pub engine_drains: Counter,
    /// argmax batches served natively (lone races and session members)
    pub races: Counter,
    pub latency_ns: std::sync::Mutex<Histogram>,
    pub batch_size: std::sync::Mutex<Histogram>,
    pub judge_iters: std::sync::Mutex<Histogram>,
    /// recent per-request service latency of dispatched PJRT batches
    pub pjrt_batch_ns: Ewma,
    /// recent per-request service latency of coalesced native session runs
    pub native_block_ns: Ewma,
    /// router decisions taken once both path EWMAs are seeded (drives the
    /// periodic re-exploration ticket)
    pub route_decisions: Counter,
}

impl ServiceMetrics {
    /// One in this many fully-seeded routing decisions re-explores the
    /// slower path (ε-greedy refresh of its latency EWMA).
    pub const EXPLORE_EVERY: u64 = 64;

    pub fn new() -> Self {
        Self::default()
    }

    /// Router heuristic (ROADMAP open item): prefer the native block path
    /// over a PJRT dispatch for coalescible requests when its recent
    /// per-request latency EWMA is lower. Self-seeding: an unmeasured
    /// native path claims the next coalescible request (one exploration
    /// sample — the coalesced serve path records its EWMA even for a
    /// degenerate single-request group), while an unmeasured PJRT path is
    /// left preferred so any bucketed dispatch seeds it. Once both are
    /// seeded the comparison takes over, except that every
    /// [`Self::EXPLORE_EVERY`]-th decision deliberately takes the
    /// currently-unpreferred path — the losing path's EWMA would
    /// otherwise freeze at its last (possibly cold-start) sample and a
    /// later regime change could never flip the preference back.
    pub fn prefer_native_block(&self) -> bool {
        match (self.native_block_ns.get(), self.pjrt_batch_ns.get()) {
            (None, _) => true,
            (Some(_), None) => false,
            (Some(native), Some(pjrt)) => {
                let prefer = native < pjrt;
                if (self.route_decisions.tick() + 1) % Self::EXPLORE_EVERY == 0 {
                    !prefer
                } else {
                    prefer
                }
            }
        }
    }

    /// One-line human summary. Poison-tolerant: a panicked worker must not
    /// take the shutdown report down with it.
    pub fn summary(&self) -> String {
        let lat = lock_tolerant(&self.latency_ns);
        let bs = lock_tolerant(&self.batch_size);
        let it = lock_tolerant(&self.judge_iters);
        format!(
            "requests={} batches={} native={} coalesced={} engine={} races={} | latency p50={} p95={} p99={} | batch p50={:.1} | iters p50={:.0} p95={:.0}",
            self.requests.get(),
            self.batches.get(),
            self.native_fallbacks.get(),
            self.coalesced_blocks.get(),
            self.engine_drains.get(),
            self.races.get(),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.50)),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.95)),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.99)),
            bs.percentile(0.50),
            it.percentile(0.50),
            it.percentile(0.95),
        )
    }

    /// Publish the current cumulative values into `reg` under `service.*`
    /// names. Uses set-style (idempotent) registry writes, so periodic
    /// re-export never double-counts.
    pub fn export_into(&self, reg: &MetricsRegistry) {
        reg.set_counter("service.requests", self.requests.get());
        reg.set_counter("service.batches", self.batches.get());
        reg.set_counter("service.native_fallbacks", self.native_fallbacks.get());
        reg.set_counter("service.coalesced_blocks", self.coalesced_blocks.get());
        reg.set_counter("service.engine_drains", self.engine_drains.get());
        reg.set_counter("service.races", self.races.get());
        reg.set_counter("service.route_decisions", self.route_decisions.get());
        reg.set_histogram("service.latency_ns", lock_tolerant(&self.latency_ns).clone());
        reg.set_histogram("service.batch_size", lock_tolerant(&self.batch_size).clone());
        reg.set_histogram("service.judge_iters", lock_tolerant(&self.judge_iters).clone());
        if let Some(v) = self.pjrt_batch_ns.get() {
            reg.set_gauge("service.pjrt_batch_ns_ewma", v);
        }
        if let Some(v) = self.native_block_ns.get() {
            reg.set_gauge("service.native_block_ns_ewma", v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_records_on_drop() {
        let hist = std::sync::Mutex::new(Histogram::new());
        {
            let _t = Timer::start(&hist);
            std::hint::black_box((0..1000).sum::<u64>());
        }
        assert_eq!(hist.lock().unwrap().count(), 1);
        assert!(hist.lock().unwrap().percentile(0.5) > 0.0);
    }

    #[test]
    fn service_metrics_summary_renders() {
        let m = ServiceMetrics::new();
        m.requests.add(3);
        m.latency_ns.lock().unwrap().record(1000.0);
        let s = m.summary();
        assert!(s.contains("requests=3"), "{s}");
    }

    #[test]
    fn ewma_tracks_and_starts_empty() {
        let e = Ewma::new(0.5);
        assert_eq!(e.get(), None);
        e.record(100.0);
        assert_eq!(e.get(), Some(100.0), "first sample seeds the average");
        e.record(200.0);
        assert_eq!(e.get(), Some(150.0));
        e.record(200.0);
        assert_eq!(e.get(), Some(175.0));
    }

    #[test]
    fn router_explores_then_prefers_the_faster_path() {
        let m = ServiceMetrics::new();
        assert!(
            m.prefer_native_block(),
            "unmeasured native path claims one exploratory request"
        );
        m.native_block_ns.record(1_000.0);
        assert!(!m.prefer_native_block(), "PJRT unmeasured: let dispatches seed it");
        m.pjrt_batch_ns.record(5_000.0);
        assert!(m.prefer_native_block(), "native measured faster");
        // a long streak of slow native runs flips the preference back
        for _ in 0..40 {
            m.native_block_ns.record(50_000.0);
        }
        assert!(!m.prefer_native_block());
    }

    #[test]
    fn ewma_is_sound_under_concurrent_recording() {
        // constant samples from many threads must converge to exactly that
        // constant (every CAS update maps v → v)
        let e = Ewma::new(0.2);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..500 {
                        e.record(42.0);
                    }
                });
            }
        });
        assert_eq!(e.get(), Some(42.0));

        // mixed samples: the average must stay finite and inside the
        // sample range regardless of interleaving
        let m = Ewma::new(0.2);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let m = &m;
                s.spawn(move || {
                    for i in 0..500u64 {
                        m.record(10.0 + ((t * 500 + i) % 90) as f64);
                    }
                });
            }
        });
        let v = m.get().expect("seeded");
        assert!(v.is_finite());
        assert!((10.0..=100.0).contains(&v), "ewma {v} escaped sample range");
    }

    #[test]
    fn timer_and_summary_tolerate_a_poisoned_lock() {
        let m = std::sync::Arc::new(ServiceMetrics::new());
        // poison every histogram mutex by panicking while holding it
        for hist in [&m.latency_ns, &m.batch_size, &m.judge_iters] {
            let _ = std::thread::scope(|s| {
                s.spawn(|| {
                    let _g = hist.lock().unwrap();
                    panic!("poison");
                })
                .join()
            });
        }
        assert!(m.latency_ns.lock().is_err(), "lock must actually be poisoned");
        // Timer::drop still records…
        {
            let _t = Timer::start(&m.latency_ns);
        }
        assert_eq!(lock_tolerant(&m.latency_ns).count(), 1);
        // …and summary still renders
        let s = m.summary();
        assert!(s.contains("requests=0"), "{s}");
    }

    #[test]
    fn export_into_publishes_service_names_idempotently() {
        let m = ServiceMetrics::new();
        m.requests.add(7);
        lock_tolerant(&m.latency_ns).record(1_000.0);
        let reg = MetricsRegistry::new();
        m.export_into(&reg);
        m.export_into(&reg); // idempotent re-export
        let snap = reg.snapshot();
        assert_eq!(snap.get("service.requests"), Some(&MetricValue::Counter(7)));
        match snap.get("service.latency_ns") {
            Some(MetricValue::Hist(h)) => assert_eq!(h.count, 1),
            other => panic!("wrong kind {other:?}"),
        }
        assert!(snap.get("service.pjrt_batch_ns_ewma").is_none(), "unseeded ewma omitted");
        m.pjrt_batch_ns.record(5.0);
        m.export_into(&reg);
        assert_eq!(
            reg.snapshot().get("service.pjrt_batch_ns_ewma"),
            Some(&MetricValue::Gauge(5.0))
        );
    }

    #[test]
    fn router_periodically_re_explores_the_slower_path() {
        let m = ServiceMetrics::new();
        m.native_block_ns.record(1_000.0);
        m.pjrt_batch_ns.record(500.0); // PJRT faster: native unpreferred
        let explorations = (0..2 * ServiceMetrics::EXPLORE_EVERY)
            .filter(|_| m.prefer_native_block())
            .count();
        assert_eq!(
            explorations, 2,
            "exactly one exploratory native run per {} decisions",
            ServiceMetrics::EXPLORE_EVERY
        );
    }
}
