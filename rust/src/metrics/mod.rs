//! Observability: counters, latency histograms with percentile queries,
//! and iteration-count histograms (how many quadrature iterations each
//! retrospective judgement actually needed — the paper's speedups live or
//! die on this distribution staying tiny).

pub mod histogram;

pub use histogram::Histogram;

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonic counter, shareable across threads.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1)
    }

    #[inline]
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Scope timer: `let _t = Timer::start(&hist);` records on drop (ns).
pub struct Timer<'a> {
    hist: &'a std::sync::Mutex<Histogram>,
    start: Instant,
}

impl<'a> Timer<'a> {
    pub fn start(hist: &'a std::sync::Mutex<Histogram>) -> Self {
        Timer { hist, start: Instant::now() }
    }
}

impl Drop for Timer<'_> {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as f64;
        self.hist.lock().unwrap().record(ns);
    }
}

/// Service-level metrics bundle for the coordinator.
#[derive(Debug, Default)]
pub struct ServiceMetrics {
    pub requests: Counter,
    pub batches: Counter,
    pub native_fallbacks: Counter,
    /// coalesced shared-operator block runs on the native path
    pub coalesced_blocks: Counter,
    pub latency_ns: std::sync::Mutex<Histogram>,
    pub batch_size: std::sync::Mutex<Histogram>,
    pub judge_iters: std::sync::Mutex<Histogram>,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        let lat = self.latency_ns.lock().unwrap();
        let bs = self.batch_size.lock().unwrap();
        let it = self.judge_iters.lock().unwrap();
        format!(
            "requests={} batches={} native={} coalesced={} | latency p50={} p95={} p99={} | batch p50={:.1} | iters p50={:.0} p95={:.0}",
            self.requests.get(),
            self.batches.get(),
            self.native_fallbacks.get(),
            self.coalesced_blocks.get(),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.50)),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.95)),
            crate::util::bench::Stats::fmt_time(lat.percentile(0.99)),
            bs.percentile(0.50),
            it.percentile(0.50),
            it.percentile(0.95),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_counts() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
    }

    #[test]
    fn timer_records_on_drop() {
        let hist = std::sync::Mutex::new(Histogram::new());
        {
            let _t = Timer::start(&hist);
            std::hint::black_box((0..1000).sum::<u64>());
        }
        assert_eq!(hist.lock().unwrap().count(), 1);
        assert!(hist.lock().unwrap().percentile(0.5) > 0.0);
    }

    #[test]
    fn service_metrics_summary_renders() {
        let m = ServiceMetrics::new();
        m.requests.add(3);
        m.latency_ns.lock().unwrap().record(1000.0);
        let s = m.summary();
        assert!(s.contains("requests=3"), "{s}");
    }
}
