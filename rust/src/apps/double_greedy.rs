//! Randomized double greedy for non-monotone submodular maximization of
//! `F(S) = log det(L_S)` (paper Alg. 8, "Gauss-DG"; Buchbinder et al.'s
//! tight 1/2-approximation).
//!
//! Iterate elements `i = 1..N` with `X` growing from ∅ and `Y` shrinking
//! from `[N]`. Gains:
//!   Δ⁺ = F(X ∪ i) − F(X)   =  log(L_ii − L_{i,X} L_X^{-1} L_{X,i})
//!   Δ⁻ = F(Y∖i) − F(Y)     = −log(L_ii − L_{i,Y'} L_{Y'}^{-1} L_{Y',i})
//! Add `i` to X iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊` (else drop from Y).
//!
//! Strategies:
//! * `Exact` — fresh dense Cholesky of `L_X` *and* `L_{Y'}` per element:
//!   the paper's baseline (the one that times out on the large graphs).
//! * `Incremental` — maintained inverses of `L_X` (insert) and `L_Y`
//!   (remove): O(k²) per element, the strong classical baseline.
//! * `Gauss` — the Δ⁺/Δ⁻ comparison race
//!   ([`crate::quadrature::race::race_dg`], Alg. 9 semantics) over
//!   submatrix views: under the default [`RacePolicy::Prune`] each
//!   element's two quadratures stop the moment the log-gap brackets
//!   separate; [`RacePolicy::Exhaustive`] refines both sides fully first
//!   and decides identically (property-tested). Since ISSUE 4 the two
//!   sides run as width-1 sessions of the unified query planner
//!   ([`crate::quadrature::query::Session`]) — they live on *different*
//!   operators (`L_X` vs `L_{Y'}`), the one shape that cannot share a
//!   panel, so the race drives one single-lane session per side with the
//!   §5.2 looser-side refinement unchanged.

use super::BifStrategy;
use crate::linalg::{Cholesky, MaintainedInverse};
use crate::quadrature::engine::{race_dg_joint, DgSideSpec, Engine, EngineConfig};
use crate::quadrature::race::{race_dg, RacePolicy};
use crate::quadrature::GqlOptions;
use crate::sparse::{Csr, SpectrumBounds, SubmatrixView};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Configuration for a double-greedy run.
#[derive(Clone, Copy, Debug)]
pub struct DgConfig {
    pub strategy: BifStrategy,
    pub window: SpectrumBounds,
    pub max_judge_iters: usize,
    /// restrict to the first `limit` elements (None = full ground set)
    pub limit: Option<usize>,
    /// process only this many elements but keep the FULL ground set in Y —
    /// used to measure per-element baseline cost without running the whole
    /// O(n⁴) baseline (the partial result is for timing only)
    pub stop_after: Option<usize>,
    /// Δ⁺/Δ⁻ comparison-race policy for the Gauss strategy (decisions are
    /// policy-independent; iteration counts are not)
    pub race: RacePolicy,
    /// Joint scheduling (ISSUE 5): run each element's Δ⁺/Δ⁻ race through
    /// a shared multi-operator [`Engine`] — both sides advance one panel
    /// per engine round and the comparison resolves from per-round
    /// bracket exchange ([`race_dg_joint`]), finishing in ~max(a, b)
    /// rounds where the §5.2 alternation spends a + b single-side steps.
    /// Decisions (and therefore selections) are identical either way;
    /// `judge_iters_total` then counts both sides' iterations at the
    /// decision round.
    pub joint: bool,
}

impl DgConfig {
    pub fn new(strategy: BifStrategy, window: SpectrumBounds) -> Self {
        DgConfig {
            strategy,
            window,
            max_judge_iters: usize::MAX,
            limit: None,
            stop_after: None,
            race: RacePolicy::Prune,
            joint: false,
        }
    }

    pub fn with_race(mut self, r: RacePolicy) -> Self {
        self.race = r;
        self
    }

    pub fn with_joint(mut self, j: bool) -> Self {
        self.joint = j;
        self
    }

    pub fn with_limit(mut self, l: usize) -> Self {
        self.limit = Some(l);
        self
    }

    pub fn with_stop_after(mut self, k: usize) -> Self {
        self.stop_after = Some(k);
        self
    }

    fn gql_opts(&self) -> GqlOptions {
        GqlOptions::new(self.window.lo, self.window.hi).with_max_iters(self.max_judge_iters)
    }
}

/// Result of a run.
#[derive(Clone, Debug)]
pub struct DgResult {
    /// the selected set X (== final Y)
    pub chosen: Vec<usize>,
    /// log det(L_X) of the selection (exact, for quality comparison)
    pub objective: f64,
    pub judge_iters_total: usize,
    pub elements: usize,
}

/// Exact BIF via Cholesky over `idx` (baseline path).
fn exact_bif(l: &Csr, idx: &[usize], v: usize) -> f64 {
    if idx.is_empty() {
        return 0.0;
    }
    let sub = l.principal_submatrix(idx).to_dense();
    let col: Vec<f64> = idx.iter().map(|&m| l.get(m, v)).collect();
    Cholesky::factor(&sub).expect("submatrix must be PD").bif(&col)
}

/// Run double greedy on the kernel `l` (shared behind an [`Arc`] so the
/// joint path's submatrix views can move into the engine's operator
/// store).
pub fn double_greedy(l: &Arc<Csr>, cfg: DgConfig, rng: &mut Rng) -> DgResult {
    let n = cfg.limit.unwrap_or(l.n).min(l.n);
    let mut x: Vec<usize> = Vec::new();
    let mut y: Vec<usize> = (0..n).collect();
    let mut in_x = vec![false; n];
    let mut in_y = vec![true; n];
    let mut judge_iters_total = 0usize;

    // incremental state (only maintained for that strategy)
    let mut minv_x = MaintainedInverse::empty();
    let mut minv_y = MaintainedInverse::empty();
    if cfg.strategy == BifStrategy::Incremental {
        for v in 0..n {
            let col: Vec<f64> = minv_y.members().iter().map(|&m| l.get(m, v)).collect();
            assert!(minv_y.insert(v, &col, l.get(v, v)), "L must be PD");
        }
    }

    let process = cfg.stop_after.map_or(n, |k| k.min(n));
    for i in 0..process {
        let p = rng.f64();
        let l_ii = l.get(i, i);
        let y_rest: Vec<usize> = y.iter().copied().filter(|&m| m != i).collect();

        let add = match cfg.strategy {
            BifStrategy::Exact => {
                let bif_x = exact_bif(l, &x, i);
                let bif_y = exact_bif(l, &y_rest, i);
                decide(p, l_ii, bif_x, bif_y)
            }
            BifStrategy::Incremental => {
                // X side through minv_x; Y side: remove i to get L_{Y'},
                // query, then conditionally reinsert (never needed: i
                // always leaves Y'⇒Y or X decision is final for i)
                let col_x: Vec<f64> =
                    minv_x.members().iter().map(|&m| l.get(m, i)).collect();
                let bif_x = if minv_x.is_empty() { 0.0 } else { minv_x.bif(&col_x) };
                minv_y.remove(i);
                let col_y: Vec<f64> =
                    minv_y.members().iter().map(|&m| l.get(m, i)).collect();
                let bif_y = if minv_y.is_empty() { 0.0 } else { minv_y.bif(&col_y) };
                let add = decide(p, l_ii, bif_x, bif_y);
                if add {
                    // i returns to Y (it stays in the shrinking set)
                    let col: Vec<f64> =
                        minv_y.members().iter().map(|&m| l.get(m, i)).collect();
                    assert!(minv_y.insert(i, &col, l_ii));
                    let colx: Vec<f64> =
                        minv_x.members().iter().map(|&m| l.get(m, i)).collect();
                    assert!(minv_x.insert(i, &colx, l_ii));
                }
                add
            }
            BifStrategy::Gauss => {
                // x and y_rest are ascending by construction (streaming
                // row order); §Perf: materialization tried and reverted
                let view_x = SubmatrixView::new(l, &x);
                let ux = view_x.column_of(i);
                let view_y = SubmatrixView::new(l, &y_rest);
                let uy = view_y.column_of(i);
                let (ans, js) = if cfg.joint {
                    // cross-operator scheduling: both sides share one
                    // engine, one panel per operator per round; the specs
                    // own their views (the engine's store pins them for
                    // the race and drops them when the tickets compact)
                    let mut eng = Engine::new(
                        EngineConfig::default().with_width(1).with_lanes(2).with_ttl_rounds(4),
                    )
                    .expect("static engine config is valid");
                    let spec_x = (!x.is_empty()).then_some(DgSideSpec {
                        op: Arc::new(view_x),
                        u: ux,
                        opts: cfg.gql_opts(),
                    });
                    let spec_y = (!y_rest.is_empty()).then_some(DgSideSpec {
                        op: Arc::new(view_y),
                        u: uy,
                        opts: cfg.gql_opts(),
                    });
                    race_dg_joint(&mut eng, spec_x, spec_y, l_ii, p, cfg.race)
                } else {
                    let op_x = (!x.is_empty())
                        .then_some((&view_x as &dyn crate::sparse::SymOp, ux.as_slice()));
                    let op_y = (!y_rest.is_empty())
                        .then_some((&view_y as &dyn crate::sparse::SymOp, uy.as_slice()));
                    race_dg(op_x, op_y, l_ii, p, cfg.gql_opts(), cfg.gql_opts(), cfg.race)
                };
                judge_iters_total += js.iters;
                ans
            }
        };

        if add {
            x.push(i);
            in_x[i] = true;
        } else {
            y = y_rest;
            in_y[i] = false;
        }
    }

    debug_assert!(x.iter().all(|&v| in_y[v]), "X ⊆ Y invariant");
    let objective = if x.is_empty() {
        f64::NEG_INFINITY
    } else {
        Cholesky::factor(&l.principal_submatrix(&x).to_dense())
            .expect("selected set must be PD")
            .logdet()
    };
    DgResult { chosen: x, objective, judge_iters_total, elements: n }
}

/// The double-greedy decision: add iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊`.
fn decide(p: f64, l_ii: f64, bif_x: f64, bif_y: f64) -> bool {
    let dp = (l_ii - bif_x).max(1e-300).ln(); // Δ⁺
    let dm = -(l_ii - bif_y).max(1e-300).ln(); // Δ⁻
    p * dm.max(0.0) <= (1.0 - p) * dp.max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::util::prop::forall;

    fn setup(rng: &mut Rng, n: usize, density: f64) -> (Arc<Csr>, SpectrumBounds) {
        let (l, w) = random_sparse_spd(rng, n, density, 0.05);
        (Arc::new(l), w)
    }

    #[test]
    fn gauss_and_exact_choose_identical_sets() {
        forall(6, 0xD6, |rng| {
            let n = 16 + rng.below(24);
            let (l, w) = setup(rng, n, 0.2);
            let seed = rng.next_u64();
            let run = |strategy| {
                let mut r = Rng::new(seed);
                double_greedy(&l, DgConfig::new(strategy, w), &mut r).chosen
            };
            assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Gauss));
        });
    }

    #[test]
    fn incremental_matches_exact() {
        forall(5, 0xD7, |rng| {
            let n = 12 + rng.below(16);
            let (l, w) = setup(rng, n, 0.3);
            let seed = rng.next_u64();
            let run = |strategy| {
                let mut r = Rng::new(seed);
                double_greedy(&l, DgConfig::new(strategy, w), &mut r).chosen
            };
            assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Incremental));
        });
    }

    #[test]
    fn objective_reported_matches_selection() {
        let mut rng = Rng::new(0xD8);
        let (l, w) = setup(&mut rng, 30, 0.2);
        let res = double_greedy(&l, DgConfig::new(BifStrategy::Exact, w), &mut rng);
        if !res.chosen.is_empty() {
            let want = Cholesky::factor(&l.principal_submatrix(&res.chosen).to_dense())
                .unwrap()
                .logdet();
            crate::util::prop::assert_close(res.objective, want, 1e-12, 1e-12);
        }
    }

    #[test]
    fn limit_restricts_ground_set() {
        let mut rng = Rng::new(0xD9);
        let (l, w) = setup(&mut rng, 40, 0.2);
        let res = double_greedy(
            &l,
            DgConfig::new(BifStrategy::Gauss, w).with_limit(10),
            &mut rng,
        );
        assert_eq!(res.elements, 10);
        assert!(res.chosen.iter().all(|&v| v < 10));
    }

    #[test]
    fn race_policies_decide_identically() {
        // the Δ⁺/Δ⁻ comparison race must pick the same set whether it
        // stops at first bracket separation or refines both sides fully
        forall(6, 0xDB, |rng| {
            let n = 16 + rng.below(20);
            let (l, w) = setup(rng, n, 0.25);
            let seed = rng.next_u64();
            let run = |race| {
                let mut r = Rng::new(seed);
                double_greedy(
                    &l,
                    DgConfig::new(BifStrategy::Gauss, w).with_race(race),
                    &mut r,
                )
            };
            let pr = run(RacePolicy::Prune);
            let ex = run(RacePolicy::Exhaustive);
            assert_eq!(pr.chosen, ex.chosen, "policies diverged");
            assert!(
                pr.judge_iters_total <= ex.judge_iters_total,
                "pruning refined more ({} vs {})",
                pr.judge_iters_total,
                ex.judge_iters_total
            );
        });
    }

    #[test]
    fn joint_engine_race_selects_identically_to_sequential() {
        // the ISSUE 5 cross-operator path: per-round bracket exchange
        // through a shared engine must pick exactly the set the §5.2
        // alternation (and the exact baseline) picks
        forall(5, 0xDC, |rng| {
            let n = 16 + rng.below(20);
            let (l, w) = setup(rng, n, 0.25);
            let seed = rng.next_u64();
            let run = |joint| {
                let mut r = Rng::new(seed);
                double_greedy(
                    &l,
                    DgConfig::new(BifStrategy::Gauss, w).with_joint(joint),
                    &mut r,
                )
                .chosen
            };
            let sequential = run(false);
            assert_eq!(sequential, run(true), "joint scheduling changed the selection");
            let mut r = Rng::new(seed);
            let exact = double_greedy(&l, DgConfig::new(BifStrategy::Exact, w), &mut r).chosen;
            assert_eq!(sequential, exact);
        });
    }

    #[test]
    fn deterministic_under_seed() {
        let mut rng = Rng::new(0xDA);
        let (l, w) = setup(&mut rng, 25, 0.25);
        let r1 = {
            let mut r = Rng::new(7);
            double_greedy(&l, DgConfig::new(BifStrategy::Gauss, w), &mut r)
        };
        let r2 = {
            let mut r = Rng::new(7);
            double_greedy(&l, DgConfig::new(BifStrategy::Gauss, w), &mut r)
        };
        assert_eq!(r1.chosen, r2.chosen);
    }
}
