//! BIF-based centrality ranking (paper §2, "Network Analysis").
//!
//! Bonacich centrality solves `(I − αA) x = 1`; the local estimate of node
//! `i` is `x_i = e_i^T (I − αA)^{-1} 1` — a *general* bilinear form, which
//! the polarization identity reduces to two BIFs:
//!
//!   u^T M^{-1} v = ¼ (u+v)^T M^{-1} (u+v) − ¼ (u−v)^T M^{-1} (u−v)
//!
//! GQL brackets each term, giving an interval per node. Ranking the top-k
//! then only needs intervals tight enough to *separate* candidates — the
//! same retrospective principle as the samplers: refine the widest
//! overlapping interval until the top-k set is unambiguous.
//!
//! Since ISSUE 4 each node's paired ± quadratures run as two estimate
//! queries on one width-2 [`Session`] panel: a single `matvec_multi`
//! sweep of `M` advances both polarization terms (they share the
//! operator), instead of the two independent scalar engines this module
//! used to drive — per-lane numerics are bit-identical to the scalar
//! path by the block engine's exactness contract.

use crate::quadrature::block::StopRule;
use crate::quadrature::query::{Query, Session};
use crate::quadrature::race::RacePolicy;
use crate::quadrature::GqlOptions;
use crate::sparse::{gershgorin_bounds, Csr, CsrBuilder};

/// Result of a top-k centrality query.
#[derive(Clone, Debug)]
pub struct CentralityResult {
    /// node ids, highest centrality first
    pub top: Vec<usize>,
    /// final [lo, hi] interval per inspected node
    pub intervals: Vec<(usize, f64, f64)>,
    /// total quadrature iterations spent
    pub iters: usize,
}

/// Interval tracker for one node's centrality via polarization: both
/// terms are estimate queries on one width-2 session panel, so each
/// refinement costs a single traversal of the shared operator.
struct NodeBracket {
    node: usize,
    session: Session,
    q_plus: usize,
    q_minus: usize,
    lo: f64,
    hi: f64,
}

impl NodeBracket {
    /// One panel sweep of the shared operator `m` (both terms advance
    /// together). Returns how many lanes could still refine, for
    /// iteration accounting.
    fn refine(&mut self, m: &Csr) -> usize {
        let live = [self.q_plus, self.q_minus]
            .iter()
            .filter(|&&q| !self.session.is_resolved(q))
            .count();
        self.session.step(m);
        let bp = self.session.bounds(self.q_plus).expect("plus lane has bounds");
        let bm = self.session.bounds(self.q_minus).expect("minus lane has bounds");
        let (mlo, mhi) = (bm.lower(), bm.upper());
        // x = ¼(plus) − ¼(minus): lower needs minus's upper, and vice versa
        self.lo = 0.25 * (bp.lower() - mhi);
        self.hi = 0.25 * (bp.upper() - mlo);
        live
    }

    fn gap(&self) -> f64 {
        self.hi - self.lo
    }

    fn exhausted(&self) -> bool {
        self.session.is_resolved(self.q_plus) && self.session.is_resolved(self.q_minus)
    }
}

/// `M = I − αA` as CSR (α must keep M SPD: α < 1/λ_max(A) suffices for
/// symmetric A with nonnegative spectrum radius; callers pick α).
pub fn bonacich_matrix(a: &Csr, alpha: f64) -> Csr {
    let mut b = CsrBuilder::new(a.n);
    for i in 0..a.n {
        for (j, v) in a.row(i) {
            b.push(i, j, -alpha * v);
        }
        b.push(i, i, 1.0);
    }
    b.build()
}

/// Rank the top-k Bonacich-central nodes of adjacency `a` among the
/// candidate set (all nodes if `None`), refining BIF intervals only as far
/// as the ranking requires.
pub fn rank_top_k_centrality(
    a: &Csr,
    alpha: f64,
    k: usize,
    candidates: Option<&[usize]>,
) -> CentralityResult {
    let m = bonacich_matrix(a, alpha);
    let window = gershgorin_bounds(&m).clamp_lo(1e-6);
    let opts = GqlOptions::new(window.lo.max(1e-9), window.hi.max(window.lo * 2.0));
    let n = m.n;
    let cand: Vec<usize> = candidates.map_or((0..n).collect(), |c| c.to_vec());
    assert!(k <= cand.len(), "k larger than candidate set");

    let ones = vec![1.0; n];
    let mut brackets: Vec<NodeBracket> = cand
        .iter()
        .map(|&i| {
            // u = e_i, v = 1: u+v and u−v share the operator M, so both
            // polarization terms ride one width-2 panel (a zero u−v —
            // only possible at n = 1 — resolves exactly without a lane)
            let mut plus = ones.clone();
            plus[i] += 1.0;
            let mut minus: Vec<f64> = ones.iter().map(|x| -x).collect();
            minus[i] += 1.0;
            let mut session = Session::new(&m, opts, 2, RacePolicy::Prune);
            let q_plus = session.submit(Query::Estimate { u: plus, stop: StopRule::Exhaust });
            let q_minus = session.submit(Query::Estimate { u: minus, stop: StopRule::Exhaust });
            NodeBracket {
                node: i,
                session,
                q_plus,
                q_minus,
                lo: f64::NEG_INFINITY,
                hi: f64::INFINITY,
            }
        })
        .collect();

    let mut iters = 0usize;
    for b in brackets.iter_mut() {
        iters += b.refine(&m);
    }

    // Refine until the k-th and (k+1)-th intervals separate.
    loop {
        // order by interval midpoint, descending
        let mut order: Vec<usize> = (0..brackets.len()).collect();
        order.sort_by(|&x, &y| {
            let mx = brackets[x].lo + brackets[x].hi;
            let my = brackets[y].lo + brackets[y].hi;
            my.partial_cmp(&mx).unwrap()
        });
        if k == 0 || k == brackets.len() {
            let top = order[..k].iter().map(|&i| brackets[i].node).collect();
            return finish(top, brackets, iters);
        }
        // separation test: min lower bound of the top-k above max upper
        // bound of the rest
        let kth_lo = order[..k]
            .iter()
            .map(|&i| brackets[i].lo)
            .fold(f64::INFINITY, f64::min);
        let rest_hi = order[k..]
            .iter()
            .map(|&i| brackets[i].hi)
            .fold(f64::NEG_INFINITY, f64::max);
        if kth_lo >= rest_hi || brackets.iter().all(|b| b.exhausted()) {
            let top = order[..k].iter().map(|&i| brackets[i].node).collect();
            return finish(top, brackets, iters);
        }
        // refine the widest still-overlapping bracket near the boundary
        let widest = order
            .iter()
            .copied()
            .filter(|&i| !brackets[i].exhausted())
            .filter(|&i| brackets[i].hi >= kth_lo && brackets[i].lo <= rest_hi)
            .max_by(|&x, &y| brackets[x].gap().partial_cmp(&brackets[y].gap()).unwrap());
        match widest {
            Some(i) => iters += brackets[i].refine(&m),
            None => {
                let top = order[..k].iter().map(|&i| brackets[i].node).collect();
                return finish(top, brackets, iters);
            }
        }
    }
}

fn finish(top: Vec<usize>, brackets: Vec<NodeBracket>, iters: usize) -> CentralityResult {
    CentralityResult {
        top,
        intervals: brackets.iter().map(|b| (b.node, b.lo, b.hi)).collect(),
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{power_law_graph, laplacian};
    use crate::quadrature::cg_solve;
    use crate::sparse::CsrBuilder;
    use crate::util::rng::Rng;

    /// adjacency of a small undirected graph
    fn adjacency(n: usize, edges: &[(usize, usize)]) -> Csr {
        let mut b = CsrBuilder::new(n);
        for &(i, j) in edges {
            b.push_sym(i, j, 1.0);
        }
        b.build()
    }

    fn exact_centrality(a: &Csr, alpha: f64) -> Vec<f64> {
        let m = bonacich_matrix(a, alpha);
        let ones = vec![1.0; a.n];
        cg_solve(&m, &ones, 1e-12, 50 * a.n).x
    }

    #[test]
    fn star_graph_hub_wins() {
        // star: node 0 connected to all others — clearly most central
        let n = 12;
        let edges: Vec<(usize, usize)> = (1..n).map(|i| (0, i)).collect();
        let a = adjacency(n, &edges);
        let res = rank_top_k_centrality(&a, 0.05, 1, None);
        assert_eq!(res.top, vec![0]);
    }

    #[test]
    fn ranking_matches_exact_solution() {
        let mut rng = Rng::new(0xCE1);
        let n = 60;
        let edges = power_law_graph(&mut rng, n, 4.0);
        let a = adjacency(n, &edges);
        let alpha = 0.5 / (gershgorin_bounds(&a).hi.max(1.0));
        let exact = exact_centrality(&a, alpha);
        let mut want: Vec<usize> = (0..n).collect();
        want.sort_by(|&x, &y| exact[y].partial_cmp(&exact[x]).unwrap());
        let res = rank_top_k_centrality(&a, alpha, 5, None);
        let mut got = res.top.clone();
        got.sort_unstable();
        let mut expect = want[..5].to_vec();
        expect.sort_unstable();
        assert_eq!(got, expect, "intervals: {:?}", &res.intervals[..8.min(n)]);
    }

    #[test]
    fn intervals_contain_exact_values() {
        let mut rng = Rng::new(0xCE2);
        let n = 30;
        let edges = power_law_graph(&mut rng, n, 3.0);
        let a = adjacency(n, &edges);
        let alpha = 0.4 / gershgorin_bounds(&a).hi.max(1.0);
        let exact = exact_centrality(&a, alpha);
        let res = rank_top_k_centrality(&a, alpha, 3, None);
        for &(node, lo, hi) in &res.intervals {
            assert!(
                lo <= exact[node] + 1e-6 && exact[node] <= hi + 1e-6,
                "node {node}: [{lo}, {hi}] vs exact {}",
                exact[node]
            );
        }
    }

    #[test]
    fn candidate_subset_respected() {
        let mut rng = Rng::new(0xCE3);
        let n = 40;
        let edges = power_law_graph(&mut rng, n, 4.0);
        let a = adjacency(n, &edges);
        let alpha = 0.3 / gershgorin_bounds(&a).hi.max(1.0);
        let cands = [3, 7, 11, 19];
        let res = rank_top_k_centrality(&a, alpha, 2, Some(&cands));
        assert_eq!(res.top.len(), 2);
        assert!(res.top.iter().all(|t| cands.contains(t)));
    }

    #[test]
    fn laplacian_plus_ridge_also_works_as_kernel() {
        // smoke: centrality machinery runs on a Laplacian-derived matrix
        let mut rng = Rng::new(0xCE4);
        let n = 25;
        let edges = power_law_graph(&mut rng, n, 3.0);
        let _l = laplacian(n, &edges);
        let a = adjacency(n, &edges);
        let res = rank_top_k_centrality(&a, 0.02, 4, None);
        assert_eq!(res.top.len(), 4);
        assert!(res.iters > 0);
    }
}
