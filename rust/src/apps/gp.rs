//! Gaussian-process marginal likelihood through the engine (§5
//! applications): for a kernel `K` and noise `σ²`,
//!
//! `log p(y) = −½ yᵀ(K+σ²I)⁻¹y − ½ logdet(K+σ²I) − (n/2) log 2π`.
//!
//! The two expensive terms are exactly the two query kinds the engine
//! serves on one operator: the data-fit term is a bilinear inverse form
//! ([`Query::Estimate`], deterministic four-bound bracket) and the
//! complexity term is a stochastic logdet ([`Query::LogDet`], combined
//! quadrature + Monte-Carlo interval). Both are submitted **co-keyed**
//! against the shifted operator `K + σ²I`, so one panel sweep advances
//! the fit lane and every probe lane together — the coalescing the
//! stochastic subsystem exists for.
//!
//! `K + σ²I` never densifies ([`Csr::with_diag_shift`]); its spectrum
//! window is free: `K` is PSD, so `λ_min ≥ σ²`, and Gershgorin on `K`
//! caps the top end.

use crate::quadrature::block::StopRule;
use crate::quadrature::engine::{Engine, EngineConfig, OpKey};
use crate::quadrature::gql::Bounds;
use crate::quadrature::query::{Answer, Query};
use crate::quadrature::stochastic::{Interval, SlqConfig, SlqConfigError, StochasticReport};
use crate::quadrature::GqlOptions;
use crate::sparse::{gershgorin_bounds, Csr};
use std::fmt;
use std::sync::Arc;

/// Configuration of one marginal-likelihood evaluation.
#[derive(Clone, Copy, Debug)]
pub struct GpConfig {
    /// Observation noise variance `σ²` (> 0; also the PD ridge).
    pub noise: f64,
    /// Stochastic config for the `logdet(K+σ²I)` term.
    pub slq: SlqConfig,
    /// Relative bracket tolerance for the data-fit term.
    pub fit_tol: f64,
}

impl GpConfig {
    pub fn new(noise: f64, slq: SlqConfig) -> Self {
        GpConfig { noise, slq, fit_tol: 1e-8 }
    }
}

/// Why a marginal-likelihood evaluation was refused.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GpError {
    /// Noise variance must be strictly positive and finite — it is the
    /// lower spectrum edge of the shifted operator.
    BadNoise(f64),
    /// `y.len()` must equal the kernel dimension.
    DimMismatch { n: usize, len: usize },
    /// The stochastic config failed its typed validation.
    Invalid(SlqConfigError),
}

impl fmt::Display for GpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            GpError::BadNoise(s) => write!(f, "noise variance must be finite and > 0 (got {s})"),
            GpError::DimMismatch { n, len } => {
                write!(f, "kernel is {n}-dimensional but y has {len} entries")
            }
            GpError::Invalid(e) => write!(f, "invalid stochastic config: {e}"),
        }
    }
}

impl std::error::Error for GpError {}

impl From<SlqConfigError> for GpError {
    fn from(e: SlqConfigError) -> Self {
        GpError::Invalid(e)
    }
}

/// The two-term evidence report.
#[derive(Clone, Debug)]
pub struct GpEvidence {
    /// Point estimate of the data-fit term `yᵀ(K+σ²I)⁻¹y` (bracket
    /// midpoint).
    pub fit: f64,
    /// Deterministic four-bound bracket on the fit term — contains the
    /// exact value by the GQL guarantee.
    pub fit_bounds: Bounds,
    /// SLQ report for `logdet(K+σ²I)`.
    pub logdet: StochasticReport,
    /// Point estimate of `log p(y)`.
    pub lml: f64,
    /// Interval on `log p(y)`: the fit bracket and the logdet combined
    /// interval propagated through the (monotone-decreasing) evidence
    /// formula. Deterministic in the fit term, 95%-confidence in the
    /// Monte-Carlo part of the logdet term.
    pub interval: Interval,
}

/// Engine key the evaluation parks its shifted operator under (the
/// engine is private to the call, so any constant works).
const GP_KEY: OpKey = 1;

/// Evaluate `log p(y)` for the GP `(K, σ²)` — both expensive terms
/// co-keyed on one engine panel (module docs).
pub fn gp_log_marginal(kernel: &Arc<Csr>, y: &[f64], cfg: &GpConfig) -> Result<GpEvidence, GpError> {
    if !(cfg.noise.is_finite() && cfg.noise > 0.0) {
        return Err(GpError::BadNoise(cfg.noise));
    }
    if y.len() != kernel.n {
        return Err(GpError::DimMismatch { n: kernel.n, len: y.len() });
    }
    cfg.slq.validate()?;
    let shifted = Arc::new(kernel.with_diag_shift(cfg.noise));
    // K is PSD ⇒ λ_min(K+σ²I) ≥ σ²; Gershgorin caps the top. The 1%
    // slack on the left end keeps the Radau fixed node strictly below
    // the spectrum under roundoff.
    let g = gershgorin_bounds(kernel);
    let opts = GqlOptions::new(0.99 * cfg.noise, g.hi.max(0.0) + cfg.noise);
    let mut eng = Engine::new(EngineConfig::default()).expect("default engine config is valid");
    let t_fit = eng.submit(
        GP_KEY,
        Arc::clone(&shifted) as Arc<dyn crate::sparse::SymOp>,
        opts,
        Query::Estimate { u: y.to_vec(), stop: StopRule::GapRel(cfg.fit_tol) },
    );
    let t_ld = eng
        .submit_keyed(GP_KEY, opts, Query::LogDet { cfg: cfg.slq }, None)
        .expect("operator keyed in the line above");
    eng.drain();
    let fit_bounds = match eng.answer(t_fit) {
        Some(Answer::Estimate { bounds, .. }) => *bounds,
        other => unreachable!("estimate queries answer with estimates, got {other:?}"),
    };
    let logdet = eng
        .answer(t_ld)
        .and_then(Answer::stochastic)
        .expect("logdet queries answer stochastically")
        .clone();
    let n = kernel.n as f64;
    let norm = 0.5 * n * (2.0 * std::f64::consts::PI).ln();
    let fit = fit_bounds.mid();
    let lml = -0.5 * fit - 0.5 * logdet.estimate - norm;
    let interval = Interval {
        lo: -0.5 * fit_bounds.upper() - 0.5 * logdet.combined.hi - norm,
        hi: -0.5 * fit_bounds.lower() - 0.5 * logdet.combined.lo - norm,
    };
    Ok(GpEvidence { fit, fit_bounds, logdet, lml, interval })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{rbf_kernel_csr, PointCloud};
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    fn setup(seed: u64, n: usize) -> (Arc<Csr>, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let cloud = PointCloud::synthetic(&mut rng, n, 4);
        let k = rbf_kernel_csr(&cloud, 0.4, 0.8, 0.3);
        let y: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        (Arc::new(k), y)
    }

    #[test]
    fn evidence_brackets_the_exact_marginal_likelihood() {
        let n = 32;
        let (k, y) = setup(0x69EE01, n);
        let cfg = GpConfig::new(0.25, SlqConfig::new(12, 0x69EE02, 2e-2));
        let got = gp_log_marginal(&k, &y, &cfg).expect("valid inputs");
        let ch = Cholesky::factor(&k.with_diag_shift(cfg.noise).to_dense()).unwrap();
        let exact_fit = ch.bif(&y);
        let exact_lml = -0.5 * exact_fit
            - 0.5 * ch.logdet()
            - 0.5 * (n as f64) * (2.0 * std::f64::consts::PI).ln();
        // the fit term's bracket is deterministic: containment is a
        // guarantee, not a confidence statement
        let eps = 1e-9 * (1.0 + exact_fit.abs());
        assert!(
            got.fit_bounds.lower() - eps <= exact_fit
                && exact_fit <= got.fit_bounds.upper() + eps,
            "exact fit {exact_fit} outside [{}, {}]",
            got.fit_bounds.lower(),
            got.fit_bounds.upper()
        );
        let guard = 4.0 * (got.interval.width() / 2.0) + 1e-9;
        assert!(
            (exact_lml - got.interval.mid()).abs() <= guard,
            "exact lml {exact_lml} vs interval [{}, {}]",
            got.interval.lo,
            got.interval.hi
        );
        assert!(got.interval.contains(got.lml));
        // pinned seed: the whole evaluation is bit-reproducible
        let again = gp_log_marginal(&k, &y, &cfg).unwrap();
        assert_eq!(got.lml.to_bits(), again.lml.to_bits());
        assert_eq!(got.interval.lo.to_bits(), again.interval.lo.to_bits());
    }

    #[test]
    fn typed_errors_cover_every_bad_input() {
        let (k, y) = setup(0x69EE03, 12);
        let slq = SlqConfig::new(4, 1, 1e-2);
        assert_eq!(
            gp_log_marginal(&k, &y, &GpConfig::new(0.0, slq)).unwrap_err(),
            GpError::BadNoise(0.0)
        );
        assert!(matches!(
            gp_log_marginal(&k, &y, &GpConfig::new(f64::NAN, slq)).unwrap_err(),
            GpError::BadNoise(_)
        ));
        assert_eq!(
            gp_log_marginal(&k, &y[..5], &GpConfig::new(0.1, slq)).unwrap_err(),
            GpError::DimMismatch { n: 12, len: 5 }
        );
        assert_eq!(
            gp_log_marginal(&k, &y, &GpConfig::new(0.1, SlqConfig::new(0, 1, 1e-2)))
                .unwrap_err(),
            GpError::Invalid(SlqConfigError::ZeroProbes)
        );
    }
}
