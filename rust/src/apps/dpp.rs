//! Metropolis–Hastings sampling from a DPP (paper Alg. 3, "Gauss-Dpp").
//!
//! State: a subset `Y ⊆ [N]`. Per step, pick `y` uniformly and propose the
//! single-element change:
//! * `y ∉ Y` — add with probability `min{1, s}` where
//!   `s = L_yy − L_{y,Y} L_Y^{-1} L_{Y,y}` (the Schur complement, i.e.
//!   `det L_{Y∪y} / det L_Y`);
//! * `y ∈ Y` — with `Y' = Y∖{y}`, remove with probability `min{1, 1/s'}`
//!   where `s' = L_yy − L_{y,Y'} L_{Y'}^{-1} L_{Y',y}`.
//!
//! Both decisions reduce to threshold comparisons on a BIF:
//! add  ⟺ `p < s`  ⟺ NOT (L_yy − p < BIF)      → `judge_threshold(t = L_yy − p)`
//! rem  ⟺ `p < 1/s'` ⟺ `L_yy − 1/p < BIF`       → `judge_threshold(t = L_yy − 1/p)`
//!
//! (The paper's Alg. 3 shows `L_yy − p` in both branches; the removal
//! threshold must be `L_yy − 1/p` for detailed balance wrt `det(L_Y)` —
//! an OCR artifact we correct and note in DESIGN.md.)
//!
//! The spectrum window for every submatrix comes from Cauchy interlacing:
//! the spectrum of any principal submatrix of `L` lies inside the spectrum
//! of `L`, so one global window (plus the ridge clamp on the left end)
//! serves the whole chain — O(1) per step.

use super::BifStrategy;
use crate::linalg::{Cholesky, MaintainedInverse};
use crate::quadrature::block::StopRule;
use crate::quadrature::engine::{Engine, EngineConfig, EngineConfigError, Ticket};
use crate::quadrature::query::{Answer, Query, QueryArm, Session};
use crate::quadrature::race::RacePolicy;
use crate::quadrature::stochastic::{Interval, SlqConfig, SlqConfigError, StochasticReport};
use crate::quadrature::{judge_threshold, GqlOptions, Reorth};
use crate::sparse::{Csr, SpectrumBounds, SubmatrixView};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Configuration for a DPP chain.
#[derive(Clone, Copy, Debug)]
pub struct DppConfig {
    pub strategy: BifStrategy,
    /// global spectrum window (valid for all submatrices by interlacing)
    pub window: SpectrumBounds,
    /// iteration cap per judgement (usize::MAX = paper semantics)
    pub max_judge_iters: usize,
    /// initial subset size (paper Fig. 2 uses N/3)
    pub init_size: usize,
}

impl DppConfig {
    pub fn new(strategy: BifStrategy, window: SpectrumBounds) -> Self {
        DppConfig { strategy, window, max_judge_iters: usize::MAX, init_size: 0 }
    }

    pub fn with_init_size(mut self, k: usize) -> Self {
        self.init_size = k;
        self
    }

    fn gql_opts(&self) -> GqlOptions {
        GqlOptions::new(self.window.lo, self.window.hi).with_max_iters(self.max_judge_iters)
    }
}

/// Cumulative chain statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct DppStats {
    pub steps: usize,
    pub accepted: usize,
    pub judge_iters_total: usize,
    /// total quadrature/cholesky decisions taken
    pub decisions: usize,
}

/// One MH DPP chain. The kernel is held behind an [`Arc`] (shared with
/// the caller and with every [`SubmatrixView`] the chain spins up), so
/// samplers are `'static` and can be parked in resident services.
pub struct DppSampler {
    l: Arc<Csr>,
    cfg: DppConfig,
    y: Vec<usize>,
    in_y: Vec<bool>,
    /// maintained inverse for BifStrategy::Incremental
    minv: MaintainedInverse,
    pub stats: DppStats,
}

impl DppSampler {
    pub fn new(l: &Arc<Csr>, cfg: DppConfig, rng: &mut Rng) -> Self {
        let n = l.n;
        let k = cfg.init_size.min(n);
        let mut y = rng.sample_indices(n, k);
        // `y` is kept sorted ascending at all times: views over it stream
        // parent rows in increasing order (prefetcher-friendly, §Perf) and
        // insert/remove are O(k) memmoves instead of an O(k log k) sort
        // per judgement.
        y.sort_unstable();
        let mut in_y = vec![false; n];
        let mut minv = MaintainedInverse::empty();
        for &v in &y {
            in_y[v] = true;
        }
        if cfg.strategy == BifStrategy::Incremental {
            for &v in &y {
                let col: Vec<f64> = minv.members().iter().map(|&m| l.get(m, v)).collect();
                assert!(minv.insert(v, &col, l.get(v, v)), "init set not PD");
            }
        }
        DppSampler { l: Arc::clone(l), cfg, y, in_y, minv, stats: DppStats::default() }
    }

    pub fn current_set(&self) -> &[usize] {
        &self.y
    }

    /// BIF `L_{y,Y'} L_{Y'}^{-1} L_{Y',y}` exactly (baselines), over the
    /// index set `idx`.
    fn exact_bif(&self, idx: &[usize], v: usize) -> f64 {
        if idx.is_empty() {
            return 0.0;
        }
        let sub = self.l.principal_submatrix(idx);
        let col: Vec<f64> = idx.iter().map(|&m| self.l.get(m, v)).collect();
        let ch = Cholesky::factor(&sub.to_dense()).expect("submatrix must be PD");
        ch.bif(&col)
    }

    /// Decide `t < BIF(idx, v)` per the configured strategy.
    /// For `Incremental`, `v ∉ Y` means an addition (BIF against `Y` via
    /// the maintained inverse, O(k²)) and `v ∈ Y` a removal (then
    /// `BIF = L_vv − 1/M_vv` by the Schur-complement identity — O(1)).
    fn judge(&mut self, idx: &[usize], v: usize, t: f64) -> bool {
        self.stats.decisions += 1;
        match self.cfg.strategy {
            BifStrategy::Exact => t < self.exact_bif(idx, v),
            BifStrategy::Incremental => {
                let bif = if !self.in_y[v] {
                    // addition: L_{v,Y} M L_{Y,v} in members order
                    let col: Vec<f64> = self
                        .minv
                        .members()
                        .iter()
                        .map(|&m| self.l.get(m, v))
                        .collect();
                    if col.is_empty() { 0.0 } else { self.minv.bif(&col) }
                } else {
                    // removal: (L_Y^{-1})_vv = 1/(L_vv − BIF) ⇒ invert
                    let p = self
                        .minv
                        .members()
                        .iter()
                        .position(|&m| m == v)
                        .expect("member tracked");
                    self.l.get(v, v) - 1.0 / self.minv.inverse().get(p, p)
                };
                t < bif
            }
            BifStrategy::Gauss => {
                if idx.is_empty() {
                    return t < 0.0;
                }
                let view = SubmatrixView::new(&self.l, idx); // idx pre-sorted
                let u = view.column_of(v);
                // NOTE §Perf: materializing the view (`to_csr`) was tried
                // and reverted — judges decide in ~1-2 iterations on these
                // workloads, so the extra traversal never amortizes.
                let (ans, js) = judge_threshold(&view, &u, t, self.cfg.gql_opts());
                self.stats.judge_iters_total += js.iters;
                ans
            }
        }
    }

    /// One MH step. Returns whether the proposal was accepted.
    pub fn step(&mut self, rng: &mut Rng) -> bool {
        self.stats.steps += 1;
        let n = self.l.n;
        let y = rng.below(n);
        let p = rng.f64();
        let l_yy = self.l.get(y, y);
        if !self.in_y[y] {
            // propose adding y: accept iff p < s  ⟺ !(L_yy − p < BIF)
            let idx: Vec<usize> = self.y.clone();
            let add = !self.judge(&idx, y, l_yy - p);
            if add {
                self.apply_add(y);
                self.stats.accepted += 1;
            }
            add
        } else {
            // propose removing y: accept iff p < 1/s' ⟺ L_yy − 1/p < BIF
            let idx: Vec<usize> = self.y.iter().copied().filter(|&m| m != y).collect();
            let rem = self.judge(&idx, y, l_yy - 1.0 / p);
            if rem {
                self.apply_remove(y);
                self.stats.accepted += 1;
            }
            rem
        }
    }

    fn apply_add(&mut self, v: usize) {
        if self.cfg.strategy == BifStrategy::Incremental {
            let col: Vec<f64> = self.minv.members().iter().map(|&m| self.l.get(m, v)).collect();
            if !self.minv.insert(v, &col, self.l.get(v, v)) {
                return; // numerically not PD: reject the move
            }
        }
        let pos = self.y.partition_point(|&m| m < v);
        self.y.insert(pos, v); // keep sorted (see `new`)
        self.in_y[v] = true;
    }

    fn apply_remove(&mut self, v: usize) {
        if self.cfg.strategy == BifStrategy::Incremental {
            self.minv.remove(v);
        }
        let pos = self.y.binary_search(&v).expect("member tracked");
        self.y.remove(pos); // keep sorted (see `new`)
        self.in_y[v] = false;
    }

    /// Run `steps` MH steps; returns acceptance count.
    pub fn run(&mut self, steps: usize, rng: &mut Rng) -> usize {
        let mut acc = 0;
        for _ in 0..steps {
            if self.step(rng) {
                acc += 1;
            }
        }
        acc
    }
}

/// Configuration for greedy MAP inference over a DPP kernel.
#[derive(Clone, Copy, Debug)]
pub struct GreedyConfig {
    /// global spectrum window (valid for every `L_Y` by Cauchy interlacing)
    pub window: SpectrumBounds,
    /// target subset size
    pub k: usize,
    /// relative bracket tolerance each candidate score is refined to
    /// (when the race does not prune the candidate first)
    pub tol_rel: f64,
    /// candidate-scoring panel width: 1 = scalar-layout lanes (bit-equal
    /// to independent `Gql` runs), > 1 scores candidates in lockstep
    /// panels. **Invariant:** a width of 0 is clamped to 1 — the scalar
    /// path — mirroring the `max_iters` clamp in
    /// [`crate::quadrature::Gql::new`] (previously an `assert!`).
    pub block_width: usize,
    /// Lanczos reorthogonalization for candidate scoring (§5.4): use
    /// [`Reorth::Full`] on ill-conditioned kernels where plain Lanczos
    /// loses bound validity. Honored identically by the scalar and the
    /// block path (the engines share one recurrence core), so selections
    /// remain width-independent.
    pub reorth: Reorth,
    /// Candidate racing policy: [`RacePolicy::Prune`] (default) evicts
    /// candidates whose gain bracket is dominated and stops each round as
    /// soon as its argmax is determined; [`RacePolicy::Exhaustive`]
    /// refines every candidate to `tol_rel` before comparing. Selections
    /// are identical either way (see `quadrature::race`); only the panel
    /// sweep count differs.
    pub race: RacePolicy,
}

impl GreedyConfig {
    pub fn new(window: SpectrumBounds, k: usize) -> Self {
        GreedyConfig {
            window,
            k,
            tol_rel: 1e-10,
            block_width: 16,
            reorth: Reorth::None,
            race: RacePolicy::Prune,
        }
    }

    pub fn with_block_width(mut self, w: usize) -> Self {
        self.block_width = w;
        self
    }

    pub fn with_reorth(mut self, r: Reorth) -> Self {
        self.reorth = r;
        self
    }

    pub fn with_race(mut self, r: RacePolicy) -> Self {
        self.race = r;
        self
    }
}

/// Marginal gains below this are numerically indistinguishable from a
/// singular update; greedy stops rather than add a non-PD element.
const GAIN_FLOOR: f64 = 1e-12;

/// Cumulative racing statistics for one [`greedy_map_stats`] run.
#[derive(Clone, Copy, Debug, Default)]
pub struct GreedyStats {
    /// selection rounds that scored candidates through the race (the
    /// diagonal-only first round is free and not counted)
    pub rounds: usize,
    /// total `matvec_multi` panel sweeps spent scoring candidates
    pub sweeps: usize,
    /// candidates evicted by interval dominance across all rounds
    pub pruned: usize,
    /// rounds whose argmax was determined before every surviving
    /// candidate reached `tol_rel`
    pub decided_early: usize,
}

/// Greedy MAP inference: repeatedly add the candidate with the largest
/// Schur complement `s_c = L_cc − L_{c,Y} L_Y^{-1} L_{Y,c}` (equivalently
/// the largest log-det gain `log s_c`) until `cfg.k` elements are chosen
/// or no candidate keeps `L_Y` positive definite.
pub fn greedy_map(l: &Arc<Csr>, cfg: &GreedyConfig) -> Vec<usize> {
    greedy_map_stats(l, cfg).0
}

/// [`greedy_map`] plus per-run racing statistics (the `race` experiment
/// and `bench_race` count panel sweeps through this entry).
///
/// Every round compiles *all* remaining candidates into one
/// [`Query::Argmax`] on a [`Session`] over the same operator `L_Y`
/// (candidate `c`'s arm value is the marginal gain `L_cc − BIF`): under
/// [`RacePolicy::Prune`] a candidate stops refining the moment its gain
/// bracket falls below the best lower bound — the paper's "bounds tighten
/// iteratively" turned into best-arm early termination (ROADMAP item).
/// Selections are **identical** across policies and panel widths:
/// per-lane scores are bit-identical to scalar runs (the block engine's
/// exactness contract) and pruning only discards dominated candidates —
/// asserted in the tests below and in `rust/tests/prop_race.rs` /
/// `rust/tests/prop_session.rs`.
pub fn greedy_map_stats(l: &Arc<Csr>, cfg: &GreedyConfig) -> (Vec<usize>, GreedyStats) {
    let n = l.n;
    let k = cfg.k.min(n);
    // clamp like Gql::new clamps max_iters: width 0 means "no batching",
    // not "no panel" (ISSUE 3 satellite — this used to assert!)
    let width = cfg.block_width.max(1);
    let opts = GqlOptions::new(cfg.window.lo, cfg.window.hi).with_reorth(cfg.reorth);
    let stop = StopRule::GapRel(cfg.tol_rel);
    let mut stats = GreedyStats::default();
    let mut y: Vec<usize> = Vec::new(); // kept sorted (streaming views)
    let mut in_y = vec![false; n];
    while y.len() < k {
        let candidates: Vec<usize> = (0..n).filter(|&c| !in_y[c]).collect();
        let chosen = if y.is_empty() {
            // first round: gains are diagonal entries, no quadrature
            let mut best: Option<(usize, f64)> = None;
            for &c in &candidates {
                let gain = l.get(c, c);
                if best.map_or(true, |(_, g)| gain > g) {
                    best = Some((c, gain));
                }
            }
            match best {
                Some((c, gain)) if gain > GAIN_FLOOR => Some(c),
                _ => None,
            }
        } else {
            let view = SubmatrixView::new(l, &y);
            let mut session = Session::new(&view, opts, width, cfg.race);
            let arms: Vec<QueryArm> = candidates
                .iter()
                // arm value = L_cc − BIF, the marginal gain bracket
                .map(|&c| QueryArm::gain(view.column_of(c), stop, l.get(c, c)))
                .collect();
            let qid = session.submit(Query::Argmax { arms, floor: Some(GAIN_FLOOR) });
            let answers = session.run(&view);
            let (winner, rstats) = match &answers[qid] {
                Answer::Argmax { winner, stats, .. } => (*winner, stats),
                _ => unreachable!("argmax queries answer with argmax answers"),
            };
            stats.rounds += 1;
            stats.sweeps += rstats.sweeps;
            stats.pruned += rstats.pruned();
            if rstats.decided_early {
                stats.decided_early += 1;
            }
            winner.map(|a| candidates[a])
        };
        match chosen {
            Some(c) => {
                let pos = y.partition_point(|&m| m < c);
                y.insert(pos, c);
                in_y[c] = true;
            }
            None => break, // no PD-feasible candidate left
        }
    }
    (y, stats)
}

/// Joint greedy MAP over **several kernels** (ISSUE 5): each selection
/// round, every unfinished instance compiles its candidate race into one
/// [`Query::Argmax`] on a shared multi-operator [`Engine`] — one
/// `matvec_multi` panel per kernel per round — so R instances finish a
/// greedy round in ~max over instances of per-instance rounds instead of
/// their sum. Per-instance behavior (panel width, race policy, candidate
/// order) is exactly [`greedy_map`]'s, and per-lane scores are
/// bit-identical to scalar runs, so every selection equals its solo
/// `greedy_map` (asserted in the tests below and
/// `rust/tests/prop_engine.rs`).
///
/// `cfg` applies to every kernel — in particular `cfg.window` must be a
/// valid spectrum window for **all** of them (take the union of the
/// per-kernel windows). Returns the per-kernel selections plus the total
/// joint engine rounds; rejects unusable engine knobs with the typed
/// admission error.
pub fn greedy_map_multi(
    kernels: &[Arc<Csr>],
    cfg: &GreedyConfig,
    ecfg: EngineConfig,
) -> Result<(Vec<Vec<usize>>, usize), EngineConfigError> {
    // per-instance sessions must behave exactly like greedy_map's: same
    // panel width, same race policy
    let ecfg = ecfg
        .with_width(cfg.block_width.max(1))
        .with_policy(cfg.race);
    ecfg.validate()?;
    let opts = GqlOptions::new(cfg.window.lo, cfg.window.hi).with_reorth(cfg.reorth);
    let stop = StopRule::GapRel(cfg.tol_rel);
    let m = kernels.len();
    let mut ys: Vec<Vec<usize>> = vec![Vec::new(); m];
    let mut in_ys: Vec<Vec<bool>> = kernels.iter().map(|l| vec![false; l.n]).collect();
    let mut done: Vec<bool> = kernels.iter().map(|l| cfg.k.min(l.n) == 0).collect();

    // round 1: gains are diagonal entries, no quadrature (same free round
    // as greedy_map)
    for i in 0..m {
        if done[i] {
            continue;
        }
        let l = &kernels[i];
        let mut best: Option<(usize, f64)> = None;
        for c in 0..l.n {
            let gain = l.get(c, c);
            if best.map_or(true, |(_, g)| gain > g) {
                best = Some((c, gain));
            }
        }
        match best {
            Some((c, gain)) if gain > GAIN_FLOOR => {
                ys[i].push(c);
                in_ys[i][c] = true;
            }
            _ => done[i] = true,
        }
        if ys[i].len() >= cfg.k.min(l.n) {
            done[i] = true;
        }
    }

    let mut rounds_total = 0usize;
    loop {
        let active: Vec<usize> = (0..m).filter(|&i| !done[i]).collect();
        if active.is_empty() {
            break;
        }
        let candidates: Vec<Vec<usize>> = active
            .iter()
            .map(|&i| (0..kernels[i].n).filter(|&c| !in_ys[i][c]).collect())
            .collect();
        // the engine (and the views its store owns) lives only for this
        // round: winners are pulled out before the selections mutate
        let winners: Vec<Option<usize>> = {
            let mut eng = Engine::new(ecfg).expect("validated above");
            let tickets: Vec<Ticket> = active
                .iter()
                .zip(&candidates)
                .map(|(&i, cand)| {
                    let view = SubmatrixView::new(&kernels[i], &ys[i]);
                    let arms: Vec<QueryArm> = cand
                        .iter()
                        .map(|&c| QueryArm::gain(view.column_of(c), stop, kernels[i].get(c, c)))
                        .collect();
                    eng.submit(
                        i as crate::quadrature::engine::OpKey,
                        Arc::new(view),
                        opts,
                        Query::Argmax { arms, floor: Some(GAIN_FLOOR) },
                    )
                })
                .collect();
            eng.drain();
            rounds_total += eng.stats().rounds;
            tickets
                .iter()
                .map(|&t| match eng.answer(t).expect("engine drained") {
                    Answer::Argmax { winner, .. } => *winner,
                    _ => unreachable!("argmax queries answer with argmax answers"),
                })
                .collect()
        };
        for ((&i, cand), winner) in active.iter().zip(&candidates).zip(winners) {
            match winner {
                Some(a) => {
                    let c = cand[a];
                    let pos = ys[i].partition_point(|&x| x < c);
                    ys[i].insert(pos, c);
                    in_ys[i][c] = true;
                    if ys[i].len() >= cfg.k.min(kernels[i].n) {
                        done[i] = true;
                    }
                }
                None => done[i] = true, // no PD-feasible candidate left
            }
        }
    }
    Ok((ys, rounds_total))
}

/// DPP log-likelihood of a subset, with the normalization constant
/// estimated by stochastic Lanczos quadrature.
#[derive(Clone, Debug)]
pub struct DppLikelihood {
    /// `logdet(L_Y)` — exact (dense Cholesky on the `|Y|×|Y|` submatrix;
    /// `|Y| ≪ N` in every DPP workload here).
    pub logdet_subset: f64,
    /// The SLQ report for the normalizer `logdet(L + I)`.
    pub normalizer: StochasticReport,
    /// Point estimate `logdet(L_Y) − logdet(L + I)`.
    pub log_likelihood: f64,
    /// Interval on the log-likelihood induced by the normalizer's
    /// combined interval (the subset term is exact).
    pub interval: Interval,
}

/// `log P(Y) = logdet(L_Y) − logdet(L + I)` for a DPP with kernel `L`.
///
/// The subset determinant is exact; the `N`-dimensional normalizer — the
/// term the "original algorithms" pay O(N³) for — goes through
/// [`Query::LogDet`] on the shifted operator `L + I` (built without
/// densifying via [`Csr::with_diag_shift`]; the spectrum window shifts by
/// exactly `+1`). Rejects an invalid probe config with the same typed
/// error the engine's admission path uses.
pub fn dpp_log_likelihood(
    l: &Arc<Csr>,
    subset: &[usize],
    window: SpectrumBounds,
    slq: SlqConfig,
) -> Result<DppLikelihood, SlqConfigError> {
    slq.validate()?;
    let logdet_subset = if subset.is_empty() {
        0.0 // det of the empty matrix is 1
    } else {
        let sub = l.principal_submatrix(subset).to_dense();
        Cholesky::factor(&sub).expect("subset kernel must be PD").logdet()
    };
    let shifted = l.with_diag_shift(1.0);
    let opts = GqlOptions::new(window.lo + 1.0, window.hi + 1.0);
    let width = slq.probes.clamp(1, 16);
    let mut session = Session::new(&shifted, opts, width, RacePolicy::Prune);
    let qid = session.submit(Query::LogDet { cfg: slq });
    let answers = session.run(&shifted);
    let normalizer = answers[qid]
        .stochastic()
        .expect("logdet queries answer stochastically")
        .clone();
    let log_likelihood = logdet_subset - normalizer.estimate;
    let interval = Interval {
        lo: logdet_subset - normalizer.combined.hi,
        hi: logdet_subset - normalizer.combined.lo,
    };
    Ok(DppLikelihood { logdet_subset, normalizer, log_likelihood, interval })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::util::prop::forall;

    fn setup(rng: &mut Rng, n: usize, density: f64) -> (Arc<Csr>, SpectrumBounds) {
        let (l, w) = random_sparse_spd(rng, n, density, 0.05);
        (Arc::new(l), w)
    }

    #[test]
    fn gauss_and_exact_make_identical_trajectories() {
        forall(6, 0xD99, |rng| {
            let n = 24 + rng.below(30);
            let (l, w) = setup(rng, n, 0.15);
            let seed = rng.next_u64();
            let run = |strategy| {
                let mut r = Rng::new(seed);
                let cfg = DppConfig::new(strategy, w).with_init_size(n / 3);
                let mut s = DppSampler::new(&l, cfg, &mut r);
                s.run(60, &mut r);
                let mut set = s.current_set().to_vec();
                set.sort_unstable();
                set
            };
            assert_eq!(
                run(BifStrategy::Exact),
                run(BifStrategy::Gauss),
                "retrospective judging must not change the chain"
            );
        });
    }

    #[test]
    fn incremental_matches_exact_too() {
        forall(5, 0xD9A, |rng| {
            let n = 20 + rng.below(20);
            let (l, w) = setup(rng, n, 0.2);
            let seed = rng.next_u64();
            let run = |strategy| {
                let mut r = Rng::new(seed);
                let cfg = DppConfig::new(strategy, w).with_init_size(n / 4);
                let mut s = DppSampler::new(&l, cfg, &mut r);
                s.run(40, &mut r);
                let mut set = s.current_set().to_vec();
                set.sort_unstable();
                set
            };
            assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Incremental));
        });
    }

    #[test]
    fn chain_moves_and_counts_stats() {
        let mut rng = Rng::new(0xD9B);
        let (l, w) = setup(&mut rng, 60, 0.1);
        let cfg = DppConfig::new(BifStrategy::Gauss, w).with_init_size(20);
        let mut s = DppSampler::new(&l, cfg, &mut rng);
        let acc = s.run(200, &mut rng);
        assert_eq!(s.stats.steps, 200);
        assert_eq!(s.stats.accepted, acc);
        assert!(acc > 0, "chain should accept something");
        assert!(s.stats.decisions == 200);
        assert!(s.stats.judge_iters_total > 0);
        // subset stays consistent
        let set = s.current_set();
        let mut uniq = set.to_vec();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), set.len());
    }

    #[test]
    fn empty_set_additions_always_judged_exactly() {
        // from Y = ∅, BIF = 0 and the add test is p < L_yy
        let mut rng = Rng::new(0xD9C);
        let (l, w) = setup(&mut rng, 20, 0.3);
        let cfg = DppConfig::new(BifStrategy::Gauss, w);
        let mut s = DppSampler::new(&l, cfg, &mut rng);
        for _ in 0..30 {
            s.step(&mut rng);
        }
        assert!(s.stats.steps == 30);
    }

    #[test]
    fn average_judge_iters_small_on_sparse_input() {
        // the paper's speedup mechanism: decisions take ≪ |Y| iterations
        let mut rng = Rng::new(0xD9D);
        let (l, w) = setup(&mut rng, 150, 0.02);
        let cfg = DppConfig::new(BifStrategy::Gauss, w).with_init_size(50);
        let mut s = DppSampler::new(&l, cfg, &mut rng);
        s.run(100, &mut rng);
        let avg = s.stats.judge_iters_total as f64 / s.stats.decisions as f64;
        assert!(avg < 25.0, "avg judge iterations {avg} too large");
    }

    #[test]
    fn greedy_block_path_selects_identically_to_scalar() {
        forall(8, 0xD9E, |rng| {
            let n = 20 + rng.below(30);
            let (l, w) = setup(rng, n, 0.2);
            let k = 3 + rng.below(n / 4);
            let base = GreedyConfig::new(w, k).with_block_width(1);
            let scalar = greedy_map(&l, &base);
            for width in [2, 5, 8, 32] {
                let block = greedy_map(&l, &base.with_block_width(width));
                assert_eq!(scalar, block, "width {width} changed the selection");
            }
        });
    }

    #[test]
    fn greedy_reorth_selects_identically_across_widths() {
        // the reorth knob must not break the width-independence guarantee
        // (scalar and block lanes share one recurrence core)
        forall(4, 0xDA1, |rng| {
            let n = 20 + rng.below(16);
            let (l, w) = setup(rng, n, 0.2);
            let k = 3 + rng.below(5);
            let base = GreedyConfig::new(w, k)
                .with_block_width(1)
                .with_reorth(Reorth::Full);
            let scalar = greedy_map(&l, &base);
            for width in [3, 8] {
                let block = greedy_map(&l, &base.with_block_width(width));
                assert_eq!(scalar, block, "width {width} changed the selection");
            }
        });
    }

    #[test]
    fn greedy_matches_exact_cholesky_scoring() {
        forall(8, 0xD9F, |rng| {
            let n = 16 + rng.below(24);
            let (l, w) = setup(rng, n, 0.25);
            let k = 2 + rng.below(6);
            let got = greedy_map(&l, &GreedyConfig::new(w, k));
            // reference: same greedy with exact Schur complements
            let mut y: Vec<usize> = Vec::new();
            for _ in 0..k {
                let mut best: Option<(usize, f64)> = None;
                for c in (0..n).filter(|c| !y.contains(c)) {
                    let gain = if y.is_empty() {
                        l.get(c, c)
                    } else {
                        let sub = l.principal_submatrix(&y).to_dense();
                        let col: Vec<f64> = y.iter().map(|&m| l.get(m, c)).collect();
                        l.get(c, c) - Cholesky::factor(&sub).unwrap().bif(&col)
                    };
                    if best.map_or(true, |(_, g)| gain > g) {
                        best = Some((c, gain));
                    }
                }
                let (c, gain) = best.unwrap();
                if gain <= GAIN_FLOOR {
                    break;
                }
                let pos = y.partition_point(|&m| m < c);
                y.insert(pos, c);
            }
            assert_eq!(got, y, "quadrature greedy deviated from exact greedy");
        });
    }

    #[test]
    fn block_width_zero_is_clamped_to_scalar_path() {
        // ISSUE 3 satellite: width 0 used to assert!; it now clamps to 1
        // like Gql::new clamps max_iters
        let mut rng = Rng::new(0xDA2);
        let (l, w) = setup(&mut rng, 30, 0.2);
        let base = GreedyConfig::new(w, 6);
        let zero = greedy_map(&l, &base.with_block_width(0));
        let one = greedy_map(&l, &base.with_block_width(1));
        assert_eq!(zero, one, "width 0 must behave as the scalar path");
        assert!(!zero.is_empty());
    }

    #[test]
    fn race_policies_select_identically() {
        forall(6, 0xDA3, |rng| {
            let n = 20 + rng.below(24);
            let (l, w) = setup(rng, n, 0.2);
            let k = 3 + rng.below(6);
            let base = GreedyConfig::new(w, k).with_block_width(1 + rng.below(8));
            let (ex, ex_stats) =
                greedy_map_stats(&l, &base.with_race(RacePolicy::Exhaustive));
            let (pr, pr_stats) = greedy_map_stats(&l, &base.with_race(RacePolicy::Prune));
            assert_eq!(ex, pr, "pruning changed the selection");
            assert!(
                pr_stats.sweeps <= ex_stats.sweeps,
                "pruning spent more sweeps ({} vs {})",
                pr_stats.sweeps,
                ex_stats.sweeps
            );
        });
    }

    #[test]
    fn joint_multi_kernel_greedy_matches_solo_greedy() {
        // ISSUE 5: several kernels' greedy rounds raced through one
        // multi-operator engine must select exactly what each solo
        // greedy_map selects
        let mut rng = Rng::new(0xDA5);
        let mut kernels = Vec::new();
        for _ in 0..3 {
            let n = 24 + rng.below(16);
            kernels.push(setup(&mut rng, n, 0.2));
        }
        // one window covering every kernel (the documented contract)
        let window = kernels.iter().fold(
            crate::sparse::SpectrumBounds { lo: f64::INFINITY, hi: 0.0 },
            |acc, (_, w)| crate::sparse::SpectrumBounds {
                lo: acc.lo.min(w.lo),
                hi: acc.hi.max(w.hi),
            },
        );
        let cfg = GreedyConfig::new(window, 6).with_block_width(8);
        let refs: Vec<Arc<Csr>> = kernels.iter().map(|(l, _)| Arc::clone(l)).collect();
        let (joint, rounds) =
            greedy_map_multi(&refs, &cfg, EngineConfig::default()).expect("valid knobs");
        assert!(rounds > 0);
        for (l, sel) in refs.iter().zip(&joint) {
            assert_eq!(*sel, greedy_map(l, &cfg), "joint selection diverged");
        }
        // unusable engine knobs are rejected with the typed error
        assert!(greedy_map_multi(&refs, &cfg, EngineConfig::default().with_lanes(0)).is_err());
    }

    #[test]
    fn dpp_log_likelihood_brackets_the_exact_value() {
        let mut rng = Rng::new(0xDA6);
        let n = 26;
        let (l, w) = setup(&mut rng, n, 0.2);
        let subset: Vec<usize> = {
            let mut s = rng.sample_indices(n, 6);
            s.sort_unstable();
            s
        };
        let slq = SlqConfig::new(12, 0xDA6_0001, 2e-2);
        let got = dpp_log_likelihood(&l, &subset, w, slq).expect("valid config");
        // exact reference: dense logdets
        let exact_sub =
            Cholesky::factor(&l.principal_submatrix(&subset).to_dense()).unwrap().logdet();
        let exact_norm =
            Cholesky::factor(&l.with_diag_shift(1.0).to_dense()).unwrap().logdet();
        let exact = exact_sub - exact_norm;
        assert!((got.logdet_subset - exact_sub).abs() < 1e-9, "subset term is exact");
        let guard = 4.0 * (got.interval.width() / 2.0) + 1e-9;
        assert!(
            (exact - got.interval.mid()).abs() <= guard,
            "exact {exact} vs interval [{}, {}]",
            got.interval.lo,
            got.interval.hi
        );
        assert!(got.interval.contains(got.log_likelihood));
        // empty subset: the subset term vanishes exactly
        let empty = dpp_log_likelihood(&l, &[], w, slq).unwrap();
        assert_eq!(empty.logdet_subset, 0.0);
        // typed rejection mirrors the engine's admission path
        assert_eq!(
            dpp_log_likelihood(&l, &subset, w, SlqConfig::new(0, 1, 1e-2)).unwrap_err(),
            SlqConfigError::ZeroProbes
        );
    }

    #[test]
    fn greedy_set_is_distinct_and_capped() {
        let mut rng = Rng::new(0xDA0);
        let (l, w) = setup(&mut rng, 50, 0.15);
        let got = greedy_map(&l, &GreedyConfig::new(w, 12));
        assert!(got.len() <= 12);
        let mut uniq = got.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), got.len());
        assert!(got.iter().all(|&c| c < 50));
        // sorted invariant
        assert!(got.windows(2).all(|p| p[0] < p[1]));
    }
}
