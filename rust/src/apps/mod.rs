//! The paper's applications (§5): Markov-chain sampling from (k-)DPPs,
//! the double-greedy algorithm for non-monotone submodular maximization
//! of log-det, BIF-based centrality ranking (§2), and the stochastic
//! quadrature consumers — DPP log-likelihood ([`dpp_log_likelihood`])
//! and GP marginal likelihood ([`gp_log_marginal`]) — whose logdet terms
//! go through [`crate::quadrature::stochastic`].
//!
//! Every application ships in (at least) two variants driven by
//! [`BifStrategy`]:
//! * `Exact` — the paper's "original algorithm" baseline: a fresh dense
//!   Cholesky solve per decision (O(|Y|³));
//! * `Gauss` — the retrospective quadrature framework (Alg. 2): bounds
//!   refined only until the decision separates;
//! plus, where meaningful, `Incremental` — a stronger
//! maintained-inverse baseline (O(|Y|²) per decision) used in ablations so
//! the reported speedups aren't an artifact of a weak baseline.
//!
//! Crucially, `Exact` and `Gauss` driven by the same RNG seed make
//! *identical* decisions (the judges are exact — Alg. 2's correctness
//! guarantee); integration tests assert trajectory equality.

pub mod centrality;
pub mod double_greedy;
pub mod dpp;
pub mod gp;
pub mod kdpp;

pub use centrality::{rank_top_k_centrality, CentralityResult};
pub use double_greedy::{double_greedy, DgConfig, DgResult};
pub use dpp::{
    dpp_log_likelihood, greedy_map, greedy_map_multi, greedy_map_stats, DppConfig,
    DppLikelihood, DppSampler, DppStats, GreedyConfig, GreedyStats,
};
pub use gp::{gp_log_marginal, GpConfig, GpError, GpEvidence};
pub use kdpp::{step_chains, KdppConfig, KdppSampler, KdppStats};

/// How an application evaluates / compares its BIFs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BifStrategy {
    /// Fresh dense Cholesky per decision — the paper's baseline.
    Exact,
    /// Maintained O(k²) submatrix inverse — stronger classical baseline.
    Incremental,
    /// Retrospective Gauss-Radau judging (the paper's contribution).
    Gauss,
}
