//! Metropolis–Hastings sampling from a k-DPP (paper Alg. 6, "Gauss-kDPP").
//!
//! State: `Y` with fixed `|Y| = k`. Per step propose swapping `v ∈ Y` for
//! `u ∉ Y`; with `Y' = Y∖{v}`, accept with probability
//!
//! `min{1, (L_uu − L_{u,Y'} L_{Y'}^{-1} L_{Y',u}) / (L_vv − L_{v,Y'} L_{Y'}^{-1} L_{Y',v})}`
//!
//! i.e. accept ⟺ `p·L_vv − L_uu < p·BIF_v − BIF_u`, which is exactly
//! Alg. 7's ratio judgement. The chain submits it as a single
//! [`Query::Compare`] to a width-2 [`Session`] (ISSUE 4): both BIFs share
//! the operator `L_{Y'}`, so the two quadratures advance from *one*
//! width-2 `matvec_multi` panel sweep per iteration instead of two scalar
//! traversals, and the swap test rides the same comparison machinery as
//! every other consumer of the planner.

use super::BifStrategy;
use crate::linalg::Cholesky;
use crate::quadrature::query::{Answer, Query, Session};
use crate::quadrature::race::RacePolicy;
use crate::quadrature::GqlOptions;
use crate::sparse::{Csr, SpectrumBounds, SubmatrixView};
use crate::util::rng::Rng;

/// Configuration for a k-DPP chain.
#[derive(Clone, Copy, Debug)]
pub struct KdppConfig {
    pub strategy: BifStrategy,
    pub window: SpectrumBounds,
    pub k: usize,
    pub max_judge_iters: usize,
}

impl KdppConfig {
    pub fn new(strategy: BifStrategy, window: SpectrumBounds, k: usize) -> Self {
        KdppConfig { strategy, window, k, max_judge_iters: usize::MAX }
    }

    fn gql_opts(&self) -> GqlOptions {
        GqlOptions::new(self.window.lo, self.window.hi).with_max_iters(self.max_judge_iters)
    }
}

/// Cumulative chain statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct KdppStats {
    pub steps: usize,
    pub accepted: usize,
    pub judge_iters_total: usize,
}

/// One MH k-DPP chain.
pub struct KdppSampler<'a> {
    l: &'a Csr,
    cfg: KdppConfig,
    y: Vec<usize>,
    in_y: Vec<bool>,
    pub stats: KdppStats,
}

impl<'a> KdppSampler<'a> {
    pub fn new(l: &'a Csr, cfg: KdppConfig, rng: &mut Rng) -> Self {
        let n = l.n;
        assert!(cfg.k >= 1 && cfg.k < n, "need 1 ≤ k < n");
        let mut y = rng.sample_indices(n, cfg.k);
        y.sort_unstable(); // kept sorted: streaming views + O(k) updates (§Perf)
        Self::from_set(l, cfg, y)
    }

    /// Start the chain from the greedy MAP subset of size `k` instead of
    /// a uniform one: candidate scoring runs as argmax queries on the
    /// unified planner ([`Session`]) over panels of `block_width`
    /// lanes, so the warm start costs one greedy sweep of panel matvecs —
    /// with dominated candidates pruned per round (the default
    /// [`crate::quadrature::race::RacePolicy::Prune`], which provably
    /// does not change the selected subset) — instead of `k · N` scalar
    /// runs. A high-likelihood start cuts chain burn-in on the peaked
    /// kernels of §5.3.
    ///
    /// Greedy can stall before `k` picks on near-singular kernels (no
    /// candidate keeps a usable marginal gain); the set is then topped up
    /// with the smallest unused indices — any size-`k` start state is a
    /// valid MH start, so this degrades gracefully instead of failing.
    pub fn new_greedy(l: &'a Csr, cfg: KdppConfig, block_width: usize) -> Self {
        let n = l.n;
        assert!(cfg.k >= 1 && cfg.k < n, "need 1 ≤ k < n");
        let gcfg = crate::apps::dpp::GreedyConfig::new(cfg.window, cfg.k)
            .with_block_width(block_width);
        let mut y = crate::apps::dpp::greedy_map(l, &gcfg);
        if y.len() < cfg.k {
            let mut in_y = vec![false; n];
            for &v in &y {
                in_y[v] = true;
            }
            for c in (0..n).filter(|&c| !in_y[c]).take(cfg.k - y.len()) {
                y.push(c);
            }
            y.sort_unstable();
        }
        Self::from_set(l, cfg, y)
    }

    /// `y` must be sorted, duplicate-free, and of size `cfg.k`.
    fn from_set(l: &'a Csr, cfg: KdppConfig, y: Vec<usize>) -> Self {
        debug_assert_eq!(y.len(), cfg.k);
        debug_assert!(y.windows(2).all(|p| p[0] < p[1]));
        let mut in_y = vec![false; l.n];
        for &v in &y {
            in_y[v] = true;
        }
        KdppSampler { l, cfg, y, in_y, stats: KdppStats::default() }
    }

    pub fn current_set(&self) -> &[usize] {
        &self.y
    }

    /// One swap proposal. Returns whether it was accepted.
    pub fn step(&mut self, rng: &mut Rng) -> bool {
        self.stats.steps += 1;
        let n = self.l.n;
        // v ∈ Y uniformly; u ∉ Y uniformly
        let vi = rng.below(self.y.len());
        let v = self.y[vi];
        let u = loop {
            let c = rng.below(n);
            if !self.in_y[c] {
                break c;
            }
        };
        let p = rng.f64();
        let t = p * self.l.get(v, v) - self.l.get(u, u);
        let idx: Vec<usize> = self.y.iter().copied().filter(|&m| m != v).collect();

        let accept = match self.cfg.strategy {
            BifStrategy::Gauss => {
                let view = SubmatrixView::new(self.l, &idx); // idx pre-sorted
                let uu = view.column_of(u);
                let vv = view.column_of(v);
                // accept ⟺ t < p·BIF_v − BIF_u, both sides fed by one
                // paired panel sweep (§Perf: materialization tried and
                // reverted — ~2 iterations don't amortize it)
                let mut session = Session::new(&view, self.cfg.gql_opts(), 2, RacePolicy::Prune);
                let qid = session.submit(Query::Compare { u: uu, v: vv, t, p });
                let (ans, js) = match session.run().swap_remove(qid) {
                    Answer::Compare { decision, stats } => (decision, stats),
                    _ => unreachable!("compare queries answer with compare answers"),
                };
                self.stats.judge_iters_total += js.iters;
                ans
            }
            _ => {
                // Exact (and Incremental falls back to exact here: the swap
                // always needs L_{Y'}^{-1}, not L_Y^{-1})
                if idx.is_empty() {
                    t < 0.0
                } else {
                    let sub = self.l.principal_submatrix(&idx).to_dense();
                    let ch = Cholesky::factor(&sub).expect("L_Y' must be PD");
                    let cu: Vec<f64> = idx.iter().map(|&m| self.l.get(m, u)).collect();
                    let cv: Vec<f64> = idx.iter().map(|&m| self.l.get(m, v)).collect();
                    t < p * ch.bif(&cv) - ch.bif(&cu)
                }
            }
        };
        if accept {
            self.y.remove(vi); // keep sorted (see `new`)
            let pos = self.y.partition_point(|&m| m < u);
            self.y.insert(pos, u);
            self.in_y[v] = false;
            self.in_y[u] = true;
            self.stats.accepted += 1;
        }
        accept
    }

    pub fn run(&mut self, steps: usize, rng: &mut Rng) -> usize {
        let mut acc = 0;
        for _ in 0..steps {
            if self.step(rng) {
                acc += 1;
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::util::prop::forall;

    #[test]
    fn cardinality_is_invariant() {
        let mut rng = Rng::new(0xE1);
        let (l, w) = random_sparse_spd(&mut rng, 50, 0.15, 0.05);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 12);
        let mut s = KdppSampler::new(&l, cfg, &mut rng);
        for _ in 0..100 {
            s.step(&mut rng);
            assert_eq!(s.current_set().len(), 12);
            let mut uniq = s.current_set().to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 12, "duplicate element in Y");
        }
    }

    #[test]
    fn gauss_and_exact_identical_trajectories() {
        forall(6, 0xE2, |rng| {
            let n = 24 + rng.below(26);
            let (l, w) = random_sparse_spd(rng, n, 0.2, 0.05);
            let k = 4 + rng.below(n / 3);
            let seed = rng.next_u64();
            let run = |strategy| {
                let mut r = Rng::new(seed);
                let cfg = KdppConfig::new(strategy, w, k);
                let mut s = KdppSampler::new(&l, cfg, &mut r);
                s.run(50, &mut r);
                let mut set = s.current_set().to_vec();
                set.sort_unstable();
                set
            };
            assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Gauss));
        });
    }

    #[test]
    fn stats_accumulate() {
        let mut rng = Rng::new(0xE3);
        let (l, w) = random_sparse_spd(&mut rng, 40, 0.2, 0.05);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 8);
        let mut s = KdppSampler::new(&l, cfg, &mut rng);
        let acc = s.run(80, &mut rng);
        assert_eq!(s.stats.steps, 80);
        assert_eq!(s.stats.accepted, acc);
        assert!(s.stats.judge_iters_total >= 80, "two BIFs per proposal");
    }

    #[test]
    fn greedy_init_matches_greedy_map_and_chain_runs() {
        let mut rng = Rng::new(0xE5);
        let (l, w) = random_sparse_spd(&mut rng, 48, 0.2, 0.05);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 10);
        let s = KdppSampler::new_greedy(&l, cfg, 8);
        let want = crate::apps::dpp::greedy_map(
            &l,
            &crate::apps::dpp::GreedyConfig::new(w, 10).with_block_width(8),
        );
        assert_eq!(s.current_set(), &want[..]);
        // the warm-started chain still samples correctly
        let mut s = s;
        for _ in 0..40 {
            s.step(&mut rng);
            assert_eq!(s.current_set().len(), 10);
        }
    }

    #[test]
    #[should_panic(expected = "need 1 ≤ k < n")]
    fn k_must_be_feasible() {
        let mut rng = Rng::new(0xE4);
        let (l, w) = random_sparse_spd(&mut rng, 10, 0.3, 0.05);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 10);
        let _ = KdppSampler::new(&l, cfg, &mut rng);
    }
}
