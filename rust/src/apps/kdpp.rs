//! Metropolis–Hastings sampling from a k-DPP (paper Alg. 6, "Gauss-kDPP").
//!
//! State: `Y` with fixed `|Y| = k`. Per step propose swapping `v ∈ Y` for
//! `u ∉ Y`; with `Y' = Y∖{v}`, accept with probability
//!
//! `min{1, (L_uu − L_{u,Y'} L_{Y'}^{-1} L_{Y',u}) / (L_vv − L_{v,Y'} L_{Y'}^{-1} L_{Y',v})}`
//!
//! i.e. accept ⟺ `p·L_vv − L_uu < p·BIF_v − BIF_u`, which is exactly
//! Alg. 7's ratio judgement. The chain submits it as a single
//! [`Query::Compare`] to a width-2 [`Session`] (ISSUE 4): both BIFs share
//! the operator `L_{Y'}`, so the two quadratures advance from *one*
//! width-2 `matvec_multi` panel sweep per iteration instead of two scalar
//! traversals, and the swap test rides the same comparison machinery as
//! every other consumer of the planner.
//!
//! **Chain pools (ISSUE 5):** [`step_chains`] advances several chains —
//! several live submatrix operators — through one multi-operator
//! [`Engine`], resolving every swap test from a shared round loop with
//! trajectories identical to solo stepping.

use super::BifStrategy;
use crate::linalg::Cholesky;
use crate::quadrature::engine::{Engine, EngineConfig, EngineConfigError, OpKey, Ticket};
use crate::quadrature::query::{Answer, Query, Session};
use crate::quadrature::race::RacePolicy;
use crate::quadrature::GqlOptions;
use crate::sparse::{Csr, SpectrumBounds, SubmatrixView};
use crate::util::rng::Rng;
use std::sync::Arc;

/// Configuration for a k-DPP chain.
#[derive(Clone, Copy, Debug)]
pub struct KdppConfig {
    pub strategy: BifStrategy,
    pub window: SpectrumBounds,
    pub k: usize,
    pub max_judge_iters: usize,
}

impl KdppConfig {
    pub fn new(strategy: BifStrategy, window: SpectrumBounds, k: usize) -> Self {
        KdppConfig { strategy, window, k, max_judge_iters: usize::MAX }
    }

    fn gql_opts(&self) -> GqlOptions {
        GqlOptions::new(self.window.lo, self.window.hi).with_max_iters(self.max_judge_iters)
    }
}

/// Cumulative chain statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct KdppStats {
    pub steps: usize,
    pub accepted: usize,
    pub judge_iters_total: usize,
}

/// One drawn swap proposal: the chain's RNG has already advanced, but the
/// chain state is untouched until [`KdppSampler::apply`].
struct Proposal {
    vi: usize,
    v: usize,
    u: usize,
    p: f64,
    t: f64,
    /// Y' = Y∖{v}, sorted — the operator index set of this proposal.
    idx: Vec<usize>,
}

/// One MH k-DPP chain. The kernel is held behind an [`Arc`] (shared
/// with the caller, with sibling chains in a pool, and with every
/// [`SubmatrixView`] a proposal spins up), so chains are `'static` and
/// can be parked in resident services.
pub struct KdppSampler {
    l: Arc<Csr>,
    cfg: KdppConfig,
    y: Vec<usize>,
    in_y: Vec<bool>,
    pub stats: KdppStats,
}

impl KdppSampler {
    pub fn new(l: &Arc<Csr>, cfg: KdppConfig, rng: &mut Rng) -> Self {
        let n = l.n;
        assert!(cfg.k >= 1 && cfg.k < n, "need 1 ≤ k < n");
        let mut y = rng.sample_indices(n, cfg.k);
        y.sort_unstable(); // kept sorted: streaming views + O(k) updates (§Perf)
        Self::from_set(l, cfg, y)
    }

    /// Start the chain from the greedy MAP subset of size `k` instead of
    /// a uniform one: candidate scoring runs as argmax queries on the
    /// unified planner ([`Session`]) over panels of `block_width`
    /// lanes, so the warm start costs one greedy sweep of panel matvecs —
    /// with dominated candidates pruned per round (the default
    /// [`crate::quadrature::race::RacePolicy::Prune`], which provably
    /// does not change the selected subset) — instead of `k · N` scalar
    /// runs. A high-likelihood start cuts chain burn-in on the peaked
    /// kernels of §5.3.
    ///
    /// Greedy can stall before `k` picks on near-singular kernels (no
    /// candidate keeps a usable marginal gain); the set is then topped up
    /// with the smallest unused indices — any size-`k` start state is a
    /// valid MH start, so this degrades gracefully instead of failing.
    pub fn new_greedy(l: &Arc<Csr>, cfg: KdppConfig, block_width: usize) -> Self {
        let n = l.n;
        assert!(cfg.k >= 1 && cfg.k < n, "need 1 ≤ k < n");
        let gcfg = crate::apps::dpp::GreedyConfig::new(cfg.window, cfg.k)
            .with_block_width(block_width);
        let mut y = crate::apps::dpp::greedy_map(l, &gcfg);
        if y.len() < cfg.k {
            let mut in_y = vec![false; n];
            for &v in &y {
                in_y[v] = true;
            }
            for c in (0..n).filter(|&c| !in_y[c]).take(cfg.k - y.len()) {
                y.push(c);
            }
            y.sort_unstable();
        }
        Self::from_set(l, cfg, y)
    }

    /// `y` must be sorted, duplicate-free, and of size `cfg.k`.
    fn from_set(l: &Arc<Csr>, cfg: KdppConfig, y: Vec<usize>) -> Self {
        debug_assert_eq!(y.len(), cfg.k);
        debug_assert!(y.windows(2).all(|p| p[0] < p[1]));
        let mut in_y = vec![false; l.n];
        for &v in &y {
            in_y[v] = true;
        }
        KdppSampler { l: Arc::clone(l), cfg, y, in_y, stats: KdppStats::default() }
    }

    pub fn current_set(&self) -> &[usize] {
        &self.y
    }

    /// Draw one swap proposal (advancing the chain's RNG exactly as
    /// [`KdppSampler::step`] does) without judging it — the split that
    /// lets [`step_chains`] batch many chains' judgements onto one
    /// multi-operator engine.
    fn propose(&mut self, rng: &mut Rng) -> Proposal {
        self.stats.steps += 1;
        let n = self.l.n;
        // v ∈ Y uniformly; u ∉ Y uniformly
        let vi = rng.below(self.y.len());
        let v = self.y[vi];
        let u = loop {
            let c = rng.below(n);
            if !self.in_y[c] {
                break c;
            }
        };
        let p = rng.f64();
        let t = p * self.l.get(v, v) - self.l.get(u, u);
        let idx: Vec<usize> = self.y.iter().copied().filter(|&m| m != v).collect();
        Proposal { vi, v, u, p, t, idx }
    }

    /// The exact (Cholesky) side of the swap test.
    fn judge_exact(&self, prop: &Proposal) -> bool {
        // Exact (and Incremental falls back to exact here: the swap
        // always needs L_{Y'}^{-1}, not L_Y^{-1})
        if prop.idx.is_empty() {
            prop.t < 0.0
        } else {
            let sub = self.l.principal_submatrix(&prop.idx).to_dense();
            let ch = Cholesky::factor(&sub).expect("L_Y' must be PD");
            let cu: Vec<f64> = prop.idx.iter().map(|&m| self.l.get(m, prop.u)).collect();
            let cv: Vec<f64> = prop.idx.iter().map(|&m| self.l.get(m, prop.v)).collect();
            prop.t < prop.p * ch.bif(&cv) - ch.bif(&cu)
        }
    }

    /// Apply an already-judged proposal; returns `accept` back.
    fn apply(&mut self, prop: &Proposal, accept: bool) -> bool {
        if accept {
            self.y.remove(prop.vi); // keep sorted (see `new`)
            let pos = self.y.partition_point(|&m| m < prop.u);
            self.y.insert(pos, prop.u);
            self.in_y[prop.v] = false;
            self.in_y[prop.u] = true;
            self.stats.accepted += 1;
        }
        accept
    }

    /// One swap proposal. Returns whether it was accepted.
    pub fn step(&mut self, rng: &mut Rng) -> bool {
        let prop = self.propose(rng);
        let accept = match self.cfg.strategy {
            BifStrategy::Gauss => {
                let view = SubmatrixView::new(&self.l, &prop.idx); // idx pre-sorted
                let uu = view.column_of(prop.u);
                let vv = view.column_of(prop.v);
                // accept ⟺ t < p·BIF_v − BIF_u, both sides fed by one
                // paired panel sweep (§Perf: materialization tried and
                // reverted — ~2 iterations don't amortize it)
                let mut session = Session::new(&view, self.cfg.gql_opts(), 2, RacePolicy::Prune);
                let qid =
                    session.submit(Query::Compare { u: uu, v: vv, t: prop.t, p: prop.p });
                let (ans, js) = match session.run(&view).swap_remove(qid) {
                    Answer::Compare { decision, stats } => (decision, stats),
                    _ => unreachable!("compare queries answer with compare answers"),
                };
                self.stats.judge_iters_total += js.iters;
                ans
            }
            _ => self.judge_exact(&prop),
        };
        self.apply(&prop, accept)
    }

    pub fn run(&mut self, steps: usize, rng: &mut Rng) -> usize {
        let mut acc = 0;
        for _ in 0..steps {
            if self.step(rng) {
                acc += 1;
            }
        }
        acc
    }
}

/// Advance a pool of chains by one proposal each, **jointly** (ISSUE 5):
/// every chain's swap test — one `Query::Compare` per live submatrix
/// operator `L_{Y'}` — is submitted to one multi-operator [`Engine`] and
/// resolves from a shared round loop, one `matvec_multi` panel per
/// operator per round. A pool of C chains finishes a proposal wave in
/// ~max over chains of per-chain rounds instead of their sum, which is
/// where the cross-operator batching pays.
///
/// Each chain draws from its own RNG exactly as [`KdppSampler::step`]
/// would, and every decision is certified by the same nested brackets, so
/// trajectories are identical to stepping the chains one at a time
/// (asserted in the tests below and `rust/tests/prop_engine.rs`). Chains
/// with non-Gauss strategies are judged exactly, outside the engine.
/// Returns the joint engine rounds spent on this wave; unusable engine
/// knobs are rejected with the typed admission error **before** any
/// chain's RNG advances (mirroring `greedy_map_multi`), so a failed wave
/// leaves every chain exactly where it was.
pub fn step_chains(
    chains: &mut [KdppSampler],
    rngs: &mut [Rng],
    ecfg: EngineConfig,
) -> Result<usize, EngineConfigError> {
    assert_eq!(chains.len(), rngs.len(), "one RNG per chain");
    ecfg.validate()?;
    let props: Vec<Proposal> = chains
        .iter_mut()
        .zip(rngs.iter_mut())
        .map(|(c, r)| c.propose(r))
        .collect();
    // every proposal's operator must be alive at once: each view shares
    // its chain's kernel Arc and moves into the engine's operator store
    let optss: Vec<GqlOptions> = chains.iter().map(|c| c.cfg.gql_opts()).collect();
    let gauss: Vec<bool> = chains
        .iter()
        .map(|c| c.cfg.strategy == BifStrategy::Gauss)
        .collect();
    let mut eng = Engine::new(ecfg).expect("validated above");
    let tickets: Vec<Option<Ticket>> = props
        .iter()
        .enumerate()
        .map(|(i, prop)| {
            gauss[i].then(|| {
                let view = SubmatrixView::new(&chains[i].l, &prop.idx);
                let uu = view.column_of(prop.u);
                let vv = view.column_of(prop.v);
                eng.submit(
                    i as OpKey,
                    Arc::new(view),
                    optss[i],
                    Query::Compare { u: uu, v: vv, t: prop.t, p: prop.p },
                )
            })
        })
        .collect();
    eng.drain();
    let rounds = eng.stats().rounds;
    for (i, prop) in props.iter().enumerate() {
        let accept = match tickets[i] {
            Some(t) => match eng.answer(t).expect("engine drained") {
                Answer::Compare { decision, stats } => {
                    chains[i].stats.judge_iters_total += stats.iters;
                    *decision
                }
                _ => unreachable!("compare queries answer with compare answers"),
            },
            None => chains[i].judge_exact(prop),
        };
        chains[i].apply(prop, accept);
    }
    Ok(rounds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::util::prop::forall;

    fn setup(rng: &mut Rng, n: usize, density: f64) -> (Arc<Csr>, SpectrumBounds) {
        let (l, w) = random_sparse_spd(rng, n, density, 0.05);
        (Arc::new(l), w)
    }

    #[test]
    fn cardinality_is_invariant() {
        let mut rng = Rng::new(0xE1);
        let (l, w) = setup(&mut rng, 50, 0.15);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 12);
        let mut s = KdppSampler::new(&l, cfg, &mut rng);
        for _ in 0..100 {
            s.step(&mut rng);
            assert_eq!(s.current_set().len(), 12);
            let mut uniq = s.current_set().to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), 12, "duplicate element in Y");
        }
    }

    #[test]
    fn gauss_and_exact_identical_trajectories() {
        forall(6, 0xE2, |rng| {
            let n = 24 + rng.below(26);
            let (l, w) = setup(rng, n, 0.2);
            let k = 4 + rng.below(n / 3);
            let seed = rng.next_u64();
            let run = |strategy| {
                let mut r = Rng::new(seed);
                let cfg = KdppConfig::new(strategy, w, k);
                let mut s = KdppSampler::new(&l, cfg, &mut r);
                s.run(50, &mut r);
                let mut set = s.current_set().to_vec();
                set.sort_unstable();
                set
            };
            assert_eq!(run(BifStrategy::Exact), run(BifStrategy::Gauss));
        });
    }

    #[test]
    fn stats_accumulate() {
        let mut rng = Rng::new(0xE3);
        let (l, w) = setup(&mut rng, 40, 0.2);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 8);
        let mut s = KdppSampler::new(&l, cfg, &mut rng);
        let acc = s.run(80, &mut rng);
        assert_eq!(s.stats.steps, 80);
        assert_eq!(s.stats.accepted, acc);
        assert!(s.stats.judge_iters_total >= 80, "two BIFs per proposal");
    }

    #[test]
    fn greedy_init_matches_greedy_map_and_chain_runs() {
        let mut rng = Rng::new(0xE5);
        let (l, w) = setup(&mut rng, 48, 0.2);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 10);
        let s = KdppSampler::new_greedy(&l, cfg, 8);
        let want = crate::apps::dpp::greedy_map(
            &l,
            &crate::apps::dpp::GreedyConfig::new(w, 10).with_block_width(8),
        );
        assert_eq!(s.current_set(), &want[..]);
        // the warm-started chain still samples correctly
        let mut s = s;
        for _ in 0..40 {
            s.step(&mut rng);
            assert_eq!(s.current_set().len(), 10);
        }
    }

    #[test]
    fn joint_chain_pool_matches_sequential_trajectories() {
        // ISSUE 5: a pool of chains advanced through one multi-operator
        // engine must walk exactly the trajectories of solo stepping —
        // the engine is a scheduler, not a numeric path
        let mut rng = Rng::new(0xE6);
        let mut kernels = Vec::new();
        for _ in 0..3 {
            let n = 30 + rng.below(12);
            kernels.push(setup(&mut rng, n, 0.2));
        }
        let seeds: Vec<u64> = (0..3).map(|_| rng.next_u64()).collect();
        let steps = 25usize;

        // sequential reference: each chain stepped alone
        let sequential: Vec<Vec<usize>> = kernels
            .iter()
            .zip(&seeds)
            .map(|((l, w), &s)| {
                let mut r = Rng::new(s);
                let cfg = KdppConfig::new(BifStrategy::Gauss, *w, 8);
                let mut smp = KdppSampler::new(l, cfg, &mut r);
                smp.run(steps, &mut r);
                smp.current_set().to_vec()
            })
            .collect();

        // joint pool: same seeds, one engine per proposal wave
        let mut rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
        let mut chains: Vec<KdppSampler> = kernels
            .iter()
            .zip(rngs.iter_mut())
            .map(|((l, w), r)| {
                KdppSampler::new(l, KdppConfig::new(BifStrategy::Gauss, *w, 8), r)
            })
            .collect();
        let mut joint_rounds = 0usize;
        for _ in 0..steps {
            joint_rounds += step_chains(&mut chains, &mut rngs, EngineConfig::default())
                .expect("valid engine knobs");
        }
        assert!(joint_rounds > 0);
        // unusable knobs are rejected before any chain's RNG advances
        let steps_before: Vec<usize> = chains.iter().map(|c| c.stats.steps).collect();
        assert!(
            step_chains(&mut chains, &mut rngs, EngineConfig::default().with_lanes(0)).is_err()
        );
        let steps_after: Vec<usize> = chains.iter().map(|c| c.stats.steps).collect();
        assert_eq!(steps_before, steps_after, "failed wave must not draw proposals");
        for (c, want) in chains.iter().zip(&sequential) {
            assert_eq!(c.current_set(), &want[..], "joint pool diverged");
            assert_eq!(c.stats.steps, steps);
        }
    }

    #[test]
    #[should_panic(expected = "need 1 ≤ k < n")]
    fn k_must_be_feasible() {
        let mut rng = Rng::new(0xE4);
        let (l, w) = setup(&mut rng, 10, 0.3);
        let cfg = KdppConfig::new(BifStrategy::Gauss, w, 10);
        let _ = KdppSampler::new(&l, cfg, &mut rng);
    }
}
