//! Synthetic power-law graphs + Laplacians — the GR/HEP/Epinions/Slashdot
//! substitutes.  SNAP collaboration/social graphs have heavy-tailed degree
//! distributions; a Barabási–Albert-style preferential-attachment process
//! reproduces that class.  The average degree is tuned to match the
//! Table-1 nnz (Laplacian nnz = n + 2|E|).

use crate::sparse::{Csr, CsrBuilder};
use crate::util::rng::Rng;

/// Undirected edge list (i < j, no duplicates).
pub type EdgeList = Vec<(usize, usize)>;

/// Preferential-attachment graph with ~`avg_degree`·n/2 edges.
/// Each new node attaches `m ≈ avg_degree/2` edges to targets sampled
/// from the running endpoint multiset (degree-proportional).
pub fn power_law_graph(rng: &mut Rng, n: usize, avg_degree: f64) -> EdgeList {
    assert!(n >= 2);
    let m = (avg_degree / 2.0).round().max(1.0) as usize;
    let mut edges: EdgeList = Vec::with_capacity(n * m);
    // endpoint multiset for preferential attachment
    let mut endpoints: Vec<usize> = Vec::with_capacity(2 * n * m);
    // seed: a small clique over the first m+1 nodes
    let seed = (m + 1).min(n);
    for i in 0..seed {
        for j in (i + 1)..seed {
            edges.push((i, j));
            endpoints.push(i);
            endpoints.push(j);
        }
    }
    for v in seed..n {
        let mut picked: Vec<usize> = Vec::with_capacity(m);
        let mut guard = 0;
        while picked.len() < m && guard < 50 * m {
            guard += 1;
            let t = if endpoints.is_empty() || rng.bool(0.05) {
                rng.below(v) // small uniform mixing keeps the graph connected-ish
            } else {
                endpoints[rng.below(endpoints.len())]
            };
            if t != v && !picked.contains(&t) {
                picked.push(t);
            }
        }
        for &t in &picked {
            let (a, b) = if v < t { (v, t) } else { (t, v) };
            edges.push((a, b));
            endpoints.push(a);
            endpoints.push(b);
        }
    }
    edges.sort_unstable();
    edges.dedup();
    edges
}

/// Graph Laplacian `L = D − A` as CSR (diagonal = degree, off-diagonal
/// −1 per edge). PSD by construction; callers add the paper's ridge.
pub fn laplacian(n: usize, edges: &EdgeList) -> Csr {
    let mut deg = vec![0usize; n];
    for &(i, j) in edges {
        deg[i] += 1;
        deg[j] += 1;
    }
    let mut b = CsrBuilder::new(n);
    for i in 0..n {
        b.push(i, i, deg[i] as f64);
    }
    for &(i, j) in edges {
        b.push_sym(i, j, -1.0);
    }
    b.build()
}

/// Degree sequence of an edge list (for tail inspection in tests).
pub fn degrees(n: usize, edges: &EdgeList) -> Vec<usize> {
    let mut deg = vec![0usize; n];
    for &(i, j) in edges {
        deg[i] += 1;
        deg[j] += 1;
    }
    deg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SymOp;

    #[test]
    fn edge_count_tracks_avg_degree() {
        let mut rng = Rng::new(10);
        let n = 2000;
        for target in [4.0, 10.0, 20.0] {
            let e = power_law_graph(&mut rng, n, target);
            let avg = 2.0 * e.len() as f64 / n as f64;
            assert!(
                (avg / target) > 0.6 && (avg / target) < 1.4,
                "target {target} got {avg}"
            );
        }
    }

    #[test]
    fn degree_distribution_is_heavy_tailed() {
        let mut rng = Rng::new(11);
        let n = 3000;
        let e = power_law_graph(&mut rng, n, 6.0);
        let mut deg = degrees(n, &e);
        deg.sort_unstable();
        let max = *deg.last().unwrap() as f64;
        let median = deg[n / 2] as f64;
        // power-law-ish: the hub degree dwarfs the median
        assert!(max > 8.0 * median, "max {max} median {median}");
    }

    #[test]
    fn laplacian_rows_sum_to_zero() {
        let mut rng = Rng::new(12);
        let n = 200;
        let e = power_law_graph(&mut rng, n, 5.0);
        let l = laplacian(n, &e);
        let ones = vec![1.0; n];
        let mut y = vec![0.0; n];
        l.matvec(&ones, &mut y);
        assert!(y.iter().all(|&v| v.abs() < 1e-12), "L·1 != 0");
        assert_eq!(l.asymmetry(), 0.0);
    }

    #[test]
    fn laplacian_is_psd() {
        // x^T L x = Σ_(i,j)∈E (x_i − x_j)² ≥ 0; spot-check quadratic form
        let mut rng = Rng::new(13);
        let n = 100;
        let e = power_law_graph(&mut rng, n, 4.0);
        let l = laplacian(n, &e);
        for _ in 0..20 {
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; n];
            l.matvec(&x, &mut y);
            let q: f64 = x.iter().zip(&y).map(|(a, b)| a * b).sum();
            assert!(q >= -1e-9, "x^T L x = {q}");
        }
    }

    #[test]
    fn no_self_loops_or_duplicates() {
        let mut rng = Rng::new(14);
        let e = power_law_graph(&mut rng, 500, 8.0);
        let mut seen = std::collections::HashSet::new();
        for &(i, j) in &e {
            assert!(i < j, "unnormalized edge ({i},{j})");
            assert!(seen.insert((i, j)), "duplicate edge ({i},{j})");
        }
    }
}
