//! Synthetic point clouds + sparse RBF kernels with hard cutoff — the
//! Abalone/Wine substitutes (§5.3.2; kernel construction per Gittens &
//! Mahoney 2013 as cited by the paper: RBF with bandwidth σ, entries
//! zeroed beyond the 3σ cutoff).
//!
//! The cloud is drawn from a small mixture of Gaussians so that near-
//! neighbor structure (hence kernel sparsity pattern) resembles real
//! tabular data rather than a uniform cube; the cutoff radius is then
//! *calibrated* against a sample so the resulting nnz density matches the
//! Table-1 target.

use crate::sparse::{Csr, CsrBuilder};
use crate::util::rng::Rng;

/// Points in R^d, row-major.
#[derive(Clone, Debug)]
pub struct PointCloud {
    pub n: usize,
    pub d: usize,
    pub xs: Vec<f64>,
}

impl PointCloud {
    /// Mixture of `max(2, d/2)` Gaussian clusters in the unit box.
    pub fn synthetic(rng: &mut Rng, n: usize, d: usize) -> Self {
        let k = (d / 2).max(2);
        let centers: Vec<f64> = (0..k * d).map(|_| rng.f64()).collect();
        let mut xs = Vec::with_capacity(n * d);
        for _ in 0..n {
            let c = rng.below(k);
            for j in 0..d {
                xs.push(centers[c * d + j] + 0.08 * rng.normal());
            }
        }
        PointCloud { n, d, xs }
    }

    #[inline]
    pub fn point(&self, i: usize) -> &[f64] {
        &self.xs[i * self.d..(i + 1) * self.d]
    }

    #[inline]
    pub fn dist2(&self, i: usize, j: usize) -> f64 {
        let (a, b) = (self.point(i), self.point(j));
        a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
    }
}

/// Sparse RBF kernel `K_ij = exp(−||x_i−x_j||²/(2σ²)) · w(d/cutoff)`,
/// zero beyond `cutoff`; the cutoff is shrunk/grown by bisection on a
/// subsample so the final density approaches `target_density` (matching
/// the Table-1 nnz without the original data).
///
/// A *hard* cutoff (the paper's construction) makes the kernel indefinite
/// in general — the paper's `+1e-3·I` ridge absorbs the violation on its
/// datasets, but our clustered synthetic clouds can violate PSD-ness by
/// more than the ridge. We therefore taper with the Wendland window
/// `w(t) = (1−t)⁸₊(8t+1)` (positive definite on R^d for d ≤ 11): the
/// Schur product of two PD kernels stays PD, so `K + ridge·I` is SPD with
/// `λ_min > ridge` by construction — same sparsity pattern, same decay
/// class, and the ridge-based spectrum window stays valid.
pub fn rbf_kernel_csr(
    cloud: &PointCloud,
    sigma: f64,
    cutoff: f64,
    target_density: f64,
) -> Csr {
    let n = cloud.n;
    let cutoff = calibrate_cutoff(cloud, cutoff, target_density);
    let cut2 = cutoff * cutoff;
    let inv = 1.0 / (2.0 * sigma * sigma);
    let mut b = CsrBuilder::new(n);
    for i in 0..n {
        b.push(i, i, 1.0);
        for j in (i + 1)..n {
            let d2 = cloud.dist2(i, j);
            if d2 <= cut2 {
                let t = (d2 / cut2).sqrt();
                let wendland = (1.0 - t).powi(8) * (8.0 * t + 1.0);
                b.push_sym(i, j, (-d2 * inv).exp() * wendland);
            }
        }
    }
    b.build()
}

/// Bisect the cutoff radius on a ≤512-point subsample so the implied
/// density is close to `target`. Keeps the paper's "3σ" flavor as the
/// starting point / upper limit scale.
fn calibrate_cutoff(cloud: &PointCloud, start: f64, target: f64) -> f64 {
    let m = cloud.n.min(512);
    let density_at = |r: f64| -> f64 {
        let r2 = r * r;
        let mut cnt = 0usize;
        for i in 0..m {
            for j in (i + 1)..m {
                if cloud.dist2(i, j) <= r2 {
                    cnt += 1;
                }
            }
        }
        (2 * cnt + m) as f64 / (m as f64 * m as f64)
    };
    let (mut lo, mut hi) = (0.0f64, (start * 8.0).max(1.0));
    // grow hi until it exceeds the target (or caps out)
    let mut guard = 0;
    while density_at(hi) < target && guard < 8 {
        hi *= 2.0;
        guard += 1;
    }
    for _ in 0..24 {
        let mid = 0.5 * (lo + hi);
        if density_at(mid) < target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cloud_shape() {
        let mut rng = Rng::new(1);
        let c = PointCloud::synthetic(&mut rng, 100, 5);
        assert_eq!(c.xs.len(), 500);
        assert_eq!(c.point(99).len(), 5);
        assert_eq!(c.dist2(3, 3), 0.0);
    }

    #[test]
    fn kernel_is_symmetric_with_unit_diagonal() {
        let mut rng = Rng::new(2);
        let c = PointCloud::synthetic(&mut rng, 120, 4);
        let k = rbf_kernel_csr(&c, 0.3, 0.9, 0.05);
        assert_eq!(k.asymmetry(), 0.0);
        for i in 0..k.n {
            assert_eq!(k.get(i, i), 1.0);
        }
    }

    #[test]
    fn kernel_entries_in_unit_interval() {
        let mut rng = Rng::new(3);
        let c = PointCloud::synthetic(&mut rng, 80, 3);
        let k = rbf_kernel_csr(&c, 0.5, 1.5, 0.1);
        assert!(k.values.iter().all(|&v| v > 0.0 && v <= 1.0));
    }

    #[test]
    fn calibration_tracks_target_density() {
        let mut rng = Rng::new(4);
        let c = PointCloud::synthetic(&mut rng, 400, 6);
        for target in [0.01, 0.05, 0.15] {
            let k = rbf_kernel_csr(&c, 0.4, 1.2, target);
            let got = k.density();
            assert!(
                (got / target) > 0.3 && (got / target) < 3.0,
                "target {target} got {got}"
            );
        }
    }

    #[test]
    fn kernel_is_positive_definite_before_ridge() {
        // the Wendland taper keeps the truncated kernel PD (cf. module
        // docs); check the smallest eigenvalue of a dense copy
        let mut rng = Rng::new(6);
        let c = PointCloud::synthetic(&mut rng, 90, 8);
        let k = rbf_kernel_csr(&c, 0.15, 0.45, 0.05);
        let ev = crate::linalg::sym_eigenvalues(&k.to_dense());
        assert!(ev[0] > -1e-10, "λmin = {}", ev[0]);
    }

    #[test]
    fn denser_target_gives_denser_kernel() {
        let mut rng = Rng::new(5);
        let c = PointCloud::synthetic(&mut rng, 300, 4);
        let k1 = rbf_kernel_csr(&c, 0.4, 1.2, 0.01);
        let k2 = rbf_kernel_csr(&c, 0.4, 1.2, 0.2);
        assert!(k2.nnz() > k1.nnz());
    }
}
