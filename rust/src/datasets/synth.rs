//! Synthetic SPD generators.
//!
//! * [`random_spd_exact`] — the paper's §4.4 matrix: dense storage, random
//!   symmetric entries at a given density, diagonal shifted so λ₁ hits a
//!   prescribed value exactly (needs an O(n³) eigensolve; n ≤ ~500).
//! * [`random_sparse_spd`] — the §5.3.1 scaled-up variant: CSR, density
//!   swept over 1e-3..1e-1, diagonal shifted by a Gershgorin bound plus a
//!   prescribed λ₁ (cheap, guarantees λ_min ≥ λ₁ rather than equality —
//!   the speedup experiments only need positive definiteness + a window).

use crate::linalg::{sym_eigenvalues, DMat};
use crate::sparse::{gershgorin_bounds, Csr, CsrBuilder};
use crate::util::rng::Rng;

/// Paper §4.4: random symmetric `n×n` with `density` fraction of normal
/// entries, shifted so the smallest eigenvalue equals `lam1` exactly.
/// Returns `(A, λ₁, λ_N)` with the *true* extremal eigenvalues.
pub fn random_spd_exact(rng: &mut Rng, n: usize, density: f64, lam1: f64) -> (DMat, f64, f64) {
    let mut a = DMat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            if i == j || rng.bool(density) {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
    }
    let ev = sym_eigenvalues(&a);
    a.shift_diag(lam1 - ev[0]);
    (a, lam1, ev[n - 1] - ev[0] + lam1)
}

/// Paper §5.3.1: sparse random symmetric CSR at the given density, made
/// positive definite by shifting the diagonal to `lam1 −` (Gershgorin
/// lower bound). Returns `(A, window)` where `window` is a valid spectrum
/// bracket (Gershgorin of the shifted matrix, lower end clamped to lam1).
pub fn random_sparse_spd(
    rng: &mut Rng,
    n: usize,
    density: f64,
    lam1: f64,
) -> (Csr, crate::sparse::SpectrumBounds) {
    // sample ~density·n²/2 off-diagonal pairs
    let target_pairs = (density * (n as f64) * (n as f64) / 2.0).round() as usize;
    let mut b = CsrBuilder::new(n);
    for _ in 0..target_pairs {
        let i = rng.below(n);
        let j = rng.below(n);
        if i != j {
            b.push_sym(i, j, rng.normal());
        }
    }
    for i in 0..n {
        b.push(i, i, rng.normal());
    }
    let base = b.build();
    let g = gershgorin_bounds(&base);
    let shifted = base.with_diag_shift(lam1 - g.lo);
    let window = gershgorin_bounds(&shifted).clamp_lo(lam1 * 0.5);
    (shifted, window)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::util::prop::{assert_close, forall};

    #[test]
    fn exact_generator_hits_lambda1() {
        forall(10, 0xD51, |rng| {
            let n = 8 + rng.below(40);
            let (a, l1, ln) = random_spd_exact(rng, n, 0.3, 1e-2);
            let ev = sym_eigenvalues(&a);
            assert_close(ev[0], 1e-2, 1e-6, 1e-9);
            assert_close(ev[0], l1, 1e-12, 0.0);
            assert_close(ev[n - 1], ln, 1e-6, 1e-9);
        });
    }

    #[test]
    fn exact_generator_density_roughly_respected() {
        let mut rng = Rng::new(7);
        let n = 100;
        let (a, _, _) = random_spd_exact(&mut rng, n, 0.1, 1e-2);
        let nnz_off = (0..n)
            .flat_map(|i| (0..n).map(move |j| (i, j)))
            .filter(|&(i, j)| i != j && a.get(i, j) != 0.0)
            .count();
        let emp = nnz_off as f64 / (n * n - n) as f64;
        assert!((emp - 0.1).abs() < 0.03, "empirical density {emp}");
    }

    #[test]
    fn sparse_generator_is_spd_and_window_valid() {
        forall(8, 0xD52, |rng| {
            let n = 30 + rng.below(80);
            let density = [1e-2, 5e-2, 1e-1][rng.below(3)];
            let (a, w) = random_sparse_spd(rng, n, density, 1e-2);
            assert_eq!(a.asymmetry(), 0.0);
            // SPD check via Cholesky of the dense copy
            let ch = Cholesky::factor(&a.to_dense());
            assert!(ch.is_ok(), "not SPD at density {density}");
            let ev = sym_eigenvalues(&a.to_dense());
            assert!(w.lo <= ev[0] + 1e-9, "window lo {} > λ1 {}", w.lo, ev[0]);
            assert!(w.hi >= ev[n - 1] - 1e-9);
            assert!(w.lo > 0.0);
        });
    }

    #[test]
    fn sparse_generator_density_scales() {
        let mut rng = Rng::new(9);
        let (a_lo, _) = random_sparse_spd(&mut rng, 400, 1e-3, 1e-2);
        let (a_hi, _) = random_sparse_spd(&mut rng, 400, 1e-1, 1e-2);
        assert!(a_hi.nnz() > 10 * a_lo.nnz());
    }
}
