//! Dataset builders: the paper's synthetic generators (§4.4, §5.3.1) and
//! the six real-dataset *substitutes* of Table 1 (§5.3.2).
//!
//! The image is offline, so UCI/SNAP data is unavailable; per the
//! substitution rule (DESIGN.md §3) we generate synthetic equivalents that
//! match each dataset's dimension, construction (RBF kernel with cutoff /
//! graph Laplacian), and nnz density — the three quantities that drive both
//! the sparse-matvec cost and the conditioning, i.e. the two mechanisms
//! behind the paper's speedups.

pub mod graphs;
pub mod points;
pub mod synth;

pub use graphs::{laplacian, power_law_graph};
pub use points::{rbf_kernel_csr, PointCloud};
pub use synth::{random_sparse_spd, random_spd_exact};

use crate::sparse::Csr;
use crate::util::rng::Rng;

/// Construction recipe for a Table-1 substitute.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    /// RBF kernel over a synthetic point cloud, hard cutoff at 3σ.
    RbfKernel,
    /// Graph Laplacian of a synthetic power-law graph.
    GraphLaplacian,
}

/// A Table-1 row: name, paper stats, and our substitute's recipe.
#[derive(Clone, Debug)]
pub struct DatasetSpec {
    pub name: &'static str,
    /// paper dimension
    pub n: usize,
    /// paper nnz (target; we match the implied density approximately)
    pub paper_nnz: usize,
    pub kind: Kind,
    /// RBF: (point dimension, sigma); Laplacian: ignored
    pub dim: usize,
    pub sigma: f64,
}

/// ridge added by the paper to every dataset ("1E-3 times identity").
pub const RIDGE: f64 = 1e-3;

/// The six Table-1 substitutes.
pub fn table1_specs() -> Vec<DatasetSpec> {
    vec![
        DatasetSpec { name: "Abalone", n: 4177, paper_nnz: 144_553, kind: Kind::RbfKernel, dim: 8, sigma: 0.15 },
        DatasetSpec { name: "Wine", n: 4898, paper_nnz: 2_659_910, kind: Kind::RbfKernel, dim: 11, sigma: 1.0 },
        DatasetSpec { name: "GR", n: 5242, paper_nnz: 34_209, kind: Kind::GraphLaplacian, dim: 0, sigma: 0.0 },
        DatasetSpec { name: "HEP", n: 9877, paper_nnz: 61_821, kind: Kind::GraphLaplacian, dim: 0, sigma: 0.0 },
        DatasetSpec { name: "Epinions", n: 75_879, paper_nnz: 518_231, kind: Kind::GraphLaplacian, dim: 0, sigma: 0.0 },
        DatasetSpec { name: "Slashdot", n: 82_168, paper_nnz: 959_454, kind: Kind::GraphLaplacian, dim: 0, sigma: 0.0 },
    ]
}

impl DatasetSpec {
    /// Paper density (nnz / n²).
    pub fn paper_density(&self) -> f64 {
        self.paper_nnz as f64 / (self.n as f64 * self.n as f64)
    }

    /// Build the substitute matrix, optionally scaled down by `scale`
    /// (size divided by `scale`, density preserved) so the heavy Table-2
    /// rows fit the session budget; scale = 1 reproduces the paper shape.
    pub fn build(&self, rng: &mut Rng, scale: usize) -> Csr {
        let n = (self.n / scale.max(1)).max(16);
        let m = match self.kind {
            Kind::RbfKernel => {
                let cloud = PointCloud::synthetic(rng, n, self.dim);
                // calibrate cutoff so density lands near the paper's
                rbf_kernel_csr(&cloud, self.sigma, 3.0 * self.sigma, self.paper_density())
            }
            Kind::GraphLaplacian => {
                // paper nnz is edge-structure nnz; avg degree = nnz/n − 1
                let avg_deg = (self.paper_nnz as f64 / self.n as f64 - 1.0).max(2.0);
                let g = power_law_graph(rng, n, avg_deg);
                laplacian(n, &g)
            }
        };
        m.with_diag_shift(RIDGE)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_match_table1_shapes() {
        let specs = table1_specs();
        assert_eq!(specs.len(), 6);
        assert_eq!(specs[0].n, 4177);
        assert!((specs[1].paper_density() - 0.1109).abs() < 0.002);
        assert!((specs[2].paper_density() - 0.0012).abs() < 0.0005);
    }

    #[test]
    fn build_scaled_matches_density_class() {
        let mut rng = Rng::new(42);
        for spec in table1_specs().iter().take(4) {
            let scale = 16;
            let m = spec.build(&mut rng, scale);
            assert_eq!(m.asymmetry(), 0.0, "{} not symmetric", spec.name);
            match spec.kind {
                // RBF kernels are calibrated to the paper *density*
                Kind::RbfKernel => {
                    let ratio = m.density() / spec.paper_density();
                    assert!(
                        (0.2..6.0).contains(&ratio),
                        "{}: density {} vs paper {} (ratio {ratio})",
                        spec.name,
                        m.density(),
                        spec.paper_density()
                    );
                }
                // graphs preserve *average degree* (density rises 1/scale
                // when the node count shrinks — inherent to graph scaling)
                Kind::GraphLaplacian => {
                    let paper_deg = spec.paper_nnz as f64 / spec.n as f64;
                    let got_deg = m.nnz() as f64 / m.n as f64;
                    let ratio = got_deg / paper_deg;
                    assert!(
                        (0.4..2.5).contains(&ratio),
                        "{}: avg nnz/row {} vs paper {} (ratio {ratio})",
                        spec.name,
                        got_deg,
                        paper_deg
                    );
                }
            }
            // ridge present on the diagonal
            assert!(m.get(0, 0) >= RIDGE);
        }
    }
}
