//! Runtime layer: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + manifest) and executes them on the
//! CPU PJRT client. Python never runs here — the artifacts are
//! self-contained XLA programs.
//!
//! The PJRT backend needs the `xla` (and `anyhow`) crates, which only
//! exist in the image's vendored registry; it is gated behind the `pjrt`
//! feature. Default builds get the [`null`] stub, whose `GqlRuntime::load`
//! always fails — the coordinator then serves everything through the
//! native GQL paths (scalar and coalesced block), so the full stack works
//! offline.

pub mod history;

#[cfg(feature = "pjrt")]
pub mod pjrt;

#[cfg(not(feature = "pjrt"))]
pub mod null;

pub use history::{pad_query, BoundsHistory};

#[cfg(feature = "pjrt")]
pub use pjrt::{GqlArtifact, GqlRuntime};

#[cfg(not(feature = "pjrt"))]
pub use null::{GqlArtifact, GqlRuntime, RuntimeUnavailable};
