//! PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text + manifest) and executes them on the
//! CPU PJRT client. Python never runs here — the artifacts are
//! self-contained XLA programs.

pub mod pjrt;

pub use pjrt::{BoundsHistory, GqlArtifact, GqlRuntime};
