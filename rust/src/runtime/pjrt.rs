//! Wrapper around the `xla` crate: compile each manifest bucket once,
//! execute many times.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serializes protos with 64-bit instruction ids that the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! Artifact signature (matches aot.py):
//!   inputs  a:[n,n] | [b,n,n], u:[n] | [b,n], lam_min, lam_max (f32)
//!   outputs (g, g_rr, g_lr, g_lo) each [iters] | [b,iters]

use super::history::{pad_query, BoundsHistory};
use crate::config::run::{parse_manifest, ManifestEntry};
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// One compiled bucket.
pub struct GqlArtifact {
    pub meta: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl GqlArtifact {
    /// Execute on one query (batch buckets replicate the query — used by
    /// the batcher only through [`GqlRuntime::execute_batch`]).
    pub fn execute(
        &self,
        a: &[f32],
        u: &[f32],
        lam_min: f32,
        lam_max: f32,
    ) -> Result<BoundsHistory> {
        let n = self.meta.n;
        if self.meta.batch != 1 {
            bail!("single-query execute on a batched artifact");
        }
        if a.len() != n * n || u.len() != n {
            bail!("shape mismatch: a={} u={} for n={}", a.len(), u.len(), n);
        }
        let a_lit = xla::Literal::vec1(a).reshape(&[n as i64, n as i64])?;
        let u_lit = xla::Literal::vec1(u);
        let lo = xla::Literal::from(lam_min);
        let hi = xla::Literal::from(lam_max);
        let result = self.exe.execute::<xla::Literal>(&[a_lit, u_lit, lo, hi])?[0][0]
            .to_literal_sync()?;
        let (g, grr, glr, glo) = result.to_tuple4()?;
        Ok(BoundsHistory {
            gauss: to_f64(&g)?,
            radau_lower: to_f64(&grr)?,
            radau_upper: to_f64(&glr)?,
            lobatto: to_f64(&glo)?,
        })
    }

    /// Execute a batched bucket: `a` is `[b, n, n]` row-major flattened,
    /// `u` `[b, n]`, windows per lane. Returns one history per lane.
    pub fn execute_batch(
        &self,
        a: &[f32],
        u: &[f32],
        lam_min: &[f32],
        lam_max: &[f32],
    ) -> Result<Vec<BoundsHistory>> {
        let (n, b) = (self.meta.n, self.meta.batch);
        if b == 1 {
            bail!("batch execute on a single-query artifact");
        }
        if a.len() != b * n * n || u.len() != b * n || lam_min.len() != b || lam_max.len() != b
        {
            bail!("batch shape mismatch");
        }
        let a_lit = xla::Literal::vec1(a).reshape(&[b as i64, n as i64, n as i64])?;
        let u_lit = xla::Literal::vec1(u).reshape(&[b as i64, n as i64])?;
        let lo = xla::Literal::vec1(lam_min);
        let hi = xla::Literal::vec1(lam_max);
        let result = self.exe.execute::<xla::Literal>(&[a_lit, u_lit, lo, hi])?[0][0]
            .to_literal_sync()?;
        let (g, grr, glr, glo) = result.to_tuple4()?;
        let (g, grr, glr, glo) = (to_f64(&g)?, to_f64(&grr)?, to_f64(&glr)?, to_f64(&glo)?);
        let iters = self.meta.iters;
        let lane = |v: &Vec<f64>, i: usize| v[i * iters..(i + 1) * iters].to_vec();
        Ok((0..b)
            .map(|i| BoundsHistory {
                gauss: lane(&g, i),
                radau_lower: lane(&grr, i),
                radau_upper: lane(&glr, i),
                lobatto: lane(&glo, i),
            })
            .collect())
    }
}

fn to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}

/// All compiled buckets, indexed for dispatch.
pub struct GqlRuntime {
    client: xla::PjRtClient,
    artifacts: Vec<GqlArtifact>,
}

impl GqlRuntime {
    /// Load `manifest.json` from `dir` and compile every bucket.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let entries = parse_manifest(&src).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = Vec::with_capacity(entries.len());
        for meta in entries {
            let path = dir.join(&meta.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            artifacts.push(GqlArtifact { meta, exe });
        }
        Ok(GqlRuntime { client, artifacts })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts(&self) -> &[GqlArtifact] {
        &self.artifacts
    }

    /// Smallest single-query bucket with `n ≥ dim`.
    pub fn bucket_for(&self, dim: usize) -> Option<&GqlArtifact> {
        self.artifacts
            .iter()
            .filter(|a| a.meta.batch == 1 && a.meta.n >= dim)
            .min_by_key(|a| a.meta.n)
    }

    /// Smallest batched bucket with `n ≥ dim` (and its batch width).
    pub fn batch_bucket_for(&self, dim: usize) -> Option<&GqlArtifact> {
        self.artifacts
            .iter()
            .filter(|a| a.meta.batch > 1 && a.meta.n >= dim)
            .min_by_key(|a| a.meta.n)
    }

    /// Identity-pad a dense query to `n_pad` (delegates to the shared
    /// [`pad_query`]; exact invariance is asserted in python tests and
    /// re-checked in rust/tests/integration_runtime.rs).
    pub fn pad_query(a: &[f32], u: &[f32], n: usize, n_pad: usize) -> (Vec<f32>, Vec<f32>) {
        pad_query(a, u, n, n_pad)
    }

    /// Bounds history for one dense query, padded into the best bucket.
    pub fn gql_bounds(
        &self,
        a: &[f32],
        u: &[f32],
        n: usize,
        lam_min: f32,
        lam_max: f32,
    ) -> Result<BoundsHistory> {
        let art = self
            .bucket_for(n)
            .ok_or_else(|| anyhow!("no bucket for dim {n}"))?;
        let (ap, up) = Self::pad_query(a, u, n, art.meta.n);
        art.execute(&ap, &up, lam_min, lam_max)
    }
}

// Pure-helper tests (pad_query layout, history decisions) live in
// `super::history`; runtime tests that need compiled artifacts live in
// rust/tests/integration_runtime.rs (they require `make artifacts`).
