//! Wrapper around the `xla` crate: compile each manifest bucket once,
//! execute many times.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serializes protos with 64-bit instruction ids that the image's
//! xla_extension 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md).
//!
//! Artifact signature (matches aot.py):
//!   inputs  a:[n,n] | [b,n,n], u:[n] | [b,n], lam_min, lam_max (f32)
//!   outputs (g, g_rr, g_lr, g_lo) each [iters] | [b,iters]

use crate::config::run::{parse_manifest, ManifestEntry};
use crate::quadrature::Bounds;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Per-iteration bound history returned by one artifact execution.
#[derive(Clone, Debug)]
pub struct BoundsHistory {
    pub gauss: Vec<f64>,
    pub radau_lower: Vec<f64>,
    pub radau_upper: Vec<f64>,
    pub lobatto: Vec<f64>,
}

impl BoundsHistory {
    pub fn len(&self) -> usize {
        self.gauss.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gauss.is_empty()
    }

    /// View iteration `i` (0-based) as a [`Bounds`] snapshot.
    pub fn at(&self, i: usize) -> Bounds {
        Bounds {
            iter: i + 1,
            gauss: self.gauss[i],
            radau_lower: self.radau_lower[i],
            radau_upper: self.radau_upper[i],
            lobatto: self.lobatto[i],
            // fixed-iteration artifacts don't flag breakdown; judges treat
            // a collapsed bracket as exact
            exact: (self.radau_upper[i] - self.radau_lower[i]).abs()
                <= 1e-6 * self.gauss[i].abs().max(1e-30),
        }
    }

    /// First iteration (0-based) whose bounds decide `t < BIF`, plus the
    /// decision; `None` if the whole history is inconclusive.
    pub fn first_decision(&self, t: f64) -> Option<(usize, bool)> {
        for i in 0..self.len() {
            let b = self.at(i);
            if t < b.radau_lower {
                return Some((i, true));
            }
            if t >= b.radau_upper {
                return Some((i, false));
            }
        }
        None
    }
}

/// One compiled bucket.
pub struct GqlArtifact {
    pub meta: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl GqlArtifact {
    /// Execute on one query (batch buckets replicate the query — used by
    /// the batcher only through [`GqlRuntime::execute_batch`]).
    pub fn execute(
        &self,
        a: &[f32],
        u: &[f32],
        lam_min: f32,
        lam_max: f32,
    ) -> Result<BoundsHistory> {
        let n = self.meta.n;
        if self.meta.batch != 1 {
            bail!("single-query execute on a batched artifact");
        }
        if a.len() != n * n || u.len() != n {
            bail!("shape mismatch: a={} u={} for n={}", a.len(), u.len(), n);
        }
        let a_lit = xla::Literal::vec1(a).reshape(&[n as i64, n as i64])?;
        let u_lit = xla::Literal::vec1(u);
        let lo = xla::Literal::from(lam_min);
        let hi = xla::Literal::from(lam_max);
        let result = self.exe.execute::<xla::Literal>(&[a_lit, u_lit, lo, hi])?[0][0]
            .to_literal_sync()?;
        let (g, grr, glr, glo) = result.to_tuple4()?;
        Ok(BoundsHistory {
            gauss: to_f64(&g)?,
            radau_lower: to_f64(&grr)?,
            radau_upper: to_f64(&glr)?,
            lobatto: to_f64(&glo)?,
        })
    }

    /// Execute a batched bucket: `a` is `[b, n, n]` row-major flattened,
    /// `u` `[b, n]`, windows per lane. Returns one history per lane.
    pub fn execute_batch(
        &self,
        a: &[f32],
        u: &[f32],
        lam_min: &[f32],
        lam_max: &[f32],
    ) -> Result<Vec<BoundsHistory>> {
        let (n, b) = (self.meta.n, self.meta.batch);
        if b == 1 {
            bail!("batch execute on a single-query artifact");
        }
        if a.len() != b * n * n || u.len() != b * n || lam_min.len() != b || lam_max.len() != b
        {
            bail!("batch shape mismatch");
        }
        let a_lit = xla::Literal::vec1(a).reshape(&[b as i64, n as i64, n as i64])?;
        let u_lit = xla::Literal::vec1(u).reshape(&[b as i64, n as i64])?;
        let lo = xla::Literal::vec1(lam_min);
        let hi = xla::Literal::vec1(lam_max);
        let result = self.exe.execute::<xla::Literal>(&[a_lit, u_lit, lo, hi])?[0][0]
            .to_literal_sync()?;
        let (g, grr, glr, glo) = result.to_tuple4()?;
        let (g, grr, glr, glo) = (to_f64(&g)?, to_f64(&grr)?, to_f64(&glr)?, to_f64(&glo)?);
        let iters = self.meta.iters;
        let lane = |v: &Vec<f64>, i: usize| v[i * iters..(i + 1) * iters].to_vec();
        Ok((0..b)
            .map(|i| BoundsHistory {
                gauss: lane(&g, i),
                radau_lower: lane(&grr, i),
                radau_upper: lane(&glr, i),
                lobatto: lane(&glo, i),
            })
            .collect())
    }
}

fn to_f64(lit: &xla::Literal) -> Result<Vec<f64>> {
    Ok(lit.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}

/// All compiled buckets, indexed for dispatch.
pub struct GqlRuntime {
    client: xla::PjRtClient,
    artifacts: Vec<GqlArtifact>,
}

impl GqlRuntime {
    /// Load `manifest.json` from `dir` and compile every bucket.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest_path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let entries = parse_manifest(&src).map_err(|e| anyhow!("manifest: {e}"))?;
        let client = xla::PjRtClient::cpu()?;
        let mut artifacts = Vec::with_capacity(entries.len());
        for meta in entries {
            let path = dir.join(&meta.path);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            artifacts.push(GqlArtifact { meta, exe });
        }
        Ok(GqlRuntime { client, artifacts })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifacts(&self) -> &[GqlArtifact] {
        &self.artifacts
    }

    /// Smallest single-query bucket with `n ≥ dim`.
    pub fn bucket_for(&self, dim: usize) -> Option<&GqlArtifact> {
        self.artifacts
            .iter()
            .filter(|a| a.meta.batch == 1 && a.meta.n >= dim)
            .min_by_key(|a| a.meta.n)
    }

    /// Smallest batched bucket with `n ≥ dim` (and its batch width).
    pub fn batch_bucket_for(&self, dim: usize) -> Option<&GqlArtifact> {
        self.artifacts
            .iter()
            .filter(|a| a.meta.batch > 1 && a.meta.n >= dim)
            .min_by_key(|a| a.meta.n)
    }

    /// Identity-pad a dense query to `n_pad` (see model.pad_query; exact
    /// invariance is asserted in python tests and re-checked in
    /// rust/tests/integration_runtime.rs).
    pub fn pad_query(a: &[f32], u: &[f32], n: usize, n_pad: usize) -> (Vec<f32>, Vec<f32>) {
        assert!(n_pad >= n);
        let mut ap = vec![0.0f32; n_pad * n_pad];
        for i in 0..n_pad {
            ap[i * n_pad + i] = 1.0;
        }
        for i in 0..n {
            ap[i * n_pad..i * n_pad + n].copy_from_slice(&a[i * n..(i + 1) * n]);
        }
        let mut up = vec![0.0f32; n_pad];
        up[..n].copy_from_slice(u);
        (ap, up)
    }

    /// Bounds history for one dense query, padded into the best bucket.
    pub fn gql_bounds(
        &self,
        a: &[f32],
        u: &[f32],
        n: usize,
        lam_min: f32,
        lam_max: f32,
    ) -> Result<BoundsHistory> {
        let art = self
            .bucket_for(n)
            .ok_or_else(|| anyhow!("no bucket for dim {n}"))?;
        let (ap, up) = Self::pad_query(a, u, n, art.meta.n);
        art.execute(&ap, &up, lam_min, lam_max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Runtime tests that need compiled artifacts live in
    // rust/tests/integration_runtime.rs (they require `make artifacts`).
    // Here: pure helpers.

    #[test]
    fn pad_query_layout() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let u = [5.0f32, 6.0];
        let (ap, up) = GqlRuntime::pad_query(&a, &u, 2, 4);
        assert_eq!(ap.len(), 16);
        // original block
        assert_eq!(ap[0], 1.0);
        assert_eq!(ap[1], 2.0);
        assert_eq!(ap[4], 3.0);
        assert_eq!(ap[5], 4.0);
        // identity tail
        assert_eq!(ap[2 * 4 + 2], 1.0);
        assert_eq!(ap[3 * 4 + 3], 1.0);
        assert_eq!(ap[2 * 4 + 3], 0.0);
        assert_eq!(up, vec![5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn history_first_decision() {
        let h = BoundsHistory {
            gauss: vec![1.0, 2.0, 3.0],
            radau_lower: vec![1.5, 2.5, 3.5],
            radau_upper: vec![10.0, 6.0, 3.8],
            lobatto: vec![11.0, 7.0, 4.0],
        };
        // t below the first lower bound: decided true at iteration 0
        assert_eq!(h.first_decision(1.0), Some((0, true)));
        // t above all upper bounds: decided false once upper ≤ t
        assert_eq!(h.first_decision(6.5), Some((1, false)));
        // t in the final bracket: undecidable
        assert_eq!(h.first_decision(3.6), None);
    }

    #[test]
    fn history_at_marks_collapsed_bracket_exact() {
        let h = BoundsHistory {
            gauss: vec![2.0],
            radau_lower: vec![2.0],
            radau_upper: vec![2.0],
            lobatto: vec![2.0],
        };
        assert!(h.at(0).exact);
    }
}
