//! Backend-independent runtime types: the per-iteration bound history an
//! artifact execution returns, and the identity-padding helper. Shared by
//! the PJRT backend (`pjrt`, behind the `pjrt` feature) and the
//! native-only stub (`null`).

use crate::quadrature::Bounds;

/// Per-iteration bound history returned by one artifact execution.
#[derive(Clone, Debug)]
pub struct BoundsHistory {
    pub gauss: Vec<f64>,
    pub radau_lower: Vec<f64>,
    pub radau_upper: Vec<f64>,
    pub lobatto: Vec<f64>,
}

impl BoundsHistory {
    pub fn len(&self) -> usize {
        self.gauss.len()
    }

    pub fn is_empty(&self) -> bool {
        self.gauss.is_empty()
    }

    /// View iteration `i` (0-based) as a [`Bounds`] snapshot.
    pub fn at(&self, i: usize) -> Bounds {
        Bounds {
            iter: i + 1,
            gauss: self.gauss[i],
            radau_lower: self.radau_lower[i],
            radau_upper: self.radau_upper[i],
            lobatto: self.lobatto[i],
            // fixed-iteration artifacts don't flag breakdown; judges treat
            // a collapsed bracket as exact
            exact: (self.radau_upper[i] - self.radau_lower[i]).abs()
                <= 1e-6 * self.gauss[i].abs().max(1e-30),
        }
    }

    /// First iteration (0-based) whose bounds decide `t < BIF`, plus the
    /// decision; `None` if the whole history is inconclusive.
    pub fn first_decision(&self, t: f64) -> Option<(usize, bool)> {
        for i in 0..self.len() {
            let b = self.at(i);
            if t < b.radau_lower {
                return Some((i, true));
            }
            if t >= b.radau_upper {
                return Some((i, false));
            }
        }
        None
    }
}

/// Identity-pad a dense query to `n_pad` (see model.pad_query; exact
/// invariance is asserted in python tests and re-checked in
/// rust/tests/integration_runtime.rs).
pub fn pad_query(a: &[f32], u: &[f32], n: usize, n_pad: usize) -> (Vec<f32>, Vec<f32>) {
    assert!(n_pad >= n);
    let mut ap = vec![0.0f32; n_pad * n_pad];
    for i in 0..n_pad {
        ap[i * n_pad + i] = 1.0;
    }
    for i in 0..n {
        ap[i * n_pad..i * n_pad + n].copy_from_slice(&a[i * n..(i + 1) * n]);
    }
    let mut up = vec![0.0f32; n_pad];
    up[..n].copy_from_slice(u);
    (ap, up)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_query_layout() {
        let a = [1.0f32, 2.0, 3.0, 4.0]; // [[1,2],[3,4]]
        let u = [5.0f32, 6.0];
        let (ap, up) = pad_query(&a, &u, 2, 4);
        assert_eq!(ap.len(), 16);
        // original block
        assert_eq!(ap[0], 1.0);
        assert_eq!(ap[1], 2.0);
        assert_eq!(ap[4], 3.0);
        assert_eq!(ap[5], 4.0);
        // identity tail
        assert_eq!(ap[2 * 4 + 2], 1.0);
        assert_eq!(ap[3 * 4 + 3], 1.0);
        assert_eq!(ap[2 * 4 + 3], 0.0);
        assert_eq!(up, vec![5.0, 6.0, 0.0, 0.0]);
    }

    #[test]
    fn history_first_decision() {
        let h = BoundsHistory {
            gauss: vec![1.0, 2.0, 3.0],
            radau_lower: vec![1.5, 2.5, 3.5],
            radau_upper: vec![10.0, 6.0, 3.8],
            lobatto: vec![11.0, 7.0, 4.0],
        };
        // t below the first lower bound: decided true at iteration 0
        assert_eq!(h.first_decision(1.0), Some((0, true)));
        // t above all upper bounds: decided false once upper ≤ t
        assert_eq!(h.first_decision(6.5), Some((1, false)));
        // t in the final bracket: undecidable
        assert_eq!(h.first_decision(3.6), None);
    }

    #[test]
    fn history_at_marks_collapsed_bracket_exact() {
        let h = BoundsHistory {
            gauss: vec![2.0],
            radau_lower: vec![2.0],
            radau_upper: vec![2.0],
            lobatto: vec![2.0],
        };
        assert!(h.at(0).exact);
    }
}
