//! Native-only runtime stub, compiled when the `pjrt` feature is off
//! (the `xla` crate and its PJRT client are not in the offline crate
//! set). Every entry point reports [`RuntimeUnavailable`], so the
//! coordinator degrades to the native GQL path exactly as it does for a
//! missing artifacts directory — the whole serving stack stays usable.

use super::history::{pad_query, BoundsHistory};
use crate::config::run::ManifestEntry;
use std::fmt;
use std::path::Path;

/// The PJRT backend was not compiled in.
#[derive(Clone, Copy, Debug)]
pub struct RuntimeUnavailable;

impl fmt::Display for RuntimeUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "built without the `pjrt` feature; native GQL only")
    }
}

impl std::error::Error for RuntimeUnavailable {}

/// One compiled bucket (never instantiated in stub builds; the type
/// exists so the coordinator's dispatch code compiles unchanged).
pub struct GqlArtifact {
    pub meta: ManifestEntry,
}

impl GqlArtifact {
    pub fn execute(
        &self,
        _a: &[f32],
        _u: &[f32],
        _lam_min: f32,
        _lam_max: f32,
    ) -> Result<BoundsHistory, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn execute_batch(
        &self,
        _a: &[f32],
        _u: &[f32],
        _lam_min: &[f32],
        _lam_max: &[f32],
    ) -> Result<Vec<BoundsHistory>, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }
}

/// Stub runtime: loading always fails, so callers fall back natively.
pub struct GqlRuntime {
    artifacts: Vec<GqlArtifact>,
}

impl GqlRuntime {
    pub fn load(_dir: &Path) -> Result<Self, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }

    pub fn platform(&self) -> String {
        "disabled".to_string()
    }

    pub fn artifacts(&self) -> &[GqlArtifact] {
        &self.artifacts
    }

    pub fn bucket_for(&self, _dim: usize) -> Option<&GqlArtifact> {
        None
    }

    pub fn batch_bucket_for(&self, _dim: usize) -> Option<&GqlArtifact> {
        None
    }

    /// Same padding helper as the real backend (pure, shared).
    pub fn pad_query(a: &[f32], u: &[f32], n: usize, n_pad: usize) -> (Vec<f32>, Vec<f32>) {
        pad_query(a, u, n, n_pad)
    }

    pub fn gql_bounds(
        &self,
        _a: &[f32],
        _u: &[f32],
        _n: usize,
        _lam_min: f32,
        _lam_max: f32,
    ) -> Result<BoundsHistory, RuntimeUnavailable> {
        Err(RuntimeUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_reports_unavailable() {
        let err = GqlRuntime::load(Path::new("artifacts")).err().unwrap();
        assert!(err.to_string().contains("pjrt"));
    }
}
