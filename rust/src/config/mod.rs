//! Run configuration + the minimal JSON layer (serde is not in the
//! offline crate cache): a full JSON parser/writer in [`json`] and typed
//! config structs for the launcher and the artifact manifest.

pub mod json;
pub mod run;

pub use json::{parse, Json, JsonError};
pub use run::{ExperimentConfig, ManifestEntry, RunConfig};
