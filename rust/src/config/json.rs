//! Minimal JSON: a recursive-descent parser and a compact writer.
//! Supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, bools, null); numbers parse as f64 (adequate for manifests and
//! run configs).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a complete JSON document (rejects trailing garbage).
pub fn parse(src: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(a)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let hex = self
                            .bytes
                            .get(self.pos..self.pos + 4)
                            .ok_or_else(|| self.err("bad \\u escape"))?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex)
                                .map_err(|_| self.err("bad \\u escape"))?,
                            16,
                        )
                        .map_err(|_| self.err("bad \\u escape"))?;
                        self.pos += 4;
                        // surrogate pairs
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bytes.get(self.pos) == Some(&b'\\')
                                && self.bytes.get(self.pos + 1) == Some(&b'u')
                            {
                                let hex2 = self
                                    .bytes
                                    .get(self.pos + 2..self.pos + 6)
                                    .ok_or_else(|| self.err("bad surrogate"))?;
                                let lo = u32::from_str_radix(
                                    std::str::from_utf8(hex2)
                                        .map_err(|_| self.err("bad surrogate"))?,
                                    16,
                                )
                                .map_err(|_| self.err("bad surrogate"))?;
                                self.pos += 6;
                                let c = 0x10000 + ((code - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                            } else {
                                return Err(self.err("lone surrogate"));
                            }
                        } else {
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        let chunk = self
                            .bytes
                            .get(start..end)
                            .ok_or_else(|| self.err("truncated utf-8"))?;
                        let st = std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(st);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let src = r#"{"version": 1, "entries": [{"name": "gql_n16_b1_i16", "n": 16, "pallas": true}]}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let e = &v.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("gql_n16_b1_i16"));
        assert_eq!(e.get("pallas").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn numbers_all_forms() {
        for (s, want) in [
            ("0", 0.0),
            ("-12", -12.0),
            ("3.5", 3.5),
            ("1e3", 1000.0),
            ("-2.5E-2", -0.025),
        ] {
            assert_eq!(parse(s).unwrap().as_f64(), Some(want), "{s}");
        }
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\té\u{1F600}".into());
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape_and_surrogates() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrip_nested() {
        let src = r#"{"a":[1,2,{"b":null,"c":[true,false]}],"d":-1.5}"#;
        let v = parse(src).unwrap();
        assert_eq!(parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(parse("  [ ]  ").unwrap(), Json::Arr(vec![]));
    }
}
