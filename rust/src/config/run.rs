//! Typed configuration: the artifact manifest (written by
//! `python/compile/aot.py`) and launcher run configs.

use super::json::{parse, Json, JsonError};
use crate::quadrature::engine::EngineConfig;
use crate::quadrature::race::RacePolicy;
use crate::quadrature::stochastic::SlqConfig;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One AOT artifact bucket from `artifacts/manifest.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub path: String,
    pub n: usize,
    pub batch: usize,
    pub iters: usize,
    pub dtype: String,
    pub pallas: bool,
}

/// Parse the manifest JSON text.
pub fn parse_manifest(src: &str) -> Result<Vec<ManifestEntry>, String> {
    let v = parse(src).map_err(|e| e.to_string())?;
    let entries = v
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or("manifest missing 'entries'")?;
    let mut out = Vec::with_capacity(entries.len());
    for (i, e) in entries.iter().enumerate() {
        let gets = |k: &str| -> Result<&Json, String> {
            e.get(k).ok_or(format!("entry {i} missing '{k}'"))
        };
        out.push(ManifestEntry {
            name: gets("name")?.as_str().ok_or("name not a string")?.to_string(),
            path: gets("path")?.as_str().ok_or("path not a string")?.to_string(),
            n: gets("n")?.as_usize().ok_or("n not an integer")?,
            batch: gets("batch")?.as_usize().ok_or("batch not an integer")?,
            iters: gets("iters")?.as_usize().ok_or("iters not an integer")?,
            dtype: gets("dtype")?.as_str().ok_or("dtype not a string")?.to_string(),
            pallas: e.get("pallas").and_then(Json::as_bool).unwrap_or(false),
        });
    }
    Ok(out)
}

/// Which experiment to run (launcher subcommands mirror these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExperimentConfig {
    Fig1,
    Fig2,
    Table2,
    Rates,
    Block,
    Race,
    /// Mixed query sessions vs sequential per-query serving (ISSUE 4).
    Session,
    /// Multi-operator streaming engine vs per-operator sequential
    /// scheduling (ISSUE 5).
    Engine,
    Serve,
    /// Stochastic Lanczos quadrature: trace/logdet estimates vs dense
    /// exact references (ISSUE 9).
    Slq,
}

impl ExperimentConfig {
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "fig1" => Some(Self::Fig1),
            "fig2" => Some(Self::Fig2),
            "table2" => Some(Self::Table2),
            "rates" => Some(Self::Rates),
            "block" => Some(Self::Block),
            "race" => Some(Self::Race),
            "session" => Some(Self::Session),
            "engine" => Some(Self::Engine),
            "serve" => Some(Self::Serve),
            "slq" => Some(Self::Slq),
            _ => None,
        }
    }
}

/// Launcher run configuration, loadable from a JSON file and overridable
/// from CLI flags.
#[derive(Clone, Debug, PartialEq)]
pub struct RunConfig {
    pub seed: u64,
    /// output directory for CSV/markdown results
    pub out_dir: PathBuf,
    /// artifacts directory (PJRT buckets)
    pub artifacts_dir: PathBuf,
    /// Table-2 size divisor (1 = paper size; larger = session-budget runs)
    pub dataset_scale: usize,
    /// chain iterations for the samplers
    pub chain_iters: usize,
    /// repetitions to average
    pub repeats: usize,
    /// panel width for the block quadrature engine (candidate scoring,
    /// coalesced native serving, the `block` experiment); 1 = scalar
    pub block_width: usize,
    /// full Lanczos reorthogonalization (§5.4) for quadrature runs driven
    /// from this config (the `block` experiment sweep, `serve` requests);
    /// JSON accepts a bool or the strings "full"/"none"
    pub reorth: bool,
    /// candidate racing for config-driven greedy runs (the `race`
    /// experiment's raced arm, `serve` argmax demo batches): true =
    /// prune dominated candidates by interval dominance, false = score
    /// every candidate exhaustively. Selections are identical either way;
    /// only panel sweeps differ. JSON accepts a bool or the strings
    /// "prune"/"exhaustive"
    pub race: bool,
    /// global live-lane budget of the multi-operator streaming engine
    /// (ISSUE 5): queries beyond the budget are parked whole and resumed
    /// bit-identically, priority-ordered. Validated at admission by
    /// [`EngineConfig::validate_knobs`] — 0 and absurd values are
    /// rejected with the typed
    /// [`EngineConfigError`](crate::quadrature::engine::EngineConfigError),
    /// mirroring `BatchPolicy::validate`.
    pub engine_lanes: usize,
    /// rounds an idle engine session survives before TTL eviction;
    /// validated together with `engine_lanes` at admission
    pub engine_ttl_rounds: usize,
    /// sweep workers for the engine's parallel panel fan-out (results
    /// are bit-identical at any worker count)
    pub engine_workers: usize,
    /// byte budget of the engine's resident operator store (ISSUE 7):
    /// idle, unpinned operators LRU-evict past it; pinned (live-session)
    /// operators never count against correctness, only memory
    pub engine_store_bytes: usize,
    /// open-ticket cap for deadline-checked admission
    /// ([`Engine::try_submit`](crate::quadrature::engine::Engine::try_submit)):
    /// at the cap the least-urgent sheddable estimate resolves early to
    /// its current four-bound bracket, or the submission is refused.
    /// Clamped to >= 1 at parse (0 would shed every submission)
    pub engine_queue_cap: usize,
    /// Hutchinson probe count for stochastic trace/logdet queries driven
    /// from this config (the `slq` experiment, `serve` stochastic
    /// traffic). Validated at admission by
    /// [`SlqConfig::validate`](crate::quadrature::stochastic::SlqConfig::validate)
    /// — 0 is rejected with the typed
    /// [`SlqConfigError`](crate::quadrature::stochastic::SlqConfigError),
    /// mirroring the `engine_*` knobs.
    pub slq_probes: usize,
    /// seed of the splittable probe stream (deterministic under any
    /// worker count or sweep mode)
    pub slq_seed: u64,
    /// relative tolerance on the combined stochastic interval; must be
    /// finite and > 0 (validated at admission)
    pub slq_tol: f64,
    /// query-lifecycle flight recorder of the streaming engine (ISSUE
    /// 10): on by default — events hook only the scheduling phases, so
    /// answers are bit-identical either way. JSON accepts a bool or the
    /// strings "on"/"off"
    pub flight: bool,
    /// extra free-form knobs
    pub extra: BTreeMap<String, String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            seed: 0xB1F,
            out_dir: PathBuf::from("results"),
            artifacts_dir: PathBuf::from("artifacts"),
            dataset_scale: 1,
            chain_iters: 1000,
            repeats: 3,
            block_width: 16,
            reorth: false,
            race: true,
            engine_lanes: 256,
            engine_ttl_rounds: 32,
            engine_workers: 1,
            engine_store_bytes: 64 << 20,
            engine_queue_cap: usize::MAX,
            slq_probes: 16,
            slq_seed: 0x51D,
            slq_tol: 1e-2,
            flight: true,
            extra: BTreeMap::new(),
        }
    }
}

impl RunConfig {
    pub fn from_json(src: &str) -> Result<Self, String> {
        let v = parse(src).map_err(|e: JsonError| e.to_string())?;
        let mut c = RunConfig::default();
        if let Some(x) = v.get("seed").and_then(Json::as_f64) {
            c.seed = x as u64;
        }
        if let Some(x) = v.get("out_dir").and_then(Json::as_str) {
            c.out_dir = PathBuf::from(x);
        }
        if let Some(x) = v.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = PathBuf::from(x);
        }
        if let Some(x) = v.get("dataset_scale").and_then(Json::as_usize) {
            c.dataset_scale = x.max(1);
        }
        if let Some(x) = v.get("chain_iters").and_then(Json::as_usize) {
            c.chain_iters = x;
        }
        if let Some(x) = v.get("repeats").and_then(Json::as_usize) {
            c.repeats = x.max(1);
        }
        if let Some(x) = v.get("block_width").and_then(Json::as_usize) {
            c.block_width = x.max(1);
        }
        match v.get("reorth") {
            Some(Json::Bool(b)) => c.reorth = *b,
            Some(Json::Str(s)) => c.reorth = s.eq_ignore_ascii_case("full"),
            _ => {}
        }
        match v.get("race") {
            Some(Json::Bool(b)) => c.race = *b,
            Some(Json::Str(s)) => c.race = s.eq_ignore_ascii_case("prune"),
            _ => {}
        }
        if let Some(x) = v.get("engine_lanes").and_then(Json::as_usize) {
            c.engine_lanes = x;
        }
        if let Some(x) = v.get("engine_ttl_rounds").and_then(Json::as_usize) {
            c.engine_ttl_rounds = x;
        }
        if let Some(x) = v.get("engine_workers").and_then(Json::as_usize) {
            c.engine_workers = x.clamp(1, 1 << 10);
        }
        if let Some(x) = v.get("engine_store_bytes").and_then(Json::as_usize) {
            c.engine_store_bytes = x;
        }
        if let Some(x) = v.get("engine_queue_cap").and_then(Json::as_usize) {
            c.engine_queue_cap = x.max(1);
        }
        if let Some(x) = v.get("slq_probes").and_then(Json::as_usize) {
            c.slq_probes = x;
        }
        if let Some(x) = v.get("slq_seed").and_then(Json::as_f64) {
            c.slq_seed = x as u64;
        }
        if let Some(x) = v.get("slq_tol").and_then(Json::as_f64) {
            c.slq_tol = x;
        }
        match v.get("flight") {
            Some(Json::Bool(b)) => c.flight = *b,
            Some(Json::Str(s)) => {
                c.flight = s.eq_ignore_ascii_case("on") || s.eq_ignore_ascii_case("true")
            }
            _ => {}
        }
        // admission validation with the typed engine error (ISSUE 5
        // satellite, mirroring BatchPolicy::validate): 0 or absurd values
        // fail the whole config load instead of deadlocking the engine
        EngineConfig::validate_knobs(c.engine_lanes, c.engine_ttl_rounds)
            .map_err(|e| e.to_string())?;
        // same treatment for the stochastic knobs: zero probes or a
        // non-finite/non-positive tolerance fail the load with the typed
        // SlqConfigError's message
        c.slq_config().validate().map_err(|e| e.to_string())?;
        if let Some(Json::Obj(m)) = v.get("extra") {
            for (k, val) in m {
                if let Some(s) = val.as_str() {
                    c.extra.insert(k.clone(), s.to_string());
                }
            }
        }
        Ok(c)
    }

    /// The engine configuration this run config describes (width from
    /// `block_width`, racing policy from `race`). Knobs were validated at
    /// admission, so this cannot fail for a loaded config.
    pub fn engine_config(&self) -> EngineConfig {
        EngineConfig::default()
            .with_width(self.block_width.max(1))
            .with_lanes(self.engine_lanes)
            .with_ttl_rounds(self.engine_ttl_rounds)
            .with_workers(self.engine_workers.max(1))
            .with_store_bytes(self.engine_store_bytes)
            .with_queue_cap(self.engine_queue_cap.max(1))
            .with_flight(self.flight)
            .with_policy(if self.race { RacePolicy::Prune } else { RacePolicy::Exhaustive })
    }

    /// The stochastic query configuration this run config describes.
    /// Validated at admission for loaded configs; call
    /// [`SlqConfig::validate`] before use when the fields were set by
    /// hand (the CLI override path does).
    pub fn slq_config(&self) -> SlqConfig {
        SlqConfig::new(self.slq_probes, self.slq_seed, self.slq_tol)
    }

    pub fn load(path: &Path) -> Result<Self, String> {
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::from_json(&src)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let src = r#"{"version":1,"entries":[
            {"name":"gql_n16_b1_i16","path":"gql_n16_b1_i16.hlo.txt","n":16,
             "batch":1,"iters":16,"dtype":"f32","pallas":true},
            {"name":"gql_n32_b8_i32","path":"x.hlo.txt","n":32,"batch":8,
             "iters":32,"dtype":"f32","pallas":false}]}"#;
        let m = parse_manifest(src).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[0].n, 16);
        assert!(m[0].pallas);
        assert_eq!(m[1].batch, 8);
    }

    #[test]
    fn manifest_missing_field_errors() {
        let src = r#"{"entries":[{"name":"x"}]}"#;
        assert!(parse_manifest(src).unwrap_err().contains("missing"));
    }

    #[test]
    fn run_config_defaults_and_overrides() {
        let c = RunConfig::from_json(r#"{"seed": 7, "dataset_scale": 8, "block_width": 32}"#)
            .unwrap();
        assert_eq!(c.seed, 7);
        assert_eq!(c.dataset_scale, 8);
        assert_eq!(c.chain_iters, 1000);
        assert_eq!(c.block_width, 32);
        let d = RunConfig::default();
        assert_eq!(d.repeats, 3);
        assert_eq!(d.block_width, 16);
        // degenerate widths clamp up to the scalar path
        let z = RunConfig::from_json(r#"{"block_width": 0}"#).unwrap();
        assert_eq!(z.block_width, 1);
    }

    #[test]
    fn reorth_knob_parses_bool_and_string_forms() {
        assert!(!RunConfig::default().reorth);
        assert!(RunConfig::from_json(r#"{"reorth": true}"#).unwrap().reorth);
        assert!(RunConfig::from_json(r#"{"reorth": "full"}"#).unwrap().reorth);
        assert!(RunConfig::from_json(r#"{"reorth": "Full"}"#).unwrap().reorth);
        assert!(!RunConfig::from_json(r#"{"reorth": "none"}"#).unwrap().reorth);
        assert!(!RunConfig::from_json(r#"{"reorth": false}"#).unwrap().reorth);
        assert!(!RunConfig::from_json(r#"{}"#).unwrap().reorth);
    }

    #[test]
    fn race_knob_parses_bool_and_string_forms() {
        assert!(RunConfig::default().race, "racing is the default");
        assert!(RunConfig::from_json(r#"{"race": true}"#).unwrap().race);
        assert!(RunConfig::from_json(r#"{"race": "prune"}"#).unwrap().race);
        assert!(RunConfig::from_json(r#"{"race": "Prune"}"#).unwrap().race);
        assert!(!RunConfig::from_json(r#"{"race": "exhaustive"}"#).unwrap().race);
        assert!(!RunConfig::from_json(r#"{"race": false}"#).unwrap().race);
        assert!(RunConfig::from_json(r#"{}"#).unwrap().race);
    }

    #[test]
    fn flight_knob_parses_bool_and_string_forms() {
        assert!(RunConfig::default().flight, "the flight recorder is on by default");
        assert!(RunConfig::from_json(r#"{"flight": true}"#).unwrap().flight);
        assert!(RunConfig::from_json(r#"{"flight": "on"}"#).unwrap().flight);
        assert!(RunConfig::from_json(r#"{"flight": "On"}"#).unwrap().flight);
        assert!(!RunConfig::from_json(r#"{"flight": "off"}"#).unwrap().flight);
        assert!(!RunConfig::from_json(r#"{"flight": false}"#).unwrap().flight);
        assert!(RunConfig::from_json(r#"{}"#).unwrap().flight);
        assert!(RunConfig::from_json(r#"{"flight": "off"}"#)
            .unwrap()
            .engine_config()
            .validate()
            .is_ok());
    }

    #[test]
    fn engine_knobs_parse_and_validate_at_admission() {
        let d = RunConfig::default();
        assert_eq!(d.engine_lanes, 256);
        assert_eq!(d.engine_ttl_rounds, 32);
        assert_eq!(d.engine_workers, 1);
        let c = RunConfig::from_json(
            r#"{"engine_lanes": 64, "engine_ttl_rounds": 8, "engine_workers": 4}"#,
        )
        .unwrap();
        assert_eq!(c.engine_lanes, 64);
        assert_eq!(c.engine_ttl_rounds, 8);
        assert_eq!(c.engine_workers, 4);
        assert!(c.engine_config().validate().is_ok());
        // the ISSUE 5 satellite: 0/absurd knobs rejected at admission
        // with the typed engine error's message
        let err = RunConfig::from_json(r#"{"engine_lanes": 0}"#).unwrap_err();
        assert!(err.contains("engine_lanes"), "{err}");
        let err = RunConfig::from_json(r#"{"engine_ttl_rounds": 0}"#).unwrap_err();
        assert!(err.contains("engine_ttl_rounds"), "{err}");
        let err = RunConfig::from_json(r#"{"engine_lanes": 99999999}"#).unwrap_err();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn slq_knobs_parse_and_validate_at_admission() {
        let d = RunConfig::default();
        assert_eq!(d.slq_probes, 16);
        assert_eq!(d.slq_seed, 0x51D);
        assert!(d.slq_tol > 0.0);
        assert!(d.slq_config().validate().is_ok());
        let c = RunConfig::from_json(
            r#"{"slq_probes": 32, "slq_seed": 99, "slq_tol": 0.05}"#,
        )
        .unwrap();
        assert_eq!(c.slq_probes, 32);
        assert_eq!(c.slq_seed, 99);
        assert_eq!(c.slq_tol, 0.05);
        // the ISSUE 9 satellite: invalid stochastic knobs rejected at
        // admission with the typed SlqConfigError's message
        let err = RunConfig::from_json(r#"{"slq_probes": 0}"#).unwrap_err();
        assert!(err.contains("slq_probes"), "{err}");
        let err = RunConfig::from_json(r#"{"slq_tol": 0.0}"#).unwrap_err();
        assert!(err.contains("slq_tol"), "{err}");
        let err = RunConfig::from_json(r#"{"slq_tol": -0.5}"#).unwrap_err();
        assert!(err.contains("slq_tol"), "{err}");
    }

    #[test]
    fn experiment_names() {
        assert_eq!(ExperimentConfig::from_name("fig1"), Some(ExperimentConfig::Fig1));
        assert_eq!(ExperimentConfig::from_name("block"), Some(ExperimentConfig::Block));
        assert_eq!(ExperimentConfig::from_name("race"), Some(ExperimentConfig::Race));
        assert_eq!(
            ExperimentConfig::from_name("session"),
            Some(ExperimentConfig::Session)
        );
        assert_eq!(
            ExperimentConfig::from_name("engine"),
            Some(ExperimentConfig::Engine)
        );
        assert_eq!(ExperimentConfig::from_name("slq"), Some(ExperimentConfig::Slq));
        assert_eq!(ExperimentConfig::from_name("nope"), None);
    }
}
