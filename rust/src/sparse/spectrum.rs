//! Spectrum-bound estimators: GQL needs λ_min/λ_max estimates straddling
//! the spectrum of the working submatrix (§3 of the paper; Fig. 1 studies
//! sensitivity to their quality).
//!
//! Three estimators, cheapest first:
//! * [`gershgorin_bounds`] — O(nnz), always valid, often loose on the left
//!   end (can go ≤ 0 for non-diagonally-dominant SPD matrices, in which
//!   case callers clamp with a known ridge, cf. the paper's +1e-3·I).
//! * [`power_iteration_lmax`] — sharp λ_max, O(iters · nnz).
//! * [`lanczos_bounds`] — a few Lanczos steps give Ritz values whose
//!   extremes approximate both ends; widened by a safety margin.

use super::SymOp;
use crate::linalg::eig::tridiag_eigenvalues;

/// An interval [lo, hi] guaranteed (or assumed) to contain the spectrum.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SpectrumBounds {
    pub lo: f64,
    pub hi: f64,
}

impl SpectrumBounds {
    /// Widen multiplicatively the way the paper's experiments do
    /// (e.g. `widen(0.1, 10.0)` reproduces Fig. 1(b)+(c)).
    pub fn widen(self, lo_factor: f64, hi_factor: f64) -> Self {
        SpectrumBounds { lo: self.lo * lo_factor, hi: self.hi * hi_factor }
    }

    /// Clamp the lower end to at least `ridge` (datasets add a ridge term
    /// that guarantees λ_min ≥ ridge when the base matrix is PSD).
    pub fn clamp_lo(self, ridge: f64) -> Self {
        SpectrumBounds { lo: self.lo.max(ridge), hi: self.hi }
    }
}

/// Gershgorin disc bounds: λ ∈ [min_i (a_ii − r_i), max_i (a_ii + r_i)]
/// with r_i the off-diagonal absolute row sum. O(nnz) via one matvec of
/// |A| against 1 — here done through `row` access when the op is CSR-like;
/// for a generic op we use diag + matvec with sign trick unavailable, so
/// this function takes the CSR directly.
pub fn gershgorin_bounds(a: &crate::sparse::Csr) -> SpectrumBounds {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for i in 0..a.n {
        let mut diag = 0.0;
        let mut radius = 0.0;
        for (j, v) in a.row(i) {
            if j == i {
                diag = v;
            } else {
                radius += v.abs();
            }
        }
        lo = lo.min(diag - radius);
        hi = hi.max(diag + radius);
    }
    if a.n == 0 {
        return SpectrumBounds { lo: 0.0, hi: 0.0 };
    }
    SpectrumBounds { lo, hi }
}

/// Gershgorin for a generic [`SymOp`] view with row access expressed via
/// matvecs of indicator vectors would be O(n·nnz); instead views provide
/// their own cheap path. This helper covers any op by |A|x ≤ routine:
/// bounds from diag ± row-sum computed with two matvecs over ±1 vectors
/// is NOT valid in general, so for generic ops use [`lanczos_bounds`].
pub fn gershgorin_view(view: &crate::sparse::SubmatrixView) -> SpectrumBounds {
    let n = view.dim();
    if n == 0 {
        return SpectrumBounds { lo: 0.0, hi: 0.0 };
    }
    // Row-wise pass through the parent rows restricted to the view.
    let diag = view.diagonal();
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    // |A| x with x = 1 gives diag + radius per row: emulate via matvec of
    // the absolute submatrix — we do it manually through column_of? That
    // would be O(n · nnz). Instead: one matvec with all-ones on the
    // *absolute values* is not expressible through SymOp, so SubmatrixView
    // exposes rows via its parent: reuse nnz()-style traversal.
    for (li, r) in view.abs_row_sums().into_iter().enumerate() {
        let radius = r - diag[li].abs();
        lo = lo.min(diag[li] - radius);
        hi = hi.max(diag[li] + radius);
    }
    SpectrumBounds { lo, hi }
}

/// λ_max estimate by power iteration with deterministic start; returns a
/// slight over-estimate (×(1+margin)) so it upper-bounds the true λ_max in
/// practice.
pub fn power_iteration_lmax(op: &dyn SymOp, iters: usize, margin: f64) -> f64 {
    let n = op.dim();
    if n == 0 {
        return 0.0;
    }
    let mut x: Vec<f64> = (0..n)
        .map(|i| 1.0 + 0.3 * ((i * 2654435761) % 97) as f64 / 97.0)
        .collect();
    let norm = x.iter().map(|v| v * v).sum::<f64>().sqrt();
    for v in &mut x {
        *v /= norm;
    }
    let mut y = vec![0.0; n];
    let mut lam = 0.0;
    for _ in 0..iters {
        op.matvec(&x, &mut y);
        lam = x.iter().zip(&y).map(|(a, b)| a * b).sum();
        let ny = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if ny == 0.0 {
            return 0.0;
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / ny;
        }
    }
    lam * (1.0 + margin)
}

/// Spectrum window from `k` Lanczos steps: the extreme Ritz values of the
/// Jacobi matrix, widened by `margin` relative to the Ritz spread.  Ritz
/// values always lie *inside* the spectrum, so the widening is what makes
/// the result usable as a GQL window; the margin trades Fig. 1-style bound
/// quality against safety.
pub fn lanczos_bounds(op: &dyn SymOp, k: usize, margin: f64) -> SpectrumBounds {
    let n = op.dim();
    if n == 0 {
        return SpectrumBounds { lo: 0.0, hi: 0.0 };
    }
    let k = k.min(n);
    let mut alphas = Vec::with_capacity(k);
    let mut betas = Vec::with_capacity(k);
    let mut v: Vec<f64> = (0..n)
        .map(|i| if i % 2 == 0 { 1.0 } else { -0.7 } + 0.1 * (i % 5) as f64)
        .collect();
    let nv = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    for x in &mut v {
        *x /= nv;
    }
    let mut v_prev = vec![0.0; n];
    let mut beta_prev = 0.0;
    let mut w = vec![0.0; n];
    for _ in 0..k {
        op.matvec(&v, &mut w);
        let alpha: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
        for ((wi, &vi), &pi) in w.iter_mut().zip(&v).zip(&v_prev) {
            *wi -= alpha * vi + beta_prev * pi;
        }
        let beta = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        alphas.push(alpha);
        if beta <= 1e-14 {
            break;
        }
        betas.push(beta);
        for i in 0..n {
            v_prev[i] = v[i];
            v[i] = w[i] / beta;
        }
        beta_prev = beta;
    }
    betas.truncate(alphas.len().saturating_sub(1));
    let ritz = tridiag_eigenvalues(&alphas, &betas);
    let (rmin, rmax) = (ritz[0], ritz[ritz.len() - 1]);
    let spread = (rmax - rmin).max(rmax.abs() * 1e-3).max(1e-12);
    SpectrumBounds { lo: rmin - margin * spread, hi: rmax + margin * spread }
}

impl crate::sparse::SubmatrixView {
    /// Σ_j |A[i,j]| per view row (helper for [`gershgorin_view`]).
    pub fn abs_row_sums(&self) -> Vec<f64> {
        let idx = self.indices();
        let mut out = vec![0.0; idx.len()];
        for (li, &gi) in idx.iter().enumerate() {
            let col = self.column_of(gi); // row gi restricted to view
            out[li] = col.iter().map(|v| v.abs()).sum();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::sym_eigenvalues;
    use crate::sparse::{Csr, CsrBuilder, SubmatrixView};
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn random_sym_csr(rng: &mut Rng, n: usize, density: f64) -> Csr {
        let mut b = CsrBuilder::new(n);
        for i in 0..n {
            b.push(i, i, 2.0 + rng.f64());
            for j in (i + 1)..n {
                if rng.bool(density) {
                    b.push_sym(i, j, rng.normal() * 0.2);
                }
            }
        }
        b.build()
    }

    #[test]
    fn gershgorin_contains_spectrum() {
        forall(20, 0x6E5, |rng| {
            let n = 2 + rng.below(25);
            let a = random_sym_csr(rng, n, 0.3);
            let b = gershgorin_bounds(&a);
            let ev = sym_eigenvalues(&a.to_dense());
            assert!(b.lo <= ev[0] + 1e-10, "lo={} > λ1={}", b.lo, ev[0]);
            assert!(b.hi >= ev[n - 1] - 1e-10, "hi={} < λn={}", b.hi, ev[n - 1]);
        });
    }

    #[test]
    fn gershgorin_view_matches_materialized() {
        forall(20, 0x6E6, |rng| {
            let n = 6 + rng.below(25);
            let a = std::sync::Arc::new(random_sym_csr(rng, n, 0.3));
            let k = 2 + rng.below(n - 3);
            let idx = rng.sample_indices(n, k);
            let view = SubmatrixView::new(&a, &idx);
            let got = gershgorin_view(&view);
            let want = gershgorin_bounds(&a.principal_submatrix(&idx));
            crate::util::prop::assert_close(got.lo, want.lo, 1e-12, 1e-12);
            crate::util::prop::assert_close(got.hi, want.hi, 1e-12, 1e-12);
        });
    }

    #[test]
    fn power_iteration_overestimates_lmax_slightly() {
        forall(15, 0x907, |rng| {
            let n = 4 + rng.below(20);
            let a = random_sym_csr(rng, n, 0.4);
            let ev = sym_eigenvalues(&a.to_dense());
            let est = power_iteration_lmax(&a, 200, 0.05);
            assert!(est >= ev[n - 1] * 0.999, "est={est} λn={}", ev[n - 1]);
            assert!(est <= ev[n - 1] * 1.25 + 1.0, "est={est} λn={}", ev[n - 1]);
        });
    }

    #[test]
    fn lanczos_bounds_bracket_after_enough_steps() {
        forall(15, 0xAAA, |rng| {
            let n = 6 + rng.below(20);
            let a = random_sym_csr(rng, n, 0.5);
            let ev = sym_eigenvalues(&a.to_dense());
            let b = lanczos_bounds(&a, n, 0.1);
            assert!(b.lo <= ev[0] + 1e-6, "lo={} λ1={}", b.lo, ev[0]);
            assert!(b.hi >= ev[n - 1] - 1e-6, "hi={} λn={}", b.hi, ev[n - 1]);
        });
    }

    #[test]
    fn widen_and_clamp() {
        let b = SpectrumBounds { lo: 0.1, hi: 10.0 };
        let w = b.widen(0.1, 10.0);
        crate::util::prop::assert_close(w.lo, 0.01, 1e-12, 0.0);
        crate::util::prop::assert_close(w.hi, 100.0, 1e-12, 0.0);
        assert_eq!(w.clamp_lo(0.5).lo, 0.5);
    }

    #[test]
    fn identity_bounds_tight() {
        let a = Csr::scaled_identity(8, 3.0);
        let b = gershgorin_bounds(&a);
        assert_eq!(b, SpectrumBounds { lo: 3.0, hi: 3.0 });
    }
}
