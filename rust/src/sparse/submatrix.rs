//! Zero-copy principal-submatrix view over a CSR matrix.
//!
//! The DPP / k-DPP samplers and double greedy repeatedly need `L_Y` for a
//! working set `Y` that changes by one element per step.  Materializing the
//! submatrix each step is O(Σ nnz(rows in Y)) *plus* allocation; this view
//! does the matvec directly through the parent with a reusable scatter map,
//! so the per-iteration quadrature cost is exactly the paper's
//! O(nnz(L_Y)).

use super::csr::Csr;
use super::SymOp;
use std::sync::Arc;

/// View of `parent[idx, idx]` implementing [`SymOp`] without materializing.
///
/// The view holds the parent behind an [`Arc`], so it is `'static` and can
/// be submitted to the resident engine's operator store
/// ([`crate::quadrature::engine::OpStore`]) like any owned operator; many
/// views over one parent share the same storage.
pub struct SubmatrixView {
    parent: Arc<Csr>,
    /// global indices of the view, defining the local ordering
    idx: Vec<usize>,
    /// global -> local position map; usize::MAX = not in view
    pos: Vec<usize>,
}

impl SubmatrixView {
    pub fn new(parent: &Arc<Csr>, idx: &[usize]) -> Self {
        let mut pos = vec![usize::MAX; parent.n];
        for (local, &g) in idx.iter().enumerate() {
            debug_assert!(g < parent.n, "index {g} out of range");
            debug_assert!(pos[g] == usize::MAX, "duplicate index {g}");
            pos[g] = local;
        }
        SubmatrixView { parent: Arc::clone(parent), idx: idx.to_vec(), pos }
    }

    /// Like [`SubmatrixView::new`] but with the local ordering sorted
    /// ascending. The BIF (and every GQL iterate) is invariant under
    /// symmetric permutation, and ascending row order turns the matvec's
    /// parent-row visits into a streaming access pattern the hardware
    /// prefetcher can follow — ~10× faster on large sparse parents
    /// (EXPERIMENTS.md §Perf). Judges should prefer this constructor.
    pub fn new_sorted(parent: &Arc<Csr>, idx: &[usize]) -> Self {
        let mut sorted = idx.to_vec();
        sorted.sort_unstable();
        let mut pos = vec![usize::MAX; parent.n];
        for (local, &g) in sorted.iter().enumerate() {
            debug_assert!(g < parent.n, "index {g} out of range");
            debug_assert!(pos[g] == usize::MAX, "duplicate index {g}");
            pos[g] = local;
        }
        SubmatrixView { parent: Arc::clone(parent), idx: sorted, pos }
    }

    /// The shared parent kernel this view indexes into.
    pub fn parent(&self) -> &Arc<Csr> {
        &self.parent
    }

    pub fn indices(&self) -> &[usize] {
        &self.idx
    }

    /// nnz of the implied submatrix (counted, not stored).
    pub fn nnz(&self) -> usize {
        self.idx
            .iter()
            .map(|&gi| {
                self.parent
                    .row(gi)
                    .filter(|&(gj, _)| self.pos[gj] != usize::MAX)
                    .count()
            })
            .sum()
    }

    /// Materialize the view as a compact local CSR in ONE traversal (no
    /// sort — CSR matvec does not require sorted columns). Costs about as
    /// much as a single view matvec; every subsequent matvec then streams
    /// a k-dim CSR instead of chasing parent rows through the scatter
    /// map. Judges materialize when they expect >1 iteration
    /// (EXPERIMENTS.md §Perf: ~2-10× on the large-graph rows).
    pub fn to_csr(&self) -> Csr {
        let k = self.idx.len();
        let mut row_ptr = Vec::with_capacity(k + 1);
        row_ptr.push(0usize);
        let mut col_idx = Vec::new();
        let mut values = Vec::new();
        let mut cols_sorted = true;
        for &gi in &self.idx {
            let row_start = col_idx.len();
            for (gj, v) in self.parent.row(gi) {
                let lj = self.pos[gj];
                if lj != usize::MAX {
                    col_idx.push(lj);
                    values.push(v);
                }
            }
            // the parent's columns are ascending, but the view's local
            // relabeling need not be monotone unless idx is sorted
            // (SubmatrixView::new_sorted); record what we actually built
            // so Csr::matvec_multi only takes its cursor-based blocked
            // path when it is valid
            cols_sorted = cols_sorted && col_idx[row_start..].windows(2).all(|w| w[0] <= w[1]);
            row_ptr.push(col_idx.len());
        }
        Csr { n: k, row_ptr, col_idx, values, cols_sorted }
    }

    /// The kernel column `parent[idx, v]` in local ordering — the `u`
    /// vector of the DPP transition BIF (`L_{Y,v}`).
    pub fn column_of(&self, v: usize) -> Vec<f64> {
        let mut col = vec![0.0; self.idx.len()];
        // v's row in the parent gives the column by symmetry
        for (gj, val) in self.parent.row(v) {
            let lj = self.pos[gj];
            if lj != usize::MAX {
                col[lj] = val;
            }
        }
        col
    }
}

impl SymOp for SubmatrixView {
    fn dim(&self) -> usize {
        self.idx.len()
    }

    /// Charges the view's own index structures only: the parent kernel is
    /// shared by every view over it (and by the caller), so attributing
    /// its bytes to each view would multiply-count resident memory.
    fn nbytes(&self) -> usize {
        std::mem::size_of::<SubmatrixView>()
            + (self.idx.capacity() + self.pos.capacity()) * std::mem::size_of::<usize>()
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.idx.len());
        debug_assert_eq!(y.len(), self.idx.len());
        for (li, &gi) in self.idx.iter().enumerate() {
            let mut acc = 0.0;
            for (gj, v) in self.parent.row(gi) {
                let lj = self.pos[gj];
                if lj != usize::MAX {
                    acc += v * x[lj];
                }
            }
            y[li] = acc;
        }
    }

    fn diagonal(&self) -> Vec<f64> {
        self.idx.iter().map(|&g| self.parent.get(g, g)).collect()
    }

    /// Panel sweep through the parent rows: each parent nonzero visited
    /// once per sweep regardless of the lane count (the block-DPP hot
    /// path: scoring many candidates against one working set `Y`). Lane
    /// accumulation order matches the scalar [`SymOp::matvec`] exactly;
    /// the inner loop runs over fixed-width
    /// [`PANEL_PAD`](super::PANEL_PAD)-lane chunks so padded panel
    /// strides vectorize (see `Csr`'s `matvec_multi`).
    fn matvec_multi(&self, x: &[f64], y: &mut [f64], b: usize) {
        let k = self.idx.len();
        debug_assert_eq!(x.len(), k * b);
        debug_assert_eq!(y.len(), k * b);
        if b == 1 {
            return self.matvec(x, y);
        }
        for (li, &gi) in self.idx.iter().enumerate() {
            let yrow = &mut y[li * b..(li + 1) * b];
            yrow.fill(0.0);
            for (gj, v) in self.parent.row(gi) {
                let lj = self.pos[gj];
                if lj != usize::MAX {
                    let xrow = &x[lj * b..lj * b + b];
                    super::axpy_lanes(v, xrow, yrow);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::CsrBuilder;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;

    fn random_sym_csr(rng: &mut Rng, n: usize, density: f64) -> Csr {
        let mut b = CsrBuilder::new(n);
        for i in 0..n {
            b.push(i, i, 2.0 + rng.f64());
            for j in (i + 1)..n {
                if rng.bool(density) {
                    b.push_sym(i, j, rng.normal() * 0.1);
                }
            }
        }
        b.build()
    }

    #[test]
    fn view_matvec_matches_materialized() {
        forall(25, 0x5AB, |rng| {
            let n = 4 + rng.below(40);
            let a = Arc::new(random_sym_csr(rng, n, 0.3));
            let k = 1 + rng.below(n - 1);
            let idx = rng.sample_indices(n, k);
            let view = SubmatrixView::new(&a, &idx);
            let mat = a.principal_submatrix(&idx);
            let x: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let mut yv = vec![0.0; k];
            let mut ym = vec![0.0; k];
            view.matvec(&x, &mut yv);
            mat.matvec(&x, &mut ym);
            for (v, m) in yv.iter().zip(&ym) {
                assert_close(*v, *m, 1e-13, 1e-13);
            }
            assert_eq!(view.nnz(), mat.nnz());
            assert_eq!(view.diagonal(), mat.diagonal());
        });
    }

    #[test]
    fn view_matvec_multi_matches_scalar_lanes() {
        forall(25, 0x5AC, |rng| {
            let n = 4 + rng.below(40);
            let a = Arc::new(random_sym_csr(rng, n, 0.3));
            let k = 1 + rng.below(n - 1);
            let b = 1 + rng.below(7);
            let idx = rng.sample_indices(n, k);
            let view = SubmatrixView::new(&a, &idx);
            let x: Vec<f64> = (0..k * b).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; k * b];
            view.matvec_multi(&x, &mut y, b);
            let mut xs = vec![0.0; k];
            let mut ys = vec![0.0; k];
            for l in 0..b {
                for i in 0..k {
                    xs[i] = x[i * b + l];
                }
                view.matvec(&xs, &mut ys);
                for i in 0..k {
                    assert_eq!(y[i * b + l].to_bits(), ys[i].to_bits(), "lane {l} row {i}");
                }
            }
        });
    }

    #[test]
    fn column_of_matches_submatrix_column() {
        forall(25, 0xC01, |rng| {
            let n = 5 + rng.below(30);
            let a = Arc::new(random_sym_csr(rng, n, 0.4));
            let k = 1 + rng.below(n - 2);
            let idx = rng.sample_indices(n, k);
            // v outside the view (the DPP proposal)
            let v = (0..n).find(|i| !idx.contains(i)).unwrap();
            let view = SubmatrixView::new(&a, &idx);
            let col = view.column_of(v);
            for (li, &gi) in idx.iter().enumerate() {
                assert_close(col[li], a.get(gi, v), 0.0, 0.0);
            }
        });
    }

    #[test]
    fn local_ordering_follows_idx_order() {
        let mut b = CsrBuilder::new(3);
        b.push(0, 0, 1.0);
        b.push(1, 1, 2.0);
        b.push(2, 2, 3.0);
        let a = Arc::new(b.build());
        let view = SubmatrixView::new(&a, &[2, 0]);
        assert_eq!(view.diagonal(), vec![3.0, 1.0]);
    }
}
