//! Sparse substrate: CSR storage, the [`SymOp`] operator abstraction the
//! quadrature core iterates against, zero-copy principal-submatrix views,
//! and spectrum-bound estimators.
//!
//! Everything on the GQL hot path goes through [`SymOp::matvec`], so the
//! same quadrature code serves dense baselines, CSR matrices, and dynamic
//! submatrix views (the DPP/greedy working sets).

pub mod csr;
pub mod spectrum;
pub mod submatrix;

pub use csr::{Csr, CsrBuilder};

/// The shared per-nonzero panel update `yrow += v * xrow`, one entry per
/// lane: fixed-width 4-lane chunks (vectorizable when the caller pads the
/// panel stride to a multiple of 4, as `BlockGql` does) plus a scalar
/// remainder. Each lane accumulates independently and in caller order, so
/// using this helper cannot perturb the engines' per-lane bit-identity
/// contract — both specialized `matvec_multi` kernels call it, keeping
/// the accumulation pattern defined in exactly one place.
#[inline]
pub(crate) fn axpy_lanes(v: f64, xrow: &[f64], yrow: &mut [f64]) {
    debug_assert_eq!(xrow.len(), yrow.len());
    let mut yc = yrow.chunks_exact_mut(4);
    let mut xc = xrow.chunks_exact(4);
    for (y4, x4) in yc.by_ref().zip(xc.by_ref()) {
        y4[0] += v * x4[0];
        y4[1] += v * x4[1];
        y4[2] += v * x4[2];
        y4[3] += v * x4[3];
    }
    for (yl, &xl) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yl += v * xl;
    }
}
pub use spectrum::{
    gershgorin_bounds, gershgorin_view, lanczos_bounds, power_iteration_lmax, SpectrumBounds,
};
pub use submatrix::SubmatrixView;

/// A symmetric linear operator: the only interface the quadrature core
/// needs. `matvec` must compute `y = A x` with `A` symmetric.
///
/// `Send + Sync` are supertraits so operator handles can cross threads:
/// the multi-operator engine ([`crate::quadrature::engine`]) keeps
/// operators resident as `Arc<dyn SymOp>` entries in its
/// [`OpStore`](crate::quadrature::engine::OpStore) and sweeps their
/// panels from a pool of workers. Every implementor in the repo (CSR,
/// dense, submatrix views, the Jacobi preconditioner) is plain immutable
/// data during a matvec, so the bounds cost nothing.
pub trait SymOp: Send + Sync {
    fn dim(&self) -> usize;
    fn matvec(&self, x: &[f64], y: &mut [f64]);
    /// The diagonal of the operator (used by Jacobi preconditioning and
    /// Gershgorin bounds).
    fn diagonal(&self) -> Vec<f64>;

    /// Approximate resident size in bytes, used by the engine's operator
    /// store for LRU byte-budget accounting. The default charges one
    /// `f64` per dimension (a floor: any operator at least answers
    /// [`SymOp::diagonal`]); storage-backed implementors ([`Csr`],
    /// [`crate::linalg::DMat`]) override with their actual footprint.
    fn nbytes(&self) -> usize {
        self.dim() * std::mem::size_of::<f64>()
    }

    /// Multi-vector product `Y = A X` over an interleaved panel of `b`
    /// column vectors: `x[i * b + l]` is component `i` of lane `l`, and
    /// likewise for `y`. One panel sweep feeds every lane of a
    /// [`crate::quadrature::block::BlockGql`] run from a single traversal
    /// of the operator.
    ///
    /// The default implementation de-interleaves each lane and falls back
    /// to `b` scalar [`SymOp::matvec`] calls, so every existing operator
    /// keeps working; per-lane results are then *bit-identical* to the
    /// scalar path. Specialized impls ([`Csr`], [`SubmatrixView`]) stream
    /// the panel directly (a true spmm) while preserving the per-lane
    /// floating-point accumulation order of their scalar `matvec`.
    fn matvec_multi(&self, x: &[f64], y: &mut [f64], b: usize) {
        let n = self.dim();
        debug_assert_eq!(x.len(), n * b, "panel x shape");
        debug_assert_eq!(y.len(), n * b, "panel y shape");
        if b == 1 {
            return self.matvec(x, y);
        }
        let mut xs = vec![0.0; n];
        let mut ys = vec![0.0; n];
        for l in 0..b {
            for i in 0..n {
                xs[i] = x[i * b + l];
            }
            self.matvec(&xs, &mut ys);
            for i in 0..n {
                y[i * b + l] = ys[i];
            }
        }
    }
}
