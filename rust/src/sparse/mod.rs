//! Sparse substrate: CSR storage, the [`SymOp`] operator abstraction the
//! quadrature core iterates against, zero-copy principal-submatrix views,
//! and spectrum-bound estimators.
//!
//! Everything on the GQL hot path goes through [`SymOp::matvec`], so the
//! same quadrature code serves dense baselines, CSR matrices, and dynamic
//! submatrix views (the DPP/greedy working sets).

pub mod csr;
pub mod spectrum;
pub mod submatrix;

pub use csr::{Csr, CsrBuilder};
pub use spectrum::{
    gershgorin_bounds, gershgorin_view, lanczos_bounds, power_iteration_lmax, SpectrumBounds,
};
pub use submatrix::SubmatrixView;

/// A symmetric linear operator: the only interface the quadrature core
/// needs. `matvec` must compute `y = A x` with `A` symmetric.
pub trait SymOp {
    fn dim(&self) -> usize;
    fn matvec(&self, x: &[f64], y: &mut [f64]);
    /// The diagonal of the operator (used by Jacobi preconditioning and
    /// Gershgorin bounds).
    fn diagonal(&self) -> Vec<f64>;
}
