//! Sparse substrate: CSR storage, the [`SymOp`] operator abstraction the
//! quadrature core iterates against, zero-copy principal-submatrix views,
//! and spectrum-bound estimators.
//!
//! Everything on the GQL hot path goes through [`SymOp::matvec`], so the
//! same quadrature code serves dense baselines, CSR matrices, and dynamic
//! submatrix views (the DPP/greedy working sets).

pub mod csr;
pub mod spectrum;
pub mod submatrix;

pub use csr::{Csr, CsrBuilder};

/// The unified SIMD lane width of the panel kernels: strides padded to a
/// multiple of this (see `quadrature::block`'s `pad_stride`) let every
/// per-nonzero inner loop run over full fixed-width chunks of `f64`
/// lanes — eight per chunk, one AVX-512 register or two AVX2/NEON
/// registers, the width the shared `axpy_lanes` helper and the
/// register-tiled [`Csr`] `matvec_multi` accumulators are written
/// against.
///
/// **Contract for kernel authors:** a panel kernel may assume nothing
/// about alignment, but when the caller pads lane strides to a multiple
/// of `PANEL_PAD` (pad columns all-zero, carrying no lane) the chunked
/// fast path covers the whole row. Chunking must never reorder a lane's
/// accumulation: each lane sums its nonzeros in caller order,
/// independently — the bit-identity contract every block/engine property
/// test pins.
pub const PANEL_PAD: usize = 8;

/// The shared per-nonzero panel update `yrow += v * xrow`, one entry per
/// lane: fixed-width 8-lane chunks ([`PANEL_PAD`] — vectorizable when
/// the caller pads the panel stride, as `BlockGql` does), then one
/// 4-lane half-chunk (narrow compare/threshold panels), then a scalar
/// remainder. Each lane accumulates independently and in caller order,
/// so using this helper cannot perturb the engines' per-lane
/// bit-identity contract — the specialized `matvec_multi` kernels call
/// it, keeping the accumulation pattern defined in exactly one place.
#[inline]
pub(crate) fn axpy_lanes(v: f64, xrow: &[f64], yrow: &mut [f64]) {
    debug_assert_eq!(xrow.len(), yrow.len());
    let mut yc = yrow.chunks_exact_mut(PANEL_PAD);
    let mut xc = xrow.chunks_exact(PANEL_PAD);
    for (y8, x8) in yc.by_ref().zip(xc.by_ref()) {
        for (yl, &xl) in y8.iter_mut().zip(x8) {
            *yl += v * xl;
        }
    }
    let yr = yc.into_remainder();
    let xr = xc.remainder();
    let mut yh = yr.chunks_exact_mut(4);
    let mut xh = xr.chunks_exact(4);
    for (y4, x4) in yh.by_ref().zip(xh.by_ref()) {
        for (yl, &xl) in y4.iter_mut().zip(x4) {
            *yl += v * xl;
        }
    }
    for (yl, &xl) in yh.into_remainder().iter_mut().zip(xh.remainder()) {
        *yl += v * xl;
    }
}

/// The PR-3 fixed-width 4-lane reference kernel, kept public (but hidden
/// from docs) so the kernel benches can measure the widened
/// `axpy_lanes` against the exact code it replaced and the tests can
/// assert the two stay bit-identical (both sum per lane in caller
/// order, so chunk width cannot change a result bit).
#[doc(hidden)]
#[inline]
pub fn axpy_lanes_ref4(v: f64, xrow: &[f64], yrow: &mut [f64]) {
    debug_assert_eq!(xrow.len(), yrow.len());
    let mut yc = yrow.chunks_exact_mut(4);
    let mut xc = xrow.chunks_exact(4);
    for (y4, x4) in yc.by_ref().zip(xc.by_ref()) {
        y4[0] += v * x4[0];
        y4[1] += v * x4[1];
        y4[2] += v * x4[2];
        y4[3] += v * x4[3];
    }
    for (yl, &xl) in yc.into_remainder().iter_mut().zip(xc.remainder()) {
        *yl += v * xl;
    }
}
pub use spectrum::{
    gershgorin_bounds, gershgorin_view, lanczos_bounds, power_iteration_lmax, SpectrumBounds,
};
pub use submatrix::SubmatrixView;

/// A symmetric linear operator: the only interface the quadrature core
/// needs. `matvec` must compute `y = A x` with `A` symmetric.
///
/// `Send + Sync` are supertraits so operator handles can cross threads:
/// the multi-operator engine ([`crate::quadrature::engine`]) keeps
/// operators resident as `Arc<dyn SymOp>` entries in its
/// [`OpStore`](crate::quadrature::engine::OpStore) and sweeps their
/// panels from a pool of workers. Every implementor in the repo (CSR,
/// dense, submatrix views, the Jacobi preconditioner) is plain immutable
/// data during a matvec, so the bounds cost nothing.
pub trait SymOp: Send + Sync {
    fn dim(&self) -> usize;
    fn matvec(&self, x: &[f64], y: &mut [f64]);
    /// The diagonal of the operator (used by Jacobi preconditioning and
    /// Gershgorin bounds).
    fn diagonal(&self) -> Vec<f64>;

    /// Approximate resident size in bytes, used by the engine's operator
    /// store for LRU byte-budget accounting. The default charges one
    /// `f64` per dimension (a floor: any operator at least answers
    /// [`SymOp::diagonal`]); storage-backed implementors ([`Csr`],
    /// [`crate::linalg::DMat`]) override with their actual footprint.
    fn nbytes(&self) -> usize {
        self.dim() * std::mem::size_of::<f64>()
    }

    /// Multi-vector product `Y = A X` over an interleaved panel of `b`
    /// column vectors: `x[i * b + l]` is component `i` of lane `l`, and
    /// likewise for `y`. One panel sweep feeds every lane of a
    /// [`crate::quadrature::block::BlockGql`] run from a single traversal
    /// of the operator.
    ///
    /// The default implementation de-interleaves each lane and falls back
    /// to `b` scalar [`SymOp::matvec`] calls, so every existing operator
    /// keeps working; per-lane results are then *bit-identical* to the
    /// scalar path. Specialized impls ([`Csr`], [`SubmatrixView`]) stream
    /// the panel directly (a true spmm) while preserving the per-lane
    /// floating-point accumulation order of their scalar `matvec`.
    fn matvec_multi(&self, x: &[f64], y: &mut [f64], b: usize) {
        let n = self.dim();
        debug_assert_eq!(x.len(), n * b, "panel x shape");
        debug_assert_eq!(y.len(), n * b, "panel y shape");
        if b == 1 {
            return self.matvec(x, y);
        }
        let mut xs = vec![0.0; n];
        let mut ys = vec![0.0; n];
        for l in 0..b {
            for i in 0..n {
                xs[i] = x[i * b + l];
            }
            self.matvec(&xs, &mut ys);
            for i in 0..n {
                y[i * b + l] = ys[i];
            }
        }
    }
}
