//! Compressed sparse row matrix, built via COO accumulation.
//!
//! Only what the paper needs: symmetric matrices, matvec, principal
//! submatrix extraction, row access for kernel columns, density stats.

use super::{PANEL_PAD, SymOp};

/// Rows swept together per column block in the cache-blocked panel
/// traversal, so the tile's `y` rows stay L1-resident while a column
/// window of `x` is reused across all of them.
const TILE_ROWS: usize = 32;

/// `f64` budget for one column window of the `x` panel in the blocked
/// traversal (~192 KiB — about half a typical L2), so every per-nonzero
/// gather lands in a cache-resident window.
const BLOCK_X_F64S: usize = 24 * 1024;

/// The blocked traversal only pays once the whole interleaved `x` panel
/// (`n * b` f64s) well exceeds the cache; below this the streaming path
/// wins and the cursor bookkeeping is pure overhead.
const BLOCK_MIN_PANEL_F64S: usize = 4 * BLOCK_X_F64S;

/// CSR sparse matrix (f64 values, usize indices).
#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    /// row i occupies indices row_ptr[i]..row_ptr[i+1]
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f64>,
    /// True when every row's columns are ascending ([`CsrBuilder::build`]
    /// output always is; [`SubmatrixView::to_csr`](super::SubmatrixView)
    /// computes it from the view ordering). Gates the cache-blocked
    /// `matvec_multi` traversal, which consumes each row's nonzeros
    /// through a monotone column cursor — any site constructing a `Csr`
    /// literally must keep this consistent with `col_idx`.
    pub cols_sorted: bool,
}

/// COO accumulator; duplicate (i, j) entries are summed on build.
#[derive(Debug, Default)]
pub struct CsrBuilder {
    n: usize,
    entries: Vec<(usize, usize, f64)>,
}

impl CsrBuilder {
    pub fn new(n: usize) -> Self {
        CsrBuilder { n, entries: Vec::new() }
    }

    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n && j < self.n);
        if v != 0.0 {
            self.entries.push((i, j, v));
        }
    }

    /// Push both (i, j) and (j, i) (off-diagonal symmetric pair).
    pub fn push_sym(&mut self, i: usize, j: usize, v: f64) {
        self.push(i, j, v);
        if i != j {
            self.push(j, i, v);
        }
    }

    pub fn build(mut self) -> Csr {
        self.entries.sort_unstable_by_key(|&(i, j, _)| (i, j));
        let mut row_ptr = vec![0usize; self.n + 1];
        let mut col_idx = Vec::with_capacity(self.entries.len());
        let mut values: Vec<f64> = Vec::with_capacity(self.entries.len());
        let mut last: Option<(usize, usize)> = None;
        for &(i, j, v) in &self.entries {
            if last == Some((i, j)) {
                *values.last_mut().unwrap() += v; // merge duplicate
                continue;
            }
            col_idx.push(j);
            values.push(v);
            row_ptr[i + 1] += 1;
            last = Some((i, j));
        }
        for i in 0..self.n {
            row_ptr[i + 1] += row_ptr[i];
        }
        // entries were sorted by (i, j) and duplicates merged, so each
        // row's columns are strictly ascending
        Csr { n: self.n, row_ptr, col_idx, values, cols_sorted: true }
    }
}

impl Csr {
    /// Identity * s.
    pub fn scaled_identity(n: usize, s: f64) -> Csr {
        let mut b = CsrBuilder::new(n);
        for i in 0..n {
            b.push(i, i, s);
        }
        b.build()
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn density(&self) -> f64 {
        self.nnz() as f64 / (self.n as f64 * self.n as f64)
    }

    /// entries of row i as (col, value) pairs
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let lo = self.row_ptr[i];
        let hi = self.row_ptr[i + 1];
        self.col_idx[lo..hi]
            .iter()
            .copied()
            .zip(self.values[lo..hi].iter().copied())
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.row(i).find(|&(c, _)| c == j).map_or(0.0, |(_, v)| v)
    }

    /// A += s * I (requires all diagonal entries present; use
    /// `with_diag_shift` otherwise).
    pub fn with_diag_shift(&self, s: f64) -> Csr {
        let mut b = CsrBuilder::new(self.n);
        for i in 0..self.n {
            for (j, v) in self.row(i) {
                b.push(i, j, v);
            }
            b.push(i, i, s);
        }
        b.build()
    }

    /// Materialize the principal submatrix A[idx, idx] as CSR.
    /// `idx` must be strictly increasing? No — any order; output uses the
    /// given local ordering. O(Σ nnz(row)) with a scatter map.
    pub fn principal_submatrix(&self, idx: &[usize]) -> Csr {
        let mut pos = vec![usize::MAX; self.n];
        for (local, &g) in idx.iter().enumerate() {
            pos[g] = local;
        }
        let mut b = CsrBuilder::new(idx.len());
        for (li, &gi) in idx.iter().enumerate() {
            for (gj, v) in self.row(gi) {
                let lj = pos[gj];
                if lj != usize::MAX {
                    b.push(li, lj, v);
                }
            }
        }
        b.build()
    }

    /// Dense copy (tests / small baselines only).
    pub fn to_dense(&self) -> crate::linalg::DMat {
        let mut m = crate::linalg::DMat::zeros(self.n, self.n);
        for i in 0..self.n {
            for (j, v) in self.row(i) {
                m.set(i, j, v);
            }
        }
        m
    }

    /// Max |A - A^T| entry (symmetry check).
    pub fn asymmetry(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for i in 0..self.n {
            for (j, v) in self.row(i) {
                worst = worst.max((v - self.get(j, i)).abs());
            }
        }
        worst
    }
}

impl SymOp for Csr {
    fn dim(&self) -> usize {
        self.n
    }

    fn matvec(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        debug_assert_eq!(y.len(), self.n);
        for i in 0..self.n {
            let lo = self.row_ptr[i];
            let hi = self.row_ptr[i + 1];
            let mut acc = 0.0;
            for k in lo..hi {
                acc += self.values[k] * x[self.col_idx[k]];
            }
            y[i] = acc;
        }
    }

    fn diagonal(&self) -> Vec<f64> {
        (0..self.n).map(|i| self.get(i, i)).collect()
    }

    fn nbytes(&self) -> usize {
        std::mem::size_of::<Csr>()
            + self.row_ptr.capacity() * std::mem::size_of::<usize>()
            + self.col_idx.capacity() * std::mem::size_of::<usize>()
            + self.values.capacity() * std::mem::size_of::<f64>()
    }

    /// True spmm over an interleaved panel: one CSR traversal feeds all
    /// `b` lanes, turning `b` row-value loads into one load reused across
    /// a contiguous lane row (the cache win `quadrature::block` is built
    /// on). Per lane the nonzeros are accumulated in the same order as
    /// the scalar [`SymOp::matvec`], so lane results are bit-identical to
    /// `b` independent matvecs.
    ///
    /// The inner kernel is register-tiled: per row, each
    /// [`PANEL_PAD`]-lane chunk accumulates the whole row's nonzeros in a
    /// stack array before storing once, so the hot loop is pure
    /// load/FMA with no store traffic — when the caller pads the panel
    /// stride to a multiple of [`PANEL_PAD`], as `BlockGql` does, every
    /// chunk is full-width and vectorizes. For panels far beyond cache
    /// (and ascending [`Csr::cols_sorted`] columns) the traversal
    /// additionally walks `x` in cache-sized column windows. Neither
    /// tiling nor blocking reorders a lane's accumulation: each lane
    /// still sums its nonzeros in CSR order, independently.
    fn matvec_multi(&self, x: &[f64], y: &mut [f64], b: usize) {
        debug_assert_eq!(x.len(), self.n * b);
        debug_assert_eq!(y.len(), self.n * b);
        if b == 1 {
            return self.matvec(x, y);
        }
        if self.cols_sorted && x.len() >= BLOCK_MIN_PANEL_F64S {
            return self.matvec_multi_blocked(x, y, b);
        }
        for i in 0..self.n {
            let yrow = &mut y[i * b..(i + 1) * b];
            yrow.fill(0.0);
            self.row_panel_acc(x, yrow, b, self.row_ptr[i], self.row_ptr[i + 1]);
        }
    }
}

impl Csr {
    /// Register-tiled row kernel: accumulate nonzeros `lo..hi` of one row
    /// into `yrow`, per [`PANEL_PAD`]-lane chunk, through a stack
    /// accumulator seeded from `yrow` and stored back once. Seeding from
    /// `yrow` (rather than zero) makes the per-lane floating-point add
    /// sequence identical to in-place `yrow[l] += v * x[..]` updates in
    /// `k` order, so callers may split a row across several calls (the
    /// blocked traversal does) without changing a result bit.
    #[inline]
    fn row_panel_acc(&self, x: &[f64], yrow: &mut [f64], b: usize, lo: usize, hi: usize) {
        let mut c = 0usize;
        while c + PANEL_PAD <= b {
            let mut acc = [0.0f64; PANEL_PAD];
            acc.copy_from_slice(&yrow[c..c + PANEL_PAD]);
            for k in lo..hi {
                let v = self.values[k];
                let base = self.col_idx[k] * b + c;
                for (a, &xv) in acc.iter_mut().zip(&x[base..base + PANEL_PAD]) {
                    *a += v * xv;
                }
            }
            yrow[c..c + PANEL_PAD].copy_from_slice(&acc);
            c += PANEL_PAD;
        }
        if c < b {
            let w = b - c;
            let mut acc = [0.0f64; PANEL_PAD];
            acc[..w].copy_from_slice(&yrow[c..b]);
            for k in lo..hi {
                let v = self.values[k];
                let base = self.col_idx[k] * b + c;
                for (a, &xv) in acc[..w].iter_mut().zip(&x[base..base + w]) {
                    *a += v * xv;
                }
            }
            yrow[c..b].copy_from_slice(&acc[..w]);
        }
    }

    /// Cache-blocked panel traversal for `x` panels far beyond cache:
    /// sweep [`TILE_ROWS`] rows at a time through ascending column
    /// windows of [`BLOCK_X_F64S`] panel floats, consuming each row's
    /// nonzeros through a monotone cursor (correct because
    /// [`Csr::cols_sorted`] guarantees ascending columns per row). Every
    /// window's gathers then hit a cache-resident slice of `x` instead
    /// of striding the whole panel once per row. Per lane the adds still
    /// land in CSR order — [`Csr::row_panel_acc`] seeds its accumulator
    /// from `y` — so the result is bit-identical to the streaming path.
    fn matvec_multi_blocked(&self, x: &[f64], y: &mut [f64], b: usize) {
        debug_assert!(self.cols_sorted, "blocked traversal needs ascending columns");
        let n = self.n;
        let block_cols = (BLOCK_X_F64S / b).max(1);
        let mut cursor = [0usize; TILE_ROWS];
        let mut r0 = 0usize;
        while r0 < n {
            let r1 = (r0 + TILE_ROWS).min(n);
            y[r0 * b..r1 * b].fill(0.0);
            for (c, r) in cursor.iter_mut().zip(r0..r1) {
                *c = self.row_ptr[r];
            }
            let mut col0 = 0usize;
            while col0 < n {
                let col_end = (col0 + block_cols).min(n);
                for r in r0..r1 {
                    let lo = cursor[r - r0];
                    let hi = self.row_ptr[r + 1];
                    let mut k = lo;
                    while k < hi && self.col_idx[k] < col_end {
                        k += 1;
                    }
                    if k > lo {
                        self.row_panel_acc(x, &mut y[r * b..(r + 1) * b], b, lo, k);
                        cursor[r - r0] = k;
                    }
                }
                col0 = col_end;
            }
            r0 = r1;
        }
    }

    /// The pre-widening panel kernel (fixed 4-lane chunks, in-place `y`
    /// updates), kept public (hidden from docs) so `bench_block` can
    /// measure the register-tiled [`SymOp::matvec_multi`] against the
    /// exact code it replaced, and tests can pin bit-identity between
    /// the two.
    #[doc(hidden)]
    pub fn matvec_multi_ref4(&self, x: &[f64], y: &mut [f64], b: usize) {
        debug_assert_eq!(x.len(), self.n * b);
        debug_assert_eq!(y.len(), self.n * b);
        if b == 1 {
            return self.matvec(x, y);
        }
        for i in 0..self.n {
            let yrow = &mut y[i * b..(i + 1) * b];
            yrow.fill(0.0);
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                let v = self.values[k];
                let xrow = &x[self.col_idx[k] * b..self.col_idx[k] * b + b];
                super::axpy_lanes_ref4(v, xrow, yrow);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::SubmatrixView;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;
    use std::sync::Arc;

    pub fn random_sym_csr(rng: &mut Rng, n: usize, density: f64) -> Csr {
        let mut b = CsrBuilder::new(n);
        for i in 0..n {
            b.push(i, i, 2.0 + rng.f64());
            for j in (i + 1)..n {
                if rng.bool(density) {
                    b.push_sym(i, j, rng.normal() * 0.1);
                }
            }
        }
        b.build()
    }

    #[test]
    fn builder_sums_duplicates() {
        let mut b = CsrBuilder::new(2);
        b.push(0, 1, 1.0);
        b.push(0, 1, 2.0);
        b.push(1, 0, 3.0);
        let m = b.build();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 0), 3.0);
    }

    #[test]
    fn builder_drops_explicit_zeros() {
        let mut b = CsrBuilder::new(2);
        b.push(0, 0, 0.0);
        b.push(1, 1, 5.0);
        let m = b.build();
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn matvec_matches_dense() {
        forall(25, 0xC5A, |rng| {
            let n = 1 + rng.below(40);
            let a = random_sym_csr(rng, n, 0.3);
            let d = a.to_dense();
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut ys = vec![0.0; n];
            let mut yd = vec![0.0; n];
            a.matvec(&x, &mut ys);
            d.matvec(&x, &mut yd);
            for (s, dd) in ys.iter().zip(&yd) {
                assert_close(*s, *dd, 1e-12, 1e-12);
            }
        });
    }

    #[test]
    fn matvec_multi_is_bit_identical_to_scalar_lanes() {
        forall(25, 0xC5B, |rng| {
            let n = 1 + rng.below(40);
            let b = 1 + rng.below(9);
            let a = random_sym_csr(rng, n, 0.3);
            // interleaved panel [i * b + l]
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let mut y = vec![0.0; n * b];
            a.matvec_multi(&x, &mut y, b);
            let mut xs = vec![0.0; n];
            let mut ys = vec![0.0; n];
            for l in 0..b {
                for i in 0..n {
                    xs[i] = x[i * b + l];
                }
                a.matvec(&xs, &mut ys);
                for i in 0..n {
                    assert_eq!(
                        y[i * b + l].to_bits(),
                        ys[i].to_bits(),
                        "lane {l} row {i} not bit-identical"
                    );
                }
            }
        });
    }

    #[test]
    fn submatrix_matches_dense_submatrix() {
        forall(25, 0x5b5, |rng| {
            let n = 4 + rng.below(30);
            let a = random_sym_csr(rng, n, 0.4);
            let k = 1 + rng.below(n - 1);
            let idx = rng.sample_indices(n, k);
            let sub = a.principal_submatrix(&idx);
            let want = a.to_dense().principal_submatrix(&idx);
            assert_eq!(sub.n, k);
            for i in 0..k {
                for j in 0..k {
                    assert_close(sub.get(i, j), want.get(i, j), 0.0, 0.0);
                }
            }
        });
    }

    #[test]
    fn diag_shift() {
        let mut b = CsrBuilder::new(3);
        b.push(0, 0, 1.0);
        b.push_sym(0, 2, 4.0);
        let m = b.build().with_diag_shift(1e-3);
        assert_close(m.get(0, 0), 1.001, 1e-15, 0.0);
        assert_close(m.get(1, 1), 1e-3, 1e-15, 0.0);
        assert_close(m.get(2, 2), 1e-3, 1e-15, 0.0);
        assert_eq!(m.get(0, 2), 4.0);
    }

    #[test]
    fn symmetry_of_random_generator() {
        let mut rng = Rng::new(5);
        let a = random_sym_csr(&mut rng, 30, 0.2);
        assert_eq!(a.asymmetry(), 0.0);
    }

    #[test]
    fn density_and_nnz() {
        let m = Csr::scaled_identity(10, 2.0);
        assert_eq!(m.nnz(), 10);
        assert_close(m.density(), 0.1, 1e-15, 0.0);
        let mut y = vec![0.0; 10];
        m.matvec(&vec![1.0; 10], &mut y);
        assert!(y.iter().all(|&v| v == 2.0));
    }

    #[test]
    fn blocked_traversal_is_bit_identical_to_streaming() {
        // the public dispatch only takes the blocked path for panels of
        // >= BLOCK_MIN_PANEL_F64S floats, far beyond what the property
        // tests build — so pin its bit-identity by calling it directly,
        // on a matrix wide enough (n > BLOCK_X_F64S / b) that the
        // traversal crosses several column windows and the per-row
        // cursors genuinely split rows mid-stream (the long-range
        // couplings below guarantee rows span windows)
        let n = 7000;
        let mut rng = Rng::new(0xB10C7);
        let mut bld = CsrBuilder::new(n);
        for i in 0..n {
            bld.push(i, i, 4.0 + rng.f64());
            for d in 1..=3usize {
                if i + d < n {
                    bld.push_sym(i, i + d, rng.normal() * 0.1);
                }
            }
            if i + n / 2 < n {
                bld.push_sym(i, i + n / 2, rng.normal() * 0.05);
            }
        }
        let a = bld.build();
        assert!(a.cols_sorted);
        // b = 8 gives full-width chunks over 3 column windows; b = 5
        // exercises the 4-lane half-chunk + scalar tail over 2 windows
        for b in [5usize, 8] {
            assert!(n > BLOCK_X_F64S / b, "b={b}: single column window, test is vacuous");
            let x: Vec<f64> = (0..n * b).map(|_| rng.normal()).collect();
            let mut y_stream = vec![0.0; n * b];
            assert!(x.len() < BLOCK_MIN_PANEL_F64S, "dispatch would already go blocked");
            a.matvec_multi(&x, &mut y_stream, b);
            let mut y_blocked = vec![f64::NAN; n * b]; // blocked path must overwrite every slot
            a.matvec_multi_blocked(&x, &mut y_blocked, b);
            for k in 0..n * b {
                assert_eq!(y_blocked[k].to_bits(), y_stream[k].to_bits(), "b={b} panel slot {k}");
            }
        }
    }

    #[test]
    fn column_sortedness_is_tracked_through_construction() {
        // dense asymmetric parent so every view row keeps several entries
        // and the flag outcome is deterministic
        let mut bld = CsrBuilder::new(4);
        for i in 0..4 {
            for j in 0..4 {
                bld.push(i, j, (i * 4 + j + 1) as f64);
            }
        }
        let a = Arc::new(bld.build());
        assert!(a.cols_sorted, "builder output always has ascending columns");
        assert!(a.principal_submatrix(&[2, 0, 3]).cols_sorted, "rebuilt submatrix is re-sorted");
        let idx = [2usize, 0, 1];
        assert!(SubmatrixView::new_sorted(&a, &idx).to_csr().cols_sorted);
        // unsorted local ordering relabels ascending parent columns
        // non-monotonically: global (0, 1, 2) -> local (1, 2, 0)
        assert!(!SubmatrixView::new(&a, &idx).to_csr().cols_sorted);
    }

    #[test]
    fn empty_rows_are_fine() {
        let mut b = CsrBuilder::new(4);
        b.push(0, 0, 1.0);
        b.push(3, 3, 1.0);
        let m = b.build();
        let mut y = vec![0.0; 4];
        m.matvec(&[1.0, 1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 1.0]);
    }
}
