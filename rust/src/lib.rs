//! # gauss-bif
//!
//! Production reproduction of *"Gauss quadrature for matrix inverse forms
//! with applications"* (Li, Sra, Jegelka): iteratively tightening lower and
//! upper bounds on bilinear inverse forms `u^T A^{-1} u` via Gauss-type
//! quadrature (GQL), and the retrospective framework that accelerates
//! DPP / k-DPP Markov-chain sampling and double-greedy submodular
//! maximization.
//!
//! Layout (three-layer architecture):
//! * [`sparse`], [`linalg`], [`datasets`] — substrates (CSR, dense Cholesky,
//!   synthetic dataset builders).
//! * [`quadrature`] — the paper's core: GQL (Alg. 5), the unified query
//!   planner (`Session`: mixed estimate/threshold/compare/argmax queries
//!   compiled onto shared panels), retrospective judges (Alg. 4/7/9), CG,
//!   preconditioning.
//! * [`apps`] — DPP, k-DPP, double greedy, centrality: exact baselines and
//!   quadrature-accelerated variants.
//! * [`runtime`] — PJRT loader/executor for the AOT JAX+Pallas artifacts.
//! * [`coordinator`] — the serving layer: router + dynamic batcher +
//!   retrospective judge service.
//! * [`metrics`], [`config`] — observability and run configuration.

pub mod apps;
pub mod config;
pub mod coordinator;
pub mod datasets;
pub mod experiments;
pub mod linalg;
pub mod metrics;
pub mod quadrature;
pub mod runtime;
pub mod sparse;
pub mod util;
