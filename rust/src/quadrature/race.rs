//! Bound-driven racing: argmax and comparison decisions from iteratively
//! tightening brackets.
//!
//! The paper's bounds *tighten iteratively* (Thm. 3.3–3.4): after every
//! quadrature step each candidate's value is bracketed, and the brackets
//! only shrink. That means a surrounding decision — "which candidate is
//! the argmax?", "does the double-greedy inequality hold?" — is often
//! determined long before every bracket reaches its stop tolerance. This
//! module spends quadrature only where the decision still needs it (the
//! same lazy-evaluation pattern as the adaptive truncation in Pleiss
//! et al., arXiv:2006.11267):
//!
//! * **Argmax mode** ([`Race`]): since ISSUE 4 a thin wrapper over the
//!   unified planner — one [`Session`] carrying a single
//!   [`Query::Argmax`]. Dominated arms are evicted after every panel
//!   sweep and the race ends the moment a single possible winner remains;
//!   the scheduling machinery (shared panels, retire/refill, adaptive
//!   dominance margin) lives in [`crate::quadrature::query`].
//! * **Comparison mode** ([`race_dg`]): the paired Δ⁺/Δ⁻ lanes of the
//!   double-greedy inclusion test — two *different* operators, so the
//!   sides cannot share one panel; each runs as a width-1 session
//!   (bit-identical to a scalar [`Gql`](super::Gql) run by the engine's
//!   exactness contract) and the race stops the moment the log-gap
//!   brackets separate, or — under [`RacePolicy::Exhaustive`] — refines
//!   both sides to exhaustion/budget first and decides identically from
//!   the final brackets.
//!
//! **Selection identity.** Pruning only ever discards *dominated* arms:
//! an arm is evicted when its current upper bound sits strictly (by the
//! session's [`prune margin`](Session::prune_margin), floored at
//! [`PRUNE_MARGIN`]) below another arm's current lower bound. Because
//! brackets are nested over iterations, the evicted arm's final estimate
//! would have stayed strictly below that rival's final estimate, so the
//! argmax over the survivors equals the argmax over all arms —
//! [`RacePolicy::Prune`] and [`RacePolicy::Exhaustive`] select
//! *identically* (property-tested in `rust/tests/prop_race.rs` and
//! `rust/tests/prop_session.rs`); only the number of panel sweeps
//! differs.

use super::block::StopRule;
use super::gql::GqlOptions;
use super::is_zero;
use super::judge::{JudgeOutcome, JudgeStats};
use super::query::{Answer, Query, QueryArm, Session};
use crate::sparse::SymOp;

/// Whether a race may evict dominated arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RacePolicy {
    /// Run every arm to its own stop rule and only then compare — the
    /// pre-racing behavior, kept as the reference arm of every property
    /// test and the `race` experiment.
    Exhaustive,
    /// Evict dominated arms after every panel sweep and stop as soon as
    /// the decision is determined. Selections are identical to
    /// `Exhaustive`; sweeps are not.
    Prune,
}

/// Fixed floor of the dominance safety margin, relative to the magnitudes
/// involved: floating-point bound sequences obey the paper's monotonicity
/// only to rounding error, so an arm is only evicted when its upper bound
/// is *clearly* below the best lower bound. The planner scales this floor
/// up with the worst bracket wiggle it actually observes
/// ([`Session::prune_margin`]) — the ROADMAP "adaptive PRUNE_MARGIN"
/// item — so noisy runs get proportionally more protection while
/// well-behaved runs keep this tight default.
pub const PRUNE_MARGIN: f64 = 1e-9;

/// Accounting for one race.
#[derive(Clone, Debug, Default)]
pub struct RaceStats {
    /// `matvec_multi` panel sweeps actually performed.
    pub sweeps: usize,
    /// Number of arms entered.
    pub arms: usize,
    /// Arms evicted by dominance, as `(arm index, iteration at eviction)`
    /// — finished arms that later became dominated report their final
    /// iteration count.
    pub pruned_at: Vec<(usize, usize)>,
    /// True when the race ended before every surviving arm reached its
    /// stop rule (a lone possible winner remained).
    pub decided_early: bool,
}

impl RaceStats {
    /// Arms evicted by dominance.
    pub fn pruned(&self) -> usize {
        self.pruned_at.len()
    }
}

/// Result of an argmax race.
#[derive(Clone, Debug)]
pub struct RaceOutcome {
    /// Index (push order) of the winning arm; `None` when every arm's
    /// value fell at or below the `floor` passed to [`Race::run`].
    pub winner: Option<usize>,
    /// Per-arm value estimates: `Some` for arms that reached their stop
    /// rule (and for a winner crowned early, whose entry holds its
    /// current bracket midpoint), `None` for pruned arms.
    pub estimates: Vec<Option<f64>>,
    pub stats: RaceStats,
}

/// An argmax race over one shared operator: push arms, then [`Race::run`].
///
/// Each arm `i` is a query vector `u_i` with an affine value
/// `offset_i + scale_i · u_i^T A^{-1} u_i`; the race finds the arm with
/// the largest value. DPP greedy uses `offset = L_cc, scale = −1` (the
/// marginal-gain bracket); plain "largest BIF" callers use
/// `offset = 0, scale = 1`.
///
/// This type is a compatibility wrapper: it compiles its arms into a
/// single [`Query::Argmax`] on a [`Session`]. New code that mixes argmax
/// traffic with thresholds or comparisons on the same operator should use
/// the session directly — co-keyed queries then share panel sweeps.
pub struct Race<'a> {
    op: &'a dyn SymOp,
    session: Session,
    arms: Vec<QueryArm>,
}

impl<'a> Race<'a> {
    /// A race over `op` scored through a width-`width` panel. `opts` and
    /// `width` behave exactly as in
    /// [`BlockGql::new`](super::block::BlockGql::new).
    pub fn new(op: &'a dyn SymOp, opts: GqlOptions, width: usize, policy: RacePolicy) -> Self {
        Race { op, session: Session::new(op, opts, width, policy), arms: Vec::new() }
    }

    /// Enter an arm; returns its index (push order). `stop` is the arm's
    /// own refinement limit — the bracket tolerance it runs to when the
    /// race does not prune it first.
    pub fn push_arm(&mut self, u: &[f64], stop: StopRule, offset: f64, scale: f64) -> usize {
        self.arms.push(QueryArm { u: u.to_vec(), stop, offset, scale });
        self.arms.len() - 1
    }

    /// Number of arms entered so far.
    pub fn arms(&self) -> usize {
        self.arms.len()
    }

    /// Run the race to its decision.
    ///
    /// `floor`: optional minimum useful value (DPP greedy's PD gain
    /// floor). Arms whose upper bound falls below it are pruned like any
    /// dominated arm, and the returned `winner` is `None` unless the
    /// winning arm's value strictly exceeds the floor — the same strict
    /// comparison the exhaustive scoring loop applies.
    pub fn run(mut self, floor: Option<f64>) -> RaceOutcome {
        let arms = std::mem::take(&mut self.arms);
        let qid = self.session.submit(Query::Argmax { arms, floor });
        let mut answers = self.session.run(self.op);
        match answers.swap_remove(qid) {
            Answer::Argmax { winner, estimates, stats } => {
                RaceOutcome { winner, estimates, stats }
            }
            _ => unreachable!("argmax queries answer with argmax answers"),
        }
    }
}

// ---------------------------------------------------------------------------
// Comparison mode: the double-greedy inclusion race (paper Alg. 9)
// ---------------------------------------------------------------------------

/// Bracket for `log(t − bif)` given BIF bounds `[lo, hi]`; −∞ when the
/// argument is non-positive (degenerate gain; `[x]₊` clamps it later).
fn log_gap_bracket(t: f64, bif_lo: f64, bif_hi: f64) -> (f64, f64) {
    let lo_arg = t - bif_hi;
    let hi_arg = t - bif_lo;
    let lo = if lo_arg > 0.0 { lo_arg.ln() } else { f64::NEG_INFINITY };
    let hi = if hi_arg > 0.0 { hi_arg.ln() } else { f64::NEG_INFINITY };
    (lo, hi)
}

#[inline]
fn pos(x: f64) -> f64 {
    x.max(0.0)
}

/// One side of the double-greedy race: a width-1 session holding a single
/// estimate query, stepped one quadrature iteration at a time. The lane
/// is bit-identical to a scalar [`Gql`](super::Gql) run by the engine's
/// exactness contract, so routing the race through the planner changes no
/// numerics.
struct DgSide<'a> {
    op: &'a dyn SymOp,
    session: Session,
    qid: usize,
    /// Iteration budget, clamped like the engines clamp it.
    max_iters: usize,
}

impl<'a> DgSide<'a> {
    fn new(pair: Option<(&'a dyn SymOp, &'a [f64])>, opts: GqlOptions) -> Option<Self> {
        let (op, u) = pair?;
        if is_zero(u) {
            // zero query ⇒ BIF = 0 exactly; treated as an absent side
            return None;
        }
        let max_iters = opts.max_iters.min(op.dim()).max(1);
        let mut session = Session::new(op, opts, 1, RacePolicy::Prune);
        let qid = session.submit(Query::Estimate { u: u.to_vec(), stop: StopRule::Exhaust });
        Some(DgSide { op, session, qid, max_iters })
    }

    /// Advance one quadrature iteration and return the updated bounds
    /// (post-exhaustion steps are no-ops that keep the final bounds).
    fn step(&mut self) -> super::gql::Bounds {
        self.session.step(self.op);
        self.session.bounds(self.qid).expect("stepped lane has bounds")
    }
}

/// Double-greedy inclusion test as a two-arm comparison race (paper
/// Alg. 9): with Δ⁺ = log(l_ii − u_x^T L_X^{-1} u_x) and
/// Δ⁻ = −log(l_ii − u_y^T L_{Y'}^{-1} u_y), returns true (add `i` to X)
/// iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊`.
///
/// The two BIFs live on *different* operators (`L_X` and `L_{Y'}`), so
/// they cannot share a panel; each side runs as a width-1 [`Session`]
/// and the §5.2 refinement tightens whichever side contributes the larger
/// weighted log-gap bracket.
///
/// Under [`RacePolicy::Prune`] the race stops the moment the two log-gap
/// brackets separate — the retrospective behavior
/// [`crate::quadrature::judge_dg`] has always had. Under
/// [`RacePolicy::Exhaustive`] both quadratures refine to
/// exhaustion/budget first and the decision falls out of the final
/// brackets; because certified separations only ever tighten, the two
/// policies decide identically (property-tested), differing only in
/// `JudgeStats::iters`.
///
/// `ops` may be `None` when the corresponding set is empty (Δ then
/// depends on `l_ii` alone and is exact).
pub fn race_dg(
    op_x: Option<(&dyn SymOp, &[f64])>,
    op_y: Option<(&dyn SymOp, &[f64])>,
    l_ii: f64,
    p: f64,
    opts_x: GqlOptions,
    opts_y: GqlOptions,
    policy: RacePolicy,
) -> (bool, JudgeStats) {
    let mut qx = DgSide::new(op_x, opts_x);
    let mut qy = DgSide::new(op_y, opts_y);
    let mut bx = qx.as_mut().map(|q| q.step());
    let mut by = qy.as_mut().map(|q| q.step());
    let mut iters = 0usize;

    loop {
        let (x_lo, x_hi, x_exact) = match &bx {
            Some(b) => (b.lower(), b.upper(), b.exact),
            None => (0.0, 0.0, true),
        };
        let (y_lo, y_hi, y_exact) = match &by {
            Some(b) => (b.lower(), b.upper(), b.exact),
            None => (0.0, 0.0, true),
        };
        // Δ⁺ = log(l_ii − bif_x) ∈ [log(l_ii − x_hi), log(l_ii − x_lo)]
        let (dp_lo, dp_hi) = log_gap_bracket(l_ii, x_lo, x_hi);
        // Δ⁻ = −log(l_ii − bif_y) ∈ [−log(l_ii − y_lo), −log(l_ii − y_hi)]
        let (ly_lo, ly_hi) = log_gap_bracket(l_ii, y_lo, y_hi);
        let (dm_lo, dm_hi) = (-ly_hi, -ly_lo); // note sign flip reverses order

        if policy == RacePolicy::Prune {
            // decide early: add i  if p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊ certainly
            if p * pos(dm_hi) <= (1.0 - p) * pos(dp_lo) {
                let outcome =
                    if x_exact && y_exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided };
                return (true, JudgeStats { iters, outcome });
            }
            if p * pos(dm_lo) > (1.0 - p) * pos(dp_hi) {
                let outcome =
                    if x_exact && y_exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided };
                return (false, JudgeStats { iters, outcome });
            }
        }
        if x_exact && y_exact {
            return (
                p * pos(dm_lo) <= (1.0 - p) * pos(dp_lo),
                JudgeStats { iters, outcome: JudgeOutcome::Exact },
            );
        }
        // §5.2 refinement: tighten the side with the larger weighted
        // log-gap bracket
        let gx = (1.0 - p) * (pos(dp_hi) - pos(dp_lo));
        let gy = p * (pos(dm_hi) - pos(dm_lo));
        let x_can = !x_exact
            && bx.as_ref().zip(qx.as_ref()).map_or(false, |(b, q)| b.iter < q.max_iters);
        let y_can = !y_exact
            && by.as_ref().zip(qy.as_ref()).map_or(false, |(b, q)| b.iter < q.max_iters);
        if !x_can && !y_can {
            let dp_mid = 0.5 * (pos(dp_lo) + pos(dp_hi));
            let dm_mid = 0.5 * (pos(dm_lo) + pos(dm_hi));
            return (
                p * dm_mid <= (1.0 - p) * dp_mid,
                JudgeStats { iters, outcome: JudgeOutcome::Budget },
            );
        }
        if x_can && (gx >= gy || !y_can) {
            bx = qx.as_mut().map(|q| q.step());
        } else {
            by = qy.as_mut().map(|q| q.step());
        }
        iters += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::linalg::Cholesky;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// Oracle argmax of `offset_i − u_i^T A^{-1} u_i` via dense Cholesky.
    fn oracle_argmax(
        a: &crate::sparse::Csr,
        arms: &[(Vec<f64>, f64)],
        floor: Option<f64>,
    ) -> Option<usize> {
        let ch = Cholesky::factor(&a.to_dense()).expect("SPD");
        let mut best: Option<(usize, f64)> = None;
        for (i, (u, off)) in arms.iter().enumerate() {
            let val = off - ch.bif(u);
            if best.map_or(true, |(_, g)| val > g) {
                best = Some((i, val));
            }
        }
        match (best, floor) {
            (Some((i, v)), Some(f)) if v > f => Some(i),
            (Some(_), Some(_)) => None,
            (Some((i, _)), None) => Some(i),
            (None, _) => None,
        }
    }

    #[test]
    fn prune_and_exhaustive_pick_the_same_winner() {
        forall(12, 0xACE1, |rng| {
            let n = 10 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let m = 3 + rng.below(8);
            let width = 1 + rng.below(m);
            let opts = GqlOptions::new(w.lo, w.hi);
            let arms: Vec<(Vec<f64>, f64)> = (0..m)
                .map(|_| {
                    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let off = 2.0 + rng.f64() * 3.0;
                    (u, off)
                })
                .collect();
            let run = |policy| {
                let mut race = Race::new(&a, opts, width, policy);
                for (u, off) in &arms {
                    race.push_arm(u, StopRule::GapRel(1e-10), *off, -1.0);
                }
                race.run(None)
            };
            let ex = run(RacePolicy::Exhaustive);
            let pr = run(RacePolicy::Prune);
            assert_eq!(ex.winner, pr.winner, "policies disagreed");
            assert_eq!(ex.winner, oracle_argmax(&a, &arms, None), "wrong argmax");
            assert!(pr.stats.sweeps <= ex.stats.sweeps, "pruning added sweeps");
        });
    }

    #[test]
    fn floor_semantics_match_strict_comparison() {
        // every arm's value pushed below the floor ⇒ winner None; floor
        // below the best arm ⇒ winner unchanged
        let mut rng = Rng::new(0xACE2);
        let n = 16;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let arms: Vec<(Vec<f64>, f64)> = (0..4)
            .map(|_| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, 1.0)
            })
            .collect();
        let run = |policy, floor| {
            let mut race = Race::new(&a, opts, 4, policy);
            for (u, off) in &arms {
                race.push_arm(u, StopRule::GapRel(1e-10), *off, -1.0);
            }
            race.run(floor)
        };
        for policy in [RacePolicy::Exhaustive, RacePolicy::Prune] {
            assert_eq!(
                run(policy, Some(1e9)).winner,
                None,
                "no arm beats an impossible floor"
            );
            let want = oracle_argmax(&a, &arms, Some(-1e9));
            assert_eq!(run(policy, Some(-1e9)).winner, want);
        }
    }

    #[test]
    fn gapped_arms_race_saves_sweeps_and_reports_prunes() {
        // one arm with a much larger offset dominates almost immediately:
        // the prune race must spend strictly fewer panel sweeps
        let mut rng = Rng::new(0xACE3);
        let n = 48;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.15, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut arms: Vec<(Vec<f64>, f64)> = (0..8)
            .map(|_| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, 1.0)
            })
            .collect();
        arms[3].1 = 1e3; // clear gap
        let run = |policy| {
            let mut race = Race::new(&a, opts, 4, policy);
            for (u, off) in &arms {
                race.push_arm(u, StopRule::GapRel(1e-12), *off, -1.0);
            }
            race.run(None)
        };
        let ex = run(RacePolicy::Exhaustive);
        let pr = run(RacePolicy::Prune);
        assert_eq!(ex.winner, Some(3));
        assert_eq!(pr.winner, Some(3));
        assert!(
            pr.stats.sweeps < ex.stats.sweeps,
            "prune {} vs exhaustive {} sweeps",
            pr.stats.sweeps,
            ex.stats.sweeps
        );
        assert!(pr.stats.pruned() > 0, "no arm was pruned");
        assert!(pr.stats.decided_early);
    }

    #[test]
    fn single_arm_races_degenerate_to_plain_scoring() {
        let mut rng = Rng::new(0xACE4);
        let n = 12;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.4, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        for policy in [RacePolicy::Exhaustive, RacePolicy::Prune] {
            let mut race = Race::new(&a, opts, 1, policy);
            race.push_arm(&u, StopRule::GapRel(1e-10), 0.0, 1.0);
            let out = race.run(None);
            assert_eq!(out.winner, Some(0));
            assert!(out.estimates[0].is_some());
        }
    }

    #[test]
    fn zero_arms_yield_no_winner() {
        let mut rng = Rng::new(0xACE5);
        let (a, w) = random_sparse_spd(&mut rng, 8, 0.4, 0.05);
        let race = Race::new(&a, GqlOptions::new(w.lo, w.hi), 2, RacePolicy::Prune);
        let out = race.run(Some(0.0));
        assert_eq!(out.winner, None);
        assert_eq!(out.stats.sweeps, 0);
    }

    #[test]
    fn race_dg_policies_agree_with_each_other_and_the_oracle() {
        forall(20, 0xACE6, |rng| {
            let n = 8 + rng.below(16);
            let (l, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let k = 2 + rng.below(n / 2);
            let all = rng.sample_indices(n, n);
            let (xs, rest) = all.split_at(k);
            let (ys, _) = rest.split_at(1 + rng.below(rest.len() - 1));
            let i = *all.last().unwrap();
            let mut xs = xs.to_vec();
            let mut ys = ys.to_vec();
            xs.sort_unstable();
            ys.sort_unstable();
            let ax = l.principal_submatrix(&xs);
            let ay = l.principal_submatrix(&ys);
            let ux: Vec<f64> = xs.iter().map(|&m| l.get(m, i)).collect();
            let uy: Vec<f64> = ys.iter().map(|&m| l.get(m, i)).collect();
            let l_ii = l.get(i, i);
            let (chx, chy) = match (
                Cholesky::factor(&ax.to_dense()),
                Cholesky::factor(&ay.to_dense()),
            ) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return,
            };
            let dp = (l_ii - chx.bif(&ux)).max(1e-300).ln();
            let dm = -(l_ii - chy.bif(&uy)).max(1e-300).ln();
            let opts = GqlOptions::new(w.lo * 0.5, w.hi * 1.5);
            for p in [0.25, 0.5, 0.75] {
                let want = p * dm.max(0.0) <= (1.0 - p) * dp.max(0.0);
                let (prune, js_p) = race_dg(
                    Some((&ax, &ux)),
                    Some((&ay, &uy)),
                    l_ii,
                    p,
                    opts,
                    opts,
                    RacePolicy::Prune,
                );
                let (exhaust, js_e) = race_dg(
                    Some((&ax, &ux)),
                    Some((&ay, &uy)),
                    l_ii,
                    p,
                    opts,
                    opts,
                    RacePolicy::Exhaustive,
                );
                assert_eq!(prune, want, "prune decision wrong (p={p})");
                assert_eq!(exhaust, want, "exhaustive decision wrong (p={p})");
                assert!(js_p.iters <= js_e.iters, "pruning refined more");
            }
        });
    }
}
