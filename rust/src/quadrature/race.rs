//! Bound-driven racing scheduler: prune candidates by interval dominance.
//!
//! The paper's bounds *tighten iteratively* (Thm. 3.3–3.4): after every
//! quadrature step each candidate's value is bracketed, and the brackets
//! only shrink. That means a surrounding decision — "which candidate is
//! the argmax?", "does the double-greedy inequality hold?" — is often
//! determined long before every bracket reaches its stop tolerance. This
//! module spends panel sweeps only where the decision still needs them
//! (the same lazy-evaluation pattern as the adaptive truncation in Pleiss
//! et al., arXiv:2006.11267):
//!
//! * **Argmax mode** ([`Race`]): candidates ("arms") race through one
//!   shared [`BlockGql`] panel; after every sweep, every arm whose upper
//!   bound has fallen below the best lower bound is evicted
//!   ([`BlockGql::retire`], reason [`RetireReason::Dominated`]) and its
//!   panel column refills from the queue. The race ends the moment a
//!   single possible winner remains.
//! * **Comparison mode** ([`race_dg`]): the paired Δ⁺/Δ⁻ lanes of the
//!   double-greedy inclusion test stop the moment their log-gap brackets
//!   separate (the retrospective Alg. 9 behavior), or — under
//!   [`RacePolicy::Exhaustive`] — refine both sides to
//!   exhaustion/budget first and decide identically from the final
//!   brackets.
//!
//! **Selection identity.** Pruning only ever discards *dominated* arms:
//! an arm is evicted when its current upper bound sits strictly (by
//! [`PRUNE_MARGIN`]) below another arm's current lower bound. Because
//! brackets are nested over iterations, the evicted arm's final estimate
//! would have stayed strictly below that rival's final estimate, so the
//! argmax over the survivors equals the argmax over all arms —
//! [`RacePolicy::Prune`] and [`RacePolicy::Exhaustive`] select
//! *identically* (property-tested in `rust/tests/prop_race.rs`); only the
//! number of panel sweeps differs.

use super::block::{BlockGql, RetireReason, StopRule};
use super::gql::{Bounds, Gql, GqlOptions};
use super::is_zero;
use super::judge::{JudgeOutcome, JudgeStats};
use crate::sparse::SymOp;

/// Whether a race may evict dominated arms.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RacePolicy {
    /// Run every arm to its own stop rule and only then compare — the
    /// pre-racing behavior, kept as the reference arm of every property
    /// test and the `race` experiment.
    Exhaustive,
    /// Evict dominated arms after every panel sweep and stop as soon as
    /// the decision is determined. Selections are identical to
    /// `Exhaustive`; sweeps are not.
    Prune,
}

/// Safety margin for dominance tests, relative to the magnitudes
/// involved: floating-point bound sequences obey the paper's monotonicity
/// only to rounding error, so an arm is only evicted when its upper bound
/// is *clearly* below the best lower bound. Costs a negligible amount of
/// pruning, buys exact selection identity in practice.
pub const PRUNE_MARGIN: f64 = 1e-9;

#[inline]
fn dominated(hi: f64, best_lo: f64) -> bool {
    hi < best_lo - PRUNE_MARGIN * (1.0 + hi.abs() + best_lo.abs())
}

/// Value bracket of an arm given its BIF bounds: `value = offset +
/// scale · bif`, so the bracket endpoints swap when `scale < 0`.
fn value_bracket(offset: f64, scale: f64, b: &Bounds) -> (f64, f64) {
    let (blo, bhi) = if b.exact { (b.gauss, b.gauss) } else { (b.lower(), b.upper()) };
    let (v1, v2) = (offset + scale * blo, offset + scale * bhi);
    if v1 <= v2 {
        (v1, v2)
    } else {
        (v2, v1)
    }
}

/// Point estimate of an arm's value from finished bounds: the exact Gauss
/// value after Krylov exhaustion, the bracket midpoint otherwise — the
/// same estimator the pre-racing greedy used, so exhaustive races score
/// candidates bit-identically to the old scoring loop.
fn value_estimate(offset: f64, scale: f64, b: &Bounds) -> f64 {
    let bif = if b.exact { b.gauss } else { b.mid() };
    offset + scale * bif
}

#[derive(Clone, Copy, Debug)]
enum ArmStatus {
    /// In the panel or waiting in the engine queue.
    Racing,
    /// Reached its stop rule; final value bracket, estimate, and
    /// iteration count recorded.
    Done { est: f64, lo: f64, hi: f64, iters: usize },
    /// Evicted by interval dominance — provably not the argmax.
    Pruned,
}

struct Arm {
    offset: f64,
    scale: f64,
    status: ArmStatus,
}

/// Accounting for one race.
#[derive(Clone, Debug, Default)]
pub struct RaceStats {
    /// `matvec_multi` panel sweeps actually performed.
    pub sweeps: usize,
    /// Number of arms entered.
    pub arms: usize,
    /// Arms evicted by dominance, as `(arm index, iteration at eviction)`
    /// — finished arms that later became dominated report their final
    /// iteration count.
    pub pruned_at: Vec<(usize, usize)>,
    /// True when the race ended before every surviving arm reached its
    /// stop rule (a lone possible winner remained).
    pub decided_early: bool,
}

impl RaceStats {
    /// Arms evicted by dominance.
    pub fn pruned(&self) -> usize {
        self.pruned_at.len()
    }
}

/// Result of an argmax race.
#[derive(Clone, Debug)]
pub struct RaceOutcome {
    /// Index (push order) of the winning arm; `None` when every arm's
    /// value fell at or below the `floor` passed to [`Race::run`].
    pub winner: Option<usize>,
    /// Per-arm value estimates: `Some` for arms that reached their stop
    /// rule (and for a winner crowned early, whose entry holds its
    /// current bracket midpoint), `None` for pruned arms.
    pub estimates: Vec<Option<f64>>,
    pub stats: RaceStats,
}

/// An argmax race over one shared operator: push arms, then [`Race::run`].
///
/// Each arm `i` is a query vector `u_i` with an affine value
/// `offset_i + scale_i · u_i^T A^{-1} u_i`; the race finds the arm with
/// the largest value. DPP greedy uses `offset = L_cc, scale = −1` (the
/// marginal-gain bracket); plain "largest BIF" callers use
/// `offset = 0, scale = 1`.
pub struct Race<'a> {
    eng: BlockGql<'a>,
    arms: Vec<Arm>,
    policy: RacePolicy,
}

impl<'a> Race<'a> {
    /// A race over `op` scored through a width-`width` panel. `opts` and
    /// `width` behave exactly as in [`BlockGql::new`].
    pub fn new(op: &'a dyn SymOp, opts: GqlOptions, width: usize, policy: RacePolicy) -> Self {
        Race { eng: BlockGql::new(op, opts, width), arms: Vec::new(), policy }
    }

    /// Enter an arm; returns its index (push order). `stop` is the arm's
    /// own refinement limit — the bracket tolerance it runs to when the
    /// race does not prune it first.
    pub fn push_arm(&mut self, u: &[f64], stop: StopRule, offset: f64, scale: f64) -> usize {
        let id = self.eng.push(u, stop);
        debug_assert_eq!(id, self.arms.len(), "arm ids mirror push order");
        self.arms.push(Arm { offset, scale, status: ArmStatus::Racing });
        id
    }

    /// Number of arms entered so far.
    pub fn arms(&self) -> usize {
        self.arms.len()
    }

    /// Run the race to its decision.
    ///
    /// `floor`: optional minimum useful value (DPP greedy's PD gain
    /// floor). Arms whose upper bound falls below it are pruned like any
    /// dominated arm, and the returned `winner` is `None` unless the
    /// winning arm's value strictly exceeds the floor — the same strict
    /// comparison the exhaustive scoring loop applies.
    pub fn run(mut self, floor: Option<f64>) -> RaceOutcome {
        let mut stats = RaceStats { arms: self.arms.len(), ..RaceStats::default() };
        let mut estimates: Vec<Option<f64>> = vec![None; self.arms.len()];
        loop {
            let progressed = self.eng.step_panel();
            for r in self.eng.take_done() {
                let arm = &mut self.arms[r.id];
                // an arm pruned in the same round it finished stays pruned
                if matches!(arm.status, ArmStatus::Racing) {
                    let (lo, hi) = value_bracket(arm.offset, arm.scale, &r.bounds);
                    let est = value_estimate(arm.offset, arm.scale, &r.bounds);
                    arm.status = ArmStatus::Done { est, lo, hi, iters: r.iters };
                    estimates[r.id] = Some(est);
                }
            }
            if self.policy == RacePolicy::Prune {
                if let Some(early) =
                    self.prune_round(floor, &mut stats, &mut estimates)
                {
                    stats.sweeps = self.eng.sweeps();
                    return RaceOutcome { winner: early, estimates, stats };
                }
            }
            if !progressed {
                break;
            }
        }
        stats.sweeps = self.eng.sweeps();
        // Exhaustive scoring (or a prune race whose survivors all reached
        // their stop rules): argmax over surviving estimates in arm order
        // with a strict-greater tie-break — exactly the pre-racing loop.
        let mut best: Option<(usize, f64)> = None;
        for (i, arm) in self.arms.iter().enumerate() {
            if let ArmStatus::Done { est, .. } = arm.status {
                if best.map_or(true, |(_, g)| est > g) {
                    best = Some((i, est));
                }
            }
        }
        let winner = match (best, floor) {
            (Some((i, est)), Some(f)) if est > f => Some(i),
            (Some(_), Some(_)) => None,
            (Some((i, _)), None) => Some(i),
            (None, _) => None,
        };
        RaceOutcome { winner, estimates, stats }
    }

    /// One dominance round. Returns `Some(winner)` once the decision is
    /// determined early: `Some(Some(arm))` when a lone possible winner
    /// remains (every rival *and* the floor dominated), `Some(None)` when
    /// the floor dominated every arm. `None` means the race goes on.
    fn prune_round(
        &mut self,
        floor: Option<f64>,
        stats: &mut RaceStats,
        estimates: &mut [Option<f64>],
    ) -> Option<Option<usize>> {
        // current value brackets of the arms still in the panel
        let active: Vec<(usize, Option<Bounds>)> = self.eng.active().collect();
        let mut brackets: Vec<Option<(f64, f64, usize)>> = vec![None; self.arms.len()];
        for (i, arm) in self.arms.iter().enumerate() {
            match arm.status {
                ArmStatus::Done { lo, hi, iters, .. } => brackets[i] = Some((lo, hi, iters)),
                ArmStatus::Racing => {
                    if let Some((_, Some(b))) = active.iter().find(|(id, _)| *id == i) {
                        let (lo, hi) = value_bracket(arm.offset, arm.scale, b);
                        brackets[i] = Some((lo, hi, b.iter));
                    }
                    // arms still waiting in the queue have no bracket yet
                    // and can be neither pruned nor used for pruning
                }
                ArmStatus::Pruned => {}
            }
        }
        let mut best_lo = f64::NEG_INFINITY;
        for (i, arm) in self.arms.iter().enumerate() {
            if matches!(arm.status, ArmStatus::Pruned) {
                continue;
            }
            if let Some((lo, _, _)) = brackets[i] {
                best_lo = best_lo.max(lo);
            }
        }
        let thresh = match floor {
            Some(f) => best_lo.max(f),
            None => best_lo,
        };
        if thresh.is_finite() {
            for i in 0..self.arms.len() {
                if matches!(self.arms[i].status, ArmStatus::Pruned) {
                    continue;
                }
                if let Some((_, hi, iter)) = brackets[i] {
                    if dominated(hi, thresh) {
                        if matches!(self.arms[i].status, ArmStatus::Racing) {
                            self.eng.retire(i, RetireReason::Dominated);
                        }
                        // (finished arms have nothing to evict, but marking
                        // them keeps the survivor count honest for the
                        // early exit below)
                        self.arms[i].status = ArmStatus::Pruned;
                        estimates[i] = None;
                        stats.pruned_at.push((i, iter));
                    }
                }
            }
        }
        // early exit: how many arms can still win?
        let survivors: Vec<usize> = self
            .arms
            .iter()
            .enumerate()
            .filter(|(_, a)| !matches!(a.status, ArmStatus::Pruned))
            .map(|(i, _)| i)
            .collect();
        if survivors.is_empty() {
            // the floor dominated everything: no candidate is feasible
            return Some(None);
        }
        if survivors.len() == 1 {
            let w = survivors[0];
            // the floor must be dominated too before the winner can be
            // crowned without its final estimate
            let floor_beaten = match floor {
                None => true,
                Some(f) => brackets[w].map_or(false, |(lo, _, _)| dominated(f, lo)),
            };
            let still_racing = matches!(self.arms[w].status, ArmStatus::Racing);
            if floor_beaten && still_racing {
                // stop refining: the surrounding decision is determined
                // before the winner reached its own stop rule — the only
                // genuinely early ending (a finished winner below ended
                // on schedule, it just needs no further sweeps)
                stats.decided_early = true;
                if estimates[w].is_none() {
                    if let Some((lo, hi, _)) = brackets[w] {
                        estimates[w] = Some(0.5 * (lo + hi));
                    }
                }
                self.eng.retire(w, RetireReason::Decided);
                return Some(Some(w));
            }
            if floor_beaten && !still_racing {
                // finished winner: identical to the exhaustive exit, but
                // no need to wait for the loop to notice the empty engine
                return Some(Some(w));
            }
            // lone survivor but the floor still straddles its bracket:
            // keep refining until its own stop rule resolves the floor
            // comparison exactly like the exhaustive path
        }
        None
    }
}

// ---------------------------------------------------------------------------
// Comparison mode: the double-greedy inclusion race (paper Alg. 9)
// ---------------------------------------------------------------------------

/// Bracket for `log(t − bif)` given BIF bounds `[lo, hi]`; −∞ when the
/// argument is non-positive (degenerate gain; `[x]₊` clamps it later).
fn log_gap_bracket(t: f64, bif_lo: f64, bif_hi: f64) -> (f64, f64) {
    let lo_arg = t - bif_hi;
    let hi_arg = t - bif_lo;
    let lo = if lo_arg > 0.0 { lo_arg.ln() } else { f64::NEG_INFINITY };
    let hi = if hi_arg > 0.0 { hi_arg.ln() } else { f64::NEG_INFINITY };
    (lo, hi)
}

#[inline]
fn pos(x: f64) -> f64 {
    x.max(0.0)
}

/// Double-greedy inclusion test as a two-arm comparison race (paper
/// Alg. 9): with Δ⁺ = log(l_ii − u_x^T L_X^{-1} u_x) and
/// Δ⁻ = −log(l_ii − u_y^T L_{Y'}^{-1} u_y), returns true (add `i` to X)
/// iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊`.
///
/// Under [`RacePolicy::Prune`] the race stops the moment the two log-gap
/// brackets separate — the retrospective behavior
/// [`crate::quadrature::judge_dg`] has always had. Under
/// [`RacePolicy::Exhaustive`] both quadratures refine to
/// exhaustion/budget first and the decision falls out of the final
/// brackets; because certified separations only ever tighten, the two
/// policies decide identically (property-tested), differing only in
/// `JudgeStats::iters`.
///
/// `ops` may be `None` when the corresponding set is empty (Δ then
/// depends on `l_ii` alone and is exact).
pub fn race_dg(
    op_x: Option<(&dyn SymOp, &[f64])>,
    op_y: Option<(&dyn SymOp, &[f64])>,
    l_ii: f64,
    p: f64,
    opts_x: GqlOptions,
    opts_y: GqlOptions,
    policy: RacePolicy,
) -> (bool, JudgeStats) {
    // Quadrature state (None = exact zero-BIF, incl. zero query vectors)
    let mut qx = op_x
        .filter(|(_, u)| !is_zero(u))
        .map(|(op, u)| Gql::new(op, u, opts_x));
    let mut qy = op_y
        .filter(|(_, u)| !is_zero(u))
        .map(|(op, u)| Gql::new(op, u, opts_y));
    let mut bx = qx.as_mut().map(|q| q.step());
    let mut by = qy.as_mut().map(|q| q.step());
    let mut iters = 0usize;

    loop {
        let (x_lo, x_hi, x_exact) = match &bx {
            Some(b) => (b.lower(), b.upper(), b.exact),
            None => (0.0, 0.0, true),
        };
        let (y_lo, y_hi, y_exact) = match &by {
            Some(b) => (b.lower(), b.upper(), b.exact),
            None => (0.0, 0.0, true),
        };
        // Δ⁺ = log(l_ii − bif_x) ∈ [log(l_ii − x_hi), log(l_ii − x_lo)]
        let (dp_lo, dp_hi) = log_gap_bracket(l_ii, x_lo, x_hi);
        // Δ⁻ = −log(l_ii − bif_y) ∈ [−log(l_ii − y_lo), −log(l_ii − y_hi)]
        let (ly_lo, ly_hi) = log_gap_bracket(l_ii, y_lo, y_hi);
        let (dm_lo, dm_hi) = (-ly_hi, -ly_lo); // note sign flip reverses order

        if policy == RacePolicy::Prune {
            // decide early: add i  if p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊ certainly
            if p * pos(dm_hi) <= (1.0 - p) * pos(dp_lo) {
                let outcome =
                    if x_exact && y_exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided };
                return (true, JudgeStats { iters, outcome });
            }
            if p * pos(dm_lo) > (1.0 - p) * pos(dp_hi) {
                let outcome =
                    if x_exact && y_exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided };
                return (false, JudgeStats { iters, outcome });
            }
        }
        if x_exact && y_exact {
            return (
                p * pos(dm_lo) <= (1.0 - p) * pos(dp_lo),
                JudgeStats { iters, outcome: JudgeOutcome::Exact },
            );
        }
        // §5.2 refinement: tighten the side with the larger weighted
        // log-gap bracket
        let gx = (1.0 - p) * (pos(dp_hi) - pos(dp_lo));
        let gy = p * (pos(dm_hi) - pos(dm_lo));
        let x_can = !x_exact && qx.as_ref().map_or(false, |q| q.iterations() < opts_x.max_iters);
        let y_can = !y_exact && qy.as_ref().map_or(false, |q| q.iterations() < opts_y.max_iters);
        if !x_can && !y_can {
            let dp_mid = 0.5 * (pos(dp_lo) + pos(dp_hi));
            let dm_mid = 0.5 * (pos(dm_lo) + pos(dm_hi));
            return (
                p * dm_mid <= (1.0 - p) * dp_mid,
                JudgeStats { iters, outcome: JudgeOutcome::Budget },
            );
        }
        if x_can && (gx >= gy || !y_can) {
            bx = qx.as_mut().map(|q| q.step());
        } else {
            by = qy.as_mut().map(|q| q.step());
        }
        iters += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::linalg::Cholesky;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    /// Oracle argmax of `offset_i − u_i^T A^{-1} u_i` via dense Cholesky.
    fn oracle_argmax(
        a: &crate::sparse::Csr,
        arms: &[(Vec<f64>, f64)],
        floor: Option<f64>,
    ) -> Option<usize> {
        let ch = Cholesky::factor(&a.to_dense()).expect("SPD");
        let mut best: Option<(usize, f64)> = None;
        for (i, (u, off)) in arms.iter().enumerate() {
            let val = off - ch.bif(u);
            if best.map_or(true, |(_, g)| val > g) {
                best = Some((i, val));
            }
        }
        match (best, floor) {
            (Some((i, v)), Some(f)) if v > f => Some(i),
            (Some(_), Some(_)) => None,
            (Some((i, _)), None) => Some(i),
            (None, _) => None,
        }
    }

    #[test]
    fn prune_and_exhaustive_pick_the_same_winner() {
        forall(12, 0xACE1, |rng| {
            let n = 10 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let m = 3 + rng.below(8);
            let width = 1 + rng.below(m);
            let opts = GqlOptions::new(w.lo, w.hi);
            let arms: Vec<(Vec<f64>, f64)> = (0..m)
                .map(|_| {
                    let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                    let off = 2.0 + rng.f64() * 3.0;
                    (u, off)
                })
                .collect();
            let run = |policy| {
                let mut race = Race::new(&a, opts, width, policy);
                for (u, off) in &arms {
                    race.push_arm(u, StopRule::GapRel(1e-10), *off, -1.0);
                }
                race.run(None)
            };
            let ex = run(RacePolicy::Exhaustive);
            let pr = run(RacePolicy::Prune);
            assert_eq!(ex.winner, pr.winner, "policies disagreed");
            assert_eq!(ex.winner, oracle_argmax(&a, &arms, None), "wrong argmax");
            assert!(pr.stats.sweeps <= ex.stats.sweeps, "pruning added sweeps");
        });
    }

    #[test]
    fn floor_semantics_match_strict_comparison() {
        // every arm's value pushed below the floor ⇒ winner None; floor
        // below the best arm ⇒ winner unchanged
        let mut rng = Rng::new(0xACE2);
        let n = 16;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let arms: Vec<(Vec<f64>, f64)> = (0..4)
            .map(|_| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, 1.0)
            })
            .collect();
        let run = |policy, floor| {
            let mut race = Race::new(&a, opts, 4, policy);
            for (u, off) in &arms {
                race.push_arm(u, StopRule::GapRel(1e-10), *off, -1.0);
            }
            race.run(floor)
        };
        for policy in [RacePolicy::Exhaustive, RacePolicy::Prune] {
            assert_eq!(
                run(policy, Some(1e9)).winner,
                None,
                "no arm beats an impossible floor"
            );
            let want = oracle_argmax(&a, &arms, Some(-1e9));
            assert_eq!(run(policy, Some(-1e9)).winner, want);
        }
    }

    #[test]
    fn gapped_arms_race_saves_sweeps_and_reports_prunes() {
        // one arm with a much larger offset dominates almost immediately:
        // the prune race must spend strictly fewer panel sweeps
        let mut rng = Rng::new(0xACE3);
        let n = 48;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.15, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut arms: Vec<(Vec<f64>, f64)> = (0..8)
            .map(|_| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, 1.0)
            })
            .collect();
        arms[3].1 = 1e3; // clear gap
        let run = |policy| {
            let mut race = Race::new(&a, opts, 4, policy);
            for (u, off) in &arms {
                race.push_arm(u, StopRule::GapRel(1e-12), *off, -1.0);
            }
            race.run(None)
        };
        let ex = run(RacePolicy::Exhaustive);
        let pr = run(RacePolicy::Prune);
        assert_eq!(ex.winner, Some(3));
        assert_eq!(pr.winner, Some(3));
        assert!(
            pr.stats.sweeps < ex.stats.sweeps,
            "prune {} vs exhaustive {} sweeps",
            pr.stats.sweeps,
            ex.stats.sweeps
        );
        assert!(pr.stats.pruned() > 0, "no arm was pruned");
        assert!(pr.stats.decided_early);
    }

    #[test]
    fn single_arm_races_degenerate_to_plain_scoring() {
        let mut rng = Rng::new(0xACE4);
        let n = 12;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.4, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        for policy in [RacePolicy::Exhaustive, RacePolicy::Prune] {
            let mut race = Race::new(&a, opts, 1, policy);
            race.push_arm(&u, StopRule::GapRel(1e-10), 0.0, 1.0);
            let out = race.run(None);
            assert_eq!(out.winner, Some(0));
            assert!(out.estimates[0].is_some());
        }
    }

    #[test]
    fn zero_arms_yield_no_winner() {
        let mut rng = Rng::new(0xACE5);
        let (a, w) = random_sparse_spd(&mut rng, 8, 0.4, 0.05);
        let race = Race::new(&a, GqlOptions::new(w.lo, w.hi), 2, RacePolicy::Prune);
        let out = race.run(Some(0.0));
        assert_eq!(out.winner, None);
        assert_eq!(out.stats.sweeps, 0);
    }

    #[test]
    fn race_dg_policies_agree_with_each_other_and_the_oracle() {
        forall(20, 0xACE6, |rng| {
            let n = 8 + rng.below(16);
            let (l, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let k = 2 + rng.below(n / 2);
            let all = rng.sample_indices(n, n);
            let (xs, rest) = all.split_at(k);
            let (ys, _) = rest.split_at(1 + rng.below(rest.len() - 1));
            let i = *all.last().unwrap();
            let mut xs = xs.to_vec();
            let mut ys = ys.to_vec();
            xs.sort_unstable();
            ys.sort_unstable();
            let ax = l.principal_submatrix(&xs);
            let ay = l.principal_submatrix(&ys);
            let ux: Vec<f64> = xs.iter().map(|&m| l.get(m, i)).collect();
            let uy: Vec<f64> = ys.iter().map(|&m| l.get(m, i)).collect();
            let l_ii = l.get(i, i);
            let (chx, chy) = match (
                Cholesky::factor(&ax.to_dense()),
                Cholesky::factor(&ay.to_dense()),
            ) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return,
            };
            let dp = (l_ii - chx.bif(&ux)).max(1e-300).ln();
            let dm = -(l_ii - chy.bif(&uy)).max(1e-300).ln();
            let opts = GqlOptions::new(w.lo * 0.5, w.hi * 1.5);
            for p in [0.25, 0.5, 0.75] {
                let want = p * dm.max(0.0) <= (1.0 - p) * dp.max(0.0);
                let (prune, js_p) = race_dg(
                    Some((&ax, &ux)),
                    Some((&ay, &uy)),
                    l_ii,
                    p,
                    opts,
                    opts,
                    RacePolicy::Prune,
                );
                let (exhaust, js_e) = race_dg(
                    Some((&ax, &ux)),
                    Some((&ay, &uy)),
                    l_ii,
                    p,
                    opts,
                    opts,
                    RacePolicy::Exhaustive,
                );
                assert_eq!(prune, want, "prune decision wrong (p={p})");
                assert_eq!(exhaust, want, "exhaustive decision wrong (p={p})");
                assert!(js_p.iters <= js_e.iters, "pruning refined more");
            }
        });
    }
}
