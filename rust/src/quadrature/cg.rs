//! Conjugate gradients: (a) the classical alternative for estimating
//! `u^T A^{-1} u ≈ u^T x` by solving `A x = u` — the "black-box" approach
//! §1 argues is insufficient because it yields no bounds — and (b) the
//! theory bridge: Thm. 12 ties the CG error A-norm to the Gauss quadrature
//! gap, which `rust/tests/prop_quadrature.rs` checks numerically.

use crate::sparse::SymOp;

/// Result of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub residual_norm: f64,
    /// ||r_k|| after every iteration (for convergence plots).
    pub residual_history: Vec<f64>,
    pub converged: bool,
}

/// Solve `A x = b` for SPD `A` by conjugate gradients.
pub fn cg_solve(op: &dyn SymOp, b: &[f64], tol: f64, max_iters: usize) -> CgResult {
    let n = op.dim();
    assert_eq!(b.len(), n);
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let bnorm = r.iter().map(|v| v * v).sum::<f64>().sqrt().max(f64::MIN_POSITIVE);
    let mut rs_old: f64 = r.iter().map(|v| v * v).sum();
    let mut history = Vec::new();

    for k in 0..max_iters {
        if rs_old.sqrt() <= tol * bnorm {
            return CgResult {
                x,
                iterations: k,
                residual_norm: rs_old.sqrt(),
                residual_history: history,
                converged: true,
            };
        }
        op.matvec(&p, &mut ap);
        let pap: f64 = p.iter().zip(&ap).map(|(a, b)| a * b).sum();
        if pap <= 0.0 {
            break; // not SPD (or exhausted in exact arithmetic)
        }
        let alpha = rs_old / pap;
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rs_new: f64 = r.iter().map(|v| v * v).sum();
        history.push(rs_new.sqrt());
        let beta = rs_new / rs_old;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
        rs_old = rs_new;
    }
    CgResult {
        x,
        iterations: history.len(),
        residual_norm: rs_old.sqrt(),
        residual_history: history,
        converged: rs_old.sqrt() <= tol * bnorm,
    }
}

/// CG point estimate of the BIF: `u^T x` with `A x = u`. No bounds — the
/// baseline the paper's framework improves on.
pub fn cg_bif_estimate(op: &dyn SymOp, u: &[f64], tol: f64, max_iters: usize) -> f64 {
    let r = cg_solve(op, u, tol, max_iters);
    u.iter().zip(&r.x).map(|(a, b)| a * b).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{Cholesky, DMat};
    use crate::quadrature::gql::tests::random_shifted_spd;
    use crate::util::prop::{assert_close, forall};
    use crate::util::rng::Rng;

    #[test]
    fn solves_identity_instantly() {
        let a = DMat::eye(5);
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        let r = cg_solve(&a, &b, 1e-12, 10);
        assert!(r.converged);
        for (xi, bi) in r.x.iter().zip(&b) {
            assert_close(*xi, *bi, 1e-12, 1e-12);
        }
    }

    #[test]
    fn matches_cholesky_solution() {
        forall(25, 0xC6, |rng| {
            let n = 3 + rng.below(25);
            let (a, _, _) = random_shifted_spd(rng, n, 0.5, 0.5);
            let b: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let cg = cg_solve(&a, &b, 1e-12, 10 * n);
            assert!(cg.converged, "CG did not converge");
            let want = Cholesky::factor(&a).unwrap().solve(&b);
            for (g, w) in cg.x.iter().zip(&want) {
                assert_close(*g, *w, 1e-6, 1e-8);
            }
        });
    }

    #[test]
    fn bif_estimate_matches_exact() {
        forall(20, 0xC7, |rng| {
            let n = 4 + rng.below(20);
            let (a, _, _) = random_shifted_spd(rng, n, 0.6, 0.5);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let exact = Cholesky::factor(&a).unwrap().bif(&u);
            let est = cg_bif_estimate(&a, &u, 1e-12, 10 * n);
            assert_close(est, exact, 1e-7, 1e-9);
        });
    }

    #[test]
    fn residual_history_monotone_enough() {
        // CG residuals are not strictly monotone, but the A-norm error is;
        // check the residual at the end is far below the start.
        let mut rng = Rng::new(0xC8);
        let (a, _, _) = random_shifted_spd(&mut rng, 30, 0.6, 0.5);
        let b: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
        let r = cg_solve(&a, &b, 1e-10, 300);
        assert!(r.converged);
        let first = r.residual_history.first().unwrap();
        let last = r.residual_history.last().unwrap();
        assert!(last < first);
    }

    #[test]
    fn respects_max_iters() {
        let mut rng = Rng::new(0xC9);
        let (a, _, _) = random_shifted_spd(&mut rng, 40, 1.0, 1e-4);
        let b: Vec<f64> = (0..40).map(|_| rng.normal()).collect();
        let r = cg_solve(&a, &b, 1e-16, 3);
        assert!(r.iterations <= 3);
    }
}
