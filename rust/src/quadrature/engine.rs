//! Resident multi-tenant engine: one always-on scheduler that owns its
//! operators and runs every live [`Session`] jointly.
//!
//! The paper's central economy is that Gauss/Radau/Lobatto brackets
//! tighten at a linear rate (Thm. 3/5/8), so decisions resolve long
//! before full convergence. PR 4's [`Session`] exploits that *within one
//! operator* — mixed queries share `matvec_multi` panels — but every
//! cross-operator consumer still ran its own lockstep loop: `race_dg`'s
//! Δ⁺/Δ⁻ sides live on different submatrices, a k-DPP chain pool holds
//! several live `L_{Y'}` operators, and the coordinator drained one
//! coalesce key at a time. Block-quadrature results (Zimmerling, Druskin
//! & Simoncini, arXiv:2407.21505) show the batched recurrence preserves
//! exactly the monotone-bound structure the pruning relies on, so nothing
//! stops scheduling *all* live operators' panels in one joint round loop.
//!
//! The [`Engine`] owns an [`OpStore`] of ref-counted operators keyed by
//! [`OpKey`] and drives the live sessions from a single round loop — one
//! `matvec_multi` panel per operator per round, sessions swept in
//! parallel by a small hand-rolled worker fan-out. The default
//! [`SweepMode::Stealing`] fan-out is an index-claiming work-stealing
//! sweep: a persistent pool of parked workers (spawned once, reused
//! every round — `engine.profile.pool_reuse`) races a shared atomic
//! cursor down the slot list, so a skewed round — one slow operator next
//! to many fast ones — no longer idles every other worker through the
//! tail of a static partition. [`SweepMode::Static`] keeps the PR-5
//! `chunks_mut` split (scoped threads over disjoint session chunks) as a
//! measurable baseline. Either way there are no locks on the step path
//! and exactly one step per live session per round, so answers are
//! bit-identical to the sequential loop at any worker count — each
//! session is an independent state machine; only *which thread* steps it
//! varies. Residency adds four capabilities on top of the original joint
//! scheduling:
//!
//! * **Owned operator store** — [`Engine::submit`] takes an
//!   `Arc<dyn SymOp>`; the engine pins it in the [`OpStore`] while its
//!   session is live, releases it at TTL eviction, and LRU-evicts
//!   released operators once the store exceeds
//!   [`EngineConfig::store_bytes`]. A later submission under a still-
//!   resident key reuses the stored operator ([`Engine::submit_keyed`]
//!   needs no operator at all), so the engine has no borrowed-operator
//!   lifetime and can outlive every caller.
//! * **Ticket compaction** — submissions return a generation-tagged
//!   [`Ticket`]; [`Engine::take_answer`] frees the ticket's slot for
//!   reuse (a tombstone), and a stale ticket — one whose slot was
//!   compacted — errors with [`TicketError::Stale`] instead of aliasing
//!   a younger query's answer. A resident engine's ticket log is thereby
//!   bounded by its open queries, not its history.
//! * **Deadline admission & backpressure** — [`Engine::try_submit`]
//!   estimates a query's sweeps from its dimension, width and
//!   [`StopRule`] and schedules by slack (deadline minus estimate);
//!   when open tickets hit [`EngineConfig::queue_cap`] it sheds the
//!   least-urgent estimate mid-flight. Shed responses are *answers*, not
//!   errors: the anytime property means the cancelled lane's current
//!   four-bound bracket is still a valid certified enclosure.
//! * **Joint scheduling for cross-operator consumers** —
//!   [`race_dg_joint`] submits the double-greedy Δ⁺/Δ⁻ sides as two
//!   estimate queries on two operators and decides from per-round bracket
//!   exchange; `apps::kdpp::step_chains` advances a pool of k-DPP chains'
//!   swap tests jointly; `apps::dpp::greedy_map_multi` races several
//!   kernels' greedy rounds at once; the coordinator's native drain is a
//!   thin client of one shared resident engine.
//!
//! * **Streaming submission** (unchanged) — submissions are accepted
//!   mid-flight and land in the next round's panel for their operator;
//!   sessions spin up lazily on first use of a key and idle sessions are
//!   evicted after [`EngineConfig::ttl_rounds`] workless rounds.
//! * **Query-level suspend/resume** (unchanged) — a global lane budget
//!   ([`EngineConfig::lanes`]) parks whole queries under pressure and
//!   resumes them bit-identically, ordered by urgency then submission:
//!   the head-of-line query always keeps its lanes.
//!
//! **Invariant — a scheduler, not a numeric path.** Engine answers are
//! bit-identical to sequential per-operator [`Session`] runs: the engine
//! never touches panel math, it only decides *when* each session steps.
//! Per-lane op sequences are fixed by the block engine's exactness
//! contract regardless of interleaving, suspended queries resume with
//! their exact mid-run state, evicted-and-readmitted operators rebuild
//! the identical Krylov sequence (the store returns the same `Arc`, and
//! a fresh session replays the same deterministic recurrence), and every
//! decision is certified by the same nested brackets — property-tested
//! in `rust/tests/prop_engine.rs`, including streaming submission, a
//! lane budget of 1, LRU eviction + re-admission, stale-ticket
//! generations, and shed answers carrying valid brackets.

use super::block::{RetireReason, StopRule};
use super::gql::{Bounds, GqlOptions};
use super::is_zero;
use super::judge::{JudgeOutcome, JudgeStats};
use super::query::{Answer, Query, Session};
use super::race::RacePolicy;
use super::stochastic::SlqConfigError;
use crate::metrics::flight::{FlightEventKind, FlightRecorder, SpanId};
use crate::metrics::{lock_tolerant, Histogram, MetricsRegistry};
use crate::sparse::SymOp;
use std::any::Any;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Identifies one operator (and therefore one session) inside an engine.
/// Callers pick keys; co-keyed submissions must target the *same*
/// operator (the coordinator's `op_key` contract). Keys at or above
/// [`ANON_KEY_BASE`] are reserved for [`Engine::fresh_key`].
pub type OpKey = u64;

/// Keys handed out by [`Engine::fresh_key`] start here; user keys should
/// stay below to avoid collisions. Anonymous operators can never be
/// re-addressed, so the store drops them outright when their session is
/// evicted instead of keeping them warm.
pub const ANON_KEY_BASE: OpKey = 1 << 63;

/// Ceiling for [`EngineConfig::lanes`]: a budget above this cannot be a
/// real capacity plan (a panel lane costs O(n) floats; 2²⁰ lanes of even
/// tiny operators is gigabytes) and is rejected as a typo at admission.
pub const MAX_ENGINE_LANES: usize = 1 << 20;
/// Ceiling for [`EngineConfig::ttl_rounds`]: beyond this an "idle"
/// session would outlive any realistic run — rejected as a typo.
pub const MAX_ENGINE_TTL: usize = 1 << 20;
/// Ceiling for [`EngineConfig::workers`]: the sweep fan-out backs every
/// worker with a real OS thread (persistent pool helpers in
/// [`SweepMode::Stealing`], scoped threads in [`SweepMode::Static`]), so
/// absurd worker counts are rejected rather than honored.
pub const MAX_ENGINE_WORKERS: usize = 1 << 10;

/// How [`Engine::step_round`] fans a multi-session round out over its
/// [`EngineConfig::workers`]. Both modes step every live session exactly
/// once per round on *some* thread, and a session's panel math never
/// depends on which thread runs it — so answers are bit-identical across
/// modes and worker counts (pinned by `rust/tests/prop_engine.rs`). The
/// modes differ only in wall-clock shape, measured by
/// `engine.profile.worker_idle_frac`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SweepMode {
    /// Index-claiming work stealing (the default): a persistent pool of
    /// parked worker threads (plus the driving thread) races one shared
    /// atomic cursor down the slot list, each claim taking the next
    /// un-stepped session. A worker that lands a slow session simply
    /// stops claiming while the rest drain the remainder, so the round's
    /// tail is one session long instead of one *chunk* long. Claims that
    /// land outside a worker's fair static share are counted as
    /// `engine.profile.steal_count`; pool reuse across rounds as
    /// `engine.profile.pool_reuse`.
    #[default]
    Stealing,
    /// The PR-5 static split: `chunks_mut` partitions the slot list into
    /// one contiguous chunk per worker under per-round scoped threads.
    /// Kept as the measurable baseline the stealing sweep is judged
    /// against (`benches/bench_engine.rs` skewed-workload rows) and as a
    /// fallback with strictly simpler machinery.
    Static,
}

/// Typed rejection of unusable engine knobs, mirroring
/// [`BatchPolicy::validate`](crate::coordinator::BatchPolicy): checked at
/// admission ([`Engine::new`], `RunConfig` parsing) so a bad config fails
/// loudly instead of deadlocking the round loop or exhausting memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineConfigError {
    /// `engine_lanes == 0`: no query could ever hold a lane.
    ZeroLanes,
    /// `engine_lanes` beyond [`MAX_ENGINE_LANES`].
    AbsurdLanes(usize),
    /// `engine_ttl_rounds == 0`: every session would be evicted the round
    /// it went idle, defeating the always-on design.
    ZeroTtl,
    /// `engine_ttl_rounds` beyond [`MAX_ENGINE_TTL`].
    AbsurdTtl(usize),
    /// A zero per-session panel width.
    ZeroWidth,
    /// A zero sweep worker count.
    ZeroWorkers,
    /// Worker count beyond [`MAX_ENGINE_WORKERS`].
    AbsurdWorkers(usize),
    /// `engine_queue_cap == 0`: every submission would be shed on
    /// arrival — nothing could ever run.
    ZeroQueueCap,
}

impl fmt::Display for EngineConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineConfigError::ZeroLanes => {
                write!(f, "engine_lanes must be >= 1 (0 would park every query forever)")
            }
            EngineConfigError::AbsurdLanes(v) => write!(
                f,
                "engine_lanes = {v} exceeds the sanity ceiling {MAX_ENGINE_LANES}"
            ),
            EngineConfigError::ZeroTtl => write!(
                f,
                "engine_ttl_rounds must be >= 1 (0 would evict sessions the round they idle)"
            ),
            EngineConfigError::AbsurdTtl(v) => write!(
                f,
                "engine_ttl_rounds = {v} exceeds the sanity ceiling {MAX_ENGINE_TTL}"
            ),
            EngineConfigError::ZeroWidth => write!(f, "engine panel width must be >= 1"),
            EngineConfigError::ZeroWorkers => write!(f, "engine workers must be >= 1"),
            EngineConfigError::AbsurdWorkers(v) => write!(
                f,
                "engine workers = {v} exceeds the sanity ceiling {MAX_ENGINE_WORKERS}"
            ),
            EngineConfigError::ZeroQueueCap => write!(
                f,
                "engine_queue_cap must be >= 1 (0 would shed every submission on arrival)"
            ),
        }
    }
}

impl std::error::Error for EngineConfigError {}

/// Engine scheduling knobs. Validated by [`Engine::new`]; the
/// `engine_lanes` / `engine_ttl_rounds` pair is also validated at
/// `RunConfig` admission through [`EngineConfig::validate_knobs`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineConfig {
    /// Default panel width for sessions spun up by [`Engine::submit`]
    /// ([`Engine::spin_up`] can override per key).
    pub width: usize,
    /// Global live-lane budget across every session: when the demand of
    /// unresolved queries exceeds it, less urgent queries are parked
    /// whole (suspend/resume, bit-identical) until capacity frees. The
    /// head-of-line query always runs, so the budget can never deadlock.
    pub lanes: usize,
    /// Idle sessions (no unresolved query, no queued lane) are evicted
    /// after this many consecutive workless rounds. Eviction releases the
    /// session's operator pin in the [`OpStore`]; the operator itself
    /// stays warm until the byte budget pushes it out.
    pub ttl_rounds: usize,
    /// Sweep workers: live sessions are stepped in parallel when more
    /// than one is live. Results are bit-identical at any worker count.
    pub workers: usize,
    /// How the sweep fans out over the workers: index-claiming work
    /// stealing from a persistent pool (default) or the static
    /// `chunks_mut` split. Never changes answers, only wall-clock.
    pub sweep: SweepMode,
    /// Default race policy for sessions spun up by [`Engine::submit`].
    pub policy: RacePolicy,
    /// Collect a [`RoundProfile`] (per-round phase timings, per-worker
    /// busy/idle accounting, per-session step-time histogram). Off by
    /// default: the unprofiled round loop carries zero instrumentation.
    /// Timing reads never touch panel math, so profiled answers stay
    /// bit-identical.
    pub profile: bool,
    /// Sessions spun up by this engine record per-query convergence
    /// traces ([`Session::record_traces`]); resolved estimate answers
    /// then carry a [`GapTrace`](crate::metrics::GapTrace).
    pub record_traces: bool,
    /// Byte budget for *released* (no live session) operators kept warm
    /// in the [`OpStore`]. Pinned operators never count against
    /// eviction; the budget only bounds the warm cache. `usize::MAX`
    /// (the default) keeps everything resident.
    pub store_bytes: usize,
    /// Backpressure bound for [`Engine::try_submit`]: when this many
    /// tickets are open, admission sheds the least-urgent in-flight
    /// estimate (its answer is its current four-bound bracket) to make
    /// room, or refuses with [`SubmitError::Saturated`] when no query
    /// has a bracket to answer with yet. `usize::MAX` (the default)
    /// never sheds; [`Engine::submit`] bypasses the cap entirely.
    pub queue_cap: usize,
    /// Record per-query lifecycle events into the engine's
    /// [`FlightRecorder`] (span at admission; typed events at
    /// admission/planning/rounds/park/shed/retire/answer). On by
    /// default: recording happens only in the scheduling phases — never
    /// inside `Session::step` — so panel math and answers are
    /// bit-identical with the recorder on or off (property-tested), and
    /// the bounded ring keeps memory constant.
    pub flight: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            width: 16,
            lanes: 256,
            ttl_rounds: 32,
            workers: 1,
            sweep: SweepMode::Stealing,
            policy: RacePolicy::Prune,
            profile: false,
            record_traces: false,
            store_bytes: usize::MAX,
            queue_cap: usize::MAX,
            flight: true,
        }
    }
}

impl EngineConfig {
    pub fn with_width(mut self, w: usize) -> Self {
        self.width = w;
        self
    }

    pub fn with_lanes(mut self, l: usize) -> Self {
        self.lanes = l;
        self
    }

    pub fn with_ttl_rounds(mut self, t: usize) -> Self {
        self.ttl_rounds = t;
        self
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_sweep_mode(mut self, m: SweepMode) -> Self {
        self.sweep = m;
        self
    }

    pub fn with_policy(mut self, p: RacePolicy) -> Self {
        self.policy = p;
        self
    }

    pub fn with_profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    pub fn with_record_traces(mut self, on: bool) -> Self {
        self.record_traces = on;
        self
    }

    pub fn with_store_bytes(mut self, b: usize) -> Self {
        self.store_bytes = b;
        self
    }

    pub fn with_queue_cap(mut self, c: usize) -> Self {
        self.queue_cap = c;
        self
    }

    pub fn with_flight(mut self, on: bool) -> Self {
        self.flight = on;
        self
    }

    /// Validate the pair of config-file knobs (`engine_lanes`,
    /// `engine_ttl_rounds`) — shared by [`EngineConfig::validate`] and
    /// `RunConfig` JSON/CLI admission so both reject the same values with
    /// the same typed error.
    pub fn validate_knobs(lanes: usize, ttl_rounds: usize) -> Result<(), EngineConfigError> {
        if lanes == 0 {
            return Err(EngineConfigError::ZeroLanes);
        }
        if lanes > MAX_ENGINE_LANES {
            return Err(EngineConfigError::AbsurdLanes(lanes));
        }
        if ttl_rounds == 0 {
            return Err(EngineConfigError::ZeroTtl);
        }
        if ttl_rounds > MAX_ENGINE_TTL {
            return Err(EngineConfigError::AbsurdTtl(ttl_rounds));
        }
        Ok(())
    }

    /// Reject configurations the round loop cannot run under.
    pub fn validate(&self) -> Result<(), EngineConfigError> {
        Self::validate_knobs(self.lanes, self.ttl_rounds)?;
        if self.width == 0 {
            return Err(EngineConfigError::ZeroWidth);
        }
        if self.workers == 0 {
            return Err(EngineConfigError::ZeroWorkers);
        }
        if self.workers > MAX_ENGINE_WORKERS {
            return Err(EngineConfigError::AbsurdWorkers(self.workers));
        }
        if self.queue_cap == 0 {
            return Err(EngineConfigError::ZeroQueueCap);
        }
        Ok(())
    }
}

/// Aggregate accounting for one engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Joint rounds performed (each round sweeps one panel per live
    /// operator — the cross-operator cost model the experiments report).
    pub rounds: usize,
    /// Total `matvec_multi` panel sweeps across every session (≥ rounds:
    /// a round with `k` live operators spends `k` sweeps).
    pub sweeps: usize,
    /// Queries accepted.
    pub submitted: usize,
    /// Sessions spun up lazily.
    pub sessions_spun: usize,
    /// Idle sessions evicted by the TTL.
    pub sessions_evicted: usize,
    /// Queries parked by the lane budget.
    pub parks: usize,
    /// Parked queries resumed.
    pub resumes: usize,
    /// Largest per-round live-lane demand actually admitted.
    pub peak_live_lanes: usize,
    /// Lanes retired by interval dominance across every session
    /// (harvested from the [`RetireEvent`](super::block::RetireEvent)
    /// log — sweeps the pruning saved).
    pub retired_dominated: usize,
    /// Lanes retired because the surrounding decision resolved first.
    pub retired_decided: usize,
    /// In-flight queries shed by backpressure ([`Engine::try_submit`]
    /// over [`EngineConfig::queue_cap`]); each shed query still resolved
    /// to its current valid bracket.
    pub shed: usize,
    /// Ticket slots freed by [`Engine::take_answer`] — the compaction
    /// rate that keeps a resident engine's ticket log bounded.
    pub compactions: usize,
    /// Work-stealing sweep claims that landed outside the claiming
    /// worker's fair static share ([`SweepMode::Stealing`] only) — each
    /// one is a session a static split would have left waiting behind a
    /// slower neighbor. Exported as `engine.profile.steal_count`.
    pub steals: usize,
    /// Rounds dispatched to an already-warm persistent sweep pool
    /// (every stealing fan-out after the first): the thread-spawn
    /// overhead the pool saved versus per-round scoped threads.
    /// Exported as `engine.profile.pool_reuse`.
    pub pool_reuse: usize,
}

/// Cumulative round-loop profile, collected when
/// [`EngineConfig::profile`] is set (see [`Engine::profile`]).
///
/// Phase timings split each round into its three serial phases —
/// scheduling/refill ([`Engine`]'s lane-budget pass), the panel sweep
/// (every live session's `matvec_multi` panel + bound updates), and
/// harvest (answer pulling + TTL eviction). Worker utilization compares
/// the summed per-session step time (`busy_ns`) against what the engaged
/// workers *could* have done during the sweep wall time (`capacity_ns`),
/// so fan-out tail idleness is a measured number instead of folklore —
/// the skewed-workload drop from [`SweepMode::Static`] to
/// [`SweepMode::Stealing`] shows up directly in
/// `engine.profile.worker_idle_frac`. `step_ns` aggregates per-session
/// step times from per-worker thread-local histograms merged at round
/// end — profiling adds no shared mutable state to the sweep.
#[derive(Clone, Debug, Default)]
pub struct RoundProfile {
    /// Rounds that contributed to this profile.
    pub rounds: usize,
    /// Total ns in the lane-budget scheduling pass.
    pub schedule_ns: u64,
    /// Total wall-clock ns in the panel sweep phase.
    pub sweep_ns: u64,
    /// Total ns in answer harvest + TTL eviction.
    pub harvest_ns: u64,
    /// Summed per-session step time across all workers.
    pub busy_ns: u64,
    /// Sweep wall time × engaged workers: the time the sweep *bought*.
    pub capacity_ns: u64,
    /// Distribution of individual `Session::step` times (ns).
    pub step_ns: Histogram,
}

impl RoundProfile {
    /// Fraction of bought worker time spent stepping sessions.
    pub fn busy_frac(&self) -> f64 {
        if self.capacity_ns == 0 {
            0.0
        } else {
            (self.busy_ns as f64 / self.capacity_ns as f64).min(1.0)
        }
    }

    /// Fraction of bought worker time spent idle — the measured
    /// tail-idleness of the sweep fan-out (the number the work-stealing
    /// sweep exists to drive down on skewed rounds; compare
    /// [`SweepMode`] variants on the same workload to see the gap).
    pub fn idle_frac(&self) -> f64 {
        if self.capacity_ns == 0 {
            0.0
        } else {
            1.0 - self.busy_frac()
        }
    }
}

// ---------------------------------------------------------------------------
// Operator store
// ---------------------------------------------------------------------------

/// One resident operator: the shared handle, its byte cost (via
/// [`SymOp::nbytes`]), and its LRU/pin state.
struct StoreEntry {
    key: OpKey,
    op: Arc<dyn SymOp>,
    bytes: usize,
    /// Engine round of the last release/touch — the LRU clock.
    last_used: u64,
    /// Pinned while a live session runs on this operator; pinned entries
    /// are immune to the byte budget.
    pinned: bool,
}

/// The engine's owned operator cache: `Arc<dyn SymOp>` entries keyed by
/// [`OpKey`], pinned while their session is live and LRU-evicted (oldest
/// release first) once the resident bytes of *released* operators exceed
/// the [`EngineConfig::store_bytes`] budget.
///
/// The store is what frees [`Engine`] from borrowed-operator lifetimes:
/// callers hand over a ref-counted operator once and may drop their own
/// handle; re-submissions under a warm key ([`Engine::submit_keyed`])
/// need no operator at all. Anonymous keys ([`ANON_KEY_BASE`] and above)
/// can never be re-addressed, so they are dropped outright — not kept
/// warm — when their session is evicted.
pub struct OpStore {
    entries: Vec<StoreEntry>,
    budget: usize,
    inserted: u64,
    evicted: u64,
}

impl OpStore {
    fn new(budget: usize) -> Self {
        OpStore { entries: Vec::new(), budget, inserted: 0, evicted: 0 }
    }

    fn find(&self, key: OpKey) -> Option<usize> {
        self.entries.iter().position(|e| e.key == key)
    }

    /// Make `op` resident under `key` and pin it; an already-resident
    /// key re-pins the *stored* operator and ignores `op` (the co-keyed
    /// submission contract: one operator per key). Returns the canonical
    /// handle the session should run on.
    fn insert(&mut self, key: OpKey, op: Arc<dyn SymOp>, now: u64) -> Arc<dyn SymOp> {
        if let Some(i) = self.find(key) {
            let e = &mut self.entries[i];
            e.pinned = true;
            e.last_used = now;
            return Arc::clone(&e.op);
        }
        let bytes = op.nbytes();
        self.entries.push(StoreEntry {
            key,
            op: Arc::clone(&op),
            bytes,
            last_used: now,
            pinned: true,
        });
        self.inserted += 1;
        op
    }

    /// Make `op` resident without pinning (no session spun): later
    /// keyed submissions find it warm, and the byte budget may evict it.
    fn preload(&mut self, key: OpKey, op: Arc<dyn SymOp>, now: u64) {
        if let Some(i) = self.find(key) {
            self.entries[i].last_used = now;
            return;
        }
        let bytes = op.nbytes();
        self.entries.push(StoreEntry { key, op, bytes, last_used: now, pinned: false });
        self.inserted += 1;
        self.enforce_budget();
    }

    /// Refresh the LRU clock of a key whose session is still live.
    fn touch(&mut self, key: OpKey, now: u64) {
        if let Some(i) = self.find(key) {
            self.entries[i].last_used = now;
        }
    }

    /// Unpin `key` (its session was evicted). User keys stay warm under
    /// the LRU clock; anonymous keys are dropped outright.
    fn release(&mut self, key: OpKey, now: u64) {
        if key >= ANON_KEY_BASE {
            let before = self.entries.len();
            self.entries.retain(|e| e.key != key);
            self.evicted += (before - self.entries.len()) as u64;
            return;
        }
        if let Some(i) = self.find(key) {
            let e = &mut self.entries[i];
            e.pinned = false;
            e.last_used = now;
        }
    }

    /// Evict released operators, oldest first, until the resident bytes
    /// fit the budget. Pinned entries never move: the budget bounds the
    /// warm cache, not live work.
    fn enforce_budget(&mut self) {
        while self.resident_bytes() > self.budget {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| !e.pinned)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            match victim {
                Some(i) => {
                    self.entries.remove(i);
                    self.evicted += 1;
                }
                None => break, // everything resident is pinned
            }
        }
    }

    /// Resident operators (pinned + warm).
    pub fn resident(&self) -> usize {
        self.entries.len()
    }

    /// Operators pinned by a live session.
    pub fn pinned(&self) -> usize {
        self.entries.iter().filter(|e| e.pinned).count()
    }

    /// Total bytes of resident operators ([`SymOp::nbytes`] at insert).
    pub fn resident_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes).sum()
    }

    /// Operators ever inserted.
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Operators evicted (budget LRU + dropped anonymous keys).
    pub fn evicted(&self) -> u64 {
        self.evicted
    }

    /// True while `key` is resident (pinned or warm).
    pub fn contains(&self, key: OpKey) -> bool {
        self.find(key).is_some()
    }

    /// The resident operator behind `key`, if any.
    pub fn get(&self, key: OpKey) -> Option<Arc<dyn SymOp>> {
        self.find(key).map(|i| Arc::clone(&self.entries[i].op))
    }
}

// ---------------------------------------------------------------------------
// Tickets
// ---------------------------------------------------------------------------

/// Handle to one submitted query: a slab index plus the generation the
/// slot carried at submission. [`Engine::take_answer`] compacts the slot
/// and bumps its generation, so a retained stale ticket errors instead
/// of aliasing whatever query reuses the slot.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Ticket {
    idx: u32,
    gen: u32,
}

/// Why a [`Ticket`] could not produce an answer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TicketError {
    /// The ticket's slot was compacted (its answer was already taken) or
    /// the ticket never came from this engine — its generation does not
    /// match the slot.
    Stale,
    /// The query behind the ticket has not resolved yet.
    Unresolved,
}

impl fmt::Display for TicketError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TicketError::Stale => write!(f, "stale ticket: its slot was compacted or reused"),
            TicketError::Unresolved => write!(f, "ticket not resolved yet"),
        }
    }
}

impl std::error::Error for TicketError {}

/// Why an admission-checked submission was not accepted.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SubmitError {
    /// [`Engine::submit_keyed`] addressed a key with no resident
    /// operator (never submitted, or evicted from the store).
    UnknownKey(OpKey),
    /// The queue is at [`EngineConfig::queue_cap`] and no in-flight
    /// query has a bracket to shed with yet — the caller should retry
    /// after a round or drop the request.
    Saturated,
    /// The query carries a structurally invalid stochastic config
    /// (zero probes, non-finite tolerance, unsupported power) — refused
    /// at admission before any lane or shed is spent, mirroring
    /// [`EngineConfigError`].
    Invalid(SlqConfigError),
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::UnknownKey(k) => write!(f, "no resident operator under key {k}"),
            SubmitError::Saturated => {
                write!(f, "engine saturated: queue at capacity with nothing sheddable")
            }
            SubmitError::Invalid(e) => write!(f, "invalid stochastic query config: {e}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Ticket bookkeeping: which session/query answers it, its admission
/// priority, and the harvested answer once resolved (sessions may be
/// evicted afterwards).
struct TicketState {
    key: OpKey,
    qid: usize,
    /// Global submission order — the FIFO tiebreak.
    seq: u64,
    /// Scheduling slack: engine round by which work must *start* to make
    /// the deadline, given the sweep estimate. `u64::MAX` for deadline-
    /// free submissions, which therefore run FIFO after every deadline.
    urgency: u64,
    /// Estimated lane cost (admission accounting; 1 per estimate lane).
    cost: u64,
    /// Estimates may be shed mid-flight (their bracket is an answer);
    /// decision queries may not.
    sheddable: bool,
    /// Engine round at submission — flight-recorder rounds accounting.
    submit_round: u64,
    /// Recorder timestamp at submission (0 with the recorder off) — the
    /// `Answered` event's wall-time base.
    submit_ns: u64,
    answer: Option<Answer>,
}

/// One slab slot: the current generation plus the live state, `None`
/// once compacted (a tombstone awaiting reuse).
struct TicketSlot {
    gen: u32,
    state: Option<TicketState>,
}

// ---------------------------------------------------------------------------
// Engine
// ---------------------------------------------------------------------------

/// One live operator: its session, the canonical store handle it runs
/// on, and the tickets still pointing at it.
struct OpSlot {
    key: OpKey,
    op: Arc<dyn SymOp>,
    session: Session,
    /// Tickets not yet harvested into answers.
    open: Vec<Ticket>,
    /// Consecutive workless harvests (drives TTL eviction).
    idle_rounds: usize,
    /// Session sweep count at the last harvest (delta accounting).
    last_sweeps: usize,
    /// Retire-log length at the last harvest (delta accounting for the
    /// dominated/decided counters).
    last_retired: usize,
    /// Set by the planner each round; read by the sweep workers.
    live: bool,
}

impl OpSlot {
    /// One panel sweep of this slot's session against its own operator
    /// (disjoint-field borrow: the session steps while the op is read).
    fn step(&mut self) {
        let OpSlot { session, op, .. } = self;
        session.step(&**op);
    }
}

/// The always-on scheduler. See the module docs for the design; the
/// lifecycle is: [`Engine::submit`] / [`Engine::try_submit`] (any time,
/// including mid-flight) → [`Engine::step_round`] / [`Engine::drain`] →
/// [`Engine::take_answer`].
///
/// Tickets live in a generation-tagged slab: [`Engine::take_answer`]
/// tombstones the slot for reuse, so a resident engine's ticket memory
/// is bounded by its open queries. [`Engine::answer`] peeks without
/// compacting for callers that want the borrow; per-burst consumers that
/// never call `take_answer` simply grow the slab for the burst's
/// lifetime, same as before.
pub struct Engine {
    cfg: EngineConfig,
    store: OpStore,
    slots: Vec<OpSlot>,
    tickets: Vec<TicketSlot>,
    /// Compacted slab slots awaiting reuse.
    free: Vec<u32>,
    /// Unresolved tickets in scheduling order: stale/answered entries
    /// drop out each round and the rest stable-sort by (urgency, seq).
    order: Vec<Ticket>,
    /// Monotone submission counter (the FIFO tiebreak).
    seq: u64,
    /// Open (unanswered) tickets — the backpressure measure.
    open: usize,
    stats: EngineStats,
    /// Round-loop profile, allocated iff [`EngineConfig::profile`] —
    /// `None` keeps the unprofiled hot path free of even a branch-y
    /// accumulation.
    profile: Option<Box<RoundProfile>>,
    /// Persistent sweep workers for [`SweepMode::Stealing`]: spawned
    /// lazily on the first multi-session parallel round, then parked on
    /// a condvar between rounds and reused until the engine drops
    /// (`stats.pool_reuse` counts the reuses). `None` until then, so
    /// single-worker engines never pay for a pool.
    pool: Option<SweepPool>,
    /// Query-lifecycle flight recorder, allocated iff
    /// [`EngineConfig::flight`]. Shared (`Arc`) so serving binaries can
    /// scrape and dump it while the engine runs.
    flight: Option<Arc<FlightRecorder>>,
    next_anon: OpKey,
}

impl Engine {
    /// Build an engine, rejecting unusable knobs with a typed error.
    pub fn new(cfg: EngineConfig) -> Result<Self, EngineConfigError> {
        cfg.validate()?;
        Ok(Engine {
            cfg,
            store: OpStore::new(cfg.store_bytes),
            slots: Vec::new(),
            tickets: Vec::new(),
            free: Vec::new(),
            order: Vec::new(),
            seq: 0,
            open: 0,
            stats: EngineStats::default(),
            profile: cfg.profile.then(|| Box::new(RoundProfile::default())),
            pool: None,
            flight: cfg.flight.then(|| Arc::new(FlightRecorder::new())),
            next_anon: ANON_KEY_BASE,
        })
    }

    /// Record one lifecycle event for `span` (no-op with the recorder
    /// off).
    #[inline]
    fn emit(&self, span: SpanId, kind: FlightEventKind) {
        if let Some(f) = &self.flight {
            f.record(span, kind);
        }
    }

    /// The engine's flight recorder, for scrape/dump consumers (`None`
    /// when [`EngineConfig::flight`] is off). Clone the `Arc` to read it
    /// from other threads while the engine runs.
    pub fn flight(&self) -> Option<&Arc<FlightRecorder>> {
        self.flight.as_ref()
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// The operator store (residency/eviction accounting).
    pub fn store(&self) -> &OpStore {
        &self.store
    }

    /// The collected round profile ([`EngineConfig::profile`] engines
    /// only).
    pub fn profile(&self) -> Option<&RoundProfile> {
        self.profile.as_deref()
    }

    /// Publish stats (and the round profile, when collected) into `reg`
    /// under `engine.*` names. Idempotent set-style writes.
    pub fn export_into(&self, reg: &MetricsRegistry) {
        let st = &self.stats;
        reg.set_counter("engine.rounds", st.rounds as u64);
        reg.set_counter("engine.sweeps", st.sweeps as u64);
        reg.set_counter("engine.submitted", st.submitted as u64);
        reg.set_counter("engine.sessions_spun", st.sessions_spun as u64);
        reg.set_counter("engine.sessions_evicted", st.sessions_evicted as u64);
        reg.set_counter("engine.parks", st.parks as u64);
        reg.set_counter("engine.resumes", st.resumes as u64);
        reg.set_counter("engine.retired_dominated", st.retired_dominated as u64);
        reg.set_counter("engine.retired_decided", st.retired_decided as u64);
        reg.set_gauge("engine.peak_live_lanes", st.peak_live_lanes as f64);
        reg.set_gauge("engine.live_sessions", self.slots.len() as f64);
        reg.set_gauge("engine.open_tickets", self.open as f64);
        reg.set_gauge("engine.store.resident", self.store.resident() as f64);
        reg.set_gauge("engine.store.pinned", self.store.pinned() as f64);
        reg.set_gauge("engine.store.resident_bytes", self.store.resident_bytes() as f64);
        reg.set_counter("engine.store.inserted", self.store.inserted());
        reg.set_counter("engine.store.evicted", self.store.evicted());
        reg.set_counter("engine.admission.admitted", st.submitted as u64);
        reg.set_counter("engine.admission.parked", st.parks as u64);
        reg.set_counter("engine.admission.shed", st.shed as u64);
        reg.set_counter("engine.admission.compactions", st.compactions as u64);
        reg.set_counter("engine.profile.steal_count", st.steals as u64);
        reg.set_counter("engine.profile.pool_reuse", st.pool_reuse as u64);
        reg.set_gauge("engine.profile.kernel_lane_width", crate::sparse::PANEL_PAD as f64);
        if let Some(p) = self.profile.as_deref() {
            reg.set_counter("engine.profile.rounds", p.rounds as u64);
            reg.set_counter("engine.profile.schedule_ns", p.schedule_ns);
            reg.set_counter("engine.profile.sweep_ns", p.sweep_ns);
            reg.set_counter("engine.profile.harvest_ns", p.harvest_ns);
            reg.set_counter("engine.profile.busy_ns", p.busy_ns);
            reg.set_counter("engine.profile.capacity_ns", p.capacity_ns);
            reg.set_gauge("engine.profile.worker_busy_frac", p.busy_frac());
            reg.set_gauge("engine.profile.worker_idle_frac", p.idle_frac());
            reg.set_histogram("engine.profile.step_ns", p.step_ns.clone());
        }
        if let Some(f) = &self.flight {
            f.export_into(reg);
        }
    }

    /// Live (not yet evicted) sessions.
    pub fn sessions(&self) -> usize {
        self.slots.len()
    }

    /// Open (unanswered) tickets — what [`EngineConfig::queue_cap`]
    /// bounds.
    pub fn open_tickets(&self) -> usize {
        self.open
    }

    /// Slab slots currently holding a query or retained answer (total
    /// minus compacted) — the measure [`Engine::take_answer`] bounds.
    pub fn live_tickets(&self) -> usize {
        self.tickets.len() - self.free.len()
    }

    /// Snapshot every in-flight (unanswered) ticket as a [`LiveSpan`],
    /// sorted by span id (= admission order). Read-only: walks the open
    /// lists and asks each session for its latest bracket, so it is safe
    /// to call between rounds from an introspection endpoint.
    pub fn live_spans(&self) -> Vec<LiveSpan> {
        let now = self.stats.rounds as u64;
        let mut out = Vec::with_capacity(self.open);
        for slot in &self.slots {
            for tk in &slot.open {
                let Some(st) = self.ticket_state(*tk) else {
                    continue;
                };
                if st.answer.is_some() {
                    continue;
                }
                out.push(LiveSpan {
                    span: st.seq,
                    key: slot.key,
                    rounds_elapsed: now.saturating_sub(st.submit_round),
                    bounds: slot.session.bounds(st.qid),
                    parked: slot.session.is_parked(st.qid),
                });
            }
        }
        out.sort_by_key(|s| s.span);
        out
    }

    /// Flight-recorder span id of a ticket (its admission sequence
    /// number), or `None` for stale tickets.
    pub fn span_of(&self, ticket: Ticket) -> Option<SpanId> {
        self.ticket_state(ticket).map(|st| st.seq)
    }

    /// A key guaranteed not to collide with other [`Engine::fresh_key`]
    /// keys (consumers without a natural operator id — `race_dg_joint`'s
    /// per-element sides — use these; keep user keys below
    /// [`ANON_KEY_BASE`]).
    pub fn fresh_key(&mut self) -> OpKey {
        let k = self.next_anon;
        self.next_anon += 1;
        k
    }

    fn slot_index(&self, key: OpKey) -> Option<usize> {
        self.slots.iter().position(|s| s.key == key)
    }

    fn ticket_state(&self, t: Ticket) -> Option<&TicketState> {
        self.tickets
            .get(t.idx as usize)
            .filter(|s| s.gen == t.gen)
            .and_then(|s| s.state.as_ref())
    }

    fn alloc_ticket(&mut self, st: TicketState) -> Ticket {
        if let Some(idx) = self.free.pop() {
            let slot = &mut self.tickets[idx as usize];
            debug_assert!(slot.state.is_none(), "free list held a live slot");
            slot.state = Some(st);
            return Ticket { idx, gen: slot.gen };
        }
        let idx = self.tickets.len() as u32;
        self.tickets.push(TicketSlot { gen: 0, state: Some(st) });
        Ticket { idx, gen: 0 }
    }

    /// Make `op` resident under `key` without spinning a session: later
    /// [`Engine::submit_keyed`] calls find it warm. The entry is
    /// unpinned, so the store budget may evict it before use.
    pub fn preload(&mut self, key: OpKey, op: Arc<dyn SymOp>) {
        let now = self.stats.rounds as u64;
        self.store.preload(key, op, now);
    }

    /// Look up — or lazily spin up — the session for `key`, with an
    /// explicit panel width and race policy for the spin-up case (an
    /// existing session keeps its own). The operator is pinned in the
    /// store for the session's lifetime; if `key` is already resident
    /// the *stored* operator is canonical and `op` is ignored (co-keyed
    /// submissions target one operator). Returns the slot index for
    /// [`Engine::submit_to`].
    pub fn spin_up(
        &mut self,
        key: OpKey,
        op: Arc<dyn SymOp>,
        opts: GqlOptions,
        width: usize,
        policy: RacePolicy,
    ) -> usize {
        let now = self.stats.rounds as u64;
        if let Some(i) = self.slot_index(key) {
            self.store.touch(key, now);
            return i;
        }
        let canonical = self.store.insert(key, op, now);
        let mut session = Session::new(&*canonical, opts, width.max(1), policy);
        if self.cfg.record_traces {
            session = session.record_traces(true);
        }
        self.slots.push(OpSlot {
            key,
            op: canonical,
            session,
            open: Vec::new(),
            idle_rounds: 0,
            last_sweeps: 0,
            last_retired: 0,
            live: false,
        });
        self.stats.sessions_spun += 1;
        self.slots.len() - 1
    }

    /// [`Engine::spin_up`] from the warm store alone: succeeds iff `key`
    /// is already resident (live session or warm operator). The keyed
    /// re-admission path — no operator crosses the API.
    pub fn spin_up_keyed(
        &mut self,
        key: OpKey,
        opts: GqlOptions,
        width: usize,
        policy: RacePolicy,
    ) -> Option<usize> {
        if let Some(i) = self.slot_index(key) {
            return Some(i);
        }
        let op = self.store.get(key)?;
        Some(self.spin_up(key, op, opts, width, policy))
    }

    /// Streaming submission: enter `q` against the operator behind
    /// `key`, spinning up a session lazily (with the engine-default
    /// width and policy) and pinning `op` in the store. Accepted
    /// mid-flight — the query's lanes land in the next round's panel for
    /// that operator. Infallible and deadline-free: this is the trusted
    /// in-process path that bypasses [`EngineConfig::queue_cap`];
    /// service front ends use [`Engine::try_submit`]. Returns a ticket
    /// for [`Engine::take_answer`].
    pub fn submit(
        &mut self,
        key: OpKey,
        op: Arc<dyn SymOp>,
        opts: GqlOptions,
        q: Query,
    ) -> Ticket {
        let (width, policy) = (self.cfg.width, self.cfg.policy);
        let slot = self.spin_up(key, op, opts, width, policy);
        self.submit_to_with(slot, q, None)
    }

    /// Admission-checked submission with an optional deadline (engine
    /// rounds from now the caller is willing to wait). Scheduling runs
    /// most-urgent-first — urgency is the slack between the deadline and
    /// the estimated sweeps ([`estimate_cost`]) — and when open tickets
    /// reach [`EngineConfig::queue_cap`] the least-urgent in-flight
    /// estimate is shed to make room: it resolves *now* to its current
    /// four-bound bracket (the anytime property — still a certified
    /// enclosure, just wider than a full run's). With nothing sheddable
    /// the submission is refused as [`SubmitError::Saturated`].
    pub fn try_submit(
        &mut self,
        key: OpKey,
        op: Arc<dyn SymOp>,
        opts: GqlOptions,
        q: Query,
        deadline: Option<u64>,
    ) -> Result<Ticket, SubmitError> {
        q.validate().map_err(SubmitError::Invalid)?;
        if self.open >= self.cfg.queue_cap {
            self.shed_one()?;
        }
        let (width, policy) = (self.cfg.width, self.cfg.policy);
        let slot = self.spin_up(key, op, opts, width, policy);
        Ok(self.submit_to_with(slot, q, deadline))
    }

    /// [`Engine::try_submit`] against a key whose operator is already
    /// resident — the warm path a service front end uses for repeat
    /// tenants (no operator crosses the API).
    pub fn submit_keyed(
        &mut self,
        key: OpKey,
        opts: GqlOptions,
        q: Query,
        deadline: Option<u64>,
    ) -> Result<Ticket, SubmitError> {
        q.validate().map_err(SubmitError::Invalid)?;
        if self.open >= self.cfg.queue_cap {
            self.shed_one()?;
        }
        let (width, policy) = (self.cfg.width, self.cfg.policy);
        let slot = self
            .spin_up_keyed(key, opts, width, policy)
            .ok_or(SubmitError::UnknownKey(key))?;
        Ok(self.submit_to_with(slot, q, deadline))
    }

    /// [`Engine::submit`] against a slot obtained from
    /// [`Engine::spin_up`] (callers that pick per-operator widths or
    /// policies, like the coordinator's native drain).
    pub fn submit_to(&mut self, slot: usize, q: Query) -> Ticket {
        self.submit_to_with(slot, q, None)
    }

    /// [`Engine::submit_to`] with an optional deadline (see
    /// [`Engine::try_submit`] for the semantics).
    pub fn submit_to_with(&mut self, slot: usize, q: Query, deadline: Option<u64>) -> Ticket {
        let n = self.slots[slot].op.dim();
        let (est_rounds, cost) = estimate_cost(&q, n);
        let sheddable =
            matches!(q, Query::Estimate { .. } | Query::Trace { .. } | Query::LogDet { .. });
        let urgency = match deadline {
            Some(d) => (self.stats.rounds as u64 + d).saturating_sub(est_rounds),
            None => u64::MAX,
        };
        // the submission sequence number doubles as the query's flight
        // span id: unique for the engine's lifetime, known at admission
        let span = self.seq;
        self.seq += 1;
        let submit_round = self.stats.rounds as u64;
        let submit_ns = self.flight.as_ref().map_or(0, |f| f.now_ns());
        self.emit(span, FlightEventKind::Submitted);
        self.emit(
            span,
            FlightEventKind::Admitted { cost, deadline: deadline.unwrap_or(u64::MAX) },
        );
        let (key, qid, lanes, answer) = {
            let s = &mut self.slots[slot];
            let qid = s.session.submit(q);
            // trivially-decidable queries (zero vectors, empty argmax
            // batches) answer at submission without ever taking a lane
            (s.key, qid, s.session.lane_demand(qid), s.session.answer(qid).cloned())
        };
        let resolved = answer.is_some();
        if resolved {
            self.emit(span, FlightEventKind::Answered { rounds: 0, wall_ns: 0 });
        } else {
            self.emit(
                span,
                FlightEventKind::PlannedOntoPanel { op_key: key, lanes: lanes as u32 },
            );
        }
        let ticket = self.alloc_ticket(TicketState {
            key,
            qid,
            seq: span,
            urgency,
            cost,
            sheddable,
            submit_round,
            submit_ns,
            answer,
        });
        if !resolved {
            let s = &mut self.slots[slot];
            s.open.push(ticket);
            s.idle_rounds = 0;
            self.order.push(ticket);
            self.open += 1;
        }
        self.stats.submitted += 1;
        ticket
    }

    /// Shed the least-urgent in-flight anytime query (largest slack,
    /// then youngest) that already carries a bracket: estimates resolve
    /// to their four-bound snapshot, stochastic trace/logdet queries to
    /// the combined interval over the probes that have contributed so
    /// far. `Err(Saturated)` when nothing qualifies — decision queries
    /// and not-yet-swept anytime queries have no valid answer to shed
    /// with.
    fn shed_one(&mut self) -> Result<(), SubmitError> {
        let mut victim: Option<((u64, u64), Ticket)> = None;
        for &t in &self.order {
            let Some(st) = self.ticket_state(t) else { continue };
            if st.answer.is_some() || !st.sheddable {
                continue;
            }
            let ready = self
                .slot_index(st.key)
                .is_some_and(|i| self.slots[i].session.can_cancel(st.qid));
            if !ready {
                continue; // no bracket yet: nothing valid to answer with
            }
            let rank = (st.urgency, st.seq);
            if victim.map_or(true, |(best, _)| rank > best) {
                victim = Some((rank, t));
            }
        }
        match victim {
            Some((_, t)) => {
                if self.flight.is_some() {
                    // the bracket the victim resolves to (single-lane
                    // kinds; stochastic sheds answer with their combined
                    // interval, which NaN endpoints defer to)
                    let span = self.ticket_state(t).map(|st| st.seq);
                    let (lo, hi) = self
                        .bounds(t)
                        .map_or((f64::NAN, f64::NAN), |b| (b.lower(), b.upper()));
                    if let Some(span) = span {
                        self.emit(span, FlightEventKind::Shed { lo, hi });
                    }
                }
                let ok = self.cancel(t);
                debug_assert!(ok, "shed victim had a bracket but would not cancel");
                self.stats.shed += 1;
                Ok(())
            }
            None => Err(SubmitError::Saturated),
        }
    }

    /// The harvested answer of `ticket`, if resolved — a peek that
    /// leaves the slot intact (stale tickets read as `None`).
    pub fn answer(&self, ticket: Ticket) -> Option<&Answer> {
        self.ticket_state(ticket).and_then(|st| st.answer.as_ref())
    }

    /// True once `ticket` carries an answer (stale tickets read false).
    pub fn is_resolved(&self, ticket: Ticket) -> bool {
        self.ticket_state(ticket).is_some_and(|st| st.answer.is_some())
    }

    /// Move the answer out and compact the ticket slot: the slot's
    /// generation bumps and the index returns to the free list, so the
    /// taken ticket — and any copy of it — is permanently stale. The
    /// compaction path that keeps a resident engine's ticket log bounded
    /// by its open queries.
    pub fn take_answer(&mut self, ticket: Ticket) -> Result<Answer, TicketError> {
        let slot = self
            .tickets
            .get_mut(ticket.idx as usize)
            .filter(|s| s.gen == ticket.gen)
            .ok_or(TicketError::Stale)?;
        match &slot.state {
            None => Err(TicketError::Stale),
            Some(st) if st.answer.is_none() => Err(TicketError::Unresolved),
            Some(_) => {
                let st = slot.state.take().expect("checked above");
                slot.gen = slot.gen.wrapping_add(1);
                self.free.push(ticket.idx);
                self.stats.compactions += 1;
                Ok(st.answer.expect("checked above"))
            }
        }
    }

    /// Latest bracket of a single-lane (estimate/threshold) ticket:
    /// mid-flight snapshot while racing, final bounds after resolution.
    /// Cross-operator consumers decide from these between rounds.
    pub fn bounds(&self, ticket: Ticket) -> Option<Bounds> {
        let st = self.ticket_state(ticket)?;
        if let Some(Answer::Estimate { bounds, .. }) = &st.answer {
            return Some(*bounds);
        }
        self.slot_index(st.key)
            .and_then(|i| self.slots[i].session.bounds(st.qid))
    }

    /// Resolve an anytime (estimate or stochastic) ticket right now with
    /// its latest snapshot (see [`Session::cancel`]); its lanes stop
    /// consuming sweeps.
    pub fn cancel(&mut self, ticket: Ticket) -> bool {
        let (key, qid) = match self.ticket_state(ticket) {
            Some(st) if st.answer.is_none() => (st.key, st.qid),
            _ => return false,
        };
        let Some(i) = self.slot_index(key) else {
            return false;
        };
        if !self.slots[i].session.cancel(qid) {
            return false;
        }
        let ans = self.slots[i].session.answer(qid).cloned();
        debug_assert!(ans.is_some(), "cancel resolved the query");
        // the cancel retired lanes; account them while the ticket is
        // still in the slot's open list so the flight recorder can
        // attribute the retire events to its span — and because no
        // harvest may follow if this was the engine's last open ticket
        drain_retire_log(
            &mut self.slots[i],
            &mut self.stats,
            &self.tickets,
            self.flight.as_deref(),
        );
        let now = self.stats.rounds as u64;
        let st = self.tickets[ticket.idx as usize]
            .state
            .as_mut()
            .expect("ticket_state checked the slot");
        st.answer = ans;
        if let Some(f) = &self.flight {
            f.record(
                st.seq,
                FlightEventKind::Answered {
                    rounds: now.saturating_sub(st.submit_round),
                    wall_ns: f.now_ns().saturating_sub(st.submit_ns),
                },
            );
        }
        self.open -= 1;
        self.slots[i].open.retain(|&t| t != ticket);
        true
    }

    /// True while some ticket has no answer yet.
    pub fn has_work(&self) -> bool {
        self.open > 0
    }

    /// The admission-priority lane-budget pass: drop stale/answered
    /// tickets out of the order, stable-sort the rest by (urgency, seq)
    /// — deadline slack first, submission order as the tiebreak — then
    /// walk it keeping queries live while the budget holds and parking
    /// the rest. The head-of-line query always runs whole — the budget
    /// never splits a query's lanes, so a width-2 compare under
    /// `lanes = 1` runs alone rather than deadlocking.
    fn schedule(&mut self) {
        let tickets = &self.tickets;
        self.order.retain(|t| {
            tickets
                .get(t.idx as usize)
                .filter(|s| s.gen == t.gen)
                .and_then(|s| s.state.as_ref())
                .is_some_and(|st| st.answer.is_none())
        });
        self.order.sort_by_key(|t| {
            let st = tickets[t.idx as usize].state.as_ref().expect("retained above");
            (st.urgency, st.seq)
        });
        let budget = self.cfg.lanes;
        let mut used = 0usize;
        let pending: Vec<(OpKey, usize, u64)> = self
            .order
            .iter()
            .map(|t| {
                let st = self.tickets[t.idx as usize].state.as_ref().expect("retained");
                (st.key, st.qid, st.seq)
            })
            .collect();
        for (key, qid, span) in pending {
            let Some(i) = self.slot_index(key) else {
                continue;
            };
            let slot = &mut self.slots[i];
            if slot.session.is_resolved(qid) {
                continue; // resolved this round; harvested after the sweep
            }
            let demand = slot.session.lane_demand(qid).max(1);
            if used == 0 || used + demand <= budget {
                if slot.session.is_parked(qid) && slot.session.resume_query(qid) {
                    self.stats.resumes += 1;
                    if let Some(f) = &self.flight {
                        f.record(span, FlightEventKind::Resumed);
                    }
                }
                used += demand;
            } else if !slot.session.is_parked(qid) && slot.session.suspend_query(qid) {
                self.stats.parks += 1;
                if let Some(f) = &self.flight {
                    f.record(span, FlightEventKind::Parked);
                }
            }
        }
        if used > self.stats.peak_live_lanes {
            self.stats.peak_live_lanes = used;
        }
    }

    /// Pull freshly-resolved answers out of every session, account
    /// sweeps, evict sessions idle past the TTL (releasing their store
    /// pins), and enforce the store byte budget.
    fn harvest(&mut self) {
        let ttl = self.cfg.ttl_rounds;
        let now = self.stats.rounds as u64;
        let flight = self.flight.clone();
        let flight = flight.as_deref();
        let mut i = 0;
        while i < self.slots.len() {
            let evict = {
                let slot = &mut self.slots[i];
                let sw = slot.session.sweeps();
                self.stats.sweeps += sw - slot.last_sweeps;
                slot.last_sweeps = sw;
                // retire-log delta: counted every harvest, so events are
                // never lost to a same-round TTL eviction
                drain_retire_log(slot, &mut self.stats, &self.tickets, flight);
                let session = &slot.session;
                let tickets = &mut self.tickets;
                let open_count = &mut self.open;
                slot.open.retain(|tk| {
                    let ts = &mut tickets[tk.idx as usize];
                    debug_assert_eq!(ts.gen, tk.gen, "open ticket went stale");
                    let st = ts.state.as_mut().expect("open ticket compacted");
                    match session.answer(st.qid) {
                        Some(a) => {
                            st.answer = Some(a.clone());
                            if let Some(f) = flight {
                                f.record(
                                    st.seq,
                                    FlightEventKind::Answered {
                                        rounds: now.saturating_sub(st.submit_round),
                                        wall_ns: f.now_ns().saturating_sub(st.submit_ns),
                                    },
                                );
                            }
                            *open_count -= 1;
                            false
                        }
                        None => {
                            if let Some(f) = flight {
                                // still racing: snapshot the bracket width
                                // (NaN for multi-lane kinds, whose state is
                                // not a single interval)
                                let gap = session.bounds(st.qid).map_or(f64::NAN, |b| b.gap());
                                f.record(st.seq, FlightEventKind::SweptRound { round: now, gap });
                            }
                            true
                        }
                    }
                });
                if slot.open.is_empty() && !slot.session.has_work() {
                    slot.idle_rounds += 1;
                    slot.idle_rounds > ttl
                } else {
                    slot.idle_rounds = 0;
                    false
                }
            };
            if evict {
                let dead = self.slots.remove(i);
                self.store.release(dead.key, now);
                self.stats.sessions_evicted += 1;
            } else {
                i += 1;
            }
        }
        self.store.enforce_budget();
    }

    /// One joint round: the admission-priority lane-budget pass, then
    /// one panel sweep per live operator (in parallel when configured),
    /// then answer harvest, TTL eviction and store budget enforcement.
    /// Returns `false` (after still harvesting) once no session has work
    /// — every remaining ticket is then resolved.
    pub fn step_round(&mut self) -> bool {
        if self.profile.is_some() {
            return self.step_round_profiled();
        }
        self.schedule();
        let mut live = 0usize;
        for s in &mut self.slots {
            s.live = s.session.has_work();
            if s.live {
                live += 1;
            }
        }
        if live == 0 {
            self.harvest();
            return false;
        }
        if self.cfg.workers > 1 && live > 1 {
            self.sweep_fanout(live, false);
        } else {
            // single worker or a single live session: step inline on the
            // driving thread — no scope, no spawn, no pool
            for s in &mut self.slots {
                if s.live {
                    s.step();
                }
            }
        }
        self.stats.rounds += 1;
        self.harvest();
        true
    }

    /// Fan one multi-session round out over the sweep workers in the
    /// configured [`SweepMode`], merge the per-worker accounting into
    /// the engine stats, and rethrow any worker panic on the driving
    /// thread with the panicking slot's [`OpKey`] attached. Returns
    /// `(step histogram, Σ busy ns, engaged workers)`; the histogram and
    /// busy time are empty/zero when `profiled` is false. `engaged` is
    /// `min(workers, live)` — workers beyond the live-session count can
    /// never hold work, so they don't inflate the capacity the busy
    /// fraction is measured against.
    fn sweep_fanout(&mut self, live: usize, profiled: bool) -> (Histogram, u64, usize) {
        let engaged = self.cfg.workers.min(live).max(1);
        let outcome = match self.cfg.sweep {
            SweepMode::Static => sweep_static(&mut self.slots, self.cfg.workers, profiled),
            SweepMode::Stealing => {
                let helpers = self.cfg.workers - 1;
                let Engine { pool, slots, stats, .. } = self;
                if pool.is_some() {
                    stats.pool_reuse += 1;
                }
                pool.get_or_insert_with(|| SweepPool::new(helpers)).sweep(slots, engaged, profiled)
            }
        };
        self.stats.steals += outcome.steals;
        if let Some((key, payload)) = outcome.panic {
            rethrow_with_slot(key, payload);
        }
        (outcome.steps, outcome.busy_ns, engaged)
    }

    /// [`Engine::step_round`] with phase timing and worker accounting.
    /// Kept as a separate body so the unprofiled loop carries zero
    /// instrumentation; the scheduling/sweep/harvest logic is identical
    /// (timing only reads clocks — it cannot perturb panel math, so
    /// profiled answers stay bit-identical).
    fn step_round_profiled(&mut self) -> bool {
        let t_sched = Instant::now();
        self.schedule();
        let schedule_ns = t_sched.elapsed().as_nanos() as u64;

        let mut live = 0usize;
        for s in &mut self.slots {
            s.live = s.session.has_work();
            if s.live {
                live += 1;
            }
        }
        if live == 0 {
            let t_h = Instant::now();
            self.harvest();
            if let Some(p) = self.profile.as_deref_mut() {
                p.schedule_ns += schedule_ns;
                p.harvest_ns += t_h.elapsed().as_nanos() as u64;
            }
            return false;
        }
        let workers = self.cfg.workers;
        let t_sweep = Instant::now();
        let (steps, busy_ns, engaged) = if workers > 1 && live > 1 {
            self.sweep_fanout(live, true)
        } else {
            let mut h = Histogram::new();
            let mut busy = 0u64;
            for s in &mut self.slots {
                if s.live {
                    let t = Instant::now();
                    s.step();
                    let ns = t.elapsed().as_nanos() as u64;
                    h.record(ns as f64);
                    busy += ns;
                }
            }
            (h, busy, 1)
        };
        let sweep_ns = t_sweep.elapsed().as_nanos() as u64;
        self.stats.rounds += 1;
        let t_h = Instant::now();
        self.harvest();
        let harvest_ns = t_h.elapsed().as_nanos() as u64;
        if let Some(p) = self.profile.as_deref_mut() {
            p.rounds += 1;
            p.schedule_ns += schedule_ns;
            p.sweep_ns += sweep_ns;
            p.harvest_ns += harvest_ns;
            p.busy_ns += busy_ns;
            p.capacity_ns += sweep_ns * engaged as u64;
            p.step_ns.merge(&steps);
        }
        true
    }

    /// Drive every submitted query to its answer.
    pub fn drain(&mut self) {
        while self.has_work() {
            if !self.step_round() {
                break;
            }
        }
        debug_assert!(!self.has_work(), "engine idle with unresolved tickets");
    }
}

/// Planning estimate for one query on an `n`-dim operator: (rounds to
/// resolve, lane cost). Deliberately crude — admission needs an ordering
/// signal, not a forecast: `Iters(k)` is exact, `Exhaust` is the Krylov
/// dimension, and tolerance/threshold stops are taken at half the
/// Krylov budget (the linear bracket rate of Thm. 3/5/8 means most
/// decisions resolve well before exhaustion).
fn estimate_cost(q: &Query, n: usize) -> (u64, u64) {
    let n = n.max(1);
    let stop_rounds = |stop: &StopRule| -> u64 {
        match stop {
            StopRule::Iters(k) => (*k).clamp(1, n) as u64,
            StopRule::Exhaust => n as u64,
            _ => (n / 2 + 1) as u64,
        }
    };
    match q {
        Query::Estimate { stop, .. } => (stop_rounds(stop), 1),
        Query::Threshold { .. } => ((n / 2 + 1) as u64, 1),
        Query::Compare { .. } => ((n / 2 + 1) as u64, 2),
        Query::Argmax { arms, .. } => (
            arms.iter().map(|a| stop_rounds(&a.stop)).max().unwrap_or(1),
            arms.len().max(1) as u64,
        ),
        // probe lanes run toward exhaustion; early retirement makes the
        // Krylov dimension an upper estimate, which is what admission
        // ordering wants for the widest query kind
        Query::Trace { cfg, .. } | Query::LogDet { cfg } => {
            (n as u64, cfg.probes.max(1) as u64)
        }
    }
}

/// Point-in-time snapshot of one in-flight (unanswered) ticket, keyed by
/// its flight-recorder span — the payload behind `serve`'s `/queries`
/// endpoint. Built by [`Engine::live_spans`]; carries whatever the
/// current round knows without touching the panel hot path.
#[derive(Clone, Copy, Debug)]
pub struct LiveSpan {
    /// Flight-recorder span id (the engine submission sequence number).
    pub span: SpanId,
    /// Operator the query runs against.
    pub key: OpKey,
    /// Engine rounds elapsed since admission.
    pub rounds_elapsed: u64,
    /// Latest four-bound bracket — `None` for multi-lane query kinds
    /// (compare/argmax/stochastic), whose state is not a single interval.
    pub bounds: Option<Bounds>,
    /// Whether the admission pass currently has the query parked.
    pub parked: bool,
}

/// Pull new [`RetireEvent`](super::block::RetireEvent)s out of a slot's
/// session log into the engine counters (delta via the slot's
/// `last_retired` cursor — each event is counted exactly once). With a
/// flight recorder attached, each retirement is also attributed to the
/// owning ticket's span: probe lanes emit `ProbeRetired`, query lanes emit
/// `RetiredDominated`/`RetiredDecided`.
fn drain_retire_log(
    slot: &mut OpSlot,
    stats: &mut EngineStats,
    tickets: &[TicketSlot],
    flight: Option<&FlightRecorder>,
) {
    let events = slot.session.retired();
    for e in &events[slot.last_retired..] {
        match e.reason {
            RetireReason::Dominated => stats.retired_dominated += 1,
            RetireReason::Decided => stats.retired_decided += 1,
        }
        if let Some(f) = flight {
            if let Some((qid, probe)) = slot.session.lane_query(e.id) {
                let span = slot.open.iter().find_map(|tk| {
                    tickets
                        .get(tk.idx as usize)
                        .filter(|s| s.gen == tk.gen)
                        .and_then(|s| s.state.as_ref())
                        .filter(|st| st.qid == qid)
                        .map(|st| st.seq)
                });
                if let Some(span) = span {
                    match (e.reason, probe) {
                        (_, Some(p)) => {
                            f.record(span, FlightEventKind::ProbeRetired { probe: p as u32 })
                        }
                        (RetireReason::Dominated, None) => {
                            f.record(span, FlightEventKind::RetiredDominated)
                        }
                        (RetireReason::Decided, None) => {
                            f.record(span, FlightEventKind::RetiredDecided)
                        }
                    }
                }
            }
        }
    }
    slot.last_retired = events.len();
}

// ---------------------------------------------------------------------------
// Parallel sweep fan-out (work-stealing pool + static baseline)
// ---------------------------------------------------------------------------

/// One worker's thread-local accounting for one sweep fan-out. Workers
/// never share mutable state during the sweep — each fills its own
/// report, and the driver merges them after every claimant is done.
struct SweepReport {
    steps: Histogram,
    busy_ns: u64,
    steals: usize,
    /// The first caught step panic: the slot's key plus the payload,
    /// rethrown with context by the driving thread.
    panic: Option<(OpKey, Box<dyn Any + Send>)>,
}

impl SweepReport {
    fn new() -> Self {
        SweepReport { steps: Histogram::new(), busy_ns: 0, steals: 0, panic: None }
    }
}

/// Merged result of one fanned-out sweep.
struct SweepOutcome {
    steps: Histogram,
    busy_ns: u64,
    steals: usize,
    panic: Option<(OpKey, Box<dyn Any + Send>)>,
}

fn merge_reports(reports: Vec<SweepReport>) -> SweepOutcome {
    let mut out = SweepOutcome { steps: Histogram::new(), busy_ns: 0, steals: 0, panic: None };
    for rep in reports {
        out.steps.merge(&rep.steps);
        out.busy_ns += rep.busy_ns;
        out.steals += rep.steals;
        if out.panic.is_none() {
            out.panic = rep.panic;
        }
    }
    out
}

/// Rethrow a caught sweep-worker panic on the driving thread with the
/// operator key attached, so a panicking kernel names its session
/// instead of surfacing as an opaque cross-thread unwrap.
fn rethrow_with_slot(key: OpKey, payload: Box<dyn Any + Send>) -> ! {
    let msg = if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    };
    resume_unwind(Box::new(format!(
        "engine sweep worker panicked stepping the session for operator key {key}: {msg}"
    )));
}

/// Step one live slot under `catch_unwind`, recording the step time into
/// `rep` when `profiled`. Returns `false` when the step panicked (the
/// payload is recorded in `rep.panic` with the slot's key) — the caller
/// stops taking work so the driver can rethrow promptly.
fn step_slot_caught(slot: &mut OpSlot, profiled: bool, rep: &mut SweepReport) -> bool {
    let res = if profiled {
        let t = Instant::now();
        let r = catch_unwind(AssertUnwindSafe(|| slot.step()));
        let ns = t.elapsed().as_nanos() as u64;
        rep.steps.record(ns as f64);
        rep.busy_ns += ns;
        r
    } else {
        catch_unwind(AssertUnwindSafe(|| slot.step()))
    };
    match res {
        Ok(()) => true,
        Err(payload) => {
            rep.panic = Some((slot.key, payload));
            false
        }
    }
}

/// One round's work-stealing sweep job: a raw view of the engine's slot
/// table plus the shared claim cursor the workers race down. `chunk` is
/// the fair static share used only for steal *accounting* (a claim at
/// index `i` with `i / chunk != wid` is work a static split would have
/// assigned elsewhere).
struct SweepJob {
    slots: *mut OpSlot,
    len: usize,
    cursor: AtomicUsize,
    chunk: usize,
    profiled: bool,
    /// Helper reports, pushed as each helper finishes its claims.
    reports: Mutex<Vec<SweepReport>>,
    /// Helpers that have not yet finished claiming this job.
    pending: AtomicUsize,
}

// SAFETY: the only aliasing hazard is `slots`. The cursor's fetch_add
// hands out each index at most once, so at any moment each `OpSlot` has
// at most one `&mut` across all workers; the driver participates in the
// sweep and then blocks until `pending` hits zero before returning, so
// the raw pointer never outlives the `&mut [OpSlot]` borrow it was made
// from. Everything else in the job is `Sync` by construction
// (atomics + mutex).
unsafe impl Send for SweepJob {}
unsafe impl Sync for SweepJob {}

/// Claim-and-step loop shared by the driver (worker 0) and every pool
/// helper: race the job cursor down the slot list, stepping each claimed
/// live slot exactly once. Steps are bit-identical to the sequential
/// loop regardless of claim interleaving because sessions are
/// independent state machines — the cursor only decides *which thread*
/// runs a given session, never the order of one session's panel math.
fn sweep_claims(job: &SweepJob, wid: usize) -> SweepReport {
    let mut rep = SweepReport::new();
    loop {
        let i = job.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= job.len {
            break;
        }
        // SAFETY: the cursor hands out `i` exactly once, so this is the
        // only live `&mut` to slot `i` (see the Send/Sync note above).
        let slot = unsafe { &mut *job.slots.add(i) };
        if !slot.live {
            continue;
        }
        if i / job.chunk != wid {
            rep.steals += 1;
        }
        if !step_slot_caught(slot, job.profiled, &mut rep) {
            break;
        }
    }
    rep
}

/// Body of one persistent pool helper: park on the condvar until a new
/// job epoch (or shutdown), run the claim loop, report, and notify the
/// driver when the last helper finishes.
fn sweep_worker(sh: Arc<PoolShared>, wid: usize) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_tolerant(&sh.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch > seen {
                    if let Some(job) = &st.job {
                        seen = st.epoch;
                        break Arc::clone(job);
                    }
                }
                st = sh.go.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        let rep = sweep_claims(&job, wid);
        lock_tolerant(&job.reports).push(rep);
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            // take the state lock around the notify so it cannot slip
            // between the driver's pending check and its wait
            let _guard = lock_tolerant(&sh.state);
            sh.done.notify_all();
        }
    }
}

/// State shared between the driving thread and the pool helpers.
struct PoolShared {
    state: Mutex<PoolState>,
    /// Helpers park here between rounds, woken by a new epoch/shutdown.
    go: Condvar,
    /// The driver parks here until the last helper finishes a job.
    done: Condvar,
}

struct PoolState {
    /// Monotone dispatch counter: a helper runs each epoch's job once.
    epoch: u64,
    job: Option<Arc<SweepJob>>,
    shutdown: bool,
}

/// The persistent work-stealing sweep pool ([`SweepMode::Stealing`]):
/// `workers - 1` parked helper threads spawned once and reused for every
/// fan-out (the driving thread is always worker 0), replacing the
/// per-round `thread::scope` spawn/join of the static split.
struct SweepPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl SweepPool {
    fn new(helpers: usize) -> Self {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { epoch: 0, job: None, shutdown: false }),
            go: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (0..helpers)
            .map(|h| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("gql-sweep-{}", h + 1))
                    .spawn(move || sweep_worker(sh, h + 1))
                    .expect("spawn sweep worker")
            })
            .collect();
        SweepPool { shared, handles }
    }

    /// Run one work-stealing sweep over `slots`. Publishes the job to
    /// the parked helpers, claims alongside them as worker 0, then
    /// blocks until every helper has finished — so the raw slot pointer
    /// inside the job never outlives this call's `&mut` borrow.
    fn sweep(&self, slots: &mut [OpSlot], engaged: usize, profiled: bool) -> SweepOutcome {
        let job = Arc::new(SweepJob {
            slots: slots.as_mut_ptr(),
            len: slots.len(),
            cursor: AtomicUsize::new(0),
            chunk: slots.len().div_ceil(engaged.max(1)),
            profiled,
            reports: Mutex::new(Vec::with_capacity(self.handles.len() + 1)),
            pending: AtomicUsize::new(self.handles.len()),
        });
        {
            let mut st = lock_tolerant(&self.shared.state);
            st.epoch += 1;
            st.job = Some(Arc::clone(&job));
        }
        self.shared.go.notify_all();
        let mine = sweep_claims(&job, 0);
        {
            let mut st = lock_tolerant(&self.shared.state);
            while job.pending.load(Ordering::Acquire) > 0 {
                st = self.shared.done.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            st.job = None;
        }
        let mut reports = std::mem::take(&mut *lock_tolerant(&job.reports));
        reports.push(mine);
        merge_reports(reports)
    }
}

impl Drop for SweepPool {
    fn drop(&mut self) {
        {
            let mut st = lock_tolerant(&self.shared.state);
            st.shutdown = true;
        }
        self.shared.go.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The PR-5 static fan-out ([`SweepMode::Static`]): disjoint
/// `chunks_mut` slices under per-round scoped threads. Kept as the
/// baseline the stealing sweep is benchmarked against. A single
/// effective worker steps inline on the driving thread — no scope, no
/// spawn. Worker panics are caught per step and surface in the returned
/// outcome (the driver rethrows them with slot context) instead of
/// poisoning the engine through a bare cross-thread `unwrap`.
fn sweep_static(slots: &mut [OpSlot], workers: usize, profiled: bool) -> SweepOutcome {
    let w = workers.min(slots.len()).max(1);
    if w <= 1 {
        let mut rep = SweepReport::new();
        for slot in slots {
            if slot.live && !step_slot_caught(slot, profiled, &mut rep) {
                break;
            }
        }
        return merge_reports(vec![rep]);
    }
    let chunk = slots.len().div_ceil(w);
    let mut reports: Vec<SweepReport> = Vec::with_capacity(w);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(w);
        for part in slots.chunks_mut(chunk) {
            handles.push(scope.spawn(move || {
                let mut rep = SweepReport::new();
                for slot in part {
                    if slot.live && !step_slot_caught(slot, profiled, &mut rep) {
                        break;
                    }
                }
                rep
            }));
        }
        for handle in handles {
            match handle.join() {
                Ok(rep) => reports.push(rep),
                // unreachable in practice (every step is caught), but a
                // panic in the accounting itself still propagates
                Err(payload) => resume_unwind(payload),
            }
        }
    });
    merge_reports(reports)
}

// ---------------------------------------------------------------------------
// Cross-operator consumer: the double-greedy inclusion race (paper Alg. 9)
// ---------------------------------------------------------------------------

/// One side of a joint double-greedy race: the operator (`L_X` or
/// `L_{Y'}`), the query column of the candidate element against it, and
/// the side's spectrum options. Owned — the operator enters the engine's
/// store and the query column moves into the submitted query, so the
/// race borrows nothing from the caller.
pub struct DgSideSpec {
    pub op: Arc<dyn SymOp>,
    pub u: Vec<f64>,
    pub opts: GqlOptions,
}

struct DgSideRun {
    ticket: Ticket,
    max_iters: usize,
}

/// Double-greedy inclusion test over a shared [`Engine`] (the
/// cross-operator ROADMAP item): with Δ⁺ = log(l_ii − u_x^T L_X^{-1} u_x)
/// and Δ⁻ = −log(l_ii − u_y^T L_{Y'}^{-1} u_y), returns true (add `i` to
/// X) iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊`.
///
/// Both sides enter the engine as estimate queries on *different*
/// operators and advance together — one `matvec_multi` panel per operator
/// per engine round — so the comparison resolves from per-round bracket
/// exchange in `max(a, b)`-ish rounds where the sequential §5.2
/// alternation of [`race_dg`](super::race::race_dg) spends `a + b` single
/// side steps. Decisions are identical to `race_dg` (and to exact
/// scoring) wherever brackets certify them, because both read the same
/// nested Radau brackets; only the refinement *schedule* differs, so
/// iteration counts may. Under [`RacePolicy::Prune`] the race stops at
/// the first certified separation (abandoned refinement is cancelled);
/// [`RacePolicy::Exhaustive`] refines both sides to exhaustion/budget
/// first and decides identically from the final brackets.
///
/// Sides may be `None` (empty set: Δ is exact from `l_ii` alone) — zero
/// query columns are treated the same way, mirroring `race_dg`. Both
/// tickets are compacted ([`Engine::take_answer`]) before returning, so
/// per-element reuse of one resident engine does not grow its ticket
/// log.
pub fn race_dg_joint(
    eng: &mut Engine,
    x: Option<DgSideSpec>,
    y: Option<DgSideSpec>,
    l_ii: f64,
    p: f64,
    policy: RacePolicy,
) -> (bool, JudgeStats) {
    let mut enter = |side: Option<DgSideSpec>| -> Option<DgSideRun> {
        let s = side?;
        if is_zero(&s.u) {
            return None; // zero query ⇒ BIF = 0 exactly; an absent side
        }
        let max_iters = s.opts.max_iters.min(s.op.dim()).max(1);
        let key = eng.fresh_key();
        let ticket = eng.submit(
            key,
            s.op,
            s.opts,
            Query::Estimate { u: s.u, stop: StopRule::Exhaust },
        );
        Some(DgSideRun { ticket, max_iters })
    };
    let tx = enter(x);
    let ty = enter(y);

    // bracket of log(t − bif) given BIF bounds [lo, hi]; −∞ for a
    // non-positive argument ([x]₊ clamps later) — same as race_dg
    let log_gap = |lo_arg: f64, hi_arg: f64| -> (f64, f64) {
        let lo = if lo_arg > 0.0 { lo_arg.ln() } else { f64::NEG_INFINITY };
        let hi = if hi_arg > 0.0 { hi_arg.ln() } else { f64::NEG_INFINITY };
        (lo, hi)
    };
    let pos = |v: f64| v.max(0.0);

    let mut stalled = false;
    loop {
        // (lo, hi, exact, stuck, iter, known) of a side this round
        let side_state = |run: &Option<DgSideRun>, eng: &Engine| match run {
            None => (0.0, 0.0, true, true, 0usize, true),
            Some(r) => match eng.bounds(r.ticket) {
                Some(b) => (
                    b.lower(),
                    b.upper(),
                    b.exact,
                    b.exact || b.iter >= r.max_iters || eng.is_resolved(r.ticket),
                    b.iter,
                    true,
                ),
                // submitted but not yet swept (possible under a tight
                // lane budget): undecidable this round
                None => (0.0, 0.0, false, false, 0usize, false),
            },
        };
        let (x_lo, x_hi, x_exact, x_stuck, x_iter, x_known) = side_state(&tx, eng);
        let (y_lo, y_hi, y_exact, y_stuck, y_iter, y_known) = side_state(&ty, eng);

        if x_known && y_known {
            let iters = x_iter + y_iter;
            // Δ⁺ ∈ [log(l_ii − x_hi), log(l_ii − x_lo)]
            let (dp_lo, dp_hi) = log_gap(l_ii - x_hi, l_ii - x_lo);
            // Δ⁻ ∈ [−log(l_ii − y_lo), −log(l_ii − y_hi)] (sign flip)
            let (ly_lo, ly_hi) = log_gap(l_ii - y_hi, l_ii - y_lo);
            let (dm_lo, dm_hi) = (-ly_hi, -ly_lo);

            let decided = if policy == RacePolicy::Prune {
                if p * pos(dm_hi) <= (1.0 - p) * pos(dp_lo) {
                    Some(true)
                } else if p * pos(dm_lo) > (1.0 - p) * pos(dp_hi) {
                    Some(false)
                } else {
                    None
                }
            } else {
                None
            };
            let (decision, outcome) = match decided {
                Some(d) => (
                    Some(d),
                    if x_exact && y_exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided },
                ),
                None if x_exact && y_exact => (
                    Some(p * pos(dm_lo) <= (1.0 - p) * pos(dp_lo)),
                    JudgeOutcome::Exact,
                ),
                None if (x_stuck && y_stuck) || stalled => {
                    // at least one side out of budget: midpoints, like the
                    // scalar judges (exact sides have collapsed brackets)
                    let dp_mid = 0.5 * (pos(dp_lo) + pos(dp_hi));
                    let dm_mid = 0.5 * (pos(dm_lo) + pos(dm_hi));
                    (Some(p * dm_mid <= (1.0 - p) * dp_mid), JudgeOutcome::Budget)
                }
                None => (None, JudgeOutcome::Decided),
            };
            if let Some(d) = decision {
                for run in [&tx, &ty].into_iter().flatten() {
                    // abandon refinement the decision no longer needs,
                    // then compact the ticket so a resident engine's
                    // slab stays bounded across many races
                    let _ = eng.cancel(run.ticket);
                    let _ = eng.take_answer(run.ticket);
                }
                return (d, JudgeStats { iters, outcome });
            }
        }
        // refine: every live side advances one panel this round
        let progressed = eng.step_round();
        if !progressed {
            // no session can move: the next pass must decide (both sides
            // resolved ⇒ stuck); `stalled` forces the midpoint exit even
            // if a bracket never materialized
            debug_assert!(!stalled, "engine stalled twice without deciding");
            stalled = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::linalg::Cholesky;
    use crate::quadrature::block::StopRule;
    use crate::quadrature::race::race_dg;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn config_validation_rejects_zero_and_absurd_knobs() {
        assert!(EngineConfig::default().validate().is_ok());
        assert_eq!(
            EngineConfig::default().with_lanes(0).validate(),
            Err(EngineConfigError::ZeroLanes)
        );
        assert_eq!(
            EngineConfig::default().with_lanes(MAX_ENGINE_LANES + 1).validate(),
            Err(EngineConfigError::AbsurdLanes(MAX_ENGINE_LANES + 1))
        );
        assert_eq!(
            EngineConfig::default().with_ttl_rounds(0).validate(),
            Err(EngineConfigError::ZeroTtl)
        );
        assert_eq!(
            EngineConfig::default().with_ttl_rounds(MAX_ENGINE_TTL + 9).validate(),
            Err(EngineConfigError::AbsurdTtl(MAX_ENGINE_TTL + 9))
        );
        assert_eq!(
            EngineConfig::default().with_width(0).validate(),
            Err(EngineConfigError::ZeroWidth)
        );
        assert_eq!(
            EngineConfig::default().with_workers(0).validate(),
            Err(EngineConfigError::ZeroWorkers)
        );
        assert_eq!(
            EngineConfig::default().with_queue_cap(0).validate(),
            Err(EngineConfigError::ZeroQueueCap)
        );
        assert!(Engine::new(EngineConfig::default().with_lanes(0)).is_err());
        // the typed error names the config knob for admission messages
        assert!(EngineConfigError::ZeroLanes.to_string().contains("engine_lanes"));
        assert!(EngineConfigError::ZeroTtl.to_string().contains("engine_ttl_rounds"));
        assert!(EngineConfigError::ZeroQueueCap.to_string().contains("engine_queue_cap"));
    }

    #[test]
    fn lazy_spin_up_streaming_submission_and_ttl_eviction() {
        let mut rng = Rng::new(0xE9610);
        let (a, wa) = random_sparse_spd(&mut rng, 30, 0.2, 0.05);
        let (b, wb) = random_sparse_spd(&mut rng, 12, 0.4, 0.05);
        let (a, b) = (Arc::new(a), Arc::new(b));
        let opts_a = GqlOptions::new(wa.lo, wa.hi);
        let opts_b = GqlOptions::new(wb.lo, wb.hi);
        let mut eng = Engine::new(EngineConfig::default().with_ttl_rounds(2)).unwrap();
        assert_eq!(eng.sessions(), 0, "sessions spin up lazily");

        // op B finishes fast; op A keeps the loop running long enough for
        // B's idle session to age past the TTL
        let ua = randvec(&mut rng, 30);
        let ub = randvec(&mut rng, 12);
        let ta =
            eng.submit(1, a.clone(), opts_a, Query::Estimate { u: ua, stop: StopRule::Exhaust });
        let tb =
            eng.submit(2, b.clone(), opts_b, Query::Estimate { u: ub, stop: StopRule::Iters(1) });
        assert_eq!(eng.sessions(), 2);

        // streaming: a second op-B query submitted mid-flight lands in a
        // later round and still answers
        for _ in 0..2 {
            assert!(eng.step_round());
        }
        let ub2 = randvec(&mut rng, 12);
        let tb2 =
            eng.submit(2, b.clone(), opts_b, Query::Estimate { u: ub2, stop: StopRule::Iters(2) });
        eng.drain();
        assert!(eng.is_resolved(ta) && eng.is_resolved(tb) && eng.is_resolved(tb2));
        let st = eng.stats();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.sessions_spun, 2);
        assert_eq!(st.sessions_evicted, 1, "idle op-B session evicted by TTL");
        assert_eq!(eng.sessions(), 1, "op A's session survives");
        assert!(st.sweeps >= st.rounds);
        // the evicted session's operator stays warm under the default
        // (unbounded) store budget
        assert!(eng.store().contains(2), "released op stays resident");
        assert_eq!(eng.store().resident(), 2);
        assert_eq!(eng.store().pinned(), 1, "only op A's session still pins");

        // a fresh submission under the evicted key spins a new session
        // on the warm stored operator — no operator crosses the API
        let ub3 = randvec(&mut rng, 12);
        let tb3 = eng
            .submit_keyed(2, opts_b, Query::Estimate { u: ub3, stop: StopRule::Iters(1) }, None)
            .expect("warm key re-admits");
        eng.drain();
        assert!(eng.is_resolved(tb3));
        assert_eq!(eng.stats().sessions_spun, 3);
        assert_eq!(eng.store().inserted(), 2, "re-admission reused the stored op");
    }

    #[test]
    fn lane_budget_parks_and_resumes_priority_ordered() {
        let mut rng = Rng::new(0xE9611);
        let (a, w) = random_sparse_spd(&mut rng, 24, 0.25, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let queries: Vec<Vec<f64>> = (0..4).map(|_| randvec(&mut rng, 24)).collect();

        let run = |lanes: usize| {
            let mut eng = Engine::new(EngineConfig::default().with_lanes(lanes)).unwrap();
            let tickets: Vec<Ticket> = queries
                .iter()
                .map(|u| {
                    eng.submit(
                        7,
                        a.clone(),
                        opts,
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            let answers: Vec<Answer> =
                tickets.iter().map(|&t| eng.answer(t).unwrap().clone()).collect();
            (answers, eng.stats())
        };
        let (wide, wide_st) = run(256);
        let (narrow, narrow_st) = run(1);
        assert_eq!(wide_st.parks, 0, "a wide budget parks nothing");
        assert!(narrow_st.parks > 0, "budget 1 must park the younger queries");
        assert!(narrow_st.resumes > 0, "parked queries must resume");
        assert_eq!(narrow_st.peak_live_lanes, 1);
        for (a1, a2) in wide.iter().zip(&narrow) {
            match (a1, a2) {
                (
                    Answer::Estimate { bounds: b1, iters: i1, .. },
                    Answer::Estimate { bounds: b2, iters: i2, .. },
                ) => {
                    assert_eq!(i1, i2, "suspension changed an iteration count");
                    assert_eq!(b1.gauss.to_bits(), b2.gauss.to_bits());
                    assert_eq!(b1.radau_upper.to_bits(), b2.radau_upper.to_bits());
                }
                other => panic!("wrong answer kinds {other:?}"),
            }
        }
    }

    #[test]
    fn ticket_compaction_and_stale_generation() {
        let mut rng = Rng::new(0xE9617);
        let (a, w) = random_sparse_spd(&mut rng, 12, 0.4, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        let u = randvec(&mut rng, 12);
        let t = eng.submit(1, a.clone(), opts, Query::Estimate { u, stop: StopRule::Iters(2) });
        assert!(matches!(eng.take_answer(t), Err(TicketError::Unresolved)));
        eng.drain();
        assert_eq!(eng.live_tickets(), 1);
        let ans = eng.take_answer(t).expect("resolved ticket yields its answer");
        assert!(matches!(ans, Answer::Estimate { .. }));
        assert_eq!(eng.stats().compactions, 1);
        assert_eq!(eng.live_tickets(), 0, "compaction freed the slot");
        // the slot is compacted: the old ticket is stale in every API
        assert!(matches!(eng.take_answer(t), Err(TicketError::Stale)));
        assert!(eng.answer(t).is_none());
        assert!(!eng.is_resolved(t));
        assert!(eng.bounds(t).is_none());
        assert!(!eng.cancel(t));
        // the freed slot is reused under a bumped generation
        let u2 = randvec(&mut rng, 12);
        let t2 = eng.submit(1, a.clone(), opts, Query::Estimate { u: u2, stop: StopRule::Iters(1) });
        assert_eq!(t2.idx, t.idx, "slab slot reused");
        assert_ne!(t2.gen, t.gen, "generation bumped");
        eng.drain();
        assert!(eng.take_answer(t2).is_ok());
        assert!(
            matches!(eng.take_answer(t), Err(TicketError::Stale)),
            "old ticket cannot alias the reused slot"
        );
    }

    #[test]
    fn store_budget_evicts_released_operators_lru() {
        let mut rng = Rng::new(0xE9618);
        let (a, wa) = random_sparse_spd(&mut rng, 30, 0.2, 0.05);
        let (b, wb) = random_sparse_spd(&mut rng, 10, 0.4, 0.05);
        let (a, b) = (Arc::new(a), Arc::new(b));
        let opts_a = GqlOptions::new(wa.lo, wa.hi);
        let opts_b = GqlOptions::new(wb.lo, wb.hi);
        // a 1-byte budget: nothing released can stay warm
        let mut eng = Engine::new(
            EngineConfig::default().with_ttl_rounds(2).with_store_bytes(1),
        )
        .unwrap();
        let ua = randvec(&mut rng, 30);
        let ub = randvec(&mut rng, 10);
        eng.submit(1, a.clone(), opts_a, Query::Estimate { u: ua, stop: StopRule::Exhaust });
        let tb =
            eng.submit(2, b.clone(), opts_b, Query::Estimate { u: ub, stop: StopRule::Iters(1) });
        assert_eq!(eng.store().resident(), 2);
        assert!(eng.store().resident_bytes() > 0);
        eng.drain();
        assert!(eng.is_resolved(tb));
        // op B's session idled past the TTL; with a 1-byte budget its
        // released operator cannot stay resident either
        assert_eq!(eng.stats().sessions_evicted, 1);
        assert!(!eng.store().contains(2), "LRU evicted the released operator");
        assert!(eng.store().contains(1), "pinned operator is immune to the budget");
        assert_eq!(eng.store().evicted(), 1);
        // the evicted key is now unknown to the keyed path…
        let ub2 = randvec(&mut rng, 10);
        assert_eq!(
            eng.submit_keyed(
                2,
                opts_b,
                Query::Estimate { u: ub2.clone(), stop: StopRule::Iters(1) },
                None
            )
            .unwrap_err(),
            SubmitError::UnknownKey(2)
        );
        // …but a full submission re-inserts and still answers
        let t = eng.submit(2, b.clone(), opts_b, Query::Estimate { u: ub2, stop: StopRule::Iters(1) });
        eng.drain();
        assert!(eng.is_resolved(t));
        assert_eq!(eng.store().inserted(), 3);
    }

    #[test]
    fn queue_cap_sheds_least_urgent_with_a_valid_bracket() {
        let mut rng = Rng::new(0xE9619);
        let (a, w) = random_sparse_spd(&mut rng, 20, 0.3, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default().with_queue_cap(1)).unwrap();
        let u1 = randvec(&mut rng, 20);
        let t1 = eng
            .try_submit(1, a.clone(), opts, Query::Estimate { u: u1, stop: StopRule::Exhaust }, None)
            .unwrap();
        // nothing swept yet: the only candidate has no bracket to answer
        // with, so admission refuses rather than shedding garbage
        let u2 = randvec(&mut rng, 20);
        assert_eq!(
            eng.try_submit(
                1,
                a.clone(),
                opts,
                Query::Estimate { u: u2.clone(), stop: StopRule::Exhaust },
                Some(4)
            )
            .unwrap_err(),
            SubmitError::Saturated
        );
        assert!(eng.step_round());
        // now t1 carries a live bracket: the deadline submission sheds it
        let t2 = eng
            .try_submit(
                1,
                a.clone(),
                opts,
                Query::Estimate { u: u2, stop: StopRule::Exhaust },
                Some(4),
            )
            .unwrap();
        assert_eq!(eng.stats().shed, 1);
        match eng.answer(t1).expect("shed ticket resolves immediately") {
            Answer::Estimate { bounds, iters, .. } => {
                assert!(*iters >= 1);
                assert!(
                    bounds.lower() <= bounds.upper(),
                    "shed answer must still be a valid bracket"
                );
            }
            other => panic!("wrong answer kind {other:?}"),
        }
        eng.drain();
        assert!(eng.is_resolved(t2));
    }

    #[test]
    fn race_dg_joint_agrees_with_race_dg_and_the_oracle() {
        forall(15, 0xE9612, |rng| {
            let n = 8 + rng.below(16);
            let (l, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let k = 2 + rng.below(n / 2);
            let all = rng.sample_indices(n, n);
            let (xs, rest) = all.split_at(k);
            let (ys, _) = rest.split_at(1 + rng.below(rest.len() - 1));
            let i = *all.last().unwrap();
            let mut xs = xs.to_vec();
            let mut ys = ys.to_vec();
            xs.sort_unstable();
            ys.sort_unstable();
            let ax = Arc::new(l.principal_submatrix(&xs));
            let ay = Arc::new(l.principal_submatrix(&ys));
            let ux: Vec<f64> = xs.iter().map(|&m| l.get(m, i)).collect();
            let uy: Vec<f64> = ys.iter().map(|&m| l.get(m, i)).collect();
            let l_ii = l.get(i, i);
            let (chx, chy) = match (
                Cholesky::factor(&ax.to_dense()),
                Cholesky::factor(&ay.to_dense()),
            ) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return,
            };
            let dp = (l_ii - chx.bif(&ux)).max(1e-300).ln();
            let dm = -(l_ii - chy.bif(&uy)).max(1e-300).ln();
            let opts = GqlOptions::new(w.lo * 0.5, w.hi * 1.5);
            for p in [0.25, 0.5, 0.75] {
                let want = p * dm.max(0.0) <= (1.0 - p) * dp.max(0.0);
                let (seq, _) =
                    race_dg(Some((&*ax, &ux)), Some((&*ay, &uy)), l_ii, p, opts, opts,
                        RacePolicy::Prune);
                for policy in [RacePolicy::Prune, RacePolicy::Exhaustive] {
                    let mut eng = Engine::new(EngineConfig::default().with_width(1)).unwrap();
                    let (joint, js) = race_dg_joint(
                        &mut eng,
                        Some(DgSideSpec { op: ax.clone(), u: ux.clone(), opts }),
                        Some(DgSideSpec { op: ay.clone(), u: uy.clone(), opts }),
                        l_ii,
                        p,
                        policy,
                    );
                    assert_eq!(joint, want, "joint decision wrong (p={p}, {policy:?})");
                    assert_eq!(joint, seq, "joint diverged from race_dg (p={p})");
                    assert!(js.iters <= 2 * n + 2, "runaway refinement");
                    assert!(!eng.has_work(), "decided race left work behind");
                    assert_eq!(eng.live_tickets(), 0, "race compacted its tickets");
                }
            }
        });
    }

    #[test]
    fn race_dg_joint_empty_and_zero_sides_are_exact() {
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        // both sides absent: Δ⁺ = log 2 > 0, Δ⁻ = −log 2 ⇒ [Δ⁻]₊ = 0 ⇒ add
        let (ans, stats) = race_dg_joint(&mut eng, None, None, 2.0, 0.3, RacePolicy::Prune);
        assert!(ans);
        assert_eq!(stats.iters, 0);
        assert_eq!(stats.outcome, JudgeOutcome::Exact);
        // a zero query column counts as an absent side
        let mut rng = Rng::new(0xE9613);
        let (a, w) = random_sparse_spd(&mut rng, 10, 0.4, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let z = vec![0.0; 10];
        let (ans, stats) = race_dg_joint(
            &mut eng,
            Some(DgSideSpec { op: Arc::new(a), u: z, opts }),
            None,
            2.0,
            0.3,
            RacePolicy::Prune,
        );
        assert!(ans);
        assert_eq!(stats.outcome, JudgeOutcome::Exact);
    }

    #[test]
    fn parallel_workers_answer_bit_identically_to_one_worker() {
        let mut rng = Rng::new(0xE9614);
        let ops: Vec<_> = (0..5)
            .map(|_| {
                let (a, w) = random_sparse_spd(&mut rng, 16 + rng.below(20), 0.3, 0.05);
                (Arc::new(a), w)
            })
            .collect();
        let queries: Vec<Vec<f64>> = ops
            .iter()
            .map(|(a, _)| (0..a.n).map(|_| rng.normal()).collect())
            .collect();
        let run = |workers: usize| {
            let mut eng =
                Engine::new(EngineConfig::default().with_workers(workers)).unwrap();
            let tickets: Vec<Ticket> = ops
                .iter()
                .zip(&queries)
                .enumerate()
                .map(|(k, ((a, w), u))| {
                    eng.submit(
                        k as OpKey,
                        a.clone(),
                        GqlOptions::new(w.lo, w.hi),
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            tickets
                .iter()
                .map(|&t| match eng.answer(t).unwrap() {
                    Answer::Estimate { bounds, iters, .. } => (bounds.gauss.to_bits(), *iters),
                    other => panic!("wrong answer kind {other:?}"),
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "worker count changed a result");
    }

    #[test]
    fn profiled_engine_answers_bit_identically_and_measures_phases() {
        let mut rng = Rng::new(0xE9615);
        let ops: Vec<_> = (0..4)
            .map(|_| {
                let (a, w) = random_sparse_spd(&mut rng, 16 + rng.below(16), 0.3, 0.05);
                (Arc::new(a), w)
            })
            .collect();
        let queries: Vec<Vec<f64>> = ops
            .iter()
            .map(|(a, _)| (0..a.n).map(|_| rng.normal()).collect())
            .collect();
        let run = |cfg: EngineConfig| {
            let mut eng = Engine::new(cfg).unwrap();
            let tickets: Vec<Ticket> = ops
                .iter()
                .zip(&queries)
                .enumerate()
                .map(|(k, ((a, w), u))| {
                    eng.submit(
                        k as OpKey,
                        a.clone(),
                        GqlOptions::new(w.lo, w.hi),
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            let bits: Vec<(u64, usize)> = tickets
                .iter()
                .map(|&t| match eng.answer(t).unwrap() {
                    Answer::Estimate { bounds, iters, .. } => {
                        (bounds.gauss.to_bits(), *iters)
                    }
                    other => panic!("wrong answer kind {other:?}"),
                })
                .collect();
            let profile = eng.profile().cloned();
            let stats = eng.stats();
            (bits, profile, stats)
        };
        let base = EngineConfig::default().with_workers(2);
        let (plain, no_profile, _) = run(base);
        assert!(no_profile.is_none(), "profile off by default");
        let (profiled, profile, stats) = run(base.with_profile(true));
        assert_eq!(plain, profiled, "profiling changed an answer bit");
        let p = profile.expect("profile collected");
        assert_eq!(p.rounds, stats.rounds, "every round profiled");
        assert!(p.sweep_ns > 0, "sweep phase timed");
        assert_eq!(
            p.step_ns.count() as usize, stats.sweeps,
            "one step sample per session sweep"
        );
        assert!(p.busy_ns <= p.capacity_ns, "busy cannot exceed capacity");
        let busy = p.busy_frac();
        assert!((0.0..=1.0).contains(&busy), "busy_frac {busy}");
        assert!((p.idle_frac() - (1.0 - busy)).abs() < 1e-12);

        // registry export surfaces the acceptance-criteria names
        let reg = MetricsRegistry::new();
        let mut eng = Engine::new(base.with_profile(true)).unwrap();
        let (a, w) = &ops[0];
        eng.submit(
            0,
            a.clone(),
            GqlOptions::new(w.lo, w.hi),
            Query::Estimate { u: queries[0].clone(), stop: StopRule::Exhaust },
        );
        eng.drain();
        eng.export_into(&reg);
        let snap = reg.snapshot();
        for name in [
            "engine.rounds",
            "engine.sweeps",
            "engine.store.resident",
            "engine.store.pinned",
            "engine.store.resident_bytes",
            "engine.store.inserted",
            "engine.store.evicted",
            "engine.admission.admitted",
            "engine.admission.parked",
            "engine.admission.shed",
            "engine.admission.compactions",
            "engine.profile.sweep_ns",
            "engine.profile.schedule_ns",
            "engine.profile.harvest_ns",
            "engine.profile.worker_busy_frac",
            "engine.profile.worker_idle_frac",
            "engine.profile.steal_count",
            "engine.profile.pool_reuse",
            "engine.profile.kernel_lane_width",
        ] {
            assert!(snap.get(name).is_some(), "missing exported metric {name}");
        }
        match snap.get("engine.profile.kernel_lane_width") {
            Some(crate::metrics::MetricValue::Gauge(v)) => {
                assert_eq!(*v, crate::sparse::PANEL_PAD as f64, "gauge reports PANEL_PAD");
            }
            other => panic!("kernel_lane_width gauge missing or mistyped: {other:?}"),
        }
    }

    #[test]
    fn sweep_modes_agree_and_the_stealing_pool_is_reused() {
        let mut rng = Rng::new(0xE9617);
        let ops: Vec<_> = (0..6)
            .map(|_| {
                let (a, w) = random_sparse_spd(&mut rng, 12 + rng.below(24), 0.3, 0.05);
                (Arc::new(a), w)
            })
            .collect();
        let queries: Vec<Vec<f64>> = ops
            .iter()
            .map(|(a, _)| (0..a.n).map(|_| rng.normal()).collect())
            .collect();
        let run = |mode: SweepMode| {
            let cfg = EngineConfig::default().with_workers(4).with_sweep_mode(mode);
            let mut eng = Engine::new(cfg).unwrap();
            let tickets: Vec<Ticket> = ops
                .iter()
                .zip(&queries)
                .enumerate()
                .map(|(k, ((a, w), u))| {
                    eng.submit(
                        k as OpKey,
                        a.clone(),
                        GqlOptions::new(w.lo, w.hi),
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            let bits: Vec<u64> = tickets
                .iter()
                .map(|&t| match eng.answer(t).unwrap() {
                    Answer::Estimate { bounds, .. } => bounds.gauss.to_bits(),
                    other => panic!("wrong answer kind {other:?}"),
                })
                .collect();
            (bits, eng.stats())
        };
        let (stealing, st) = run(SweepMode::Stealing);
        let (static_, ss) = run(SweepMode::Static);
        assert_eq!(stealing, static_, "sweep mode changed a result");
        // six Exhaust sessions run many multi-live rounds: every round
        // after the first reuses the warm pool instead of respawning
        assert!(st.pool_reuse >= 1, "pool never reused: {}", st.pool_reuse);
        assert_eq!(ss.pool_reuse, 0, "static mode must not touch the pool");
        assert_eq!(ss.steals, 0, "static mode cannot steal");
    }

    /// A deliberately panicking operator: the engine's sweep must carry
    /// the panic back to the driving thread tagged with the slot's key.
    struct PanicOp {
        n: usize,
    }

    impl SymOp for PanicOp {
        fn dim(&self) -> usize {
            self.n
        }
        fn matvec(&self, _x: &[f64], _y: &mut [f64]) {
            panic!("synthetic kernel failure");
        }
        fn diagonal(&self) -> Vec<f64> {
            vec![2.0; self.n]
        }
    }

    #[test]
    fn sweep_worker_panics_carry_slot_context() {
        for mode in [SweepMode::Stealing, SweepMode::Static] {
            let mut rng = Rng::new(0xE9618);
            let (a, w) = random_sparse_spd(&mut rng, 16, 0.3, 0.05);
            let healthy = Arc::new(a);
            let u = randvec(&mut rng, 16);
            let cfg = EngineConfig::default().with_workers(2).with_sweep_mode(mode);
            let mut eng = Engine::new(cfg).unwrap();
            eng.submit(
                1,
                healthy,
                GqlOptions::new(w.lo, w.hi),
                Query::Estimate { u, stop: StopRule::Exhaust },
            );
            eng.submit(
                9,
                Arc::new(PanicOp { n: 12 }),
                GqlOptions::new(0.5, 4.0),
                Query::Estimate { u: vec![1.0; 12], stop: StopRule::Exhaust },
            );
            let err = catch_unwind(AssertUnwindSafe(|| {
                eng.step_round();
            }))
            .expect_err("a panicking operator must fail the round");
            let msg = err
                .downcast_ref::<String>()
                .expect("rethrown payload is the formatted context string");
            assert!(msg.contains("operator key 9"), "missing slot context: {msg}");
            assert!(
                msg.contains("synthetic kernel failure"),
                "missing original payload: {msg}"
            );
        }
    }

    #[test]
    fn retire_counters_pull_from_the_session_retire_log() {
        use crate::quadrature::query::QueryArm;
        let mut rng = Rng::new(0xE9616);
        let (a, w) = random_sparse_spd(&mut rng, 24, 0.3, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default()).unwrap();

        // a cancelled estimate retires its lane with RetireReason::Decided
        // and must be counted even though no harvest follows the cancel
        let u = randvec(&mut rng, 24);
        let t = eng.submit(3, a.clone(), opts, Query::Estimate { u, stop: StopRule::Exhaust });
        assert!(eng.step_round());
        assert!(eng.cancel(t), "mid-flight estimate cancels");
        assert_eq!(eng.stats().retired_decided, 1);
        assert_eq!(eng.stats().retired_dominated, 0);

        // an argmax whose offsets are separated far beyond any BIF value
        // prunes every losing arm by dominance in the first resolution
        // round and crowns the still-racing winner (Decided)
        let arms: Vec<QueryArm> = (0..5)
            .map(|k| QueryArm {
                u: randvec(&mut rng, 24),
                stop: StopRule::Exhaust,
                offset: 1e6 * k as f64,
                scale: 1.0,
            })
            .collect();
        let t2 = eng.submit(3, a.clone(), opts, Query::Argmax { arms, floor: None });
        eng.drain();
        assert!(eng.is_resolved(t2));
        let st = eng.stats();
        assert_eq!(st.retired_dominated, 4, "four arms dominated");
        assert_eq!(st.retired_decided, 2, "cancelled lane + crowned winner");
        // counters are deltas over the log, never double counted
        eng.drain();
        let again = eng.stats();
        assert_eq!(again.retired_dominated, 4);
        assert_eq!(again.retired_decided, 2);
    }

    #[test]
    fn stochastic_queries_flow_through_the_streaming_engine() {
        use crate::quadrature::stochastic::{SlqConfig, SpectralFn};
        let mut rng = Rng::new(0xE9620);
        let n = 18;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let ch = Cholesky::factor(&a.to_dense()).unwrap();
        let exact_logdet = ch.logdet();
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        let cfg = SlqConfig::new(8, 0xE962_0001, 5e-2);
        let tl = eng.submit(1, a.clone(), opts, Query::LogDet { cfg });
        // co-keyed with a bilinear estimate on the same operator: one
        // panel serves both kinds
        let u = randvec(&mut rng, n);
        let te = eng
            .try_submit(1, a.clone(), opts, Query::Estimate { u, stop: StopRule::GapRel(1e-8) }, None)
            .unwrap();
        eng.drain();
        let r = eng
            .answer(tl)
            .and_then(Answer::stochastic)
            .expect("logdet ticket resolves to a stochastic report")
            .clone();
        assert_eq!(r.f, SpectralFn::Log);
        assert_eq!(r.probes_issued, 8);
        let guard = 4.0 * (r.combined.width() / 2.0) + 1e-9;
        assert!(
            (exact_logdet - r.combined.mid()).abs() <= guard,
            "exact {exact_logdet} vs [{}, {}]",
            r.combined.lo,
            r.combined.hi
        );
        assert!(matches!(eng.answer(te), Some(Answer::Estimate { .. })));
        // keyed warm path accepts stochastic queries too
        let t2 = eng
            .submit_keyed(1, opts, Query::Trace { f: SpectralFn::Inverse, cfg }, Some(64))
            .unwrap();
        eng.drain();
        assert!(eng.answer(t2).and_then(Answer::stochastic).is_some());
    }

    #[test]
    fn invalid_stochastic_configs_are_refused_at_admission() {
        use crate::quadrature::stochastic::{SlqConfig, SlqConfigError, SpectralFn};
        let mut rng = Rng::new(0xE9621);
        let (a, w) = random_sparse_spd(&mut rng, 10, 0.4, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        assert_eq!(
            eng.try_submit(
                1,
                a.clone(),
                opts,
                Query::LogDet { cfg: SlqConfig::new(0, 1, 1e-2) },
                None
            )
            .unwrap_err(),
            SubmitError::Invalid(SlqConfigError::ZeroProbes)
        );
        assert!(matches!(
            eng.try_submit(
                1,
                a.clone(),
                opts,
                Query::LogDet { cfg: SlqConfig::new(4, 1, f64::NAN) },
                None
            ),
            Err(SubmitError::Invalid(SlqConfigError::NonFiniteTol(_)))
        ));
        assert!(matches!(
            eng.try_submit(
                1,
                a.clone(),
                opts,
                Query::Trace { f: SpectralFn::Power(1.5), cfg: SlqConfig::new(4, 1, 1e-2) },
                None
            ),
            Err(SubmitError::Invalid(SlqConfigError::UnsupportedPower(_)))
        ));
        // refusal happens before any session spins up or ticket opens
        assert_eq!(eng.stats().submitted, 0);
        assert!(!eng.has_work());
    }

    #[test]
    fn queue_cap_sheds_stochastic_queries_to_a_partial_interval() {
        use crate::quadrature::stochastic::{SlqConfig, SpectralFn};
        let mut rng = Rng::new(0xE9622);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default().with_queue_cap(1)).unwrap();
        // an effectively-unreachable tolerance keeps the query in flight
        let cfg = SlqConfig::new(6, 0xE962_0002, 1e-15);
        let t1 = eng
            .try_submit(1, a.clone(), opts, Query::Trace { f: SpectralFn::Inverse, cfg }, None)
            .unwrap();
        // no sweep yet: no probe has a bracket, nothing valid to shed
        let u = randvec(&mut rng, n);
        assert_eq!(
            eng.try_submit(
                1,
                a.clone(),
                opts,
                Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                Some(4)
            )
            .unwrap_err(),
            SubmitError::Saturated
        );
        assert!(eng.step_round());
        // with brackets absorbed, the deadline submission sheds it to a
        // valid (tolerance-short) combined interval — anytime semantics
        eng.try_submit(
            1,
            a.clone(),
            opts,
            Query::Estimate { u, stop: StopRule::Exhaust },
            Some(4),
        )
        .unwrap();
        assert_eq!(eng.stats().shed, 1);
        let r = eng
            .answer(t1)
            .and_then(Answer::stochastic)
            .expect("shed stochastic ticket resolves immediately");
        assert!(r.probes_contributing >= 1);
        assert!(r.combined.lo <= r.combined.hi);
        assert!(r.combined.lo.is_finite() && r.combined.hi.is_finite());
        assert!(!r.tol_met, "a 1e-15 tolerance cannot be met mid-flight");

        // the flight recorder saw the whole shed: the victim's span (the
        // first submission → span 0) carries a Shed event (NaN endpoints
        // here — a stochastic victim's state is not a single interval),
        // probe retirements from the cancel, and a terminal Answered
        let kinds: Vec<FlightEventKind> = eng
            .flight()
            .expect("recorder on by default")
            .span_events(0)
            .iter()
            .map(|e| e.kind)
            .collect();
        let shed_at = kinds
            .iter()
            .position(|k| matches!(k, FlightEventKind::Shed { .. }))
            .expect("shed event recorded on the victim span");
        assert!(
            kinds.iter().any(|k| matches!(k, FlightEventKind::ProbeRetired { .. })),
            "cancelled probes attribute to the span"
        );
        assert!(
            matches!(kinds.last(), Some(FlightEventKind::Answered { .. })),
            "shed span terminates answered"
        );
        assert!(shed_at < kinds.len() - 1, "shed precedes the terminal event");
    }

    #[test]
    fn flight_recorder_traces_the_query_lifecycle() {
        let mut rng = Rng::new(0xE9630);
        let (a, w) = random_sparse_spd(&mut rng, 16, 0.3, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        let u = randvec(&mut rng, 16);
        let t = eng.submit(1, a.clone(), opts, Query::Estimate { u, stop: StopRule::Iters(3) });
        let span = eng.span_of(t).expect("live ticket has a span");

        // live introspection mid-flight: the span shows up with its
        // current bracket and rounds-elapsed
        assert!(eng.step_round());
        let live = eng.live_spans();
        assert_eq!(live.len(), 1);
        assert_eq!(live[0].span, span);
        assert_eq!(live[0].key, 1);
        assert!(live[0].rounds_elapsed >= 1);
        assert!(!live[0].parked);
        assert!(live[0].bounds.is_some(), "estimate exposes its four-bound bracket");

        eng.drain();
        assert!(eng.live_spans().is_empty(), "answered tickets leave the live view");
        let evs = eng.flight().expect("recorder on by default").span_events(span);
        let kinds: Vec<&str> = evs.iter().map(|e| e.kind.name()).collect();
        assert_eq!(
            &kinds[..3],
            &["submitted", "admitted", "planned_onto_panel"],
            "admission prefix in order"
        );
        assert!(kinds.contains(&"swept_round"), "mid-flight rounds are recorded");
        assert_eq!(*kinds.last().unwrap(), "answered");
        for p in evs.windows(2) {
            assert!(p[0].seq < p[1].seq, "per-span seq strictly increases");
            assert!(p[0].ts_ns <= p[1].ts_ns, "per-span timestamps are monotone");
        }
        match evs.last().unwrap().kind {
            FlightEventKind::Answered { rounds, .. } => assert!(rounds >= 1),
            other => panic!("wrong terminal event {other:?}"),
        }
    }

    #[test]
    fn flight_records_parks_and_resumes_and_off_means_off() {
        let mut rng = Rng::new(0xE9631);
        let (a, w) = random_sparse_spd(&mut rng, 20, 0.3, 0.05);
        let a = Arc::new(a);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default().with_lanes(1)).unwrap();
        let mk = |rng: &mut Rng| Query::Estimate { u: randvec(rng, 20), stop: StopRule::Exhaust };
        let q1 = mk(&mut rng);
        let q2 = mk(&mut rng);
        let t1 = eng.submit(3, a.clone(), opts, q1);
        let t2 = eng.submit(3, a.clone(), opts, q2);
        let s2 = eng.span_of(t2).unwrap();
        assert!(eng.step_round());
        assert!(
            eng.live_spans().iter().any(|l| l.span == s2 && l.parked),
            "budget 1 parks the younger span"
        );
        eng.drain();
        assert!(eng.is_resolved(t1) && eng.is_resolved(t2));
        let k2: Vec<&str> = eng
            .flight()
            .unwrap()
            .span_events(s2)
            .iter()
            .map(|e| e.kind.name())
            .collect();
        assert!(k2.contains(&"parked"), "suspension recorded");
        assert!(k2.contains(&"resumed"), "resumption recorded");
        assert_eq!(*k2.last().unwrap(), "answered");

        // recorder off: no Arc exists, the engine otherwise behaves
        // identically (bit-identity is property-tested in prop_engine)
        let mut off = Engine::new(EngineConfig::default().with_flight(false)).unwrap();
        assert!(off.flight().is_none());
        let t = off.submit(3, a, opts, mk(&mut rng));
        off.drain();
        assert!(off.is_resolved(t));
        assert!(off.span_of(t).is_some(), "span ids exist with the recorder off");
    }
}
