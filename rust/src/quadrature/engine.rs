//! Multi-operator streaming engine: one always-on scheduler that runs
//! every live [`Session`] jointly.
//!
//! The paper's central economy is that Gauss/Radau/Lobatto brackets
//! tighten at a linear rate (Thm. 3/5/8), so decisions resolve long
//! before full convergence. PR 4's [`Session`] exploits that *within one
//! operator* — mixed queries share `matvec_multi` panels — but every
//! cross-operator consumer still ran its own lockstep loop: `race_dg`'s
//! Δ⁺/Δ⁻ sides live on different submatrices, a k-DPP chain pool holds
//! several live `L_{Y'}` operators, and the coordinator drained one
//! coalesce key at a time. Block-quadrature results (Zimmerling, Druskin
//! & Simoncini, arXiv:2407.21505) show the batched recurrence preserves
//! exactly the monotone-bound structure the pruning relies on, so nothing
//! stops scheduling *all* live operators' panels in one joint round loop.
//!
//! The [`Engine`] owns a pool of live sessions keyed by operator
//! ([`OpKey`]) and drives them from a single round loop — one
//! `matvec_multi` panel per operator per round, sessions swept in
//! parallel by a small hand-rolled worker fan-out
//! (the PR 1 "parallel panel sweep" item: scoped threads over disjoint
//! session chunks, no locks, bit-identical at any worker count because
//! each session is an independent state machine stepped exactly once per
//! round). It adds three scheduling capabilities:
//!
//! * **Streaming submission** — [`Engine::submit`] is accepted mid-flight
//!   and lands in the next round's panel for that operator; sessions spin
//!   up lazily on first use of a key and idle sessions are evicted after
//!   [`EngineConfig::ttl_rounds`] workless rounds (a later submission
//!   under the same key spins a fresh session).
//! * **Query-level suspend/resume** — a global lane budget
//!   ([`EngineConfig::lanes`]) parks whole queries
//!   ([`Session::suspend_query`], which carries full mid-run lane state
//!   through [`BlockGql::suspend`](super::block::BlockGql::suspend))
//!   under pressure and resumes them bit-identically, priority-ordered by
//!   submission: the oldest unresolved query always keeps its lanes (and
//!   is never split), younger ones park until capacity frees.
//! * **Joint scheduling for cross-operator consumers** —
//!   [`race_dg_joint`] submits the double-greedy Δ⁺/Δ⁻ sides as two
//!   estimate queries on two operators and decides from per-round bracket
//!   exchange; `apps::kdpp::step_chains` advances a pool of k-DPP chains'
//!   swap tests jointly; `apps::dpp::greedy_map_multi` races several
//!   kernels' greedy rounds at once; the coordinator's native drain is a
//!   thin engine client.
//!
//! **Invariant — a scheduler, not a numeric path.** Engine answers are
//! bit-identical to sequential per-operator [`Session`] runs: the engine
//! never touches panel math, it only decides *when* each session steps.
//! Per-lane op sequences are fixed by the block engine's exactness
//! contract regardless of interleaving, suspended queries resume with
//! their exact mid-run state, and every decision is certified by the same
//! nested brackets — property-tested in `rust/tests/prop_engine.rs`,
//! including streaming submission, a lane budget of 1, `Reorth::Full` on
//! ill-conditioned kernels, and multi-worker sweeps.

use super::block::RetireReason;
use super::gql::{Bounds, GqlOptions};
use super::is_zero;
use super::judge::{JudgeOutcome, JudgeStats};
use super::query::{Answer, Query, Session};
use super::race::RacePolicy;
use crate::metrics::{Histogram, MetricsRegistry};
use crate::sparse::SymOp;
use std::fmt;
use std::time::Instant;

/// Identifies one operator (and therefore one session) inside an engine.
/// Callers pick keys; co-keyed submissions must target the *same*
/// operator (the coordinator's `op_key` contract). Keys at or above
/// [`ANON_KEY_BASE`] are reserved for [`Engine::fresh_key`].
pub type OpKey = u64;

/// Keys handed out by [`Engine::fresh_key`] start here; user keys should
/// stay below to avoid collisions.
pub const ANON_KEY_BASE: OpKey = 1 << 63;

/// Ceiling for [`EngineConfig::lanes`]: a budget above this cannot be a
/// real capacity plan (a panel lane costs O(n) floats; 2²⁰ lanes of even
/// tiny operators is gigabytes) and is rejected as a typo at admission.
pub const MAX_ENGINE_LANES: usize = 1 << 20;
/// Ceiling for [`EngineConfig::ttl_rounds`]: beyond this an "idle"
/// session would outlive any realistic run — rejected as a typo.
pub const MAX_ENGINE_TTL: usize = 1 << 20;
/// Ceiling for [`EngineConfig::workers`]: the sweep fan-out spawns scoped
/// threads, so absurd worker counts are rejected rather than honored.
pub const MAX_ENGINE_WORKERS: usize = 1 << 10;

/// Typed rejection of unusable engine knobs, mirroring
/// [`BatchPolicy::validate`](crate::coordinator::BatchPolicy): checked at
/// admission ([`Engine::new`], `RunConfig` parsing) so a bad config fails
/// loudly instead of deadlocking the round loop or exhausting memory.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineConfigError {
    /// `engine_lanes == 0`: no query could ever hold a lane.
    ZeroLanes,
    /// `engine_lanes` beyond [`MAX_ENGINE_LANES`].
    AbsurdLanes(usize),
    /// `engine_ttl_rounds == 0`: every session would be evicted the round
    /// it went idle, defeating the always-on design.
    ZeroTtl,
    /// `engine_ttl_rounds` beyond [`MAX_ENGINE_TTL`].
    AbsurdTtl(usize),
    /// A zero per-session panel width.
    ZeroWidth,
    /// A zero sweep worker count.
    ZeroWorkers,
    /// Worker count beyond [`MAX_ENGINE_WORKERS`].
    AbsurdWorkers(usize),
}

impl fmt::Display for EngineConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineConfigError::ZeroLanes => {
                write!(f, "engine_lanes must be >= 1 (0 would park every query forever)")
            }
            EngineConfigError::AbsurdLanes(v) => write!(
                f,
                "engine_lanes = {v} exceeds the sanity ceiling {MAX_ENGINE_LANES}"
            ),
            EngineConfigError::ZeroTtl => write!(
                f,
                "engine_ttl_rounds must be >= 1 (0 would evict sessions the round they idle)"
            ),
            EngineConfigError::AbsurdTtl(v) => write!(
                f,
                "engine_ttl_rounds = {v} exceeds the sanity ceiling {MAX_ENGINE_TTL}"
            ),
            EngineConfigError::ZeroWidth => write!(f, "engine panel width must be >= 1"),
            EngineConfigError::ZeroWorkers => write!(f, "engine workers must be >= 1"),
            EngineConfigError::AbsurdWorkers(v) => write!(
                f,
                "engine workers = {v} exceeds the sanity ceiling {MAX_ENGINE_WORKERS}"
            ),
        }
    }
}

impl std::error::Error for EngineConfigError {}

/// Engine scheduling knobs. Validated by [`Engine::new`]; the
/// `engine_lanes` / `engine_ttl_rounds` pair is also validated at
/// `RunConfig` admission through [`EngineConfig::validate_knobs`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineConfig {
    /// Default panel width for sessions spun up by [`Engine::submit`]
    /// ([`Engine::spin_up`] can override per key).
    pub width: usize,
    /// Global live-lane budget across every session: when the demand of
    /// unresolved queries exceeds it, younger queries are parked whole
    /// (suspend/resume, bit-identical) until capacity frees. The
    /// head-of-line query always runs, so the budget can never deadlock.
    pub lanes: usize,
    /// Idle sessions (no unresolved query, no queued lane) are evicted
    /// after this many consecutive workless rounds.
    pub ttl_rounds: usize,
    /// Sweep workers: sessions are stepped in parallel chunks when more
    /// than one is live. Results are bit-identical at any worker count.
    pub workers: usize,
    /// Default race policy for sessions spun up by [`Engine::submit`].
    pub policy: RacePolicy,
    /// Collect a [`RoundProfile`] (per-round phase timings, per-worker
    /// busy/idle accounting, per-session step-time histogram). Off by
    /// default: the unprofiled round loop carries zero instrumentation.
    /// Timing reads never touch panel math, so profiled answers stay
    /// bit-identical.
    pub profile: bool,
    /// Sessions spun up by this engine record per-query convergence
    /// traces ([`Session::record_traces`]); resolved estimate answers
    /// then carry a [`GapTrace`](crate::metrics::GapTrace).
    pub record_traces: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            width: 16,
            lanes: 256,
            ttl_rounds: 32,
            workers: 1,
            policy: RacePolicy::Prune,
            profile: false,
            record_traces: false,
        }
    }
}

impl EngineConfig {
    pub fn with_width(mut self, w: usize) -> Self {
        self.width = w;
        self
    }

    pub fn with_lanes(mut self, l: usize) -> Self {
        self.lanes = l;
        self
    }

    pub fn with_ttl_rounds(mut self, t: usize) -> Self {
        self.ttl_rounds = t;
        self
    }

    pub fn with_workers(mut self, w: usize) -> Self {
        self.workers = w;
        self
    }

    pub fn with_policy(mut self, p: RacePolicy) -> Self {
        self.policy = p;
        self
    }

    pub fn with_profile(mut self, on: bool) -> Self {
        self.profile = on;
        self
    }

    pub fn with_record_traces(mut self, on: bool) -> Self {
        self.record_traces = on;
        self
    }

    /// Validate the pair of config-file knobs (`engine_lanes`,
    /// `engine_ttl_rounds`) — shared by [`EngineConfig::validate`] and
    /// `RunConfig` JSON/CLI admission so both reject the same values with
    /// the same typed error.
    pub fn validate_knobs(lanes: usize, ttl_rounds: usize) -> Result<(), EngineConfigError> {
        if lanes == 0 {
            return Err(EngineConfigError::ZeroLanes);
        }
        if lanes > MAX_ENGINE_LANES {
            return Err(EngineConfigError::AbsurdLanes(lanes));
        }
        if ttl_rounds == 0 {
            return Err(EngineConfigError::ZeroTtl);
        }
        if ttl_rounds > MAX_ENGINE_TTL {
            return Err(EngineConfigError::AbsurdTtl(ttl_rounds));
        }
        Ok(())
    }

    /// Reject configurations the round loop cannot run under.
    pub fn validate(&self) -> Result<(), EngineConfigError> {
        Self::validate_knobs(self.lanes, self.ttl_rounds)?;
        if self.width == 0 {
            return Err(EngineConfigError::ZeroWidth);
        }
        if self.workers == 0 {
            return Err(EngineConfigError::ZeroWorkers);
        }
        if self.workers > MAX_ENGINE_WORKERS {
            return Err(EngineConfigError::AbsurdWorkers(self.workers));
        }
        Ok(())
    }
}

/// Aggregate accounting for one engine.
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Joint rounds performed (each round sweeps one panel per live
    /// operator — the cross-operator cost model the experiments report).
    pub rounds: usize,
    /// Total `matvec_multi` panel sweeps across every session (≥ rounds:
    /// a round with `k` live operators spends `k` sweeps).
    pub sweeps: usize,
    /// Queries accepted.
    pub submitted: usize,
    /// Sessions spun up lazily.
    pub sessions_spun: usize,
    /// Idle sessions evicted by the TTL.
    pub sessions_evicted: usize,
    /// Queries parked by the lane budget.
    pub parks: usize,
    /// Parked queries resumed.
    pub resumes: usize,
    /// Largest per-round live-lane demand actually admitted.
    pub peak_live_lanes: usize,
    /// Lanes retired by interval dominance across every session
    /// (harvested from the [`RetireEvent`](super::block::RetireEvent)
    /// log — sweeps the pruning saved).
    pub retired_dominated: usize,
    /// Lanes retired because the surrounding decision resolved first.
    pub retired_decided: usize,
}

/// Cumulative round-loop profile, collected when
/// [`EngineConfig::profile`] is set (see [`Engine::profile`]).
///
/// Phase timings split each round into its three serial phases —
/// scheduling/refill ([`Engine`]'s lane-budget pass), the panel sweep
/// (every live session's `matvec_multi` panel + bound updates), and
/// harvest (answer pulling + TTL eviction). Worker utilization compares
/// the summed per-session step time (`busy_ns`) against what the engaged
/// workers *could* have done during the sweep wall time (`capacity_ns`),
/// so the static-`chunks_mut` tail idleness is a measured number instead
/// of folklore. `step_ns` aggregates per-session step times from
/// per-worker thread-local histograms merged at round end — profiling
/// adds no shared mutable state to the sweep.
#[derive(Clone, Debug, Default)]
pub struct RoundProfile {
    /// Rounds that contributed to this profile.
    pub rounds: usize,
    /// Total ns in the lane-budget scheduling pass.
    pub schedule_ns: u64,
    /// Total wall-clock ns in the panel sweep phase.
    pub sweep_ns: u64,
    /// Total ns in answer harvest + TTL eviction.
    pub harvest_ns: u64,
    /// Summed per-session step time across all workers.
    pub busy_ns: u64,
    /// Sweep wall time × engaged workers: the time the sweep *bought*.
    pub capacity_ns: u64,
    /// Distribution of individual `Session::step` times (ns).
    pub step_ns: Histogram,
}

impl RoundProfile {
    /// Fraction of bought worker time spent stepping sessions.
    pub fn busy_frac(&self) -> f64 {
        if self.capacity_ns == 0 {
            0.0
        } else {
            (self.busy_ns as f64 / self.capacity_ns as f64).min(1.0)
        }
    }

    /// Fraction of bought worker time spent idle — for the static chunk
    /// split this is the measured tail-idleness of the sweep fan-out.
    pub fn idle_frac(&self) -> f64 {
        if self.capacity_ns == 0 {
            0.0
        } else {
            1.0 - self.busy_frac()
        }
    }
}

/// One live operator: its session plus the tickets still pointing at it.
struct OpSlot<'a> {
    key: OpKey,
    session: Session<'a>,
    /// Tickets not yet harvested into [`Engine`]`::tickets` answers.
    open: Vec<usize>,
    /// Consecutive workless harvests (drives TTL eviction).
    idle_rounds: usize,
    /// Session sweep count at the last harvest (delta accounting).
    last_sweeps: usize,
    /// Retire-log length at the last harvest (delta accounting for the
    /// dominated/decided counters).
    last_retired: usize,
    /// Set by the planner each round; read by the sweep workers.
    live: bool,
}

/// Ticket bookkeeping: which session/query answers it, and the harvested
/// answer once resolved (sessions may be evicted afterwards).
struct TicketState {
    key: OpKey,
    qid: usize,
    answer: Option<Answer>,
}

/// The always-on scheduler. See the module docs for the design; the
/// lifecycle is: [`Engine::submit`] (any time, including mid-flight) →
/// [`Engine::step_round`] / [`Engine::drain`] → [`Engine::answer`].
///
/// Resolved tickets stay addressable for the engine's lifetime —
/// [`Engine::answer`] is the API — so the ticket log only grows. The
/// scheduling and liveness passes skip the fully-resolved prefix through
/// a cursor, keeping per-round cost O(open tickets) regardless of
/// history; the retained answers themselves are the price of the stable
/// ticket ids. Every current consumer builds a per-burst engine, which
/// bounds that price; a truly service-resident engine wants the
/// ticket-log compaction listed as a ROADMAP follow-up.
pub struct Engine<'a> {
    cfg: EngineConfig,
    slots: Vec<OpSlot<'a>>,
    tickets: Vec<TicketState>,
    /// Every ticket below this index is resolved (the scheduling passes
    /// start here; advanced by `harvest`).
    first_open: usize,
    stats: EngineStats,
    /// Round-loop profile, allocated iff [`EngineConfig::profile`] —
    /// `None` keeps the unprofiled hot path free of even a branch-y
    /// accumulation.
    profile: Option<Box<RoundProfile>>,
    next_anon: OpKey,
}

impl<'a> Engine<'a> {
    /// Build an engine, rejecting unusable knobs with a typed error.
    pub fn new(cfg: EngineConfig) -> Result<Self, EngineConfigError> {
        cfg.validate()?;
        Ok(Engine {
            cfg,
            slots: Vec::new(),
            tickets: Vec::new(),
            first_open: 0,
            stats: EngineStats::default(),
            profile: cfg.profile.then(|| Box::new(RoundProfile::default())),
            next_anon: ANON_KEY_BASE,
        })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Accounting snapshot.
    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    /// The collected round profile ([`EngineConfig::profile`] engines
    /// only).
    pub fn profile(&self) -> Option<&RoundProfile> {
        self.profile.as_deref()
    }

    /// Publish stats (and the round profile, when collected) into `reg`
    /// under `engine.*` names. Idempotent set-style writes.
    pub fn export_into(&self, reg: &MetricsRegistry) {
        let st = &self.stats;
        reg.set_counter("engine.rounds", st.rounds as u64);
        reg.set_counter("engine.sweeps", st.sweeps as u64);
        reg.set_counter("engine.submitted", st.submitted as u64);
        reg.set_counter("engine.sessions_spun", st.sessions_spun as u64);
        reg.set_counter("engine.sessions_evicted", st.sessions_evicted as u64);
        reg.set_counter("engine.parks", st.parks as u64);
        reg.set_counter("engine.resumes", st.resumes as u64);
        reg.set_counter("engine.retired_dominated", st.retired_dominated as u64);
        reg.set_counter("engine.retired_decided", st.retired_decided as u64);
        reg.set_gauge("engine.peak_live_lanes", st.peak_live_lanes as f64);
        reg.set_gauge("engine.live_sessions", self.slots.len() as f64);
        if let Some(p) = self.profile.as_deref() {
            reg.set_counter("engine.profile.rounds", p.rounds as u64);
            reg.set_counter("engine.profile.schedule_ns", p.schedule_ns);
            reg.set_counter("engine.profile.sweep_ns", p.sweep_ns);
            reg.set_counter("engine.profile.harvest_ns", p.harvest_ns);
            reg.set_counter("engine.profile.busy_ns", p.busy_ns);
            reg.set_counter("engine.profile.capacity_ns", p.capacity_ns);
            reg.set_gauge("engine.profile.worker_busy_frac", p.busy_frac());
            reg.set_gauge("engine.profile.worker_idle_frac", p.idle_frac());
            reg.set_histogram("engine.profile.step_ns", p.step_ns.clone());
        }
    }

    /// Live (not yet evicted) sessions.
    pub fn sessions(&self) -> usize {
        self.slots.len()
    }

    /// A key guaranteed not to collide with other [`Engine::fresh_key`]
    /// keys (consumers without a natural operator id — `race_dg_joint`'s
    /// per-element sides — use these; keep user keys below
    /// [`ANON_KEY_BASE`]).
    pub fn fresh_key(&mut self) -> OpKey {
        let k = self.next_anon;
        self.next_anon += 1;
        k
    }

    fn slot_index(&self, key: OpKey) -> Option<usize> {
        self.slots.iter().position(|s| s.key == key)
    }

    /// Look up — or lazily spin up — the session for `key`, with an
    /// explicit panel width and race policy for the spin-up case (an
    /// existing session keeps its own). Returns the slot index for
    /// [`Engine::submit_to`].
    pub fn spin_up(
        &mut self,
        key: OpKey,
        op: &'a dyn SymOp,
        opts: GqlOptions,
        width: usize,
        policy: RacePolicy,
    ) -> usize {
        if let Some(i) = self.slot_index(key) {
            // key contract (same as the coordinator's `op_key`): co-keyed
            // submissions target one operator; `op`/`opts`/`width`/
            // `policy` of later calls are ignored for an existing session
            return i;
        }
        let mut session = Session::new(op, opts, width.max(1), policy);
        if self.cfg.record_traces {
            session = session.record_traces(true);
        }
        self.slots.push(OpSlot {
            key,
            session,
            open: Vec::new(),
            idle_rounds: 0,
            last_sweeps: 0,
            last_retired: 0,
            live: false,
        });
        self.stats.sessions_spun += 1;
        self.slots.len() - 1
    }

    /// Streaming submission: enter `q` against the operator behind `key`,
    /// spinning up a session lazily (with the engine-default width and
    /// policy). Accepted mid-flight — the query's lanes land in the next
    /// round's panel for that operator. Returns a ticket for
    /// [`Engine::answer`].
    pub fn submit(&mut self, key: OpKey, op: &'a dyn SymOp, opts: GqlOptions, q: Query) -> usize {
        let (width, policy) = (self.cfg.width, self.cfg.policy);
        let slot = self.spin_up(key, op, opts, width, policy);
        self.submit_to(slot, q)
    }

    /// [`Engine::submit`] against a slot obtained from
    /// [`Engine::spin_up`] (callers that pick per-operator widths or
    /// policies, like the coordinator's native drain).
    pub fn submit_to(&mut self, slot: usize, q: Query) -> usize {
        let ticket = self.tickets.len();
        let (key, qid, answer) = {
            let s = &mut self.slots[slot];
            let qid = s.session.submit(q);
            // trivially-decidable queries (zero vectors, empty argmax
            // batches) answer at submission without ever taking a lane
            (s.key, qid, s.session.answer(qid).cloned())
        };
        let resolved = answer.is_some();
        self.tickets.push(TicketState { key, qid, answer });
        if !resolved {
            let s = &mut self.slots[slot];
            s.open.push(ticket);
            s.idle_rounds = 0;
        }
        self.stats.submitted += 1;
        ticket
    }

    /// The harvested answer of `ticket`, if resolved.
    pub fn answer(&self, ticket: usize) -> Option<&Answer> {
        self.tickets[ticket].answer.as_ref()
    }

    /// True once `ticket` carries an answer.
    pub fn is_resolved(&self, ticket: usize) -> bool {
        self.tickets[ticket].answer.is_some()
    }

    /// Latest bracket of a single-lane (estimate/threshold) ticket:
    /// mid-flight snapshot while racing, final bounds after resolution.
    /// Cross-operator consumers decide from these between rounds.
    pub fn bounds(&self, ticket: usize) -> Option<Bounds> {
        let t = &self.tickets[ticket];
        if let Some(Answer::Estimate { bounds, .. }) = &t.answer {
            return Some(*bounds);
        }
        self.slot_index(t.key)
            .and_then(|i| self.slots[i].session.bounds(t.qid))
    }

    /// Resolve an estimate ticket right now with its latest bracket
    /// (see [`Session::cancel`]); its lane stops consuming sweeps.
    pub fn cancel(&mut self, ticket: usize) -> bool {
        if self.tickets[ticket].answer.is_some() {
            return false;
        }
        let (key, qid) = (self.tickets[ticket].key, self.tickets[ticket].qid);
        let Some(i) = self.slot_index(key) else {
            return false;
        };
        if !self.slots[i].session.cancel(qid) {
            return false;
        }
        let ans = self.slots[i].session.answer(qid).cloned();
        debug_assert!(ans.is_some(), "cancel resolved the query");
        self.tickets[ticket].answer = ans;
        self.slots[i].open.retain(|&t| t != ticket);
        // the cancel retired a lane; account it now — no harvest may
        // follow if this was the engine's last open ticket
        drain_retire_log(&mut self.slots[i], &mut self.stats);
        true
    }

    /// True while some ticket has no answer yet.
    pub fn has_work(&self) -> bool {
        self.tickets[self.first_open..]
            .iter()
            .any(|t| t.answer.is_none())
    }

    /// The lane-budget pass: walk unresolved queries in submission order
    /// (the priority order), keep them live while the budget holds, park
    /// the rest. The head-of-line query always runs whole — the budget
    /// never splits a query's lanes, so a width-2 compare under
    /// `lanes = 1` runs alone rather than deadlocking.
    fn schedule(&mut self) {
        let budget = self.cfg.lanes;
        let mut used = 0usize;
        let pending: Vec<(OpKey, usize)> = self.tickets[self.first_open..]
            .iter()
            .filter(|t| t.answer.is_none())
            .map(|t| (t.key, t.qid))
            .collect();
        for (key, qid) in pending {
            let Some(i) = self.slot_index(key) else {
                continue;
            };
            let slot = &mut self.slots[i];
            if slot.session.is_resolved(qid) {
                continue; // resolved this round; harvested after the sweep
            }
            let demand = slot.session.lane_demand(qid).max(1);
            if used == 0 || used + demand <= budget {
                if slot.session.is_parked(qid) && slot.session.resume_query(qid) {
                    self.stats.resumes += 1;
                }
                used += demand;
            } else if !slot.session.is_parked(qid) && slot.session.suspend_query(qid) {
                self.stats.parks += 1;
            }
        }
        if used > self.stats.peak_live_lanes {
            self.stats.peak_live_lanes = used;
        }
    }

    /// Pull freshly-resolved answers out of every session, account
    /// sweeps, and evict sessions idle past the TTL.
    fn harvest(&mut self) {
        let ttl = self.cfg.ttl_rounds;
        let mut i = 0;
        while i < self.slots.len() {
            let evict = {
                let slot = &mut self.slots[i];
                let sw = slot.session.sweeps();
                self.stats.sweeps += sw - slot.last_sweeps;
                slot.last_sweeps = sw;
                // retire-log delta: counted every harvest, so events are
                // never lost to a same-round TTL eviction
                drain_retire_log(slot, &mut self.stats);
                let session = &slot.session;
                let tickets = &mut self.tickets;
                slot.open.retain(|&tk| {
                    let st = &mut tickets[tk];
                    match session.answer(st.qid) {
                        Some(a) => {
                            st.answer = Some(a.clone());
                            false
                        }
                        None => true,
                    }
                });
                if slot.open.is_empty() && !slot.session.has_work() {
                    slot.idle_rounds += 1;
                    slot.idle_rounds > ttl
                } else {
                    slot.idle_rounds = 0;
                    false
                }
            };
            if evict {
                self.slots.remove(i);
                self.stats.sessions_evicted += 1;
            } else {
                i += 1;
            }
        }
        // advance the resolved-prefix cursor so liveness and budget
        // passes never rescan history
        while self.first_open < self.tickets.len()
            && self.tickets[self.first_open].answer.is_some()
        {
            self.first_open += 1;
        }
    }

    /// One joint round: the lane-budget pass, then one panel sweep per
    /// live operator (in parallel when configured), then answer harvest
    /// and TTL eviction. Returns `false` (after still harvesting) once no
    /// session has work — every remaining ticket is then resolved.
    pub fn step_round(&mut self) -> bool {
        if self.profile.is_some() {
            return self.step_round_profiled();
        }
        self.schedule();
        let mut live = 0usize;
        for s in &mut self.slots {
            s.live = s.session.has_work();
            if s.live {
                live += 1;
            }
        }
        if live == 0 {
            self.harvest();
            return false;
        }
        let workers = self.cfg.workers;
        if workers > 1 && live > 1 {
            sweep_parallel(&mut self.slots, workers);
        } else {
            for s in &mut self.slots {
                if s.live {
                    s.session.step();
                }
            }
        }
        self.stats.rounds += 1;
        self.harvest();
        true
    }

    /// [`Engine::step_round`] with phase timing and worker accounting.
    /// Kept as a separate body so the unprofiled loop carries zero
    /// instrumentation; the scheduling/sweep/harvest logic is identical
    /// (timing only reads clocks — it cannot perturb panel math, so
    /// profiled answers stay bit-identical).
    fn step_round_profiled(&mut self) -> bool {
        let t_sched = Instant::now();
        self.schedule();
        let schedule_ns = t_sched.elapsed().as_nanos() as u64;

        let mut live = 0usize;
        for s in &mut self.slots {
            s.live = s.session.has_work();
            if s.live {
                live += 1;
            }
        }
        if live == 0 {
            let t_h = Instant::now();
            self.harvest();
            if let Some(p) = self.profile.as_deref_mut() {
                p.schedule_ns += schedule_ns;
                p.harvest_ns += t_h.elapsed().as_nanos() as u64;
            }
            return false;
        }
        let workers = self.cfg.workers;
        let t_sweep = Instant::now();
        let (steps, busy_ns, engaged) = if workers > 1 && live > 1 {
            sweep_parallel_profiled(&mut self.slots, workers)
        } else {
            let mut h = Histogram::new();
            let mut busy = 0u64;
            for s in &mut self.slots {
                if s.live {
                    let t = Instant::now();
                    s.session.step();
                    let ns = t.elapsed().as_nanos() as u64;
                    h.record(ns as f64);
                    busy += ns;
                }
            }
            (h, busy, 1)
        };
        let sweep_ns = t_sweep.elapsed().as_nanos() as u64;
        self.stats.rounds += 1;
        let t_h = Instant::now();
        self.harvest();
        let harvest_ns = t_h.elapsed().as_nanos() as u64;
        if let Some(p) = self.profile.as_deref_mut() {
            p.rounds += 1;
            p.schedule_ns += schedule_ns;
            p.sweep_ns += sweep_ns;
            p.harvest_ns += harvest_ns;
            p.busy_ns += busy_ns;
            p.capacity_ns += sweep_ns * engaged as u64;
            p.step_ns.merge(&steps);
        }
        true
    }

    /// Drive every submitted query to its answer.
    pub fn drain(&mut self) {
        while self.has_work() {
            if !self.step_round() {
                break;
            }
        }
        debug_assert!(!self.has_work(), "engine idle with unresolved tickets");
    }
}

/// Pull new [`RetireEvent`](super::block::RetireEvent)s out of a slot's
/// session log into the engine counters (delta via the slot's
/// `last_retired` cursor — each event is counted exactly once).
fn drain_retire_log(slot: &mut OpSlot<'_>, stats: &mut EngineStats) {
    let events = slot.session.retired();
    for e in &events[slot.last_retired..] {
        match e.reason {
            RetireReason::Dominated => stats.retired_dominated += 1,
            RetireReason::Decided => stats.retired_decided += 1,
        }
    }
    slot.last_retired = events.len();
}

/// The hand-rolled parallel panel sweep (the PR 1 follow-up): fan the
/// live sessions out over scoped worker threads in disjoint `chunks_mut`
/// slices — no locks, no work queue, and exactly one `Session::step` per
/// live session per round, so the result is bit-identical to the
/// sequential loop at any worker count. Engine bookkeeping (scheduling,
/// harvest, eviction) stays on the driving thread between rounds.
fn sweep_parallel(slots: &mut [OpSlot<'_>], workers: usize) {
    let w = workers.min(slots.len()).max(1);
    let chunk = slots.len().div_ceil(w);
    std::thread::scope(|scope| {
        for part in slots.chunks_mut(chunk) {
            scope.spawn(move || {
                for slot in part {
                    if slot.live {
                        slot.session.step();
                    }
                }
            });
        }
    });
}

/// [`sweep_parallel`] with per-worker accounting: each worker records its
/// own step-time histogram and busy nanoseconds thread-locally (no shared
/// mutable state touches the sweep), merged on the driving thread after
/// the scope joins. Returns `(step histogram, Σ busy ns, engaged
/// workers)` — engaged × sweep-wall-time is the capacity the busy
/// fraction is measured against.
fn sweep_parallel_profiled(
    slots: &mut [OpSlot<'_>],
    workers: usize,
) -> (Histogram, u64, usize) {
    let w = workers.min(slots.len()).max(1);
    let chunk = slots.len().div_ceil(w);
    let mut steps = Histogram::new();
    let mut busy_ns = 0u64;
    let mut engaged = 0usize;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for part in slots.chunks_mut(chunk) {
            handles.push(scope.spawn(move || {
                let mut h = Histogram::new();
                let mut busy = 0u64;
                for slot in part {
                    if slot.live {
                        let t = Instant::now();
                        slot.session.step();
                        let ns = t.elapsed().as_nanos() as u64;
                        h.record(ns as f64);
                        busy += ns;
                    }
                }
                (h, busy)
            }));
        }
        engaged = handles.len();
        for handle in handles {
            let (h, busy) = handle.join().unwrap();
            steps.merge(&h);
            busy_ns += busy;
        }
    });
    (steps, busy_ns, engaged)
}

// ---------------------------------------------------------------------------
// Cross-operator consumer: the double-greedy inclusion race (paper Alg. 9)
// ---------------------------------------------------------------------------

/// One side of a joint double-greedy race: the operator (`L_X` or
/// `L_{Y'}`), the query column of the candidate element against it, and
/// the side's spectrum options.
pub struct DgSideSpec<'a> {
    pub op: &'a dyn SymOp,
    pub u: &'a [f64],
    pub opts: GqlOptions,
}

struct DgSideRun {
    ticket: usize,
    max_iters: usize,
}

/// Double-greedy inclusion test over a shared [`Engine`] (the
/// cross-operator ROADMAP item): with Δ⁺ = log(l_ii − u_x^T L_X^{-1} u_x)
/// and Δ⁻ = −log(l_ii − u_y^T L_{Y'}^{-1} u_y), returns true (add `i` to
/// X) iff `p·[Δ⁻]₊ ≤ (1−p)·[Δ⁺]₊`.
///
/// Both sides enter the engine as estimate queries on *different*
/// operators and advance together — one `matvec_multi` panel per operator
/// per engine round — so the comparison resolves from per-round bracket
/// exchange in `max(a, b)`-ish rounds where the sequential §5.2
/// alternation of [`race_dg`](super::race::race_dg) spends `a + b` single
/// side steps. Decisions are identical to `race_dg` (and to exact
/// scoring) wherever brackets certify them, because both read the same
/// nested Radau brackets; only the refinement *schedule* differs, so
/// iteration counts may. Under [`RacePolicy::Prune`] the race stops at
/// the first certified separation (abandoned refinement is cancelled);
/// [`RacePolicy::Exhaustive`] refines both sides to exhaustion/budget
/// first and decides identically from the final brackets.
///
/// Sides may be `None` (empty set: Δ is exact from `l_ii` alone) — zero
/// query columns are treated the same way, mirroring `race_dg`.
pub fn race_dg_joint<'a>(
    eng: &mut Engine<'a>,
    x: Option<DgSideSpec<'a>>,
    y: Option<DgSideSpec<'a>>,
    l_ii: f64,
    p: f64,
    policy: RacePolicy,
) -> (bool, JudgeStats) {
    let mut enter = |side: Option<DgSideSpec<'a>>| -> Option<DgSideRun> {
        let s = side?;
        if is_zero(s.u) {
            return None; // zero query ⇒ BIF = 0 exactly; an absent side
        }
        let max_iters = s.opts.max_iters.min(s.op.dim()).max(1);
        let key = eng.fresh_key();
        let ticket = eng.submit(
            key,
            s.op,
            s.opts,
            Query::Estimate {
                u: s.u.to_vec(),
                stop: super::block::StopRule::Exhaust,
            },
        );
        Some(DgSideRun { ticket, max_iters })
    };
    let tx = enter(x);
    let ty = enter(y);

    // bracket of log(t − bif) given BIF bounds [lo, hi]; −∞ for a
    // non-positive argument ([x]₊ clamps later) — same as race_dg
    let log_gap = |lo_arg: f64, hi_arg: f64| -> (f64, f64) {
        let lo = if lo_arg > 0.0 { lo_arg.ln() } else { f64::NEG_INFINITY };
        let hi = if hi_arg > 0.0 { hi_arg.ln() } else { f64::NEG_INFINITY };
        (lo, hi)
    };
    let pos = |v: f64| v.max(0.0);

    let mut stalled = false;
    loop {
        // (lo, hi, exact, stuck, iter, known) of a side this round
        let side_state = |run: &Option<DgSideRun>, eng: &Engine<'a>| match run {
            None => (0.0, 0.0, true, true, 0usize, true),
            Some(r) => match eng.bounds(r.ticket) {
                Some(b) => (
                    b.lower(),
                    b.upper(),
                    b.exact,
                    b.exact || b.iter >= r.max_iters || eng.is_resolved(r.ticket),
                    b.iter,
                    true,
                ),
                // submitted but not yet swept (possible under a tight
                // lane budget): undecidable this round
                None => (0.0, 0.0, false, false, 0usize, false),
            },
        };
        let (x_lo, x_hi, x_exact, x_stuck, x_iter, x_known) = side_state(&tx, eng);
        let (y_lo, y_hi, y_exact, y_stuck, y_iter, y_known) = side_state(&ty, eng);

        if x_known && y_known {
            let iters = x_iter + y_iter;
            // Δ⁺ ∈ [log(l_ii − x_hi), log(l_ii − x_lo)]
            let (dp_lo, dp_hi) = log_gap(l_ii - x_hi, l_ii - x_lo);
            // Δ⁻ ∈ [−log(l_ii − y_lo), −log(l_ii − y_hi)] (sign flip)
            let (ly_lo, ly_hi) = log_gap(l_ii - y_hi, l_ii - y_lo);
            let (dm_lo, dm_hi) = (-ly_hi, -ly_lo);

            let decided = if policy == RacePolicy::Prune {
                if p * pos(dm_hi) <= (1.0 - p) * pos(dp_lo) {
                    Some(true)
                } else if p * pos(dm_lo) > (1.0 - p) * pos(dp_hi) {
                    Some(false)
                } else {
                    None
                }
            } else {
                None
            };
            let (decision, outcome) = match decided {
                Some(d) => (
                    Some(d),
                    if x_exact && y_exact { JudgeOutcome::Exact } else { JudgeOutcome::Decided },
                ),
                None if x_exact && y_exact => (
                    Some(p * pos(dm_lo) <= (1.0 - p) * pos(dp_lo)),
                    JudgeOutcome::Exact,
                ),
                None if (x_stuck && y_stuck) || stalled => {
                    // at least one side out of budget: midpoints, like the
                    // scalar judges (exact sides have collapsed brackets)
                    let dp_mid = 0.5 * (pos(dp_lo) + pos(dp_hi));
                    let dm_mid = 0.5 * (pos(dm_lo) + pos(dm_hi));
                    (Some(p * dm_mid <= (1.0 - p) * dp_mid), JudgeOutcome::Budget)
                }
                None => (None, JudgeOutcome::Decided),
            };
            if let Some(d) = decision {
                for run in [&tx, &ty].into_iter().flatten() {
                    // abandon refinement the decision no longer needs
                    let _ = eng.cancel(run.ticket);
                }
                return (d, JudgeStats { iters, outcome });
            }
        }
        // refine: every live side advances one panel this round
        let progressed = eng.step_round();
        if !progressed {
            // no session can move: the next pass must decide (both sides
            // resolved ⇒ stuck); `stalled` forces the midpoint exit even
            // if a bracket never materialized
            debug_assert!(!stalled, "engine stalled twice without deciding");
            stalled = true;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::linalg::Cholesky;
    use crate::quadrature::block::StopRule;
    use crate::quadrature::race::race_dg;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    #[test]
    fn config_validation_rejects_zero_and_absurd_knobs() {
        assert!(EngineConfig::default().validate().is_ok());
        assert_eq!(
            EngineConfig::default().with_lanes(0).validate(),
            Err(EngineConfigError::ZeroLanes)
        );
        assert_eq!(
            EngineConfig::default().with_lanes(MAX_ENGINE_LANES + 1).validate(),
            Err(EngineConfigError::AbsurdLanes(MAX_ENGINE_LANES + 1))
        );
        assert_eq!(
            EngineConfig::default().with_ttl_rounds(0).validate(),
            Err(EngineConfigError::ZeroTtl)
        );
        assert_eq!(
            EngineConfig::default().with_ttl_rounds(MAX_ENGINE_TTL + 9).validate(),
            Err(EngineConfigError::AbsurdTtl(MAX_ENGINE_TTL + 9))
        );
        assert_eq!(
            EngineConfig::default().with_width(0).validate(),
            Err(EngineConfigError::ZeroWidth)
        );
        assert_eq!(
            EngineConfig::default().with_workers(0).validate(),
            Err(EngineConfigError::ZeroWorkers)
        );
        assert!(Engine::new(EngineConfig::default().with_lanes(0)).is_err());
        // the typed error names the config knob for admission messages
        assert!(EngineConfigError::ZeroLanes.to_string().contains("engine_lanes"));
        assert!(EngineConfigError::ZeroTtl.to_string().contains("engine_ttl_rounds"));
    }

    #[test]
    fn lazy_spin_up_streaming_submission_and_ttl_eviction() {
        let mut rng = Rng::new(0xE9610);
        let (a, wa) = random_sparse_spd(&mut rng, 30, 0.2, 0.05);
        let (b, wb) = random_sparse_spd(&mut rng, 12, 0.4, 0.05);
        let opts_a = GqlOptions::new(wa.lo, wa.hi);
        let opts_b = GqlOptions::new(wb.lo, wb.hi);
        let mut eng = Engine::new(EngineConfig::default().with_ttl_rounds(2)).unwrap();
        assert_eq!(eng.sessions(), 0, "sessions spin up lazily");

        // op B finishes fast; op A keeps the loop running long enough for
        // B's idle session to age past the TTL
        let ua = randvec(&mut rng, 30);
        let ub = randvec(&mut rng, 12);
        let ta = eng.submit(1, &a, opts_a, Query::Estimate { u: ua, stop: StopRule::Exhaust });
        let tb = eng.submit(2, &b, opts_b, Query::Estimate { u: ub, stop: StopRule::Iters(1) });
        assert_eq!(eng.sessions(), 2);

        // streaming: a second op-B query submitted mid-flight lands in a
        // later round and still answers
        for _ in 0..2 {
            assert!(eng.step_round());
        }
        let ub2 = randvec(&mut rng, 12);
        let tb2 = eng.submit(2, &b, opts_b, Query::Estimate { u: ub2, stop: StopRule::Iters(2) });
        eng.drain();
        assert!(eng.is_resolved(ta) && eng.is_resolved(tb) && eng.is_resolved(tb2));
        let st = eng.stats();
        assert_eq!(st.submitted, 3);
        assert_eq!(st.sessions_spun, 2);
        assert_eq!(st.sessions_evicted, 1, "idle op-B session evicted by TTL");
        assert_eq!(eng.sessions(), 1, "op A's session survives");
        assert!(st.sweeps >= st.rounds);

        // a fresh submission under the evicted key spins a new session
        let ub3 = randvec(&mut rng, 12);
        let tb3 = eng.submit(2, &b, opts_b, Query::Estimate { u: ub3, stop: StopRule::Iters(1) });
        eng.drain();
        assert!(eng.is_resolved(tb3));
        assert_eq!(eng.stats().sessions_spun, 3);
    }

    #[test]
    fn lane_budget_parks_and_resumes_priority_ordered() {
        let mut rng = Rng::new(0xE9611);
        let (a, w) = random_sparse_spd(&mut rng, 24, 0.25, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let queries: Vec<Vec<f64>> = (0..4).map(|_| randvec(&mut rng, 24)).collect();

        let run = |lanes: usize| {
            let mut eng = Engine::new(EngineConfig::default().with_lanes(lanes)).unwrap();
            let tickets: Vec<usize> = queries
                .iter()
                .map(|u| {
                    eng.submit(
                        7,
                        &a,
                        opts,
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            let answers: Vec<Answer> =
                tickets.iter().map(|&t| eng.answer(t).unwrap().clone()).collect();
            (answers, eng.stats())
        };
        let (wide, wide_st) = run(256);
        let (narrow, narrow_st) = run(1);
        assert_eq!(wide_st.parks, 0, "a wide budget parks nothing");
        assert!(narrow_st.parks > 0, "budget 1 must park the younger queries");
        assert!(narrow_st.resumes > 0, "parked queries must resume");
        assert_eq!(narrow_st.peak_live_lanes, 1);
        for (a1, a2) in wide.iter().zip(&narrow) {
            match (a1, a2) {
                (
                    Answer::Estimate { bounds: b1, iters: i1, .. },
                    Answer::Estimate { bounds: b2, iters: i2, .. },
                ) => {
                    assert_eq!(i1, i2, "suspension changed an iteration count");
                    assert_eq!(b1.gauss.to_bits(), b2.gauss.to_bits());
                    assert_eq!(b1.radau_upper.to_bits(), b2.radau_upper.to_bits());
                }
                other => panic!("wrong answer kinds {other:?}"),
            }
        }
    }

    #[test]
    fn race_dg_joint_agrees_with_race_dg_and_the_oracle() {
        forall(15, 0xE9612, |rng| {
            let n = 8 + rng.below(16);
            let (l, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let k = 2 + rng.below(n / 2);
            let all = rng.sample_indices(n, n);
            let (xs, rest) = all.split_at(k);
            let (ys, _) = rest.split_at(1 + rng.below(rest.len() - 1));
            let i = *all.last().unwrap();
            let mut xs = xs.to_vec();
            let mut ys = ys.to_vec();
            xs.sort_unstable();
            ys.sort_unstable();
            let ax = l.principal_submatrix(&xs);
            let ay = l.principal_submatrix(&ys);
            let ux: Vec<f64> = xs.iter().map(|&m| l.get(m, i)).collect();
            let uy: Vec<f64> = ys.iter().map(|&m| l.get(m, i)).collect();
            let l_ii = l.get(i, i);
            let (chx, chy) = match (
                Cholesky::factor(&ax.to_dense()),
                Cholesky::factor(&ay.to_dense()),
            ) {
                (Ok(a), Ok(b)) => (a, b),
                _ => return,
            };
            let dp = (l_ii - chx.bif(&ux)).max(1e-300).ln();
            let dm = -(l_ii - chy.bif(&uy)).max(1e-300).ln();
            let opts = GqlOptions::new(w.lo * 0.5, w.hi * 1.5);
            for p in [0.25, 0.5, 0.75] {
                let want = p * dm.max(0.0) <= (1.0 - p) * dp.max(0.0);
                let (seq, _) =
                    race_dg(Some((&ax, &ux)), Some((&ay, &uy)), l_ii, p, opts, opts,
                        RacePolicy::Prune);
                for policy in [RacePolicy::Prune, RacePolicy::Exhaustive] {
                    let mut eng = Engine::new(EngineConfig::default().with_width(1)).unwrap();
                    let (joint, js) = race_dg_joint(
                        &mut eng,
                        Some(DgSideSpec { op: &ax, u: &ux, opts }),
                        Some(DgSideSpec { op: &ay, u: &uy, opts }),
                        l_ii,
                        p,
                        policy,
                    );
                    assert_eq!(joint, want, "joint decision wrong (p={p}, {policy:?})");
                    assert_eq!(joint, seq, "joint diverged from race_dg (p={p})");
                    assert!(js.iters <= 2 * n + 2, "runaway refinement");
                    assert!(!eng.has_work(), "decided race left work behind");
                }
            }
        });
    }

    #[test]
    fn race_dg_joint_empty_and_zero_sides_are_exact() {
        let mut eng = Engine::new(EngineConfig::default()).unwrap();
        // both sides absent: Δ⁺ = log 2 > 0, Δ⁻ = −log 2 ⇒ [Δ⁻]₊ = 0 ⇒ add
        let (ans, stats) = race_dg_joint(&mut eng, None, None, 2.0, 0.3, RacePolicy::Prune);
        assert!(ans);
        assert_eq!(stats.iters, 0);
        assert_eq!(stats.outcome, JudgeOutcome::Exact);
        // a zero query column counts as an absent side
        let mut rng = Rng::new(0xE9613);
        let (a, w) = random_sparse_spd(&mut rng, 10, 0.4, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let z = vec![0.0; 10];
        let (ans, stats) = race_dg_joint(
            &mut eng,
            Some(DgSideSpec { op: &a, u: &z, opts }),
            None,
            2.0,
            0.3,
            RacePolicy::Prune,
        );
        assert!(ans);
        assert_eq!(stats.outcome, JudgeOutcome::Exact);
    }

    #[test]
    fn parallel_workers_answer_bit_identically_to_one_worker() {
        let mut rng = Rng::new(0xE9614);
        let ops: Vec<_> = (0..5)
            .map(|_| random_sparse_spd(&mut rng, 16 + rng.below(20), 0.3, 0.05))
            .collect();
        let queries: Vec<Vec<f64>> = ops
            .iter()
            .map(|(a, _)| (0..a.n).map(|_| rng.normal()).collect())
            .collect();
        let run = |workers: usize| {
            let mut eng =
                Engine::new(EngineConfig::default().with_workers(workers)).unwrap();
            let tickets: Vec<usize> = ops
                .iter()
                .zip(&queries)
                .enumerate()
                .map(|(k, ((a, w), u))| {
                    eng.submit(
                        k as OpKey,
                        a,
                        GqlOptions::new(w.lo, w.hi),
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            tickets
                .iter()
                .map(|&t| match eng.answer(t).unwrap() {
                    Answer::Estimate { bounds, iters, .. } => (bounds.gauss.to_bits(), *iters),
                    other => panic!("wrong answer kind {other:?}"),
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(4), "worker count changed a result");
    }

    #[test]
    fn profiled_engine_answers_bit_identically_and_measures_phases() {
        let mut rng = Rng::new(0xE9615);
        let ops: Vec<_> = (0..4)
            .map(|_| random_sparse_spd(&mut rng, 16 + rng.below(16), 0.3, 0.05))
            .collect();
        let queries: Vec<Vec<f64>> = ops
            .iter()
            .map(|(a, _)| (0..a.n).map(|_| rng.normal()).collect())
            .collect();
        let run = |cfg: EngineConfig| {
            let mut eng = Engine::new(cfg).unwrap();
            let tickets: Vec<usize> = ops
                .iter()
                .zip(&queries)
                .enumerate()
                .map(|(k, ((a, w), u))| {
                    eng.submit(
                        k as OpKey,
                        a,
                        GqlOptions::new(w.lo, w.hi),
                        Query::Estimate { u: u.clone(), stop: StopRule::Exhaust },
                    )
                })
                .collect();
            eng.drain();
            let bits: Vec<(u64, usize)> = tickets
                .iter()
                .map(|&t| match eng.answer(t).unwrap() {
                    Answer::Estimate { bounds, iters, .. } => {
                        (bounds.gauss.to_bits(), *iters)
                    }
                    other => panic!("wrong answer kind {other:?}"),
                })
                .collect();
            let profile = eng.profile().cloned();
            let stats = eng.stats();
            (bits, profile, stats)
        };
        let base = EngineConfig::default().with_workers(2);
        let (plain, no_profile, _) = run(base);
        assert!(no_profile.is_none(), "profile off by default");
        let (profiled, profile, stats) = run(base.with_profile(true));
        assert_eq!(plain, profiled, "profiling changed an answer bit");
        let p = profile.expect("profile collected");
        assert_eq!(p.rounds, stats.rounds, "every round profiled");
        assert!(p.sweep_ns > 0, "sweep phase timed");
        assert_eq!(
            p.step_ns.count() as usize, stats.sweeps,
            "one step sample per session sweep"
        );
        assert!(p.busy_ns <= p.capacity_ns, "busy cannot exceed capacity");
        let busy = p.busy_frac();
        assert!((0.0..=1.0).contains(&busy), "busy_frac {busy}");
        assert!((p.idle_frac() - (1.0 - busy)).abs() < 1e-12);

        // registry export surfaces the acceptance-criteria names
        let reg = MetricsRegistry::new();
        let mut eng = Engine::new(base.with_profile(true)).unwrap();
        let (a, w) = &ops[0];
        eng.submit(
            0,
            a,
            GqlOptions::new(w.lo, w.hi),
            Query::Estimate { u: queries[0].clone(), stop: StopRule::Exhaust },
        );
        eng.drain();
        eng.export_into(&reg);
        let snap = reg.snapshot();
        for name in [
            "engine.rounds",
            "engine.sweeps",
            "engine.profile.sweep_ns",
            "engine.profile.schedule_ns",
            "engine.profile.harvest_ns",
            "engine.profile.worker_busy_frac",
            "engine.profile.worker_idle_frac",
        ] {
            assert!(snap.get(name).is_some(), "missing exported metric {name}");
        }
    }

    #[test]
    fn retire_counters_pull_from_the_session_retire_log() {
        use crate::quadrature::query::QueryArm;
        let mut rng = Rng::new(0xE9616);
        let (a, w) = random_sparse_spd(&mut rng, 24, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = Engine::new(EngineConfig::default()).unwrap();

        // a cancelled estimate retires its lane with RetireReason::Decided
        // and must be counted even though no harvest follows the cancel
        let u = randvec(&mut rng, 24);
        let t = eng.submit(3, &a, opts, Query::Estimate { u, stop: StopRule::Exhaust });
        assert!(eng.step_round());
        assert!(eng.cancel(t), "mid-flight estimate cancels");
        assert_eq!(eng.stats().retired_decided, 1);
        assert_eq!(eng.stats().retired_dominated, 0);

        // an argmax whose offsets are separated far beyond any BIF value
        // prunes every losing arm by dominance in the first resolution
        // round and crowns the still-racing winner (Decided)
        let arms: Vec<QueryArm> = (0..5)
            .map(|k| QueryArm {
                u: randvec(&mut rng, 24),
                stop: StopRule::Exhaust,
                offset: 1e6 * k as f64,
                scale: 1.0,
            })
            .collect();
        let t2 = eng.submit(3, &a, opts, Query::Argmax { arms, floor: None });
        eng.drain();
        assert!(eng.is_resolved(t2));
        let st = eng.stats();
        assert_eq!(st.retired_dominated, 4, "four arms dominated");
        assert_eq!(st.retired_decided, 2, "cancelled lane + crowned winner");
        // counters are deltas over the log, never double counted
        eng.drain();
        let again = eng.stats();
        assert_eq!(again.retired_dominated, 4);
        assert_eq!(again.retired_decided, 2);
    }
}
