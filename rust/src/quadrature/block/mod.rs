//! Block Gauss-Quadrature-Lanczos: B independent GQL recurrences advanced
//! in lockstep against a **shared** operator.
//!
//! Every consumer in this repo — DPP/k-DPP greedy scoring, centrality
//! ranking, the judge service — issues many `u_i^T A^{-1} u_i` queries
//! against the *same* `A`. Run scalar, each query pays one sparse matvec
//! per iteration; run as a block, one [`SymOp::matvec_multi`] panel sweep
//! (a single traversal of the operator) advances every lane at once, which
//! is where the hardware-level speedup lives (cf. Zimmerling, Druskin &
//! Simoncini, arXiv:2407.21505 for the block-quadrature bounds and Pleiss
//! et al., arXiv:2006.11267 for batched Krylov on shared operators).
//!
//! Each lane carries the full four-bound state of the scalar engine
//! (Gauss, both Gauss-Radau flavors, Gauss-Lobatto) and its own
//! [`StopRule`]. Converged lanes exit early: their panel column is
//! refilled from a pending queue so the panel stays dense (the mechanism
//! that makes block DPP-greedy fast — score all remaining candidates in
//! panels of `B`), and only once the queue drains does the panel compact
//! to the surviving lanes.
//!
//! **Exactness contract:** per lane, the floating-point operation sequence
//! is identical to a scalar [`Gql`] run *by construction*: both drivers
//! advance the same [`LaneCore`](crate::quadrature::recurrence::LaneCore)
//! (one owner of the Sherman–Morrison recurrence, corrections, breakdown
//! detection, and the per-column Lanczos step), and the specialized
//! `matvec_multi` impls preserve per-lane accumulation order. Block
//! results are therefore bit-identical to scalar results — still asserted
//! by the `block_width = 1` property tests in `rust/tests/prop_block.rs`.
//!
//! Reorthogonalization (§5.4): lanes accept [`Reorth::Full`] — each lane
//! stores its own deinterleaved basis and applies the scalar engine's
//! two-pass Gram–Schmidt column-wise inside the interleaved panel, so the
//! bit-identity contract extends to the ill-conditioned regime (O(n·i)
//! extra per lane-iteration, same as scalar).

use super::gql::{Bounds, Gql, GqlOptions};
use super::recurrence::LaneCore;
use crate::sparse::SymOp;
use std::collections::VecDeque;

/// When a lane is allowed to leave the panel.
///
/// **Invariant:** every admitted query performs at least one iteration —
/// stop rules are only consulted *after* a sweep, so a zero iteration
/// budget cannot be honored. [`StopRule::normalized`] (applied by
/// [`BlockGql::push`] and [`run_scalar`]) floors `Iters(0)` to `Iters(1)`
/// accordingly, matching the `max_iters` floor in [`Gql::new`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopRule {
    /// Run to Krylov exhaustion (or the iteration budget).
    Exhaust,
    /// Stop once the bound bracket width drops below an absolute tolerance.
    GapAbs(f64),
    /// Stop once the bracket width drops below `tol * upper` (relative).
    GapRel(f64),
    /// Stop as soon as the Radau bounds decide `t < u^T A^{-1} u`; the
    /// decision lands in [`BlockResult::decision`] (paper Alg. 4 semantics).
    Threshold(f64),
    /// Stop after a fixed number of iterations (floored to 1 on
    /// admission — see the type-level invariant).
    Iters(usize),
}

impl StopRule {
    /// Enforce the type-level invariant: `Iters(0)` still runs one full
    /// iteration (the rule is only consulted after a sweep), so it is
    /// floored to `Iters(1)` when a query is admitted instead of silently
    /// overshooting its budget.
    pub fn normalized(self) -> Self {
        match self {
            StopRule::Iters(0) => StopRule::Iters(1),
            s => s,
        }
    }
}

/// Outcome of one lane.
#[derive(Clone, Debug)]
pub struct BlockResult {
    /// Push order (0-based): results from [`BlockGql::run_all`] are sorted
    /// by this id, matching the order queries were pushed.
    pub id: usize,
    /// Final bounds when the lane exited.
    pub bounds: Bounds,
    /// For [`StopRule::Threshold`]: the decision `t < u^T A^{-1} u`
    /// (midpoint fallback when the iteration budget ran out first).
    pub decision: Option<bool>,
    /// Quadrature iterations the lane consumed.
    pub iters: usize,
    /// Per-iteration bound history (empty unless recording was enabled
    /// via [`BlockGql::record_history`]).
    pub history: Vec<Bounds>,
}

/// Should a run with these bounds stop, and with what threshold decision?
///
/// Shared verbatim by the block lanes and the scalar reference driver
/// [`run_scalar`], so the two paths terminate at exactly the same
/// iteration with exactly the same decision — the invariant the block DPP
/// greedy's "identical selections" guarantee rests on. `n` is the operator
/// dimension, `max_iters` the (already clamped) budget.
pub fn stop_decision(
    b: &Bounds,
    stop: &StopRule,
    n: usize,
    max_iters: usize,
) -> Option<Option<bool>> {
    let threshold_of = |t: f64, val: f64| Some(Some(t < val));
    if b.exact {
        // breakdown: the Gauss value is the exact BIF (Lemma 15)
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.gauss),
            _ => Some(None),
        };
    }
    match *stop {
        StopRule::Threshold(t) => {
            if t < b.radau_lower {
                return Some(Some(true));
            }
            if t >= b.radau_upper {
                return Some(Some(false));
            }
        }
        StopRule::GapAbs(tol) => {
            if b.gap() <= tol {
                return Some(None);
            }
        }
        StopRule::GapRel(tol) => {
            if b.gap() <= tol * b.upper().abs() {
                return Some(None);
            }
        }
        StopRule::Iters(k) => {
            if b.iter >= k {
                return Some(None);
            }
        }
        StopRule::Exhaust => {}
    }
    if b.iter >= n {
        // Krylov space full: value exact even without a breakdown flag
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.gauss),
            _ => Some(None),
        };
    }
    if b.iter >= max_iters {
        // budget: decide at the bracket midpoint, like the scalar judges
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.mid()),
            _ => Some(None),
        };
    }
    None
}

/// Scalar reference path: one query driven through [`Gql`] with the same
/// stopping logic as a block lane. `BlockGql` with `width = 1` reproduces
/// this bit-for-bit; apps use it as their non-batched code path.
pub fn run_scalar(
    op: &dyn SymOp,
    u: &[f64],
    opts: GqlOptions,
    stop: StopRule,
    record_history: bool,
) -> BlockResult {
    let stop = stop.normalized();
    if is_zero(u) {
        return zero_result(0, &stop);
    }
    let n = op.dim();
    let max_iters = opts.max_iters.min(n).max(1);
    let mut q = Gql::new(op, u, opts);
    let mut history = Vec::new();
    loop {
        let b = q.step();
        if record_history {
            history.push(b);
        }
        if let Some(decision) = stop_decision(&b, &stop, n, max_iters) {
            return BlockResult { id: 0, bounds: b, decision, iters: b.iter, history };
        }
    }
}

/// One lane: id + stop rule + the shared recurrence core (the
/// Sherman–Morrison state and reorth basis live in [`LaneCore`]; the
/// Lanczos vectors live in the engine's interleaved panels).
struct Lane {
    id: usize,
    stop: StopRule,
    core: LaneCore,
    history: Vec<Bounds>,
}

impl Lane {
    /// Placeholder lane; [`BlockGql::write_query`] installs the real core
    /// once the query vector (and its norm) is in the panel.
    fn new(id: usize, stop: StopRule, opts: &GqlOptions) -> Self {
        Lane { id, stop, core: LaneCore::new(opts, 0.0), history: Vec::new() }
    }
}

struct Pending {
    id: usize,
    u: Vec<f64>,
    stop: StopRule,
}

/// Batched GQL engine: push queries, then [`BlockGql::run_all`].
pub struct BlockGql<'a> {
    op: &'a dyn SymOp,
    opts: GqlOptions,
    n: usize,
    /// configured maximum panel width B
    width: usize,
    /// current stride (= active lane count = `lanes.len()`)
    b: usize,
    // interleaved panels, `n * b`: column `l` of lane `l` at `[i * b + l]`
    v_prev: Vec<f64>,
    v_curr: Vec<f64>,
    w: Vec<f64>,
    lanes: Vec<Lane>,
    pending: VecDeque<Pending>,
    done: Vec<BlockResult>,
    next_id: usize,
    record_history: bool,
    sweeps: usize,
}

impl<'a> BlockGql<'a> {
    /// Engine over `op` with panel width `width`. Like [`Gql::new`],
    /// `opts.max_iters` is clamped to the operator dimension (no lane can
    /// usefully iterate past Krylov exhaustion). `opts.reorth` applies to
    /// every lane (per-lane basis storage; see the module docs).
    pub fn new(op: &'a dyn SymOp, opts: GqlOptions, width: usize) -> Self {
        let n = op.dim();
        assert!(width >= 1, "block width must be at least 1");
        assert!(
            opts.lam_min > 0.0 && opts.lam_max > opts.lam_min,
            "need 0 < lam_min < lam_max (got {} .. {})",
            opts.lam_min,
            opts.lam_max
        );
        let mut opts = opts;
        opts.max_iters = opts.max_iters.min(n).max(1);
        BlockGql {
            op,
            opts,
            n,
            width,
            b: 0,
            v_prev: Vec::new(),
            v_curr: Vec::new(),
            w: Vec::new(),
            lanes: Vec::new(),
            pending: VecDeque::new(),
            done: Vec::new(),
            next_id: 0,
            record_history: false,
            sweeps: 0,
        }
    }

    /// Record per-iteration bound histories into each [`BlockResult`].
    pub fn record_history(mut self, yes: bool) -> Self {
        self.record_history = yes;
        self
    }

    /// Queue a query `u^T op^{-1} u`; returns its id (push order). Zero
    /// queries resolve immediately (BIF = 0 exactly) without taking a lane.
    pub fn push(&mut self, u: &[f64], stop: StopRule) -> usize {
        assert_eq!(u.len(), self.n, "dimension mismatch");
        let stop = stop.normalized();
        let id = self.next_id;
        self.next_id += 1;
        if is_zero(u) {
            self.done.push(zero_result(id, &stop));
        } else {
            self.pending.push_back(Pending { id, u: u.to_vec(), stop });
        }
        id
    }

    /// Panel sweeps performed so far (each = one `matvec_multi`, i.e. one
    /// traversal of the shared operator regardless of lane count).
    pub fn sweeps(&self) -> usize {
        self.sweeps
    }

    /// Run until every queued query has completed; results sorted by id.
    pub fn run_all(&mut self) -> Vec<BlockResult> {
        loop {
            self.admit();
            if self.lanes.is_empty() {
                break;
            }
            self.sweep();
        }
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Admit pending queries up to the configured width (growing the
    /// panel stride).
    fn admit(&mut self) {
        let m = (self.width - self.b).min(self.pending.len());
        if m == 0 {
            return;
        }
        self.grow(m);
        for _ in 0..m {
            let p = self.pending.pop_front().unwrap();
            let slot = self.lanes.len();
            let lane = Lane::new(p.id, p.stop, &self.opts); // core set below
            self.lanes.push(lane);
            self.write_query(slot, &p.u);
        }
    }

    /// Install `u` into lane `slot`: `v_curr` column = normalized query,
    /// `v_prev` column = 0, recurrence core fresh.
    fn write_query(&mut self, slot: usize, u: &[f64]) {
        let b = self.b;
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        debug_assert!(unorm2 > 0.0, "zero queries never reach a lane");
        let inv_norm = 1.0 / unorm2.sqrt();
        for (i, &ui) in u.iter().enumerate() {
            self.v_prev[i * b + slot] = 0.0;
            self.v_curr[i * b + slot] = ui * inv_norm;
        }
        let opts = self.opts;
        let lane = &mut self.lanes[slot];
        lane.core = LaneCore::new(&opts, unorm2);
        lane.history = Vec::new();
    }

    /// Widen the panels by `m` lanes (in-place backward repack: for each
    /// row the write offset `i * new_b + l` is ≥ the read offset
    /// `i * b + l`, so iterating rows and lanes in descending order never
    /// clobbers unread data).
    fn grow(&mut self, m: usize) {
        let (n, ob) = (self.n, self.b);
        let nb = ob + m;
        for panel in [&mut self.v_prev, &mut self.v_curr] {
            panel.resize(n * nb, 0.0);
            for i in (0..n).rev() {
                for l in (0..ob).rev() {
                    panel[i * nb + l] = panel[i * ob + l];
                }
                for l in ob..nb {
                    panel[i * nb + l] = 0.0;
                }
            }
        }
        self.w.resize(n * nb, 0.0);
        self.w.fill(0.0);
        self.b = nb;
    }

    /// Drop the lanes *not* listed in `keep` (ascending old slot indices);
    /// forward in-place repack — the mirror argument of [`BlockGql::grow`].
    fn compact(&mut self, keep: &[usize]) {
        let (n, ob) = (self.n, self.b);
        let nb = keep.len();
        for panel in [&mut self.v_prev, &mut self.v_curr] {
            for i in 0..n {
                for (nl, &ol) in keep.iter().enumerate() {
                    panel[i * nb + nl] = panel[i * ob + ol];
                }
            }
            panel.truncate(n * nb);
        }
        self.w.truncate(n * nb);
        let old = std::mem::take(&mut self.lanes);
        let mut it = keep.iter().peekable();
        for (slot, lane) in old.into_iter().enumerate() {
            if it.peek() == Some(&&slot) {
                it.next();
                self.lanes.push(lane);
            }
        }
        self.b = nb;
    }

    /// One lockstep iteration: a single panel sweep of the operator plus
    /// one [`LaneCore::step_column`] per lane (the scalar engine's exact
    /// op sequence on each column — see `quadrature::recurrence`).
    /// Completed lanes are emitted, refilled from the queue in place, or
    /// compacted away.
    fn sweep(&mut self) {
        let (n, b) = (self.n, self.b);
        debug_assert!(b > 0);
        self.op.matvec_multi(&self.v_curr, &mut self.w, b);
        self.sweeps += 1;

        let max_iters = self.opts.max_iters;
        let mut finished: Vec<(usize, Option<bool>)> = Vec::new();
        for l in 0..b {
            let lane = &mut self.lanes[l];
            let bounds = lane.core.step_column(
                &mut self.v_prev,
                &mut self.v_curr,
                &mut self.w,
                n,
                b,
                l,
            );
            if self.record_history {
                lane.history.push(bounds);
            }
            if let Some(decision) = stop_decision(&bounds, &lane.stop, n, max_iters) {
                finished.push((l, decision));
            }
        }

        // --- emit finished lanes; refill in place while the queue lasts ---
        let mut dead: Vec<usize> = Vec::new();
        for (slot, decision) in finished {
            {
                let lane = &mut self.lanes[slot];
                self.done.push(BlockResult {
                    id: lane.id,
                    bounds: lane.core.last_bounds().expect("finished lane has bounds"),
                    decision,
                    iters: lane.core.iterations(),
                    history: std::mem::take(&mut lane.history),
                });
            }
            if let Some(p) = self.pending.pop_front() {
                let lane = Lane::new(p.id, p.stop, &self.opts);
                self.lanes[slot] = lane;
                self.write_query(slot, &p.u);
            } else {
                dead.push(slot);
            }
        }
        if !dead.is_empty() {
            let keep: Vec<usize> = (0..b).filter(|s| !dead.contains(s)).collect();
            self.compact(&keep);
        }
    }
}

#[inline]
fn is_zero(u: &[f64]) -> bool {
    u.iter().all(|&x| x == 0.0)
}

/// Immediately-exact result for a zero query (`BIF = 0`).
fn zero_result(id: usize, stop: &StopRule) -> BlockResult {
    let bounds = Bounds {
        iter: 0,
        gauss: 0.0,
        radau_lower: 0.0,
        radau_upper: 0.0,
        lobatto: 0.0,
        exact: true,
    };
    let decision = match *stop {
        StopRule::Threshold(t) => Some(t < 0.0),
        _ => None,
    };
    BlockResult { id, bounds, decision, iters: 0, history: Vec::new() }
}

/// One-shot convenience: run `queries` (pairs of query vector and stop
/// rule) through a width-`width` block engine; results in push order.
/// Queries are borrowed so timed comparisons against the scalar path
/// don't pay per-query clones.
pub fn block_solve<'q>(
    op: &dyn SymOp,
    opts: GqlOptions,
    width: usize,
    queries: impl IntoIterator<Item = (&'q [f64], StopRule)>,
) -> Vec<BlockResult> {
    let mut engine = BlockGql::new(op, opts, width);
    for (u, stop) in queries {
        engine.push(u, stop);
    }
    engine.run_all()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::quadrature::gql::Reorth;
    use crate::quadrature::judge_threshold;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn width_one_is_bit_identical_to_scalar() {
        forall(15, 0xB70C, |rng| {
            let n = 4 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let opts = GqlOptions::new(w.lo, w.hi);
            let scalar = run_scalar(&a, &u, opts, StopRule::Exhaust, true);
            let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
            eng.push(&u, StopRule::Exhaust);
            let block = eng.run_all().pop().unwrap();
            assert_eq!(scalar.history.len(), block.history.len());
            for (s, b) in scalar.history.iter().zip(&block.history) {
                assert_eq!(s.gauss.to_bits(), b.gauss.to_bits());
                assert_eq!(s.radau_lower.to_bits(), b.radau_lower.to_bits());
                assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
                assert_eq!(s.lobatto.to_bits(), b.lobatto.to_bits());
                assert_eq!(s.exact, b.exact);
            }
        });
    }

    #[test]
    fn thresholds_match_scalar_judge_decisions() {
        forall(10, 0xB71D, |rng| {
            let n = 6 + rng.below(20);
            let (a, w) = random_sparse_spd(rng, n, 0.4, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let mut eng = BlockGql::new(&a, opts, 4);
            let mut want = Vec::new();
            for _ in 0..9 {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let exact = crate::quadrature::cg::cg_bif_estimate(&a, &u, 1e-14, 10 * n);
                let t = exact * (0.5 + rng.f64());
                let (dec, _) = judge_threshold(&a, &u, t, opts);
                eng.push(&u, StopRule::Threshold(t));
                want.push(dec);
            }
            let got = eng.run_all();
            assert_eq!(got.len(), want.len());
            for (r, w) in got.iter().zip(&want) {
                assert_eq!(r.decision, Some(*w), "lane {}", r.id);
            }
        });
    }

    #[test]
    fn refill_and_compaction_preserve_per_query_results() {
        // more queries than lanes, stopping at different iterations, so
        // lanes exit, refill from the queue, and finally compact
        let mut rng = Rng::new(0xB72E);
        let n = 40;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.1, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let rules = [
            StopRule::Iters(1),
            StopRule::Iters(7),
            StopRule::GapRel(1e-4),
            StopRule::Exhaust,
        ];
        let queries: Vec<(Vec<f64>, StopRule)> = (0..13)
            .map(|i| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, rules[i % rules.len()])
            })
            .collect();
        let block = block_solve(&a, opts, 3, queries.iter().map(|(u, s)| (u.as_slice(), *s)));
        assert_eq!(block.len(), queries.len());
        for (r, (u, stop)) in block.iter().zip(&queries) {
            let scalar = run_scalar(&a, u, opts, *stop, false);
            assert_eq!(r.iters, scalar.iters, "query {}", r.id);
            assert_eq!(r.bounds.gauss.to_bits(), scalar.bounds.gauss.to_bits());
            assert_eq!(
                r.bounds.radau_upper.to_bits(),
                scalar.bounds.radau_upper.to_bits()
            );
        }
    }

    #[test]
    fn zero_query_resolves_immediately() {
        let mut rng = Rng::new(0xB73F);
        let (a, w) = random_sparse_spd(&mut rng, 10, 0.3, 0.05);
        let mut eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 2);
        let id = eng.push(&vec![0.0; 10], StopRule::Threshold(-1.0));
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        eng.push(&u, StopRule::Exhaust);
        let out = eng.run_all();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].iters, 0);
        assert_eq!(out[0].decision, Some(true), "-1 < 0 exactly");
        assert!(out[0].bounds.exact);
    }

    #[test]
    fn max_iters_is_clamped_to_dimension() {
        let mut rng = Rng::new(0xB740);
        let (a, w) = random_sparse_spd(&mut rng, 8, 0.5, 0.05);
        let eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 2);
        assert_eq!(eng.opts.max_iters, 8);
    }

    #[test]
    fn panel_stays_dense_while_queue_lasts() {
        // 8 one-iteration queries through width 4: every sweep should
        // advance a full panel, so 2 sweeps finish everything
        let mut rng = Rng::new(0xB751);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.2, 0.05);
        let mut eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 4);
        for _ in 0..8 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            eng.push(&u, StopRule::Iters(1));
        }
        let out = eng.run_all();
        assert_eq!(out.len(), 8);
        assert_eq!(eng.sweeps(), 2, "refill must keep the panel dense");
    }

    #[test]
    fn reorth_lanes_are_bit_identical_to_scalar_reorth() {
        // every lane of a reorthogonalized panel must reproduce its own
        // scalar Reorth::Full run bit-for-bit — the exactness contract
        // extended to §5.4 (ISSUE 2 tentpole)
        forall(10, 0xB762, |rng| {
            let n = 6 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi).with_reorth(Reorth::Full);
            let m = 1 + rng.below(6);
            let width = 1 + rng.below(m);
            let queries: Vec<Vec<f64>> = (0..m)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            let mut eng = BlockGql::new(&a, opts, width).record_history(true);
            for u in &queries {
                eng.push(u, StopRule::Exhaust);
            }
            for (r, u) in eng.run_all().iter().zip(&queries) {
                let scalar = run_scalar(&a, u, opts, StopRule::Exhaust, true);
                assert_eq!(scalar.history.len(), r.history.len(), "query {}", r.id);
                for (s, b) in scalar.history.iter().zip(&r.history) {
                    assert_eq!(s.gauss.to_bits(), b.gauss.to_bits(), "query {}", r.id);
                    assert_eq!(s.radau_lower.to_bits(), b.radau_lower.to_bits());
                    assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
                    assert_eq!(s.lobatto.to_bits(), b.lobatto.to_bits());
                    assert_eq!(s.exact, b.exact);
                }
            }
        });
    }

    #[test]
    fn iters_zero_is_floored_to_one_iteration() {
        // StopRule::Iters(0) would otherwise run a full sweep and then
        // report it stopped "within budget" — the normalized() floor makes
        // the one-iteration minimum explicit (ISSUE 2 satellite)
        let mut rng = Rng::new(0xB773);
        let n = 12;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.4, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        assert_eq!(StopRule::Iters(0).normalized(), StopRule::Iters(1));
        assert_eq!(StopRule::Iters(3).normalized(), StopRule::Iters(3));
        let zero = run_scalar(&a, &u, opts, StopRule::Iters(0), false);
        let one = run_scalar(&a, &u, opts, StopRule::Iters(1), false);
        assert_eq!(zero.iters, 1);
        assert_eq!(zero.bounds.gauss.to_bits(), one.bounds.gauss.to_bits());
        let mut eng = BlockGql::new(&a, opts, 2);
        eng.push(&u, StopRule::Iters(0));
        let r = eng.run_all().pop().unwrap();
        assert_eq!(r.iters, 1);
        assert_eq!(r.bounds.gauss.to_bits(), one.bounds.gauss.to_bits());
    }

    #[test]
    fn exactness_flag_set_when_krylov_space_fills() {
        // at iter == n the Gauss value is exact; the emitted Bounds must
        // say so, collapsing Bounds::upper() onto it (ISSUE 2 satellite)
        let mut rng = Rng::new(0xB784);
        let n = 10;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.5, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        let r = run_scalar(&a, &u, opts, StopRule::Exhaust, true);
        let last = r.history.last().unwrap();
        assert!(last.exact, "final bounds must be flagged exact");
        assert_eq!(last.upper(), last.gauss);
        // block path agrees
        let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
        eng.push(&u, StopRule::Exhaust);
        let b = eng.run_all().pop().unwrap();
        assert!(b.history.last().unwrap().exact);
    }
}
