//! Block Gauss-Quadrature-Lanczos: B independent GQL recurrences advanced
//! in lockstep against a **shared** operator.
//!
//! Every consumer in this repo — DPP/k-DPP greedy scoring, centrality
//! ranking, the judge service — issues many `u_i^T A^{-1} u_i` queries
//! against the *same* `A`. Run scalar, each query pays one sparse matvec
//! per iteration; run as a block, one [`SymOp::matvec_multi`] panel sweep
//! (a single traversal of the operator) advances every lane at once, which
//! is where the hardware-level speedup lives (cf. Zimmerling, Druskin &
//! Simoncini, arXiv:2407.21505 for the block-quadrature bounds and Pleiss
//! et al., arXiv:2006.11267 for batched Krylov on shared operators).
//!
//! Each lane carries the full four-bound state of the scalar engine
//! (Gauss, both Gauss-Radau flavors, Gauss-Lobatto) and its own
//! [`StopRule`]. Converged lanes exit early: their panel column is
//! refilled from a pending queue so the panel stays dense (the mechanism
//! that makes block DPP-greedy fast — score all remaining candidates in
//! panels of `B`), and only once the queue drains does the panel compact
//! to the surviving lanes.
//!
//! **Exactness contract:** per lane, the floating-point operation sequence
//! is identical to a scalar [`Gql`] run (the specialized `matvec_multi`
//! impls preserve per-lane accumulation order), so block results are
//! bit-identical to scalar results — asserted by the `block_width = 1`
//! property tests in `rust/tests/prop_block.rs`.

use super::gql::{Bounds, Gql, GqlOptions, Reorth};
use crate::sparse::SymOp;
use std::collections::VecDeque;

/// When a lane is allowed to leave the panel.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopRule {
    /// Run to Krylov exhaustion (or the iteration budget).
    Exhaust,
    /// Stop once the bound bracket width drops below an absolute tolerance.
    GapAbs(f64),
    /// Stop once the bracket width drops below `tol * upper` (relative).
    GapRel(f64),
    /// Stop as soon as the Radau bounds decide `t < u^T A^{-1} u`; the
    /// decision lands in [`BlockResult::decision`] (paper Alg. 4 semantics).
    Threshold(f64),
    /// Stop after a fixed number of iterations.
    Iters(usize),
}

/// Outcome of one lane.
#[derive(Clone, Debug)]
pub struct BlockResult {
    /// Push order (0-based): results from [`BlockGql::run_all`] are sorted
    /// by this id, matching the order queries were pushed.
    pub id: usize,
    /// Final bounds when the lane exited.
    pub bounds: Bounds,
    /// For [`StopRule::Threshold`]: the decision `t < u^T A^{-1} u`
    /// (midpoint fallback when the iteration budget ran out first).
    pub decision: Option<bool>,
    /// Quadrature iterations the lane consumed.
    pub iters: usize,
    /// Per-iteration bound history (empty unless recording was enabled
    /// via [`BlockGql::record_history`]).
    pub history: Vec<Bounds>,
}

/// Should a run with these bounds stop, and with what threshold decision?
///
/// Shared verbatim by the block lanes and the scalar reference driver
/// [`run_scalar`], so the two paths terminate at exactly the same
/// iteration with exactly the same decision — the invariant the block DPP
/// greedy's "identical selections" guarantee rests on. `n` is the operator
/// dimension, `max_iters` the (already clamped) budget.
pub fn stop_decision(
    b: &Bounds,
    stop: &StopRule,
    n: usize,
    max_iters: usize,
) -> Option<Option<bool>> {
    let threshold_of = |t: f64, val: f64| Some(Some(t < val));
    if b.exact {
        // breakdown: the Gauss value is the exact BIF (Lemma 15)
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.gauss),
            _ => Some(None),
        };
    }
    match *stop {
        StopRule::Threshold(t) => {
            if t < b.radau_lower {
                return Some(Some(true));
            }
            if t >= b.radau_upper {
                return Some(Some(false));
            }
        }
        StopRule::GapAbs(tol) => {
            if b.gap() <= tol {
                return Some(None);
            }
        }
        StopRule::GapRel(tol) => {
            if b.gap() <= tol * b.upper().abs() {
                return Some(None);
            }
        }
        StopRule::Iters(k) => {
            if b.iter >= k {
                return Some(None);
            }
        }
        StopRule::Exhaust => {}
    }
    if b.iter >= n {
        // Krylov space full: value exact even without a breakdown flag
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.gauss),
            _ => Some(None),
        };
    }
    if b.iter >= max_iters {
        // budget: decide at the bracket midpoint, like the scalar judges
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.mid()),
            _ => Some(None),
        };
    }
    None
}

/// Scalar reference path: one query driven through [`Gql`] with the same
/// stopping logic as a block lane. `BlockGql` with `width = 1` reproduces
/// this bit-for-bit; apps use it as their non-batched code path.
pub fn run_scalar(
    op: &dyn SymOp,
    u: &[f64],
    opts: GqlOptions,
    stop: StopRule,
    record_history: bool,
) -> BlockResult {
    if is_zero(u) {
        return zero_result(0, &stop);
    }
    let n = op.dim();
    let max_iters = opts.max_iters.min(n).max(1);
    let mut q = Gql::new(op, u, opts);
    let mut history = Vec::new();
    loop {
        let b = q.step();
        if record_history {
            history.push(b);
        }
        if let Some(decision) = stop_decision(&b, &stop, n, max_iters) {
            return BlockResult { id: 0, bounds: b, decision, iters: b.iter, history };
        }
    }
}

/// One lane's Sherman–Morrison recurrence state (mirrors [`Gql`]'s fields;
/// the Lanczos vectors live in the engine's interleaved panels).
struct Lane {
    id: usize,
    stop: StopRule,
    unorm2: f64,
    beta_prev: f64,
    g: f64,
    c: f64,
    delta: f64,
    d_lr: f64,
    d_rr: f64,
    iter: usize,
    last: Option<Bounds>,
    history: Vec<Bounds>,
}

impl Lane {
    fn new(id: usize, stop: StopRule, unorm2: f64) -> Self {
        Lane {
            id,
            stop,
            unorm2,
            beta_prev: 0.0,
            g: 0.0,
            c: 1.0,
            delta: 0.0,
            d_lr: 0.0,
            d_rr: 0.0,
            iter: 0,
            last: None,
            history: Vec::new(),
        }
    }
}

struct Pending {
    id: usize,
    u: Vec<f64>,
    stop: StopRule,
}

/// Batched GQL engine: push queries, then [`BlockGql::run_all`].
pub struct BlockGql<'a> {
    op: &'a dyn SymOp,
    opts: GqlOptions,
    n: usize,
    /// configured maximum panel width B
    width: usize,
    /// current stride (= active lane count = `lanes.len()`)
    b: usize,
    // interleaved panels, `n * b`: column `l` of lane `l` at `[i * b + l]`
    v_prev: Vec<f64>,
    v_curr: Vec<f64>,
    w: Vec<f64>,
    lanes: Vec<Lane>,
    pending: VecDeque<Pending>,
    done: Vec<BlockResult>,
    next_id: usize,
    record_history: bool,
    sweeps: usize,
}

impl<'a> BlockGql<'a> {
    /// Engine over `op` with panel width `width`. Like [`Gql::new`],
    /// `opts.max_iters` is clamped to the operator dimension (no lane can
    /// usefully iterate past Krylov exhaustion).
    pub fn new(op: &'a dyn SymOp, opts: GqlOptions, width: usize) -> Self {
        let n = op.dim();
        assert!(width >= 1, "block width must be at least 1");
        assert!(
            opts.lam_min > 0.0 && opts.lam_max > opts.lam_min,
            "need 0 < lam_min < lam_max (got {} .. {})",
            opts.lam_min,
            opts.lam_max
        );
        assert!(
            opts.reorth == Reorth::None,
            "BlockGql does not support reorthogonalization (use scalar Gql)"
        );
        let mut opts = opts;
        opts.max_iters = opts.max_iters.min(n).max(1);
        BlockGql {
            op,
            opts,
            n,
            width,
            b: 0,
            v_prev: Vec::new(),
            v_curr: Vec::new(),
            w: Vec::new(),
            lanes: Vec::new(),
            pending: VecDeque::new(),
            done: Vec::new(),
            next_id: 0,
            record_history: false,
            sweeps: 0,
        }
    }

    /// Record per-iteration bound histories into each [`BlockResult`].
    pub fn record_history(mut self, yes: bool) -> Self {
        self.record_history = yes;
        self
    }

    /// Queue a query `u^T op^{-1} u`; returns its id (push order). Zero
    /// queries resolve immediately (BIF = 0 exactly) without taking a lane.
    pub fn push(&mut self, u: &[f64], stop: StopRule) -> usize {
        assert_eq!(u.len(), self.n, "dimension mismatch");
        let id = self.next_id;
        self.next_id += 1;
        if is_zero(u) {
            self.done.push(zero_result(id, &stop));
        } else {
            self.pending.push_back(Pending { id, u: u.to_vec(), stop });
        }
        id
    }

    /// Panel sweeps performed so far (each = one `matvec_multi`, i.e. one
    /// traversal of the shared operator regardless of lane count).
    pub fn sweeps(&self) -> usize {
        self.sweeps
    }

    /// Run until every queued query has completed; results sorted by id.
    pub fn run_all(&mut self) -> Vec<BlockResult> {
        loop {
            self.admit();
            if self.lanes.is_empty() {
                break;
            }
            self.sweep();
        }
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Admit pending queries up to the configured width (growing the
    /// panel stride).
    fn admit(&mut self) {
        let m = (self.width - self.b).min(self.pending.len());
        if m == 0 {
            return;
        }
        self.grow(m);
        for _ in 0..m {
            let p = self.pending.pop_front().unwrap();
            let slot = self.lanes.len();
            self.lanes.push(Lane::new(p.id, p.stop, 0.0)); // unorm2 set below
            self.write_query(slot, &p.u);
        }
    }

    /// Install `u` into lane `slot`: `v_curr` column = normalized query,
    /// `v_prev` column = 0, recurrence state fresh.
    fn write_query(&mut self, slot: usize, u: &[f64]) {
        let b = self.b;
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        debug_assert!(unorm2 > 0.0, "zero queries never reach a lane");
        let inv_norm = 1.0 / unorm2.sqrt();
        for (i, &ui) in u.iter().enumerate() {
            self.v_prev[i * b + slot] = 0.0;
            self.v_curr[i * b + slot] = ui * inv_norm;
        }
        let lane = &mut self.lanes[slot];
        let (id, stop) = (lane.id, lane.stop);
        *lane = Lane::new(id, stop, unorm2);
    }

    /// Widen the panels by `m` lanes (in-place backward repack: for each
    /// row the write offset `i * new_b + l` is ≥ the read offset
    /// `i * b + l`, so iterating rows and lanes in descending order never
    /// clobbers unread data).
    fn grow(&mut self, m: usize) {
        let (n, ob) = (self.n, self.b);
        let nb = ob + m;
        for panel in [&mut self.v_prev, &mut self.v_curr] {
            panel.resize(n * nb, 0.0);
            for i in (0..n).rev() {
                for l in (0..ob).rev() {
                    panel[i * nb + l] = panel[i * ob + l];
                }
                for l in ob..nb {
                    panel[i * nb + l] = 0.0;
                }
            }
        }
        self.w.resize(n * nb, 0.0);
        self.w.fill(0.0);
        self.b = nb;
    }

    /// Drop the lanes *not* listed in `keep` (ascending old slot indices);
    /// forward in-place repack — the mirror argument of [`BlockGql::grow`].
    fn compact(&mut self, keep: &[usize]) {
        let (n, ob) = (self.n, self.b);
        let nb = keep.len();
        for panel in [&mut self.v_prev, &mut self.v_curr] {
            for i in 0..n {
                for (nl, &ol) in keep.iter().enumerate() {
                    panel[i * nb + nl] = panel[i * ob + ol];
                }
            }
            panel.truncate(n * nb);
        }
        self.w.truncate(n * nb);
        let old = std::mem::take(&mut self.lanes);
        let mut it = keep.iter().peekable();
        for (slot, lane) in old.into_iter().enumerate() {
            if it.peek() == Some(&&slot) {
                it.next();
                self.lanes.push(lane);
            }
        }
        self.b = nb;
    }

    /// One lockstep iteration: a single panel sweep of the operator plus
    /// per-lane O(1) recurrences. Completed lanes are emitted, refilled
    /// from the queue in place, or compacted away.
    fn sweep(&mut self) {
        let (n, b) = (self.n, self.b);
        debug_assert!(b > 0);
        self.op.matvec_multi(&self.v_curr, &mut self.w, b);
        self.sweeps += 1;

        let max_iters = self.opts.max_iters;
        let mut finished: Vec<(usize, Option<bool>)> = Vec::new();
        for l in 0..b {
            let lane = &mut self.lanes[l];
            lane.iter += 1;

            // --- Lanczos step on column l (same op order as Gql::step) ---
            let mut alpha = 0.0;
            for i in 0..n {
                alpha += self.v_curr[i * b + l] * self.w[i * b + l];
            }
            for i in 0..n {
                let k = i * b + l;
                self.w[k] -= alpha * self.v_curr[k] + lane.beta_prev * self.v_prev[k];
            }
            let mut beta2_acc = 0.0;
            for i in 0..n {
                let wk = self.w[i * b + l];
                beta2_acc += wk * wk;
            }
            let beta = beta2_acc.sqrt();

            // --- bound recurrences (verbatim from the scalar engine) ---
            if lane.iter == 1 {
                lane.g = lane.unorm2 / alpha;
                lane.c = 1.0;
                lane.delta = alpha;
                lane.d_lr = alpha - self.opts.lam_min;
                lane.d_rr = alpha - self.opts.lam_max;
            } else {
                let bp2 = lane.beta_prev * lane.beta_prev;
                lane.g += lane.unorm2 * bp2 * lane.c * lane.c
                    / (lane.delta * (alpha * lane.delta - bp2));
                lane.c *= lane.beta_prev / lane.delta;
                let delta_new = alpha - bp2 / lane.delta;
                lane.d_lr = alpha - self.opts.lam_min - bp2 / lane.d_lr;
                lane.d_rr = alpha - self.opts.lam_max - bp2 / lane.d_rr;
                lane.delta = delta_new;
            }

            let breakdown = !(beta > Gql::BREAKDOWN_TOL * alpha.abs().max(1.0));
            let bounds = if breakdown {
                Bounds {
                    iter: lane.iter,
                    gauss: lane.g,
                    radau_lower: lane.g,
                    radau_upper: lane.g,
                    lobatto: lane.g,
                    exact: true,
                }
            } else {
                let (g_rr, g_lr, g_lo) = corrections(lane, &self.opts, beta);
                Bounds {
                    iter: lane.iter,
                    gauss: lane.g,
                    radau_lower: g_rr,
                    radau_upper: g_lr,
                    lobatto: g_lo,
                    exact: false,
                }
            };

            if !breakdown {
                // advance the lane's Lanczos column in place
                let inv_beta = 1.0 / beta;
                for i in 0..n {
                    let k = i * b + l;
                    self.v_prev[k] = self.v_curr[k];
                    self.v_curr[k] = self.w[k] * inv_beta;
                }
                lane.beta_prev = beta;
            }
            if self.record_history {
                lane.history.push(bounds);
            }
            lane.last = Some(bounds);
            if let Some(decision) = stop_decision(&bounds, &lane.stop, n, max_iters) {
                finished.push((l, decision));
            }
        }

        // --- emit finished lanes; refill in place while the queue lasts ---
        let mut dead: Vec<usize> = Vec::new();
        for (slot, decision) in finished {
            {
                let lane = &mut self.lanes[slot];
                self.done.push(BlockResult {
                    id: lane.id,
                    bounds: lane.last.expect("finished lane has bounds"),
                    decision,
                    iters: lane.iter,
                    history: std::mem::take(&mut lane.history),
                });
            }
            if let Some(p) = self.pending.pop_front() {
                self.lanes[slot] = Lane::new(p.id, p.stop, 0.0);
                self.write_query(slot, &p.u);
            } else {
                dead.push(slot);
            }
        }
        if !dead.is_empty() {
            let keep: Vec<usize> = (0..b).filter(|s| !dead.contains(s)).collect();
            self.compact(&keep);
        }
    }
}

/// Radau/Lobatto corrections from a lane's recurrence state — identical
/// arithmetic to `Gql::corrections`.
fn corrections(lane: &Lane, opts: &GqlOptions, beta: f64) -> (f64, f64, f64) {
    let (lam_min, lam_max) = (opts.lam_min, opts.lam_max);
    let beta2 = beta * beta;
    let a_lr = lam_min + beta2 / lane.d_lr;
    let a_rr = lam_max + beta2 / lane.d_rr;
    let denom = lane.d_rr - lane.d_lr;
    let b_lo2 = (lam_max - lam_min) * lane.d_lr * lane.d_rr / denom;
    let a_lo = (lam_max * lane.d_rr - lam_min * lane.d_lr) / denom;
    let c2 = lane.c * lane.c;
    let k = lane.unorm2 * c2 / lane.delta;
    let g_rr = lane.g + k * beta2 / (a_rr * lane.delta - beta2);
    let g_lr = lane.g + k * beta2 / (a_lr * lane.delta - beta2);
    let g_lo = lane.g + k * b_lo2 / (a_lo * lane.delta - b_lo2);
    (g_rr, g_lr, g_lo)
}

#[inline]
fn is_zero(u: &[f64]) -> bool {
    u.iter().all(|&x| x == 0.0)
}

/// Immediately-exact result for a zero query (`BIF = 0`).
fn zero_result(id: usize, stop: &StopRule) -> BlockResult {
    let bounds = Bounds {
        iter: 0,
        gauss: 0.0,
        radau_lower: 0.0,
        radau_upper: 0.0,
        lobatto: 0.0,
        exact: true,
    };
    let decision = match *stop {
        StopRule::Threshold(t) => Some(t < 0.0),
        _ => None,
    };
    BlockResult { id, bounds, decision, iters: 0, history: Vec::new() }
}

/// One-shot convenience: run `queries` (pairs of query vector and stop
/// rule) through a width-`width` block engine; results in push order.
/// Queries are borrowed so timed comparisons against the scalar path
/// don't pay per-query clones.
pub fn block_solve<'q>(
    op: &dyn SymOp,
    opts: GqlOptions,
    width: usize,
    queries: impl IntoIterator<Item = (&'q [f64], StopRule)>,
) -> Vec<BlockResult> {
    let mut engine = BlockGql::new(op, opts, width);
    for (u, stop) in queries {
        engine.push(u, stop);
    }
    engine.run_all()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::quadrature::judge_threshold;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn width_one_is_bit_identical_to_scalar() {
        forall(15, 0xB70C, |rng| {
            let n = 4 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let opts = GqlOptions::new(w.lo, w.hi);
            let scalar = run_scalar(&a, &u, opts, StopRule::Exhaust, true);
            let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
            eng.push(&u, StopRule::Exhaust);
            let block = eng.run_all().pop().unwrap();
            assert_eq!(scalar.history.len(), block.history.len());
            for (s, b) in scalar.history.iter().zip(&block.history) {
                assert_eq!(s.gauss.to_bits(), b.gauss.to_bits());
                assert_eq!(s.radau_lower.to_bits(), b.radau_lower.to_bits());
                assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
                assert_eq!(s.lobatto.to_bits(), b.lobatto.to_bits());
                assert_eq!(s.exact, b.exact);
            }
        });
    }

    #[test]
    fn thresholds_match_scalar_judge_decisions() {
        forall(10, 0xB71D, |rng| {
            let n = 6 + rng.below(20);
            let (a, w) = random_sparse_spd(rng, n, 0.4, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let mut eng = BlockGql::new(&a, opts, 4);
            let mut want = Vec::new();
            for _ in 0..9 {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let exact = crate::quadrature::cg::cg_bif_estimate(&a, &u, 1e-14, 10 * n);
                let t = exact * (0.5 + rng.f64());
                let (dec, _) = judge_threshold(&a, &u, t, opts);
                eng.push(&u, StopRule::Threshold(t));
                want.push(dec);
            }
            let got = eng.run_all();
            assert_eq!(got.len(), want.len());
            for (r, w) in got.iter().zip(&want) {
                assert_eq!(r.decision, Some(*w), "lane {}", r.id);
            }
        });
    }

    #[test]
    fn refill_and_compaction_preserve_per_query_results() {
        // more queries than lanes, stopping at different iterations, so
        // lanes exit, refill from the queue, and finally compact
        let mut rng = Rng::new(0xB72E);
        let n = 40;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.1, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let rules = [
            StopRule::Iters(1),
            StopRule::Iters(7),
            StopRule::GapRel(1e-4),
            StopRule::Exhaust,
        ];
        let queries: Vec<(Vec<f64>, StopRule)> = (0..13)
            .map(|i| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, rules[i % rules.len()])
            })
            .collect();
        let block = block_solve(&a, opts, 3, queries.iter().map(|(u, s)| (u.as_slice(), *s)));
        assert_eq!(block.len(), queries.len());
        for (r, (u, stop)) in block.iter().zip(&queries) {
            let scalar = run_scalar(&a, u, opts, *stop, false);
            assert_eq!(r.iters, scalar.iters, "query {}", r.id);
            assert_eq!(r.bounds.gauss.to_bits(), scalar.bounds.gauss.to_bits());
            assert_eq!(
                r.bounds.radau_upper.to_bits(),
                scalar.bounds.radau_upper.to_bits()
            );
        }
    }

    #[test]
    fn zero_query_resolves_immediately() {
        let mut rng = Rng::new(0xB73F);
        let (a, w) = random_sparse_spd(&mut rng, 10, 0.3, 0.05);
        let mut eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 2);
        let id = eng.push(&vec![0.0; 10], StopRule::Threshold(-1.0));
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        eng.push(&u, StopRule::Exhaust);
        let out = eng.run_all();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].iters, 0);
        assert_eq!(out[0].decision, Some(true), "-1 < 0 exactly");
        assert!(out[0].bounds.exact);
    }

    #[test]
    fn max_iters_is_clamped_to_dimension() {
        let mut rng = Rng::new(0xB740);
        let (a, w) = random_sparse_spd(&mut rng, 8, 0.5, 0.05);
        let eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 2);
        assert_eq!(eng.opts.max_iters, 8);
    }

    #[test]
    fn panel_stays_dense_while_queue_lasts() {
        // 8 one-iteration queries through width 4: every sweep should
        // advance a full panel, so 2 sweeps finish everything
        let mut rng = Rng::new(0xB751);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.2, 0.05);
        let mut eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 4);
        for _ in 0..8 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            eng.push(&u, StopRule::Iters(1));
        }
        let out = eng.run_all();
        assert_eq!(out.len(), 8);
        assert_eq!(eng.sweeps(), 2, "refill must keep the panel dense");
    }

    #[test]
    #[should_panic(expected = "reorthogonalization")]
    fn reorth_rejected() {
        let mut rng = Rng::new(0xB762);
        let (a, w) = random_sparse_spd(&mut rng, 6, 0.5, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi).with_reorth(Reorth::Full);
        let _ = BlockGql::new(&a, opts, 2);
    }
}
