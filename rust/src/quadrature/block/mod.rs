//! Block Gauss-Quadrature-Lanczos: B independent GQL recurrences advanced
//! in lockstep against a **shared** operator.
//!
//! Every consumer in this repo — DPP/k-DPP greedy scoring, centrality
//! ranking, the judge service — issues many `u_i^T A^{-1} u_i` queries
//! against the *same* `A`. Run scalar, each query pays one sparse matvec
//! per iteration; run as a block, one [`SymOp::matvec_multi`] panel sweep
//! (a single traversal of the operator) advances every lane at once, which
//! is where the hardware-level speedup lives (cf. Zimmerling, Druskin &
//! Simoncini, arXiv:2407.21505 for the block-quadrature bounds and Pleiss
//! et al., arXiv:2006.11267 for batched Krylov on shared operators).
//!
//! Each lane carries the full four-bound state of the scalar engine
//! (Gauss, both Gauss-Radau flavors, Gauss-Lobatto) and its own
//! [`StopRule`]. Converged lanes exit early: their panel column is
//! refilled from a pending queue so the panel stays dense (the mechanism
//! that makes block DPP-greedy fast — score all remaining candidates in
//! panels of `B`), and only once the queue drains does the panel compact
//! to the surviving lanes.
//!
//! **Incremental scheduling API** (ISSUE 3 tentpole): besides the one-shot
//! [`BlockGql::run_all`], the engine exposes [`BlockGql::step_panel`] (one
//! `matvec_multi` sweep), [`BlockGql::active`] (per-lane bound snapshots),
//! [`BlockGql::take_done`], and the eviction hooks
//! [`BlockGql::retire`] / [`BlockGql::suspend`] / [`BlockGql::resume`].
//! These let a scheduler ([`crate::quadrature::race::Race`]) evict a lane
//! whose bound bracket is already dominated and refill its panel column
//! from the pending queue. When no lane is evicted the op sequence — and
//! therefore every result — is identical to `run_all`, preserving the
//! exactness contract below.
//!
//! **Panel layout:** lanes live interleaved at a stride that is padded up
//! to a multiple of [`PANEL_PAD`] (half of it for narrow 2–4 lane
//! panels) whenever more than one lane is active (pad columns are zero
//! and carry no lane), so the per-nonzero inner loop of the specialized
//! `matvec_multi` kernels runs over fixed-width chunks — eight `f64`
//! lanes at a time — the compiler can vectorize. Per-lane
//! accumulation order is unaffected — a lane's column sees exactly the
//! scalar op sequence at any stride.
//!
//! **Exactness contract:** per lane, the floating-point operation sequence
//! is identical to a scalar [`Gql`] run *by construction*: both drivers
//! advance the same [`LaneCore`](crate::quadrature::recurrence::LaneCore)
//! (one owner of the Sherman–Morrison recurrence, corrections, breakdown
//! detection, and the per-column Lanczos step), and the specialized
//! `matvec_multi` impls preserve per-lane accumulation order. Block
//! results are therefore bit-identical to scalar results — still asserted
//! by the `block_width = 1` property tests in `rust/tests/prop_block.rs`.
//!
//! Reorthogonalization (§5.4): lanes accept
//! [`Reorth::Full`](crate::quadrature::Reorth) — each lane
//! stores its own deinterleaved basis and applies the scalar engine's
//! two-pass Gram–Schmidt column-wise inside the interleaved panel, so the
//! bit-identity contract extends to the ill-conditioned regime (O(n·i)
//! extra per lane-iteration, same as scalar).

use super::gql::{Bounds, Gql, GqlOptions};
use super::is_zero;
use super::recurrence::LaneCore;
use crate::sparse::SymOp;
use std::collections::VecDeque;

pub use crate::sparse::PANEL_PAD;

/// Stride for `lanes` interleaved columns: exactly 1 for a single lane
/// (the scalar memory layout — the structural bit-identity anchor), the
/// half-chunk width `PANEL_PAD / 2` for 2..=4 lanes (narrow compare /
/// threshold panels would double their memory under full-width padding
/// for no extra vector throughput — the kernels carry a 4-lane
/// half-chunk path), else the next multiple of [`PANEL_PAD`]. Pad
/// columns are zero and carry no lane, so padding never perturbs a
/// lane's accumulation.
#[inline]
fn pad_stride(lanes: usize) -> usize {
    if lanes <= 1 {
        lanes
    } else if lanes <= PANEL_PAD / 2 {
        PANEL_PAD / 2
    } else {
        lanes.div_ceil(PANEL_PAD) * PANEL_PAD
    }
}

/// When a lane is allowed to leave the panel.
///
/// **Invariant:** every admitted query performs at least one iteration —
/// stop rules are only consulted *after* a sweep, so a zero iteration
/// budget cannot be honored. [`StopRule::normalized`] (applied by
/// [`BlockGql::push`] and [`run_scalar`]) floors `Iters(0)` to `Iters(1)`
/// accordingly, matching the `max_iters` floor in [`Gql::new`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StopRule {
    /// Run to Krylov exhaustion (or the iteration budget).
    Exhaust,
    /// Stop once the bound bracket width drops below an absolute tolerance.
    GapAbs(f64),
    /// Stop once the bracket width drops below `tol * upper` (relative).
    GapRel(f64),
    /// Stop as soon as the Radau bounds decide `t < u^T A^{-1} u`; the
    /// decision lands in [`BlockResult::decision`] (paper Alg. 4 semantics).
    Threshold(f64),
    /// Stop after a fixed number of iterations (floored to 1 on
    /// admission — see the type-level invariant).
    Iters(usize),
}

impl StopRule {
    /// Enforce the type-level invariant: `Iters(0)` still runs one full
    /// iteration (the rule is only consulted after a sweep), so it is
    /// floored to `Iters(1)` when a query is admitted instead of silently
    /// overshooting its budget.
    pub fn normalized(self) -> Self {
        match self {
            StopRule::Iters(0) => StopRule::Iters(1),
            s => s,
        }
    }
}

/// Why a scheduler evicted a lane before its own stop rule fired.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetireReason {
    /// Interval dominance: the lane's upper bound fell below a rival's
    /// lower bound, so no further refinement can change the surrounding
    /// decision (Thm. 3.3–3.4 monotonicity is what makes this sound).
    Dominated,
    /// The surrounding decision resolved without needing this lane's
    /// refinement (e.g. a race crowned its winner).
    Decided,
}

/// Record of one [`BlockGql::retire`] call.
#[derive(Clone, Copy, Debug)]
pub struct RetireEvent {
    /// Query id (push order) of the evicted lane.
    pub id: usize,
    pub reason: RetireReason,
    /// Quadrature iterations the lane had consumed when evicted.
    pub iters: usize,
}

/// Outcome of one lane.
#[derive(Clone, Debug)]
pub struct BlockResult {
    /// Push order (0-based): results from [`BlockGql::run_all`] are sorted
    /// by this id, matching the order queries were pushed.
    pub id: usize,
    /// Final bounds when the lane exited.
    pub bounds: Bounds,
    /// For [`StopRule::Threshold`]: the decision `t < u^T A^{-1} u`
    /// (midpoint fallback when the iteration budget ran out first).
    pub decision: Option<bool>,
    /// Quadrature iterations the lane consumed.
    pub iters: usize,
    /// Per-iteration bound history (empty unless recording was enabled
    /// via [`BlockGql::record_history`]).
    pub history: Vec<Bounds>,
    /// Recorded `(alpha, beta)` Lanczos coefficients (empty unless the
    /// query was admitted via [`BlockGql::push_recorded`]). `beta_i` is
    /// the off-diagonal produced by step `i`, so the k-step Jacobi matrix
    /// reads `alpha_1..alpha_k` over `beta_1..beta_{k-1}`.
    pub jacobi: Vec<(f64, f64)>,
}

/// Should a run with these bounds stop, and with what threshold decision?
///
/// Shared verbatim by the block lanes and the scalar reference driver
/// [`run_scalar`], so the two paths terminate at exactly the same
/// iteration with exactly the same decision — the invariant the block DPP
/// greedy's "identical selections" guarantee rests on. `n` is the operator
/// dimension, `max_iters` the (already clamped) budget.
pub fn stop_decision(
    b: &Bounds,
    stop: &StopRule,
    n: usize,
    max_iters: usize,
) -> Option<Option<bool>> {
    let threshold_of = |t: f64, val: f64| Some(Some(t < val));
    if b.exact {
        // breakdown: the Gauss value is the exact BIF (Lemma 15)
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.gauss),
            _ => Some(None),
        };
    }
    match *stop {
        StopRule::Threshold(t) => {
            if t < b.radau_lower {
                return Some(Some(true));
            }
            if t >= b.radau_upper {
                return Some(Some(false));
            }
        }
        StopRule::GapAbs(tol) => {
            if b.gap() <= tol {
                return Some(None);
            }
        }
        StopRule::GapRel(tol) => {
            if b.gap() <= tol * b.upper().abs() {
                return Some(None);
            }
        }
        StopRule::Iters(k) => {
            if b.iter >= k {
                return Some(None);
            }
        }
        StopRule::Exhaust => {}
    }
    if b.iter >= n {
        // Krylov space full: value exact even without a breakdown flag
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.gauss),
            _ => Some(None),
        };
    }
    if b.iter >= max_iters {
        // budget: decide at the bracket midpoint, like the scalar judges
        return match *stop {
            StopRule::Threshold(t) => threshold_of(t, b.mid()),
            _ => Some(None),
        };
    }
    None
}

/// Scalar reference path: one query driven through [`Gql`] with the same
/// stopping logic as a block lane. `BlockGql` with `width = 1` reproduces
/// this bit-for-bit; apps use it as their non-batched code path.
pub fn run_scalar(
    op: &dyn SymOp,
    u: &[f64],
    opts: GqlOptions,
    stop: StopRule,
    record_history: bool,
) -> BlockResult {
    let stop = stop.normalized();
    if is_zero(u) {
        return zero_result(0, &stop);
    }
    let n = op.dim();
    let max_iters = opts.max_iters.min(n).max(1);
    let mut q = Gql::new(op, u, opts);
    let mut history = Vec::new();
    loop {
        let b = q.step();
        if record_history {
            history.push(b);
        }
        if let Some(decision) = stop_decision(&b, &stop, n, max_iters) {
            return BlockResult {
                id: 0,
                bounds: b,
                decision,
                iters: b.iter,
                history,
                jacobi: Vec::new(),
            };
        }
    }
}

/// One lane: id + stop rule + the shared recurrence core (the
/// Sherman–Morrison state and reorth basis live in [`LaneCore`]; the
/// Lanczos vectors live in the engine's interleaved panels).
struct Lane {
    id: usize,
    stop: StopRule,
    core: LaneCore,
    history: Vec<Bounds>,
}

impl Lane {
    /// Placeholder lane; [`BlockGql::write_query`] (or a resume) installs
    /// the real core once the query vector is in the panel.
    fn new(id: usize, stop: StopRule, opts: &GqlOptions) -> Self {
        Lane { id, stop, core: LaneCore::new(opts, 0.0), history: Vec::new() }
    }
}

/// A query waiting for a panel column: either fresh (never stepped) or a
/// suspended lane carrying its full mid-run state (recurrence core and
/// both Lanczos columns), which re-enters the panel and continues with an
/// op sequence identical to an uninterrupted run.
enum Pending {
    Fresh { id: usize, u: Vec<f64>, stop: StopRule, record_jacobi: bool },
    Suspended(Box<SuspendedLane>),
}

impl Pending {
    fn id(&self) -> usize {
        match self {
            Pending::Fresh { id, .. } => *id,
            Pending::Suspended(s) => s.id,
        }
    }

    fn iters(&self) -> usize {
        match self {
            Pending::Fresh { .. } => 0,
            Pending::Suspended(s) => s.core.iterations(),
        }
    }
}

/// Deinterleaved mid-run lane state (see [`BlockGql::suspend`]).
struct SuspendedLane {
    id: usize,
    stop: StopRule,
    core: LaneCore,
    v_prev: Vec<f64>,
    v_curr: Vec<f64>,
    history: Vec<Bounds>,
}

/// Batched GQL engine: push queries, then [`BlockGql::run_all`] — or
/// drive it sweep by sweep with [`BlockGql::step_panel`].
///
/// The engine does not own (or borrow) its operator: the caller passes
/// `&dyn SymOp` into every sweeping call ([`BlockGql::step_panel`] /
/// [`BlockGql::run_all`]), which is what lets owners of panel state — the
/// resident multi-tenant engine, app structs — be `'static` while the
/// operator lives in a shared store. **Caller contract:** every sweep of
/// one `BlockGql` must receive the same operator it was constructed
/// against (same dimension, same matrix); the constructor records the
/// dimension and sweeps debug-assert it.
pub struct BlockGql {
    opts: GqlOptions,
    n: usize,
    /// configured maximum *lane* count B (the stride may exceed it by
    /// SIMD padding)
    width: usize,
    /// current panel stride: `pad_stride(lanes.len())` — equal to the lane
    /// count for 0 or 1 lanes, padded to a multiple of [`PANEL_PAD`] (or
    /// its 4-lane half-chunk) otherwise (pad columns are zero and carry
    /// no lane)
    b: usize,
    // interleaved panels, `n * b`: column `l` of lane `l` at `[i * b + l]`
    v_prev: Vec<f64>,
    v_curr: Vec<f64>,
    w: Vec<f64>,
    lanes: Vec<Lane>,
    pending: VecDeque<Pending>,
    /// lanes parked by [`BlockGql::suspend`], re-queued by `resume`
    parked: Vec<Pending>,
    done: Vec<BlockResult>,
    retired: Vec<RetireEvent>,
    next_id: usize,
    record_history: bool,
    sweeps: usize,
}

impl BlockGql {
    /// Engine sized for `op` with panel width `width` (`op` is only read
    /// for its dimension here — the same operator must then be passed to
    /// every sweep). Like [`Gql::new`], `opts.max_iters` is clamped to the
    /// operator dimension (no lane can usefully iterate past Krylov
    /// exhaustion). `opts.reorth` applies to every lane (per-lane basis
    /// storage; see the module docs).
    pub fn new(op: &dyn SymOp, opts: GqlOptions, width: usize) -> Self {
        let n = op.dim();
        assert!(width >= 1, "block width must be at least 1");
        assert!(
            opts.lam_min > 0.0 && opts.lam_max > opts.lam_min,
            "need 0 < lam_min < lam_max (got {} .. {})",
            opts.lam_min,
            opts.lam_max
        );
        let mut opts = opts;
        opts.max_iters = opts.max_iters.min(n).max(1);
        BlockGql {
            opts,
            n,
            width,
            b: 0,
            v_prev: Vec::new(),
            v_curr: Vec::new(),
            w: Vec::new(),
            lanes: Vec::new(),
            pending: VecDeque::new(),
            parked: Vec::new(),
            done: Vec::new(),
            retired: Vec::new(),
            next_id: 0,
            record_history: false,
            sweeps: 0,
        }
    }

    /// Record per-iteration bound histories into each [`BlockResult`].
    pub fn record_history(mut self, yes: bool) -> Self {
        self.record_history = yes;
        self
    }

    /// In-place form of [`BlockGql::record_history`], for owners that
    /// hold the engine behind a field (the convergence-tracing hook of
    /// [`Session`](super::query::Session)). History recording sits
    /// outside the recurrence arithmetic, so toggling it cannot change
    /// any lane's floating-point op sequence.
    pub fn set_record_history(&mut self, yes: bool) {
        self.record_history = yes;
    }

    /// Queue a query `u^T op^{-1} u`; returns its id (push order). Zero
    /// queries resolve immediately (BIF = 0 exactly) without taking a lane.
    pub fn push(&mut self, u: &[f64], stop: StopRule) -> usize {
        self.push_with(u, stop, false)
    }

    /// [`BlockGql::push`] with per-iteration `(alpha, beta)` Jacobi
    /// recording enabled for this lane (see [`LaneCore::jacobi`]):
    /// [`BlockResult::jacobi`] carries the full transcript and
    /// [`BlockGql::lane_jacobi`] exposes it mid-run. Recording is pure
    /// observation — it cannot change any bound bit.
    pub fn push_recorded(&mut self, u: &[f64], stop: StopRule) -> usize {
        self.push_with(u, stop, true)
    }

    fn push_with(&mut self, u: &[f64], stop: StopRule, record_jacobi: bool) -> usize {
        assert_eq!(u.len(), self.n, "dimension mismatch");
        let stop = stop.normalized();
        let id = self.next_id;
        self.next_id += 1;
        if is_zero(u) {
            self.done.push(zero_result(id, &stop));
        } else {
            self.pending.push_back(Pending::Fresh { id, u: u.to_vec(), stop, record_jacobi });
        }
        id
    }

    /// Operator dimension this engine was constructed for.
    #[inline]
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Mid-run Jacobi transcript of the (active or parked) lane `id`, if
    /// it was admitted with [`BlockGql::push_recorded`]. `None` for
    /// unknown ids, non-recording lanes, and lanes still in the fresh
    /// pending queue.
    pub fn lane_jacobi(&self, id: usize) -> Option<&[(f64, f64)]> {
        if let Some(l) = self.lanes.iter().find(|l| l.id == id) {
            return l.core.jacobi();
        }
        let parked_or_pending = self.parked.iter().chain(self.pending.iter());
        for p in parked_or_pending {
            if let Pending::Suspended(s) = p {
                if s.id == id {
                    return s.core.jacobi();
                }
            }
        }
        None
    }

    /// Panel sweeps performed so far (each = one `matvec_multi`, i.e. one
    /// traversal of the shared operator regardless of lane count).
    pub fn sweeps(&self) -> usize {
        self.sweeps
    }

    /// True while un-finished queries remain in the panel or the queue
    /// (suspended lanes do not count until resumed).
    pub fn has_work(&self) -> bool {
        !self.lanes.is_empty() || !self.pending.is_empty()
    }

    /// Ids and latest bounds of the lanes currently in the panel (freshly
    /// admitted lanes report `None` until their first sweep).
    pub fn active(&self) -> impl Iterator<Item = (usize, Option<Bounds>)> + '_ {
        self.lanes.iter().map(|l| (l.id, l.core.last_bounds()))
    }

    /// Drain the finished results accumulated so far, sorted by id.
    pub fn take_done(&mut self) -> Vec<BlockResult> {
        let mut out = std::mem::take(&mut self.done);
        out.sort_by_key(|r| r.id);
        out
    }

    /// Eviction log: every [`BlockGql::retire`] call with its reason.
    pub fn retired(&self) -> &[RetireEvent] {
        &self.retired
    }

    /// One scheduler round against `op` (which must be the operator this
    /// engine was constructed for): admit pending queries up to the
    /// configured width, then advance every lane by one `matvec_multi`
    /// panel sweep. Returns `false` (without sweeping) once no lane or
    /// pending query remains. Completed lanes land in
    /// [`BlockGql::take_done`] and their columns refill from the queue,
    /// exactly as under `run_all`.
    pub fn step_panel(&mut self, op: &dyn SymOp) -> bool {
        self.admit();
        if self.lanes.is_empty() {
            return false;
        }
        self.sweep(op);
        true
    }

    /// Run until every queued query has completed; results sorted by id.
    /// Queries evicted by [`BlockGql::retire`] produce no result, and
    /// suspended lanes are not resumed implicitly.
    pub fn run_all(&mut self, op: &dyn SymOp) -> Vec<BlockResult> {
        while self.step_panel(op) {}
        self.take_done()
    }

    /// Evict the (active or pending) query `id` before its stop rule
    /// fires, recording the reason; an active lane's panel column refills
    /// from the pending queue (or the panel compacts). Returns `false` if
    /// `id` is not currently active or pending. The evicted query yields
    /// no [`BlockResult`].
    pub fn retire(&mut self, id: usize, reason: RetireReason) -> bool {
        if let Some(slot) = self.lanes.iter().position(|l| l.id == id) {
            let iters = self.lanes[slot].core.iterations();
            self.retired.push(RetireEvent { id, reason, iters });
            self.evict_slot(slot);
            return true;
        }
        if let Some(pos) = self.pending.iter().position(|p| p.id() == id) {
            let p = self.pending.remove(pos).expect("position just found");
            self.retired.push(RetireEvent { id, reason, iters: p.iters() });
            return true;
        }
        false
    }

    /// Park the (active or pending) query `id`: its full mid-run state —
    /// recurrence core, reorth basis, both Lanczos columns — is pulled out
    /// of the panel so the column can serve another query. A later
    /// [`BlockGql::resume`] re-queues it and the lane continues with an op
    /// sequence identical to an uninterrupted run (bit-exactness is
    /// preserved across the round trip). Returns `false` for unknown ids.
    pub fn suspend(&mut self, id: usize) -> bool {
        if let Some(slot) = self.lanes.iter().position(|l| l.id == id) {
            let b = self.b;
            let vp: Vec<f64> = (0..self.n).map(|i| self.v_prev[i * b + slot]).collect();
            let vc: Vec<f64> = (0..self.n).map(|i| self.v_curr[i * b + slot]).collect();
            let lane = self.evict_slot(slot);
            self.parked.push(Pending::Suspended(Box::new(SuspendedLane {
                id: lane.id,
                stop: lane.stop,
                core: lane.core,
                v_prev: vp,
                v_curr: vc,
                history: lane.history,
            })));
            return true;
        }
        if let Some(pos) = self.pending.iter().position(|p| p.id() == id) {
            let p = self.pending.remove(pos).expect("position just found");
            self.parked.push(p);
            return true;
        }
        false
    }

    /// Re-queue a suspended query; it re-enters the panel at the next
    /// admission round. Returns `false` for ids that are not parked.
    pub fn resume(&mut self, id: usize) -> bool {
        if let Some(pos) = self.parked.iter().position(|p| p.id() == id) {
            let p = self.parked.remove(pos);
            self.pending.push_back(p);
            return true;
        }
        false
    }

    /// Admit pending queries up to the configured width (growing the
    /// panel stride).
    fn admit(&mut self) {
        let m = (self.width - self.lanes.len()).min(self.pending.len());
        if m == 0 {
            return;
        }
        self.grow(m);
        for _ in 0..m {
            let p = self.pending.pop_front().expect("counted above");
            let slot = self.lanes.len();
            self.lanes.push(Lane::new(p.id(), StopRule::Exhaust, &self.opts));
            self.install(slot, p);
        }
    }

    /// Install a pending query into lane `slot` (which must exist):
    /// fresh queries get a normalized column and a fresh core, suspended
    /// lanes get their saved columns and core back verbatim.
    fn install(&mut self, slot: usize, p: Pending) {
        match p {
            Pending::Fresh { id, u, stop, record_jacobi } => {
                self.lanes[slot] = Lane::new(id, stop, &self.opts);
                self.write_query(slot, &u, record_jacobi);
            }
            Pending::Suspended(s) => {
                let b = self.b;
                for i in 0..self.n {
                    self.v_prev[i * b + slot] = s.v_prev[i];
                    self.v_curr[i * b + slot] = s.v_curr[i];
                }
                let mut lane = Lane::new(s.id, s.stop, &self.opts);
                lane.core = s.core;
                lane.history = s.history;
                self.lanes[slot] = lane;
            }
        }
    }

    /// Install `u` into lane `slot`: `v_curr` column = normalized query,
    /// `v_prev` column = 0, recurrence core fresh.
    fn write_query(&mut self, slot: usize, u: &[f64], record_jacobi: bool) {
        let b = self.b;
        let unorm2: f64 = u.iter().map(|x| x * x).sum();
        debug_assert!(unorm2 > 0.0, "zero queries never reach a lane");
        let inv_norm = 1.0 / unorm2.sqrt();
        for (i, &ui) in u.iter().enumerate() {
            self.v_prev[i * b + slot] = 0.0;
            self.v_curr[i * b + slot] = ui * inv_norm;
        }
        let opts = self.opts;
        let lane = &mut self.lanes[slot];
        lane.core = LaneCore::new(&opts, unorm2);
        lane.core.set_record_jacobi(record_jacobi);
        lane.history = Vec::new();
    }

    /// Remove the lane at `slot` from the panel and return it, refilling
    /// the slot from the pending queue when possible and repacking the
    /// panels otherwise.
    fn evict_slot(&mut self, slot: usize) -> Lane {
        if let Some(p) = self.pending.pop_front() {
            let placeholder = Lane::new(p.id(), StopRule::Exhaust, &self.opts);
            let lane = std::mem::replace(&mut self.lanes[slot], placeholder);
            self.install(slot, p);
            lane
        } else {
            let lane = self.lanes.remove(slot);
            let old_count = self.lanes.len() + 1;
            let keep: Vec<usize> = (0..old_count).filter(|&s| s != slot).collect();
            self.repack_panels(&keep);
            lane
        }
    }

    /// Widen the panels to hold `m` more lanes (in-place backward repack:
    /// for each row the write offset `i * new_b + l` is ≥ the read offset
    /// `i * b + l`, so iterating rows and lanes in descending order never
    /// clobbers unread data). The new stride is SIMD-padded; pad and
    /// not-yet-admitted columns are zeroed.
    fn grow(&mut self, m: usize) {
        let (n, ob) = (self.n, self.b);
        let nb = pad_stride(self.lanes.len() + m);
        debug_assert!(nb >= ob, "stride shrank on grow");
        if nb == ob {
            return; // new lanes fit inside the existing pad columns
        }
        for panel in [&mut self.v_prev, &mut self.v_curr] {
            panel.resize(n * nb, 0.0);
            for i in (0..n).rev() {
                for l in (0..ob).rev() {
                    panel[i * nb + l] = panel[i * ob + l];
                }
                for l in ob..nb {
                    panel[i * nb + l] = 0.0;
                }
            }
        }
        self.w.resize(n * nb, 0.0);
        self.w.fill(0.0);
        self.b = nb;
    }

    /// Forward in-place repack of the panels onto the lane slots listed in
    /// `keep` (ascending old slot indices) — the mirror argument of
    /// [`BlockGql::grow`]. The caller keeps `self.lanes` in sync. Pad
    /// columns of the (possibly shorter) new stride are zeroed.
    fn repack_panels(&mut self, keep: &[usize]) {
        let (n, ob) = (self.n, self.b);
        let nl = keep.len();
        let nb = pad_stride(nl);
        debug_assert!(nb <= ob, "stride grew on repack");
        for panel in [&mut self.v_prev, &mut self.v_curr] {
            for i in 0..n {
                for (nlane, &ol) in keep.iter().enumerate() {
                    panel[i * nb + nlane] = panel[i * ob + ol];
                }
                for c in nl..nb {
                    panel[i * nb + c] = 0.0;
                }
            }
            panel.truncate(n * nb);
        }
        self.w.truncate(n * nb);
        self.b = nb;
    }

    /// One lockstep iteration: a single panel sweep of the operator plus
    /// one [`LaneCore::step_column`] per lane (the scalar engine's exact
    /// op sequence on each column — see `quadrature::recurrence`).
    /// Completed lanes are emitted, refilled from the queue in place, or
    /// compacted away.
    fn sweep(&mut self, op: &dyn SymOp) {
        let (n, b) = (self.n, self.b);
        let nl = self.lanes.len();
        debug_assert!(nl > 0 && b >= nl);
        debug_assert_eq!(op.dim(), n, "sweep operator must match construction");
        op.matvec_multi(&self.v_curr, &mut self.w, b);
        self.sweeps += 1;

        let max_iters = self.opts.max_iters;
        let mut finished: Vec<(usize, Option<bool>)> = Vec::new();
        for l in 0..nl {
            let lane = &mut self.lanes[l];
            let bounds = lane.core.step_column(
                &mut self.v_prev,
                &mut self.v_curr,
                &mut self.w,
                n,
                b,
                l,
            );
            if self.record_history {
                lane.history.push(bounds);
            }
            if let Some(decision) = stop_decision(&bounds, &lane.stop, n, max_iters) {
                finished.push((l, decision));
            }
        }

        // --- emit finished lanes; refill in place while the queue lasts ---
        let mut dead: Vec<usize> = Vec::new();
        for (slot, decision) in finished {
            {
                let lane = &mut self.lanes[slot];
                self.done.push(BlockResult {
                    id: lane.id,
                    bounds: lane.core.last_bounds().expect("finished lane has bounds"),
                    decision,
                    iters: lane.core.iterations(),
                    history: std::mem::take(&mut lane.history),
                    jacobi: lane.core.jacobi().map(<[_]>::to_vec).unwrap_or_default(),
                });
            }
            if let Some(p) = self.pending.pop_front() {
                self.install(slot, p);
            } else {
                dead.push(slot);
            }
        }
        if !dead.is_empty() {
            let keep: Vec<usize> = (0..nl).filter(|s| !dead.contains(s)).collect();
            let old = std::mem::take(&mut self.lanes);
            let mut it = keep.iter().peekable();
            for (slot, lane) in old.into_iter().enumerate() {
                if it.peek() == Some(&&slot) {
                    it.next();
                    self.lanes.push(lane);
                }
            }
            self.repack_panels(&keep);
        }
    }
}

/// Immediately-exact result for a zero query (`BIF = 0`).
fn zero_result(id: usize, stop: &StopRule) -> BlockResult {
    let bounds = Bounds {
        iter: 0,
        gauss: 0.0,
        radau_lower: 0.0,
        radau_upper: 0.0,
        lobatto: 0.0,
        exact: true,
    };
    let decision = match *stop {
        StopRule::Threshold(t) => Some(t < 0.0),
        _ => None,
    };
    BlockResult { id, bounds, decision, iters: 0, history: Vec::new(), jacobi: Vec::new() }
}

/// One-shot convenience: run `queries` (pairs of query vector and stop
/// rule) through a width-`width` block engine; results in push order.
/// Queries are borrowed so timed comparisons against the scalar path
/// don't pay per-query clones.
pub fn block_solve<'q>(
    op: &dyn SymOp,
    opts: GqlOptions,
    width: usize,
    queries: impl IntoIterator<Item = (&'q [f64], StopRule)>,
) -> Vec<BlockResult> {
    let mut engine = BlockGql::new(op, opts, width);
    for (u, stop) in queries {
        engine.push(u, stop);
    }
    engine.run_all(op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::random_sparse_spd;
    use crate::quadrature::gql::Reorth;
    use crate::quadrature::judge_threshold;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn width_one_is_bit_identical_to_scalar() {
        forall(15, 0xB70C, |rng| {
            let n = 4 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let opts = GqlOptions::new(w.lo, w.hi);
            let scalar = run_scalar(&a, &u, opts, StopRule::Exhaust, true);
            let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
            eng.push(&u, StopRule::Exhaust);
            let block = eng.run_all(&a).pop().unwrap();
            assert_eq!(scalar.history.len(), block.history.len());
            for (s, b) in scalar.history.iter().zip(&block.history) {
                assert_eq!(s.gauss.to_bits(), b.gauss.to_bits());
                assert_eq!(s.radau_lower.to_bits(), b.radau_lower.to_bits());
                assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
                assert_eq!(s.lobatto.to_bits(), b.lobatto.to_bits());
                assert_eq!(s.exact, b.exact);
            }
        });
    }

    #[test]
    fn thresholds_match_scalar_judge_decisions() {
        forall(10, 0xB71D, |rng| {
            let n = 6 + rng.below(20);
            let (a, w) = random_sparse_spd(rng, n, 0.4, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi);
            let mut eng = BlockGql::new(&a, opts, 4);
            let mut want = Vec::new();
            for _ in 0..9 {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let exact = crate::quadrature::cg::cg_bif_estimate(&a, &u, 1e-14, 10 * n);
                let t = exact * (0.5 + rng.f64());
                let (dec, _) = judge_threshold(&a, &u, t, opts);
                eng.push(&u, StopRule::Threshold(t));
                want.push(dec);
            }
            let got = eng.run_all(&a);
            assert_eq!(got.len(), want.len());
            for (r, w) in got.iter().zip(&want) {
                assert_eq!(r.decision, Some(*w), "lane {}", r.id);
            }
        });
    }

    #[test]
    fn refill_and_compaction_preserve_per_query_results() {
        // more queries than lanes, stopping at different iterations, so
        // lanes exit, refill from the queue, and finally compact
        let mut rng = Rng::new(0xB72E);
        let n = 40;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.1, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let rules = [
            StopRule::Iters(1),
            StopRule::Iters(7),
            StopRule::GapRel(1e-4),
            StopRule::Exhaust,
        ];
        let queries: Vec<(Vec<f64>, StopRule)> = (0..13)
            .map(|i| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                (u, rules[i % rules.len()])
            })
            .collect();
        let block = block_solve(&a, opts, 3, queries.iter().map(|(u, s)| (u.as_slice(), *s)));
        assert_eq!(block.len(), queries.len());
        for (r, (u, stop)) in block.iter().zip(&queries) {
            let scalar = run_scalar(&a, u, opts, *stop, false);
            assert_eq!(r.iters, scalar.iters, "query {}", r.id);
            assert_eq!(r.bounds.gauss.to_bits(), scalar.bounds.gauss.to_bits());
            assert_eq!(
                r.bounds.radau_upper.to_bits(),
                scalar.bounds.radau_upper.to_bits()
            );
        }
    }

    #[test]
    fn zero_query_resolves_immediately() {
        let mut rng = Rng::new(0xB73F);
        let (a, w) = random_sparse_spd(&mut rng, 10, 0.3, 0.05);
        let mut eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 2);
        let id = eng.push(&vec![0.0; 10], StopRule::Threshold(-1.0));
        let u: Vec<f64> = (0..10).map(|_| rng.normal()).collect();
        eng.push(&u, StopRule::Exhaust);
        let out = eng.run_all(&a);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].id, id);
        assert_eq!(out[0].iters, 0);
        assert_eq!(out[0].decision, Some(true), "-1 < 0 exactly");
        assert!(out[0].bounds.exact);
    }

    #[test]
    fn max_iters_is_clamped_to_dimension() {
        let mut rng = Rng::new(0xB740);
        let (a, w) = random_sparse_spd(&mut rng, 8, 0.5, 0.05);
        let eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 2);
        assert_eq!(eng.opts.max_iters, 8);
    }

    #[test]
    fn panel_stays_dense_while_queue_lasts() {
        // 8 one-iteration queries through width 4: every sweep should
        // advance a full panel, so 2 sweeps finish everything
        let mut rng = Rng::new(0xB751);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.2, 0.05);
        let mut eng = BlockGql::new(&a, GqlOptions::new(w.lo, w.hi), 4);
        for _ in 0..8 {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            eng.push(&u, StopRule::Iters(1));
        }
        let out = eng.run_all(&a);
        assert_eq!(out.len(), 8);
        assert_eq!(eng.sweeps(), 2, "refill must keep the panel dense");
    }

    #[test]
    fn padded_stride_is_a_stride_multiple_with_lanes_preserved() {
        assert_eq!(pad_stride(0), 0);
        assert_eq!(pad_stride(1), 1, "width-1 keeps the scalar layout");
        assert_eq!(pad_stride(2), 4, "narrow panels pad to the half-chunk");
        assert_eq!(pad_stride(4), 4);
        assert_eq!(pad_stride(5), 8);
        assert_eq!(pad_stride(8), 8);
        assert_eq!(pad_stride(9), 16, "above one chunk: full PANEL_PAD multiples");
        assert_eq!(pad_stride(17), 24);
        // a width whose stride is padded (5 lanes → stride 8) still
        // reproduces every scalar run bit-for-bit
        let mut rng = Rng::new(0xB752);
        let n = 28;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let queries: Vec<Vec<f64>> = (0..5)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let out = block_solve(
            &a,
            opts,
            5,
            queries.iter().map(|u| (u.as_slice(), StopRule::Exhaust)),
        );
        for (r, u) in out.iter().zip(&queries) {
            let s = run_scalar(&a, u, opts, StopRule::Exhaust, false);
            assert_eq!(r.bounds.gauss.to_bits(), s.bounds.gauss.to_bits());
            assert_eq!(r.iters, s.iters);
        }
    }

    #[test]
    fn reorth_lanes_are_bit_identical_to_scalar_reorth() {
        // every lane of a reorthogonalized panel must reproduce its own
        // scalar Reorth::Full run bit-for-bit — the exactness contract
        // extended to §5.4 (ISSUE 2 tentpole)
        forall(10, 0xB762, |rng| {
            let n = 6 + rng.below(24);
            let (a, w) = random_sparse_spd(rng, n, 0.3, 0.05);
            let opts = GqlOptions::new(w.lo, w.hi).with_reorth(Reorth::Full);
            let m = 1 + rng.below(6);
            let width = 1 + rng.below(m);
            let queries: Vec<Vec<f64>> = (0..m)
                .map(|_| (0..n).map(|_| rng.normal()).collect())
                .collect();
            let mut eng = BlockGql::new(&a, opts, width).record_history(true);
            for u in &queries {
                eng.push(u, StopRule::Exhaust);
            }
            for (r, u) in eng.run_all(&a).iter().zip(&queries) {
                let scalar = run_scalar(&a, u, opts, StopRule::Exhaust, true);
                assert_eq!(scalar.history.len(), r.history.len(), "query {}", r.id);
                for (s, b) in scalar.history.iter().zip(&r.history) {
                    assert_eq!(s.gauss.to_bits(), b.gauss.to_bits(), "query {}", r.id);
                    assert_eq!(s.radau_lower.to_bits(), b.radau_lower.to_bits());
                    assert_eq!(s.radau_upper.to_bits(), b.radau_upper.to_bits());
                    assert_eq!(s.lobatto.to_bits(), b.lobatto.to_bits());
                    assert_eq!(s.exact, b.exact);
                }
            }
        });
    }

    #[test]
    fn iters_zero_is_floored_to_one_iteration() {
        // StopRule::Iters(0) would otherwise run a full sweep and then
        // report it stopped "within budget" — the normalized() floor makes
        // the one-iteration minimum explicit (ISSUE 2 satellite)
        let mut rng = Rng::new(0xB773);
        let n = 12;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.4, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        assert_eq!(StopRule::Iters(0).normalized(), StopRule::Iters(1));
        assert_eq!(StopRule::Iters(3).normalized(), StopRule::Iters(3));
        let zero = run_scalar(&a, &u, opts, StopRule::Iters(0), false);
        let one = run_scalar(&a, &u, opts, StopRule::Iters(1), false);
        assert_eq!(zero.iters, 1);
        assert_eq!(zero.bounds.gauss.to_bits(), one.bounds.gauss.to_bits());
        let mut eng = BlockGql::new(&a, opts, 2);
        eng.push(&u, StopRule::Iters(0));
        let r = eng.run_all(&a).pop().unwrap();
        assert_eq!(r.iters, 1);
        assert_eq!(r.bounds.gauss.to_bits(), one.bounds.gauss.to_bits());
    }

    #[test]
    fn exactness_flag_set_when_krylov_space_fills() {
        // at iter == n the Gauss value is exact; the emitted Bounds must
        // say so, collapsing Bounds::upper() onto it (ISSUE 2 satellite)
        let mut rng = Rng::new(0xB784);
        let n = 10;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.5, 0.05);
        let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let opts = GqlOptions::new(w.lo, w.hi);
        let r = run_scalar(&a, &u, opts, StopRule::Exhaust, true);
        let last = r.history.last().unwrap();
        assert!(last.exact, "final bounds must be flagged exact");
        assert_eq!(last.upper(), last.gauss);
        // block path agrees
        let mut eng = BlockGql::new(&a, opts, 1).record_history(true);
        eng.push(&u, StopRule::Exhaust);
        let b = eng.run_all(&a).pop().unwrap();
        assert!(b.history.last().unwrap().exact);
    }

    #[test]
    fn step_panel_take_done_matches_run_all() {
        // the incremental API must accumulate exactly run_all's results
        let mut rng = Rng::new(0xB795);
        let n = 30;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.2, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let queries: Vec<Vec<f64>> = (0..7)
            .map(|_| (0..n).map(|_| rng.normal()).collect())
            .collect();
        let reference = block_solve(
            &a,
            opts,
            3,
            queries.iter().map(|u| (u.as_slice(), StopRule::GapRel(1e-8))),
        );
        let mut eng = BlockGql::new(&a, opts, 3);
        for u in &queries {
            eng.push(u, StopRule::GapRel(1e-8));
        }
        let mut incremental = Vec::new();
        while eng.step_panel(&a) {
            incremental.extend(eng.take_done());
        }
        incremental.extend(eng.take_done());
        incremental.sort_by_key(|r| r.id);
        assert_eq!(incremental.len(), reference.len());
        for (i, r) in incremental.iter().zip(&reference) {
            assert_eq!(i.id, r.id);
            assert_eq!(i.iters, r.iters);
            assert_eq!(i.bounds.gauss.to_bits(), r.bounds.gauss.to_bits());
        }
        assert!(!eng.has_work());
    }

    #[test]
    fn suspend_resume_round_trip_is_bit_identical() {
        // park a lane mid-run, let the rest of the panel proceed, resume
        // it: its bound history must match an uninterrupted run exactly
        let mut rng = Rng::new(0xB7A6);
        let n = 26;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let u0: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let u1: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let reference = run_scalar(&a, &u0, opts, StopRule::Exhaust, true);

        let mut eng = BlockGql::new(&a, opts, 2).record_history(true);
        let id0 = eng.push(&u0, StopRule::Exhaust);
        eng.push(&u1, StopRule::Iters(3));
        for _ in 0..2 {
            assert!(eng.step_panel(&a));
        }
        assert!(eng.suspend(id0), "active lane must suspend");
        // the other lane finishes alone
        while eng.step_panel(&a) {}
        assert!(eng.resume(id0), "parked lane must resume");
        let mut results = Vec::new();
        while eng.step_panel(&a) {}
        results.extend(eng.take_done());
        let r0 = results.iter().find(|r| r.id == id0).expect("resumed lane finished");
        assert_eq!(r0.history.len(), reference.history.len());
        for (got, want) in r0.history.iter().zip(&reference.history) {
            assert_eq!(got.gauss.to_bits(), want.gauss.to_bits());
            assert_eq!(got.radau_lower.to_bits(), want.radau_lower.to_bits());
            assert_eq!(got.radau_upper.to_bits(), want.radau_upper.to_bits());
            assert_eq!(got.lobatto.to_bits(), want.lobatto.to_bits());
        }
    }

    #[test]
    fn retire_evicts_lane_refills_panel_and_logs_reason() {
        let mut rng = Rng::new(0xB7B7);
        let n = 24;
        let (a, w) = random_sparse_spd(&mut rng, n, 0.3, 0.05);
        let opts = GqlOptions::new(w.lo, w.hi);
        let mut eng = BlockGql::new(&a, opts, 2);
        let ids: Vec<usize> = (0..4)
            .map(|_| {
                let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                eng.push(&u, StopRule::Exhaust)
            })
            .collect();
        assert!(eng.step_panel(&a));
        // evict an active lane: its slot must refill from the queue
        assert!(eng.retire(ids[0], RetireReason::Dominated));
        let active: Vec<usize> = eng.active().map(|(id, _)| id).collect();
        assert!(!active.contains(&ids[0]));
        assert!(active.contains(&ids[2]), "pending query refilled the slot");
        // evict a still-pending query
        assert!(eng.retire(ids[3], RetireReason::Decided));
        assert!(!eng.retire(ids[3], RetireReason::Decided), "already gone");
        let out = eng.run_all(&a);
        // retired queries produce no result
        let got: Vec<usize> = out.iter().map(|r| r.id).collect();
        assert_eq!(got, vec![ids[1], ids[2]]);
        let events = eng.retired();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].id, ids[0]);
        assert_eq!(events[0].reason, RetireReason::Dominated);
        assert!(events[0].iters >= 1);
        assert_eq!(events[1].id, ids[3]);
        assert_eq!(events[1].iters, 0, "never admitted");
        // survivors ran to their own stop rules undisturbed (bit-identity
        // of survivors under eviction is property-tested in prop_race)
        assert!(out.iter().all(|r| r.bounds.exact || r.iters == n));
    }
}
